#!/usr/bin/env bash
# Perf + lint gate for the native kernel layer.
#
#   scripts/bench_check.sh
#
# Runs `cargo fmt --check` and `cargo clippy -D warnings`, then the capped
# precond benchmark (BENCH_MAX_D=256) and the optimizer-step benchmark,
# and fails if:
#   * any recorded RMNP speedup (Table 2 ratio) drops below 1.0,
#   * any seed-vs-kernel improvement drops below 1.0,
#   * any vector-rung-vs-scalar ns5 speedup drops below 1.0, or rownorm
#     below 0.9 (rownorm is memory-bandwidth-bound, so parity + noise
#     margin is the honest bar on shared runners; skipped entirely when
#     the CPU has no vector rung — AVX2 on x86-64, NEON on aarch64 — or
#     RMNP_SIMD=scalar forces the portable rung),
#   * the median seed-vs-kernel improvement falls below half of the most
#     recent bench_history/ snapshot (skipped with a notice on the first
#     run, when no prior-PR snapshot exists yet),
#   * the anomaly guard's per-step overhead exceeds 15% (it only inspects
#     two scalars, so anything above noise level is a regression), or the
#     checkpoint walkback/roundtrip recovery flags come back false,
#   * the distributed coordinator's per-step overhead at worker count 1
#     (localhost TCP, CRC framing both ways) exceeds 2.5x the plain local
#     loop (the overlapped chunk streaming bought the headroom to tighten
#     this from the old 4x bar), the dist run's final weights stop being
#     bit-exact against the local loop, or bf16 wire compression stops
#     cutting total wire bytes/step to <= 0.55x the f32 baseline,
#   * the optimizer-zoo shootout loses registry coverage (every registry
#     entry must appear in BENCH_shootout.json as a case or an explicit
#     skip), any run diverges at its registry default LR, or rmnp's
#     isolated per-step preconditioning cost exceeds muon's at the
#     d >= 512 gate shape (the paper's O(mn) vs O(mn·min(m,n)) claim,
#     measured instead of asserted),
#   * the bf16 storage mode stops meeting its envelope: modeled
#     parameter+momentum traffic must stay <= 0.55x the f32 mode, and the
#     measured fused RMNP step must run >= 1.2x faster than f32 at the
#     d >= 1024 gate shape (speed gate skipped with a notice when
#     BENCH_MAX_D kept the big shape from running),
#   * the data pipeline stops out-producing the training consumer: every
#     corpus and the prefetching loader must clear 1e5 tokens/s.
# On success it appends dated BENCH_precond / BENCH_train_step snapshots
# to bench_history/ so the next PR has a trajectory baseline.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (default features) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo bench --bench precond (BENCH_MAX_D=${BENCH_MAX_D:-256}) =="
BENCH_MAX_D="${BENCH_MAX_D:-256}" BENCH_REPEATS="${BENCH_REPEATS:-2}" \
    cargo bench --bench precond

echo "== cargo bench --bench optim_step =="
BENCH_REPEATS="${BENCH_REPEATS:-2}" cargo bench --bench optim_step

echo "== cargo bench --bench data_pipeline =="
cargo bench --bench data_pipeline

echo "== cargo bench --bench host_train (native backend end-to-end) =="
BENCH_REPEATS="${BENCH_REPEATS:-2}" cargo bench --bench host_train

echo "== cargo bench --bench faults (guard overhead + checkpoint recovery) =="
BENCH_REPEATS="${BENCH_REPEATS:-2}" cargo bench --bench faults

echo "== cargo bench --bench dist (coordination overhead vs local loop) =="
BENCH_REPEATS="${BENCH_REPEATS:-2}" cargo bench --bench dist

echo "== cargo bench --bench shootout (optimizer zoo, matched budgets) =="
BENCH_SHOOTOUT_STEPS="${BENCH_SHOOTOUT_STEPS:-20}" BENCH_REPEATS="${BENCH_REPEATS:-2}" \
    cargo bench --bench shootout

echo "== checking BENCH_precond.json =="
# newest prior-PR snapshot, if any (first run has none — that's fine)
BASELINE="$(ls -1t "$ROOT"/bench_history/*_precond.json 2>/dev/null | head -n1 || true)"
python3 - "${BASELINE:-}" <<'EOF'
import json, sys

baseline_path = sys.argv[1] if len(sys.argv) > 1 else ""

with open("BENCH_precond.json") as f:
    doc = json.load(f)

bad = []
for row in doc["table2"]:
    if row["speedup"] < 1.0:
        bad.append(f"table2 {row['model']} speedup {row['speedup']:.2f} < 1.0")
for d in doc["seed_vs_kernel"]:
    if d["improvement"] < 1.0:
        bad.append(
            f"seed_vs_kernel {d['op']} d={d['d_model']} "
            f"improvement {d['improvement']:.2f} < 1.0"
        )
# ns5 is compute-bound and must win outright; rownorm is memory-bound, so
# require parity minus a noise margin rather than a strict win
SIMD_BAR = {"ns5": 1.0, "rownorm": 0.9}
for d in doc.get("simd_vs_scalar", []):
    bar = SIMD_BAR.get(d["op"], 1.0)
    if d["speedup"] < bar:
        bad.append(
            f"simd_vs_scalar {d['op']} d={d['d_model']} "
            f"speedup {d['speedup']:.2f} < {bar}"
        )

for row in doc["table2"]:
    print(f"  {row['model']:<6} d={row['d_model']:<5} speedup {row['speedup']:.1f}x")
for d in doc["seed_vs_kernel"]:
    print(f"  {d['op']:<8} d={d['d_model']:<5} kernel vs seed {d['improvement']:.2f}x")
simd = doc.get("simd_vs_scalar", [])
if simd:
    for d in simd:
        rung = d.get("rung", "simd")
        print(f"  {d['op']:<8} d={d['d_model']:<5} {rung} vs scalar {d['speedup']:.2f}x")
else:
    print(f"  simd rung: {doc.get('simd', '?')} (no vector-vs-scalar delta recorded)")

# trajectory gate against the newest bench_history snapshot. Absolute
# medians are machine-dependent, so compare the improvement *ratios*,
# with generous headroom (fail only on a >2x collapse).
def median_improvement(d):
    xs = sorted(x["improvement"] for x in d.get("seed_vs_kernel", []))
    return xs[len(xs) // 2] if xs else None

if baseline_path:
    with open(baseline_path) as f:
        base = json.load(f)
    b, c = median_improvement(base), median_improvement(doc)
    if b is not None and c is not None:
        name = baseline_path.rsplit("/", 1)[-1]
        if c < 0.5 * b:
            bad.append(
                f"median seed_vs_kernel improvement {c:.2f} fell below half "
                f"of baseline {b:.2f} ({name})"
            )
        else:
            print(f"  baseline {name}: median improvement {b:.2f} -> {c:.2f}")
else:
    print("  no bench_history baseline yet — skipping trajectory gate (first run)")

if bad:
    print("FAIL:")
    for b in bad:
        print("  " + b)
    sys.exit(1)
print("bench check OK")
EOF

echo "== checking BENCH_train_step.json (precision envelope) =="
python3 - <<'EOF'
import json

with open("BENCH_train_step.json") as f:
    doc = json.load(f)

bad = []
prec = doc.get("precision", [])
if not prec:
    raise SystemExit("train_step lost its precision section (f32 vs bf16 cases)")
for c in prec:
    d = max(c["rows"], c["cols"])
    ratio = c["bytes_ratio"]
    speedup = c["speedup"]
    print(
        f"  rmnp {c['rows']}x{c['cols']}  state bytes/elem "
        f"f32 {c['f32_state_bytes_per_elem']} -> bf16 {c['bf16_state_bytes_per_elem']} "
        f"(ratio {ratio:.2f})  speedup {speedup:.2f}x"
    )
    # storage contract: bf16 halves every persistent-state access
    if ratio > 0.55:
        bad.append(f"bf16 state-byte ratio {ratio:.2f} at {d} exceeds the 0.55x bar")
    # the speed gate only binds where the working set outruns cache and
    # the step is genuinely bandwidth-bound
    if d >= 1024 and speedup < 1.2:
        bad.append(f"bf16 speedup {speedup:.2f}x at d={d} below the 1.2x bar")
if not any(max(c["rows"], c["cols"]) >= 1024 for c in prec):
    print("  no d >= 1024 case ran (BENCH_MAX_D cap) — skipping the bf16 speed gate")

if bad:
    print("FAIL:")
    for b in bad:
        print("  " + b)
    raise SystemExit(1)
print("precision envelope OK")
EOF

echo "== checking BENCH_data_pipeline.json =="
python3 - <<'EOF'
import json

with open("BENCH_data_pipeline.json") as f:
    doc = json.load(f)

bad = []
corpora = doc["corpora"]
if {c["corpus"] for c in corpora} != {"markov", "zipf", "ngram"}:
    bad.append(f"corpus coverage lost: {sorted(c['corpus'] for c in corpora)}")
# the consumer bar: the largest CPU model eats ~1e5 tokens/s, so every
# producer must clear it with room to spare
for c in corpora:
    print(f"  {c['corpus']:<8} {c['tokens_per_s']/1e6:8.1f}M tokens/s")
    if c["tokens_per_s"] < 1e5:
        bad.append(f"{c['corpus']} produces {c['tokens_per_s']:.0f} tokens/s < 1e5")
loader = doc["loader"]
print(f"  loader   {loader['tokens_per_s']/1e6:8.1f}M tokens/s")
if loader["tokens_per_s"] < 1e5:
    bad.append(f"prefetch loader produces {loader['tokens_per_s']:.0f} tokens/s < 1e5")
print(f"  images   {doc['images']['images_per_s']:8.0f} images/s")
print(f"  bpe      {doc['bpe']['bytes_per_s']/1e6:8.2f} MB/s")

if bad:
    print("FAIL:")
    for b in bad:
        print("  " + b)
    raise SystemExit(1)
print("data pipeline envelope OK")
EOF

echo "== checking BENCH_host_train.json =="
python3 - <<'EOF'
import json
from collections import OrderedDict

with open("BENCH_host_train.json") as f:
    doc = json.load(f)
cases = doc["cases"]
assert cases, "host_train bench produced no cases"
# group by model arch so the per-arch envelopes are visible in CI logs
# (and so a missing arch is an error, not a silent hole in the table)
by_arch = OrderedDict()
for c in cases:
    by_arch.setdefault(c.get("arch", "?"), []).append(c)
expected = {"attention", "gated_mlp", "ssm", "conv"}
missing = expected - set(by_arch)
if missing:
    raise SystemExit(f"host_train envelope lost arch coverage: missing {sorted(missing)}")
for arch, rows in by_arch.items():
    print(f"  [{arch}]")
    for c in rows:
        print(
            f"    {c['model']:<12} {c['optimizer']:<6} "
            f"{c['steps_per_s']:>8.1f} steps/s  loss {c['final_loss']:.3f}"
        )
        if not (0.0 < c["final_loss"] < 20.0):
            raise SystemExit(f"implausible final loss in {c}")
print("host_train envelope OK")
EOF

echo "== checking BENCH_faults.json =="
python3 - <<'EOF'
import json

with open("BENCH_faults.json") as f:
    doc = json.load(f)

bad = []
# the guard reads two scalars per step — its cost must be noise against a
# full forward/backward; 15% is a generous noise allowance for shared runners
frac = doc["guard_overhead_frac"]
if frac > 0.15:
    bad.append(f"guard overhead {frac*100:.1f}% per step exceeds the 15% noise bar")
if not doc["roundtrip_ok"]:
    bad.append("checkpoint save/validated-load roundtrip lost data")
if not doc["walkback_ok"]:
    bad.append("walkback over a corrupted newest checkpoint did not recover")

print(f"  guard overhead   {frac*100:+.2f}% per step")
print(f"  ckpt save        {doc['ckpt_save_s']*1e3:.2f} ms ({doc['ckpt_bytes']} bytes)")
print(f"  ckpt load+verify {doc['ckpt_load_s']*1e3:.2f} ms")
print(f"  walkback scan    {doc['walkback_s']*1e3:.2f} ms")

if bad:
    print("FAIL:")
    for b in bad:
        print("  " + b)
    raise SystemExit(1)
print("faults envelope OK")
EOF

echo "== checking BENCH_dist.json =="
python3 - <<'EOF'
import json

with open("BENCH_dist.json") as f:
    doc = json.load(f)

bad = []
# worker count 1 pays registration + two localhost round-trips of the
# gradient per step; with chunked streaming overlapping the send with
# the backward pass, 2.5x the in-process loop is the bar (down from 4x
# pre-streaming) — real regressions (an accidental extra copy, a
# lost-frame retry loop on the happy path) blow far past it
frac = doc["overhead_frac"]
if frac > 2.5:
    bad.append(f"dist coordination overhead {frac:.2f}x exceeds the 2.5x bar")
if not doc["bitexact_vs_local"]:
    bad.append("1-worker dist run is no longer bit-exact vs the local loop")
# the bf16 codec halves the dominant gradient payload; 0.55x total wire
# bytes (headers, control frames, and the checkpoint transfer stay f32)
# is the contract the compression mode exists to meet
ratio = doc["wire_ratio_bf16"]
if ratio > 0.55:
    bad.append(f"bf16 wire ratio {ratio:.3f} exceeds the 0.55x bar")

print(f"  local loop  {doc['local_step_s']*1e3:.2f} ms/step")
print(f"  dist (1w)   {doc['dist_step_s']*1e3:.2f} ms/step")
print(f"  dist (2w)   {doc['dist_step_2w_s']*1e3:.2f} ms/step")
print(f"  overhead    {frac*100:+.1f}%  ({doc['steps']} steps, {doc['shards']} shards, {doc['elems']} elems)")
print(f"  wire/step   f32 {doc['wire_bytes_per_step_f32']:.0f} B, bf16 {doc['wire_bytes_per_step_bf16']:.0f} B (ratio {ratio:.3f})")
print(f"  bit-exact   {'yes' if doc['bitexact_vs_local'] else 'NO'}")

if bad:
    print("FAIL:")
    for b in bad:
        print("  " + b)
    raise SystemExit(1)
print("dist envelope OK")
EOF

echo "== checking BENCH_shootout.json =="
python3 - <<'EOF'
import json

with open("BENCH_shootout.json") as f:
    doc = json.load(f)

bad = []
cases = doc["cases"]
skipped = doc.get("skipped", [])
costs = doc["step_cost"]
assert cases, "shootout produced no cases"

# registry coverage: every optimizer must show up as a case or an
# explicit skip — a silently vanished entry is a gate failure
expected = {
    "rmnp", "muon", "adamw", "nora", "normuon",
    "turbo_muon", "muown", "shampoo", "soap",
}
seen = {c["optimizer"] for c in cases} | {s["optimizer"] for s in skipped}
missing = expected - seen
if missing:
    bad.append(f"registry coverage lost: missing {sorted(missing)}")

by_model = {}
for c in cases:
    by_model.setdefault(c["model"], []).append(c)
for model, rows in by_model.items():
    print(f"  [{model} / {rows[0]['arch']}]")
    for c in rows:
        print(
            f"    {c['optimizer']:<10} {c['steps_per_s']:>8.1f} steps/s"
            f"  loss {c['final_loss']:.3f}"
        )
        if not (0.0 < c["final_loss"] < 20.0):
            bad.append(f"implausible final loss in {c}")
for s in skipped:
    print(f"  skipped {s['optimizer']:<10} {s['reason']}")

# the paper's cost claim, measured: rmnp's fused O(mn) step must not
# cost more than muon's O(mn·min(m,n)) NS5 step at the d >= 512 shape
cost = {c["optimizer"]: c for c in costs}
for c in costs:
    print(
        f"  step cost {c['optimizer']:<10} {c['rows']}x{c['cols']}"
        f"  {c['step_median_s']*1e3:.3f} ms"
    )
if "rmnp" in cost and "muon" in cost:
    r, m = cost["rmnp"], cost["muon"]
    if r["cols"] >= 512 and r["step_median_s"] > m["step_median_s"]:
        bad.append(
            f"rmnp per-step cost {r['step_median_s']*1e3:.3f} ms exceeds "
            f"muon's {m['step_median_s']*1e3:.3f} ms at {r['rows']}x{r['cols']}"
        )
else:
    bad.append("step_cost section lost rmnp or muon")

if bad:
    print("FAIL:")
    for b in bad:
        print("  " + b)
    raise SystemExit(1)
print("shootout envelope OK")
EOF

# record this run for the next PR's trajectory gate (only after the gates
# above passed — failing runs must not become baselines)
mkdir -p "$ROOT/bench_history"
SHA="$(git -C "$ROOT" rev-parse --short HEAD 2>/dev/null || echo nogit)"
STAMP="$(date -u +%Y%m%d%H%M%S)_${SHA}"
cp BENCH_precond.json "$ROOT/bench_history/${STAMP}_precond.json"
cp BENCH_train_step.json "$ROOT/bench_history/${STAMP}_train_step.json"
cp BENCH_data_pipeline.json "$ROOT/bench_history/${STAMP}_data_pipeline.json"
cp BENCH_host_train.json "$ROOT/bench_history/${STAMP}_host_train.json"
cp BENCH_faults.json "$ROOT/bench_history/${STAMP}_faults.json"
cp BENCH_dist.json "$ROOT/bench_history/${STAMP}_dist.json"
cp BENCH_shootout.json "$ROOT/bench_history/${STAMP}_shootout.json"
echo "recorded bench_history/${STAMP}_{precond,train_step,data_pipeline,host_train,faults,dist,shootout}.json"
