#!/usr/bin/env bash
# Perf + lint gate for the native kernel layer.
#
#   scripts/bench_check.sh
#
# Runs `cargo fmt --check` and `cargo clippy -D warnings`, then the capped
# precond benchmark (BENCH_MAX_D=256), and fails if any recorded RMNP
# speedup (Table 2 ratio) or seed-vs-kernel improvement drops below 1.0.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (default features) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo bench --bench precond (BENCH_MAX_D=${BENCH_MAX_D:-256}) =="
BENCH_MAX_D="${BENCH_MAX_D:-256}" BENCH_REPEATS="${BENCH_REPEATS:-2}" \
    cargo bench --bench precond

echo "== checking BENCH_precond.json =="
python3 - <<'EOF'
import json, sys

with open("BENCH_precond.json") as f:
    doc = json.load(f)

bad = []
for row in doc["table2"]:
    if row["speedup"] < 1.0:
        bad.append(f"table2 {row['model']} speedup {row['speedup']:.2f} < 1.0")
for d in doc["seed_vs_kernel"]:
    if d["improvement"] < 1.0:
        bad.append(
            f"seed_vs_kernel {d['op']} d={d['d_model']} "
            f"improvement {d['improvement']:.2f} < 1.0"
        )

for row in doc["table2"]:
    print(f"  {row['model']:<6} d={row['d_model']:<5} speedup {row['speedup']:.1f}x")
for d in doc["seed_vs_kernel"]:
    print(f"  {d['op']:<8} d={d['d_model']:<5} kernel vs seed {d['improvement']:.2f}x")

if bad:
    print("FAIL:")
    for b in bad:
        print("  " + b)
    sys.exit(1)
print("bench check OK")
EOF
