#!/usr/bin/env python3
"""Render the README's benchmark tables from BENCH_*.json.

Usage:
    scripts/bench_table.py [PRECOND_JSON] [HOST_TRAIN_JSON] [SHOOTOUT_JSON] [DIST_JSON]

With no arguments, prefers rust/BENCH_precond.json,
rust/BENCH_host_train.json, rust/BENCH_shootout.json, and
rust/BENCH_dist.json (fresh local `cargo bench` runs) and falls back to
the newest bench_history/ snapshots. Prints GitHub-flavored markdown to stdout; paste it into
README.md's "Benchmarks & perf tracking" section after re-running the
benches:

    cd rust && cargo bench --bench precond \
        && cargo bench --bench host_train \
        && cargo bench --bench shootout && cd .. \
        && scripts/bench_table.py

The host-train rows are grouped by model architecture (attention /
gated_mlp / ssm / conv), matching the `arch` tag the bench envelope
records per case. The envelopes are machine-local measurements —
regenerate rather than hand-edit, and expect absolute numbers to differ
across hosts (the ratios are the signal).
"""

import glob
import json
import os
import sys


def find_default(kind, required=True):
    """Newest envelope of `kind`: local rust/BENCH_<kind>.json, else the
    latest bench_history snapshot, else an error (or None if optional)."""
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    local = os.path.join(root, "rust", f"BENCH_{kind}.json")
    if os.path.exists(local):
        return local
    hist = sorted(
        glob.glob(os.path.join(root, "bench_history", f"*_{kind}.json")),
        key=os.path.getmtime,
    )
    if hist:
        return hist[-1]
    if not required:
        return None
    sys.exit(
        f"no BENCH_{kind}.json found: run `cargo bench --bench {kind}` "
        "in rust/ first (or let CI populate bench_history/)"
    )


def fmt_s(x):
    return f"{x:.4f}" if x < 10 else f"{x:.2f}"


def host_train_table(path):
    """The per-arch native train-step table from BENCH_host_train.json."""
    if path is None:
        print("_No host-train envelope found (run `cargo bench --bench "
              "host_train` to record the per-arch table)._")
        return
    with open(path) as f:
        doc = json.load(f)
    cases = doc.get("cases", [])
    if not cases:
        return
    print(f"<!-- host-train rows from {os.path.basename(path)} -->")
    print("**Native train step, per architecture** "
          f"(`{doc.get('simd', '?')}` rung, {doc.get('threads', '?')} threads):")
    print()
    print("| arch | model | optimizer | params | elems | steps/s | final loss |")
    print("|---|---|---|---|---|---|---|")
    order = {"attention": 0, "gated_mlp": 1, "ssm": 2, "conv": 3}
    for c in sorted(cases, key=lambda c: (order.get(c.get("arch", "?"), 9),
                                          c["model"], c["optimizer"])):
        print(
            f"| {c.get('arch', '?')} | {c['model']} | {c['optimizer']} "
            f"| {c['params']} | {c['elems']} | {c['steps_per_s']:.1f} "
            f"| {c['final_loss']:.3f} |"
        )
    print()


def shootout_table(path):
    """The optimizer-zoo race from BENCH_shootout.json: one block per
    model at the matched budget, then the isolated per-step costs."""
    if path is None:
        print("_No shootout envelope found (run `cargo bench --bench "
              "shootout` to record the optimizer-zoo table)._")
        return
    with open(path) as f:
        doc = json.load(f)
    cases = doc.get("cases", [])
    if not cases:
        return
    print(f"<!-- shootout rows from {os.path.basename(path)} -->")
    print("**Optimizer shootout** (matched budget of "
          f"{doc.get('steps', '?')} steps, registry default LRs):")
    print()
    print("| model | arch | optimizer | lr | steps/s | final loss |")
    print("|---|---|---|---|---|---|")
    for c in sorted(cases, key=lambda c: (c["model"], c["optimizer"])):
        print(
            f"| {c['model']} | {c.get('arch', '?')} | {c['optimizer']} "
            f"| {c['lr']:g} | {c['steps_per_s']:.1f} | {c['final_loss']:.3f} |"
        )
    skipped = doc.get("skipped", [])
    for s in skipped:
        print(f"| — | — | {s['optimizer']} | — | _skipped_ | {s['reason']} |")
    print()
    costs = doc.get("step_cost", [])
    if costs:
        shape = f"{costs[0]['rows']}x{costs[0]['cols']}"
        print(f"**Isolated fused-step cost** at {shape} (warm workspace):")
        print()
        print("| optimizer | ms/step |")
        print("|---|---|")
        for c in sorted(costs, key=lambda c: c["step_median_s"]):
            print(f"| {c['optimizer']} | {c['step_median_s']*1e3:.3f} |")
        print()


def dist_table(path):
    """The distributed streaming economics from BENCH_dist.json: per-step
    latency vs worker count and wire bytes per codec mode."""
    if path is None:
        print("_No dist envelope found (run `cargo bench --bench dist` to "
              "record the streaming/wire table)._")
        return
    with open(path) as f:
        doc = json.load(f)
    if "dist_step_s" not in doc:
        return
    print(f"<!-- dist rows from {os.path.basename(path)} -->")
    print("**Distributed streaming** "
          f"({doc.get('steps', '?')} steps, {doc.get('shards', '?')} shards, "
          f"{doc.get('elems', '?')} parameter elements, localhost TCP):")
    print()
    print("| setup | ms/step | vs local |")
    print("|---|---|---|")
    local = doc["local_step_s"]
    rows = [("local loop (in-process)", local),
            ("dist, 1 worker", doc["dist_step_s"])]
    if "dist_step_2w_s" in doc:
        rows.append(("dist, 2 workers", doc["dist_step_2w_s"]))
    for label, s in rows:
        print(f"| {label} | {s*1e3:.2f} | {s/local:.2f}x |")
    print()
    if "wire_ratio_bf16" in doc:
        print("| wire codec | bytes/step | vs f32 |")
        print("|---|---|---|")
        f32 = doc["wire_bytes_per_step_f32"]
        bf16 = doc["wire_bytes_per_step_bf16"]
        print(f"| none (f32) | {f32:.0f} | 1.00x |")
        print(f"| bf16 | {bf16:.0f} | {doc['wire_ratio_bf16']:.2f}x |")
        print()


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else find_default("precond")
    host_path = sys.argv[2] if len(sys.argv) > 2 else find_default(
        "host_train", required=False)
    shootout_path = sys.argv[3] if len(sys.argv) > 3 else find_default(
        "shootout", required=False)
    dist_path = sys.argv[4] if len(sys.argv) > 4 else find_default(
        "dist", required=False)
    with open(path) as f:
        doc = json.load(f)

    print(f"<!-- generated by scripts/bench_table.py from {os.path.basename(path)}; "
          "do not hand-edit -->")
    print(f"Measured on the `{doc.get('simd', '?')}` rung with "
          f"{doc.get('threads', '?')} kernel threads.")
    print()

    rows = doc.get("table2", [])
    if rows:
        print("**Table 2/3 (native):** preconditioning cost per 100 steps.")
        print()
        print("| size | d_model | Muon NS5 (s) | RMNP rownorm (s) | speedup |")
        print("|---|---|---|---|---|")
        for r in rows:
            print(
                f"| {r['model']} | {r['d_model']} | {fmt_s(r['muon_100steps_s'])} "
                f"| {fmt_s(r['rmnp_100steps_s'])} | {r['speedup']:.1f}x |"
            )
        print()

    deltas = doc.get("seed_vs_kernel", [])
    if deltas:
        print("**Seed scalar path vs kernel layer** (same op, same shape):")
        print()
        print("| op | shape | seed (s) | kernel (s) | improvement |")
        print("|---|---|---|---|---|")
        for d in deltas:
            print(
                f"| {d['op']} | {d['rows']}x{d['cols']} | {fmt_s(d['seed_median_s'])} "
                f"| {fmt_s(d['kernel_median_s'])} | {d['improvement']:.2f}x |"
            )
        print()

    simd = doc.get("simd_vs_scalar", [])
    if simd:
        print("**Scalar rung vs vector rung** (the dispatch-ladder delta):")
        print()
        print("| op | shape | rung | scalar (s) | vector (s) | speedup |")
        print("|---|---|---|---|---|---|")
        for d in simd:
            print(
                f"| {d['op']} | {d['rows']}x{d['cols']} | {d.get('rung', 'simd')} "
                f"| {fmt_s(d['scalar_median_s'])} | {fmt_s(d['simd_median_s'])} "
                f"| {d['speedup']:.2f}x |"
            )
        print()
    else:
        print("_No vector rung was available on the measuring host "
              "(scalar-only ladder), so there is no per-rung delta to show._")
        print()

    host_train_table(host_path)
    shootout_table(shootout_path)
    dist_table(dist_path)


if __name__ == "__main__":
    main()
