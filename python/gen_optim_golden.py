#!/usr/bin/env python3
"""Golden-vector generator for rust/tests/optim_conformance.rs.

Replays every native registry optimizer's update math in plain Python
(f64, stdlib only — no numpy needed) on f32-snapped seeded inputs, and
writes the transcript to rust/tests/golden/optim_golden.json. The Rust
conformance suite steps the fused f32 implementations on the same
inputs and must land within 1e-5 relative error of these values.

The constants and eps placements mirror rust/src/optim/ exactly:
  - MATRIX_BETA / NORA_BETA2 / NORMUON_BETA2 = 0.95, WEIGHT_DECAY = 0.1
  - ROW_EPS = 1e-7 row-norm floor, max(norm, eps) semantics
    (python/compile/kernels/ref.py::rownorm_ref)
  - NS5: x / (frobenius + 1e-7), transpose when rows > cols,
    coefficients (3.4445, -4.7750, 2.0315)
    (ref.py::newton_schulz_ref / NS_COEFFS)
  - rms LR scale max(1, sqrt(m/n))

Regenerate with:  python3 python/gen_optim_golden.py
"""

import json
import math
import os
import random
import struct

BETA = 0.95
BETA2 = 0.95  # NORA_BETA2 == NORMUON_BETA2 == 0.95
WD = 0.1
ROW_EPS = 1e-7
NS_EPS = 1e-7
NS_A, NS_B, NS_C = 3.4445, -4.7750, 2.0315
MUON_NS_STEPS = 5
TURBO_NS_STEPS = 3
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8

LR = 0.05
STEPS = 4
SHAPES = [(4, 6), (6, 4)]


def f32(x):
    """Round x to the nearest binary32 (so inputs are exactly
    representable on the Rust side)."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


# ---- tiny f64 matrix helpers (nested lists) --------------------------------


def zeros(m, n):
    return [[0.0] * n for _ in range(m)]


def axpby(a, A, b, B):
    """a*A + b*B, elementwise."""
    return [[a * x + b * y for x, y in zip(ra, rb)] for ra, rb in zip(A, B)]


def transpose(A):
    return [list(col) for col in zip(*A)]


def matmul(A, B):
    bt = transpose(B)
    return [[sum(x * y for x, y in zip(ra, cb)) for cb in bt] for ra in A]


def frobenius(A):
    return math.sqrt(sum(x * x for r in A for x in r))


def row_sumsq(row):
    return sum(x * x for x in row)


def rownorm(A, eps):
    """v / max(||v||, eps) per row — ref.py::rownorm_ref semantics."""
    out = []
    for row in A:
        inv = 1.0 / max(math.sqrt(row_sumsq(row)), eps)
        out.append([x * inv for x in row])
    return out


def newton_schulz(G, steps):
    """Quintic NS (muon.rs::newton_schulz5_into semantics): transpose so
    the Gram side is min(m,n), normalize by frobenius + eps, iterate
    x <- a*x + (b*A + c*A^2) @ x with A = x x^T."""
    m, n = len(G), len(G[0])
    t = m > n
    x = transpose(G) if t else [row[:] for row in G]
    inv = 1.0 / (frobenius(x) + NS_EPS)
    x = [[v * inv for v in row] for row in x]
    for _ in range(steps):
        gram = matmul(x, transpose(x))
        poly = axpby(NS_B, gram, NS_C, matmul(gram, gram))
        x = axpby(NS_A, x, 1.0, matmul(poly, x))
    return transpose(x) if t else x


def rms_scale(m, n):
    return max(1.0, math.sqrt(m / n))


# ---- optimizer steps (mirror rust/src/optim/<name>.rs) ---------------------


def step_rmnp(st, W, G, lr, m, n):
    st["momentum"] = axpby(BETA, st["momentum"], 1.0 - BETA, G)
    scale = lr * rms_scale(m, n)
    wfac = 1.0 - scale * WD
    for i in range(m):
        v = st["momentum"][i]
        inv = 1.0 / max(math.sqrt(row_sumsq(v)), ROW_EPS)
        W[i] = [wfac * w - scale * inv * vv for w, vv in zip(W[i], v)]


def step_muon(st, W, G, lr, m, n):
    st["momentum"] = axpby(BETA, st["momentum"], 1.0 - BETA, G)
    d = newton_schulz(st["momentum"], MUON_NS_STEPS)
    scale = lr * rms_scale(m, n)
    for i in range(m):
        W[i] = [w - scale * (dv + WD * w) for w, dv in zip(W[i], d[i])]


def step_adamw(st, W, G, lr, m, n):
    st["t"] += 1
    bc1 = 1.0 - ADAM_B1 ** st["t"]
    bc2 = 1.0 - ADAM_B2 ** st["t"]
    for i in range(m):
        for j in range(n):
            g = G[i][j]
            mi = ADAM_B1 * st["m"][i][j] + (1.0 - ADAM_B1) * g
            vi = ADAM_B2 * st["v"][i][j] + (1.0 - ADAM_B2) * g * g
            st["m"][i][j] = mi
            st["v"][i][j] = vi
            mhat = mi / bc1
            vhat = vi / bc2
            W[i][j] -= lr * (mhat / (math.sqrt(vhat) + ADAM_EPS) + WD * W[i][j])


def step_nora(st, W, G, lr, m, n):
    st["t"] += 1
    bias = 1.0 - BETA2 ** st["t"]
    st["momentum"] = axpby(BETA, st["momentum"], 1.0 - BETA, G)
    scale = lr * rms_scale(m, n)
    wfac = 1.0 - scale * WD
    for i in range(m):
        v = st["momentum"][i]
        st["v"][i] = BETA2 * st["v"][i] + (1.0 - BETA2) * row_sumsq(v)
        denom = max(math.sqrt(st["v"][i] / bias), ROW_EPS)
        W[i] = [wfac * w - (scale / denom) * vv for w, vv in zip(W[i], v)]


def step_normuon(st, W, G, lr, m, n):
    st["momentum"] = axpby(BETA, st["momentum"], 1.0 - BETA, G)
    d = newton_schulz(st["momentum"], MUON_NS_STEPS)
    st["t"] += 1
    bias = 1.0 - BETA2 ** st["t"]
    sum_o = 0.0
    sum_c = 0.0
    cs = []
    for i in range(m):
        sq = row_sumsq(d[i])
        st["v"][i] = BETA2 * st["v"][i] + (1.0 - BETA2) * sq / n
        c = 1.0 / (math.sqrt(st["v"][i] / bias) + ROW_EPS)
        cs.append(c)
        sum_o += sq
        sum_c += c * c * sq
    gamma = math.sqrt(sum_o / sum_c) if sum_c > 0.0 else 1.0
    scale = lr * rms_scale(m, n)
    wfac = 1.0 - scale * WD
    for i in range(m):
        W[i] = [
            wfac * w - scale * gamma * cs[i] * dv for w, dv in zip(W[i], d[i])
        ]


def step_turbo_muon(st, W, G, lr, m, n):
    st["momentum"] = axpby(BETA, st["momentum"], 1.0 - BETA, G)
    p = rownorm(st["momentum"], ROW_EPS)
    d = newton_schulz(p, TURBO_NS_STEPS)
    scale = lr * rms_scale(m, n)
    for i in range(m):
        W[i] = [w - scale * (dv + WD * w) for w, dv in zip(W[i], d[i])]


def step_muown(st, W, G, lr, m, n):
    st["momentum"] = axpby(BETA, st["momentum"], 1.0 - BETA, G)
    d = newton_schulz(st["momentum"], MUON_NS_STEPS)
    scale = lr * rms_scale(m, n)
    wfac = 1.0 - scale * WD
    for i in range(m):
        inv = 1.0 / max(math.sqrt(row_sumsq(d[i])), ROW_EPS)
        W[i] = [wfac * w - scale * inv * dv for w, dv in zip(W[i], d[i])]


# name -> (step fn, fresh state fn, exported state buffers fn)
OPTIMIZERS = {
    "rmnp": (
        step_rmnp,
        lambda m, n: {"momentum": zeros(m, n)},
        lambda st: {"momentum": flat(st["momentum"])},
    ),
    "muon": (
        step_muon,
        lambda m, n: {"momentum": zeros(m, n)},
        lambda st: {"momentum": flat(st["momentum"])},
    ),
    "adamw": (
        step_adamw,
        lambda m, n: {"m": zeros(m, n), "v": zeros(m, n), "t": 0},
        lambda st: {"m": flat(st["m"]), "v": flat(st["v"]), "t": st["t"]},
    ),
    "nora": (
        step_nora,
        lambda m, n: {"momentum": zeros(m, n), "v": [0.0] * m, "t": 0},
        lambda st: {
            "momentum": flat(st["momentum"]),
            "v": list(st["v"]),
            "t": st["t"],
        },
    ),
    "normuon": (
        step_normuon,
        lambda m, n: {"momentum": zeros(m, n), "v": [0.0] * m, "t": 0},
        lambda st: {
            "momentum": flat(st["momentum"]),
            "v": list(st["v"]),
            "t": st["t"],
        },
    ),
    "turbo_muon": (
        step_turbo_muon,
        lambda m, n: {"momentum": zeros(m, n)},
        lambda st: {"momentum": flat(st["momentum"])},
    ),
    "muown": (
        step_muown,
        lambda m, n: {"momentum": zeros(m, n)},
        lambda st: {"momentum": flat(st["momentum"])},
    ),
}


def flat(A):
    return [x for row in A for x in row]


def main():
    cases = []
    for ci, (name, (step, init, export)) in enumerate(sorted(OPTIMIZERS.items())):
        for si, (m, n) in enumerate(SHAPES):
            rnd = random.Random(1000 + 10 * ci + si)
            w0 = [[f32(rnd.uniform(-0.5, 0.5)) for _ in range(n)] for _ in range(m)]
            grads = [
                [[f32(rnd.uniform(-1.0, 1.0)) for _ in range(n)] for _ in range(m)]
                for _ in range(STEPS)
            ]
            w = [row[:] for row in w0]
            st = init(m, n)
            for g in grads:
                step(st, w, g, LR, m, n)
            cases.append(
                {
                    "optimizer": name,
                    "rows": m,
                    "cols": n,
                    "w0": flat(w0),
                    "grads": [flat(g) for g in grads],
                    "w_final": flat(w),
                    "state": export(st),
                }
            )
    doc = {
        "_generator": "python/gen_optim_golden.py",
        "lr": LR,
        "steps": STEPS,
        "cases": cases,
    }
    out = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "rust",
        "tests",
        "golden",
        "optim_golden.json",
    )
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as fh:
        json.dump(doc, fh)
        fh.write("\n")
    print(f"wrote {out}: {len(cases)} cases ({STEPS} steps each)")


if __name__ == "__main__":
    main()
