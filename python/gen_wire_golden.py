#!/usr/bin/env python3
"""Independent oracle for the dist wire frames pinned in dist/wire.rs.

Builds each golden frame from the documented layout alone — struct-packed
little-endian fields, zlib CRC-32 over the payload — and prints the byte
arrays the Rust tests assert against. If this script and the Rust encoder
ever disagree, the wire format drifted.

Run with:  python3 python/gen_wire_golden.py
"""

import binascii
import struct


def frame(payload):
    return struct.pack("<II", len(payload), binascii.crc32(payload)) + payload


def show(name, buf):
    print(f"{name} ({len(buf)} bytes):")
    print("  [" + ", ".join(f"0x{b:02X}" for b in buf) + "]")


def main():
    # Heartbeat { rank: 7 } — tag 4 (pinned since PR 7)
    show("Heartbeat{rank:7}", frame(struct.pack("<BI", 4, 7)))

    # ShardGradChunk { step: 7, shard: 1, seq: 2, total: 3, codec: bf16(1),
    #   elems: 2, loss: 1.5, data: bf16(1.5), bf16(-0.5) } — tag 12
    data = struct.pack("<HH", 0x3FC0, 0xBF00)  # bf16 bits of 1.5, -0.5
    payload = struct.pack("<BQIIIBIf", 12, 7, 1, 2, 3, 1, 2, 1.5)
    payload += struct.pack("<I", len(data)) + data
    show("ShardGradChunk", frame(payload))

    # ApplyChunk { step: 7, seq: 0, total: 2, codec: none(0), elems: 1,
    #   data: f32(1.0) } — tag 13
    data = struct.pack("<f", 1.0)
    payload = struct.pack("<BQIIBI", 13, 7, 0, 2, 0, 1)
    payload += struct.pack("<I", len(data)) + data
    show("ApplyChunk", frame(payload))


if __name__ == "__main__":
    main()
