"""L1 Pallas kernels (build-time only; lowered into the L2 HLO graphs).

Public surface:

* :func:`rownorm.rownorm` — RMNP's row-wise l2 normalization preconditioner.
* :func:`newton_schulz.newton_schulz` — Muon's NS5 orthogonalization.
* :func:`momentum.momentum` / :func:`momentum.adamw_update` — fused
  elementwise optimizer-state updates.
* :mod:`ref` — pure-jnp oracles for all of the above.
"""

from . import ref
from .momentum import adamw_update, momentum
from .newton_schulz import fits_single_block, flops, newton_schulz, rownorm_flops
from .rownorm import rownorm, vmem_bytes

__all__ = [
    "ref",
    "rownorm",
    "newton_schulz",
    "momentum",
    "adamw_update",
    "fits_single_block",
    "flops",
    "rownorm_flops",
    "vmem_bytes",
]
