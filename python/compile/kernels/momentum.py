"""L1 Pallas kernels for the elementwise optimizer-state updates.

Two kernels:

* `momentum` — the EMA update V' = beta*V + (1-beta)*G shared by Muon and
  RMNP (Algorithms 1/2, line 4).
* `adamw_update` — the fused AdamW parameter/moment update used for
  non-matrix parameters in the mixed strategy (paper Section 4.1).

Both are purely elementwise, so the BlockSpec tiles a flattened view into
fixed-size VMEM panels; arithmetic intensity is O(1) FLOP/byte and the ops
are bandwidth-bound on any backend. interpret=True as everywhere.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Elementwise panel size: 64 Ki elements x 4 B = 256 KiB per operand.
BLOCK = 64 * 1024


def _pad_flat(x):
    """Flatten to 1-D and pad to a BLOCK multiple; returns (flat, n)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    padded = (n + BLOCK - 1) // BLOCK * BLOCK
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat, n


def _momentum_kernel(v_ref, g_ref, o_ref, *, beta):
    o_ref[...] = beta * v_ref[...] + (1.0 - beta) * g_ref[...]


@functools.partial(jax.jit, static_argnames=("beta",))
def momentum(v, g, *, beta):
    """EMA momentum via the Pallas elementwise kernel (any shape)."""
    vf, n = _pad_flat(v)
    gf, _ = _pad_flat(g)
    blocks = vf.shape[0] // BLOCK
    out = pl.pallas_call(
        functools.partial(_momentum_kernel, beta=beta),
        out_shape=jax.ShapeDtypeStruct(vf.shape, vf.dtype),
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=True,
    )(vf, gf)
    return out[:n].reshape(v.shape)


def _adamw_kernel(p_ref, g_ref, m_ref, v_ref, lr_ref, t_ref, o_p, o_m, o_v,
                  *, beta1, beta2, eps, wd):
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    t = t_ref[0].astype(jnp.float32)
    lr = lr_ref[0]
    mhat = m / (1.0 - beta1**t)
    vhat = v / (1.0 - beta2**t)
    p = p_ref[...]
    o_p[...] = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    o_m[...] = m
    o_v[...] = v


@functools.partial(jax.jit, static_argnames=("beta1", "beta2", "eps", "wd"))
def adamw_update(p, g, m, v, lr, t, *, beta1=0.9, beta2=0.95, eps=1e-8,
                 wd=0.1):
    """Fused AdamW step via the Pallas elementwise kernel.

    `lr` is a scalar f32 array, `t` a scalar i32 step index (1-based).
    Returns (p', m', v').
    """
    pf, n = _pad_flat(p)
    gf, _ = _pad_flat(g)
    mf, _ = _pad_flat(m)
    vf, _ = _pad_flat(v)
    blocks = pf.shape[0] // BLOCK
    lr1 = jnp.reshape(lr, (1,)).astype(jnp.float32)
    t1 = jnp.reshape(t, (1,)).astype(jnp.int32)
    shape = jax.ShapeDtypeStruct(pf.shape, pf.dtype)
    po, mo, vo = pl.pallas_call(
        functools.partial(
            _adamw_kernel, beta1=beta1, beta2=beta2, eps=eps, wd=wd
        ),
        out_shape=(shape, shape, shape),
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ),
        interpret=True,
    )(pf, gf, mf, vf, lr1, t1)
    unshape = lambda x: x[:n].reshape(p.shape)
    return unshape(po), unshape(mo), unshape(vo)
