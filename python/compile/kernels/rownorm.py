"""L1 Pallas kernel: row-wise l2 normalization — the RMNP preconditioner.

This is the paper's core operator (Algorithm 2, line 5):

    D_t = diag(V_t V_t^T)^{-1/2} V_t   ==   V_t[i,:] / ||V_t[i,:]||_2

Hardware adaptation (DESIGN.md §2): the paper implements this as a rowwise
CUDA reduction. On TPU the analogue is a VPU reduction over the lane
dimension with the row resident in VMEM. The BlockSpec grid tiles the row
dimension into `block_rows`-row panels; each panel holds the *entire* row
(shape `(block_rows, n)`) so the reduction never crosses a block boundary —
one HBM read + one HBM write per element, the bandwidth roofline for this
memory-bound op.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter into plain
HLO. Correctness vs `ref.rownorm_ref` is asserted in
python/tests/test_kernels.py; the real-TPU performance estimate lives in
DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS

#: Default number of rows per VMEM panel. 128 rows x 4096 cols x 4B = 2 MiB,
#: comfortably double-bufferable in a 16 MiB VMEM.
DEFAULT_BLOCK_ROWS = 128


def _rownorm_kernel(x_ref, o_ref, *, eps):
    """One grid step: normalize a (block_rows, n) panel of rows."""
    v = x_ref[...]
    # VPU reduction along the lane (last) dimension; keepdims so the
    # divide broadcasts back over the row.
    norms = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True))
    o_ref[...] = v / jnp.maximum(norms, eps)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def rownorm(v, *, block_rows=DEFAULT_BLOCK_ROWS, eps=EPS):
    """Row-l2-normalize a 2-D matrix via the Pallas kernel.

    Pads the row dimension up to a multiple of `block_rows` (padding rows
    are zero and normalize to zero thanks to the eps floor), runs the
    panel grid, then slices the result back.
    """
    m, n = v.shape
    bm = min(block_rows, m)
    padded = (m + bm - 1) // bm * bm
    vp = jnp.pad(v, ((0, padded - m), (0, 0))) if padded != m else v
    out = pl.pallas_call(
        functools.partial(_rownorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct(vp.shape, vp.dtype),
        grid=(padded // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        interpret=True,
    )(vp)
    return out[:m] if padded != m else out


def vmem_bytes(m, n, block_rows=DEFAULT_BLOCK_ROWS, dtype_bytes=4):
    """Estimated VMEM footprint of one grid step (input + output panel).

    Used by DESIGN.md §8 and the `rmnp bench precond --analyze` report to
    sanity-check that every paper shape fits VMEM with double buffering.
    """
    bm = min(block_rows, m)
    return 2 * bm * n * dtype_bytes
