"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these references to tight tolerances. They are also
used directly by the L2 graphs when a shape falls outside a kernel's tiling
assumptions (e.g. 1-D bias vectors).
"""

import jax.numpy as jnp

#: Muon's quintic Newton-Schulz coefficients (Jordan et al., 2024).
NS_COEFFS = (3.4445, -4.7750, 2.0315)
#: Numerical floor for row norms / Frobenius norms.
EPS = 1e-7


def rownorm_ref(v, eps=EPS):
    """RMNP preconditioned direction: RN(V) = diag(VV^T)^{-1/2} V.

    Each row (the d_out index) is divided by its l2 norm along d_in
    (paper Eq. 4). Zero rows are left at zero via the eps floor.
    """
    norms = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True))
    return v / jnp.maximum(norms, eps)


def gram_diag_ref(v):
    """diag(VV^T): squared l2 norm of each row of V."""
    return jnp.sum(v * v, axis=-1)


def newton_schulz_ref(g, steps=5, eps=EPS):
    """Muon's NS5 orthogonalization: X ~ (GG^T)^{-1/2} G.

    Follows the Muon reference implementation: normalize by the Frobenius
    norm, then iterate the quintic polynomial X <- aX + (bA + cA^2)X with
    A = XX^T. Operates on the leading (smaller) dimension; transposes
    internally when m > n (paper Section 3.1, 'WLOG m <= n').
    """
    a, b, c = NS_COEFFS
    x = g / (jnp.linalg.norm(g) + eps)
    transpose = x.shape[0] > x.shape[1]
    if transpose:
        x = x.T
    for _ in range(steps):
        gram = x @ x.T
        poly = b * gram + c * (gram @ gram)
        x = a * x + poly @ x
    if transpose:
        x = x.T
    return x


def momentum_ref(v, g, beta):
    """EMA momentum (Algorithm 1/2 line 4): V' = beta*V + (1-beta)*G."""
    return beta * v + (1.0 - beta) * g


def adamw_update_ref(p, g, m, v, lr, beta1, beta2, eps, wd, t):
    """One decoupled-weight-decay Adam step; returns (p', m', v').

    `t` is the 1-based step index used for bias correction.
    """
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * (g * g)
    mhat = m / (1.0 - beta1**t)
    vhat = v / (1.0 - beta2**t)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p, m, v


def rms_lr_scale(shape):
    """Muon/RMNP learning-rate shape correction max(1, sqrt(m/n))
    (paper Eq. 17/18)."""
    m, n = shape[-2], shape[-1]
    return max(1.0, (m / n) ** 0.5)
