"""L1 Pallas kernel: quintic Newton-Schulz orthogonalization (Muon baseline).

Muon (Algorithm 1, line 5) computes D_t = NS5(V_t) ~ (V_tV_t^T)^{-1/2} V_t
with five iterations of the quintic polynomial

    A = X X^T;  X <- a X + (b A + c A^2) X,     (a,b,c) = NS_COEFFS.

Each iteration is two m x m x m and one m x m x n matmul — this is the
O(mn * min(m,n)) cost the paper eliminates, and the reason the Table 2 gap
grows with d_model.

Hardware adaptation: on TPU these matmuls target the MXU; the kernel keeps
the whole (m, n) operand in VMEM (one block) because NS iterations are
global — every output element depends on every input element, so row
tiling cannot help. That bounds the kernel to matrices with
2*(mn + m*m)*4 bytes <= VMEM; for larger shapes the L2 graph falls back to
the jnp reference (identical math, XLA-tiled matmuls). interpret=True for
CPU-PJRT executability (see rownorm.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS, NS_COEFFS

#: Above this many f32 elements (~8 MiB against a 16 MiB VMEM), don't
#: attempt the single-block Pallas kernel.
SINGLE_BLOCK_LIMIT = 2 * 1024 * 1024


def _ns5_kernel(g_ref, o_ref, *, steps, eps):
    g = g_ref[...]
    a, b, c = NS_COEFFS
    x = g / (jnp.sqrt(jnp.sum(g * g)) + eps)
    for _ in range(steps):
        gram = jnp.dot(x, x.T)
        poly = b * gram + c * jnp.dot(gram, gram)
        x = a * x + jnp.dot(poly, x)
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("steps", "eps"))
def newton_schulz(g, *, steps=5, eps=EPS):
    """NS5-orthogonalize a 2-D matrix via the single-block Pallas kernel.

    Transposes internally so iterations run on the smaller Gram dimension
    (paper: 'WLOG m <= n').
    """
    transpose = g.shape[0] > g.shape[1]
    x = g.T if transpose else g
    out = pl.pallas_call(
        functools.partial(_ns5_kernel, steps=steps, eps=eps),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)
    return out.T if transpose else out


def fits_single_block(m, n):
    """Whether the single-block kernel is applicable for an (m, n) matrix."""
    return m * n <= SINGLE_BLOCK_LIMIT


def flops(m, n, steps=5):
    """Matmul FLOPs of one NS5 call (used for roofline estimates).

    Per iteration (on the transposed-if-needed m<=n operand):
      X X^T: 2 m^2 n, A A: 2 m^3, poly@X: 2 m^2 n.
    """
    mm, nn = (m, n) if m <= n else (n, m)
    per_iter = 2 * mm * mm * nn * 2 + 2 * mm**3
    return steps * per_iter


def rownorm_flops(m, n):
    """FLOPs of the RMNP preconditioner on the same shape (2mn: square+add,
    plus the rsqrt-scale pass)."""
    return 3 * m * n
