"""Fused train-step / init / eval / dominance graph builders.

Every graph is a pure function over flat positional arrays so its lowered
HLO has a stable parameter order the rust runtime can rely on:

* ``init(seed)                         -> (state...)``
* ``train(*state, *batch, lr)          -> (state'..., loss, gnorm, clipped)``
* ``eval(*params, *batch)              -> loss``
* ``dominance(*matrix_momenta)         -> f32[K, 3]  (r_avg, r_min, r_max)``

State order is canonical: sorted parameter names, then sorted optimizer
state keys (see optim.py). The manifest records names, shapes, dtypes and
the index ranges so rust treats state as an opaque buffer list and feeds
output buffers of step t straight back into step t+1 (device-resident via
the patched `execute_b_untupled`).
"""

import jax
import jax.numpy as jnp

from . import optim as O

CLIP_NORM = 1.0  # standard global-norm clip; clip-rate figures count hits


# ---------------------------------------------------------------------------
# state packing


def make_optimizer(spec, opt_name):
    module = spec.module()
    # params are only needed for shapes here — use eval_shape to stay cheap
    shapes = jax.eval_shape(lambda k: module.init(spec.cfg, k),
                            jax.random.PRNGKey(0))
    groups = module.param_groups(spec.cfg, shapes)
    return O.make(opt_name, groups, lr_adamw_ratio=spec.lr_adamw_ratio)


def state_layout(spec, opt_name):
    """(param_names, opt_state_names, shapes dict, dtypes dict)."""
    module = spec.module()
    pshapes = jax.eval_shape(lambda k: module.init(spec.cfg, k),
                             jax.random.PRNGKey(0))
    opt = make_optimizer(spec, opt_name)
    sshapes = jax.eval_shape(opt.init_state, pshapes)
    pnames = sorted(pshapes.keys())
    snames = sorted(sshapes.keys())
    shapes = {n: tuple(pshapes[n].shape) for n in pnames}
    shapes.update({n: tuple(sshapes[n].shape) for n in snames})
    dtypes = {n: str(pshapes[n].dtype) for n in pnames}
    dtypes.update({n: str(sshapes[n].dtype) for n in snames})
    return pnames, snames, shapes, dtypes


def _pack(params, state, pnames, snames):
    return tuple(params[n] for n in pnames) + tuple(state[n] for n in snames)


def _unpack(flat, pnames, snames):
    params = {n: flat[i] for i, n in enumerate(pnames)}
    state = {n: flat[len(pnames) + i] for i, n in enumerate(snames)}
    return params, state


# ---------------------------------------------------------------------------
# loss dispatch


def loss_fn(spec, params, batch):
    module = spec.module()
    if spec.family == "vision":
        images, labels = batch
        return module.loss(spec.cfg, params, images, labels)
    (tokens,) = batch
    return module.loss(spec.cfg, params, tokens)


# ---------------------------------------------------------------------------
# graph builders


def build_init(spec, opt_name):
    """fn(seed: i32[]) -> flat state tuple."""
    module = spec.module()
    opt = make_optimizer(spec, opt_name)
    pnames, snames, _, _ = state_layout(spec, opt_name)

    def init(seed):
        key = jax.random.PRNGKey(seed)
        params = module.init(spec.cfg, key)
        state = opt.init_state(params)
        return _pack(params, state, pnames, snames)

    return init


def build_train(spec, opt_name):
    """fn(*state, *batch, lr) -> (*state', loss, grad_norm, clipped)."""
    opt = make_optimizer(spec, opt_name)
    pnames, snames, _, _ = state_layout(spec, opt_name)
    n_batch = len(spec.batch_specs())

    def train(*args):
        flat = args[: len(pnames) + len(snames)]
        batch = args[len(pnames) + len(snames):-1]
        lr = args[-1]
        assert len(batch) == n_batch
        params, state = _unpack(flat, pnames, snames)
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(spec, p, batch)
        )(params)
        # global-norm clipping + clip indicator (Figures 29-32)
        gnorm = jnp.sqrt(
            sum(jnp.sum(g * g) for g in grads.values())
        )
        scale = jnp.minimum(1.0, CLIP_NORM / jnp.maximum(gnorm, 1e-12))
        clipped = (gnorm > CLIP_NORM).astype(jnp.float32)
        grads = {n: g * scale for n, g in grads.items()}
        new_params, new_state = opt.apply(params, grads, state, lr)
        return _pack(new_params, new_state, pnames, snames) + (
            loss, gnorm, clipped,
        )

    return train


def build_eval(spec, opt_name):
    """fn(*params, *batch) -> loss (parameters only, no optimizer state)."""
    pnames, _, _, _ = state_layout(spec, opt_name)

    def evaluate(*args):
        params = {n: args[i] for i, n in enumerate(pnames)}
        batch = args[len(pnames):]
        return loss_fn(spec, params, batch)

    return evaluate


def build_dominance(spec, opt_name):
    """fn(*matrix momenta) -> f32[K,3] of (r_avg, r_min, r_max) rows.

    Inputs are the `mom.<p>` entries of the optimizer state, in state
    order; the manifest lists their state indices so rust can feed the
    corresponding live buffers without copies.
    """
    opt = make_optimizer(spec, opt_name)
    matrix = opt.matrix_names()

    def dominance(*moms):
        rows = [O.dominance_metrics(v) for v in moms]
        return jnp.stack(rows)

    return dominance, ["mom." + n for n in matrix]


def dominance_state_indices(spec, opt_name):
    """Indices into the flat state of each matrix-momentum buffer."""
    pnames, snames, _, _ = state_layout(spec, opt_name)
    _, wanted = build_dominance(spec, opt_name)
    all_names = pnames + snames
    return [all_names.index(w) for w in wanted], wanted
