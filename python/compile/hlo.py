"""Lowering helper: jitted-jax function -> HLO *text*.

HLO text (never `.serialize()`) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

import jax
from jax._src.lib import xla_client as xc

DTYPES = {"f32": "float32", "i32": "int32", "u32": "uint32"}


def to_hlo_text(fn, arg_specs):
    """Lower `fn` at the given ShapeDtypeStructs and return HLO text.

    `return_tuple=True` so the root is always a tuple; the rust side runs
    executables with `untuple_result`, receiving one buffer per element.
    """
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(DTYPES[dtype]))


def out_specs(fn, arg_specs):
    """Output ShapeDtypeStructs (flattened) via eval_shape."""
    outs = jax.eval_shape(fn, *arg_specs)
    return jax.tree_util.tree_leaves(outs)
