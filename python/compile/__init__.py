"""Build-time compile path: L1 Pallas kernels, L2 JAX graphs, AOT lowering.

Nothing in this package runs at serving/training time — `make artifacts`
invokes :mod:`compile.aot` once and the rust coordinator consumes the
resulting HLO-text artifacts through PJRT.
"""
