"""Experiment configuration registry — the single source of truth for which
artifacts exist. The rust coordinator discovers everything through the
manifest that `aot.py` generates from this registry; keep tags stable.

Scaling note (DESIGN.md §3): model dims are scaled versions of the paper's
GPT-2/LLaMA families so that the full experiment grid runs on a CPU PJRT
testbed. The `e2e` config is the required ~100M-parameter end-to-end
driver. Preconditioner-op shapes for Table 2 use the paper's *true*
Table 4 d_model values.
"""

from .models import convnet, gpt2, llama, ssm

VOCAB = 512  # byte-pair vocabulary produced by the rust tokenizer


class ModelSpec:
    """One (family, scale): model config + batch geometry + optimizers."""

    def __init__(self, family, scale, cfg, batch, optimizers,
                 lr_adamw_ratio=1.0):
        self.family = family
        self.scale = scale
        self.cfg = cfg
        self.batch = batch
        self.optimizers = optimizers
        self.lr_adamw_ratio = lr_adamw_ratio

    @property
    def tag(self):
        return f"{self.family}_{self.scale}"

    def module(self):
        return {
            "gpt2": gpt2,
            "llama": llama,
            "ssm": ssm,
            "vision": convnet,
        }[self.family]

    def batch_specs(self):
        """Input tensors the rust data pipeline must feed per step."""
        if self.family == "vision":
            b = self.batch
            hw = self.cfg.image_hw
            return [
                ("images", (b, 3, hw, hw), "f32"),
                ("labels", (b,), "i32"),
            ]
        return [("tokens", (self.batch, self.cfg.seq_len + 1), "i32")]


def _gpt2(scale, d, layers, heads, seq=128, batch=16,
          optimizers=("adamw", "muon", "rmnp"), **kw):
    cfg = gpt2.GPT2Config(VOCAB, d, layers, heads, seq)
    return ModelSpec("gpt2", scale, cfg, batch, list(optimizers), **kw)


def _llama(scale, d, layers, heads, ff, seq=128, batch=16,
           optimizers=("adamw", "muon", "rmnp"), covers_embed=False, **kw):
    cfg = llama.LlamaConfig(
        VOCAB, d, layers, heads, ff, seq,
        matrix_covers_embeddings=covers_embed,
    )
    return ModelSpec("llama", scale, cfg, batch, list(optimizers), **kw)


def build_registry():
    """All (family, scale) specs keyed by tag."""
    specs = [
        # GPT-2 family (OpenWebText-analogue protocol: matrix optimizer
        # covers embeddings + head; lr_adamw fixed relative to lr_matrix).
        _gpt2("tiny", 64, 2, 2,
              optimizers=("adamw", "muon", "rmnp", "shampoo", "soap")),
        _gpt2("small", 128, 4, 4),
        _gpt2("medium", 192, 6, 6),
        _gpt2("large", 256, 8, 8),
        # Required end-to-end driver: ~100M params.
        _gpt2("e2e", 768, 14, 12, seq=256, batch=4,
              optimizers=("rmnp", "muon")),
        # LLaMA family (C4-analogue protocol: embeddings/head on AdamW,
        # shared-LR convention lr_adamw == lr_matrix).
        _llama("s60", 64, 3, 4, 176,
               optimizers=("adamw", "muon", "rmnp", "shampoo", "soap")),
        _llama("s130", 96, 4, 6, 256,
               optimizers=("adamw", "muon", "rmnp", "shampoo", "soap")),
        _llama("s350", 128, 6, 8, 352),
        _llama("s1b", 160, 8, 8, 432),
        # Appendix D.4 ablation: matrix optimizer also covers embeddings.
        _llama("s60emb", 64, 3, 4, 176, covers_embed=True,
               optimizers=("muon", "rmnp")),
        _llama("s130emb", 96, 4, 6, 256, covers_embed=True,
               optimizers=("muon", "rmnp")),
    ]
    # Mamba-like SSM (Appendix E.5).
    specs.append(ModelSpec(
        "ssm", "base",
        ssm.SSMConfig(VOCAB, 128, 128, 4, 128),
        16, ["adamw", "muon", "rmnp"],
    ))
    # ResNet-18-like CNN (Appendix E.6).
    specs.append(ModelSpec(
        "vision", "base",
        convnet.ConvNetConfig(n_classes=10, width=32, n_blocks=3),
        32, ["adamw", "muon", "rmnp"],
    ))
    return {s.tag: s for s in specs}


REGISTRY = build_registry()

#: Table 4 of the paper: GPT-2 configs used for the preconditioning
#: wall-clock benchmark (true d_model values; layer counts for per-model
#: matrix multiplicity).
TABLE4_CONFIGS = [
    # (name, params-label, layers, d_model)
    ("60M", "60M", 6, 640),
    ("125M", "125M", 12, 768),
    ("200M", "200M", 16, 896),
    ("355M", "355M", 24, 1024),
    ("500M", "500M", 28, 1152),
    ("770M", "770M", 36, 1280),
    ("1.3B", "1.3B", 44, 1536),
    ("1.5B", "1.5B", 48, 1600),
]


def precond_shapes():
    """Unique matrix shapes across all Table 4 configs, with per-model
    multiplicity recorded for the bench harness.

    Each transformer block holds qkv (3d, d), attn-out (d, d),
    mlp-in (4d, d), mlp-out (d, 4d); embeddings/head are (VOCAB, d)
    (vocab scaled, DESIGN.md §3).
    """
    shapes = {}
    per_model = []
    for name, label, layers, d in TABLE4_CONFIGS:
        counts = {
            (3 * d, d): layers,
            (d, d): layers,
            (4 * d, d): layers,
            (d, 4 * d): layers,
            (VOCAB, d): 2,
        }
        for shape in counts:
            shapes[shape] = True
        per_model.append(
            {"name": name, "layers": layers, "d_model": d,
             "counts": [[list(k), v] for k, v in counts.items()]}
        )
    return sorted(shapes.keys()), per_model
