"""AOT artifact builder: lower every graph in the registry to HLO text.

Usage (from python/):

    python -m compile.aot --out ../artifacts [--only TAG ...] [--skip-e2e]

Emits:

* `train_<tag>_<opt>.hlo.txt`, `init_<tag>_<opt>.hlo.txt`,
  `eval_<tag>_<opt>.hlo.txt`, `dom_<tag>_<opt>.hlo.txt` per registry entry;
* `ns5_<m>x<n>.hlo.txt` / `rownorm_<m>x<n>.hlo.txt` preconditioner ops for
  every Table 4 shape (the Table 2 / Figure 1 bench);
* `manifest.json` describing every graph's I/O so the rust runtime is
  fully manifest-driven.

This is the only entry point that runs Python; the rust binary consumes
the artifacts through PJRT and never imports this package.
"""

import argparse
import json
import os
import sys

import jax

from . import configs, trainstep
from .hlo import out_specs, spec, to_hlo_text
from .kernels import ref
from .kernels.newton_schulz import (fits_single_block, flops,
                                    newton_schulz as ns5_pallas,
                                    rownorm_flops)
from .kernels.rownorm import rownorm as rownorm_pallas, vmem_bytes
from .models.common import count_params


def _io_entry(names, specs):
    return [
        [n, [int(d) for d in s.shape], str(s.dtype)]
        for n, s in zip(names, specs)
    ]


def _write(outdir, name, text):
    path = os.path.join(outdir, name + ".hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return os.path.basename(path)


def _lower_graph(outdir, manifest, name, fn, in_names, in_specs,
                 out_names=None):
    outs = out_specs(fn, in_specs)
    if out_names is None:
        out_names = [f"out{i}" for i in range(len(outs))]
    fname = _write(outdir, name, to_hlo_text(fn, in_specs))
    manifest["graphs"][name] = {
        "file": fname,
        "inputs": _io_entry(in_names, in_specs),
        "outputs": _io_entry(out_names, outs),
    }
    print(f"  lowered {name} ({len(in_specs)} in / {len(outs)} out)")


def build_model_artifacts(outdir, manifest, model_spec, opt_name):
    tag = f"{model_spec.tag}_{opt_name}"
    pnames, snames, shapes, dtypes = trainstep.state_layout(
        model_spec, opt_name
    )
    state_names = pnames + snames
    state_specs = [
        spec(shapes[n], "i32" if dtypes[n] == "int32" else "f32")
        for n in state_names
    ]
    batch = model_spec.batch_specs()
    batch_names = [b[0] for b in batch]
    batch_specs = [spec(b[1], b[2]) for b in batch]

    # init(seed) -> state
    _lower_graph(
        outdir, manifest, f"init_{tag}",
        trainstep.build_init(model_spec, opt_name),
        ["seed"], [spec((), "i32")], out_names=state_names,
    )
    # train(*state, *batch, lr) -> (state', loss, gnorm, clipped)
    _lower_graph(
        outdir, manifest, f"train_{tag}",
        trainstep.build_train(model_spec, opt_name),
        state_names + batch_names + ["lr"],
        state_specs + batch_specs + [spec((), "f32")],
        out_names=state_names + ["loss", "grad_norm", "clipped"],
    )
    # eval(*params, *batch) -> loss
    _lower_graph(
        outdir, manifest, f"eval_{tag}",
        trainstep.build_eval(model_spec, opt_name),
        pnames + batch_names,
        state_specs[: len(pnames)] + batch_specs,
        out_names=["loss"],
    )
    entry = {
        "train": f"train_{tag}",
        "init": f"init_{tag}",
        "eval": f"eval_{tag}",
        "state_names": state_names,
        "n_params": len(pnames),
    }
    # dominance(*momenta) -> f32[K,3] (only for momentum-carrying matrix opts)
    dom_fn, dom_names = trainstep.build_dominance(model_spec, opt_name)
    if dom_names:
        dom_indices, _ = trainstep.dominance_state_indices(
            model_spec, opt_name
        )
        dom_specs = [state_specs[i] for i in dom_indices]
        _lower_graph(
            outdir, manifest, f"dom_{tag}", dom_fn,
            dom_names, dom_specs, out_names=["ratios"],
        )
        entry["dominance"] = f"dom_{tag}"
        entry["dom_indices"] = dom_indices
        entry["dom_names"] = dom_names
    return entry


def build_precond_artifacts(outdir, manifest):
    shapes, per_model = configs.precond_shapes()
    ops = {}
    for m, n in shapes:
        v = spec((m, n), "f32")

        def ns_op(x):
            if fits_single_block(*x.shape):
                return ns5_pallas(x)
            return ref.newton_schulz_ref(x)

        def rn_op(x):
            return rownorm_pallas(x)

        name_ns = f"ns5_{m}x{n}"
        name_rn = f"rownorm_{m}x{n}"
        _lower_graph(outdir, manifest, name_ns, ns_op, ["v"], [v],
                     out_names=["d"])
        _lower_graph(outdir, manifest, name_rn, rn_op, ["v"], [v],
                     out_names=["d"])
        ops[f"{m}x{n}"] = {
            "ns5": name_ns, "rownorm": name_rn,
            "ns5_flops": flops(m, n),
            "rownorm_flops": rownorm_flops(m, n),
            "vmem_bytes": vmem_bytes(m, n),
        }
    manifest["precond"] = {
        "shapes": [list(s) for s in shapes],
        "per_model": per_model,
        "ops": ops,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="restrict to these model tags")
    ap.add_argument("--skip-e2e", action="store_true",
                    help="skip the ~100M e2e graphs (fast CI builds)")
    ap.add_argument("--skip-precond", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"vocab": configs.VOCAB, "graphs": {}, "models": {}}

    for tag, model_spec in configs.REGISTRY.items():
        if args.only and tag not in args.only:
            continue
        if args.skip_e2e and model_spec.scale == "e2e":
            continue
        params = jax.eval_shape(
            lambda k, ms=model_spec: ms.module().init(ms.cfg, k),
            jax.random.PRNGKey(0),
        )
        entry = {
            "family": model_spec.family,
            "scale": model_spec.scale,
            "batch_specs": [
                [b[0], [int(d) for d in b[1]], b[2]]
                for b in model_spec.batch_specs()
            ],
            "param_count": count_params(params),
            "lr_adamw_ratio": model_spec.lr_adamw_ratio,
            "optimizers": {},
        }
        print(f"[{tag}] params={entry['param_count']:,}")
        for opt_name in model_spec.optimizers:
            entry["optimizers"][opt_name] = build_model_artifacts(
                args.out, manifest, model_spec, opt_name
            )
        manifest["models"][tag] = entry

    if not args.skip_precond:
        print("[precond ops]")
        build_precond_artifacts(args.out, manifest)

    path = os.path.join(args.out, "manifest.json")
    # merge with an existing manifest so --only builds stay incremental
    if (args.only or args.skip_e2e or args.skip_precond) and os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        old["graphs"].update(manifest["graphs"])
        old["models"].update(manifest["models"])
        if "precond" in manifest:
            old["precond"] = manifest["precond"]
        manifest = old
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {path} ({len(manifest['graphs'])} graphs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
