"""LLaMA-style decoder LM: RMSNorm, rotary positions, SwiGLU MLP, no biases.

Matches the paper's LLaMA protocol (Section 4.1): by default the LM head
and token embedding are handled by AdamW (`matrix_covers_embeddings=False`);
Appendix D.4's ablation flips that flag.
"""

import jax
import jax.numpy as jnp

from . import common as C


class LlamaConfig:
    def __init__(self, vocab, d_model, n_layers, n_heads, d_ff, seq_len,
                 matrix_covers_embeddings=False, rope_base=10000.0):
        assert d_model % n_heads == 0
        self.vocab = vocab
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.d_ff = d_ff
        self.seq_len = seq_len
        self.matrix_covers_embeddings = matrix_covers_embeddings
        self.rope_base = rope_base


def init(cfg, key):
    d, f = cfg.d_model, cfg.d_ff
    keys = iter(jax.random.split(key, 4 + 7 * cfg.n_layers))
    p = {
        "tok_emb": jax.random.normal(next(keys), (cfg.vocab, d)) * 0.02,
        "final_norm": jnp.ones((d,)),
        "head": C.linear_init(next(keys), cfg.vocab, d, scale=0.02),
    }
    proj_scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    for i in range(cfg.n_layers):
        pre = f"h{i:02d}."
        p[pre + "norm1"] = jnp.ones((d,))
        p[pre + "norm2"] = jnp.ones((d,))
        p[pre + "attn_qkv"] = C.linear_init(next(keys), 3 * d, d, scale=0.02)
        p[pre + "attn_out"] = C.linear_init(next(keys), d, d, scale=proj_scale)
        p[pre + "mlp_gate"] = C.linear_init(next(keys), f, d, scale=0.02)
        p[pre + "mlp_up"] = C.linear_init(next(keys), f, d, scale=0.02)
        p[pre + "mlp_down"] = C.linear_init(next(keys), d, f, scale=proj_scale)
    return p


def param_groups(cfg, params):
    groups = {}
    for name, v in params.items():
        is_embed = name in ("tok_emb", "head")
        if v.ndim == 2 and (cfg.matrix_covers_embeddings or not is_embed):
            groups[name] = "matrix"
        else:
            groups[name] = "adamw"
    return groups


def _rope(x, base):
    """Rotary position embedding over (B, H, T, hd)."""
    b, h, t, hd = x.shape
    half = hd // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)  # (T, half)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )


def _attention(cfg, q, k, v):
    b, t, d = q.shape
    h, hd = cfg.n_heads, d // cfg.n_heads

    def split(x):
        return x.reshape(b, t, h, hd).transpose(0, 2, 1, 3)

    qh = _rope(split(q), cfg.rope_base)
    kh = _rope(split(k), cfg.rope_base)
    vh = split(v)
    att = (qh @ kh.transpose(0, 1, 3, 2)) * (hd**-0.5)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jax.nn.softmax(jnp.where(mask, att, -1e9), axis=-1)
    return (att @ vh).transpose(0, 2, 1, 3).reshape(b, t, d)


def forward(cfg, params, inputs):
    x = params["tok_emb"][inputs]
    for i in range(cfg.n_layers):
        pre = f"h{i:02d}."
        hN = C.rmsnorm(x, params[pre + "norm1"])
        qkv = C.apply_linear(hN, params[pre + "attn_qkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        x = x + C.apply_linear(_attention(cfg, q, k, v), params[pre + "attn_out"])
        hN = C.rmsnorm(x, params[pre + "norm2"])
        gate = C.silu(C.apply_linear(hN, params[pre + "mlp_gate"]))
        up = C.apply_linear(hN, params[pre + "mlp_up"])
        x = x + C.apply_linear(gate * up, params[pre + "mlp_down"])
    x = C.rmsnorm(x, params["final_norm"])
    return C.apply_linear(x, params["head"])


def loss(cfg, params, tokens):
    inputs, targets = C.split_tokens(tokens)
    return C.cross_entropy_lm(forward(cfg, params, inputs), targets)
