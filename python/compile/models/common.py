"""Shared model plumbing: parameter dictionaries, initializers, losses.

Conventions (binding for every model family):

* Parameters live in a flat ``dict[str, jnp.ndarray]``; graph lowering
  orders them by sorted key, and ``aot.py`` records that order in the
  manifest so the rust side can treat state as an opaque buffer list.
* Every linear weight is stored ``(d_out, d_in)`` and applied as
  ``x @ W.T`` — rows index d_out, so RMNP's row normalization along the
  last axis is exactly the paper's "row-wise (d_in) l2 normalization".
* ``param_groups`` labels each parameter ``"matrix"`` (preconditioned by
  Muon/RMNP/Shampoo/SOAP) or ``"adamw"`` (vector-like, or embeddings/head
  when the config excludes them — paper Section 4.1 / Appendix D.4).
"""

import jax
import jax.numpy as jnp


def linear_init(key, d_out, d_in, scale=None):
    """Gaussian init with 1/sqrt(d_in) fan-in scaling (GPT-2 convention)."""
    if scale is None:
        scale = d_in**-0.5
    return jax.random.normal(key, (d_out, d_in), jnp.float32) * scale


def apply_linear(x, w):
    """x: (..., d_in) @ W(d_out, d_in)^T -> (..., d_out)."""
    return x @ w.T


def layernorm(x, gain, eps=1e-5):
    """LayerNorm without bias (paper disables biases)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gain


def rmsnorm(x, gain, eps=1e-6):
    """RMSNorm (LLaMA convention)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def gelu(x):
    """tanh-approximate GELU (GPT-2's activation; avoids the erf custom
    call so artifacts stay portable across PJRT plugins)."""
    return (
        0.5
        * x
        * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))
    )


def silu(x):
    return x * jax.nn.sigmoid(x)


def causal_attention(q, k, v, n_heads):
    """Multi-head causal attention over (B, T, D) tensors."""
    b, t, d = q.shape
    hd = d // n_heads

    def split(x):
        return x.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    att = (qh @ kh.transpose(0, 1, 3, 2)) * (hd**-0.5)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = att @ vh  # (b, h, t, hd)
    return out.transpose(0, 2, 1, 3).reshape(b, t, d)


def cross_entropy_lm(logits, targets):
    """Mean next-token cross entropy. logits: (B,T,V), targets: (B,T) i32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def cross_entropy_cls(logits, labels):
    """Mean classification cross entropy. logits: (B,C), labels: (B,) i32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def split_tokens(tokens):
    """(B, T+1) token block -> (inputs (B,T), targets (B,T))."""
    return tokens[:, :-1], tokens[:, 1:]


def count_params(params):
    return int(sum(int(p.size) for p in params.values()))


def ordered_names(params):
    """The canonical (manifest) parameter ordering."""
    return sorted(params.keys())
