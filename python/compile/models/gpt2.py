"""GPT-2-style decoder LM (pre-LN, learned positions, GELU MLP, no biases).

Mirrors the paper's GPT-2 configuration (Section 4.1 / Table 5): dropout 0,
biases disabled, untied LM head. Following Appendix D.1, the token
embedding and LM head are *matrix* parameters for this family (the matrix
optimizer covers them) unless the config overrides it.
"""

import jax
import jax.numpy as jnp

from . import common as C


class GPT2Config:
    def __init__(self, vocab, d_model, n_layers, n_heads, seq_len,
                 matrix_covers_embeddings=True):
        assert d_model % n_heads == 0
        self.vocab = vocab
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.seq_len = seq_len
        self.matrix_covers_embeddings = matrix_covers_embeddings


def init(cfg, key):
    """Build the parameter dict."""
    d = cfg.d_model
    keys = iter(jax.random.split(key, 4 + 6 * cfg.n_layers))
    p = {
        "tok_emb": jax.random.normal(next(keys), (cfg.vocab, d)) * 0.02,
        "pos_emb": jax.random.normal(next(keys), (cfg.seq_len, d)) * 0.01,
        "final_ln": jnp.ones((d,)),
        "head": C.linear_init(next(keys), cfg.vocab, d, scale=0.02),
    }
    # residual-branch output projections get the GPT-2 depth-scaled init
    proj_scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    for i in range(cfg.n_layers):
        pre = f"h{i:02d}."
        p[pre + "ln1"] = jnp.ones((d,))
        p[pre + "ln2"] = jnp.ones((d,))
        p[pre + "attn_qkv"] = C.linear_init(next(keys), 3 * d, d, scale=0.02)
        p[pre + "attn_out"] = C.linear_init(next(keys), d, d, scale=proj_scale)
        p[pre + "mlp_in"] = C.linear_init(next(keys), 4 * d, d, scale=0.02)
        p[pre + "mlp_out"] = C.linear_init(next(keys), d, 4 * d, scale=proj_scale)
    return p


def param_groups(cfg, params):
    """Label each parameter matrix/adamw (see common.py docstring)."""
    groups = {}
    for name, v in params.items():
        is_embed = name in ("tok_emb", "pos_emb", "head")
        if v.ndim == 2 and (cfg.matrix_covers_embeddings or not is_embed):
            groups[name] = "matrix"
        else:
            groups[name] = "adamw"
    return groups


def forward(cfg, params, inputs):
    """inputs: (B, T) i32 -> logits (B, T, V)."""
    t = inputs.shape[1]
    x = params["tok_emb"][inputs] + params["pos_emb"][:t][None]
    for i in range(cfg.n_layers):
        pre = f"h{i:02d}."
        h = C.layernorm(x, params[pre + "ln1"])
        qkv = C.apply_linear(h, params[pre + "attn_qkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        att = C.causal_attention(q, k, v, cfg.n_heads)
        x = x + C.apply_linear(att, params[pre + "attn_out"])
        h = C.layernorm(x, params[pre + "ln2"])
        h = C.gelu(C.apply_linear(h, params[pre + "mlp_in"]))
        x = x + C.apply_linear(h, params[pre + "mlp_out"])
    x = C.layernorm(x, params["final_ln"])
    return C.apply_linear(x, params["head"])


def loss(cfg, params, tokens):
    """tokens: (B, T+1) i32 -> scalar LM loss."""
    inputs, targets = C.split_tokens(tokens)
    return C.cross_entropy_lm(forward(cfg, params, inputs), targets)
