"""Small residual CNN ("ResNet-18-like", Appendix E.6) for image
classification on synthetic CIFAR-shaped data.

Conv kernels are *stored* as 2-D matrices (out_ch, in_ch*k*k) — the exact
flattening under which the paper applies matrix preconditioning to conv
layers — and reshaped to OIHW inside the forward pass. Convolutions use
lax.conv_general_dilated (pure HLO, no custom calls).
"""

import jax
import jax.numpy as jnp
from jax import lax

from . import common as C


class ConvNetConfig:
    def __init__(self, n_classes=10, width=32, n_blocks=3, image_hw=32,
                 matrix_covers_embeddings=True):
        self.n_classes = n_classes
        self.width = width
        self.n_blocks = n_blocks
        self.image_hw = image_hw
        # kept for interface parity with the LM configs (unused here)
        self.matrix_covers_embeddings = matrix_covers_embeddings


def _conv_init(key, c_out, c_in, k=3):
    scale = (c_in * k * k) ** -0.5
    return jax.random.normal(key, (c_out, c_in * k * k)) * scale


def init(cfg, key):
    w = cfg.width
    keys = iter(jax.random.split(key, 3 + 2 * cfg.n_blocks))
    p = {
        "stem": _conv_init(next(keys), w, 3),
        "head": C.linear_init(next(keys), cfg.n_classes, w * 2),
        "final_norm": jnp.ones((w * 2,)),
    }
    for i in range(cfg.n_blocks):
        pre = f"b{i:02d}."
        cin = w if i == 0 else w * 2
        p[pre + "conv1"] = _conv_init(next(keys), w * 2, cin)
        p[pre + "conv2"] = _conv_init(next(keys), w * 2, w * 2)
        p[pre + "norm1"] = jnp.ones((w * 2,))
        p[pre + "norm2"] = jnp.ones((w * 2,))
    return p


def param_groups(cfg, params):
    return {
        name: "matrix" if v.ndim == 2 else "adamw"
        for name, v in params.items()
    }


def _conv(x, w2d, k=3):
    """NCHW conv, stride 1, SAME padding; w2d is (c_out, c_in*k*k)."""
    c_out = w2d.shape[0]
    c_in = w2d.shape[1] // (k * k)
    w = w2d.reshape(c_out, c_in, k, k)
    return lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def _chan_norm(x, gain, eps=1e-5):
    """Per-channel RMS norm over spatial dims (batch-stat-free, so the
    train graph stays stateless)."""
    ms = jnp.mean(x * x, axis=(2, 3), keepdims=True)
    return x * lax.rsqrt(ms + eps) * gain[None, :, None, None]


def forward(cfg, params, images):
    """images: (B, 3, H, W) f32 -> logits (B, n_classes)."""
    x = jax.nn.relu(_conv(images, params["stem"]))
    for i in range(cfg.n_blocks):
        pre = f"b{i:02d}."
        h = jax.nn.relu(_chan_norm(_conv(x, params[pre + "conv1"]), params[pre + "norm1"]))
        h = _chan_norm(_conv(h, params[pre + "conv2"]), params[pre + "norm2"])
        if x.shape[1] == h.shape[1]:
            x = jax.nn.relu(x + h)
        else:
            x = jax.nn.relu(h)
        if i == 0:
            # one 2x2 average-pool downsample after the first block
            x = lax.reduce_window(
                x, 0.0, lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
            ) / 4.0
    feat = jnp.mean(x, axis=(2, 3))
    feat = feat * jax.lax.rsqrt(
        jnp.mean(feat * feat, axis=-1, keepdims=True) + 1e-5
    ) * params["final_norm"]
    return C.apply_linear(feat, params["head"])


def loss(cfg, params, images, labels):
    return C.cross_entropy_cls(forward(cfg, params, images), labels)
