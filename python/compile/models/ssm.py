"""Minimal selective state-space LM ("Mamba-like", Appendix E.5).

A faithful Mamba block needs hardware-aware scan kernels; what the paper's
Appendix E.5 actually tests is whether RMNP's row-normalized preconditioner
generalizes to *state-space* matrix parameters. This block keeps that
structure: input/gate projections, an input-dependent (selective) decay
gate driving a diagonal state recurrence along time, and an output
projection — all 2-D matrix parameters that the matrix optimizer
preconditions. The recurrence is a first-order scan

    s_t = a_t * s_{t-1} + (1 - a_t) * u_t,   a_t = sigmoid(W_a x_t + b)

implemented with jax.lax.scan over time (lowering to a pure-HLO while
loop; no custom calls).
"""

import jax
import jax.numpy as jnp

from . import common as C


class SSMConfig:
    def __init__(self, vocab, d_model, d_state, n_layers, seq_len,
                 matrix_covers_embeddings=False):
        self.vocab = vocab
        self.d_model = d_model
        self.d_state = d_state
        self.n_layers = n_layers
        self.seq_len = seq_len
        self.matrix_covers_embeddings = matrix_covers_embeddings


def init(cfg, key):
    d, s = cfg.d_model, cfg.d_state
    keys = iter(jax.random.split(key, 2 + 5 * cfg.n_layers))
    p = {
        "tok_emb": jax.random.normal(next(keys), (cfg.vocab, d)) * 0.02,
        "final_norm": jnp.ones((d,)),
        "head": C.linear_init(next(keys), cfg.vocab, d, scale=0.02),
    }
    for i in range(cfg.n_layers):
        pre = f"h{i:02d}."
        p[pre + "norm"] = jnp.ones((d,))
        p[pre + "in_proj"] = C.linear_init(next(keys), s, d, scale=0.02)
        p[pre + "gate_proj"] = C.linear_init(next(keys), s, d, scale=0.02)
        p[pre + "decay_proj"] = C.linear_init(next(keys), s, d, scale=0.02)
        p[pre + "out_proj"] = C.linear_init(next(keys), d, s, scale=0.02)
    return p


def param_groups(cfg, params):
    groups = {}
    for name, v in params.items():
        is_embed = name in ("tok_emb", "head")
        if v.ndim == 2 and (cfg.matrix_covers_embeddings or not is_embed):
            groups[name] = "matrix"
        else:
            groups[name] = "adamw"
    return groups


def _selective_scan(u, a):
    """s_t = a_t s_{t-1} + (1-a_t) u_t over axis 1 of (B, T, S)."""

    def step(s, ua):
        u_t, a_t = ua
        s = a_t * s + (1.0 - a_t) * u_t
        return s, s

    u_t = u.transpose(1, 0, 2)  # (T, B, S)
    a_t = a.transpose(1, 0, 2)
    s0 = jnp.zeros_like(u[:, 0, :])
    _, ys = jax.lax.scan(step, s0, (u_t, a_t))
    return ys.transpose(1, 0, 2)


def forward(cfg, params, inputs):
    x = params["tok_emb"][inputs]
    for i in range(cfg.n_layers):
        pre = f"h{i:02d}."
        h = C.rmsnorm(x, params[pre + "norm"])
        u = C.apply_linear(h, params[pre + "in_proj"])
        gate = C.silu(C.apply_linear(h, params[pre + "gate_proj"]))
        decay = jax.nn.sigmoid(C.apply_linear(h, params[pre + "decay_proj"]) + 2.0)
        s = _selective_scan(u, decay)
        x = x + C.apply_linear(s * gate, params[pre + "out_proj"])
    x = C.rmsnorm(x, params["final_norm"])
    return C.apply_linear(x, params["head"])


def loss(cfg, params, tokens):
    inputs, targets = C.split_tokens(tokens)
    return C.cross_entropy_lm(forward(cfg, params, inputs), targets)
