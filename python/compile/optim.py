"""L2 optimizer update graphs: AdamW, Muon, RMNP, Shampoo-lite, SOAP-lite.

All five implement the same mixed-update protocol as the paper
(Section 4.1): *matrix* parameters get the matrix optimizer, everything
else gets AdamW with beta=(0.9, 0.95), wd=0.1. The matrix learning rate is
RMS-rescaled by max(1, sqrt(m/n)) (Eq. 17/18).

State layout (per optimizer) is a flat dict name -> array; ordering is by
sorted key so the manifest ordering matches rust's expectations:

* adamw:   m.<p>, v.<p> for every param; plus scalar step "t".
* muon:    mom.<p> for matrix params, m.<p>/v.<p> for adamw params, "t".
* rmnp:    identical layout to muon.
* shampoo: mom.<p>, pl.<p> (m x m), pr.<p> (n x n) for matrix params,
           m./v. for adamw params, "t".
* soap:    shampoo layout plus vsq.<p> second-moment accumulators.

Shampoo/SOAP substitution note (DESIGN.md §3): the published versions take
inverse 4th roots via eigendecomposition; `eigh` lowers to LAPACK custom
calls that xla_extension 0.5.1 cannot load, so we compute inverse p-th
roots with a coupled Newton iteration (matmul-only, same fixed point) and
run SOAP as Adam-in-preconditioned-space. These appear only as sweep
baselines (paper Tables 11/12).
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.newton_schulz import fits_single_block
from .kernels.newton_schulz import newton_schulz as ns5_pallas
from .kernels.rownorm import rownorm as rownorm_pallas

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8
WEIGHT_DECAY = 0.1
MATRIX_BETA = 0.95  # Muon/RMNP momentum (Appendix B)


# ---------------------------------------------------------------------------
# shared pieces


def rms_scale(shape):
    m, n = shape
    return jnp.float32(max(1.0, (m / n) ** 0.5))


def adamw_param_update(p, g, m, v, lr, t, wd=WEIGHT_DECAY):
    """Single-tensor AdamW with bias correction; `t` is 1-based i32."""
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    tf = t.astype(jnp.float32)
    mhat = m / (1.0 - ADAM_B1**tf)
    vhat = v / (1.0 - ADAM_B2**tf)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + wd * p)
    return p, m, v


def _precondition_rownorm(vmom):
    """RMNP direction via the L1 Pallas kernel (falls back to the jnp
    oracle for 1-D/oversized operands — same math)."""
    if vmom.ndim == 2:
        return rownorm_pallas(vmom)
    return ref.rownorm_ref(vmom)


def _precondition_ns5(vmom):
    """Muon direction via the L1 Pallas kernel when the single-block
    tiling applies, else the jnp reference (identical iteration)."""
    m, n = vmom.shape
    if fits_single_block(m, n):
        return ns5_pallas(vmom)
    return ref.newton_schulz_ref(vmom)


# ---------------------------------------------------------------------------
# inverse p-th root via coupled Newton (Shampoo substrate)


def _inv_root_newton(a, p=4, iters=25):
    """X ~ (A + ridge I)^(-1/p) for SPD A, matmul-only.

    Coupled Newton iteration (Higham, *Functions of Matrices*, alg. 7.12):
      M_0 = A/c, X_0 = I;  X <- X((p+1)I - M)/p,  M <- (((p+1)I - M)/p)^p M.
    Normalizing by c = tr(A) (an upper bound on lambda_max for PSD A) keeps
    every eigenvalue of M_0 in (0, 1], where the iteration is provably
    non-expanding — a mean-eigenvalue normalizer diverges whenever the
    condition number exceeds p+1, which happens on the near-rank-1
    statistics of early training. A relative ridge keeps the smallest
    eigenvalues within the iteration's reach.
    """
    dim = a.shape[0]
    ident = jnp.eye(dim, dtype=a.dtype)
    ridge = 1e-4 * jnp.trace(a) / dim + 1e-10
    a = a + ridge * ident
    c = jnp.trace(a)
    m = a / c
    x = ident
    alpha = -1.0 / p
    for _ in range(iters):
        t = (1.0 - alpha) * ident + alpha * m
        x = x @ t
        # m <- t^p m  (p = 4: square twice)
        t2 = t @ t
        m = t2 @ t2 @ m
    return x * c**alpha


def shampoo_matrix_update(p_, g, mom, pl, pr, lr, beta=MATRIX_BETA,
                          accum=0.95, wd=WEIGHT_DECAY):
    """One Shampoo-lite step on a matrix parameter.

    L/R statistics are EMAs of GG^T and G^T G; the preconditioned direction
    is L^{-1/4} V R^{-1/4}, Frobenius-rescaled to match the Muon/RMNP
    update magnitude.
    """
    mom = beta * mom + (1.0 - beta) * g
    pl = accum * pl + (1.0 - accum) * (g @ g.T)
    pr = accum * pr + (1.0 - accum) * (g.T @ g)
    d = _inv_root_newton(pl) @ mom @ _inv_root_newton(pr)
    # normalize to unit RMS like Muon's orthogonal update (Frobenius ~ sqrt(m))
    d = d * (jnp.sqrt(jnp.float32(mom.shape[0])) / (jnp.linalg.norm(d) + 1e-8))
    p_ = p_ - lr * rms_scale(p_.shape) * (d + wd * p_)
    return p_, mom, pl, pr


def soap_matrix_update(p_, g, mom, pl, pr, vsq, lr, beta=MATRIX_BETA,
                       accum=0.95, wd=WEIGHT_DECAY):
    """SOAP-lite: Shampoo's preconditioned direction with an Adam-style
    second moment accumulated in the *preconditioned* space."""
    mom = beta * mom + (1.0 - beta) * g
    pl = accum * pl + (1.0 - accum) * (g @ g.T)
    pr = accum * pr + (1.0 - accum) * (g.T @ g)
    gp = _inv_root_newton(pl) @ g @ _inv_root_newton(pr)
    vsq = ADAM_B2 * vsq + (1.0 - ADAM_B2) * gp * gp
    dp = _inv_root_newton(pl) @ mom @ _inv_root_newton(pr)
    d = dp / (jnp.sqrt(vsq) + 1e-8)
    d = d * (jnp.sqrt(jnp.float32(mom.shape[0])) / (jnp.linalg.norm(d) + 1e-8))
    p_ = p_ - lr * rms_scale(p_.shape) * (d + wd * p_)
    return p_, mom, pl, pr, vsq


# ---------------------------------------------------------------------------
# optimizer objects


class Optimizer:
    """Builds init-state and apply-update graphs over a param dict.

    `groups` maps param name -> "matrix"|"adamw"; `lr_adamw_ratio` is the
    fixed ratio lr_adamw / lr_matrix used by the mixed protocol (rust
    passes lr_matrix each step; the AdamW LR follows at this ratio, which
    mirrors the paper's fixed-lr_AdamW + swept-lr_Matrix setup).
    """

    name = "base"

    def __init__(self, groups, lr_adamw_ratio=1.0):
        self.groups = groups
        self.lr_adamw_ratio = lr_adamw_ratio

    def matrix_names(self):
        return sorted(n for n, g in self.groups.items() if g == "matrix")

    def adamw_names(self):
        return sorted(n for n, g in self.groups.items() if g == "adamw")

    def init_state(self, params):
        raise NotImplementedError

    def apply(self, params, grads, state, lr):
        raise NotImplementedError

    def _apply_adamw_group(self, params, grads, state, new_state, lr, t):
        lr_a = lr * self.lr_adamw_ratio
        for name in self.adamw_names():
            p, m, v = adamw_param_update(
                params[name], grads[name], state["m." + name],
                state["v." + name], lr_a, t,
            )
            params[name] = p
            new_state["m." + name] = m
            new_state["v." + name] = v


class AdamW(Optimizer):
    name = "adamw"

    def __init__(self, groups, **kw):
        # AdamW ignores the matrix/adamw split: everything is elementwise.
        groups = {k: "adamw" for k in groups}
        super().__init__(groups, **kw)

    def init_state(self, params):
        s = {"t": jnp.zeros((), jnp.int32)}
        for name in self.adamw_names():
            s["m." + name] = jnp.zeros_like(params[name])
            s["v." + name] = jnp.zeros_like(params[name])
        return s

    def apply(self, params, grads, state, lr):
        params = dict(params)
        t = state["t"] + 1
        new_state = {"t": t}
        self._apply_adamw_group(params, grads, state, new_state, lr, t)
        return params, new_state


class _MatrixMomentumOpt(Optimizer):
    """Shared scaffolding for Muon and RMNP (identical except for the
    preconditioner on line 5 of Algorithms 1/2)."""

    def _precondition(self, vmom):
        raise NotImplementedError

    def init_state(self, params):
        s = {"t": jnp.zeros((), jnp.int32)}
        for name in self.matrix_names():
            s["mom." + name] = jnp.zeros_like(params[name])
        for name in self.adamw_names():
            s["m." + name] = jnp.zeros_like(params[name])
            s["v." + name] = jnp.zeros_like(params[name])
        return s

    def apply(self, params, grads, state, lr):
        params = dict(params)
        t = state["t"] + 1
        new_state = {"t": t}
        for name in self.matrix_names():
            vmom = MATRIX_BETA * state["mom." + name] + (1.0 - MATRIX_BETA) * grads[name]
            d = self._precondition(vmom)
            scale = rms_scale(params[name].shape)
            params[name] = params[name] - lr * scale * (d + WEIGHT_DECAY * params[name])
            new_state["mom." + name] = vmom
        self._apply_adamw_group(params, grads, state, new_state, lr, t)
        return params, new_state


class Muon(_MatrixMomentumOpt):
    name = "muon"

    def _precondition(self, vmom):
        return _precondition_ns5(vmom)


class RMNP(_MatrixMomentumOpt):
    name = "rmnp"

    def _precondition(self, vmom):
        return _precondition_rownorm(vmom)


class Shampoo(Optimizer):
    name = "shampoo"

    def init_state(self, params):
        s = {"t": jnp.zeros((), jnp.int32)}
        for name in self.matrix_names():
            m, n = params[name].shape
            s["mom." + name] = jnp.zeros_like(params[name])
            s["pl." + name] = jnp.zeros((m, m), jnp.float32)
            s["pr." + name] = jnp.zeros((n, n), jnp.float32)
        for name in self.adamw_names():
            s["m." + name] = jnp.zeros_like(params[name])
            s["v." + name] = jnp.zeros_like(params[name])
        return s

    def apply(self, params, grads, state, lr):
        params = dict(params)
        t = state["t"] + 1
        new_state = {"t": t}
        for name in self.matrix_names():
            p, mom, pl, pr = shampoo_matrix_update(
                params[name], grads[name], state["mom." + name],
                state["pl." + name], state["pr." + name], lr,
            )
            params[name] = p
            new_state["mom." + name] = mom
            new_state["pl." + name] = pl
            new_state["pr." + name] = pr
        self._apply_adamw_group(params, grads, state, new_state, lr, t)
        return params, new_state


class Soap(Optimizer):
    name = "soap"

    def init_state(self, params):
        s = {"t": jnp.zeros((), jnp.int32)}
        for name in self.matrix_names():
            m, n = params[name].shape
            s["mom." + name] = jnp.zeros_like(params[name])
            s["pl." + name] = jnp.zeros((m, m), jnp.float32)
            s["pr." + name] = jnp.zeros((n, n), jnp.float32)
            s["vsq." + name] = jnp.zeros_like(params[name])
        for name in self.adamw_names():
            s["m." + name] = jnp.zeros_like(params[name])
            s["v." + name] = jnp.zeros_like(params[name])
        return s

    def apply(self, params, grads, state, lr):
        params = dict(params)
        t = state["t"] + 1
        new_state = {"t": t}
        for name in self.matrix_names():
            p, mom, pl, pr, vsq = soap_matrix_update(
                params[name], grads[name], state["mom." + name],
                state["pl." + name], state["pr." + name],
                state["vsq." + name], lr,
            )
            params[name] = p
            new_state["mom." + name] = mom
            new_state["pl." + name] = pl
            new_state["pr." + name] = pr
            new_state["vsq." + name] = vsq
        self._apply_adamw_group(params, grads, state, new_state, lr, t)
        return params, new_state


OPTIMIZERS = {
    "adamw": AdamW,
    "muon": Muon,
    "rmnp": RMNP,
    "shampoo": Shampoo,
    "soap": Soap,
}


def make(name, groups, lr_adamw_ratio=1.0):
    return OPTIMIZERS[name](groups, lr_adamw_ratio=lr_adamw_ratio)


# ---------------------------------------------------------------------------
# dominance metrics (paper Section 3.2 / Appendix B)


def dominance_metrics(vmom):
    """(r_avg, r_min, r_max) of the Gram matrix V V^T for one matrix
    parameter (Eqs. 5-6). Transposes tall matrices so the Gram side is the
    smaller dimension, matching the paper's m <= n convention."""
    v = vmom if vmom.shape[0] <= vmom.shape[1] else vmom.T
    m = v.shape[0]
    gram = v @ v.T
    diag = jnp.diag(gram)
    offdiag_sum = jnp.sum(jnp.abs(gram), axis=1) - jnp.abs(diag)
    denom = offdiag_sum / jnp.maximum(m - 1, 1)
    r = diag / jnp.maximum(denom, 1e-12)
    return jnp.stack([jnp.mean(r), jnp.min(r), jnp.max(r)])
