#!/usr/bin/env python3
"""Golden vectors for the bf16 round-to-nearest-even codec.

Emits rust/tests/golden/bf16_golden.json: pairs of (f32 bit pattern,
expected bf16 bit pattern), computed with an *independent* rounding
formulation (explicit round/sticky bits over struct-packed IEEE-754
words) rather than the add-trick the Rust code uses — so the test pins
the rounding semantics, not self-consistency. Includes exact halfway
ties in both directions, subnormals, overflow-to-inf, infinities, and
NaN quieting.

Regenerate with:  python3 python/gen_bf16_golden.py
"""

import json
import os
import struct


def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", x))[0]


def bits_f32(b):
    return struct.unpack("<f", struct.pack("<I", b))[0]


def bf16_rne(bits):
    """Round the f32 bit pattern to bf16 with round-to-nearest-even."""
    exp = (bits >> 23) & 0xFF
    man = bits & 0x7FFFFF
    if exp == 0xFF and man != 0:  # NaN: quiet it, keep the payload's top bits
        return ((bits >> 16) | 0x0040) & 0xFFFF
    kept = bits >> 16
    round_bit = (bits >> 15) & 1
    sticky = bits & 0x7FFF
    if round_bit and (sticky != 0 or (kept & 1)):
        kept += 1  # may carry into the exponent: overflow rounds to inf
    return kept & 0xFFFF


def main():
    values = [
        0.0, -0.0, 1.0, -1.0, 2.0, 1.5, -0.5, 0.25, -0.0078125,
        0.1, -0.1, 3.14159265, 2.7182818, 1e-8, 123456.789, 65504.0,
        1e-40, -1e-40,              # subnormals survive (bf16 shares the exponent range)
        3.389e38, 3.4e38,           # near/over bf16 max: RNE rounds the latter to inf
        float("inf"), float("-inf"),
    ]
    bit_patterns = [f32_bits(v) for v in values]
    # exact halfway ties (round bit set, sticky clear): RNE goes to even,
    # so 0x3F80 stays and 0x3F81 bumps; both signs; exponent-carry tie
    for kept in (0x3F80, 0x3F81, 0x4000, 0x4001, 0xBF80, 0xBF81,
                 0x7F00, 0x7F7F, 0x0080, 0x0001, 0x8081, 0x3FFF):
        bit_patterns.append((kept << 16) | 0x8000)
    # ties broken by sticky bits (must round up regardless of evenness)
    bit_patterns.append((0x3F80 << 16) | 0x8001)
    bit_patterns.append((0xBF80 << 16) | 0xFFFF)
    # NaNs: payload preserved in the kept bits, quiet bit forced on
    bit_patterns.append(0x7FC00000)  # canonical quiet NaN
    bit_patterns.append(0x7F800001)  # signaling NaN -> quieted, not inf
    bit_patterns.append(0xFFC01234)  # negative NaN with payload

    cases = [
        {"f32_bits": b, "bf16_bits": bf16_rne(b)} for b in bit_patterns
    ]
    out = os.path.join(
        os.path.dirname(os.path.abspath(__file__)),
        "..", "rust", "tests", "golden", "bf16_golden.json",
    )
    with open(out, "w") as f:
        json.dump({"cases": cases}, f, indent=1)
        f.write("\n")
    print(f"wrote {len(cases)} cases to {out}")


if __name__ == "__main__":
    main()
