"""Optimizer-graph correctness: each update rule vs hand-computed numpy.

These run the L2 update functions eagerly (same code that gets lowered
into the train-step artifacts) and check them against independent numpy
implementations of Algorithms 1/2 and AdamW.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim as O


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


def groups_for(names, matrix):
    return {n: ("matrix" if n in matrix else "adamw") for n in names}


class TestAdamWGraph:
    def test_single_step_matches_numpy(self):
        p = rand((6, 4), 0)
        g = rand((6, 4), 1)
        opt = O.AdamW(groups_for(["w"], []))
        state = opt.init_state({"w": p})
        newp, news = opt.apply({"w": p}, {"w": g}, state, jnp.float32(1e-2))
        # numpy reference
        pn, gn = np.asarray(p), np.asarray(g)
        m = 0.1 * gn
        v = 0.05 * gn * gn
        mhat = m / (1 - 0.9)
        vhat = v / (1 - 0.95)
        want = pn - 1e-2 * (mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * pn)
        np.testing.assert_allclose(newp["w"], want, rtol=1e-5, atol=1e-6)
        assert int(news["t"]) == 1

    def test_all_params_treated_elementwise(self):
        opt = O.AdamW(groups_for(["a", "b"], ["a"]))
        assert opt.matrix_names() == []
        assert set(opt.adamw_names()) == {"a", "b"}


class TestMuonRmnpGraphs:
    def _run(self, opt_cls, p, g):
        opt = opt_cls(groups_for(["w"], ["w"]))
        state = opt.init_state({"w": p})
        return opt.apply({"w": p}, {"w": g}, state, jnp.float32(0.01))

    def test_rmnp_update_is_row_normalized_momentum(self):
        p = rand((8, 16), 2)
        g = rand((8, 16), 3)
        newp, news = self._run(O.RMNP, p, g)
        vmom = 0.05 * np.asarray(g)  # beta=0.95, V0=0
        norms = np.linalg.norm(vmom, axis=1, keepdims=True)
        d = vmom / np.maximum(norms, 1e-7)
        want = np.asarray(p) - 0.01 * (d + 0.1 * np.asarray(p))
        np.testing.assert_allclose(newp["w"], want, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(news["mom.w"], vmom, rtol=1e-5)

    def test_muon_update_direction_is_orthogonalized(self):
        p = rand((8, 16), 4)
        g = rand((8, 16), 5)
        newp, _ = self._run(O.Muon, p, g)
        # implied direction d = (p - p' )/lr - wd*p must be ~ orthogonal rows
        d = (np.asarray(p) - np.asarray(newp["w"])) / 0.01 - 0.1 * np.asarray(p)
        s = np.linalg.svd(d, compute_uv=False)
        assert s.max() < 1.7 and s.min() > 0.15

    def test_rms_scale_applied_for_tall_matrices(self):
        # (32, 8): scale = sqrt(32/8) = 2
        p = rand((32, 8), 6)
        g = rand((32, 8), 7)
        opt = O.RMNP(groups_for(["w"], ["w"]))
        state = opt.init_state({"w": p})
        newp, _ = opt.apply({"w": p}, {"w": g}, state, jnp.float32(0.01))
        d_eff = (np.asarray(p) - np.asarray(newp["w"])) / 0.01
        vmom = 0.05 * np.asarray(g)
        d = vmom / np.maximum(np.linalg.norm(vmom, axis=1, keepdims=True), 1e-7)
        want = 2.0 * (d + 0.1 * np.asarray(p))
        np.testing.assert_allclose(d_eff, want, rtol=1e-4, atol=1e-5)

    def test_mixed_groups_route_correctly(self):
        p = {"w": rand((4, 4), 8), "b": rand((4,), 9)}
        g = {"w": rand((4, 4), 10), "b": rand((4,), 11)}
        opt = O.RMNP(groups_for(["w", "b"], ["w"]))
        state = opt.init_state(p)
        assert "mom.w" in state and "m.b" in state and "v.b" in state
        newp, news = opt.apply(dict(p), g, state, jnp.float32(0.01))
        assert newp["w"].shape == (4, 4) and newp["b"].shape == (4,)
        assert int(news["t"]) == 1


class TestShampooSoap:
    def test_inv_root_newton_accuracy(self):
        rng = np.random.default_rng(0)
        b = rng.standard_normal((12, 12)).astype(np.float32)
        a = jnp.asarray(b @ b.T + 0.5 * np.eye(12, dtype=np.float32))
        x = O._inv_root_newton(a, p=4, iters=25)
        # verify X^4 A ~ I
        x4 = x @ x @ x @ x
        np.testing.assert_allclose(
            np.asarray(x4 @ a), np.eye(12), rtol=0, atol=5e-2
        )

    def test_shampoo_step_shapes_and_descent_scale(self):
        p = rand((8, 12), 12)
        g = rand((8, 12), 13)
        opt = O.Shampoo(groups_for(["w"], ["w"]))
        state = opt.init_state({"w": p})
        assert state["pl.w"].shape == (8, 8)
        assert state["pr.w"].shape == (12, 12)
        newp, news = opt.apply({"w": p}, {"w": g}, state, jnp.float32(0.01))
        assert np.all(np.isfinite(np.asarray(newp["w"])))
        assert news["pl.w"].shape == (8, 8)

    def test_soap_step_finite(self):
        p = rand((8, 12), 14)
        g = rand((8, 12), 15)
        opt = O.Soap(groups_for(["w"], ["w"]))
        state = opt.init_state({"w": p})
        newp, news = opt.apply({"w": p}, {"w": g}, state, jnp.float32(0.01))
        assert np.all(np.isfinite(np.asarray(newp["w"])))
        assert "vsq.w" in news


class TestDominanceMetrics:
    def test_identity_rows_are_perfectly_dominant(self):
        # orthogonal rows -> off-diagonals ~ 0 -> huge ratios
        v = jnp.eye(6, dtype=jnp.float32)
        r = np.asarray(O.dominance_metrics(v))
        assert r[0] > 1e6 and r[1] > 1e6 and r[2] > 1e6

    def test_rank_one_is_non_dominant(self):
        # identical rows -> diag == offdiag -> ratios ~ 1
        row = rand((1, 32), 16)
        v = jnp.tile(row, (8, 1))
        r = np.asarray(O.dominance_metrics(v))
        np.testing.assert_allclose(r, np.ones(3), rtol=1e-3)

    def test_ordering_min_avg_max(self):
        v = rand((16, 64), 17)
        r_avg, r_min, r_max = np.asarray(O.dominance_metrics(v))
        assert r_min <= r_avg <= r_max
        assert r_min > 0

    def test_transposes_tall_input(self):
        v = rand((64, 16), 18)
        r1 = np.asarray(O.dominance_metrics(v))
        r2 = np.asarray(O.dominance_metrics(v.T))
        np.testing.assert_allclose(r1, r2, rtol=1e-5)
