"""Manifest/artifact contract tests (run against the built `artifacts/`).

Skipped when artifacts haven't been built yet (e.g. a fresh checkout
running unit tests before `make artifacts`).
"""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_every_graph_file_exists(manifest):
    for name, g in manifest["graphs"].items():
        path = os.path.join(ART, g["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 100, name


def test_model_entries_reference_known_graphs(manifest):
    for tag, model in manifest["models"].items():
        for opt, entry in model["optimizers"].items():
            for role in ("train", "init", "eval"):
                assert entry[role] in manifest["graphs"], (tag, opt, role)
            if "dominance" in entry:
                assert entry["dominance"] in manifest["graphs"]


def test_train_io_contract(manifest):
    """train inputs = state + batch + lr; outputs = state + 3 metrics, with
    matching names/shapes so rust can feed outputs back as inputs."""
    for tag, model in manifest["models"].items():
        batch_names = [b[0] for b in model["batch_specs"]]
        for opt, entry in model["optimizers"].items():
            g = manifest["graphs"][entry["train"]]
            names_in = [i[0] for i in g["inputs"]]
            names_out = [o[0] for o in g["outputs"]]
            state = entry["state_names"]
            assert names_in == state + batch_names + ["lr"], (tag, opt)
            assert names_out == state + ["loss", "grad_norm", "clipped"]
            # state element shapes identical between input and output
            for i in range(len(state)):
                assert g["inputs"][i][1] == g["outputs"][i][1], (tag, opt, i)
                assert g["inputs"][i][2] == g["outputs"][i][2]


def test_eval_takes_params_only(manifest):
    for tag, model in manifest["models"].items():
        for opt, entry in model["optimizers"].items():
            g = manifest["graphs"][entry["eval"]]
            n_params = entry["n_params"]
            batch = len(model["batch_specs"])
            assert len(g["inputs"]) == n_params + batch, (tag, opt)
            assert [o[0] for o in g["outputs"]] == ["loss"]


def test_dominance_indices_point_at_momenta(manifest):
    for tag, model in manifest["models"].items():
        for opt, entry in model["optimizers"].items():
            if "dominance" not in entry:
                continue
            for idx, name in zip(entry["dom_indices"], entry["dom_names"]):
                assert entry["state_names"][idx] == name, (tag, opt)


def test_precond_ops_cover_table4(manifest):
    pre = manifest["precond"]
    assert len(pre["per_model"]) == 8
    for model in pre["per_model"]:
        for (shape, _count) in model["counts"]:
            key = f"{shape[0]}x{shape[1]}"
            assert key in pre["ops"], key
            for role in ("ns5", "rownorm"):
                gname = pre["ops"][key][role]
                assert gname in manifest["graphs"], gname


def test_precond_flops_gap_grows(manifest):
    """The arithmetic-complexity ratio (the paper's core claim) must grow
    with d_model across the Table 4 shape set."""
    pre = manifest["precond"]
    ratios = []
    for model in pre["per_model"]:
        d = model["d_model"]
        key = f"{4 * d}x{d}"
        ops = pre["ops"][key]
        ratios.append(ops["ns5_flops"] / ops["rownorm_flops"])
    assert ratios == sorted(ratios)
    assert ratios[-1] > 10 * ratios[0] / 10  # strictly increasing overall
    assert ratios[-1] > 1000
