"""Kernel-vs-oracle correctness: the core L1 signal.

Every Pallas kernel (interpret=True) must match its pure-jnp reference in
`compile.kernels.ref` across a hypothesis sweep of shapes and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

dims = st.integers(min_value=1, max_value=97)
small_dims = st.integers(min_value=1, max_value=48)


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


class TestRownorm:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, n, seed):
        v = rand((m, n), seed)
        got = kernels.rownorm(v)
        want = ref.rownorm_ref(v)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
    def test_unit_rows(self, m, n, seed):
        # Lemma A.1(i): every row of RN(V) has unit l2 norm (a.s.).
        v = rand((m, n), seed) + 0.1
        d = kernels.rownorm(v)
        norms = jnp.linalg.norm(d, axis=-1)
        np.testing.assert_allclose(norms, np.ones(m), rtol=1e-4)

    def test_zero_rows_stay_zero(self):
        v = jnp.zeros((4, 8))
        d = kernels.rownorm(v)
        assert bool(jnp.all(d == 0.0))
        assert bool(jnp.all(jnp.isfinite(d)))

    def test_blocking_invariance(self):
        # Result must not depend on the BlockSpec tiling.
        v = rand((300, 33), 7)
        a = kernels.rownorm(v, block_rows=128)
        b = kernels.rownorm(v, block_rows=64)
        c = kernels.rownorm(v, block_rows=301)
        np.testing.assert_allclose(a, b, rtol=1e-6)
        np.testing.assert_allclose(a, c, rtol=1e-6)

    def test_scale_invariance(self):
        # RN(cV) == RN(V) for c > 0 — normalization kills row scale.
        v = rand((16, 32), 3) + 0.05
        np.testing.assert_allclose(
            kernels.rownorm(v), kernels.rownorm(17.0 * v), rtol=1e-4, atol=1e-6
        )


class TestNewtonSchulz:
    @settings(max_examples=15, deadline=None)
    @given(m=small_dims, n=small_dims, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, n, seed):
        g = rand((m, n), seed)
        got = kernels.newton_schulz(g)
        want = ref.newton_schulz_ref(g)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_approx_orthogonalizes(self):
        # After NS5, singular values should be pushed toward 1.
        g = rand((32, 64), 11)
        x = np.asarray(kernels.newton_schulz(g))
        s = np.linalg.svd(x, compute_uv=False)
        assert s.max() < 1.6
        assert s.min() > 0.3

    def test_transpose_consistency(self):
        # Tall matrices go through the internal transpose path.
        g = rand((64, 24), 5)
        got = kernels.newton_schulz(g)
        want = ref.newton_schulz_ref(g)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_fits_single_block(self):
        assert kernels.fits_single_block(1024, 1024)
        assert not kernels.fits_single_block(4096, 4096)

    def test_flops_ordering(self):
        # NS5 cost dwarfs rownorm cost and the gap grows with m (Table 2).
        small = kernels.flops(64, 256) / kernels.rownorm_flops(64, 256)
        big = kernels.flops(1024, 4096) / kernels.rownorm_flops(1024, 4096)
        assert big > small > 10


class TestMomentum:
    @settings(max_examples=20, deadline=None)
    @given(
        m=dims,
        n=dims,
        beta=st.sampled_from([0.0, 0.5, 0.9, 0.95, 0.99]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, n, beta, seed):
        v = rand((m, n), seed)
        g = rand((m, n), seed + 1)
        got = kernels.momentum(v, g, beta=beta)
        want = ref.momentum_ref(v, g, beta)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    def test_beta_zero_is_gradient(self):
        v = rand((8, 8), 1)
        g = rand((8, 8), 2)
        np.testing.assert_allclose(
            kernels.momentum(v, g, beta=0.0), g, rtol=1e-6
        )

    def test_large_unaligned_shape(self):
        # Exceeds one BLOCK and isn't a multiple of it.
        v = rand((257, 300), 3)
        g = rand((257, 300), 4)
        got = kernels.momentum(v, g, beta=0.9)
        want = ref.momentum_ref(v, g, 0.9)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


class TestAdamW:
    @settings(max_examples=10, deadline=None)
    @given(n=dims, t=st.integers(1, 1000), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, n, t, seed):
        p = rand((n, 7), seed)
        g = rand((n, 7), seed + 1)
        m = rand((n, 7), seed + 2, scale=0.1)
        v = jnp.abs(rand((n, 7), seed + 3, scale=0.01))
        lr = jnp.float32(3e-3)
        po, mo, vo = kernels.adamw_update(
            p, g, m, v, lr, jnp.int32(t), beta1=0.9, beta2=0.95, eps=1e-8, wd=0.1
        )
        pw, mw, vw = ref.adamw_update_ref(
            p, g, m, v, lr, 0.9, 0.95, 1e-8, 0.1, t
        )
        np.testing.assert_allclose(po, pw, rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(mo, mw, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(vo, vw, rtol=1e-6, atol=1e-7)

    def test_descends_on_quadratic(self):
        # 30 AdamW steps on f(p)=||p||^2/2 must shrink the norm.
        p = rand((16, 16), 9)
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        start = float(jnp.linalg.norm(p))
        for t in range(1, 31):
            g = p  # grad of ||p||^2/2
            p, m, v = kernels.adamw_update(
                p, g, m, v, jnp.float32(0.05), jnp.int32(t), wd=0.0
            )
        assert float(jnp.linalg.norm(p)) < 0.5 * start


class TestVmemEstimates:
    def test_vmem_fits_all_paper_shapes(self):
        # Every matrix shape in the paper's Table 4 configs must fit a
        # double-buffered 16 MiB VMEM with the default panel.
        for d in [640, 768, 896, 1024, 1152, 1280, 1536, 1600]:
            for shape in [(d, d), (3 * d, d), (4 * d, d), (d, 4 * d)]:
                assert kernels.vmem_bytes(*shape) <= 16 * 2**20, shape
