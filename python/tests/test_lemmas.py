"""Numerical checks of the paper's key lemmas (Appendix A.3).

The convergence proofs rest on exact algebraic identities of the RN
operator; each is a checkable invariant:

  Lemma A.1: ||RN(V)||_F = sqrt(m);  <V, RN(V)> = sum_i ||V_i||_2 >= ||V||_F
  Lemma A.2: ||RN(V)||_{inf,2} = 1;  <V, RN(V)> = ||V||_{1,2}
  Section 5.1 duality: |<A,B>| <= ||A||_{1,2} ||B||_{inf,2}
  Lemma A.9/A.10 tool: ||A||_{1,2} <= sqrt(m) ||A||_F
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

dims = st.integers(min_value=1, max_value=64)


def rand(shape, seed, scale):
    rng = np.random.default_rng(seed)
    # bound rows away from zero so RN is well-conditioned
    x = rng.standard_normal(shape).astype(np.float32) * scale
    x += 0.05 * np.sign(x + 1e-9)
    return jnp.asarray(x)


def one2(a):
    return float(np.sum(np.linalg.norm(np.asarray(a), axis=1)))


def inf2(a):
    return float(np.max(np.linalg.norm(np.asarray(a), axis=1)))


@settings(max_examples=40, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1),
       scale=st.sampled_from([0.01, 1.0, 30.0]))
def test_lemma_a1(m, n, seed, scale):
    v = rand((m, n), seed, scale)
    d = ref.rownorm_ref(v)
    # (i) ||D||_F = sqrt(m)
    assert abs(float(jnp.linalg.norm(d)) - m**0.5) < 1e-2 * m**0.5
    # (ii) <V, D> = sum_i ||V_i|| >= ||V||_F
    pairing = float(jnp.sum(v * d))
    assert abs(pairing - one2(v)) < 1e-3 * max(one2(v), 1.0)
    assert pairing >= float(jnp.linalg.norm(v)) - 1e-3 * max(one2(v), 1.0)


@settings(max_examples=40, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1),
       scale=st.sampled_from([0.01, 1.0, 30.0]))
def test_lemma_a2(m, n, seed, scale):
    v = rand((m, n), seed, scale)
    d = ref.rownorm_ref(v)
    # (i) ||D||_{inf,2} = 1
    assert abs(inf2(d) - 1.0) < 1e-4
    # (ii) <V, D> = ||V||_{1,2}
    pairing = float(jnp.sum(v * d))
    assert abs(pairing - one2(v)) < 1e-3 * max(one2(v), 1.0)


@settings(max_examples=40, deadline=None)
@given(m=dims, n=dims, s1=st.integers(0, 2**31 - 1),
       s2=st.integers(0, 2**31 - 1))
def test_duality_pairing(m, n, s1, s2):
    a = rand((m, n), s1, 1.0)
    b = rand((m, n), s2, 2.0)
    lhs = abs(float(jnp.sum(a * b)))
    rhs = one2(a) * inf2(b)
    assert lhs <= rhs * (1 + 1e-5)


@settings(max_examples=40, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_one2_vs_frobenius(m, n, seed):
    a = rand((m, n), seed, 1.0)
    f = float(jnp.linalg.norm(a))
    assert one2(a) <= m**0.5 * f * (1 + 1e-5)
    assert f <= one2(a) * (1 + 1e-5)


def test_descent_lemma_a4_numeric():
    """Simulate Lemma A.4 on a quadratic f(W) = L/2 ||W||_F^2: the descent
    inequality f(W_t) - f(W_{t+1}) >= eta<grad, D> - L eta^2 m / 2 must
    hold exactly for the RN update."""
    rng = np.random.default_rng(0)
    lf, eta = 2.0, 0.05
    w = jnp.asarray(rng.standard_normal((8, 20)).astype(np.float32))
    for _ in range(20):
        grad = lf * w
        d = ref.rownorm_ref(grad)
        w_next = w - eta * d
        lhs = 0.5 * lf * (float(jnp.sum(w * w)) - float(jnp.sum(w_next * w_next)))
        rhs = eta * float(jnp.sum(grad * d)) - lf * eta**2 * 8 / 2
        assert lhs >= rhs - 1e-4
        w = w_next
