"""Model-level checks: shapes, causality, loss behaviour, param grouping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs
from compile.models import common as C
from compile.models import convnet, gpt2, llama, ssm


def key(i=0):
    return jax.random.PRNGKey(i)


@pytest.fixture(scope="module")
def gpt2_cfg():
    return gpt2.GPT2Config(vocab=64, d_model=32, n_layers=2, n_heads=2,
                           seq_len=16)


@pytest.fixture(scope="module")
def llama_cfg():
    return llama.LlamaConfig(vocab=64, d_model=32, n_layers=2, n_heads=2,
                             d_ff=64, seq_len=16)


@pytest.fixture(scope="module")
def ssm_cfg():
    return ssm.SSMConfig(vocab=64, d_model=32, d_state=24, n_layers=2,
                         seq_len=16)


class TestGPT2:
    def test_forward_shape(self, gpt2_cfg):
        params = gpt2.init(gpt2_cfg, key())
        toks = jnp.zeros((3, 16), jnp.int32)
        logits = gpt2.forward(gpt2_cfg, params, toks)
        assert logits.shape == (3, 16, 64)

    def test_causality(self, gpt2_cfg):
        # changing a future token must not change past logits
        params = gpt2.init(gpt2_cfg, key())
        a = jnp.arange(16, dtype=jnp.int32)[None] % 64
        b = a.at[0, 10].set(13)
        la = gpt2.forward(gpt2_cfg, params, a)
        lb = gpt2.forward(gpt2_cfg, params, b)
        np.testing.assert_allclose(la[0, :10], lb[0, :10], atol=1e-5)
        assert not np.allclose(la[0, 10:], lb[0, 10:], atol=1e-5)

    def test_initial_loss_near_uniform(self, gpt2_cfg):
        params = gpt2.init(gpt2_cfg, key())
        toks = jax.random.randint(key(1), (4, 17), 0, 64)
        loss = gpt2.loss(gpt2_cfg, params, toks)
        assert abs(float(loss) - np.log(64)) < 0.5

    def test_param_groups_cover_embeddings(self, gpt2_cfg):
        params = gpt2.init(gpt2_cfg, key())
        groups = gpt2.param_groups(gpt2_cfg, params)
        assert groups["tok_emb"] == "matrix"  # GPT-2 protocol
        assert groups["head"] == "matrix"
        assert groups["h00.ln1"] == "adamw"

    def test_grads_flow_everywhere(self, gpt2_cfg):
        params = gpt2.init(gpt2_cfg, key())
        toks = jax.random.randint(key(2), (2, 17), 0, 64)
        grads = jax.grad(lambda p: gpt2.loss(gpt2_cfg, p, toks))(params)
        for name, g in grads.items():
            assert float(jnp.max(jnp.abs(g))) > 0, f"dead grad: {name}"


class TestLlama:
    def test_forward_shape(self, llama_cfg):
        params = llama.init(llama_cfg, key())
        toks = jnp.zeros((3, 16), jnp.int32)
        assert llama.forward(llama_cfg, params, toks).shape == (3, 16, 64)

    def test_param_groups_exclude_embeddings(self, llama_cfg):
        params = llama.init(llama_cfg, key())
        groups = llama.param_groups(llama_cfg, params)
        assert groups["tok_emb"] == "adamw"  # LLaMA protocol
        assert groups["head"] == "adamw"
        assert groups["h00.attn_qkv"] == "matrix"

    def test_rope_is_position_sensitive(self, llama_cfg):
        params = llama.init(llama_cfg, key())
        tok = jax.random.randint(key(3), (1, 16), 0, 64)
        rolled = jnp.roll(tok, 3, axis=1)
        la = llama.forward(llama_cfg, params, tok)
        lb = llama.forward(llama_cfg, params, rolled)
        # same tokens at shifted positions produce different logits
        assert not np.allclose(la[0, 5], lb[0, 8], atol=1e-4)

    def test_causality(self, llama_cfg):
        params = llama.init(llama_cfg, key())
        a = jnp.arange(16, dtype=jnp.int32)[None] % 64
        b = a.at[0, 12].set(1)
        la = llama.forward(llama_cfg, params, a)
        lb = llama.forward(llama_cfg, params, b)
        np.testing.assert_allclose(la[0, :12], lb[0, :12], atol=1e-5)


class TestSSM:
    def test_forward_shape(self, ssm_cfg):
        params = ssm.init(ssm_cfg, key())
        toks = jnp.zeros((2, 16), jnp.int32)
        assert ssm.forward(ssm_cfg, params, toks).shape == (2, 16, 64)

    def test_scan_is_causal(self, ssm_cfg):
        params = ssm.init(ssm_cfg, key())
        a = jnp.arange(16, dtype=jnp.int32)[None] % 64
        b = a.at[0, 15].set(2)
        la = ssm.forward(ssm_cfg, params, a)
        lb = ssm.forward(ssm_cfg, params, b)
        np.testing.assert_allclose(la[0, :15], lb[0, :15], atol=1e-5)

    def test_selective_scan_matches_loop(self):
        u = jax.random.normal(key(4), (2, 8, 4))
        a = jax.nn.sigmoid(jax.random.normal(key(5), (2, 8, 4)))
        got = ssm._selective_scan(u, a)
        s = np.zeros((2, 4), np.float32)
        for t in range(8):
            s = np.asarray(a[:, t]) * s + (1 - np.asarray(a[:, t])) * np.asarray(u[:, t])
            np.testing.assert_allclose(got[:, t], s, rtol=1e-5, atol=1e-6)


class TestConvNet:
    def test_forward_shape(self):
        cfg = convnet.ConvNetConfig(n_classes=10, width=8, n_blocks=2)
        params = convnet.init(cfg, key())
        imgs = jax.random.normal(key(6), (4, 3, 32, 32))
        assert convnet.forward(cfg, params, imgs).shape == (4, 10)

    def test_conv_weights_are_matrices(self):
        cfg = convnet.ConvNetConfig(width=8, n_blocks=2)
        params = convnet.init(cfg, key())
        groups = convnet.param_groups(cfg, params)
        assert params["stem"].ndim == 2
        assert groups["stem"] == "matrix"
        assert groups["b00.norm1"] == "adamw"

    def test_loss_finite_and_near_uniform(self):
        cfg = convnet.ConvNetConfig(width=8, n_blocks=2)
        params = convnet.init(cfg, key())
        imgs = jax.random.normal(key(7), (8, 3, 32, 32))
        labels = jnp.zeros((8,), jnp.int32)
        loss = convnet.loss(cfg, params, imgs, labels)
        assert np.isfinite(float(loss))
        assert abs(float(loss) - np.log(10)) < 2.5


class TestRegistry:
    def test_all_tags_resolve(self):
        for tag, spec in configs.REGISTRY.items():
            assert spec.module() is not None
            assert spec.batch_specs(), tag

    def test_e2e_is_about_100m_params(self):
        spec = configs.REGISTRY["gpt2_e2e"]
        shapes = jax.eval_shape(
            lambda k: spec.module().init(spec.cfg, k), key()
        )
        total = sum(int(np.prod(s.shape)) for s in shapes.values())
        assert 8e7 < total < 1.5e8, total

    def test_precond_shape_set(self):
        shapes, per_model = configs.precond_shapes()
        assert len(per_model) == 8  # Table 4 rows
        assert (3 * 640, 640) in shapes
        assert (1600, 6400) in shapes
