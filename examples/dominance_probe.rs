//! Dominance probe (the Figures 4/5 protocol at demo scale): train with
//! Muon while measuring the diagonal dominance of the momentum Gram
//! matrix V Vᵀ on device, then print the per-parameter and global ratio
//! trajectories. Values above the y = 1 threshold reproduce the paper's
//! structural claim motivating RMNP.
//!
//!     cargo run --release --example dominance_probe -- [model] [steps]

use rmnp::config::DataSpec;
use rmnp::exp::{dominance_exp, ExpOpts};
use rmnp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "gpt2_tiny".into());
    let steps: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let opts = ExpOpts { steps, out: "runs/dominance_probe".into(), ..Default::default() };
    let engine = Engine::new(&opts.artifacts)?;
    let data = if model.starts_with("llama") { DataSpec::Zipf } else { DataSpec::Markov };
    let run = dominance_exp::run_one(&opts, &engine, &model, "muon", data)?;
    println!("{}", dominance_exp::format_per_param(&run));
    println!("{}", dominance_exp::format_global(std::slice::from_ref(&run)));
    println!(
        "dominance above threshold (paper claim reproduced): {}",
        dominance_exp::reproduces_dominance(&run)
    );
    Ok(())
}
