//! End-to-end driver (the DESIGN.md §4 "required e2e run"): train the
//! ~100M-parameter GPT-2 (`gpt2_e2e`: d=768, 14 layers, seq 256) with RMNP
//! for a few hundred steps on the synthetic Markov corpus, logging the
//! loss curve to `runs/e2e_gpt2/metrics.csv`.
//!
//!     cargo run --release --example train_gpt2 -- [steps] [optimizer]
//!
//! Defaults: 300 steps, rmnp. On this CPU testbed a step takes a few
//! seconds — the recorded run lives in EXPERIMENTS.md §E2E.

use rmnp::config::{DataSpec, RunConfig, Schedule};
use rmnp::coordinator::train;
use rmnp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let optimizer = std::env::args().nth(2).unwrap_or_else(|| "rmnp".into());
    let cfg = RunConfig {
        model: "gpt2_e2e".into(),
        optimizer: optimizer.clone(),
        lr: 2e-3,
        schedule: Schedule::CosineWarmup { warmup_frac: 0.1, min_ratio: 0.1 },
        steps,
        seed: 42,
        data: DataSpec::Markov,
        eval_every: (steps / 6).max(1),
        eval_batches: 2,
        dominance_every: 0,
        checkpoint_every: 0,
        out_dir: format!("runs/e2e_gpt2_{optimizer}").into(),
        artifacts: "artifacts".into(),
    };
    let engine = Engine::new(&cfg.artifacts)?;
    let params = engine.manifest.model(&cfg.model)?.param_count;
    println!(
        "e2e: {} ({:.1}M params) x {} steps with {}",
        cfg.model,
        params as f64 / 1e6,
        cfg.steps,
        cfg.optimizer
    );
    let t0 = std::time::Instant::now();
    let result = train::run(&engine, &cfg)?;
    println!(
        "e2e done in {:.1}s ({:.2}s/step): train {:.4} -> eval {:.4} (ppl {:.2})",
        t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64() / cfg.steps as f64,
        result.final_train_loss,
        result.final_eval_loss,
        result.final_ppl
    );
    println!("loss curve: {}/metrics.csv", cfg.out_dir.display());
    Ok(())
}
