//! Optimizer shootout: AdamW vs Muon vs RMNP on the same model/corpus
//! (the Figure 6 protocol at demo scale), printing a Table-17-style block
//! and per-optimizer wall-clock — RMNP should match Muon's loss at a
//! fraction of its step time.
//!
//!     cargo run --release --example optimizer_shootout -- [model] [steps]

use rmnp::analysis::report::{mark_column_winners, markdown_table};
use rmnp::config::{DataSpec, RunConfig, Schedule};
use rmnp::coordinator::train;
use rmnp::exp::default_lr;
use rmnp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "gpt2_small".into());
    let steps: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let engine = Engine::new(std::path::Path::new("artifacts"))?;
    let data = if model.starts_with("llama") { DataSpec::Zipf } else { DataSpec::Markov };

    let mut ppl = Vec::new();
    let mut rows_meta = Vec::new();
    for optimizer in ["adamw", "muon", "rmnp"] {
        let cfg = RunConfig {
            model: model.clone(),
            optimizer: optimizer.into(),
            lr: default_lr(optimizer),
            schedule: Schedule::CosineWarmup { warmup_frac: 0.1, min_ratio: 0.1 },
            steps,
            seed: 99,
            data,
            eval_every: 0,
            eval_batches: 4,
            dominance_every: 0,
            checkpoint_every: 0,
            out_dir: format!("runs/shootout_{model}/{optimizer}").into(),
            artifacts: "artifacts".into(),
        };
        let r = train::run(&engine, &cfg)?;
        ppl.push(vec![r.final_ppl]);
        rows_meta.push((optimizer.to_string(), r.seconds, r.final_ppl));
    }
    let marked = mark_column_winners(&ppl);
    let table: Vec<Vec<String>> = rows_meta
        .iter()
        .zip(marked)
        .map(|((opt, secs, _), cells)| {
            vec![opt.to_uppercase(), cells[0].clone(), format!("{secs:.1}s")]
        })
        .collect();
    println!(
        "{}",
        markdown_table(&["Optimizer", "Val PPL", "Wall clock"], &table)
    );
    println!("(paper Figure 6: RMNP ≤ Muon < AdamW on validation perplexity)");
    Ok(())
}
