//! Quickstart: train a tiny GPT-2 with RMNP for 60 steps on the synthetic
//! Markov corpus and print the loss curve plus final held-out perplexity.
//!
//!     make artifacts && cargo run --release --example quickstart

use rmnp::config::{DataSpec, RunConfig, Schedule};
use rmnp::coordinator::train;
use rmnp::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        model: "gpt2_tiny".into(),
        optimizer: "rmnp".into(),
        lr: 4e-3,
        schedule: Schedule::CosineWarmup { warmup_frac: 0.1, min_ratio: 0.1 },
        steps: 60,
        seed: 7,
        data: DataSpec::Markov,
        eval_every: 20,
        eval_batches: 4,
        dominance_every: 0,
        checkpoint_every: 0,
        out_dir: "runs/quickstart".into(),
        artifacts: "artifacts".into(),
    };
    let engine = Engine::new(&cfg.artifacts)?;
    println!(
        "training {} with {} for {} steps on `{}`...",
        cfg.model, cfg.optimizer, cfg.steps, cfg.data.name()
    );
    let result = train::run(&engine, &cfg)?;
    println!(
        "final: train loss {:.4}  |  eval loss {:.4}  |  ppl {:.2}  |  {:.1}s",
        result.final_train_loss,
        result.final_eval_loss,
        result.final_ppl,
        result.seconds
    );
    println!("metrics: runs/quickstart/metrics.csv");
    // random guessing is ln(512) = 6.24 nats; anything meaningfully lower
    // means the device-resident pipeline is learning.
    assert!(result.final_train_loss < 5.5, "no learning happened");
    Ok(())
}
