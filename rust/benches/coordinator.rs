//! `cargo bench --bench coordinator` — host-side substrate costs: the
//! pure-rust optimizer references (cross-check of the Table 2 arithmetic
//! gap without PJRT), dominance metric computation, schedules, and
//! checkpoint I/O. The native NS5/rownorm ratio should show the same
//! O(min(m,n)) growth as the artifact path.

use rmnp::bench::{bench, BenchOpts};
use rmnp::coordinator::checkpoint::{self, NamedBuffer};
use rmnp::coordinator::lr_at;
use rmnp::config::Schedule;
use rmnp::optim::lemmas::dominance_ratios;
use rmnp::optim::newton_schulz5;
use rmnp::tensor::Matrix;
use rmnp::util::Rng;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts { sample_target: 0.1, samples: 6, budget: 8.0, warmup: 1 };
    let mut rng = Rng::new(1);

    println!("native preconditioner ops (rust reference, Table 2 cross-check):");
    let mut ratios = Vec::new();
    for d in [64usize, 128, 256] {
        let v = Matrix::randn(4 * d, d, 0.02, &mut rng);
        let ns = bench(&format!("ns5 {}x{}", 4 * d, d), opts, || {
            let _ = newton_schulz5(&v, 5);
        });
        let rn = bench(&format!("rownorm {}x{}", 4 * d, d), opts, || {
            let _ = v.row_normalize(1e-7);
        });
        let ratio = ns.median() / rn.median();
        println!("  {}", ns.report_line());
        println!("  {}", rn.report_line());
        println!("  -> native speedup {ratio:.1}x");
        ratios.push(ratio);
    }
    assert!(
        ratios.windows(2).all(|w| w[1] > w[0]),
        "native speedup must grow with d: {ratios:?}"
    );

    println!("\ndominance metric (Gram + ratios):");
    for (m, n) in [(128usize, 512usize), (256, 1024)] {
        let v = Matrix::randn(m, n, 0.02, &mut rng);
        let r = bench(&format!("dominance {m}x{n}"), opts, || {
            let _ = dominance_ratios(&v);
        });
        println!("  {}", r.report_line());
    }

    println!("\nLR schedule (1e6 evaluations):");
    let sched = Schedule::CosineWarmup { warmup_frac: 0.1, min_ratio: 0.1 };
    let r = bench("cosine_warmup x1e6", opts, || {
        let mut acc = 0.0;
        for t in 0..1_000_000 {
            acc += lr_at(sched, 1e-3, t, 1_000_000);
        }
        std::hint::black_box(acc);
    });
    println!("  {}", r.report_line());

    println!("\ncheckpoint save+load (8 MiB state):");
    let buffers: Vec<NamedBuffer> = (0..16)
        .map(|i| NamedBuffer {
            name: format!("p{i}"),
            data: vec![0.5f32; 128 * 1024],
        })
        .collect();
    let dir = std::env::temp_dir().join("rmnp-bench-ckpt");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("step-1.ckpt");
    let r = bench("ckpt roundtrip 8MiB", opts, || {
        checkpoint::save(&path, &buffers).unwrap();
        let back = checkpoint::load(&path).unwrap();
        assert_eq!(back.len(), 16);
    });
    println!("  {}", r.report_line());
    Ok(())
}
