//! `cargo bench --bench data_pipeline` — throughput of the synthetic
//! corpora and the prefetching loader. The data path must comfortably
//! out-produce the training consumer (tokens/s here vs ~1e5 tokens/s
//! consumed by the largest CPU model), or the L3 pipeline would become
//! the bottleneck the paper's coordinator exists to avoid. Writes
//! `BENCH_data_pipeline.json` so `scripts/bench_check.sh` can gate the
//! envelope and snapshot it to `bench_history/`.

use std::path::Path;

use rmnp::bench::report::{self, envelope, num, obj, text};
use rmnp::bench::{bench, BenchOpts};
use rmnp::config::DataSpec;
use rmnp::data::corpus::token_source;
use rmnp::data::images::ImageSource;
use rmnp::data::loader::token_batches;
use rmnp::data::tokenizer::BpeTokenizer;
use rmnp::util::Json;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts { sample_target: 0.1, samples: 8, budget: 6.0, warmup: 1 };
    const N: usize = 16 * 129;

    let mut corpora: Vec<Json> = Vec::new();
    println!("corpus generation ({N} tokens/call):");
    for spec in [DataSpec::Markov, DataSpec::Zipf, DataSpec::Ngram] {
        let mut src = token_source(spec, 1, 0);
        let mut buf = vec![0i32; N];
        let r = bench(spec.name(), opts, || src.fill(&mut buf));
        let tps = N as f64 / r.median();
        println!("  {}  ({:.1}M tokens/s)", r.report_line(), tps / 1e6);
        assert!(tps > 1e5, "{} too slow: {tps} tokens/s", spec.name());
        corpora.push(obj(vec![
            ("corpus", text(spec.name())),
            ("median_s", num(r.median())),
            ("tokens_per_s", num(tps)),
        ]));
    }

    println!("\nprefetching loader (depth 4):");
    let loader = token_batches(token_source(DataSpec::Markov, 1, 0), 16, 129, 4);
    let r = bench("loader.next", opts, || {
        let b = loader.next();
        assert_eq!(b.tokens.len(), N);
    });
    println!("  {}", r.report_line());
    let loader_tps = N as f64 / r.median();
    let loader_json = obj(vec![
        ("median_s", num(r.median())),
        ("tokens_per_s", num(loader_tps)),
    ]);

    println!("\nimage synthesis (32x32x3 x 32):");
    let mut img = ImageSource::new(10, 32, 3, 0);
    let mut images = vec![0f32; 32 * 3 * 32 * 32];
    let mut labels = vec![0i32; 32];
    let r = bench("images", opts, || img.fill(32, &mut images, &mut labels));
    println!("  {}", r.report_line());
    let images_json = obj(vec![
        ("median_s", num(r.median())),
        ("images_per_s", num(32.0 / r.median())),
    ]);

    println!("\nBPE tokenizer:");
    let txt = "the quick brown fox jumps over the lazy dog ".repeat(64);
    let tok = BpeTokenizer::train(&txt, 320);
    let r = bench("bpe.encode", opts, || {
        let _ = tok.encode(&txt);
    });
    let bps = txt.len() as f64 / r.median();
    println!("  {}  ({:.2} MB/s)", r.report_line(), bps / 1e6);
    let bpe_json = obj(vec![
        ("median_s", num(r.median())),
        ("bytes_per_s", num(bps)),
    ]);

    let doc = envelope(
        "data_pipeline",
        vec![
            ("corpora", Json::Arr(corpora)),
            ("loader", loader_json),
            ("images", images_json),
            ("bpe", bpe_json),
        ],
    );
    report::write(Path::new("BENCH_data_pipeline.json"), &doc)?;
    println!("\nwrote BENCH_data_pipeline.json");
    Ok(())
}
