//! `cargo bench --bench optim_step` — native optimizer-step latency: the
//! fused RMNP sweep and workspace-backed Muon NS5 step against seed-style
//! unfused baselines, plus AdamW throughput. Writes
//! `BENCH_train_step.json` so per-step cost is tracked across PRs (the
//! `pjrt` train_step bench overwrites it with artifact-path numbers when
//! it runs).

use std::path::Path;

use rmnp::bench::report::{self, bench_json, envelope, num, obj, text};
use rmnp::bench::{bench_n, fmt_secs};
use rmnp::optim::{
    newton_schulz5_naive, rms_scale, AdamWState, MuonState, RmnpState, MATRIX_BETA,
};
use rmnp::tensor::Matrix;
use rmnp::util::{Json, Rng};

struct Case {
    op: String,
    rows: usize,
    cols: usize,
    fused: f64,
    seed: f64,
}

fn main() -> anyhow::Result<()> {
    let repeats: usize = std::env::var("BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let mut rng = Rng::new(42);
    let mut cases: Vec<Case> = Vec::new();

    println!("fused RMNP step vs seed-style unfused step:");
    for (m, n) in [(768usize, 768usize), (3072, 768), (768, 3072)] {
        let g = Matrix::randn(m, n, 0.02, &mut rng);
        let mut w = Matrix::randn(m, n, 0.02, &mut rng);
        let mut st = RmnpState::new(m, n);
        let fused = bench_n(&format!("rmnp_fused_{m}x{n}"), 20, repeats, || {
            st.step(&mut w, &g, 1e-3);
        });
        let mut w2 = Matrix::randn(m, n, 0.02, &mut rng);
        let mut st2 = RmnpState::new(m, n);
        let seed = bench_n(&format!("rmnp_seed_{m}x{n}"), 20, repeats, || {
            st2.step_unfused(&mut w2, &g, 1e-3);
        });
        println!("  {}", fused.report_line());
        println!("  {}", seed.report_line());
        println!("  -> {:.2}x", seed.median() / fused.median());
        cases.push(Case {
            op: "rmnp_step".into(),
            rows: m,
            cols: n,
            fused: fused.median(),
            seed: seed.median(),
        });
    }

    println!("\nworkspace Muon step vs seed-style NS5 step:");
    for (m, n) in [(256usize, 1024usize), (512, 512)] {
        let g = Matrix::randn(m, n, 0.02, &mut rng);
        let mut w = Matrix::randn(m, n, 0.02, &mut rng);
        let mut st = MuonState::new(m, n);
        let fused = bench_n(&format!("muon_ws_{m}x{n}"), 1, repeats, || {
            st.step(&mut w, &g, 1e-3);
        });
        // seed-style: allocating axpby momentum + scalar-kernel NS5
        let mut w2 = Matrix::randn(m, n, 0.02, &mut rng);
        let mut mom = Matrix::zeros(m, n);
        let scale = 1e-3 * rms_scale(m, n);
        let seed = bench_n(&format!("muon_seed_{m}x{n}"), 1, repeats, || {
            mom = mom.axpby(MATRIX_BETA, &g, 1.0 - MATRIX_BETA);
            let d = newton_schulz5_naive(&mom, 5);
            for (wv, dv) in w2.data_mut().iter_mut().zip(d.data()) {
                *wv -= scale * (dv + 0.1 * *wv);
            }
        });
        println!("  {}", fused.report_line());
        println!("  {}", seed.report_line());
        println!("  -> {:.2}x", seed.median() / fused.median());
        cases.push(Case {
            op: "muon_step".into(),
            rows: m,
            cols: n,
            fused: fused.median(),
            seed: seed.median(),
        });
    }

    println!("\nAdamW flat-buffer step:");
    let len = 768 * 768;
    let mut st = AdamWState::new(len);
    let mut w = vec![0.02f32; len];
    let grad = vec![0.01f32; len];
    let adamw = bench_n("adamw_589k", 20, repeats, || {
        st.step(&mut w, &grad, 1e-3);
    });
    println!(
        "  {}  ({:.1}M params/s)",
        adamw.report_line(),
        len as f64 / adamw.median() / 1e6
    );

    // fused/workspace paths must not be slower than the seed baselines
    for c in &cases {
        let ratio = c.seed / c.fused.max(1e-12);
        assert!(
            ratio > 0.9,
            "{} {}x{} regressed vs seed path: {ratio:.2}x",
            c.op, c.rows, c.cols
        );
    }

    let entries: Vec<Json> = cases
        .iter()
        .map(|c| {
            obj(vec![
                ("op", text(&c.op)),
                ("rows", report::int(c.rows)),
                ("cols", report::int(c.cols)),
                ("fused_median_s", num(c.fused)),
                ("seed_median_s", num(c.seed)),
                ("improvement", num(c.seed / c.fused.max(1e-12))),
            ])
        })
        .collect();
    let doc = envelope(
        "train_step_native",
        vec![
            ("steps", Json::Arr(entries)),
            ("adamw", bench_json(&adamw)),
        ],
    );
    report::write(Path::new("BENCH_train_step.json"), &doc)?;
    println!("\nwrote BENCH_train_step.json ({})", fmt_secs(adamw.median()));
    Ok(())
}
