//! `cargo bench --bench optim_step` — native optimizer-step latency: the
//! fused RMNP sweep and workspace-backed Muon NS5 step against seed-style
//! unfused baselines, plus AdamW throughput. Writes
//! `BENCH_train_step.json` so per-step cost is tracked across PRs (the
//! `pjrt` train_step bench overwrites it with artifact-path numbers when
//! it runs).

use std::path::Path;

use rmnp::bench::report::{self, bench_json, envelope, num, obj, text};
use rmnp::bench::{bench_n, fmt_secs};
use rmnp::optim::{
    newton_schulz5_naive, rms_scale, AdamWState, MuonState, RmnpState, MATRIX_BETA,
};
use rmnp::tensor::{Bf16Matrix, Matrix, Precision};
use rmnp::util::{Json, Rng};

struct Case {
    op: String,
    rows: usize,
    cols: usize,
    fused: f64,
    seed: f64,
}

/// One f32-vs-bf16 storage comparison of the fused RMNP step.
///
/// `*_state_bytes_per_elem` is the *modeled* per-element traffic to the
/// persistent state (parameter + momentum, read and written once each):
/// 4 f32 accesses in f32 mode, the same 4 as bf16 in bf16 mode. The
/// gradient read (4 B/elem) is identical in both modes and excluded —
/// the ratio isolates what the storage format changes.
struct PrecCase {
    rows: usize,
    cols: usize,
    f32_median: f64,
    bf16_median: f64,
    f32_state_bytes_per_elem: usize,
    bf16_state_bytes_per_elem: usize,
}

fn main() -> anyhow::Result<()> {
    let repeats: usize = std::env::var("BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let mut rng = Rng::new(42);
    let mut cases: Vec<Case> = Vec::new();

    println!("fused RMNP step vs seed-style unfused step:");
    for (m, n) in [(768usize, 768usize), (3072, 768), (768, 3072)] {
        let g = Matrix::randn(m, n, 0.02, &mut rng);
        let mut w = Matrix::randn(m, n, 0.02, &mut rng);
        let mut st = RmnpState::new(m, n);
        let fused = bench_n(&format!("rmnp_fused_{m}x{n}"), 20, repeats, || {
            st.step(&mut w, &g, 1e-3);
        });
        let mut w2 = Matrix::randn(m, n, 0.02, &mut rng);
        let mut st2 = RmnpState::new(m, n);
        let seed = bench_n(&format!("rmnp_seed_{m}x{n}"), 20, repeats, || {
            st2.step_unfused(&mut w2, &g, 1e-3);
        });
        println!("  {}", fused.report_line());
        println!("  {}", seed.report_line());
        println!("  -> {:.2}x", seed.median() / fused.median());
        cases.push(Case {
            op: "rmnp_step".into(),
            rows: m,
            cols: n,
            fused: fused.median(),
            seed: seed.median(),
        });
    }

    println!("\nworkspace Muon step vs seed-style NS5 step:");
    for (m, n) in [(256usize, 1024usize), (512, 512)] {
        let g = Matrix::randn(m, n, 0.02, &mut rng);
        let mut w = Matrix::randn(m, n, 0.02, &mut rng);
        let mut st = MuonState::new(m, n);
        let fused = bench_n(&format!("muon_ws_{m}x{n}"), 1, repeats, || {
            st.step(&mut w, &g, 1e-3);
        });
        // seed-style: allocating axpby momentum + scalar-kernel NS5
        let mut w2 = Matrix::randn(m, n, 0.02, &mut rng);
        let mut mom = Matrix::zeros(m, n);
        let scale = 1e-3 * rms_scale(m, n);
        let seed = bench_n(&format!("muon_seed_{m}x{n}"), 1, repeats, || {
            mom = mom.axpby(MATRIX_BETA, &g, 1.0 - MATRIX_BETA);
            let d = newton_schulz5_naive(&mom, 5);
            for (wv, dv) in w2.data_mut().iter_mut().zip(d.data()) {
                *wv -= scale * (dv + 0.1 * *wv);
            }
        });
        println!("  {}", fused.report_line());
        println!("  {}", seed.report_line());
        println!("  -> {:.2}x", seed.median() / fused.median());
        cases.push(Case {
            op: "muon_step".into(),
            rows: m,
            cols: n,
            fused: fused.median(),
            seed: seed.median(),
        });
    }

    // f32 vs bf16 storage on the memory-bound rownorm/axpby path. The
    // big shape is the gate shape (d >= 1024, where the working set
    // outruns cache and bandwidth dominates); BENCH_MAX_D caps it for
    // quick local runs — bench_check.sh skips the speed gate when the
    // big shape did not run.
    let max_d: usize = std::env::var("BENCH_MAX_D")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(usize::MAX);
    let mut prec_cases: Vec<PrecCase> = Vec::new();
    println!("\nfused RMNP step, f32 vs bf16 storage:");
    for (m, n) in [(256usize, 256usize), (1024, 1024)] {
        if m.max(n) > max_d {
            println!("  skipping {m}x{n} (BENCH_MAX_D={max_d})");
            continue;
        }
        let g = Matrix::randn(m, n, 0.02, &mut rng);
        let w0 = Matrix::randn(m, n, 0.02, &mut rng);
        let mut w = w0.clone();
        let mut st = RmnpState::new(m, n);
        let f32_r = bench_n(&format!("rmnp_f32_{m}x{n}"), 20, repeats, || {
            st.step(&mut w, &g, 1e-3);
        });
        let mut wb = Bf16Matrix::from_matrix(&w0);
        let mut stb = RmnpState::new_with(m, n, Precision::Bf16);
        let bf16_r = bench_n(&format!("rmnp_bf16_{m}x{n}"), 20, repeats, || {
            stb.step_bf16(&mut wb, &g, 1e-3);
        });
        println!("  {}", f32_r.report_line());
        println!("  {}", bf16_r.report_line());
        println!("  -> {:.2}x", f32_r.median() / bf16_r.median());
        prec_cases.push(PrecCase {
            rows: m,
            cols: n,
            f32_median: f32_r.median(),
            bf16_median: bf16_r.median(),
            f32_state_bytes_per_elem: 4 * 4,
            bf16_state_bytes_per_elem: 4 * 2,
        });
    }

    println!("\nAdamW flat-buffer step:");
    let len = 768 * 768;
    let mut st = AdamWState::new(len);
    let mut w = vec![0.02f32; len];
    let grad = vec![0.01f32; len];
    let adamw = bench_n("adamw_589k", 20, repeats, || {
        st.step(&mut w, &grad, 1e-3);
    });
    println!(
        "  {}  ({:.1}M params/s)",
        adamw.report_line(),
        len as f64 / adamw.median() / 1e6
    );

    // fused/workspace paths must not be slower than the seed baselines
    for c in &cases {
        let ratio = c.seed / c.fused.max(1e-12);
        assert!(
            ratio > 0.9,
            "{} {}x{} regressed vs seed path: {ratio:.2}x",
            c.op, c.rows, c.cols
        );
    }

    let entries: Vec<Json> = cases
        .iter()
        .map(|c| {
            obj(vec![
                ("op", text(&c.op)),
                ("rows", report::int(c.rows)),
                ("cols", report::int(c.cols)),
                ("fused_median_s", num(c.fused)),
                ("seed_median_s", num(c.seed)),
                ("improvement", num(c.seed / c.fused.max(1e-12))),
            ])
        })
        .collect();
    let prec_entries: Vec<Json> = prec_cases
        .iter()
        .map(|c| {
            obj(vec![
                ("rows", report::int(c.rows)),
                ("cols", report::int(c.cols)),
                ("f32_median_s", num(c.f32_median)),
                ("bf16_median_s", num(c.bf16_median)),
                ("speedup", num(c.f32_median / c.bf16_median.max(1e-12))),
                ("f32_state_bytes_per_elem", report::int(c.f32_state_bytes_per_elem)),
                ("bf16_state_bytes_per_elem", report::int(c.bf16_state_bytes_per_elem)),
                (
                    "bytes_ratio",
                    num(c.bf16_state_bytes_per_elem as f64
                        / c.f32_state_bytes_per_elem as f64),
                ),
            ])
        })
        .collect();
    let doc = envelope(
        "train_step_native",
        vec![
            ("steps", Json::Arr(entries)),
            ("precision", Json::Arr(prec_entries)),
            ("adamw", bench_json(&adamw)),
        ],
    );
    report::write(Path::new("BENCH_train_step.json"), &doc)?;
    println!("\nwrote BENCH_train_step.json ({})", fmt_secs(adamw.median()));
    Ok(())
}
