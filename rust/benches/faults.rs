//! `cargo bench --bench faults` — the price of fault tolerance.
//!
//! Measures (a) the anomaly guard's per-step overhead — a full native
//! train step through `step_gated` + `StepGuard::observe` vs the plain
//! `step` path; it must be noise-level, since the guard only inspects
//! two scalars — and (b) checkpoint durability costs: v3 save (CRC
//! stamping), validated load, and the walkback scan over a corrupted
//! newest checkpoint. Writes `BENCH_faults.json`; `scripts/bench_check.sh`
//! gates on `guard_overhead_frac` and the recovery `ok` flags.
//!
//! Env knobs: `BENCH_REPEATS` (samples per measurement, default 3),
//! `RMNP_THREADS`, `RMNP_SIMD`.

use std::path::Path;

use rmnp::bench::report::{self, envelope, int, num};
use rmnp::bench::{bench_n, fmt_secs};
use rmnp::config::DataSpec;
use rmnp::coordinator::{checkpoint, GuardConfig, StepGuard, Verdict};
use rmnp::data::corpus::token_source;
use rmnp::runtime::{Batch, BatchShape, NativeBackend, StepMetrics, TrainBackend};

fn main() -> anyhow::Result<()> {
    // measure serialization + CRC cost, not disk-sync latency — fsync
    // timing is a property of the CI filesystem, not of this code
    std::env::set_var("RMNP_NO_FSYNC", "1");
    let repeats: usize = std::env::var("BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    println!(
        "faults bench: repeats={repeats} threads={} simd={}",
        rmnp::tensor::kernels::num_threads(),
        rmnp::tensor::simd::label()
    );

    let mut backend = NativeBackend::new("gpt2_tiny", "rmnp", 42, 0)?;
    let (rows, cols) = match backend.batch_shape() {
        BatchShape::Tokens { rows, cols } => (rows, cols),
        BatchShape::Images { .. } => anyhow::bail!("gpt2_tiny should consume tokens"),
    };
    let mut src = token_source(DataSpec::Markov, 7, 0);
    let mut tokens = vec![0i32; rows * cols];
    src.fill(&mut tokens);
    backend.step(&Batch::Tokens(&tokens), 1e-3)?; // warm workspace + pool

    println!("guard overhead (full gpt2_tiny/rmnp train step):");
    let plain = bench_n("step_plain", 5, repeats, || {
        backend.step(&Batch::Tokens(&tokens), 1e-3).expect("plain step");
    });
    println!("  {}", plain.report_line());
    let mut guard = StepGuard::new(GuardConfig::default())?;
    let mut step_no = 0usize;
    let gated = bench_n("step_gated+observe", 5, repeats, || {
        let decide = &mut |m: &StepMetrics| {
            step_no += 1;
            guard.observe(step_no, m) == Verdict::Apply
        };
        backend
            .step_gated(&Batch::Tokens(&tokens), 1e-3, decide)
            .expect("gated step");
    });
    println!("  {}", gated.report_line());
    let overhead_frac = (gated.median() - plain.median()) / plain.median().max(1e-12);
    println!("  -> guard overhead {:+.2}% per step", overhead_frac * 100.0);
    assert_eq!(guard.skipped(), 0, "healthy bench steps must not be skipped");

    println!("checkpoint durability (gpt2_tiny full state):");
    let state = backend.export_state()?;
    let dir = std::env::temp_dir().join(format!("rmnp-bench-faults-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let ckpt = dir.join("bench.ckpt");
    let save = bench_n("ckpt_save_v3", 3, repeats, || {
        checkpoint::save_state(&ckpt, &state).expect("save");
    });
    println!("  {}", save.report_line());
    let ckpt_bytes = std::fs::metadata(&ckpt)?.len() as usize;
    let load = bench_n("ckpt_load_validated", 3, repeats, || {
        checkpoint::load_state(&ckpt).expect("load");
    });
    println!("  {}", load.report_line());
    let back = checkpoint::load_state(&ckpt)?;
    let roundtrip_ok = back.step == state.step
        && back.params.len() == state.params.len()
        && back
            .params
            .iter()
            .zip(&state.params)
            .all(|(a, b)| a.name == b.name && a.data == b.data);

    // walkback: newest checkpoint corrupted, latest_valid must land on
    // the older one — this is the recovery path a resume pays once
    let walkdir = dir.join("walkback");
    std::fs::create_dir_all(&walkdir)?;
    let mut old = backend.export_state()?;
    old.step = 3;
    checkpoint::save_state(&walkdir.join("step-3.ckpt"), &old)?;
    old.step = 6;
    checkpoint::save_state(&walkdir.join("step-6.ckpt"), &old)?;
    let newest = walkdir.join("step-6.ckpt");
    let mut bytes = std::fs::read(&newest)?;
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&newest, &bytes)?;
    let mut walkback_ok = true;
    let walk = bench_n("walkback_recovery", 1, repeats, || {
        let found = checkpoint::latest_valid(&walkdir).expect("walkback scan");
        walkback_ok &= matches!(found, Some((3, _, _)));
    });
    println!("  {}", walk.report_line());
    println!(
        "  -> save {} / load {} / walkback {} over {ckpt_bytes} bytes",
        fmt_secs(save.median()),
        fmt_secs(load.median()),
        fmt_secs(walk.median())
    );

    let doc = envelope(
        "faults",
        vec![
            ("step_plain_s", num(plain.median())),
            ("step_gated_s", num(gated.median())),
            ("guard_overhead_frac", num(overhead_frac)),
            ("ckpt_save_s", num(save.median())),
            ("ckpt_load_s", num(load.median())),
            ("walkback_s", num(walk.median())),
            ("ckpt_bytes", int(ckpt_bytes)),
            ("roundtrip_ok", int(roundtrip_ok as usize)),
            ("walkback_ok", int(walkback_ok as usize)),
        ],
    );
    report::write(Path::new("BENCH_faults.json"), &doc)?;
    println!(
        "wrote BENCH_faults.json (guard overhead {:+.2}%)",
        overhead_frac * 100.0
    );
    Ok(())
}
