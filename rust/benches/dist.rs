//! `cargo bench --bench dist` — the price of distributed coordination.
//!
//! Runs the same 2-shard training job two ways and compares per-step
//! cost: (a) a real 1-worker distributed run — coordinator thread,
//! worker thread, localhost TCP, CRC-framed gradients both directions —
//! and (b) a plain local loop computing the identical math in-process
//! (per-shard `grad_batch`, `reduce_shards`, `apply_flat_grads`). Both
//! timings include their setup (backend build; for the dist run also
//! registration), so `overhead_frac` is the honest end-to-end cost of
//! going distributed at worker count 1. The bench also verifies the two
//! paths land on bit-identical weights (`bitexact_vs_local`), which
//! `scripts/bench_check.sh` gates on alongside the overhead.
//!
//! On top of that it measures the streaming wire economics: total wire
//! bytes per step (gradient chunks up + apply chunks down + control
//! frames, via the `wire::bytes_written` counter) under both
//! `dist.compress` modes — `wire_ratio_bf16` is gated ≤ 0.55 — and the
//! per-step wall clock at 2 workers, where compute halves per replica
//! and chunk N ships while N+1 is still being computed.
//!
//! Env knobs: `BENCH_REPEATS` (samples per measurement, default 3),
//! `RMNP_THREADS`, `RMNP_SIMD`.

use std::path::{Path, PathBuf};
use std::time::Duration;

use rmnp::bench::report::{self, envelope, int, num};
use rmnp::bench::{bench_n, fmt_secs};
use rmnp::config::{DataSpec, RunConfig};
use rmnp::coordinator::{checkpoint, guard, lr_at};
use rmnp::data::corpus::token_source;
use rmnp::dist::worker::{self, WorkerOpts};
use rmnp::dist::{
    coordinator as dist_coordinator, read_addr_file, reduce_shards, wire, CLIP_NORM,
    SHARD_SPLIT_BASE,
};
use rmnp::runtime::{Batch, BatchShape, NativeBackend, TrainBackend, TrainState};

const STEPS: usize = 12;
const SHARDS: usize = 2;

fn bench_cfg(out: PathBuf, workers: usize, compress: &str) -> RunConfig {
    RunConfig {
        model: "gpt2_tiny".into(),
        optimizer: "rmnp".into(),
        steps: STEPS,
        seed: 42,
        data: DataSpec::Markov,
        eval_every: 0,
        checkpoint_every: STEPS, // one final checkpoint; needed for the bit check
        out_dir: out,
        dist_workers: workers,
        dist_shards: SHARDS,
        dist_bind: "127.0.0.1:0".into(),
        dist_compress: compress.into(),
        ..RunConfig::default()
    }
}

/// One full distributed run: coordinator + `workers` worker threads over
/// localhost TCP. Returns the final checkpoint path.
fn dist_run(out: &Path, workers: usize, compress: &str) -> PathBuf {
    let _ = std::fs::remove_dir_all(out);
    let cfg = bench_cfg(out.to_path_buf(), workers, compress);
    let dir = cfg.out_dir.clone();
    let coord = std::thread::spawn(move || dist_coordinator::run(&cfg));
    let (addr, nonce) = loop {
        if let Ok(parsed) = read_addr_file(&dir.join("coordinator.addr")) {
            break parsed;
        }
        std::thread::sleep(Duration::from_millis(1));
    };
    let fleet: Vec<_> = (0..workers)
        .map(|i| {
            let opts = WorkerOpts {
                connect: addr.clone(),
                worker_id: format!("bench{i}"),
                plan_threads: 0,
                heartbeat_ms: 50,
                worker_timeout_ms: 30_000,
                connect_attempts: 8,
                expect_nonce: nonce,
            };
            std::thread::spawn(move || worker::run(&opts))
        })
        .collect();
    coord.join().unwrap().expect("dist run failed");
    for w in fleet {
        w.join().unwrap().expect("worker failed");
    }
    out.join(format!("step-{STEPS}.ckpt"))
}

/// Total wire bytes (all sockets, both directions — this process hosts
/// every peer) for one full run in `compress` mode, per step.
fn wire_bytes_per_step(out: &Path, compress: &str) -> f64 {
    let before = wire::bytes_written();
    dist_run(out, 1, compress);
    (wire::bytes_written() - before) as f64 / STEPS as f64
}

/// The same job as a plain local loop: identical shard streams, the same
/// deterministic reduction and LR schedule, no sockets. Returns the
/// final state.
fn local_run(cfg: &RunConfig) -> TrainState {
    let mut backend =
        NativeBackend::new(&cfg.model, &cfg.optimizer, cfg.seed, 0).expect("backend");
    let BatchShape::Tokens { rows, cols } = backend.batch_shape() else {
        panic!("gpt2_tiny should consume tokens");
    };
    let mut feeds: Vec<_> = (0..SHARDS)
        .map(|k| token_source(cfg.data, cfg.seed, SHARD_SPLIT_BASE + k as u64))
        .collect();
    let mut tokens = vec![0i32; rows * cols];
    for step in 0..cfg.steps {
        let mut shards = Vec::with_capacity(SHARDS);
        for feed in &mut feeds {
            feed.fill(&mut tokens);
            shards.push(backend.grad_batch(&Batch::Tokens(&tokens)).expect("grad"));
        }
        let (_, avg) = reduce_shards(&shards, CLIP_NORM).expect("reduce");
        // mirror the coordinator's LR computation exactly (scale 1.0)
        let lr = (lr_at(cfg.schedule, cfg.lr, step, cfg.steps) * 1.0) as f32;
        backend.apply_flat_grads(&avg, lr).expect("apply");
    }
    backend.export_state().expect("export")
}

fn main() -> anyhow::Result<()> {
    std::env::set_var("RMNP_NO_FSYNC", "1");
    let repeats: usize = std::env::var("BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    println!(
        "dist bench: repeats={repeats} steps={STEPS} shards={SHARDS} threads={} simd={}",
        rmnp::tensor::kernels::num_threads(),
        rmnp::tensor::simd::label()
    );

    let dir = std::env::temp_dir().join(format!("rmnp-bench-dist-{}", std::process::id()));
    let cfg = bench_cfg(dir.clone(), 1, "none");

    // warm-up + bit-exactness: one run of each path, compared elementwise
    let ckpt = dist_run(&dir, 1, "none");
    let mut dist_state = checkpoint::load_state(&ckpt)?;
    let _ = guard::extract_guard(&mut dist_state); // drop the guard stamp
    let local_state = local_run(&cfg);
    let elems: usize = local_state.params.iter().map(|b| b.data.len()).sum();
    let same = |a: &[rmnp::runtime::NamedBuffer], b: &[rmnp::runtime::NamedBuffer]| {
        a.len() == b.len()
            && a.iter()
                .zip(b)
                .all(|(x, y)| x.name == y.name && x.data == y.data)
    };
    let bitexact = same(&dist_state.params, &local_state.params)
        && same(&dist_state.opt, &local_state.opt);
    println!(
        "  bit-exact vs local loop: {} ({elems} parameter elements)",
        if bitexact { "yes" } else { "NO" }
    );

    // wire economics: bytes/step under each codec, same 1-worker job
    let wire_f32 = wire_bytes_per_step(&dir, "none");
    let wire_bf16 = wire_bytes_per_step(&dir, "bf16");
    let wire_ratio = wire_bf16 / wire_f32.max(1e-12);
    println!(
        "wire bytes/step: f32 {:.0}, bf16 {:.0} (ratio {:.3})",
        wire_f32, wire_bf16, wire_ratio
    );

    println!("full-run timings ({STEPS} steps, {SHARDS} shards):");
    let local = bench_n("local_loop", 1, repeats, || {
        local_run(&cfg);
    });
    println!("  {}", local.report_line());
    let dist = bench_n("dist_1worker", 1, repeats, || {
        dist_run(&dir, 1, "none");
    });
    println!("  {}", dist.report_line());
    let dir2 = std::env::temp_dir().join(format!("rmnp-bench-dist2-{}", std::process::id()));
    let dist2 = bench_n("dist_2worker", 1, repeats, || {
        dist_run(&dir2, 2, "none");
    });
    println!("  {}", dist2.report_line());

    let local_step = local.median() / STEPS as f64;
    let dist_step = dist.median() / STEPS as f64;
    let dist_step_2w = dist2.median() / STEPS as f64;
    let overhead_frac = (dist_step - local_step) / local_step.max(1e-12);
    println!(
        "  -> local {}/step, dist {}/step (1w, overhead {:+.1}%), {}/step (2w, {:.2}x vs 1w)",
        fmt_secs(local_step),
        fmt_secs(dist_step),
        overhead_frac * 100.0,
        fmt_secs(dist_step_2w),
        dist_step / dist_step_2w.max(1e-12)
    );

    let doc = envelope(
        "dist",
        vec![
            ("steps", int(STEPS)),
            ("shards", int(SHARDS)),
            ("elems", int(elems)),
            ("local_step_s", num(local_step)),
            ("dist_step_s", num(dist_step)),
            ("dist_step_2w_s", num(dist_step_2w)),
            ("overhead_frac", num(overhead_frac)),
            ("wire_bytes_per_step_f32", num(wire_f32)),
            ("wire_bytes_per_step_bf16", num(wire_bf16)),
            ("wire_ratio_bf16", num(wire_ratio)),
            ("bitexact_vs_local", int(bitexact as usize)),
        ],
    );
    report::write(Path::new("BENCH_dist.json"), &doc)?;
    println!(
        "wrote BENCH_dist.json (overhead {:+.1}%, wire ratio {:.3}, bitexact={})",
        overhead_frac * 100.0,
        wire_ratio,
        bitexact as usize
    );
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir2);
    Ok(())
}
