//! `cargo bench --bench host_train` — the native training backend end to
//! end: batch assembly + model-layer forward/backward + global-norm
//! clip + sharded fused stepping through `StepPlan`, per optimizer and
//! per **architecture** (attention / gated MLP / SSM scan / conv stem).
//! Writes `BENCH_host_train.json` with one arch-tagged case per row so
//! the per-arch envelopes land in `bench_history/` and the README bench
//! table (`scripts/bench_table.py` groups by the `arch` field).
//!
//! Env knobs: `BENCH_REPEATS` (samples per measurement, default 3),
//! `RMNP_THREADS`, `RMNP_SIMD`.

use std::path::Path;

use rmnp::bench::report::{self, envelope, int, num, obj, text};
use rmnp::bench::{bench_n, fmt_secs};
use rmnp::config::DataSpec;
use rmnp::data::corpus::token_source;
use rmnp::data::images::ImageSource;
use rmnp::runtime::{Batch, BatchShape, NativeBackend, TrainBackend};
use rmnp::tensor::Precision;
use rmnp::util::Json;

struct Case {
    model: &'static str,
    arch: &'static str,
    optimizer: &'static str,
    precision: Precision,
    params: usize,
    elems: usize,
    step_median: f64,
    final_loss: f32,
}

enum Feed {
    Tokens { src: Box<dyn rmnp::data::TokenSource>, tokens: Vec<i32> },
    Images { src: ImageSource, images: Vec<f32>, labels: Vec<i32> },
}

impl Feed {
    fn new(backend: &NativeBackend, data: DataSpec) -> Self {
        match backend.batch_shape() {
            BatchShape::Tokens { rows, cols } => Feed::Tokens {
                src: token_source(data, 7, 0),
                tokens: vec![0i32; rows * cols],
            },
            BatchShape::Images { batch, hw, pixels } => Feed::Images {
                src: ImageSource::new(10, hw, 7, 0),
                images: vec![0.0f32; pixels],
                labels: vec![0i32; batch],
            },
        }
    }

    fn step(&mut self, backend: &mut NativeBackend, lr: f32) -> f32 {
        match self {
            Feed::Tokens { src, tokens } => {
                src.fill(tokens);
                backend
                    .step(&Batch::Tokens(tokens.as_slice()), lr)
                    .expect("bench step")
                    .loss
            }
            Feed::Images { src, images, labels } => {
                let n = labels.len();
                src.fill(n, images, labels);
                let batch =
                    Batch::Images { images: images.as_slice(), labels: labels.as_slice() };
                backend.step(&batch, lr).expect("bench step").loss
            }
        }
    }
}

fn run_case(
    model: &'static str,
    data: DataSpec,
    optimizer: &'static str,
    precision: Precision,
    steps_per_iter: usize,
    repeats: usize,
) -> anyhow::Result<Case> {
    let mut backend = NativeBackend::new_with_precision(model, optimizer, 42, 0, precision)?;
    let arch = backend.arch();
    let mut feed = Feed::new(&backend, data);
    let params = backend.n_params();
    let elems = backend.total_elems();
    let mut last = 0.0f32;
    // warm the workspace and the plan pool before timing
    feed.step(&mut backend, 1e-3);
    let r = bench_n(
        &format!("{model}_{optimizer}_{}_step", precision.name()),
        steps_per_iter,
        repeats,
        || {
            last = feed.step(&mut backend, 1e-3);
        },
    );
    println!("  {}", r.report_line());
    println!(
        "  -> [{arch}] {:.1} steps/s over {params} params ({elems} elems), loss {last:.3}",
        1.0 / r.median().max(1e-12)
    );
    assert!(last.is_finite(), "{model}/{optimizer} diverged in the bench");
    Ok(Case {
        model,
        arch,
        optimizer,
        precision,
        params,
        elems,
        step_median: r.median(),
        final_loss: last,
    })
}

fn main() -> anyhow::Result<()> {
    let repeats: usize = std::env::var("BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    println!(
        "host-train bench: repeats={repeats} threads={} simd={}",
        rmnp::tensor::kernels::num_threads(),
        rmnp::tensor::simd::label()
    );

    let mut cases = Vec::new();
    println!("gpt2_tiny (attention) full native train step:");
    for optimizer in ["rmnp", "muon", "adamw"] {
        cases.push(run_case(
            "gpt2_tiny",
            DataSpec::Markov,
            optimizer,
            Precision::F32,
            5,
            repeats,
        )?);
    }
    println!("gpt2_tiny (attention) full native train step (rmnp, bf16 storage):");
    cases.push(run_case(
        "gpt2_tiny",
        DataSpec::Markov,
        "rmnp",
        Precision::Bf16,
        5,
        repeats,
    )?);
    println!("gpt2_medium (attention, 3 blocks) full native train step (rmnp):");
    cases.push(run_case(
        "gpt2_medium",
        DataSpec::Markov,
        "rmnp",
        Precision::F32,
        3,
        repeats,
    )?);
    println!("llama_s60 (gated_mlp) full native train step (rmnp):");
    cases.push(run_case("llama_s60", DataSpec::Zipf, "rmnp", Precision::F32, 5, repeats)?);
    println!("ssm_base (ssm scan) full native train step (rmnp):");
    cases.push(run_case("ssm_base", DataSpec::Ngram, "rmnp", Precision::F32, 5, repeats)?);
    println!("vision_base (conv stem) full native train step (rmnp):");
    cases.push(run_case(
        "vision_base",
        DataSpec::Images,
        "rmnp",
        Precision::F32,
        5,
        repeats,
    )?);

    let entries: Vec<Json> = cases
        .iter()
        .map(|c| {
            obj(vec![
                ("model", text(c.model)),
                ("arch", text(c.arch)),
                ("optimizer", text(c.optimizer)),
                ("precision", text(c.precision.name())),
                ("params", int(c.params)),
                ("elems", int(c.elems)),
                ("step_median_s", num(c.step_median)),
                ("steps_per_s", num(1.0 / c.step_median.max(1e-12))),
                ("final_loss", num(c.final_loss as f64)),
            ])
        })
        .collect();
    let doc = envelope("host_train", vec![("cases", Json::Arr(entries))]);
    report::write(Path::new("BENCH_host_train.json"), &doc)?;
    println!(
        "wrote BENCH_host_train.json (gpt2_tiny rmnp step {})",
        fmt_secs(cases[0].step_median)
    );
    Ok(())
}
