//! `cargo bench --bench host_train` — the native training backend end to
//! end: batch assembly + scaled-model forward/backward + global-norm
//! clip + sharded fused stepping through `StepPlan`, per optimizer.
//! Writes `BENCH_host_train.json` so the whole-training-step trajectory
//! is comparable across PRs (`scripts/bench_check.sh` snapshots it into
//! `bench_history/`).
//!
//! Env knobs: `BENCH_REPEATS` (samples per measurement, default 3),
//! `RMNP_THREADS`, `RMNP_SIMD`.

use std::path::Path;

use rmnp::bench::report::{self, envelope, int, num, obj, text};
use rmnp::bench::{bench_n, fmt_secs};
use rmnp::config::DataSpec;
use rmnp::data::corpus::token_source;
use rmnp::runtime::{Batch, NativeBackend, TrainBackend};
use rmnp::util::Json;

struct Case {
    model: &'static str,
    optimizer: &'static str,
    params: usize,
    elems: usize,
    step_median: f64,
    final_loss: f32,
}

fn run_case(
    model: &'static str,
    optimizer: &'static str,
    steps_per_iter: usize,
    repeats: usize,
) -> anyhow::Result<Case> {
    let mut backend = NativeBackend::new(model, optimizer, 42, 0)?;
    let spec = backend.spec().clone();
    let mut src = token_source(DataSpec::Markov, 7, 0);
    let mut tokens = vec![0i32; spec.batch * spec.seq];
    let params = backend.n_params();
    let elems = backend.total_elems();
    let mut last = 0.0f32;
    // warm the workspace and the plan pool before timing
    src.fill(&mut tokens);
    backend.step(&Batch::Tokens(&tokens), 1e-3)?;
    let r = bench_n(
        &format!("{model}_{optimizer}_step"),
        steps_per_iter,
        repeats,
        || {
            src.fill(&mut tokens);
            last = backend
                .step(&Batch::Tokens(&tokens), 1e-3)
                .expect("bench step")
                .loss;
        },
    );
    println!("  {}", r.report_line());
    println!(
        "  -> {:.1} steps/s over {params} params ({elems} elems), loss {last:.3}",
        1.0 / r.median().max(1e-12)
    );
    assert!(last.is_finite(), "{model}/{optimizer} diverged in the bench");
    Ok(Case {
        model,
        optimizer,
        params,
        elems,
        step_median: r.median(),
        final_loss: last,
    })
}

fn main() -> anyhow::Result<()> {
    let repeats: usize = std::env::var("BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    println!(
        "host-train bench: repeats={repeats} threads={} simd={}",
        rmnp::tensor::kernels::num_threads(),
        rmnp::tensor::simd::label()
    );

    let mut cases = Vec::new();
    println!("gpt2_tiny full native train step:");
    for optimizer in ["rmnp", "muon", "adamw"] {
        cases.push(run_case("gpt2_tiny", optimizer, 5, repeats)?);
    }
    println!("gpt2_medium full native train step (rmnp):");
    cases.push(run_case("gpt2_medium", "rmnp", 3, repeats)?);

    let entries: Vec<Json> = cases
        .iter()
        .map(|c| {
            obj(vec![
                ("model", text(c.model)),
                ("optimizer", text(c.optimizer)),
                ("params", int(c.params)),
                ("elems", int(c.elems)),
                ("step_median_s", num(c.step_median)),
                ("steps_per_s", num(1.0 / c.step_median.max(1e-12))),
                ("final_loss", num(c.final_loss as f64)),
            ])
        })
        .collect();
    let doc = envelope("host_train", vec![("cases", Json::Arr(entries))]);
    report::write(Path::new("BENCH_host_train.json"), &doc)?;
    println!(
        "wrote BENCH_host_train.json (gpt2_tiny rmnp step {})",
        fmt_secs(cases[0].step_median)
    );
    Ok(())
}
