//! `cargo bench --bench shootout` — the optimizer-zoo race
//! ([`rmnp::exp::shootout`]) as a bench binary, so `scripts/bench_check.sh`
//! can gate on its output: rmnp's isolated per-step preconditioning cost
//! must not exceed muon's at d ≥ 512, and every registry optimizer must
//! appear (as a case or an explicit skip) in `BENCH_shootout.json`.
//!
//! Env knobs: `BENCH_SHOOTOUT_STEPS` (matched budget, default 20),
//! `BENCH_REPEATS` (step-cost samples, default 3), `RMNP_THREADS`,
//! `RMNP_SIMD`.

use rmnp::exp::shootout::{self, ShootoutOpts};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let opts = ShootoutOpts {
        steps: env_usize("BENCH_SHOOTOUT_STEPS", 20),
        repeats: env_usize("BENCH_REPEATS", 3),
        ..ShootoutOpts::default()
    };
    println!(
        "shootout bench: models={:?} steps={} threads={} simd={}",
        opts.models,
        opts.steps,
        rmnp::tensor::kernels::num_threads(),
        rmnp::tensor::simd::label()
    );
    let (shots, skips, costs) = shootout::run(&opts)?;
    println!("{}", shootout::format_table(&opts, &shots, &skips, &costs));
    shootout::write_report(&opts, &shots, &skips, &costs)?;
    println!("wrote {}", opts.json.display());
    Ok(())
}
