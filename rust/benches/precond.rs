//! `cargo bench --bench precond` — regenerates paper Table 2/3 + Figure 1:
//! preconditioner wall-clock, Muon NS5 vs RMNP row normalization, over the
//! Table 4 GPT-2 shape sets. Pass `--max-d N` via BENCH_MAX_D to cap the
//! largest config (full sweep to d=1600 takes several minutes of NS5 time
//! on CPU).

use rmnp::exp::{precond, ExpOpts};

fn main() -> anyhow::Result<()> {
    let max_d: usize = std::env::var("BENCH_MAX_D")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let repeats: usize = std::env::var("BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let opts = ExpOpts::default();
    let rows = precond::run(&opts, max_d, repeats)?;
    println!("{}", precond::format_table(&rows));
    println!("{}", precond::format_figure1(&rows));
    // reproduction checks: RMNP always wins and the gap grows with d_model
    let speedups: Vec<f64> = rows.iter().map(|r| r.speedup).collect();
    assert!(speedups.iter().all(|&s| s > 1.0), "RMNP must win every size");
    if speedups.len() >= 3 {
        let first = speedups.first().unwrap();
        let last = speedups.last().unwrap();
        // On GPU the gap grows monotonically (paper Table 2); on CPU PJRT
        // the small/mid sizes are flatter because the whole NS5 chain still
        // fits cache. Warn rather than fail if the trend is noisy.
        if last <= first {
            eprintln!("WARNING: speedup did not grow with size: {speedups:?}");
        }
    }
    Ok(())
}
