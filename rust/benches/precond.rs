//! `cargo bench --bench precond` — regenerates paper Table 2/3 + Figure 1
//! on the native kernel layer: preconditioner wall-clock, Muon NS5 vs RMNP
//! row normalization, over the GPT-2 shape sets, plus the seed-vs-kernel
//! before/after deltas. Writes the machine-readable `BENCH_precond.json`
//! (in the package root) so the perf trajectory is comparable across PRs.
//!
//! Env knobs: `BENCH_MAX_D` caps the largest d_model (default 640; the
//! full native sweep to 768 takes a couple of minutes of NS5 time on CPU),
//! `BENCH_REPEATS` sets samples per measurement (default 2), and
//! `RMNP_THREADS` pins the kernel thread count.

use std::path::Path;

use rmnp::bench::report;
use rmnp::exp::precond;

fn main() -> anyhow::Result<()> {
    let max_d: usize = std::env::var("BENCH_MAX_D")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(640);
    let repeats: usize = std::env::var("BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    println!(
        "native precond bench: max_d={max_d} repeats={repeats} threads={} simd={}",
        rmnp::tensor::kernels::num_threads(),
        rmnp::tensor::simd::label()
    );

    let rows = precond::run_native(max_d, repeats);
    anyhow::ensure!(!rows.is_empty(), "BENCH_MAX_D={max_d} excluded every config");
    println!("{}", precond::format_table(&rows));
    println!("{}", precond::format_figure1(&rows));

    // reproduction checks: RMNP always wins and the gap grows with d_model
    let speedups: Vec<f64> = rows.iter().map(|r| r.speedup).collect();
    assert!(speedups.iter().all(|&s| s > 1.0), "RMNP must win every size");
    if speedups.len() >= 3 {
        let first = speedups.first().unwrap();
        let last = speedups.last().unwrap();
        // On GPU the gap grows monotonically (paper Table 2); on CPU the
        // small/mid sizes are flatter because the whole NS5 chain still
        // fits cache. Warn rather than fail if the trend is noisy.
        if last <= first {
            eprintln!("WARNING: speedup did not grow with size: {speedups:?}");
        }
    }

    // before/after: seed scalar paths vs the kernel layer. d=512 is the
    // acceptance floor and is always measured; 640 joins when the cap
    // allows it (max_d == 0 means uncapped).
    let compare_ds: Vec<usize> = [512usize, 640]
        .into_iter()
        .filter(|&d| d == 512 || max_d == 0 || d <= max_d)
        .collect();
    let deltas = precond::seed_vs_kernel(&compare_ds, repeats.clamp(1, 2));
    println!("seed scalar path vs kernel layer (same op, same shape):");
    for d in &deltas {
        println!(
            "  {:<8} d={:<5} ({}x{}): seed {:>10.4}s  kernel {:>10.4}s  -> {:.2}x",
            d.op, d.d_model, d.rows, d.cols, d.seed_median, d.kernel_median,
            d.improvement
        );
    }

    // dispatch-ladder delta: the same kernel-layer ops on the scalar rung
    // vs the best vector rung — AVX2 on x86-64, NEON on aarch64 (empty
    // when the CPU has neither, or when the scalar rung was forced)
    let simd_deltas = precond::simd_vs_scalar(&compare_ds, repeats.clamp(1, 2));
    if simd_deltas.is_empty() {
        println!("simd vs scalar: skipped (no vector rung on this CPU, or scalar forced)");
    } else {
        println!("scalar rung vs vector rung (same op, same shape):");
        for d in &simd_deltas {
            println!(
                "  {:<8} d={:<5} ({}x{}): scalar {:>10.4}s  {} {:>10.4}s  -> {:.2}x",
                d.op, d.d_model, d.rows, d.cols, d.scalar_median, d.rung,
                d.simd_median, d.speedup
            );
        }
    }

    let doc = precond::json_report(&rows, &deltas, &simd_deltas, max_d);
    report::write(Path::new("BENCH_precond.json"), &doc)?;
    println!("wrote BENCH_precond.json");
    Ok(())
}
