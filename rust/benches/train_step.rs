//! `cargo bench --bench train_step --features pjrt` — end-to-end step
//! latency per (model, optimizer): the figure-6-protocol cost view.
//! Reports median step time and the share of it attributable to the L3
//! host path (upload + metric fetch), which the perf pass drives below
//! 5%. Overwrites `BENCH_train_step.json` (native numbers come from
//! `cargo bench --bench optim_step`) with the artifact-path measurements.

use std::path::Path;

use rmnp::bench::report::{self, bench_json, envelope, num};
use rmnp::bench::{bench_n, fmt_secs};
use rmnp::config::DataSpec;
use rmnp::data::corpus::token_source;
use rmnp::runtime::session::{Batch, TrainSession};
use rmnp::runtime::Engine;
use rmnp::util::Json;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(std::path::Path::new("artifacts"))?;
    let cases = [
        ("gpt2_tiny", "adamw"),
        ("gpt2_tiny", "muon"),
        ("gpt2_tiny", "rmnp"),
        ("gpt2_small", "muon"),
        ("gpt2_small", "rmnp"),
        ("llama_s60", "muon"),
        ("llama_s60", "rmnp"),
    ];
    let mut results: Vec<Json> = Vec::new();
    println!("train-step latency (device-resident loop, batch from manifest):");
    for (model, opt) in cases {
        let mut sess = TrainSession::new(&engine, model, opt, 1)?;
        let spec = engine.manifest.model(model)?.batch_specs[0].clone();
        let mut tokens = vec![0i32; spec.elements()];
        token_source(DataSpec::Markov, 5, 0).fill(&mut tokens);
        let r = bench_n(&format!("{model}/{opt}"), 5, 4, || {
            sess.step(&Batch::Tokens(&tokens), 1e-3).expect("step");
        });
        println!("  {}", r.report_line());
        results.push(bench_json(&r));
    }
    // host-path overhead: time upload alone vs a full step
    let mut sess = TrainSession::new(&engine, "gpt2_small", "rmnp", 1)?;
    let spec = engine.manifest.model("gpt2_small")?.batch_specs[0].clone();
    let mut tokens = vec![0i32; spec.elements()];
    token_source(DataSpec::Markov, 5, 0).fill(&mut tokens);
    let up_lit = bench_n("upload_via_literal (before)", 20, 4, || {
        let _ = engine
            .upload_i32_via_literal(&tokens, &spec.shape)
            .expect("upload");
    });
    println!("  {}", up_lit.report_line());
    let up = bench_n("upload_direct (after)", 20, 4, || {
        let _ = engine.upload_i32(&tokens, &spec.shape).expect("upload");
    });
    println!("  {}  (perf L3-1 delta {:+.1}%)",
        up.report_line(),
        100.0 * (up.median() - up_lit.median()) / up_lit.median());
    let step = bench_n("full_step", 5, 4, || {
        sess.step(&Batch::Tokens(&tokens), 1e-3).expect("step");
    });
    let overhead = up.median() / step.median();
    println!(
        "\nL3 host path: upload {} vs step {} -> {:.2}% of step",
        fmt_secs(up.median()),
        fmt_secs(step.median()),
        100.0 * overhead
    );
    assert!(overhead < 0.10, "host path must stay <10% of step time");

    let doc = envelope(
        "train_step_pjrt",
        vec![
            ("results", Json::Arr(results)),
            ("upload_direct", bench_json(&up)),
            ("upload_via_literal", bench_json(&up_lit)),
            ("full_step", bench_json(&step)),
            ("host_path_share", num(overhead)),
        ],
    );
    report::write(Path::new("BENCH_train_step.json"), &doc)?;
    println!("wrote BENCH_train_step.json");
    Ok(())
}
