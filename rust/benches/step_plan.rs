//! `cargo bench --bench step_plan` — sharded multi-param stepping: a
//! GPT-2-shaped parameter list stepped sequentially (inner-matmul
//! threading, PR 1's model) vs through a [`rmnp::optim::StepPlan`]
//! (across-param sharding on the persistent pool). Writes
//! `BENCH_step_plan.json` so the multi-param path's trajectory is
//! comparable across PRs.
//!
//! Env knobs: `BENCH_PLAN_D` (RMNP width, default 512), `BENCH_REPEATS`
//! (samples per measurement, default 3), `RMNP_THREADS`, `RMNP_SIMD`.

use std::path::Path;

use rmnp::bench::report::{self, envelope, int, num, obj, text};
use rmnp::bench::{bench_n, fmt_secs};
use rmnp::exp::precond::shape_counts;
use rmnp::optim::plan::{tasks_from_shapes, OptKind, ParamTask, StepPlan};
use rmnp::util::{Json, Rng};

struct Case {
    optimizer: &'static str,
    d_model: usize,
    layers: usize,
    params: usize,
    elems: usize,
    seq_median: f64,
    plan_median: f64,
    plan_threads: usize,
}

/// Deterministic gradient fill shared by the baseline and the plan.
fn fill_grads(tasks: &mut [ParamTask], seed: u64) {
    for (i, t) in tasks.iter_mut().enumerate() {
        let mut rng = Rng::new(seed ^ (i as u64 + 1));
        rng.fill_normal(t.grad.data_mut(), 1.0);
    }
}

fn run_case(
    optimizer: &'static str,
    kind: OptKind,
    d: usize,
    layers: usize,
    steps_per_iter: usize,
    repeats: usize,
) -> Case {
    let shapes = shape_counts(d, layers);
    let mut rng = Rng::new(42);
    // sequential baseline: the PR 1 model — one fused step at a time,
    // intra-kernel threading active
    let mut seq_tasks = tasks_from_shapes(&shapes, kind, 0.02, &mut rng);
    fill_grads(&mut seq_tasks, 7);
    let params = seq_tasks.len();
    let elems: usize = seq_tasks.iter().map(|t| t.w.rows() * t.w.cols()).sum();
    let seq = bench_n(&format!("{optimizer}_seq_d{d}"), steps_per_iter, repeats, || {
        for t in seq_tasks.iter_mut() {
            t.step(1e-3);
        }
    });

    // sharded plan: same shapes/seeds, across-param pool
    let mut rng = Rng::new(42);
    let mut plan_tasks = tasks_from_shapes(&shapes, kind, 0.02, &mut rng);
    fill_grads(&mut plan_tasks, 7);
    let mut plan = StepPlan::new(plan_tasks, 0);
    let plan_threads = plan.threads();
    let sharded = bench_n(&format!("{optimizer}_plan_d{d}"), steps_per_iter, repeats, || {
        plan.step_all(1e-3);
    });

    println!("  {}", seq.report_line());
    println!("  {}", sharded.report_line());
    println!(
        "  -> {:.2}x across {} params ({} workers)",
        seq.median() / sharded.median().max(1e-12),
        params,
        plan_threads
    );
    Case {
        optimizer,
        d_model: d,
        layers,
        params,
        elems,
        seq_median: seq.median(),
        plan_median: sharded.median(),
        plan_threads,
    }
}

fn main() -> anyhow::Result<()> {
    let d: usize = std::env::var("BENCH_PLAN_D")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let repeats: usize = std::env::var("BENCH_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    println!(
        "step-plan bench: d={d} repeats={repeats} threads={} simd={}",
        rmnp::tensor::kernels::num_threads(),
        rmnp::tensor::simd::label()
    );

    println!("RMNP sharded vs sequential (d={d}, 6 layers):");
    let rmnp_case = run_case("rmnp", OptKind::Rmnp, d, 6, 5, repeats);

    // Muon's NS5 makes big widths CPU-hostile; half width and fewer
    // layers keep the bench tractable while NS5 still dominates
    let muon_d = (d / 2).max(128);
    println!("Muon sharded vs sequential (d={muon_d}, 2 layers):");
    let muon_case = run_case("muon", OptKind::Muon, muon_d, 2, 1, repeats);

    let cases = [rmnp_case, muon_case];
    // sharding must not make multi-param stepping slower than the
    // sequential loop (some headroom for 1-2 core runners and noise)
    for c in &cases {
        let speedup = c.seq_median / c.plan_median.max(1e-12);
        if speedup < 0.9 {
            eprintln!(
                "WARNING: {} plan slower than sequential: {speedup:.2}x \
                 ({} workers)",
                c.optimizer, c.plan_threads
            );
        }
    }

    let entries: Vec<Json> = cases
        .iter()
        .map(|c| {
            obj(vec![
                ("optimizer", text(c.optimizer)),
                ("d_model", int(c.d_model)),
                ("layers", int(c.layers)),
                ("params", int(c.params)),
                ("elems", int(c.elems)),
                ("seq_median_s", num(c.seq_median)),
                ("plan_median_s", num(c.plan_median)),
                ("speedup", num(c.seq_median / c.plan_median.max(1e-12))),
                ("plan_threads", int(c.plan_threads)),
            ])
        })
        .collect();
    let doc = envelope("step_plan", vec![("cases", Json::Arr(entries))]);
    report::write(Path::new("BENCH_step_plan.json"), &doc)?;
    println!(
        "wrote BENCH_step_plan.json (rmnp plan step {})",
        fmt_secs(cases[0].plan_median)
    );
    Ok(())
}
