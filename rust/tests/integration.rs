//! Integration tests over the full stack: artifacts → PJRT → coordinator.
//!
//! All tests share one process-global Engine (concurrent PJRT client
//! lifecycles are not safe in xla_extension 0.5.1), acquired through a
//! mutex. Tests no-op gracefully when `artifacts/` hasn't been built.

use std::path::Path;
use std::sync::Mutex;

use rmnp::config::{DataSpec, RunConfig, Schedule};
use rmnp::coordinator::{checkpoint, train};
use rmnp::coordinator::metrics::CsvData;
use rmnp::data::corpus::token_source;
use rmnp::optim::{AdamWState, MuonState, RmnpState};
use rmnp::runtime::session::{Batch, TrainSession};
use rmnp::runtime::Engine;
use rmnp::tensor::Matrix;
use rmnp::util::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn with_engine(f: impl FnOnce(&Engine)) {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new(dir).expect("engine");
    f(&engine);
}

fn tmp_out(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("rmnp-it-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn quick_cfg(engine_model: &str, optimizer: &str, steps: usize, name: &str) -> RunConfig {
    RunConfig {
        model: engine_model.into(),
        optimizer: optimizer.into(),
        lr: 4e-3,
        schedule: Schedule::CosineWarmup { warmup_frac: 0.1, min_ratio: 0.1 },
        steps,
        seed: 11,
        data: DataSpec::Markov,
        eval_every: steps / 2,
        eval_batches: 2,
        out_dir: tmp_out(name),
        artifacts: "artifacts".into(),
        backend: rmnp::config::BackendKind::Pjrt,
        ..RunConfig::default()
    }
}

/// Drive one run through the shared engine (the trait-based loop).
fn run_with(engine: &Engine, cfg: &RunConfig) -> anyhow::Result<train::RunResult> {
    let mut sess =
        TrainSession::new(engine, &cfg.model, &cfg.optimizer, cfg.seed as i32)?;
    train::run(&mut sess, cfg)
}

#[test]
fn full_training_run_writes_metrics_and_learns() {
    with_engine(|engine| {
        let cfg = quick_cfg("gpt2_tiny", "rmnp", 40, "learn");
        let result = run_with(engine, &cfg).expect("run");
        assert!(result.final_train_loss < 6.0, "{result:?}");
        assert!(result.final_ppl.is_finite() && result.final_ppl > 1.0);
        let csv = CsvData::read(&cfg.out_dir.join("metrics.csv")).unwrap();
        assert_eq!(csv.rows.len(), 40);
        let losses = csv.column("loss").unwrap();
        assert!(losses.last().unwrap() < &losses[0]);
        // summary file parses back
        let ppl = train::read_final_ppl(&cfg.out_dir).unwrap();
        assert!((ppl - result.final_ppl).abs() < 1e-2);
    });
}

#[test]
fn every_optimizer_trains_gpt2_tiny() {
    with_engine(|engine| {
        for optimizer in ["adamw", "muon", "rmnp", "shampoo", "soap"] {
            let mut cfg = quick_cfg("gpt2_tiny", optimizer, 8, optimizer);
            cfg.lr = match optimizer {
                "muon" | "shampoo" => 1e-2,
                "adamw" | "soap" => 3e-3,
                _ => 4e-3,
            };
            let result = run_with(engine, &cfg)
                .unwrap_or_else(|e| panic!("{optimizer}: {e}"));
            assert!(
                result.final_train_loss.is_finite(),
                "{optimizer} diverged: {result:?}"
            );
        }
    });
}

#[test]
fn every_model_family_trains_one_step() {
    with_engine(|engine| {
        for (model, data) in [
            ("llama_s60", DataSpec::Zipf),
            ("ssm_base", DataSpec::Ngram),
            ("vision_base", DataSpec::Images),
        ] {
            let mut cfg = quick_cfg(model, "rmnp", 3, model);
            cfg.data = data;
            cfg.eval_every = 0;
            let result = run_with(engine, &cfg)
                .unwrap_or_else(|e| panic!("{model}: {e}"));
            assert!(result.final_train_loss.is_finite(), "{model}");
        }
    });
}

#[test]
fn hlo_rmnp_update_matches_rust_reference() {
    // Cross-check: drive the train artifact for 1 step with a known batch,
    // then verify selected momentum buffers obey V1 = (1-beta) * clip(G)
    // and parameters moved by lr*(RN(V1) + wd*W0) — using the pure-rust
    // reference on downloaded buffers.
    with_engine(|engine| {
        let entry = engine.manifest.opt_entry("gpt2_tiny", "rmnp").unwrap().clone();
        let mut sess = TrainSession::new(engine, "gpt2_tiny", "rmnp", 5).unwrap();
        let before = sess.download_state().unwrap();
        let mut tokens = vec![0i32; 16 * 129];
        token_source(DataSpec::Markov, 9, 0).fill(&mut tokens);
        let lr = 3e-3f32;
        sess.step(&Batch::Tokens(&tokens), lr).unwrap();
        let after = sess.download_state().unwrap();

        // pick the first matrix-momentum entry and its parameter
        let mom_idx = entry.dom_indices[0];
        let mom_name = &entry.dom_names[0]; // "mom.<param>"
        let param_name = mom_name.strip_prefix("mom.").unwrap();
        let param_idx = entry
            .state_names
            .iter()
            .position(|n| n == param_name)
            .unwrap();
        let graph = engine.manifest.graph(&entry.train).unwrap();
        let shape = &graph.inputs[param_idx].shape;
        let (m, n) = (shape[0], shape[1]);

        let w0 = Matrix::from_vec(m, n, before[param_idx].clone());
        let w1 = Matrix::from_vec(m, n, after[param_idx].clone());
        let v1 = Matrix::from_vec(m, n, after[mom_idx].clone());

        // rust reference: one RMNP step from (w0, grad_implied)
        // grad can be recovered from the momentum: V1 = (1-beta) * g_clipped
        let mut grad = v1.clone();
        grad.scale_inplace(1.0 / (1.0 - rmnp::optim::MATRIX_BETA));
        let mut st = RmnpState::new(m, n);
        let mut w_ref = w0.clone();
        st.step(&mut w_ref, &grad, lr);
        let mut max_err = 0.0f32;
        for (a, b) in w_ref.data().iter().zip(w1.data()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 5e-5, "HLO vs rust reference mismatch: {max_err}");
        // and the momentum buffer itself matches the reference state
        let mut max_err = 0.0f32;
        for (a, b) in st.momentum.data().iter().zip(v1.data()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-6, "momentum mismatch: {max_err}");
    });
}

#[test]
fn hlo_muon_direction_is_orthogonal_like_reference() {
    with_engine(|engine| {
        let entry = engine.manifest.opt_entry("gpt2_tiny", "muon").unwrap().clone();
        let mut sess = TrainSession::new(engine, "gpt2_tiny", "muon", 5).unwrap();
        let before = sess.download_state().unwrap();
        let mut tokens = vec![0i32; 16 * 129];
        token_source(DataSpec::Markov, 9, 0).fill(&mut tokens);
        let lr = 3e-3f32;
        sess.step(&Batch::Tokens(&tokens), lr).unwrap();
        let after = sess.download_state().unwrap();

        let mom_idx = entry.dom_indices[0];
        let mom_name = &entry.dom_names[0];
        let param_name = mom_name.strip_prefix("mom.").unwrap();
        let param_idx = entry.state_names.iter().position(|n| n == param_name).unwrap();
        let graph = engine.manifest.graph(&entry.train).unwrap();
        let shape = &graph.inputs[param_idx].shape;
        let (m, n) = (shape[0], shape[1]);

        let w0 = Matrix::from_vec(m, n, before[param_idx].clone());
        let w1 = Matrix::from_vec(m, n, after[param_idx].clone());
        let v1 = Matrix::from_vec(m, n, after[mom_idx].clone());

        let mut grad = v1;
        grad.scale_inplace(1.0 / (1.0 - rmnp::optim::MATRIX_BETA));
        let mut st = MuonState::new(m, n);
        let mut w_ref = w0.clone();
        st.step(&mut w_ref, &grad, lr);
        let mut max_err = 0.0f32;
        for (a, b) in w_ref.data().iter().zip(w1.data()) {
            max_err = max_err.max((a - b).abs());
        }
        // NS5 in f32 across two implementations: allow small drift
        assert!(max_err < 5e-3, "muon HLO vs rust reference: {max_err}");
    });
}

#[test]
fn adamw_artifact_matches_reference_on_scalar_state() {
    with_engine(|engine| {
        let entry = engine.manifest.opt_entry("gpt2_tiny", "adamw").unwrap().clone();
        let mut sess = TrainSession::new(engine, "gpt2_tiny", "adamw", 5).unwrap();
        let before = sess.download_state().unwrap();
        let mut tokens = vec![0i32; 16 * 129];
        token_source(DataSpec::Markov, 9, 0).fill(&mut tokens);
        sess.step(&Batch::Tokens(&tokens), 1e-3).unwrap();
        let after = sess.download_state().unwrap();
        // recover the (clipped) gradient from the m buffer: m1 = 0.1 g
        let name = "h00.attn_qkv";
        let p_idx = entry.state_names.iter().position(|n| n == name).unwrap();
        let m_idx = entry
            .state_names
            .iter()
            .position(|n| n == &format!("m.{name}"))
            .unwrap();
        let graph = engine.manifest.graph(&entry.train).unwrap();
        let len = graph.inputs[p_idx].elements();
        let grad: Vec<f32> = after[m_idx].iter().map(|x| x * 10.0).collect();
        let mut w = before[p_idx].clone();
        let mut st = AdamWState::new(len);
        st.step(&mut w, &grad, 1e-3);
        let mut max_err = 0.0f32;
        for (a, b) in w.iter().zip(&after[p_idx]) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 5e-5, "adamw mismatch {max_err}");
    });
}

#[test]
fn checkpoint_roundtrip_through_session() {
    with_engine(|engine| {
        let mut cfg = quick_cfg("gpt2_tiny", "rmnp", 6, "ckpt");
        cfg.checkpoint_every = 3;
        run_with(engine, &cfg).unwrap();
        let (step, path) =
            checkpoint::latest(&cfg.out_dir).unwrap().expect("checkpoint written");
        assert_eq!(step, 6);
        let buffers = checkpoint::load(&path).unwrap();
        let entry = engine.manifest.opt_entry("gpt2_tiny", "rmnp").unwrap();
        assert_eq!(buffers.len(), entry.state_names.len());
        for (b, name) in buffers.iter().zip(&entry.state_names) {
            assert_eq!(&b.name, name);
        }
    });
}

#[test]
fn eval_uses_heldout_split() {
    with_engine(|engine| {
        let cfg = quick_cfg("gpt2_tiny", "rmnp", 30, "heldout");
        let result = run_with(engine, &cfg).unwrap();
        // held-out loss should track train loss at this scale but not be
        // wildly lower (that would indicate a split leak)
        assert!(result.final_eval_loss > result.tail_train_loss - 0.5);
        assert!(result.final_eval_loss < result.tail_train_loss + 1.5);
    });
}

#[test]
fn dominance_metrics_device_matches_host() {
    with_engine(|engine| {
        let entry = engine.manifest.opt_entry("gpt2_tiny", "muon").unwrap().clone();
        let mut sess = TrainSession::new(engine, "gpt2_tiny", "muon", 3).unwrap();
        let mut tokens = vec![0i32; 16 * 129];
        token_source(DataSpec::Markov, 4, 0).fill(&mut tokens);
        for _ in 0..3 {
            sess.step(&Batch::Tokens(&tokens), 2e-3).unwrap();
        }
        let device = sess.dominance().unwrap();
        let state = sess.download_state().unwrap();
        let graph = engine.manifest.graph(&entry.train).unwrap();
        for (k, &idx) in entry.dom_indices.iter().enumerate() {
            let shape = &graph.inputs[idx].shape;
            let v = Matrix::from_vec(shape[0], shape[1], state[idx].clone());
            let (avg, min, max) = rmnp::optim::lemmas::dominance_ratios(&v);
            let (da, dmi, dma) = device[k];
            assert!((avg - da as f64).abs() / avg < 2e-3, "avg {avg} vs {da}");
            assert!((min - dmi as f64).abs() / min < 2e-3, "min {min} vs {dmi}");
            assert!((max - dma as f64).abs() / max < 2e-3, "max {max} vs {dma}");
        }
    });
}

#[test]
fn precond_artifacts_match_native_ops() {
    with_engine(|engine| {
        let op = engine.manifest.precond_ops.get("640x640").unwrap().clone();
        let mut rng = Rng::new(3);
        let host = Matrix::randn(640, 640, 0.02, &mut rng);
        let v = engine.upload_f32(host.data(), &[640, 640]).unwrap();
        // rownorm artifact vs rust reference
        let rn = engine.executable(&op.rownorm).unwrap();
        let out = rn.execute_b_untupled(&[&v]).unwrap().remove(0);
        let got = engine.fetch_f32(&out[0]).unwrap();
        let want = host.row_normalize(1e-7);
        let mut max_err = 0.0f32;
        for (a, b) in got.iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-5, "rownorm artifact mismatch {max_err}");
        // ns5 artifact vs rust reference
        let ns = engine.executable(&op.ns5).unwrap();
        let out = ns.execute_b_untupled(&[&v]).unwrap().remove(0);
        let got = engine.fetch_f32(&out[0]).unwrap();
        let want = rmnp::optim::newton_schulz5(&host, 5);
        let mut max_err = 0.0f32;
        for (a, b) in got.iter().zip(want.data()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 5e-3, "ns5 artifact mismatch {max_err}");
    });
}

#[test]
fn deterministic_runs_same_seed() {
    with_engine(|engine| {
        let run = |name: &str| {
            let cfg = quick_cfg("gpt2_tiny", "rmnp", 10, name);
            run_with(engine, &cfg).unwrap().final_train_loss
        };
        assert_eq!(run("det-a"), run("det-b"));
    });
}
