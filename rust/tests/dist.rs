//! Distributed-training integration suite (default features, offline).
//!
//! Drives the real coordinator and worker loops *in-process* — the
//! coordinator on one thread, each worker replica on its own thread,
//! talking over real localhost TCP sockets — so the wire protocol,
//! registration, barriers, and reduction run exactly as they do across
//! processes, while failures stay debuggable in one test binary. (The
//! SIGKILL-based scenarios, which genuinely need separate OS processes,
//! live in `tests/fault_injection.rs` via the `exp::faults` harness.)
//!
//! The core claim under test is the determinism contract: at a fixed
//! shard count, the final checkpoint bytes are identical for any worker
//! count, and a coordinator restart resumes bit-exactly.

use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use rmnp::config::{DataSpec, RunConfig};
use rmnp::dist::coordinator::{self, DistResult};
use rmnp::dist::read_addr_file;
use rmnp::dist::wire::{self, Msg};
use rmnp::dist::worker::{self, WorkerOpts, WorkerResult};

fn tmp_out(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rmnp-dist-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A small distributed run config: 2 shards always, so the global batch
/// (and therefore the trajectory) is the same for every worker count.
fn dist_cfg(out: PathBuf, steps: usize, workers: usize) -> RunConfig {
    RunConfig {
        model: "gpt2_tiny".into(),
        optimizer: "rmnp".into(),
        steps,
        seed: 99,
        data: DataSpec::Markov,
        eval_every: 0,
        checkpoint_every: 3,
        out_dir: out,
        dist_workers: workers,
        dist_shards: 2,
        dist_bind: "127.0.0.1:0".into(),
        dist_deadline_ms: 10_000,
        ..RunConfig::default()
    }
}

/// Poll for the coordinator's published address (it binds port 0).
/// Returns the address plus the run nonce from the file's second line.
fn wait_addr(dir: &Path) -> (String, Option<u64>) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(parsed) = read_addr_file(&dir.join("coordinator.addr")) {
            return parsed;
        }
        assert!(Instant::now() < deadline, "coordinator never published its address");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn worker_opts(addr: &str, id: &str) -> WorkerOpts {
    WorkerOpts {
        connect: addr.to_string(),
        worker_id: id.to_string(),
        plan_threads: 1,
        heartbeat_ms: 50,
        worker_timeout_ms: 30_000,
        connect_attempts: 8,
        expect_nonce: None,
    }
}

/// Run one coordinator plus `nworkers` worker replicas to completion.
/// Workers carry the published run nonce, so every in-process run also
/// exercises the nonce echo check.
fn run_dist(cfg: RunConfig, nworkers: usize) -> (DistResult, Vec<WorkerResult>) {
    let dir = cfg.out_dir.clone();
    let coord = std::thread::spawn(move || coordinator::run(&cfg));
    let (addr, nonce) = wait_addr(&dir);
    let workers: Vec<_> = (0..nworkers)
        .map(|i| {
            let mut opts = worker_opts(&addr, &format!("w{i}"));
            opts.expect_nonce = nonce;
            std::thread::spawn(move || worker::run(&opts))
        })
        .collect();
    let run = coord
        .join()
        .expect("coordinator thread panicked")
        .expect("coordinator run failed");
    let results = workers
        .into_iter()
        .map(|j| j.join().expect("worker thread panicked").expect("worker failed"))
        .collect();
    (run, results)
}

/// Dial the coordinator like a worker would, send one `Register`, and
/// return the socket plus the coordinator's reply.
fn raw_register(addr: &str, id: &str) -> (TcpStream, Msg) {
    let mut stream = TcpStream::connect(addr).expect("raw connect failed");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    wire::write_msg(&mut stream, &Msg::Register { worker_id: id.to_string() })
        .expect("raw register send failed");
    let reply = wire::read_msg(&mut stream).expect("no reply to raw register");
    (stream, reply)
}

/// The determinism contract end to end: at 2 shards, the final
/// checkpoint bytes are identical for 1, 2, and 3 workers (3 workers >
/// shards exercises the idle rank that only sees empty `StepBegin`s).
#[test]
fn final_checkpoint_is_bit_exact_for_any_worker_count() {
    let mut finals = Vec::new();
    for workers in [1usize, 2, 3] {
        let out = tmp_out(&format!("count-{workers}"));
        let (run, results) = run_dist(dist_cfg(out.clone(), 6, workers), workers);
        assert_eq!(run.steps_run, 6);
        assert_eq!(run.deaths, 0, "{workers}-worker run saw deaths");
        assert_eq!(run.workers, workers);
        let shards_done: usize = results.iter().map(|r| r.shards_done).sum();
        assert_eq!(shards_done, 2 * 6, "every shard computed exactly once per step");
        finals.push(std::fs::read(out.join("step-6.ckpt")).unwrap());
    }
    assert_eq!(finals[0], finals[1], "1-worker and 2-worker runs diverged");
    assert_eq!(finals[0], finals[2], "1-worker and 3-worker runs diverged");
}

/// The determinism contract holds under bf16 wire compression too: the
/// codec rounds once on the uplink and once on the shared downlink
/// average, so every worker count decodes the identical byte stream and
/// the final checkpoints stay bit-exact across 1, 2, and 3 workers.
#[test]
fn bf16_compression_is_bit_exact_for_any_worker_count() {
    let mut finals = Vec::new();
    for workers in [1usize, 2, 3] {
        let out = tmp_out(&format!("bf16-count-{workers}"));
        let mut cfg = dist_cfg(out.clone(), 6, workers);
        cfg.dist_compress = "bf16".into();
        let (run, results) = run_dist(cfg, workers);
        assert_eq!(run.steps_run, 6);
        assert_eq!(run.deaths, 0, "bf16 {workers}-worker run saw deaths");
        let shards_done: usize = results.iter().map(|r| r.shards_done).sum();
        assert_eq!(shards_done, 2 * 6, "every shard computed exactly once per step");
        finals.push(std::fs::read(out.join("step-6.ckpt")).unwrap());
    }
    assert_eq!(finals[0], finals[1], "bf16: 1-worker and 2-worker runs diverged");
    assert_eq!(finals[0], finals[2], "bf16: 1-worker and 3-worker runs diverged");
}

/// A worker holding a stale run nonce (left over from a previous
/// coordinator incarnation's addr file) is turned away at registration
/// time — before it computes a single shard — with an error naming the
/// nonce mismatch.
#[test]
fn stale_run_nonce_is_rejected_before_compute() {
    let out = tmp_out("stale-nonce");
    let mut cfg = dist_cfg(out.clone(), 2, 1);
    cfg.dist_join_timeout_ms = 2_000;
    let dir = cfg.out_dir.clone();
    let coord = std::thread::spawn(move || coordinator::run(&cfg));
    let (addr, nonce) = wait_addr(&dir);
    let nonce = nonce.expect("coordinator should publish a run nonce");

    let mut stale = worker_opts(&addr, "stale");
    stale.expect_nonce = Some(nonce ^ 0x5A5A_5A5A);
    let err = worker::run(&stale).expect_err("a stale run nonce must be rejected");
    let text = err.to_string();
    assert!(text.contains("nonce"), "error does not name the nonce: {text}");

    // the mismatched worker burned the only roster slot and hung up, so
    // the coordinator fails its run instead of training a ghost fleet —
    // either way it must terminate
    let _ = coord.join().expect("coordinator thread panicked");
}

/// A worker whose chunk stream dies mid-frame (truncated gradient chunk,
/// then a vanished socket) is marked dead; its shards redistribute and
/// the run still finishes byte-identical to a clean 1-worker run.
#[test]
fn truncated_chunk_stream_recovers_byte_exact() {
    let ref_out = tmp_out("trunc-ref");
    let (ref_run, _) = run_dist(dist_cfg(ref_out.clone(), 6, 1), 1);
    assert_eq!(ref_run.steps_run, 6);
    let reference = std::fs::read(ref_out.join("step-6.ckpt")).unwrap();

    let out = tmp_out("trunc");
    let cfg = dist_cfg(out.clone(), 6, 2);
    let dir = cfg.out_dir.clone();
    let coord = std::thread::spawn(move || coordinator::run(&cfg));
    let (addr, nonce) = wait_addr(&dir);

    // a fake worker registers first, waits for its shard assignment, then
    // ships only the front half of a gradient-chunk frame and vanishes
    let (mut sock, reply) = raw_register(&addr, "liar");
    assert!(matches!(reply, Msg::RegisterAck { .. }), "got {}", reply.name());
    let mut real = worker_opts(&addr, "honest");
    real.expect_nonce = nonce;
    let work = std::thread::spawn(move || worker::run(&real));

    loop {
        match wire::read_msg(&mut sock) {
            Ok(Msg::StepBegin { .. }) => break,
            Ok(_) => continue,
            Err(e) => panic!("fake worker lost the coordinator early: {e:?}"),
        }
    }
    let mut frame = Vec::new();
    wire::write_msg(
        &mut frame,
        &Msg::ShardGradChunk {
            step: 0,
            shard: 0,
            seq: 0,
            total: 4,
            codec: 0,
            elems: 8,
            loss: 1.0,
            data: vec![0u8; 32],
        },
    )
    .unwrap();
    use std::io::Write;
    sock.write_all(&frame[..frame.len() / 2]).unwrap();
    drop(sock);

    let run = coord.join().unwrap().expect("coordinator failed after truncated stream");
    assert_eq!(run.steps_run, 6);
    assert!(run.deaths >= 1, "the truncating worker was never declared dead");
    work.join().unwrap().expect("surviving worker failed");
    let bytes = std::fs::read(out.join("step-6.ckpt")).unwrap();
    assert_eq!(bytes, reference, "recovery after a truncated chunk stream diverged");
}

/// The same worker-count determinism contract for the optimizer zoo's
/// stateful entries: nora and normuon carry per-row second-moment
/// buffers and a step counter on top of the momentum, so their state
/// must shard, reduce, and checkpoint bit-exactly too.
#[test]
fn zoo_optimizers_are_bit_exact_across_worker_counts() {
    for optimizer in ["nora", "normuon"] {
        let mut finals = Vec::new();
        for workers in [1usize, 2] {
            let out = tmp_out(&format!("zoo-{optimizer}-{workers}"));
            let mut cfg = dist_cfg(out.clone(), 6, workers);
            cfg.optimizer = optimizer.into();
            let (run, results) = run_dist(cfg, workers);
            assert_eq!(run.steps_run, 6);
            assert_eq!(run.deaths, 0, "{optimizer}/{workers}: run saw deaths");
            let shards_done: usize = results.iter().map(|r| r.shards_done).sum();
            assert_eq!(shards_done, 2 * 6);
            finals.push(std::fs::read(out.join("step-6.ckpt")).unwrap());
        }
        assert_eq!(
            finals[0], finals[1],
            "{optimizer}: 1-worker and 2-worker runs diverged"
        );
    }
}

/// Coordinator restart: finish a 6-step run, then resume the same
/// directory to 12 steps with a fresh worker fleet. The result must be
/// byte-identical to an uninterrupted 12-step run, and `steps_run` on
/// the resumed leg proves it continued rather than restarting.
#[test]
fn coordinator_restart_resumes_bit_exact() {
    let ref_out = tmp_out("resume-ref");
    let (ref_run, _) = run_dist(dist_cfg(ref_out.clone(), 12, 1), 1);
    assert_eq!(ref_run.steps_run, 12);
    let reference = std::fs::read(ref_out.join("step-12.ckpt")).unwrap();

    let out = tmp_out("resume-cont");
    let (first, _) = run_dist(dist_cfg(out.clone(), 6, 1), 1);
    assert_eq!(first.steps_run, 6);
    let mut cont = dist_cfg(out.clone(), 12, 1);
    cont.resume = true;
    let (second, _) = run_dist(cont, 1);
    assert_eq!(second.steps_run, 6, "resume should run only the remaining steps");
    let resumed = std::fs::read(out.join("step-12.ckpt")).unwrap();
    assert_eq!(resumed, reference, "resumed run diverged from the uninterrupted one");
}

/// A worker that shows up after training started is refused with a
/// clean `RegisterNack` — mid-epoch joins would silently skew the
/// barrier math, so they are rejected, not absorbed.
#[test]
fn late_join_is_rejected_cleanly() {
    let out = tmp_out("late-join");
    let cfg = dist_cfg(out.clone(), 40, 1);
    let dir = cfg.out_dir.clone();
    let coord = std::thread::spawn(move || coordinator::run(&cfg));
    let (addr, _) = wait_addr(&dir);
    let opts = worker_opts(&addr, "w0");
    let work = std::thread::spawn(move || worker::run(&opts));

    // wait until training provably started (first durable checkpoint),
    // then try to join mid-run
    let deadline = Instant::now() + Duration::from_secs(60);
    while !dir.join("step-3.ckpt").exists() {
        assert!(Instant::now() < deadline, "no checkpoint appeared within 60s");
        std::thread::sleep(Duration::from_millis(2));
    }
    let (_late, reply) = raw_register(&addr, "latecomer");
    match reply {
        Msg::RegisterNack { reason } => {
            assert!(reason.contains("in progress"), "unexpected nack reason: {reason}")
        }
        other => panic!("late join got {} instead of a RegisterNack", other.name()),
    }

    let run = coord.join().unwrap().expect("coordinator failed");
    assert_eq!(run.steps_run, 40);
    assert_eq!(run.deaths, 0, "the rejected latecomer must not count as a death");
    work.join().unwrap().expect("worker failed");
}

/// Registering the same worker id twice while the first holder is alive
/// is refused; with the roster then stuck below `dist.workers`, the
/// coordinator gives up at the join deadline instead of hanging.
#[test]
fn duplicate_worker_id_is_refused() {
    let out = tmp_out("dup-id");
    let mut cfg = dist_cfg(out.clone(), 6, 2);
    cfg.dist_join_timeout_ms = 1_500;
    let dir = cfg.out_dir.clone();
    let coord = std::thread::spawn(move || coordinator::run(&cfg));
    let (addr, _) = wait_addr(&dir);

    let (_first, reply) = raw_register(&addr, "dup");
    assert!(
        matches!(reply, Msg::RegisterAck { rank: 0, .. }),
        "first registration should be acked as rank 0, got {}",
        reply.name()
    );
    let (_second, reply) = raw_register(&addr, "dup");
    match reply {
        Msg::RegisterNack { reason } => {
            assert!(reason.contains("already registered"), "unexpected nack reason: {reason}")
        }
        other => panic!("duplicate id got {} instead of a RegisterNack", other.name()),
    }

    // the roster never fills (we hold rank 0 but are not a real worker),
    // so the coordinator must bail at the join deadline, not hang
    let err = coord.join().unwrap().expect_err("coordinator should give up at the join deadline");
    assert!(!err.to_string().is_empty());
}

/// A worker abort report surfaces in the coordinator's error instead of
/// the worker just vanishing: with its only worker aborting, the run
/// fails naming the worker's reason.
#[test]
fn worker_abort_reason_surfaces_in_coordinator_error() {
    let out = tmp_out("abort-report");
    let cfg = dist_cfg(out.clone(), 6, 1);
    let dir = cfg.out_dir.clone();
    let coord = std::thread::spawn(move || coordinator::run(&cfg));
    let (addr, _) = wait_addr(&dir);

    let (mut sock, reply) = raw_register(&addr, "doomed");
    assert!(matches!(reply, Msg::RegisterAck { .. }), "got {}", reply.name());
    wire::write_msg(
        &mut sock,
        &Msg::WorkerAbort { rank: 0, reason: "simulated guard abort".into() },
    )
    .unwrap();

    let err = coord.join().unwrap().expect_err("coordinator should fail with no live workers");
    let text = err.to_string();
    assert!(
        text.contains("simulated guard abort"),
        "coordinator error does not carry the abort reason: {text}"
    );
}
