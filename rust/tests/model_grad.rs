//! Finite-difference oracles for every model-layer backward (attention,
//! gated MLP, SSM scan, conv stem), plus arch-level bit-determinism:
//! each arch's fwd/bwd/step must produce identical bits across
//! `perf.plan_threads` and be reproducible under forced
//! `RMNP_SIMD=scalar`.
//!
//! The FD check perturbs each parameter along random unit directions and
//! compares `(L(w+hD) − L(w−hD)) / 2h` against `⟨∇L, D⟩` from the
//! analytic backward. Directional probes amortize f32 forward noise over
//! the whole parameter (elementwise FD at f32 precision would drown small
//! entries); a wrong backward formula shows up as an O(1) relative error,
//! far outside the tolerance. Tests flip or depend on the process-global
//! SIMD mode, so each holds the shared mode lock.

use std::sync::{Mutex, MutexGuard};

use rmnp::config::DataSpec;
use rmnp::data::corpus::token_source;
use rmnp::data::images::ImageSource;
use rmnp::model::{
    attention::AttentionArch, conv::ConvArch, gated_mlp::GatedMlpArch, model_spec,
    ssm::SsmArch, ArchKind, Batch, BatchShape, ModelArch, ModelSpec, ParamInit,
};
use rmnp::optim::plan::{OptKind, ParamTask, StepPlan};
use rmnp::runtime::{NativeBackend, TrainBackend, TrainState};
use rmnp::tensor::simd::{self, SimdMode};
use rmnp::tensor::Matrix;
use rmnp::util::Rng;

static MODE_LOCK: Mutex<()> = Mutex::new(());

fn mode_lock() -> MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn build(spec: ModelSpec) -> Box<dyn ModelArch> {
    match spec.arch {
        ArchKind::Attention => Box::new(AttentionArch::new(spec)),
        ArchKind::GatedMlp => Box::new(GatedMlpArch::new(spec)),
        ArchKind::Ssm => Box::new(SsmArch::new(spec)),
        ArchKind::Conv => Box::new(ConvArch::new(spec)),
    }
}

/// Arch + plan + layout→plan index map over a small-batch variant of a
/// registry tag (fewer positions keeps the FD sweep fast).
fn harness(tag: &str, batch: usize, seed: u64) -> (Box<dyn ModelArch>, StepPlan, Vec<usize>) {
    let mut spec = model_spec(tag).unwrap();
    spec.batch = batch;
    let arch = build(spec);
    let defs = arch.params();
    let mut rng = Rng::new(seed);
    let tasks: Vec<ParamTask> = defs
        .iter()
        .map(|d| {
            let w = match d.init {
                ParamInit::Randn(std) => Matrix::randn(d.rows, d.cols, std, &mut rng),
                ParamInit::Const(v) => Matrix::from_vec(d.rows, d.cols, vec![v; d.rows * d.cols]),
            };
            // the optimizer state is irrelevant here: only fwd/bwd run
            ParamTask::new(&d.name, w, OptKind::AdamW)
        })
        .collect();
    let plan = StepPlan::new(tasks, 1);
    let idx: Vec<usize> = defs.iter().map(|d| plan.task_index(&d.name).unwrap()).collect();
    (arch, plan, idx)
}

fn token_batch_for(arch: &dyn ModelArch, seed: u64) -> Vec<i32> {
    let BatchShape::Tokens { rows, cols } = arch.batch_shape() else {
        panic!("expected a token arch");
    };
    let mut t = vec![0i32; rows * cols];
    token_source(DataSpec::Markov, seed, 0).fill(&mut t);
    t
}

fn image_batch_for(arch: &dyn ModelArch, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let BatchShape::Images { batch, hw, pixels } = arch.batch_shape() else {
        panic!("expected an image arch");
    };
    let mut src = ImageSource::new(10, hw, seed, 0);
    let mut images = vec![0.0f32; pixels];
    let mut labels = vec![0i32; batch];
    src.fill(batch, &mut images, &mut labels);
    (images, labels)
}

/// The oracle: every parameter's analytic gradient must match central
/// finite differences along random unit directions.
fn assert_grads_match_fd(tag: &str, batch: &Batch) {
    let (mut arch, plan, idx) = harness(tag, 3.min(model_spec(tag).unwrap().batch), 17);
    // analytic gradients from one fwd/bwd
    let loss0 = plan.with_all_tasks(|tasks| {
        arch.load_batch(tasks, &idx, batch).unwrap();
        let loss = arch.forward(tasks, &idx);
        arch.backward(tasks, &idx);
        loss
    });
    assert!(loss0.is_finite() && loss0 > 0.0, "{tag}: bad loss {loss0}");
    let h = 1e-3f32;
    let names: Vec<String> = arch.params().iter().map(|d| d.name.clone()).collect();
    for (p, name) in names.iter().enumerate() {
        let ti = idx[p];
        let (grad, w0) = plan.with_task(ti, |t| (t.grad.clone(), t.w.clone()));
        for probe in 0..2u64 {
            // random unit direction over the whole parameter
            let mut dir = Matrix::zeros(w0.rows(), w0.cols());
            Rng::new(1000 + 131 * p as u64 + probe).fill_normal(dir.data_mut(), 1.0);
            let norm = dir
                .data()
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt() as f32;
            for x in dir.data_mut() {
                *x /= norm;
            }
            let want: f64 = grad
                .data()
                .iter()
                .zip(dir.data())
                .map(|(&g, &d)| g as f64 * d as f64)
                .sum();
            let mut losses = [0.0f64; 2];
            for (li, sign) in [1.0f32, -1.0].into_iter().enumerate() {
                plan.with_task(ti, |t| {
                    for (w, (&o, &d)) in t
                        .w
                        .data_mut()
                        .iter_mut()
                        .zip(w0.data().iter().zip(dir.data()))
                    {
                        *w = o + sign * h * d;
                    }
                });
                losses[li] = plan.with_all_tasks(|tasks| {
                    arch.load_batch(tasks, &idx, batch).unwrap();
                    arch.forward(tasks, &idx)
                });
            }
            plan.with_task(ti, |t| t.w.copy_from(&w0));
            let fd = (losses[0] - losses[1]) / (2.0 * h as f64);
            let err = (fd - want).abs();
            assert!(
                err < 0.05 * want.abs() + 2e-3,
                "{tag}/{name} probe {probe}: fd {fd} vs analytic {want} (err {err})"
            );
        }
    }
}

#[test]
fn attention_backward_matches_finite_differences() {
    let _guard = mode_lock();
    let (arch, ..) = harness("gpt2_tiny", 3, 1);
    let toks = token_batch_for(arch.as_ref(), 5);
    assert_grads_match_fd("gpt2_tiny", &Batch::Tokens(&toks));
}

#[test]
fn gated_mlp_backward_matches_finite_differences() {
    let _guard = mode_lock();
    let (arch, ..) = harness("llama_s60", 3, 1);
    let toks = token_batch_for(arch.as_ref(), 6);
    assert_grads_match_fd("llama_s60", &Batch::Tokens(&toks));
}

#[test]
fn ssm_backward_matches_finite_differences() {
    let _guard = mode_lock();
    let (arch, ..) = harness("ssm_base", 3, 1);
    let toks = token_batch_for(arch.as_ref(), 7);
    assert_grads_match_fd("ssm_base", &Batch::Tokens(&toks));
}

#[test]
fn conv_backward_matches_finite_differences() {
    let _guard = mode_lock();
    let (arch, ..) = harness("vision_base", 3, 1);
    let (images, labels) = image_batch_for(arch.as_ref(), 8);
    assert_grads_match_fd("vision_base", &Batch::Images { images: &images, labels: &labels });
}

/// Run 3 full native steps (fwd/bwd/clip/step) on one arch and export.
fn run_steps(tag: &str, data: DataSpec, plan_threads: usize) -> TrainState {
    let mut b = NativeBackend::new(tag, "rmnp", 23, plan_threads).unwrap();
    for step in 0..3u64 {
        match b.batch_shape() {
            BatchShape::Tokens { rows, cols } => {
                let mut toks = vec![0i32; rows * cols];
                token_source(data, 400 + step, 0).fill(&mut toks);
                b.step(&Batch::Tokens(&toks), 4e-3).unwrap();
            }
            BatchShape::Images { batch, hw, pixels } => {
                let mut src = ImageSource::new(10, hw, 400 + step, 0);
                let mut images = vec![0.0f32; pixels];
                let mut labels = vec![0i32; batch];
                src.fill(batch, &mut images, &mut labels);
                b.step(&Batch::Images { images: &images, labels: &labels }, 4e-3).unwrap();
            }
        }
    }
    b.export_state().unwrap()
}

const ARCH_CASES: &[(&str, DataSpec)] = &[
    ("gpt2_tiny", DataSpec::Markov),
    ("llama_s60", DataSpec::Zipf),
    ("ssm_base", DataSpec::Ngram),
    ("vision_base", DataSpec::Images),
];

#[test]
fn every_arch_is_bit_deterministic_across_plan_threads() {
    let _guard = mode_lock();
    for &(tag, data) in ARCH_CASES {
        let a = run_steps(tag, data, 1);
        let b = run_steps(tag, data, 4);
        assert_eq!(a, b, "{tag}: plan_threads changed the trained bits");
    }
}

#[test]
fn every_arch_is_bit_deterministic_under_forced_scalar() {
    let _guard = mode_lock();
    let prev = simd::mode();
    simd::set_mode(SimdMode::Scalar);
    assert_eq!(simd::active(), simd::SimdPath::Scalar);
    for &(tag, data) in ARCH_CASES {
        let a = run_steps(tag, data, 1);
        let b = run_steps(tag, data, 4);
        assert_eq!(a, b, "{tag}: scalar-rung run not reproducible");
    }
    simd::set_mode(prev);
}
