//! The forced-AVX-512 suite: what `RMNP_SIMD=avx512` must mean on every
//! host.
//!
//! On an AVX-512F x86-64 this is the f32x16 twin of the forced-scalar CI
//! job: force the rung, verify the ladder resolved to it, and run the
//! op-level parity suite against the seed scalar baselines. On any other
//! host (including AVX2-only x86-64) the suite is **cleanly skipped, not
//! silently passed**: each test prints a visible `SKIP(avx512)` line to
//! stderr and then pins the documented fallback contract — forcing a
//! rung the CPU cannot run resolves to the scalar tiles, never to a
//! *different* vector rung (not even AVX2, which every AVX-512 CPU also
//! has) — so a plain runner still asserts something real about the
//! ladder.
//!
//! Tests here flip the process-global dispatch mode, so every test holds
//! the shared mode lock.

use std::sync::{Mutex, MutexGuard};

use rmnp::optim::{newton_schulz5_into, newton_schulz5_naive, ROW_EPS};
use rmnp::tensor::simd::{self, SimdMode, SimdPath};
use rmnp::tensor::{Matrix, Workspace};
use rmnp::util::Rng;

static MODE_LOCK: Mutex<()> = Mutex::new(());

fn mode_lock() -> MutexGuard<'static, ()> {
    // a failed test poisons the lock; the () state cannot be corrupted
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Force the AVX-512 rung for the duration of `f` (restoring the
/// previous mode), running `f` only when the host can actually execute
/// it. On hosts without AVX-512F, print the skip marker and assert the
/// fallback contract instead.
fn with_forced_avx512(test: &str, f: impl FnOnce()) {
    let _guard = mode_lock();
    let prev = simd::mode();
    simd::set_mode(SimdMode::Avx512);
    if simd::avx512_available() {
        assert_eq!(
            simd::active(),
            SimdPath::Avx512,
            "avx512f detected but the ladder did not resolve to it"
        );
        f();
    } else {
        eprintln!(
            "SKIP(avx512): {test}: no AVX-512F on this host ({})",
            std::env::consts::ARCH
        );
        // the fallback contract: forced-but-unavailable rungs land on
        // scalar, never on another vector rung
        assert_eq!(simd::active(), SimdPath::Scalar);
    }
    simd::set_mode(prev);
}

fn max_abs_diff(a: &Matrix, b: &Matrix) -> f32 {
    a.data()
        .iter()
        .zip(b.data())
        .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()))
}

/// Rect/tall/wide shapes, including one past the packed-A threshold with
/// a remainder-row tail (and widths that leave an f32x16 remainder).
const SHAPES: &[(usize, usize)] = &[(7, 13), (96, 24), (24, 96), (130, 66)];

#[test]
fn forced_avx512_matmul_and_gram_match_naive() {
    with_forced_avx512("matmul/gram parity", || {
        let mut rng = Rng::new(1);
        for &(m, k) in SHAPES {
            let n = (k / 2).max(1) + 3;
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let d = max_abs_diff(&a.matmul(&b), &a.matmul_naive(&b));
            assert!(d < 1e-4, "matmul ({m},{k},{n}): {d}");
            let d = max_abs_diff(&a.gram(), &a.gram_naive());
            assert!(d < 1e-4, "gram ({m},{k}): {d}");
        }
    });
}

#[test]
fn forced_avx512_rownorm_matches_naive_including_zero_rows() {
    with_forced_avx512("rownorm parity", || {
        let mut rng = Rng::new(2);
        for &(m, n) in SHAPES {
            let mut v = Matrix::randn(m, n, 2.0, &mut rng);
            let mid = m / 2;
            for x in v.data_mut()[mid * n..(mid + 1) * n].iter_mut() {
                *x = 0.0; // zero row: eps-floor semantics must agree
            }
            let d = max_abs_diff(&v.row_normalize(ROW_EPS), &v.row_normalize_naive(ROW_EPS));
            assert!(d < 1e-4, "rownorm ({m},{n}): {d}");
        }
    });
}

#[test]
fn forced_avx512_ns5_matches_naive() {
    with_forced_avx512("ns5 parity", || {
        let mut rng = Rng::new(3);
        let mut ws = Workspace::new();
        for &(m, n) in &[(12usize, 40usize), (40, 12), (16, 16)] {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            let naive = newton_schulz5_naive(&g, 5);
            let mut fast = Matrix::zeros(m, n);
            newton_schulz5_into(&g, 5, &mut ws, &mut fast);
            let d = max_abs_diff(&fast, &naive);
            assert!(d < 1e-4, "ns5 ({m},{n}): {d}");
        }
    });
}

#[test]
fn forced_avx512_model_sweeps_match_reference() {
    // the model-layer kernels (row softmax ± mask, RMSNorm) on the
    // AVX-512 rung against f64 references
    with_forced_avx512("row_softmax/rmsnorm parity", || {
        let mut rng = Rng::new(5);
        for (rows, cols) in [(6usize, 16usize), (9, 33), (8, 96)] {
            let mut src = vec![0.0f32; rows * cols];
            rng.fill_normal(&mut src, 1.0);
            for x in src[cols / 2..cols].iter_mut() {
                *x = f32::NEG_INFINITY; // mask part of row 0
            }
            let mut gain = vec![0.0f32; cols];
            rng.fill_normal(&mut gain, 0.2);
            for g in gain.iter_mut() {
                *g += 1.0;
            }
            let mut sm = vec![0.0f32; rows * cols];
            rmnp::tensor::kernels::row_softmax_into(&mut sm, &src, rows, cols);
            let mut rn = vec![0.0f32; rows * cols];
            let mut positive = vec![0.0f32; rows * cols];
            rng.fill_normal(&mut positive, 1.0);
            rmnp::tensor::kernels::rmsnorm_into(&mut rn, &positive, &gain, rows, cols, 1e-6);
            for i in 0..rows {
                // softmax rows sum to 1
                let s: f64 = sm[i * cols..(i + 1) * cols].iter().map(|&x| x as f64).sum();
                assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
                // rmsnorm matches the f64 formula
                let ss: f64 = positive[i * cols..(i + 1) * cols]
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum();
                let r = 1.0 / (ss / cols as f64 + 1e-6).sqrt();
                for j in 0..cols {
                    let want = gain[j] as f64 * positive[i * cols + j] as f64 * r;
                    assert!(
                        (rn[i * cols + j] as f64 - want).abs() < 1e-4,
                        "rmsnorm ({rows},{cols}) at ({i},{j})"
                    );
                }
            }
            for &p in &sm[cols / 2..cols] {
                assert_eq!(p, 0.0, "masked prob must be exactly 0");
            }
        }
    });
}

#[test]
fn forced_avx512_bf16_sweeps_match_scalar_bits() {
    // the bf16 storage kernels pin their accumulation order, so the
    // forced-AVX-512 instantiation must be *bit-identical* to scalar —
    // not merely within tolerance
    let _guard = mode_lock();
    let prev = simd::mode();
    let mut rng = Rng::new(6);
    for &(m, n) in SHAPES {
        let len = m * n;
        let mut x0 = vec![0.0f32; len];
        rng.fill_normal(&mut x0, 0.5);
        let mut y = vec![0.0f32; len];
        rng.fill_normal(&mut y, 1.0);
        let mut bits0 = vec![0u16; len];
        simd::bf16_pack(&x0, &mut bits0);
        let run = |mode: SimdMode| {
            simd::set_mode(mode);
            let mut bits = bits0.clone();
            rmnp::tensor::kernels::bf16_axpby_inplace(&mut bits, 0.95, &y, 0.05);
            let sq = rmnp::tensor::kernels::bf16_row_sumsq(&bits);
            let mut w = bits0.clone();
            rmnp::tensor::kernels::bf16_axpby_from_bf16(&mut w, 0.9, &bits, -0.02);
            (bits, sq.to_bits(), w)
        };
        let scalar = run(SimdMode::Scalar);
        let forced = run(SimdMode::Avx512); // avx512 or the scalar fallback
        assert_eq!(scalar, forced, "bf16 sweeps diverged at ({m},{n})");
    }
    simd::set_mode(prev);
}

#[test]
fn forced_avx512_thread_count_does_not_change_bits() {
    with_forced_avx512("thread-count determinism", || {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(130, 90, 1.0, &mut rng);
        let b = Matrix::randn(90, 110, 1.0, &mut rng);
        rmnp::tensor::kernels::set_num_threads(1);
        let serial = a.matmul(&b);
        rmnp::tensor::kernels::set_num_threads(4);
        let par = a.matmul(&b);
        rmnp::tensor::kernels::set_num_threads(0);
        assert_eq!(serial, par);
    });
}

#[test]
fn forcing_avx512_never_lands_on_another_vector_rung() {
    // runs meaningfully on every host: forced avx512 is avx512 where it
    // exists and scalar everywhere else — never avx2 or neon, even
    // though every AVX-512F CPU also has AVX2
    let _guard = mode_lock();
    let prev = simd::mode();
    simd::set_mode(SimdMode::Avx512);
    let path = simd::active();
    assert!(
        path == SimdPath::Avx512 || path == SimdPath::Scalar,
        "forced avx512 resolved to {path:?}"
    );
    assert_eq!(path == SimdPath::Avx512, simd::avx512_available());
    simd::set_mode(prev);
}
