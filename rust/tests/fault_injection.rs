//! Fault-injection suite (default features, no artifacts).
//!
//! Two layers of attack on the fault-tolerance contract:
//!
//! 1. **Corruption fuzz** (in-process): every single-byte flip and every
//!    truncation of a v3 checkpoint must be *rejected* by `load_state` —
//!    an error, never a panic, never a silently-wrong state.
//! 2. **Process-level scenarios** (child `rmnp` binaries via
//!    `CARGO_BIN_EXE_rmnp`, reusing the `exp::faults` harness): SIGKILL
//!    mid-train, truncated/bit-flipped newest checkpoint, NaN-gradient
//!    bursts, sustained-anomaly aborts, guard state riding checkpoints
//!    across a resume, and the distributed pair (worker SIGKILL →
//!    redistribution, coordinator SIGKILL → clean worker exits + resumed
//!    restart). Every scenario must end in byte-exact resumed training
//!    or a clean error.
//!
//! Plus the format-compat leg: a v2 (pre-CRC) checkpoint still resumes a
//! run end-to-end, bit-exactly.

use std::path::{Path, PathBuf};

use rmnp::config::{DataSpec, RunConfig, Schedule};
use rmnp::coordinator::{checkpoint, train};
use rmnp::exp::faults::{self, Corruption, FaultOpts};
use rmnp::runtime::{NamedBuffer, TrainState};

fn tmp_out(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "rmnp-fault-{}-{name}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn rmnp_bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_rmnp"))
}

fn suite_opts(name: &str) -> FaultOpts {
    FaultOpts {
        out: tmp_out(name),
        steps: 8,
        checkpoint_every: 4,
        kills: 1,
        seed: 77,
        compress: "none".into(),
    }
}

/// Every single-byte flip and every truncation of a v3 checkpoint is
/// rejected — the CRC coverage has no blind spots, and nothing panics.
#[test]
fn corruption_fuzz_rejects_every_byte_flip_and_truncation() {
    let buf = |name: &str, vals: &[f32]| NamedBuffer {
        name: name.into(),
        data: vals.to_vec(),
    };
    let state = TrainState {
        step: 7,
        params: vec![buf("w", &[0.5, -1.25, 3.0]), buf("b", &[0.0])],
        opt: vec![buf("w.m", &[0.1, 0.2, 0.3]), buf("b.m", &[9.0])],
    };
    let dir = tmp_out("fuzz");
    std::fs::create_dir_all(&dir).unwrap();
    let clean = dir.join("step-7.ckpt");
    checkpoint::save_state(&clean, &state).unwrap();
    let original = std::fs::read(&clean).unwrap();
    let victim = dir.join("victim.ckpt");

    for at in 0..original.len() {
        let mut bytes = original.clone();
        bytes[at] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        assert!(
            checkpoint::load_state(&victim).is_err(),
            "flipped byte at offset {at} was not detected"
        );
    }
    for keep in 0..original.len() {
        std::fs::write(&victim, &original[..keep]).unwrap();
        assert!(
            checkpoint::load_state(&victim).is_err(),
            "truncation to {keep}/{} bytes was not detected",
            original.len()
        );
    }
    // and the untouched file still loads exactly
    let back = checkpoint::load_state(&clean).unwrap();
    assert_eq!(back.step, 7);
    assert_eq!(back.params.len(), 2);
    assert_eq!(back.params[0].data, vec![0.5, -1.25, 3.0]);
    assert_eq!(back.opt[1].data, vec![9.0]);
}

/// SIGKILL a real child `rmnp train` mid-run: the resume must finish
/// byte-exactly against an uninterrupted reference, without a silent
/// restart from scratch.
#[test]
fn sigkill_mid_train_resumes_byte_exact() {
    let opts = suite_opts("sigkill");
    let reference = faults::reference_bytes(rmnp_bin(), &opts).unwrap();
    let s = faults::sigkill_mid_train(rmnp_bin(), &opts, &reference, 0).unwrap();
    assert!(s.passed, "{}: {}", s.name, s.detail);
}

/// Corrupt the newest checkpoint of a finished run (torn write and bit
/// rot): resume must walk back to the previous valid checkpoint and
/// still reproduce the reference bytes.
#[test]
fn corrupted_latest_checkpoint_walks_back_byte_exact() {
    let opts = suite_opts("corrupt");
    let reference = faults::reference_bytes(rmnp_bin(), &opts).unwrap();
    for kind in [Corruption::Truncate, Corruption::BitFlip] {
        let s = faults::corrupted_latest(rmnp_bin(), &opts, &reference, kind).unwrap();
        assert!(s.passed, "{}: {}", s.name, s.detail);
    }
}

/// A NaN-gradient burst (injected via the env hook in a child process)
/// is skipped by the guard, the LR backs off and recovers, and the run
/// still completes with a finite loss.
#[test]
fn nan_burst_is_skipped_and_recovers() {
    let opts = suite_opts("nan");
    let s = faults::nan_burst(rmnp_bin(), &opts).unwrap();
    assert!(s.passed, "{}: {}", s.name, s.detail);
}

/// Sustained anomalies beyond `train.guard_max_bad` abort cleanly: a
/// nonzero exit that names the anomaly, recorded in summary.jsonl, and
/// no panic anywhere.
#[test]
fn sustained_anomalies_abort_cleanly() {
    let opts = suite_opts("abort");
    let s = faults::guard_abort(rmnp_bin(), &opts).unwrap();
    assert!(s.passed, "{}: {}", s.name, s.detail);
}

/// A NaN burst split across a checkpoint boundary: the guard's LR scale
/// and abort streak must be persisted in the checkpoint and restored on
/// resume — a resumed burst aborts at the combined streak, and a healthy
/// resume recovers the scale by doublings.
#[test]
fn guard_state_rides_checkpoints_across_resume() {
    let opts = suite_opts("backoff");
    let s = faults::resume_mid_backoff(rmnp_bin(), &opts).unwrap();
    assert!(s.passed, "{}: {}", s.name, s.detail);
}

/// SIGKILL one of two distributed workers mid-run: the coordinator must
/// redistribute the dead rank's shard and finish byte-exact against an
/// uninterrupted 1-worker distributed reference.
#[test]
fn dist_worker_kill_redistributes_byte_exact() {
    let opts = suite_opts("dist-wk");
    let reference = faults::dist_reference_bytes(rmnp_bin(), &opts).unwrap();
    let s = faults::dist_worker_kill(rmnp_bin(), &opts, &reference).unwrap();
    assert!(s.passed, "{}: {}", s.name, s.detail);
}

/// SIGKILL the distributed coordinator mid-run: workers exit cleanly
/// naming the coordinator, and a restarted `--resume` coordinator with a
/// fresh worker fleet finishes byte-exact from the newest validated
/// checkpoint.
#[test]
fn dist_coordinator_kill_workers_exit_cleanly_and_resume_works() {
    let opts = suite_opts("dist-ck");
    let reference = faults::dist_reference_bytes(rmnp_bin(), &opts).unwrap();
    let s = faults::dist_coordinator_kill(rmnp_bin(), &opts, &reference).unwrap();
    assert!(s.passed, "{}: {}", s.name, s.detail);
}

/// Format compat: a v2 (pre-CRC) checkpoint written by an older build
/// still resumes a run end-to-end, and the continued trajectory matches
/// an uninterrupted v3 run byte-for-byte.
#[test]
fn v2_checkpoint_resumes_end_to_end_bit_exact() {
    let cfg = |steps: usize, name: &str| RunConfig {
        model: "gpt2_tiny".into(),
        optimizer: "rmnp".into(),
        lr: 4e-3,
        schedule: Schedule::CosineWarmup { warmup_frac: 0.1, min_ratio: 0.1 },
        steps,
        seed: 23,
        data: DataSpec::Markov,
        eval_every: 0,
        checkpoint_every: 4,
        out_dir: tmp_out(name),
        ..RunConfig::default()
    };
    // uninterrupted 8-step reference
    let full = cfg(8, "v2-full");
    train::run_auto(&full).unwrap();
    let full_end = std::fs::read(full.out_dir.join("step-8.ckpt")).unwrap();

    // downgrade its step-4 checkpoint to the v2 format in a fresh dir,
    // then resume from it
    let state = checkpoint::load_state(&full.out_dir.join("step-4.ckpt")).unwrap();
    let mut cont = cfg(8, "v2-cont");
    cont.resume = true;
    std::fs::create_dir_all(&cont.out_dir).unwrap();
    checkpoint::save_state_v2(&cont.out_dir.join("step-4.ckpt"), &state).unwrap();
    train::run_auto(&cont).unwrap();
    let resumed_end = std::fs::read(cont.out_dir.join("step-8.ckpt")).unwrap();
    assert_eq!(full_end, resumed_end, "resume from a v2 checkpoint diverged");
}
