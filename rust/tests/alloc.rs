//! Counting-allocator proof of the allocation-free optimizer hot path:
//! after warmup, `RmnpState::step`, (with a warm workspace)
//! `MuonState::step`, and every other native registry optimizer perform
//! zero heap allocations per call.
//!
//! This file intentionally contains a single test: the counting allocator
//! is process-global, so concurrent tests would pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use rmnp::config::DataSpec;
use rmnp::data::corpus::token_source;
use rmnp::model::{attention::AttentionArch, model_spec, ssm::SsmArch, Batch, ModelArch, ParamInit};
use rmnp::optim::plan::{OptKind, OptState, ParamTask, StepPlan};
use rmnp::optim::registry::{MatrixOptimizer, REGISTRY};
use rmnp::optim::{MuonState, RmnpState};
use rmnp::tensor::{Bf16Matrix, Matrix, Precision};
use rmnp::util::Rng;

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

#[test]
fn optimizer_steps_are_allocation_free_after_warmup() {
    // single-threaded kernels: spawning scoped threads allocates, which is
    // thread machinery, not per-element work — the zero-alloc contract is
    // for the compute path
    rmnp::tensor::kernels::set_num_threads(1);
    let mut rng = Rng::new(7);

    // --- RMNP: fused step never allocates, even on the first call ---
    let g = Matrix::randn(96, 64, 1.0, &mut rng);
    let mut w = Matrix::randn(96, 64, 0.1, &mut rng);
    let mut st = RmnpState::new(96, 64);
    st.step(&mut w, &g, 1e-3); // warmup (cache warming only)
    let before = allocs();
    for _ in 0..10 {
        st.step(&mut w, &g, 1e-3);
    }
    assert_eq!(
        allocs(),
        before,
        "RmnpState::step must be allocation-free per call"
    );

    // --- Muon: NS5 intermediates come from the state's workspace, so the
    // steady state after the first (warmup) call is allocation-free ---
    let g = Matrix::randn(48, 96, 1.0, &mut rng);
    let mut w = Matrix::randn(48, 96, 0.1, &mut rng);
    let mut st = MuonState::new(48, 96);
    st.step(&mut w, &g, 1e-3); // warmup: fills the workspace pool
    let before = allocs();
    for _ in 0..5 {
        st.step(&mut w, &g, 1e-3);
    }
    assert_eq!(
        allocs(),
        before,
        "warm MuonState::step must be allocation-free per call"
    );
    // d + x + gram + poly + prod: the fused bA + cA² polynomial dropped
    // the A² buffer that used to make this 6
    assert_eq!(st.workspace.fresh_allocs(), 5, "one alloc per NS5 buffer");

    // --- optimizer zoo: the same contract for every native registry
    // entry, through the `OptState` dispatch the StepPlan uses. The
    // row-normalized family (rmnp, nora) is fused and never allocates;
    // the NS family (muon, normuon, turbo_muon, muown) draws its
    // intermediates from the state's workspace, filled by the first
    // (warmup) step. ---
    for (name, kind) in REGISTRY.iter().filter_map(|s| s.native.map(|k| (s.name, k))) {
        let g = Matrix::randn(40, 56, 1.0, &mut rng);
        let mut w = Matrix::randn(40, 56, 0.1, &mut rng);
        let mut st = OptState::new(kind, 40, 56);
        st.step(&mut w, &g, 1e-3); // warmup: fills any workspace pool
        let before = allocs();
        for _ in 0..5 {
            st.step(&mut w, &g, 1e-3);
        }
        assert_eq!(
            allocs(),
            before,
            "warm {name} step must be allocation-free per call"
        );
    }

    // --- bf16 storage mode: the same zoo contract. The fused bf16
    // sweeps work on the u16 buffers in place, and the NS family widens
    // into scratch owned by the state (allocated at construction or on
    // the warmup step), so a warm `step_bf16` may not touch the heap
    // either. ---
    for (name, kind) in REGISTRY.iter().filter_map(|s| s.native.map(|k| (s.name, k))) {
        let g = Matrix::randn(40, 56, 1.0, &mut rng);
        let w0 = Matrix::randn(40, 56, 0.1, &mut rng);
        let mut w = Bf16Matrix::from_matrix(&w0);
        let mut st = OptState::new_with(kind, 40, 56, Precision::Bf16);
        st.step_bf16(&mut w, &g, 1e-3); // warmup: fills any workspace pool
        let before = allocs();
        for _ in 0..5 {
            st.step_bf16(&mut w, &g, 1e-3);
        }
        assert_eq!(
            allocs(),
            before,
            "warm {name} step_bf16 must be allocation-free per call"
        );
    }

    // --- model layer: warm fwd/bwd is allocation-free, including the
    // new row-softmax/RMSNorm sweeps (attention) and the scan buffers
    // (ssm). The arch preallocates activations at construction and draws
    // transposes from its workspace, so after one warm pass nothing on
    // the forward/backward path may touch the heap. ---
    for tag in ["gpt2_tiny", "ssm_base"] {
        let mut spec = model_spec(tag).unwrap();
        spec.batch = 2;
        let mut arch: Box<dyn ModelArch> = if tag == "gpt2_tiny" {
            Box::new(AttentionArch::new(spec))
        } else {
            Box::new(SsmArch::new(spec))
        };
        let defs = arch.params();
        let mut prng = Rng::new(3);
        let tasks: Vec<ParamTask> = defs
            .iter()
            .map(|d| {
                let w = match d.init {
                    ParamInit::Randn(std) => Matrix::randn(d.rows, d.cols, std, &mut prng),
                    ParamInit::Const(v) => {
                        Matrix::from_vec(d.rows, d.cols, vec![v; d.rows * d.cols])
                    }
                };
                ParamTask::new(&d.name, w, OptKind::Rmnp)
            })
            .collect();
        let plan = StepPlan::new(tasks, 1);
        let idx: Vec<usize> =
            defs.iter().map(|d| plan.task_index(&d.name).unwrap()).collect();
        let rows_cols = match arch.batch_shape() {
            rmnp::model::BatchShape::Tokens { rows, cols } => rows * cols,
            _ => unreachable!("both alloc-test archs are token archs"),
        };
        let mut toks = vec![0i32; rows_cols];
        token_source(DataSpec::Markov, 9, 0).fill(&mut toks);
        let batch = Batch::Tokens(&toks);
        plan.with_all_tasks(|tasks| {
            for _ in 0..2 {
                // warmup: fills the arch workspace
                arch.load_batch(tasks, &idx, &batch).unwrap();
                arch.forward(tasks, &idx);
                arch.backward(tasks, &idx);
            }
            let before = allocs();
            for _ in 0..5 {
                arch.load_batch(tasks, &idx, &batch).unwrap();
                arch.forward(tasks, &idx);
                arch.backward(tasks, &idx);
            }
            assert_eq!(
                allocs(),
                before,
                "{tag}: warm model fwd/bwd must be allocation-free"
            );
        });
    }

    // --- dist streaming path: after one warmup frame, chunk encode →
    // frame write → chunk decode is allocation-free in both codec modes.
    // The worker pre-sizes its chunk buffer from the parameter layout
    // and `write_msg` stages frames in a thread-local scratch, so warm
    // steps never touch the heap for wire traffic. ---
    {
        use rmnp::dist::compress::{Compression, GradCodec};
        use rmnp::dist::wire::{self, Msg};
        let mut grad = vec![0.0f32; 4096];
        rng.fill_normal(&mut grad, 1.0);
        for mode in [Compression::None, Compression::Bf16] {
            let mut codec = GradCodec::new(mode);
            codec.reserve(grad.len());
            let mut data: Vec<u8> = Vec::with_capacity(grad.len() * 4);
            let mut sink: Vec<u8> = Vec::with_capacity(grad.len() * 4 + 64);
            let mut flat: Vec<f32> = Vec::with_capacity(grad.len());
            let mut stream = |codec: &mut GradCodec,
                              data: &mut Vec<u8>,
                              sink: &mut Vec<u8>,
                              flat: &mut Vec<f32>| {
                sink.clear();
                flat.clear();
                let mut payload = std::mem::take(data);
                codec.encode_into(&grad, &mut payload);
                let msg = Msg::ShardGradChunk {
                    step: 1,
                    shard: 0,
                    seq: 0,
                    total: 1,
                    codec: mode.id(),
                    elems: grad.len() as u32,
                    loss: 0.5,
                    data: payload,
                };
                wire::write_msg(sink, &msg).unwrap();
                if let Msg::ShardGradChunk { data: payload, .. } = msg {
                    *data = payload;
                }
                codec.decode_append(data, grad.len(), flat).unwrap();
            };
            stream(&mut codec, &mut data, &mut sink, &mut flat); // warmup
            let before = allocs();
            for _ in 0..5 {
                stream(&mut codec, &mut data, &mut sink, &mut flat);
            }
            assert_eq!(
                allocs(),
                before,
                "{}: warm chunk encode/frame/decode must be allocation-free",
                mode.name()
            );
        }
    }
}
