//! Counting-allocator proof of the allocation-free optimizer hot path:
//! after warmup, `RmnpState::step` and (with a warm workspace)
//! `MuonState::step` perform zero heap allocations per call.
//!
//! This file intentionally contains a single test: the counting allocator
//! is process-global, so concurrent tests would pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use rmnp::optim::{MuonState, RmnpState};
use rmnp::tensor::Matrix;
use rmnp::util::Rng;

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

#[test]
fn optimizer_steps_are_allocation_free_after_warmup() {
    // single-threaded kernels: spawning scoped threads allocates, which is
    // thread machinery, not per-element work — the zero-alloc contract is
    // for the compute path
    rmnp::tensor::kernels::set_num_threads(1);
    let mut rng = Rng::new(7);

    // --- RMNP: fused step never allocates, even on the first call ---
    let g = Matrix::randn(96, 64, 1.0, &mut rng);
    let mut w = Matrix::randn(96, 64, 0.1, &mut rng);
    let mut st = RmnpState::new(96, 64);
    st.step(&mut w, &g, 1e-3); // warmup (cache warming only)
    let before = allocs();
    for _ in 0..10 {
        st.step(&mut w, &g, 1e-3);
    }
    assert_eq!(
        allocs(),
        before,
        "RmnpState::step must be allocation-free per call"
    );

    // --- Muon: NS5 intermediates come from the state's workspace, so the
    // steady state after the first (warmup) call is allocation-free ---
    let g = Matrix::randn(48, 96, 1.0, &mut rng);
    let mut w = Matrix::randn(48, 96, 0.1, &mut rng);
    let mut st = MuonState::new(48, 96);
    st.step(&mut w, &g, 1e-3); // warmup: fills the workspace pool
    let before = allocs();
    for _ in 0..5 {
        st.step(&mut w, &g, 1e-3);
    }
    assert_eq!(
        allocs(),
        before,
        "warm MuonState::step must be allocation-free per call"
    );
    // d + x + gram + poly + prod: the fused bA + cA² polynomial dropped
    // the A² buffer that used to make this 6
    assert_eq!(st.workspace.fresh_allocs(), 5, "one alloc per NS5 buffer");
}
