//! Cross-layer parity: the SIMD-dispatched kernel layer and the fused
//! optimizer steps must match the seed scalar implementations within 1e-4
//! across rectangular, tall, wide, and zero-row shapes — including at
//! sizes large enough to engage the multi-threaded paths and the
//! packed-A panel fast path, on every rung of the dispatch ladder
//! (forced scalar and, where available, the host's vector rung — AVX2 on
//! x86-64, NEON on aarch64).
//!
//! Tests that flip the process-global SIMD mode or rely on bit-exact
//! reproducibility across calls hold [`mode_lock`] so a concurrent flip
//! can never change the active rung mid-assertion.

use std::sync::{Mutex, MutexGuard};

use rmnp::optim::plan::{tasks_from_shapes, OptKind, StepPlan};
use rmnp::optim::{
    newton_schulz5_into, newton_schulz5_naive, rms_scale, MuonState, RmnpState,
    MATRIX_BETA, ROW_EPS, WEIGHT_DECAY,
};
use rmnp::tensor::simd::{self, SimdMode};
use rmnp::tensor::{kernels, Matrix, Workspace};
use rmnp::util::Rng;

static MODE_LOCK: Mutex<()> = Mutex::new(());

fn mode_lock() -> MutexGuard<'static, ()> {
    // a failed test poisons the lock; the () state cannot be corrupted
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn max_abs_diff(a: &Matrix, b: &Matrix) -> f32 {
    a.data()
        .iter()
        .zip(b.data())
        .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()))
}

/// Shapes covering rectangular, tall, wide, threaded-size, and packed-A
/// (large-m with a remainder-row tail) cases.
const SHAPES: &[(usize, usize)] = &[(7, 13), (96, 24), (24, 96), (160, 161), (258, 64)];

/// The full op-level parity suite against the seed scalar baselines,
/// runnable under any dispatch mode.
fn assert_ops_match_naive(tolerance: f32) {
    let mut rng = Rng::new(1);
    for &(m, k) in SHAPES {
        let n = (k / 2).max(1) + 3;
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let d = max_abs_diff(&a.matmul(&b), &a.matmul_naive(&b));
        assert!(d < tolerance, "matmul ({m},{k},{n}): {d}");
        let d = max_abs_diff(&a.gram(), &a.gram_naive());
        assert!(d < tolerance, "gram ({m},{k}): {d}");
        let mut v = Matrix::randn(m, k, 2.0, &mut rng);
        let mid = m / 2;
        for x in v.data_mut()[mid * k..(mid + 1) * k].iter_mut() {
            *x = 0.0; // zero row: eps-floor semantics must agree
        }
        let d = max_abs_diff(&v.row_normalize(ROW_EPS), &v.row_normalize_naive(ROW_EPS));
        assert!(d < tolerance, "rownorm ({m},{k}): {d}");
    }
}

#[test]
fn parallel_matmul_matches_naive() {
    let mut rng = Rng::new(1);
    for &(m, k) in SHAPES {
        let n = (k / 2).max(1) + 3;
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let d = max_abs_diff(&a.matmul(&b), &a.matmul_naive(&b));
        assert!(d < 1e-4, "matmul ({m},{k},{n}): {d}");
    }
}

#[test]
fn parallel_gram_matches_naive() {
    let mut rng = Rng::new(2);
    for &(m, k) in SHAPES {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let d = max_abs_diff(&a.gram(), &a.gram_naive());
        assert!(d < 1e-4, "gram ({m},{k}): {d}");
    }
}

#[test]
fn row_normalize_matches_naive_including_zero_rows() {
    let mut rng = Rng::new(3);
    for &(m, n) in SHAPES {
        let mut v = Matrix::randn(m, n, 2.0, &mut rng);
        // zero the middle row: eps-floor semantics must agree
        let mid = m / 2;
        for x in v.data_mut()[mid * n..(mid + 1) * n].iter_mut() {
            *x = 0.0;
        }
        let d = max_abs_diff(&v.row_normalize(ROW_EPS), &v.row_normalize_naive(ROW_EPS));
        assert!(d < 1e-4, "rownorm ({m},{n}): {d}");
    }
}

#[test]
fn ns5_kernel_path_matches_naive() {
    let mut rng = Rng::new(4);
    let mut ws = Workspace::new();
    for &(m, n) in &[(12usize, 40usize), (40, 12), (16, 16)] {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        let naive = newton_schulz5_naive(&g, 5);
        let mut fast = Matrix::zeros(m, n);
        newton_schulz5_into(&g, 5, &mut ws, &mut fast);
        let d = max_abs_diff(&fast, &naive);
        assert!(d < 1e-4, "ns5 ({m},{n}): {d}");
    }
}

#[test]
fn fused_rmnp_step_matches_seed_semantics() {
    // independent reimplementation of the seed step (not step_unfused) so
    // a shared bug can't hide
    let mut rng = Rng::new(5);
    for &(m, n) in SHAPES {
        let mut w_fused = Matrix::randn(m, n, 0.3, &mut rng);
        let mut w_seed = w_fused.clone();
        let mut st = RmnpState::new(m, n);
        let mut mom = Matrix::zeros(m, n);
        for _ in 0..3 {
            let mut g = Matrix::randn(m, n, 1.0, &mut rng);
            for x in g.data_mut()[0..n].iter_mut() {
                *x = 0.0; // zero row each step
            }
            st.step(&mut w_fused, &g, 0.01);
            mom = mom.axpby(MATRIX_BETA, &g, 1.0 - MATRIX_BETA);
            let d = mom.row_normalize_naive(ROW_EPS);
            let scale = 0.01 * rms_scale(m, n);
            for (wv, dv) in w_seed.data_mut().iter_mut().zip(d.data()) {
                *wv -= scale * (dv + WEIGHT_DECAY * *wv);
            }
        }
        let dw = max_abs_diff(&w_fused, &w_seed);
        assert!(dw < 1e-4, "rmnp step ({m},{n}): {dw}");
        let dm = max_abs_diff(&st.momentum, &mom);
        assert!(dm < 1e-4, "rmnp momentum ({m},{n})");
    }
}

#[test]
fn fused_muon_step_matches_seed_semantics() {
    let mut rng = Rng::new(6);
    for &(m, n) in &[(10usize, 30usize), (30, 10)] {
        let mut w_ws = Matrix::randn(m, n, 0.3, &mut rng);
        let mut w_seed = w_ws.clone();
        let mut st = MuonState::new(m, n);
        let mut mom = Matrix::zeros(m, n);
        for _ in 0..3 {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            st.step(&mut w_ws, &g, 0.01);
            mom = mom.axpby(MATRIX_BETA, &g, 1.0 - MATRIX_BETA);
            let d = newton_schulz5_naive(&mom, 5);
            let scale = 0.01 * rms_scale(m, n);
            for (wv, dv) in w_seed.data_mut().iter_mut().zip(d.data()) {
                *wv -= scale * (dv + WEIGHT_DECAY * *wv);
            }
        }
        let dw = max_abs_diff(&w_ws, &w_seed);
        assert!(dw < 1e-4, "muon step ({m},{n}): {dw}");
    }
}

#[test]
fn workspace_reuse_never_leaks_between_ops() {
    // run NS5 on matrix A, then on B, then on A again through the same
    // workspace: the second A result must equal the first exactly
    let _guard = mode_lock(); // bit-exactness needs a stable dispatch rung
    let mut rng = Rng::new(7);
    let a = Matrix::randn(14, 22, 1.0, &mut rng);
    let b = Matrix::randn(22, 14, 3.0, &mut rng);
    let mut ws = Workspace::new();
    let mut first = Matrix::zeros(14, 22);
    newton_schulz5_into(&a, 5, &mut ws, &mut first);
    let mut other = Matrix::zeros(22, 14);
    newton_schulz5_into(&b, 5, &mut ws, &mut other);
    let mut again = Matrix::zeros(14, 22);
    newton_schulz5_into(&a, 5, &mut ws, &mut again);
    assert_eq!(first, again, "workspace state leaked between calls");
    // and raw take() after arbitrary scribbling is always zeroed
    let mut buf = ws.take(257);
    rng.fill_normal(&mut buf, 5.0);
    ws.give(buf);
    assert!(ws.take(101).iter().all(|&x| x == 0.0));
}

#[test]
fn thread_count_does_not_change_results() {
    let _guard = mode_lock(); // bit-exactness needs a stable dispatch rung
    let mut rng = Rng::new(8);
    let a = Matrix::randn(130, 90, 1.0, &mut rng);
    let b = Matrix::randn(90, 110, 1.0, &mut rng);
    kernels::set_num_threads(1);
    let serial_mm = a.matmul(&b);
    let serial_gram = a.gram();
    let serial_rn = a.row_normalize(ROW_EPS);
    kernels::set_num_threads(4);
    let par_mm = a.matmul(&b);
    let par_gram = a.gram();
    let par_rn = a.row_normalize(ROW_EPS);
    kernels::set_num_threads(0);
    assert_eq!(serial_mm, par_mm);
    assert_eq!(serial_rn, par_rn);
    // gram too: the triangle boundaries are tile-aligned, so the
    // tile/remainder fold assignment (and the bits) never move with the
    // thread count
    assert_eq!(serial_gram, par_gram);
}

#[test]
fn forced_scalar_dispatch_passes_full_suite() {
    // `perf.simd = "scalar"` must keep every op on the portable rung and
    // every parity bound intact — this is what CI's forced-scalar job
    // checks on AVX2 runners too
    let _guard = mode_lock();
    let prev = simd::mode();
    simd::set_mode(SimdMode::Scalar);
    assert_eq!(simd::active(), simd::SimdPath::Scalar);
    assert_ops_match_naive(1e-4);
    // NS5 through the full scalar stack (fused polynomial included)
    let mut rng = Rng::new(9);
    let mut ws = Workspace::new();
    for &(m, n) in &[(12usize, 40usize), (16, 16)] {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        let naive = newton_schulz5_naive(&g, 5);
        let mut fast = Matrix::zeros(m, n);
        newton_schulz5_into(&g, 5, &mut ws, &mut fast);
        let d = max_abs_diff(&fast, &naive);
        assert!(d < 1e-4, "scalar ns5 ({m},{n}): {d}");
    }
    simd::set_mode(prev);
}

#[test]
fn simd_and_scalar_rungs_agree_within_1e4() {
    // the acceptance bar: the host's vector rung (AVX2 on x86-64, NEON
    // on aarch64), scalar, and naive paths within 1e-4 of each other
    // across rectangular/tall/wide/zero-row shapes — including the
    // (258, 64) shape whose matmuls take the packed-A panel path
    let _guard = mode_lock();
    let best = simd::detected();
    if best == simd::SimdPath::Scalar {
        return; // single-rung ladder: nothing to compare
    }
    let prev = simd::mode();
    let mut rng = Rng::new(10);
    for &(m, k) in SHAPES {
        let n = (k / 2).max(1) + 3;
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut v = Matrix::randn(m, k, 2.0, &mut rng);
        for x in v.data_mut()[0..k].iter_mut() {
            *x = 0.0; // zero row
        }
        simd::set_mode(SimdMode::Scalar);
        let mm_s = a.matmul(&b);
        let gr_s = a.gram();
        let rn_s = v.row_normalize(ROW_EPS);
        simd::set_mode(best.to_mode());
        assert_eq!(simd::active(), best);
        let mm_v = a.matmul(&b);
        let gr_v = a.gram();
        let rn_v = v.row_normalize(ROW_EPS);
        let d = max_abs_diff(&mm_s, &mm_v);
        assert!(d < 1e-4, "matmul rungs ({m},{k},{n}): {d}");
        let d = max_abs_diff(&gr_s, &gr_v);
        assert!(d < 1e-4, "gram rungs ({m},{k}): {d}");
        let d = max_abs_diff(&rn_s, &rn_v);
        assert!(d < 1e-4, "rownorm rungs ({m},{k}): {d}");
    }
    // NS5 end-to-end across rungs
    let mut ws = Workspace::new();
    let g = Matrix::randn(24, 56, 1.0, &mut rng);
    simd::set_mode(SimdMode::Scalar);
    let mut ns_s = Matrix::zeros(24, 56);
    newton_schulz5_into(&g, 5, &mut ws, &mut ns_s);
    simd::set_mode(best.to_mode());
    let mut ns_v = Matrix::zeros(24, 56);
    newton_schulz5_into(&g, 5, &mut ws, &mut ns_v);
    let d = max_abs_diff(&ns_s, &ns_v);
    assert!(d < 1e-4, "ns5 rungs: {d}");
    simd::set_mode(prev);
}

/// Reference (f64) implementations of the model-layer sweeps, used as
/// the oracle for every rung.
mod model_ref {
    pub fn row_softmax(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * cols];
        for i in 0..rows {
            let row = &src[i * cols..(i + 1) * cols];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f64> = row.iter().map(|&v| ((v - max) as f64).exp()).collect();
            let sum: f64 = exps.iter().sum();
            for j in 0..cols {
                out[i * cols + j] = (exps[j] / sum) as f32;
            }
        }
        out
    }

    pub fn row_softmax_grad(p: &[f32], dp: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * cols];
        for i in 0..rows {
            let c: f64 = (0..cols)
                .map(|j| p[i * cols + j] as f64 * dp[i * cols + j] as f64)
                .sum();
            for j in 0..cols {
                out[i * cols + j] =
                    (p[i * cols + j] as f64 * (dp[i * cols + j] as f64 - c)) as f32;
            }
        }
        out
    }

    pub fn rmsnorm(src: &[f32], gain: &[f32], rows: usize, cols: usize, eps: f32) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * cols];
        for i in 0..rows {
            let ss: f64 = src[i * cols..(i + 1) * cols]
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum();
            let r = 1.0 / (ss / cols as f64 + eps as f64).sqrt();
            for j in 0..cols {
                out[i * cols + j] = (gain[j] as f64 * src[i * cols + j] as f64 * r) as f32;
            }
        }
        out
    }
}

fn randvec(len: usize, rng: &mut Rng) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 1.0);
    v
}

/// The model-layer sweeps (row softmax ± mask, its backward, RMSNorm and
/// its backward) across every available rung, against f64 references —
/// the same parity structure the matmul/gram/rownorm ops get.
#[test]
fn row_softmax_and_rmsnorm_parity_across_rungs() {
    let _guard = mode_lock();
    let prev = simd::mode();
    let mut modes = vec![SimdMode::Scalar];
    if simd::detected() != simd::SimdPath::Scalar {
        modes.push(simd::detected().to_mode());
    }
    let mut rng = Rng::new(31);
    for (rows, cols) in [(9usize, 7usize), (16, 16), (32, 32), (11, 48), (8, 96)] {
        let mut src = randvec(rows * cols, &mut rng);
        // causal-style mask on one row
        for v in src[cols + cols / 2..2 * cols].iter_mut() {
            *v = f32::NEG_INFINITY;
        }
        let gain: Vec<f32> = randvec(cols, &mut rng).iter().map(|g| 1.0 + 0.2 * g).collect();
        let positive = randvec(rows * cols, &mut rng);
        let dp = randvec(rows * cols, &mut rng);
        let dy = randvec(rows * cols, &mut rng);
        let sm_ref = model_ref::row_softmax(&src, rows, cols);
        let p = model_ref::row_softmax(&src, rows, cols);
        let smg_ref = model_ref::row_softmax_grad(&p, &dp, rows, cols);
        let rn_ref = model_ref::rmsnorm(&positive, &gain, rows, cols, 1e-6);
        for &mode in &modes {
            simd::set_mode(mode);
            let mut sm = vec![0.0f32; rows * cols];
            kernels::row_softmax_into(&mut sm, &src, rows, cols);
            let mut smg = vec![0.0f32; rows * cols];
            kernels::row_softmax_grad_into(&mut smg, &p, &dp, rows, cols);
            let mut rn = vec![0.0f32; rows * cols];
            kernels::rmsnorm_into(&mut rn, &positive, &gain, rows, cols, 1e-6);
            let mut dx = vec![0.0f32; rows * cols];
            let mut dgain = vec![0.0f32; cols];
            kernels::rmsnorm_grad_into(
                &mut dx, &mut dgain, &dy, &positive, &gain, rows, cols, 1e-6,
            );
            for i in 0..rows * cols {
                assert!(
                    (sm[i] - sm_ref[i]).abs() < 1e-4,
                    "softmax {mode:?} ({rows},{cols}) at {i}"
                );
                assert!(
                    (smg[i] - smg_ref[i]).abs() < 1e-4,
                    "softmax grad {mode:?} ({rows},{cols}) at {i}"
                );
                assert!(
                    (rn[i] - rn_ref[i]).abs() < 1e-4,
                    "rmsnorm {mode:?} ({rows},{cols}) at {i}"
                );
            }
            // rmsnorm backward: checked against the formula in f64
            for i in 0..rows {
                let ss: f64 = positive[i * cols..(i + 1) * cols]
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum();
                let r = 1.0 / (ss / cols as f64 + 1e-6).sqrt();
                let c: f64 = (0..cols)
                    .map(|j| {
                        gain[j] as f64
                            * dy[i * cols + j] as f64
                            * positive[i * cols + j] as f64
                    })
                    .sum();
                let b = r * r * r * c / cols as f64;
                for j in 0..cols {
                    let want = r * gain[j] as f64 * dy[i * cols + j] as f64
                        - b * positive[i * cols + j] as f64;
                    assert!(
                        (dx[i * cols + j] as f64 - want).abs() < 1e-4,
                        "rmsnorm grad {mode:?} ({rows},{cols}) at ({i},{j})"
                    );
                }
            }
            // masked entries: probability and gradient exactly zero
            for j in cols / 2..cols {
                assert_eq!(sm[cols + j], 0.0, "{mode:?}: masked prob");
                assert_eq!(smg[cols + j], 0.0, "{mode:?}: masked grad");
            }
        }
    }
    simd::set_mode(prev);
}

/// Mixed-optimizer parameter list for the StepPlan determinism check:
/// overlapping costs force real scheduling differences between pools.
fn plan_under_test(threads: usize) -> StepPlan {
    let mut rng = Rng::new(11);
    let mut tasks = tasks_from_shapes(
        &[((48, 16), 2), ((16, 48), 1)],
        OptKind::Rmnp,
        0.3,
        &mut rng,
    );
    tasks.extend(tasks_from_shapes(&[((20, 36), 2)], OptKind::Muon, 0.3, &mut rng));
    tasks.extend(tasks_from_shapes(&[((32, 32), 1)], OptKind::AdamW, 0.3, &mut rng));
    // the optimizer zoo shards through the same plan
    tasks.extend(tasks_from_shapes(&[((24, 40), 1)], OptKind::Nora, 0.3, &mut rng));
    tasks.extend(tasks_from_shapes(&[((40, 24), 1)], OptKind::NorMuon, 0.3, &mut rng));
    tasks.extend(tasks_from_shapes(&[((28, 28), 1)], OptKind::TurboMuon, 0.3, &mut rng));
    tasks.extend(tasks_from_shapes(&[((18, 44), 1)], OptKind::Muown, 0.3, &mut rng));
    StepPlan::new(tasks, threads)
}

#[test]
fn step_plan_bits_identical_across_plan_threads() {
    // the `perf.plan_threads` contract: 1, 2, and 4 workers produce the
    // same update bits — sharding must never change numerics
    let _guard = mode_lock();
    let mut plans: Vec<StepPlan> = [1usize, 2, 4].into_iter().map(plan_under_test).collect();
    assert_eq!(plans[0].threads(), 0, "threads=1 runs poolless");
    assert!(plans[2].threads() >= 2);
    for round in 0..3u64 {
        for plan in plans.iter_mut() {
            for i in 0..plan.len() {
                plan.with_task(i, |t| {
                    // name-keyed grads: identical inputs per task whatever
                    // the scheduling order
                    let key = t.name.bytes().map(|b| b as u64).sum::<u64>();
                    let mut rng = Rng::new(1000 + round * 131 + key);
                    rng.fill_normal(t.grad.data_mut(), 1.0);
                });
            }
            plan.step_all(0.02);
        }
    }
    let reference: Vec<(String, Matrix)> = (0..plans[0].len())
        .map(|i| plans[0].with_task(i, |t| (t.name.clone(), t.w.clone())))
        .collect();
    for plan in &plans[1..] {
        for (i, (name, want)) in reference.iter().enumerate() {
            let (got_name, got) = plan.with_task(i, |t| (t.name.clone(), t.w.clone()));
            assert_eq!(&got_name, name, "scheduling order must be deterministic");
            assert_eq!(&got, want, "task {name} diverged at {} workers", plan.threads());
        }
    }
    // momentum state must agree too, not just the weights
    for plan in &plans[1..] {
        for i in 0..plan.len() {
            let want = plans[0].with_task(i, |t| t.state.momentum());
            let got = plan.with_task(i, |t| t.state.momentum());
            assert_eq!(got, want, "momentum diverged on task {i}");
        }
    }
}
