//! Cross-layer parity: the tiled/threaded kernel layer and the fused
//! optimizer steps must match the seed scalar implementations within 1e-4
//! across rectangular, tall, wide, and zero-row shapes — including at
//! sizes large enough to engage the multi-threaded paths.

use rmnp::optim::{
    newton_schulz5_into, newton_schulz5_naive, rms_scale, MuonState, RmnpState,
    MATRIX_BETA, ROW_EPS, WEIGHT_DECAY,
};
use rmnp::tensor::{kernels, Matrix, Workspace};
use rmnp::util::Rng;

fn max_abs_diff(a: &Matrix, b: &Matrix) -> f32 {
    a.data()
        .iter()
        .zip(b.data())
        .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()))
}

/// Shapes covering rectangular, tall, wide, and threaded-size cases.
const SHAPES: &[(usize, usize)] = &[(7, 13), (96, 24), (24, 96), (160, 161)];

#[test]
fn parallel_matmul_matches_naive() {
    let mut rng = Rng::new(1);
    for &(m, k) in SHAPES {
        let n = (k / 2).max(1) + 3;
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let d = max_abs_diff(&a.matmul(&b), &a.matmul_naive(&b));
        assert!(d < 1e-4, "matmul ({m},{k},{n}): {d}");
    }
}

#[test]
fn parallel_gram_matches_naive() {
    let mut rng = Rng::new(2);
    for &(m, k) in SHAPES {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let d = max_abs_diff(&a.gram(), &a.gram_naive());
        assert!(d < 1e-4, "gram ({m},{k}): {d}");
    }
}

#[test]
fn row_normalize_matches_naive_including_zero_rows() {
    let mut rng = Rng::new(3);
    for &(m, n) in SHAPES {
        let mut v = Matrix::randn(m, n, 2.0, &mut rng);
        // zero the middle row: eps-floor semantics must agree
        let mid = m / 2;
        for x in v.data_mut()[mid * n..(mid + 1) * n].iter_mut() {
            *x = 0.0;
        }
        let d = max_abs_diff(&v.row_normalize(ROW_EPS), &v.row_normalize_naive(ROW_EPS));
        assert!(d < 1e-4, "rownorm ({m},{n}): {d}");
    }
}

#[test]
fn ns5_kernel_path_matches_naive() {
    let mut rng = Rng::new(4);
    let mut ws = Workspace::new();
    for &(m, n) in &[(12usize, 40usize), (40, 12), (16, 16)] {
        let g = Matrix::randn(m, n, 1.0, &mut rng);
        let naive = newton_schulz5_naive(&g, 5);
        let mut fast = Matrix::zeros(m, n);
        newton_schulz5_into(&g, 5, &mut ws, &mut fast);
        let d = max_abs_diff(&fast, &naive);
        assert!(d < 1e-4, "ns5 ({m},{n}): {d}");
    }
}

#[test]
fn fused_rmnp_step_matches_seed_semantics() {
    // independent reimplementation of the seed step (not step_unfused) so
    // a shared bug can't hide
    let mut rng = Rng::new(5);
    for &(m, n) in SHAPES {
        let mut w_fused = Matrix::randn(m, n, 0.3, &mut rng);
        let mut w_seed = w_fused.clone();
        let mut st = RmnpState::new(m, n);
        let mut mom = Matrix::zeros(m, n);
        for _ in 0..3 {
            let mut g = Matrix::randn(m, n, 1.0, &mut rng);
            for x in g.data_mut()[0..n].iter_mut() {
                *x = 0.0; // zero row each step
            }
            st.step(&mut w_fused, &g, 0.01);
            mom = mom.axpby(MATRIX_BETA, &g, 1.0 - MATRIX_BETA);
            let d = mom.row_normalize_naive(ROW_EPS);
            let scale = 0.01 * rms_scale(m, n);
            for (wv, dv) in w_seed.data_mut().iter_mut().zip(d.data()) {
                *wv -= scale * (dv + WEIGHT_DECAY * *wv);
            }
        }
        let dw = max_abs_diff(&w_fused, &w_seed);
        assert!(dw < 1e-4, "rmnp step ({m},{n}): {dw}");
        let dm = max_abs_diff(&st.momentum, &mom);
        assert!(dm < 1e-4, "rmnp momentum ({m},{n}): {dm}");
    }
}

#[test]
fn fused_muon_step_matches_seed_semantics() {
    let mut rng = Rng::new(6);
    for &(m, n) in &[(10usize, 30usize), (30, 10)] {
        let mut w_ws = Matrix::randn(m, n, 0.3, &mut rng);
        let mut w_seed = w_ws.clone();
        let mut st = MuonState::new(m, n);
        let mut mom = Matrix::zeros(m, n);
        for _ in 0..3 {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            st.step(&mut w_ws, &g, 0.01);
            mom = mom.axpby(MATRIX_BETA, &g, 1.0 - MATRIX_BETA);
            let d = newton_schulz5_naive(&mom, 5);
            let scale = 0.01 * rms_scale(m, n);
            for (wv, dv) in w_seed.data_mut().iter_mut().zip(d.data()) {
                *wv -= scale * (dv + WEIGHT_DECAY * *wv);
            }
        }
        let dw = max_abs_diff(&w_ws, &w_seed);
        assert!(dw < 1e-4, "muon step ({m},{n}): {dw}");
    }
}

#[test]
fn workspace_reuse_never_leaks_between_ops() {
    // run NS5 on matrix A, then on B, then on A again through the same
    // workspace: the second A result must equal the first exactly
    let mut rng = Rng::new(7);
    let a = Matrix::randn(14, 22, 1.0, &mut rng);
    let b = Matrix::randn(22, 14, 3.0, &mut rng);
    let mut ws = Workspace::new();
    let mut first = Matrix::zeros(14, 22);
    newton_schulz5_into(&a, 5, &mut ws, &mut first);
    let mut other = Matrix::zeros(22, 14);
    newton_schulz5_into(&b, 5, &mut ws, &mut other);
    let mut again = Matrix::zeros(14, 22);
    newton_schulz5_into(&a, 5, &mut ws, &mut again);
    assert_eq!(first, again, "workspace state leaked between calls");
    // and raw take() after arbitrary scribbling is always zeroed
    let mut buf = ws.take(257);
    rng.fill_normal(&mut buf, 5.0);
    ws.give(buf);
    assert!(ws.take(101).iter().all(|&x| x == 0.0));
}

#[test]
fn thread_count_does_not_change_results() {
    let mut rng = Rng::new(8);
    let a = Matrix::randn(130, 90, 1.0, &mut rng);
    let b = Matrix::randn(90, 110, 1.0, &mut rng);
    kernels::set_num_threads(1);
    let serial_mm = a.matmul(&b);
    let serial_gram = a.gram();
    let serial_rn = a.row_normalize(ROW_EPS);
    kernels::set_num_threads(4);
    let par_mm = a.matmul(&b);
    let par_gram = a.gram();
    let par_rn = a.row_normalize(ROW_EPS);
    kernels::set_num_threads(0);
    assert_eq!(serial_mm, par_mm);
    assert_eq!(serial_rn, par_rn);
    for (x, y) in serial_gram.data().iter().zip(par_gram.data()) {
        assert!((x - y).abs() < 1e-4);
    }
}
