//! Experiment-harness integration tests: tiny-budget versions of each
//! paper experiment, verifying the harness plumbing end to end (the full
//! budgets are exercised by `rmnp exp ...` and recorded in
//! EXPERIMENTS.md). Serialized like integration.rs.

use std::path::Path;
use std::sync::Mutex;

use rmnp::config::{BackendKind, DataSpec};
use rmnp::exp::{cliprate, dominance_exp, precond, pretrain, sweeps, ExpOpts};
use rmnp::runtime::Engine;

static LOCK: Mutex<()> = Mutex::new(());

fn opts(name: &str, steps: usize) -> Option<ExpOpts> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let out = std::env::temp_dir().join(format!("rmnp-exp-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out);
    Some(ExpOpts {
        steps,
        out,
        workers: 1,
        backend: BackendKind::Pjrt,
        ..Default::default()
    })
}

#[test]
fn precond_bench_small_configs() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some(o) = opts("precond", 0) else { return };
    // cap at d=768 so the test stays fast; 2 repeats
    let rows = precond::run(&o, 768, 2).unwrap();
    assert_eq!(rows.len(), 2, "60M + 125M configs");
    for r in &rows {
        assert!(r.speedup > 1.0, "RMNP must beat NS5: {r:?}");
        assert!(r.muon_100steps > 0.0 && r.rmnp_100steps > 0.0);
    }
    assert!(
        rows[1].speedup > rows[0].speedup * 0.5,
        "speedup roughly non-collapsing: {rows:?}"
    );
    let table = precond::format_table(&rows);
    assert!(table.contains("Speedup"));
}

#[test]
fn pretrain_compare_tiny() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some(o) = opts("pretrain", 12) else { return };
    let grid = pretrain::compare(
        &o, "gpt2", &["tiny"], &["adamw", "rmnp"], DataSpec::Markov, 1,
    )
    .unwrap();
    assert_eq!(grid.ppl.len(), 2);
    assert!(grid.ppl[0][0].is_finite() && grid.ppl[1][0].is_finite());
    let rendered = pretrain::format_grid(&grid, "test");
    assert!(rendered.contains("ADAMW") && rendered.contains("RMNP"));
}

#[test]
fn sweep_grid_runs_and_orders() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some(mut o) = opts("sweep", 10) else { return };
    o.workers = 2; // exercise the multi-worker path
    let cells = sweeps::run(&o, "gpt2_tiny", &["rmnp"], DataSpec::Markov).unwrap();
    assert_eq!(cells.len(), sweeps::grid_for("rmnp").unwrap().len());
    let w = sweeps::winners(&cells);
    assert_eq!(w.len(), 1);
    assert!(cells.iter().any(|c| (c.final_ppl - w[0].2).abs() < 1e-9));
}

#[test]
fn dominance_exp_reproduces_claim_even_at_tiny_budget() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some(o) = opts("dom", 30) else { return };
    let engine = Engine::new(&o.artifacts).unwrap();
    let run = dominance_exp::run_one(&o, &engine, "gpt2_tiny", "muon", DataSpec::Markov)
        .unwrap();
    assert!(run.global.steps.len() >= 10);
    assert_eq!(run.representative.len(), 3);
    // the structural claim (Figure 4/5): ratios sit above 1 from early on
    assert!(
        dominance_exp::reproduces_dominance(&run),
        "tail means: {:?}",
        run.global.tail_means()
    );
    let txt = dominance_exp::format_per_param(&run);
    assert!(txt.contains("r_avg"));
}

#[test]
fn cliprate_scan_reads_runs() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let Some(o) = opts("clip", 15) else { return };
    // produce one pretrain run, then scan it
    pretrain::compare(&o, "gpt2", &["tiny"], &["rmnp"], DataSpec::Markov, 1).unwrap();
    let summaries = cliprate::scan(&o.out).unwrap();
    assert!(!summaries.is_empty());
    assert!(summaries[0].steps == 15);
    assert!(cliprate::format(&summaries).contains("rolling mean"));
}
