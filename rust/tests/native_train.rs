//! End-to-end tests of the native training backend — the offline
//! pretrain path through `StepPlan`, run with **default features** (no
//! artifacts, no XLA).
//!
//! The heart of the suite is the resume contract: a run stepped to N,
//! checkpointed, restored, and continued must be **bit-identical** to an
//! uninterrupted run — parameters and optimizer state both, for every
//! native optimizer, across `perf.plan_threads ∈ {1, 4}`. Checkpoints
//! are compared as raw bytes, the strongest form of the assertion.

use std::path::PathBuf;

use rmnp::config::{DataSpec, RunConfig, Schedule};
use rmnp::coordinator::{checkpoint, train};
use rmnp::coordinator::metrics::CsvData;
use rmnp::exp::{pretrain, sweeps, ExpOpts};

fn tmp_out(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "rmnp-native-{}-{name}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn cfg(optimizer: &str, steps: usize, plan_threads: usize, name: &str) -> RunConfig {
    RunConfig {
        model: "gpt2_tiny".into(),
        optimizer: optimizer.into(),
        lr: 4e-3,
        schedule: Schedule::CosineWarmup { warmup_frac: 0.1, min_ratio: 0.1 },
        steps,
        seed: 11,
        data: DataSpec::Markov,
        eval_every: (steps / 2).max(1),
        eval_batches: 2,
        plan_threads,
        out_dir: tmp_out(name),
        ..RunConfig::default()
    }
}

#[test]
fn native_pretrain_learns_and_writes_metrics() {
    let cfg = cfg("rmnp", 40, 2, "learn");
    let result = train::run_auto(&cfg).expect("native run");
    assert!(result.final_train_loss.is_finite());
    assert!(result.final_ppl.is_finite() && result.final_ppl > 1.0);
    let csv = CsvData::read(&cfg.out_dir.join("metrics.csv")).unwrap();
    assert_eq!(csv.rows.len(), 40);
    let losses = csv.column("loss").unwrap();
    assert!(
        losses.last().unwrap() < &losses[0],
        "no learning: {} -> {}",
        losses[0],
        losses.last().unwrap()
    );
    let ppl = train::read_final_ppl(&cfg.out_dir).unwrap();
    assert!((ppl - result.final_ppl).abs() < 1e-2);
}

/// The acceptance-criteria centerpiece: save/restore/continue is
/// bit-exact vs an uninterrupted run for rmnp, muon, adamw, and the
/// zoo's row-second-moment entries (nora, normuon — the ones with extra
/// per-row state buffers and step counters), across plan_threads ∈
/// {1, 4}. Compares the final checkpoints byte-for-byte.
#[test]
fn checkpoint_resume_is_bit_exact_across_optimizers_and_threads() {
    const STEPS: usize = 10;
    const HALF: usize = 5;
    for optimizer in ["rmnp", "muon", "adamw", "nora", "normuon"] {
        // reference checkpoint bytes, computed once per optimizer with
        // plan_threads = 1
        let mut reference: Option<Vec<u8>> = None;
        for plan_threads in [1usize, 4] {
            let tag = format!("{optimizer}-t{plan_threads}");
            // (a) uninterrupted: 10 steps, checkpoint every 5
            let mut full = cfg(optimizer, STEPS, plan_threads, &format!("full-{tag}"));
            full.checkpoint_every = HALF;
            train::run_auto(&full).unwrap();
            let full_end = std::fs::read(full.out_dir.join("step-10.ckpt")).unwrap();

            // (b) "interrupted" run: the same job restarted from the
            // mid-run checkpoint in a fresh directory (as if the process
            // had died at step 5) and continued to 10
            let mut cont = cfg(optimizer, STEPS, plan_threads, &format!("cont-{tag}"));
            cont.checkpoint_every = HALF;
            cont.resume = true;
            std::fs::create_dir_all(&cont.out_dir).unwrap();
            std::fs::copy(
                full.out_dir.join("step-5.ckpt"),
                cont.out_dir.join("step-5.ckpt"),
            )
            .unwrap();
            let (step, _) = checkpoint::latest(&cont.out_dir).unwrap().unwrap();
            assert_eq!(step, HALF);
            train::run_auto(&cont).unwrap();
            let resumed_end = std::fs::read(cont.out_dir.join("step-10.ckpt")).unwrap();

            assert_eq!(
                full_end, resumed_end,
                "{optimizer} plan_threads={plan_threads}: resumed run is not \
                 bit-identical to the uninterrupted run"
            );
            // and the trajectory is identical across plan_threads too
            match &reference {
                None => reference = Some(full_end),
                Some(r) => assert_eq!(
                    r, &full_end,
                    "{optimizer}: plan_threads={plan_threads} diverged from \
                     plan_threads=1"
                ),
            }
        }
    }
}

#[test]
fn resume_appends_metrics_rows_in_place() {
    let mut part = cfg("rmnp", 4, 1, "metrics-resume");
    part.checkpoint_every = 4;
    part.eval_every = 0;
    train::run_auto(&part).unwrap();
    let mut cont = part.clone();
    cont.steps = 8;
    cont.resume = true;
    train::run_auto(&cont).unwrap();
    let csv = CsvData::read(&cont.out_dir.join("metrics.csv")).unwrap();
    assert_eq!(csv.rows.len(), 8, "4 original + 4 resumed rows");
    let steps = csv.column("step").unwrap();
    assert_eq!(steps, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
}

#[test]
fn resume_drops_rows_past_the_restored_checkpoint() {
    // an interruption after the checkpoint but before the run finished:
    // metrics.csv holds rows past the step the resume restores from
    let mut c = cfg("rmnp", 8, 1, "metrics-trunc");
    c.checkpoint_every = 4;
    c.eval_every = 0;
    train::run_auto(&c).unwrap();
    // forget the final checkpoint -> latest is step-4, but rows 4..8 exist
    std::fs::remove_file(c.out_dir.join("step-8.ckpt")).unwrap();
    let mut cont = c.clone();
    cont.resume = true;
    train::run_auto(&cont).unwrap();
    let csv = CsvData::read(&cont.out_dir.join("metrics.csv")).unwrap();
    let steps = csv.column("step").unwrap();
    assert_eq!(
        steps,
        vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
        "stale rows past the checkpoint must be dropped, not duplicated"
    );
}

#[test]
fn resume_without_checkpoint_starts_fresh() {
    let mut c = cfg("rmnp", 3, 1, "resume-fresh");
    c.resume = true; // nothing to resume from — must run from step 0
    let result = train::run_auto(&c).unwrap();
    assert_eq!(result.steps, 3);
}

#[test]
fn pjrt_only_optimizer_is_a_clean_error_on_native() {
    let c = cfg("shampoo", 2, 1, "shampoo-native");
    let err = train::run_auto(&c).unwrap_err().to_string();
    assert!(err.contains("no native fused implementation"), "{err}");
}

#[test]
fn pretrain_grid_runs_offline() {
    let opts = ExpOpts {
        steps: 6,
        out: tmp_out("pretrain-grid"),
        workers: 2,
        ..Default::default()
    };
    let grid = pretrain::compare(
        &opts,
        "gpt2",
        &["tiny"],
        &["adamw", "rmnp"],
        DataSpec::Markov,
        1,
    )
    .unwrap();
    assert_eq!(grid.ppl.len(), 2);
    assert!(grid.ppl[0][0].is_finite() && grid.ppl[1][0].is_finite());
    let rendered = pretrain::format_grid(&grid, "offline");
    assert!(rendered.contains("ADAMW") && rendered.contains("RMNP"));
}

#[test]
fn sweep_grid_runs_offline() {
    let opts = ExpOpts {
        steps: 4,
        out: tmp_out("sweep-grid"),
        workers: 2,
        ..Default::default()
    };
    let cells = sweeps::run(&opts, "gpt2_tiny", &["rmnp"], DataSpec::Markov).unwrap();
    assert_eq!(cells.len(), sweeps::grid_for("rmnp").unwrap().len());
    let winners = sweeps::winners(&cells);
    assert_eq!(winners.len(), 1);
    assert!(winners[0].2.is_finite());
}

#[test]
fn vision_family_trains_offline() {
    let mut c = cfg("muon", 3, 1, "vision");
    c.model = "vision_base".into();
    c.data = DataSpec::Images;
    c.eval_every = 0;
    let result = train::run_auto(&c).unwrap();
    assert!(result.final_train_loss.is_finite());
}

#[test]
fn dominance_logging_works_natively() {
    let mut c = cfg("muon", 6, 1, "dom");
    c.dominance_every = 2;
    c.eval_every = 0;
    train::run_auto(&c).unwrap();
    let csv = CsvData::read(&c.out_dir.join("dominance.csv")).unwrap();
    assert_eq!(csv.rows.len(), 3, "logged every 2 steps over 6");
    // gpt2_tiny attention: 2 blocks × 4 projection matrices on the
    // matrix optimizer -> step + 8×3 cols
    assert_eq!(csv.header.len(), 1 + 8 * 3);
}

#[test]
fn every_arch_saves_and_resumes_bit_exact_end_to_end() {
    // the acceptance criterion: `exp pretrain|ablation-embed|ssm|vision`
    // families run offline on the new blocks with byte-identical
    // save/resume — exercised here per arch through the full train loop
    for (tag, data, arch) in [
        ("llama_s60", DataSpec::Zipf, "gated_mlp"),
        ("ssm_base", DataSpec::Ngram, "ssm"),
        ("vision_base", DataSpec::Images, "conv"),
    ] {
        let mut full = cfg("rmnp", 6, 2, &format!("arch-full-{tag}"));
        full.model = tag.into();
        full.data = data;
        full.eval_every = 0;
        full.checkpoint_every = 3;
        train::run_auto(&full).unwrap();
        let full_end = std::fs::read(full.out_dir.join("step-6.ckpt")).unwrap();
        let mut cont = cfg("rmnp", 6, 2, &format!("arch-cont-{tag}"));
        cont.model = tag.into();
        cont.data = data;
        cont.eval_every = 0;
        cont.checkpoint_every = 3;
        cont.resume = true;
        std::fs::create_dir_all(&cont.out_dir).unwrap();
        std::fs::copy(
            full.out_dir.join("step-3.ckpt"),
            cont.out_dir.join("step-3.ckpt"),
        )
        .unwrap();
        train::run_auto(&cont).unwrap();
        let resumed_end = std::fs::read(cont.out_dir.join("step-6.ckpt")).unwrap();
        assert_eq!(full_end, resumed_end, "{tag}: resume diverged");
        // the summary records which arch ran
        let summary =
            std::fs::read_to_string(full.out_dir.join("summary.jsonl")).unwrap();
        assert!(summary.contains(&format!("\"arch\":\"{arch}\"")), "{summary}");
    }
}

#[test]
fn resume_with_mismatched_optimizer_is_a_clean_error() {
    // save under nora, resume with muon: both are matrix optimizers on
    // the same parameter set, and nora's `momentum` buffer would satisfy
    // muon's import by name — the __optim__ stamp must reject it instead
    // of silently reinterpreting state
    let mut a = cfg("nora", 4, 1, "optim-mismatch-save");
    a.eval_every = 0;
    a.checkpoint_every = 4;
    train::run_auto(&a).unwrap();
    let mut b = a.clone();
    b.optimizer = "muon".into();
    b.steps = 8;
    b.resume = true;
    let err = train::run_auto(&b).unwrap_err().to_string();
    assert!(
        err.contains("nora") && err.contains("muon"),
        "mismatched-optimizer resume must name both optimizers: {err}"
    );
}

#[test]
fn resume_with_mismatched_model_tag_is_a_clean_error() {
    // save under llama_s60, resume under the shape-identical llama_s60emb:
    // before the arch/tag stamp this imported silently
    let mut a = cfg("adamw", 4, 1, "arch-mismatch-save");
    a.model = "llama_s60".into();
    a.data = DataSpec::Zipf;
    a.eval_every = 0;
    a.checkpoint_every = 4;
    train::run_auto(&a).unwrap();
    let mut b = a.clone();
    b.model = "llama_s60emb".into();
    b.steps = 8;
    b.resume = true;
    let err = train::run_auto(&b).unwrap_err().to_string();
    assert!(
        err.contains("llama_s60") && err.contains("llama_s60emb"),
        "mismatched-tag resume must name both models: {err}"
    );
}
