//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The repo builds in environments without the crates.io registry, so this
//! vendored shim provides exactly the API surface the `rmnp` crate uses:
//! [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros,
//! plus a blanket `From<E: std::error::Error>` so `?` works on std errors.
//! Swap the path dependency for the real `anyhow` when a registry is
//! available — no call sites need to change.

use std::fmt;

/// A stringly-typed error value. Unlike the real `anyhow::Error` it keeps
/// only the rendered message (no backtrace, no source chain), which is all
/// this crate's error reporting consumes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does not implement `std::error::Error`, which keeps
// this blanket conversion coherent (same trick as the real anyhow).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: `Result<T, anyhow::Error>` with an overridable
/// error type, matching the real crate's alias shape.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($msg:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($msg, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/zzz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros() {
        let e: Error = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        let e: Error = anyhow!(String::from("plain"));
        assert_eq!(e.to_string(), "plain");
        fn inner(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted ok, got {ok}");
            if !ok {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(inner(true).unwrap(), 7);
        assert!(inner(false).is_err());
    }
}
