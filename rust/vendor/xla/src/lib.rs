//! Offline API stub for the XLA/PJRT bindings.
//!
//! The real dependency (xla_extension bindings) is unavailable in offline
//! builds, so this crate mirrors the exact API surface `rmnp`'s `pjrt`
//! feature consumes and fails at *runtime* with a clear message instead of
//! failing at *compile* time. That keeps `cargo build --features pjrt`
//! green everywhere while real-PJRT environments can substitute the actual
//! bindings via the path dependency without touching rmnp code.
//!
//! Every constructor that would touch a device returns
//! `Err(Error::unavailable())`; pure host-side containers ([`Literal`])
//! work normally so code paths that only shuttle host data stay testable.

use std::fmt;

/// Error type mirroring the bindings' stringly errors.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error(
            "xla stub: PJRT is unavailable in this build (vendor/xla is an \
             offline stub; substitute the real bindings to run artifacts)"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the manifest declares.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Scalar types that can cross the host boundary.
pub trait NativeType: Copy + 'static {
    const TY: ElementType;
}
impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}
impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host-side literal: dtype-tagged flat buffer + shape. Fully functional
/// (the real Literal is host-side too); only device transfer is stubbed.
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Internal constructor dispatch so the public API can stay generic.
pub trait IntoPayload: NativeType {
    fn payload(data: Vec<Self>) -> Payload;
    fn extract(p: &Payload) -> Option<Vec<Self>>;
}
impl IntoPayload for f32 {
    fn payload(data: Vec<Self>) -> Payload {
        Payload::F32(data)
    }
    fn extract(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            Payload::I32(_) => None,
        }
    }
}
impl IntoPayload for i32 {
    fn payload(data: Vec<Self>) -> Payload {
        Payload::I32(data)
    }
    fn extract(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            Payload::F32(_) => None,
        }
    }
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: IntoPayload>(v: T) -> Literal {
        Literal { payload: T::payload(vec![v]), dims: vec![] }
    }

    /// Rank-1 literal.
    pub fn vec1<T: IntoPayload>(data: &[T]) -> Literal {
        Literal {
            payload: T::payload(data.to_vec()),
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape to new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        };
        if n as usize != have {
            return Err(Error(format!("reshape {have} elements to {dims:?}")));
        }
        let payload = match &self.payload {
            Payload::F32(v) => Payload::F32(v.clone()),
            Payload::I32(v) => Payload::I32(v.clone()),
        };
        Ok(Literal { payload, dims: dims.to_vec() })
    }

    /// Flat host copy, checked against the stored dtype.
    pub fn to_vec<T: IntoPayload>(&self) -> Result<Vec<T>> {
        T::extract(&self.payload)
            .ok_or_else(|| Error("literal dtype mismatch".to_string()))
    }

    /// Stored element type.
    pub fn ty(&self) -> Result<ElementType> {
        Ok(match &self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::I32(_) => ElementType::S32,
        })
    }

    /// Declared dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: retains nothing).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable())
    }
}

/// Computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (stub: cannot be constructed).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Compiled executable (stub: cannot be constructed).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_untupled<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }

    pub fn execute_b_untupled(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// PJRT client (stub: `cpu()` always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1i32, 2, 3, 4]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.ty().unwrap(), ElementType::S32);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(l.to_vec::<f32>().is_err());
        assert!(Literal::scalar(1.0f32).reshape(&[3]).is_err());
    }

    #[test]
    fn device_paths_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
