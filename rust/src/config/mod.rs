//! Experiment configuration: TOML-subset parser + typed run configs.
//!
//! A run is fully described by a [`RunConfig`]; `configs/*.toml` hold the
//! presets mirroring the paper's protocols and the CLI can override any
//! field (`--set train.steps=200`).

pub mod toml;

use std::path::{Path, PathBuf};

pub use toml::{Document, Value};

/// Which training backend executes a run (`runtime.backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Host-native matrices + `StepPlan` stepping — the default; runs
    /// offline in every build.
    Native,
    /// PJRT artifact path (needs the `pjrt` feature and real XLA).
    Pjrt,
}

impl BackendKind {
    /// Parse a config/CLI backend name.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "native" => BackendKind::Native,
            "pjrt" => BackendKind::Pjrt,
            other => anyhow::bail!("unknown backend `{other}` (native|pjrt)"),
        })
    }

    /// The config spelling of this backend.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Learning-rate schedule shape (paper: cosine with 10% warmup).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    /// Flat learning rate for the whole run.
    Constant,
    /// Linear warmup over `warmup_frac` of the run then cosine decay to
    /// `min_ratio * lr`.
    CosineWarmup {
        /// Fraction of total steps spent warming up.
        warmup_frac: f64,
        /// Final LR as a fraction of the peak.
        min_ratio: f64,
    },
}

/// Synthetic-corpus choice (DESIGN.md §3 substitutions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataSpec {
    /// Order-2 Markov chain over words — OpenWebText analogue.
    Markov,
    /// Zipfian unigram stream with local repetition — C4 analogue.
    Zipf,
    /// Repeated-ngram corpus — FineWeb-Edu analogue.
    Ngram,
    /// Class-conditional synthetic images (vision experiments).
    Images,
}

impl DataSpec {
    /// Parse a `data.corpus` config value.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "markov" => DataSpec::Markov,
            "zipf" => DataSpec::Zipf,
            "ngram" => DataSpec::Ngram,
            "images" => DataSpec::Images,
            other => anyhow::bail!("unknown dataset `{other}`"),
        })
    }
    /// The config spelling of this corpus.
    pub fn name(&self) -> &'static str {
        match self {
            DataSpec::Markov => "markov",
            DataSpec::Zipf => "zipf",
            DataSpec::Ngram => "ngram",
            DataSpec::Images => "images",
        }
    }
}

/// Everything needed to run one training job.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Registry tag, e.g. "gpt2_small".
    pub model: String,
    /// Optimizer name, e.g. "rmnp".
    pub optimizer: String,
    /// Peak matrix learning rate (lr_adamw follows at the manifest ratio).
    pub lr: f64,
    /// Learning-rate schedule shape.
    pub schedule: Schedule,
    /// Total training steps.
    pub steps: usize,
    /// Base RNG seed (init, data streams).
    pub seed: u64,
    /// Which synthetic corpus feeds the run.
    pub data: DataSpec,
    /// Evaluate on held-out batches every `eval_every` steps (0 = end only).
    pub eval_every: usize,
    /// Number of held-out batches per evaluation.
    pub eval_batches: usize,
    /// Log dominance ratios every N steps (0 = never).
    pub dominance_every: usize,
    /// Checkpoint every N steps (0 = never).
    pub checkpoint_every: usize,
    /// Output directory for metrics/checkpoints.
    pub out_dir: PathBuf,
    /// Artifact directory.
    pub artifacts: PathBuf,
    /// Host tensor-kernel threads (`perf.threads`); 0 = auto (the
    /// `RMNP_THREADS` env var, else `available_parallelism`). Applied via
    /// [`crate::tensor::kernels::set_num_threads`].
    pub threads: usize,
    /// SIMD dispatch mode (`perf.simd`): "auto" (detect the best rung —
    /// AVX2+FMA on x86-64, NEON on aarch64 — once at startup, the
    /// default), "avx2", "neon", or "scalar". Forcing a rung the CPU
    /// cannot run falls back to scalar. Applied via
    /// [`crate::tensor::simd::set_mode`]; the `RMNP_SIMD` env var covers
    /// the auto case.
    pub simd: String,
    /// `StepPlan` worker count (`perf.plan_threads`); 0 = the kernel
    /// thread count.
    pub plan_threads: usize,
    /// Parameter/optimizer-state storage precision (`perf.precision`):
    /// "f32" (default) or "bf16" (half the parameter + momentum bytes;
    /// every accumulation stays f32 — see `docs/ARCHITECTURE.md`
    /// §Precision modes). Parsed via
    /// [`crate::tensor::Precision::parse`].
    pub precision: String,
    /// Minimum row count before matmul pre-packs its A panels
    /// (`perf.pack_a_min_rows`); 0 = default (the `RMNP_PACK_A_MIN_ROWS`
    /// env var, else 64). Packed and unpacked paths are bit-identical —
    /// this is a pure tuning knob. Applied via
    /// [`crate::tensor::kernels::set_pack_a_min_rows`].
    pub pack_a_min_rows: usize,
    /// Which backend executes the run (`runtime.backend`): the host-native
    /// path (default, offline) or the PJRT artifact path.
    pub backend: BackendKind,
    /// Resume from the latest checkpoint in `out_dir` (`train.resume`).
    /// The restored trajectory is bit-identical to an uninterrupted run.
    pub resume: bool,
    /// Keep only the newest N checkpoints in `out_dir`
    /// (`train.keep_checkpoints`); 0 = keep everything.
    pub keep_checkpoints: usize,
    /// Anomaly step guard on/off (`train.guard`). When on, non-finite
    /// loss/grad-norm steps skip the optimizer update and back off the
    /// LR; see [`crate::coordinator::guard`].
    pub guard: bool,
    /// LR-scale multiplier per anomalous step (`train.guard_backoff`).
    pub guard_backoff: f64,
    /// LR-scale floor under backoff (`train.guard_min_scale`).
    pub guard_min_scale: f64,
    /// LR-scale multiplier per healthy step (`train.guard_recover`).
    pub guard_recover: f64,
    /// Abort after this many consecutive anomalous steps
    /// (`train.guard_max_bad`).
    pub guard_max_bad: usize,
    /// Treat finite grad norms above this as anomalous
    /// (`train.guard_max_grad_norm`); 0 = off.
    pub guard_max_grad_norm: f64,
    /// Worker count a distributed coordinator waits for (`dist.workers`);
    /// 1 is the degenerate single-worker case.
    pub dist_workers: usize,
    /// Data shards per global step (`dist.shards`); 0 = one per worker.
    /// The shard count — not the worker count — fixes the global batch,
    /// so runs with equal shards are bit-comparable across worker counts.
    pub dist_shards: usize,
    /// Coordinator listen address (`dist.bind`); port 0 lets the OS pick
    /// — the bound address lands in `<out_dir>/coordinator.addr`.
    pub dist_bind: String,
    /// Coordinator address a worker dials (`dist.connect`); the `rmnp
    /// worker --connect` flag takes precedence.
    pub dist_connect: String,
    /// Worker heartbeat period in ms (`dist.heartbeat_ms`).
    pub dist_heartbeat_ms: u64,
    /// Coordinator declares a worker dead after this many ms of silence
    /// (`dist.deadline_ms`); must comfortably exceed the heartbeat period.
    pub dist_deadline_ms: u64,
    /// Coordinator re-issues a step's assignments after this many ms
    /// without completing the gather (`dist.step_timeout_ms`) — recovers
    /// CRC-dropped frames.
    pub dist_step_timeout_ms: u64,
    /// Worker exits after this many ms without a frame from the
    /// coordinator (`dist.worker_timeout_ms`); a *crashed* coordinator is
    /// detected instantly via EOF, this is the hung/partitioned backstop.
    pub dist_worker_timeout_ms: u64,
    /// Coordinator aborts if the full worker set hasn't registered within
    /// this many ms (`dist.join_timeout_ms`).
    pub dist_join_timeout_ms: u64,
    /// Gradient wire codec (`dist.compress`): `"none"` ships f32 bits
    /// verbatim, `"bf16"` halves the gradient bytes per step with
    /// round-to-nearest-even truncation. Either mode is bit-exact across
    /// worker counts; the two modes are distinct trajectories. See
    /// [`crate::dist::compress`].
    pub dist_compress: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "gpt2_tiny".into(),
            optimizer: "rmnp".into(),
            lr: 4e-3,
            schedule: Schedule::CosineWarmup { warmup_frac: 0.1, min_ratio: 0.1 },
            steps: 200,
            seed: 1234,
            data: DataSpec::Markov,
            eval_every: 50,
            eval_batches: 4,
            dominance_every: 0,
            checkpoint_every: 0,
            out_dir: PathBuf::from("runs/default"),
            artifacts: PathBuf::from("artifacts"),
            threads: 0,
            simd: "auto".into(),
            plan_threads: 0,
            precision: "f32".into(),
            pack_a_min_rows: 0,
            backend: BackendKind::Native,
            resume: false,
            keep_checkpoints: 0,
            guard: true,
            guard_backoff: 0.5,
            guard_min_scale: 1.0 / 64.0,
            guard_recover: 2.0,
            guard_max_bad: 8,
            guard_max_grad_norm: 0.0,
            dist_workers: 1,
            dist_shards: 0,
            dist_bind: "127.0.0.1:0".into(),
            dist_connect: String::new(),
            dist_heartbeat_ms: 250,
            dist_deadline_ms: 3000,
            dist_step_timeout_ms: 60_000,
            dist_worker_timeout_ms: 30_000,
            dist_join_timeout_ms: 60_000,
            dist_compress: "none".into(),
        }
    }
}

impl RunConfig {
    /// Load from a TOML document (missing keys fall back to defaults).
    pub fn from_document(doc: &Document) -> anyhow::Result<Self> {
        let mut cfg = RunConfig::default();
        cfg.apply_document(doc)?;
        Ok(cfg)
    }

    /// Load from a TOML file (missing keys fall back to defaults).
    pub fn from_file(path: &Path) -> anyhow::Result<Self> {
        Self::from_document(&toml::parse_file(path)?)
    }

    /// Apply every recognized key from the document over the current values.
    pub fn apply_document(&mut self, doc: &Document) -> anyhow::Result<()> {
        let d = doc;
        self.model = d.str_or("model.tag", &self.model).to_string();
        self.optimizer = d.str_or("train.optimizer", &self.optimizer).to_string();
        self.lr = d.float_or("train.lr", self.lr);
        self.steps = d.int_or("train.steps", self.steps as i64) as usize;
        self.seed = d.int_or("train.seed", self.seed as i64) as u64;
        self.eval_every = d.int_or("eval.every", self.eval_every as i64) as usize;
        self.eval_batches =
            d.int_or("eval.batches", self.eval_batches as i64) as usize;
        self.dominance_every =
            d.int_or("analysis.dominance_every", self.dominance_every as i64) as usize;
        self.checkpoint_every =
            d.int_or("train.checkpoint_every", self.checkpoint_every as i64) as usize;
        // .max(0) so a negative value clamps instead of wrapping to 2^64-1
        self.threads = d.int_or("perf.threads", self.threads as i64).max(0) as usize;
        self.plan_threads =
            d.int_or("perf.plan_threads", self.plan_threads as i64).max(0) as usize;
        self.pack_a_min_rows = d
            .int_or("perf.pack_a_min_rows", self.pack_a_min_rows as i64)
            .max(0) as usize;
        if let Some(v) = d.get("perf.precision") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("perf.precision must be a string"))?;
            crate::tensor::Precision::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown precision `{s}` (f32|bf16)"))?;
            self.precision = s.to_string();
        }
        self.resume = d.bool_or("train.resume", self.resume);
        self.keep_checkpoints = d
            .int_or("train.keep_checkpoints", self.keep_checkpoints as i64)
            .max(0) as usize;
        self.guard = d.bool_or("train.guard", self.guard);
        self.guard_backoff = d.float_or("train.guard_backoff", self.guard_backoff);
        self.guard_min_scale = d.float_or("train.guard_min_scale", self.guard_min_scale);
        self.guard_recover = d.float_or("train.guard_recover", self.guard_recover);
        self.guard_max_bad =
            d.int_or("train.guard_max_bad", self.guard_max_bad as i64).max(0) as usize;
        self.guard_max_grad_norm =
            d.float_or("train.guard_max_grad_norm", self.guard_max_grad_norm);
        self.dist_workers =
            d.int_or("dist.workers", self.dist_workers as i64).max(0) as usize;
        self.dist_shards = d.int_or("dist.shards", self.dist_shards as i64).max(0) as usize;
        self.dist_bind = d.str_or("dist.bind", &self.dist_bind).to_string();
        self.dist_connect = d.str_or("dist.connect", &self.dist_connect).to_string();
        self.dist_heartbeat_ms =
            d.int_or("dist.heartbeat_ms", self.dist_heartbeat_ms as i64).max(0) as u64;
        self.dist_deadline_ms =
            d.int_or("dist.deadline_ms", self.dist_deadline_ms as i64).max(0) as u64;
        self.dist_step_timeout_ms = d
            .int_or("dist.step_timeout_ms", self.dist_step_timeout_ms as i64)
            .max(0) as u64;
        self.dist_worker_timeout_ms = d
            .int_or("dist.worker_timeout_ms", self.dist_worker_timeout_ms as i64)
            .max(0) as u64;
        self.dist_join_timeout_ms = d
            .int_or("dist.join_timeout_ms", self.dist_join_timeout_ms as i64)
            .max(0) as u64;
        if let Some(v) = d.get("dist.compress") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("dist.compress must be a string"))?;
            crate::dist::compress::Compression::parse(s)?; // reject bad values early
            self.dist_compress = s.to_string();
        }
        if let Some(v) = d.get("runtime.backend") {
            self.backend = BackendKind::parse(
                v.as_str()
                    .ok_or_else(|| anyhow::anyhow!("runtime.backend must be a string"))?,
            )?;
        }
        if let Some(v) = d.get("perf.simd") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("perf.simd must be a string"))?;
            crate::tensor::simd::SimdMode::parse(s)?; // reject bad values early
            self.simd = s.to_string();
        }
        if let Some(v) = d.get("data.corpus") {
            self.data = DataSpec::parse(
                v.as_str().ok_or_else(|| anyhow::anyhow!("data.corpus must be a string"))?,
            )?;
        }
        if let Some(v) = d.get("out.dir") {
            self.out_dir = PathBuf::from(
                v.as_str().ok_or_else(|| anyhow::anyhow!("out.dir must be a string"))?,
            );
        }
        if let Some(v) = d.get("artifacts.dir") {
            self.artifacts = PathBuf::from(
                v.as_str().ok_or_else(|| anyhow::anyhow!("artifacts.dir must be a string"))?,
            );
        }
        match d.str_or("train.schedule", "") {
            "" => {}
            "constant" => self.schedule = Schedule::Constant,
            "cosine" => {
                self.schedule = Schedule::CosineWarmup {
                    warmup_frac: d.float_or("train.warmup_frac", 0.1),
                    min_ratio: d.float_or("train.min_lr_ratio", 0.1),
                }
            }
            other => anyhow::bail!("unknown schedule `{other}`"),
        }
        Ok(())
    }

    /// Apply one `section.key=value` CLI override.
    pub fn apply_override(&mut self, kv: &str) -> anyhow::Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("override must be key=value: `{kv}`"))?;
        let mut doc = Document::default();
        // try to parse as scalar; fall back to string
        let val = toml::parse(&format!("x = {v}"))
            .ok()
            .and_then(|d| d.get("x").cloned())
            .unwrap_or_else(|| Value::Str(v.to_string()));
        doc.insert(k, val);
        self.apply_document(&doc)
    }

    /// The artifact tag (`<model>_<optimizer>`).
    pub fn tag(&self) -> String {
        format!("{}_{}", self.model, self.optimizer)
    }

    /// Apply the perf knobs to the process-global kernel configuration
    /// (thread count + SIMD dispatch mode) and announce the now-active
    /// rung — the startup banner only shows the pre-override detection.
    pub fn apply_perf(&self) -> anyhow::Result<()> {
        if self.threads > 0 {
            crate::tensor::kernels::set_num_threads(self.threads);
        }
        crate::tensor::simd::set_mode(crate::tensor::simd::SimdMode::parse(&self.simd)?);
        crate::tensor::kernels::set_pack_a_min_rows(self.pack_a_min_rows);
        crate::info!(
            "kernels: active simd={} threads={} precision={} pack_a_min_rows={}",
            crate::tensor::simd::label(),
            crate::tensor::kernels::num_threads(),
            self.precision,
            crate::tensor::kernels::pack_a_min_rows()
        );
        Ok(())
    }

    /// The parsed [`perf.precision`](RunConfig::precision) storage mode.
    /// `apply_document` already validated the string, so this only fails
    /// on a hand-mutated config.
    pub fn precision_mode(&self) -> anyhow::Result<crate::tensor::Precision> {
        crate::tensor::Precision::parse(&self.precision)
            .ok_or_else(|| anyhow::anyhow!("unknown precision `{}` (f32|bf16)", self.precision))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_document() {
        let doc = toml::parse(
            r#"
[model]
tag = "llama_s60"
[train]
optimizer = "muon"
lr = 0.01
steps = 500
schedule = "cosine"
warmup_frac = 0.2
[data]
corpus = "zipf"
"#,
        )
        .unwrap();
        let cfg = RunConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.model, "llama_s60");
        assert_eq!(cfg.optimizer, "muon");
        assert_eq!(cfg.steps, 500);
        assert_eq!(cfg.data, DataSpec::Zipf);
        match cfg.schedule {
            Schedule::CosineWarmup { warmup_frac, .. } => {
                assert!((warmup_frac - 0.2).abs() < 1e-12)
            }
            _ => panic!("expected cosine"),
        }
        assert_eq!(cfg.tag(), "llama_s60_muon");
    }

    #[test]
    fn overrides() {
        let mut cfg = RunConfig::default();
        cfg.apply_override("train.steps=42").unwrap();
        cfg.apply_override("train.lr=0.5").unwrap();
        cfg.apply_override("model.tag=ssm_base").unwrap();
        cfg.apply_override("perf.threads=4").unwrap();
        assert_eq!(cfg.threads, 4);
        cfg.apply_override("perf.plan_threads=3").unwrap();
        assert_eq!(cfg.plan_threads, 3);
        cfg.apply_override("perf.simd=scalar").unwrap();
        assert_eq!(cfg.simd, "scalar");
        cfg.apply_override("perf.simd=neon").unwrap();
        assert_eq!(cfg.simd, "neon", "the neon rung is a legal override");
        cfg.apply_override("perf.simd=avx512").unwrap();
        assert_eq!(cfg.simd, "avx512", "the avx512 rung is a legal override");
        assert!(cfg.apply_override("perf.simd=sse9").is_err());
        assert_eq!(cfg.simd, "avx512", "bad simd value must not stick");
        assert_eq!(cfg.precision, "f32", "full precision is the default");
        assert_eq!(
            cfg.precision_mode().unwrap(),
            crate::tensor::Precision::F32
        );
        cfg.apply_override("perf.precision=bf16").unwrap();
        assert_eq!(cfg.precision, "bf16");
        assert_eq!(
            cfg.precision_mode().unwrap(),
            crate::tensor::Precision::Bf16
        );
        assert!(cfg.apply_override("perf.precision=fp8").is_err());
        assert_eq!(cfg.precision, "bf16", "bad precision value must not stick");
        cfg.apply_override("perf.precision=f32").unwrap();
        assert_eq!(cfg.precision, "f32");
        assert_eq!(cfg.pack_a_min_rows, 0, "0 = built-in/env pack threshold");
        cfg.apply_override("perf.pack_a_min_rows=128").unwrap();
        assert_eq!(cfg.pack_a_min_rows, 128);
        cfg.apply_override("perf.pack_a_min_rows=-5").unwrap();
        assert_eq!(cfg.pack_a_min_rows, 0, "negative clamps instead of wrapping");
        assert_eq!(cfg.backend, BackendKind::Native, "native is the default");
        cfg.apply_override("runtime.backend=pjrt").unwrap();
        assert_eq!(cfg.backend, BackendKind::Pjrt);
        cfg.apply_override("runtime.backend=native").unwrap();
        assert_eq!(cfg.backend, BackendKind::Native);
        assert!(cfg.apply_override("runtime.backend=tpu").is_err());
        assert!(!cfg.resume);
        cfg.apply_override("train.resume=true").unwrap();
        assert!(cfg.resume);
        cfg.apply_override("train.resume=false").unwrap();
        assert!(!cfg.resume);
        assert_eq!(cfg.keep_checkpoints, 0, "retention off by default");
        cfg.apply_override("train.keep_checkpoints=3").unwrap();
        assert_eq!(cfg.keep_checkpoints, 3);
        assert!(cfg.guard, "anomaly guard on by default");
        cfg.apply_override("train.guard=false").unwrap();
        assert!(!cfg.guard);
        cfg.apply_override("train.guard_backoff=0.25").unwrap();
        assert!((cfg.guard_backoff - 0.25).abs() < 1e-12);
        cfg.apply_override("train.guard_max_bad=4").unwrap();
        assert_eq!(cfg.guard_max_bad, 4);
        cfg.apply_override("train.guard_max_grad_norm=50.0").unwrap();
        assert!((cfg.guard_max_grad_norm - 50.0).abs() < 1e-12);
        assert_eq!(cfg.dist_workers, 1, "single-worker is the degenerate default");
        assert_eq!(cfg.dist_shards, 0, "0 shards = one per worker");
        cfg.apply_override("dist.workers=4").unwrap();
        assert_eq!(cfg.dist_workers, 4);
        cfg.apply_override("dist.shards=8").unwrap();
        assert_eq!(cfg.dist_shards, 8);
        cfg.apply_override("dist.bind=0.0.0.0:7070").unwrap();
        assert_eq!(cfg.dist_bind, "0.0.0.0:7070");
        cfg.apply_override("dist.connect=127.0.0.1:7070").unwrap();
        assert_eq!(cfg.dist_connect, "127.0.0.1:7070");
        cfg.apply_override("dist.heartbeat_ms=100").unwrap();
        assert_eq!(cfg.dist_heartbeat_ms, 100);
        cfg.apply_override("dist.deadline_ms=1500").unwrap();
        assert_eq!(cfg.dist_deadline_ms, 1500);
        cfg.apply_override("dist.step_timeout_ms=9000").unwrap();
        assert_eq!(cfg.dist_step_timeout_ms, 9000);
        cfg.apply_override("dist.worker_timeout_ms=2500").unwrap();
        assert_eq!(cfg.dist_worker_timeout_ms, 2500);
        cfg.apply_override("dist.join_timeout_ms=30000").unwrap();
        assert_eq!(cfg.dist_join_timeout_ms, 30000);
        assert_eq!(cfg.dist_compress, "none", "uncompressed wire is the default");
        cfg.apply_override("dist.compress=bf16").unwrap();
        assert_eq!(cfg.dist_compress, "bf16");
        assert!(cfg.apply_override("dist.compress=fp8").is_err());
        assert_eq!(cfg.dist_compress, "bf16", "bad codec value must not stick");
        cfg.apply_override("dist.compress=none").unwrap();
        assert_eq!(cfg.dist_compress, "none");
        cfg.apply_override("dist.workers=-2").unwrap();
        assert_eq!(cfg.dist_workers, 0, "negative clamps instead of wrapping");
        assert_eq!(cfg.steps, 42);
        assert!((cfg.lr - 0.5).abs() < 1e-12);
        assert_eq!(cfg.model, "ssm_base");
        assert!(cfg.apply_override("no_equals").is_err());
    }

    #[test]
    fn bad_values_rejected() {
        let doc = toml::parse("[train]\nschedule = \"nope\"").unwrap();
        assert!(RunConfig::from_document(&doc).is_err());
        let doc = toml::parse("[data]\ncorpus = \"wat\"").unwrap();
        assert!(RunConfig::from_document(&doc).is_err());
    }
}
