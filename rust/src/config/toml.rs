//! Minimal TOML-subset parser (no external crates are available offline).
//!
//! Supported: `[section]` / `[a.b]` headers, `key = value` with strings,
//! integers, floats, booleans, and flat arrays of those; `#` comments;
//! blank lines. Unsupported TOML (multi-line strings, inline tables,
//! datetimes, array-of-tables) is rejected with a line-numbered error —
//! the experiment configs in `configs/` only need the subset.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat array of scalars.
    Array(Vec<Value>),
}

impl Value {
    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The integer payload, if this is an integer.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// The float payload (integers coerce), if numeric.
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// The boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The array payload, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parsed document: dotted-path key -> value ("section.key").
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Document {
    entries: BTreeMap<String, Value>,
}

impl Document {
    /// The value at a dotted path, if present.
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    /// String at `path`, else `default`.
    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }
    /// Integer at `path`, else `default`.
    pub fn int_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_int).unwrap_or(default)
    }
    /// Float at `path` (integers coerce), else `default`.
    pub fn float_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_float).unwrap_or(default)
    }
    /// Boolean at `path`, else `default`.
    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    /// String at `path`, or a "missing key" error.
    pub fn require_str(&self, path: &str) -> anyhow::Result<&str> {
        self.get(path)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("config: missing string key `{path}`"))
    }

    /// All keys under a section prefix ("train." -> ["train.lr", ...]).
    pub fn keys_under(&self, prefix: &str) -> Vec<&str> {
        self.entries
            .keys()
            .filter(|k| k.starts_with(prefix))
            .map(String::as_str)
            .collect()
    }

    /// Set the value at a dotted path (CLI overrides use this).
    pub fn insert(&mut self, path: &str, v: Value) {
        self.entries.insert(path.to_string(), v);
    }

    /// Number of keys in the document.
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    /// Whether the document has no keys.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn err(line_no: usize, msg: &str) -> anyhow::Error {
    anyhow::anyhow!("toml parse error at line {}: {}", line_no + 1, msg)
}

fn parse_scalar(s: &str, line_no: usize) -> anyhow::Result<Value> {
    let s = s.trim();
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| err(line_no, "unterminated string"))?;
        // basic escapes
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => {
                        return Err(err(
                            line_no,
                            &format!("bad escape \\{other:?}"),
                        ))
                    }
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(line_no, &format!("cannot parse value `{s}`")))
}

/// Split a top-level array body on commas (no nested arrays supported).
fn parse_array(body: &str, line_no: usize) -> anyhow::Result<Value> {
    let body = body.trim();
    if body.is_empty() {
        return Ok(Value::Array(vec![]));
    }
    let mut items = Vec::new();
    let mut depth_quote = false;
    let mut cur = String::new();
    for c in body.chars() {
        match c {
            '"' => {
                depth_quote = !depth_quote;
                cur.push(c);
            }
            ',' if !depth_quote => {
                items.push(parse_scalar(&cur, line_no)?);
                cur.clear();
            }
            '[' | ']' if !depth_quote => {
                return Err(err(line_no, "nested arrays unsupported"))
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        items.push(parse_scalar(&cur, line_no)?);
    }
    Ok(Value::Array(items))
}

/// Strip a trailing comment that is not inside a string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> anyhow::Result<Document> {
    let mut doc = Document::default();
    let mut section = String::new();
    for (line_no, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            if line.starts_with("[[") {
                return Err(err(line_no, "array-of-tables unsupported"));
            }
            let name = stripped
                .strip_suffix(']')
                .ok_or_else(|| err(line_no, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(line_no, "empty section name"));
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(line_no, "expected `key = value`"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(line_no, "empty key"));
        }
        let vtext = line[eq + 1..].trim();
        let value = if let Some(body) = vtext.strip_prefix('[') {
            let body = body
                .strip_suffix(']')
                .ok_or_else(|| err(line_no, "unterminated array"))?;
            parse_array(body, line_no)?
        } else {
            parse_scalar(vtext, line_no)?
        };
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.insert(&path, value);
    }
    Ok(doc)
}

/// Parse from a file path.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Document> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_document() {
        let doc = parse(
            r#"
# experiment
name = "demo"
steps = 400
lr = 4e-3
debug = true

[model]
tag = "gpt2_small"
dims = [1, 2, 3]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "demo");
        assert_eq!(doc.int_or("steps", 0), 400);
        assert!((doc.float_or("lr", 0.0) - 4e-3).abs() < 1e-12);
        assert!(doc.bool_or("debug", false));
        assert_eq!(doc.str_or("model.tag", ""), "gpt2_small");
        let dims = doc.get("model.dims").unwrap().as_array().unwrap();
        assert_eq!(dims.len(), 3);
        assert_eq!(dims[1].as_int(), Some(2));
    }

    #[test]
    fn strings_with_escapes_and_comments() {
        let doc = parse("s = \"a # not comment\\n\" # real comment").unwrap();
        assert_eq!(doc.str_or("s", ""), "a # not comment\n");
    }

    #[test]
    fn dotted_sections() {
        let doc = parse("[a.b]\nc = 1").unwrap();
        assert_eq!(doc.int_or("a.b.c", 0), 1);
    }

    #[test]
    fn int_vs_float() {
        let doc = parse("i = 3\nf = 3.5\nu = 1_000").unwrap();
        assert_eq!(doc.get("i"), Some(&Value::Int(3)));
        assert_eq!(doc.get("f"), Some(&Value::Float(3.5)));
        assert_eq!(doc.int_or("u", 0), 1000);
        // ints coerce to float on demand
        assert_eq!(doc.float_or("i", 0.0), 3.0);
    }

    #[test]
    fn error_cases() {
        assert!(parse("x =").is_err());
        assert!(parse("[unclosed").is_err());
        assert!(parse("x = [1, [2]]").is_err());
        assert!(parse("[[aot]]").is_err());
        assert!(parse("x = @").is_err());
        let e = parse("\n\nbad line").unwrap_err().to_string();
        assert!(e.contains("line 3"), "{e}");
    }

    #[test]
    fn keys_under_prefix() {
        let doc = parse("[t]\na = 1\nb = 2\n[u]\nc = 3").unwrap();
        assert_eq!(doc.keys_under("t.").len(), 2);
    }

    #[test]
    fn bool_array_roundtrip_display() {
        let doc = parse("xs = [true, false]").unwrap();
        assert_eq!(format!("{}", doc.get("xs").unwrap()), "[true, false]");
    }
}
