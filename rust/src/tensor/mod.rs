//! Host-side f32 matrix substrate.
//!
//! The PJRT artifacts do all heavy compute; this module exists so the crate
//! can (a) run exact pure-rust reference implementations of every optimizer
//! for cross-checking the HLO path, (b) compute analysis metrics (Gram
//! diagonal dominance) on checkpoints, and (c) property-test the paper's
//! lemmas without any Python in the loop.

mod matrix;
mod norms;

pub use matrix::Matrix;
pub use norms::{dual_pairing, frobenius, inf2_norm, one2_norm};
