//! Host-side f32 tensor substrate: matrix type, kernels, scratch arena.
//!
//! Layered as:
//!
//! * [`simd`] — the instruction-level layer: explicit AVX2/FMA f32x8
//!   microkernels (dot, packed-B matmul, Gram, axpby, fused row
//!   normalize, NS5 polynomial) behind a runtime dispatch ladder
//!   resolved once at startup (`perf.simd` config key → `RMNP_SIMD` env
//!   var → `is_x86_feature_detected!`). Scalar tiles are the portable
//!   fallback rung.
//! * [`kernels`] — the performance layer: SIMD-dispatched, register-tiled
//!   matmul/Gram microkernels, blocked transpose, fused row
//!   normalization, all with caller-provided `dst` buffers and row-block
//!   multi-threading via `std::thread::scope`. The thread count comes
//!   from the [`kernels::set_num_threads`] knob (config key
//!   `perf.threads`), the `RMNP_THREADS` env var, or
//!   `available_parallelism`, in that order; `StepPlan` workers pin their
//!   thread single-threaded via [`kernels::pin_thread_single`].
//! * [`Matrix`] — the ergonomic owner type. Hot ops delegate to
//!   [`kernels`] and expose `_into(dst)` variants that do not allocate;
//!   the seed's scalar paths survive as `*_naive` parity baselines.
//! * [`Workspace`] — a best-fit scratch-buffer pool so multi-buffer
//!   pipelines (Newton–Schulz iterations, fused optimizer steps) run
//!   allocation-free after warmup.
//! * [`norms`](self) — the paper's norm zoo (Section 5.1) used by the
//!   lemma property tests.
//!
//! The PJRT artifacts do all heavy *training* compute when the `pjrt`
//! feature is on; this module is the native path: exact pure-rust
//! reference implementations for cross-checking, analysis metrics on
//! checkpoints, and the Table 2/3 native benchmarks.

pub mod kernels;
mod matrix;
mod norms;
pub mod simd;
mod workspace;

pub use matrix::Matrix;
pub use norms::{dual_pairing, frobenius, inf2_norm, one2_norm};
pub use workspace::{PackedB, Workspace};
