//! Host-side f32 tensor substrate: matrix type, kernels, scratch arena.
//!
//! Layered as (see `docs/ARCHITECTURE.md` for the full book):
//!
//! * [`simd`] — the instruction-level layer: one set of generic
//!   microkernel bodies (dot, packed matmul, Gram, axpby, fused row
//!   normalize, NS5 polynomial) instantiated per backend — AVX-512F
//!   f32x16 and AVX2/FMA f32x8 on x86-64, NEON f32x4 on aarch64 —
//!   behind a runtime dispatch
//!   ladder resolved at startup (`perf.simd` config key → `RMNP_SIMD`
//!   env var → feature detection). Scalar tiles are the portable
//!   fallback rung.
//! * [`kernels`] — the performance layer: SIMD-dispatched, register-tiled
//!   matmul/Gram microkernels, blocked transpose, fused row
//!   normalization, all with caller-provided `dst` buffers and row-block
//!   multi-threading via `std::thread::scope`. The thread count comes
//!   from the [`kernels::set_num_threads`] knob (config key
//!   `perf.threads`), the `RMNP_THREADS` env var, or
//!   `available_parallelism`, in that order; `StepPlan` workers pin their
//!   thread single-threaded via [`kernels::pin_thread_single`].
//! * [`Matrix`] — the ergonomic owner type. Hot ops delegate to
//!   [`kernels`] and expose `_into(dst)` variants that do not allocate;
//!   the seed's scalar paths survive as `*_naive` parity baselines.
//!   [`Bf16Matrix`] is its bf16-storage sibling for the
//!   `perf.precision = bf16` mode: raw bfloat16 bits that the fused
//!   `bf16_*` kernels read and write directly, with all accumulation in
//!   f32 ([`Precision`] selects the mode per run).
//! * [`Workspace`] — a best-fit scratch-buffer pool so multi-buffer
//!   pipelines (Newton–Schulz iterations, fused optimizer steps) run
//!   allocation-free after warmup. [`PackedB`] (16-column strips) and
//!   [`PackedA`] (4-row panels) are the pack layouts the vector matmul
//!   microkernel streams; the kernel layer keeps one of each per thread.
//! * `norms` — the paper's norm zoo (Section 5.1) used by the lemma
//!   property tests ([`frobenius`], [`one2_norm`], [`inf2_norm`],
//!   [`dual_pairing`]).
//!
//! The PJRT artifacts do all heavy *training* compute when the `pjrt`
//! feature is on; this module is the native path: exact pure-rust
//! reference implementations for cross-checking, analysis metrics on
//! checkpoints, and the Table 2/3 native benchmarks.

pub mod kernels;
mod matrix;
mod norms;
pub mod simd;
mod workspace;

pub use matrix::{Bf16Matrix, Matrix, Precision};
pub use norms::{dual_pairing, frobenius, inf2_norm, one2_norm};
pub use workspace::{PackedA, PackedB, Workspace};
