//! Scratch-buffer arena so hot loops run allocation-free.
//!
//! A [`Workspace`] owns a pool of `Vec<f32>` buffers. [`Workspace::take`]
//! hands out a zeroed buffer of the requested length, reusing pooled
//! capacity best-fit (smallest sufficient buffer wins, so a steady-state
//! call pattern maps each request onto the same buffer every time);
//! [`Workspace::give`] returns it. After the first pass over a fixed set
//! of shapes ("warmup"), no further heap allocation happens — verified by
//! the counting-allocator test in `tests/alloc.rs` and the
//! [`Workspace::fresh_allocs`] counter.

use super::Matrix;

/// Reusable pool of f32 scratch buffers.
#[derive(Clone, Debug, Default)]
pub struct Workspace {
    pool: Vec<Vec<f32>>,
    fresh_allocs: usize,
}

impl Workspace {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// A zero-filled buffer of exactly `len` elements. Reuses the pooled
    /// buffer with the smallest sufficient capacity; allocates (and counts
    /// it) only when no pooled buffer is large enough.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (idx, b) in self.pool.iter().enumerate() {
            let cap = b.capacity();
            let better = match best {
                None => cap >= len,
                Some((_, c)) => cap >= len && cap < c,
            };
            if better {
                best = Some((idx, cap));
            }
        }
        let mut buf = match best {
            Some((idx, _)) => self.pool.swap_remove(idx),
            None => Vec::new(),
        };
        if buf.capacity() < len {
            self.fresh_allocs += 1;
        }
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer to the pool for reuse.
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.pool.push(buf);
        }
    }

    /// A zeroed `rows × cols` matrix backed by a pooled buffer.
    pub fn take_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take(rows * cols))
    }

    /// Return a matrix's backing buffer to the pool.
    pub fn give_matrix(&mut self, m: Matrix) {
        self.give(m.into_vec());
    }

    /// Number of times `take` had to grow/allocate (warmup cost). Stable
    /// across steady-state reuse.
    pub fn fresh_allocs(&self) -> usize {
        self.fresh_allocs
    }

    /// Number of buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn take_is_always_zeroed_no_state_leaks() {
        // property: whatever garbage a previous user wrote, a fresh take
        // of any size sees only zeros
        let mut ws = Workspace::new();
        let mut rng = Rng::new(1);
        for round in 0..50 {
            let len = 1 + rng.below(256) as usize;
            let mut buf = ws.take(len);
            assert!(
                buf.iter().all(|&x| x == 0.0),
                "leaked state in round {round}"
            );
            rng.fill_normal(&mut buf, 10.0); // scribble
            ws.give(buf);
        }
    }

    #[test]
    fn steady_state_reuse_stops_allocating() {
        let mut ws = Workspace::new();
        // warmup: the NS5-like shape set
        let shapes = [(8usize, 24usize), (8, 8), (8, 8), (8, 8), (8, 24)];
        let run = |ws: &mut Workspace| {
            let taken: Vec<Matrix> =
                shapes.iter().map(|&(r, c)| ws.take_matrix(r, c)).collect();
            for m in taken {
                ws.give_matrix(m);
            }
        };
        run(&mut ws);
        let after_warmup = ws.fresh_allocs();
        assert!(after_warmup > 0);
        for _ in 0..20 {
            run(&mut ws);
        }
        assert_eq!(ws.fresh_allocs(), after_warmup, "steady state must not allocate");
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let mut ws = Workspace::new();
        let small = ws.take(10);
        let big = ws.take(1000);
        ws.give(big);
        ws.give(small);
        let b = ws.take(10);
        assert!(b.capacity() < 1000, "should reuse the small buffer");
        ws.give(b);
        assert_eq!(ws.pooled(), 2);
    }

    #[test]
    fn matrix_roundtrip_preserves_capacity() {
        let mut ws = Workspace::new();
        let m = ws.take_matrix(4, 6);
        assert_eq!((m.rows(), m.cols()), (4, 6));
        ws.give_matrix(m);
        let allocs = ws.fresh_allocs();
        let m2 = ws.take_matrix(3, 8);
        ws.give_matrix(m2);
        assert_eq!(ws.fresh_allocs(), allocs, "same size class reuses");
    }
}
