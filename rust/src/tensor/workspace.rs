//! Scratch-buffer arena so hot loops run allocation-free, plus the
//! [`PackedB`] / [`PackedA`] panel layouts the vector matmul microkernel
//! consumes (AVX2 and NEON rungs alike — the layouts are lane-width
//! agnostic).
//!
//! A [`Workspace`] owns a pool of `Vec<f32>` buffers. [`Workspace::take`]
//! hands out a zeroed buffer of the requested length, reusing pooled
//! capacity best-fit (smallest sufficient buffer wins, so a steady-state
//! call pattern maps each request onto the same buffer every time);
//! [`Workspace::give`] returns it. After the first pass over a fixed set
//! of shapes ("warmup"), no further heap allocation happens — verified by
//! the counting-allocator test in `tests/alloc.rs` and the
//! [`Workspace::fresh_allocs`] counter.
//!
//! **Packed-buffer lifetime rule:** `PackedA`/`PackedB` contents are only
//! valid until the next `pack` call on the same instance; the kernel
//! layer packs in the calling thread *before* spawning row-chunk workers,
//! which then share the panels read-only for the duration of one kernel
//! call (see `docs/ARCHITECTURE.md`).

use super::Matrix;

/// A `k×n` B matrix repacked into the strip-major panel layout the
/// vector matmul microkernel streams: the columns are cut into
/// [`PackedB::NR`]-wide strips, and each strip stores its `k` rows
/// contiguously (zero-padded past `n`). One repack per matmul (or per
/// NS5 iteration) replaces the strided row reads the axpy-form kernel
/// would otherwise perform once per 4-row output tile — for k-panels
/// that overflow L2 that means the panel is read from memory once
/// instead of `m/4` times, and the microkernel's accumulators stay in
/// registers across the whole k loop.
///
/// The backing `Vec` only ever grows ([`PackedB::pack`] reuses capacity),
/// so a `PackedB` held per thread is allocation-free after warmup — the
/// kernel layer keeps one in thread-local storage.
#[derive(Clone, Debug, Default)]
pub struct PackedB {
    data: Vec<f32>,
    k: usize,
    n: usize,
}

impl PackedB {
    /// Strip width in columns (two f32x8 vectors on AVX2, four f32x4 on
    /// NEON — the layout is lane-width agnostic).
    pub const NR: usize = 16;

    /// An empty pack buffer (no allocation until the first `pack`).
    pub fn new() -> Self {
        PackedB::default()
    }

    /// Elements a packed `k×n` matrix occupies (strips are padded to NR).
    pub fn packed_len(k: usize, n: usize) -> usize {
        k * n.div_ceil(Self::NR) * Self::NR
    }

    /// Repack `b` (row-major `k×n`) into the panel layout, reusing the
    /// existing allocation when it is large enough.
    pub fn pack(&mut self, b: &[f32], k: usize, n: usize) {
        assert_eq!(b.len(), k * n, "pack shape");
        let nr = Self::NR;
        let strips = n.div_ceil(nr);
        let len = k * strips * nr;
        if self.data.len() < len {
            self.data.resize(len, 0.0);
        }
        self.k = k;
        self.n = n;
        for s in 0..strips {
            let j0 = s * nr;
            let w = nr.min(n - j0);
            let base = s * k * nr;
            for p in 0..k {
                let dst = &mut self.data[base + p * nr..base + (p + 1) * nr];
                dst[..w].copy_from_slice(&b[p * n + j0..p * n + j0 + w]);
                for x in &mut dst[w..] {
                    *x = 0.0;
                }
            }
        }
    }

    /// The packed panel data for the last [`PackedB::pack`] call.
    pub fn data(&self) -> &[f32] {
        &self.data[..Self::packed_len(self.k, self.n)]
    }

    /// `(k, n)` of the currently packed matrix.
    pub fn dims(&self) -> (usize, usize) {
        (self.k, self.n)
    }
}

/// An `m×k` A matrix repacked into [`PackedA::MR`]-row panels for the
/// vector matmul microkernel: rows are cut into 4-row panels, and panel
/// `t` stores, for each `p` in `0..k`, the four values
/// `a[(4t+r)·k + p]` contiguously (`p`-major, row-minor). A 4-row output
/// tile then reads its A operands as one sequential stream instead of
/// four `k`-strided row walks repeated once per 16-column strip — at
/// large `m` that turns `n/16` strided traversals of A into a single
/// sequential pass plus one O(m·k) pack.
///
/// Only full panels are packed: the `m % 4` remainder rows are read
/// straight from the raw matrix by the remainder-row kernel (which is
/// the same per-row arithmetic sequence, so the fast path never changes
/// output bits — see `tensor/simd/lane.rs`).
///
/// Like [`PackedB`], the backing `Vec` only grows, so the thread-local
/// instance the kernel layer keeps is allocation-free after warmup.
#[derive(Clone, Debug, Default)]
pub struct PackedA {
    data: Vec<f32>,
    m: usize,
    k: usize,
}

impl PackedA {
    /// Panel height in rows (matches the microkernel tile height).
    pub const MR: usize = 4;

    /// An empty pack buffer (no allocation until the first `pack`).
    pub fn new() -> Self {
        PackedA::default()
    }

    /// Elements a packed `m×k` matrix occupies (full panels only).
    pub fn packed_len(m: usize, k: usize) -> usize {
        (m / Self::MR) * Self::MR * k
    }

    /// Repack `a` (row-major `m×k`) into the panel layout, reusing the
    /// existing allocation when it is large enough.
    pub fn pack(&mut self, a: &[f32], m: usize, k: usize) {
        assert_eq!(a.len(), m * k, "pack shape");
        let mr = Self::MR;
        let panels = m / mr;
        let len = panels * mr * k;
        if self.data.len() < len {
            self.data.resize(len, 0.0);
        }
        self.m = m;
        self.k = k;
        for t in 0..panels {
            let base = t * mr * k;
            let r0 = t * mr;
            // four sequential source streams, one interleaved dst stream
            for p in 0..k {
                let dst = &mut self.data[base + p * mr..base + (p + 1) * mr];
                for (r, x) in dst.iter_mut().enumerate() {
                    *x = a[(r0 + r) * k + p];
                }
            }
        }
    }

    /// The packed panel data for the last [`PackedA::pack`] call.
    pub fn data(&self) -> &[f32] {
        &self.data[..Self::packed_len(self.m, self.k)]
    }

    /// `(m, k)` of the currently packed matrix (`m` includes the
    /// unpacked remainder rows).
    pub fn dims(&self) -> (usize, usize) {
        (self.m, self.k)
    }
}

/// Reusable pool of f32 scratch buffers.
///
/// ```
/// use rmnp::tensor::Workspace;
/// let mut ws = Workspace::new();
/// let buf = ws.take(128);              // zeroed, counted as one alloc
/// assert!(buf.iter().all(|&x| x == 0.0));
/// ws.give(buf);
/// let again = ws.take(64);             // reuses the pooled capacity
/// assert_eq!(ws.fresh_allocs(), 1, "steady state allocates nothing");
/// ws.give(again);
/// ```
#[derive(Clone, Debug, Default)]
pub struct Workspace {
    pool: Vec<Vec<f32>>,
    fresh_allocs: usize,
}

impl Workspace {
    /// An empty pool (no allocation until the first `take`).
    pub fn new() -> Self {
        Workspace::default()
    }

    /// A zero-filled buffer of exactly `len` elements. Reuses the pooled
    /// buffer with the smallest sufficient capacity; allocates (and counts
    /// it) only when no pooled buffer is large enough.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (idx, b) in self.pool.iter().enumerate() {
            let cap = b.capacity();
            let better = match best {
                None => cap >= len,
                Some((_, c)) => cap >= len && cap < c,
            };
            if better {
                best = Some((idx, cap));
            }
        }
        let mut buf = match best {
            Some((idx, _)) => self.pool.swap_remove(idx),
            None => Vec::new(),
        };
        if buf.capacity() < len {
            self.fresh_allocs += 1;
        }
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer to the pool for reuse.
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.pool.push(buf);
        }
    }

    /// A zeroed `rows × cols` matrix backed by a pooled buffer.
    pub fn take_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take(rows * cols))
    }

    /// Return a matrix's backing buffer to the pool.
    pub fn give_matrix(&mut self, m: Matrix) {
        self.give(m.into_vec());
    }

    /// Number of times `take` had to grow/allocate (warmup cost). Stable
    /// across steady-state reuse.
    pub fn fresh_allocs(&self) -> usize {
        self.fresh_allocs
    }

    /// Number of buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn take_is_always_zeroed_no_state_leaks() {
        // property: whatever garbage a previous user wrote, a fresh take
        // of any size sees only zeros
        let mut ws = Workspace::new();
        let mut rng = Rng::new(1);
        for round in 0..50 {
            let len = 1 + rng.below(256) as usize;
            let mut buf = ws.take(len);
            assert!(
                buf.iter().all(|&x| x == 0.0),
                "leaked state in round {round}"
            );
            rng.fill_normal(&mut buf, 10.0); // scribble
            ws.give(buf);
        }
    }

    #[test]
    fn steady_state_reuse_stops_allocating() {
        let mut ws = Workspace::new();
        // warmup: the NS5-like shape set
        let shapes = [(8usize, 24usize), (8, 8), (8, 8), (8, 8), (8, 24)];
        let run = |ws: &mut Workspace| {
            let taken: Vec<Matrix> =
                shapes.iter().map(|&(r, c)| ws.take_matrix(r, c)).collect();
            for m in taken {
                ws.give_matrix(m);
            }
        };
        run(&mut ws);
        let after_warmup = ws.fresh_allocs();
        assert!(after_warmup > 0);
        for _ in 0..20 {
            run(&mut ws);
        }
        assert_eq!(ws.fresh_allocs(), after_warmup, "steady state must not allocate");
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let mut ws = Workspace::new();
        let small = ws.take(10);
        let big = ws.take(1000);
        ws.give(big);
        ws.give(small);
        let b = ws.take(10);
        assert!(b.capacity() < 1000, "should reuse the small buffer");
        ws.give(b);
        assert_eq!(ws.pooled(), 2);
    }

    #[test]
    fn packed_b_layout_roundtrip() {
        // every (p, j) element must land at its strip-major slot, padded
        // lanes must be zero, and repacking a smaller shape must reuse
        // (not shrink) the allocation
        let mut rng = Rng::new(2);
        let (k, n) = (5usize, 37usize); // 3 strips: 16 + 16 + 5(+11 pad)
        let mut b = vec![0.0f32; k * n];
        rng.fill_normal(&mut b, 1.0);
        let mut pb = PackedB::new();
        pb.pack(&b, k, n);
        assert_eq!(pb.dims(), (k, n));
        let nr = PackedB::NR;
        let data = pb.data();
        assert_eq!(data.len(), PackedB::packed_len(k, n));
        for p in 0..k {
            for j in 0..n {
                let s = j / nr;
                let got = data[s * k * nr + p * nr + (j - s * nr)];
                assert_eq!(got, b[p * n + j], "({p},{j})");
            }
        }
        // padded tail lanes are zero
        let last = 2 * k * nr;
        for p in 0..k {
            for lane in 5..nr {
                assert_eq!(data[last + p * nr + lane], 0.0);
            }
        }
        // repack smaller: capacity reused, dims/len updated
        let cap_before = pb.data.capacity();
        let b2 = vec![1.0f32; 2 * 3];
        pb.pack(&b2, 2, 3);
        assert_eq!(pb.dims(), (2, 3));
        assert_eq!(pb.data().len(), PackedB::packed_len(2, 3));
        assert_eq!(pb.data.capacity(), cap_before, "pack must not shrink");
        assert_eq!(pb.data()[0], 1.0);
        assert_eq!(pb.data()[3], 0.0, "padding re-zeroed");
    }

    #[test]
    fn packed_a_layout_roundtrip() {
        // every (row, p) element of a full panel must land at its
        // p-major/row-minor slot; remainder rows are not packed; and
        // repacking a smaller shape reuses (not shrinks) the allocation
        let mut rng = Rng::new(3);
        let (m, k) = (11usize, 7usize); // 2 full panels + 3 remainder rows
        let mut a = vec![0.0f32; m * k];
        rng.fill_normal(&mut a, 1.0);
        let mut pa = PackedA::new();
        pa.pack(&a, m, k);
        assert_eq!(pa.dims(), (m, k));
        let mr = PackedA::MR;
        let data = pa.data();
        assert_eq!(data.len(), PackedA::packed_len(m, k));
        assert_eq!(data.len(), (m / mr) * mr * k);
        for t in 0..m / mr {
            for p in 0..k {
                for r in 0..mr {
                    let got = data[t * mr * k + p * mr + r];
                    assert_eq!(got, a[(t * mr + r) * k + p], "panel {t} ({p},{r})");
                }
            }
        }
        // repack smaller: capacity reused, dims/len updated
        let cap_before = pa.data.capacity();
        let a2 = vec![2.0f32; 4 * 3];
        pa.pack(&a2, 4, 3);
        assert_eq!(pa.dims(), (4, 3));
        assert_eq!(pa.data().len(), PackedA::packed_len(4, 3));
        assert_eq!(pa.data.capacity(), cap_before, "pack must not shrink");
        assert!(pa.data().iter().all(|&x| x == 2.0));
        // fewer than MR rows pack to an empty panel set
        pa.pack(&[1.0, 2.0, 3.0], 3, 1);
        assert!(pa.data().is_empty());
    }

    #[test]
    fn matrix_roundtrip_preserves_capacity() {
        let mut ws = Workspace::new();
        let m = ws.take_matrix(4, 6);
        assert_eq!((m.rows(), m.cols()), (4, 6));
        ws.give_matrix(m);
        let allocs = ws.fresh_allocs();
        let m2 = ws.take_matrix(3, 8);
        ws.give_matrix(m2);
        assert_eq!(ws.fresh_allocs(), allocs, "same size class reuses");
    }
}
