//! Performance-first tensor kernels: register-tiled, multi-threaded, and
//! allocation-free.
//!
//! Every kernel here writes into a caller-provided `dst` slice so hot loops
//! (NS5 iterations, fused optimizer steps) can run on preallocated
//! [`super::Workspace`] buffers. Design notes:
//!
//! * **Matmul microkernel** — the inner loop is the axpy form
//!   `dst_row[j] += a_ip * b_row[j]`, blocked 4 output rows at a time
//!   ([`MR`]) so each streamed row of B feeds four accumulator rows
//!   (4× the arithmetic intensity of the scalar loop), with a [`KC`]-wide
//!   k-panel so the active B panel stays cache-resident. The four dst-row
//!   streams are independent elementwise updates, which LLVM vectorizes;
//!   the seed implementation's `a == 0.0` branch is gone from the inner
//!   loop. Accumulation order over `p` is unchanged from the naive kernel,
//!   so results are bit-identical on finite inputs.
//! * **Reductions** — strict FP forbids LLVM from vectorizing
//!   `s += x*y` loops, so dot products ([`dot`]) and row sum-of-squares
//!   ([`row_sumsq`]) accumulate into 8 independent lanes and fold at the
//!   end. This reassociates the sum (results differ from a sequential sum
//!   by normal f32 rounding, covered by the parity tests).
//! * **Threading** — row-block parallelism over `std::thread::scope`; the
//!   symmetric [`gram_into`] balances its upper-triangle row blocks by
//!   area. The thread count comes from [`num_threads`]: the
//!   [`set_num_threads`] knob (wired to the `perf.threads` config key),
//!   else the `RMNP_THREADS` env var, else `available_parallelism`.
//!   Small problems stay single-threaded (spawn cost dominates).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Output rows per register tile in matmul/gram.
const MR: usize = 4;
/// k-panel width: `KC * 4B` per streamed B row chunk stays L1/L2-friendly.
const KC: usize = 256;
/// Reduction lanes (accumulator count) for dot-style loops.
const LANES: usize = 8;
/// Minimum multiply-adds before a matmul/gram goes multi-threaded.
const PAR_MIN_MULS: usize = 1 << 20;
/// Minimum elements before an elementwise/row kernel goes multi-threaded.
const PAR_MIN_ELEMS: usize = 1 << 19;

static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the kernel thread count (0 restores auto detection). Wired to the
/// `perf.threads` config key and the CLI. Capped at 256: `plan_threads`
/// clamps to the row count, so an absurd override would otherwise degrade
/// into one-thread-per-row spawn storms.
pub fn set_num_threads(n: usize) {
    THREAD_OVERRIDE.store(n.min(256), Ordering::Relaxed);
}

/// Effective kernel thread count: explicit override, else `RMNP_THREADS`,
/// else `available_parallelism` (capped at 16).
pub fn num_threads() -> usize {
    let n = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        std::env::var("RMNP_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
                    .min(16)
            })
    })
}

fn plan_threads(units: usize, work: usize, min_work: usize) -> usize {
    if work < min_work || units < 2 {
        1
    } else {
        num_threads().clamp(1, units)
    }
}

/// 8-lane dot product of two equal-length slices.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let o = c * LANES;
        let xb = &x[o..o + LANES];
        let yb = &y[o..o + LANES];
        for l in 0..LANES {
            acc[l] += xb[l] * yb[l];
        }
    }
    let mut s = 0.0f32;
    for a in acc {
        s += a;
    }
    for p in chunks * LANES..n {
        s += x[p] * y[p];
    }
    s
}

/// 8-lane sum of squares of a row.
#[inline]
pub fn row_sumsq(row: &[f32]) -> f32 {
    dot(row, row)
}

/// `dst (m×n) = a (m×k) · b (k×n)`. `dst` is fully overwritten.
pub fn matmul_into(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(dst.len(), m * n, "matmul dst shape");
    assert_eq!(a.len(), m * k, "matmul lhs shape");
    assert_eq!(b.len(), k * n, "matmul rhs shape");
    let t = plan_threads(m, m * n * k, PAR_MIN_MULS);
    if t <= 1 {
        matmul_rows(dst, a, b, k, n);
        return;
    }
    let rows_per = m.div_ceil(t);
    std::thread::scope(|s| {
        let mut dst_rest = dst;
        let mut i0 = 0usize;
        while i0 < m {
            let take = rows_per.min(m - i0);
            let (chunk, rest) = std::mem::take(&mut dst_rest).split_at_mut(take * n);
            dst_rest = rest;
            let a_chunk = &a[i0 * k..(i0 + take) * k];
            s.spawn(move || matmul_rows(chunk, a_chunk, b, k, n));
            i0 += take;
        }
    });
}

/// Serial register-tiled matmul over a contiguous block of output rows.
fn matmul_rows(dst: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize) {
    dst.fill(0.0);
    if n == 0 || k == 0 {
        return;
    }
    let m = dst.len() / n;
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        let mut i = 0;
        // 4-row register tiles
        while i + MR <= m {
            let base = i * n;
            let block = &mut dst[base..base + MR * n];
            let (r0, rest) = block.split_at_mut(n);
            let (r1, rest) = rest.split_at_mut(n);
            let (r2, r3) = rest.split_at_mut(n);
            for p in kk..kend {
                let a0 = a[i * k + p];
                let a1 = a[(i + 1) * k + p];
                let a2 = a[(i + 2) * k + p];
                let a3 = a[(i + 3) * k + p];
                let brow = &b[p * n..p * n + n];
                for j in 0..n {
                    let x = brow[j];
                    r0[j] += a0 * x;
                    r1[j] += a1 * x;
                    r2[j] += a2 * x;
                    r3[j] += a3 * x;
                }
            }
            i += MR;
        }
        // remainder rows
        while i < m {
            let row = &mut dst[i * n..(i + 1) * n];
            for p in kk..kend {
                let av = a[i * k + p];
                let brow = &b[p * n..p * n + n];
                for j in 0..n {
                    row[j] += av * brow[j];
                }
            }
            i += 1;
        }
        kk = kend;
    }
}

/// `dst (m×m) = a (m×k) · aᵀ`. Computes the upper triangle with 4-row
/// register tiles (each streamed row `a_j` feeds four dot lanes), threads
/// over area-balanced row blocks, then mirrors to the lower triangle.
pub fn gram_into(dst: &mut [f32], a: &[f32], m: usize, k: usize) {
    assert_eq!(dst.len(), m * m, "gram dst shape");
    assert_eq!(a.len(), m * k, "gram src shape");
    // upper-triangle work ≈ m²k/2 multiply-adds
    let t = plan_threads(m, m * m * k / 2, PAR_MIN_MULS);
    if t <= 1 {
        gram_rows(dst, a, 0, m, m, k);
    } else {
        let bounds = triangle_partition(m, t);
        // reborrow (not move) so `dst` is usable again for the mirror pass
        // once every scoped borrow has ended
        let mut dst_rest: &mut [f32] = &mut *dst;
        std::thread::scope(|s| {
            for w in bounds.windows(2) {
                let (i0, i1) = (w[0], w[1]);
                if i1 <= i0 {
                    continue;
                }
                let (chunk, rest) =
                    std::mem::take(&mut dst_rest).split_at_mut((i1 - i0) * m);
                dst_rest = rest;
                s.spawn(move || gram_rows(chunk, a, i0, i1, m, k));
            }
        });
    }
    // mirror the strict lower triangle from the upper
    mirror_lower(dst, m);
}

fn mirror_lower(dst: &mut [f32], m: usize) {
    for i in 1..m {
        for j in 0..i {
            dst[i * m + j] = dst[j * m + i];
        }
    }
}

/// Row boundaries `0 = b0 < … < bt = m` splitting the upper-triangle area
/// roughly evenly: rows `0..x` cover area `x·m − x(x−1)/2`, so the b-th
/// boundary solves the quadratic for `b/t` of the total.
fn triangle_partition(m: usize, t: usize) -> Vec<usize> {
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0usize);
    let mf = m as f64;
    let total = mf * (mf + 1.0) / 2.0;
    for b in 1..t {
        let target = total * b as f64 / t as f64;
        let x = mf - (mf * mf - 2.0 * target).max(0.0).sqrt();
        let prev = *bounds.last().unwrap();
        bounds.push((x.round() as usize).clamp(prev, m));
    }
    bounds.push(m);
    bounds
}

/// Upper-triangle rows `i0..i1` of the Gram matrix into `dst_chunk`
/// (which holds full rows `i0..i1`, each of length `m`). Entries strictly
/// left of the diagonal within a 4-row tile are computed too (they are
/// correct values); the mirror pass makes the lower triangle consistent.
fn gram_rows(dst_chunk: &mut [f32], a: &[f32], i0: usize, i1: usize, m: usize, k: usize) {
    let mut i = i0;
    while i < i1 {
        if i + MR <= i1 {
            let ri0 = &a[i * k..(i + 1) * k];
            let ri1 = &a[(i + 1) * k..(i + 2) * k];
            let ri2 = &a[(i + 2) * k..(i + 3) * k];
            let ri3 = &a[(i + 3) * k..(i + 4) * k];
            let base = (i - i0) * m;
            let block = &mut dst_chunk[base..base + MR * m];
            let (o0, rest) = block.split_at_mut(m);
            let (o1, rest) = rest.split_at_mut(m);
            let (o2, o3) = rest.split_at_mut(m);
            let chunks = k / LANES;
            for j in i..m {
                let rj = &a[j * k..(j + 1) * k];
                let mut acc0 = [0.0f32; LANES];
                let mut acc1 = [0.0f32; LANES];
                let mut acc2 = [0.0f32; LANES];
                let mut acc3 = [0.0f32; LANES];
                for c in 0..chunks {
                    let o = c * LANES;
                    let rjb = &rj[o..o + LANES];
                    let r0b = &ri0[o..o + LANES];
                    let r1b = &ri1[o..o + LANES];
                    let r2b = &ri2[o..o + LANES];
                    let r3b = &ri3[o..o + LANES];
                    for l in 0..LANES {
                        let x = rjb[l];
                        acc0[l] += r0b[l] * x;
                        acc1[l] += r1b[l] * x;
                        acc2[l] += r2b[l] * x;
                        acc3[l] += r3b[l] * x;
                    }
                }
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
                for l in 0..LANES {
                    s0 += acc0[l];
                    s1 += acc1[l];
                    s2 += acc2[l];
                    s3 += acc3[l];
                }
                for p in chunks * LANES..k {
                    let x = rj[p];
                    s0 += ri0[p] * x;
                    s1 += ri1[p] * x;
                    s2 += ri2[p] * x;
                    s3 += ri3[p] * x;
                }
                o0[j] = s0;
                o1[j] = s1;
                o2[j] = s2;
                o3[j] = s3;
            }
            i += MR;
        } else {
            let ri = &a[i * k..(i + 1) * k];
            let base = (i - i0) * m;
            let orow = &mut dst_chunk[base..base + m];
            for j in i..m {
                orow[j] = dot(ri, &a[j * k..(j + 1) * k]);
            }
            i += 1;
        }
    }
}

/// `dst (cols×rows) = src (rows×cols)ᵀ`, 32×32 cache tiles.
pub fn transpose_into(dst: &mut [f32], src: &[f32], rows: usize, cols: usize) {
    assert_eq!(dst.len(), rows * cols, "transpose dst shape");
    assert_eq!(src.len(), rows * cols, "transpose src shape");
    const TB: usize = 32;
    let mut ii = 0;
    while ii < rows {
        let iend = (ii + TB).min(rows);
        let mut jj = 0;
        while jj < cols {
            let jend = (jj + TB).min(cols);
            for i in ii..iend {
                for j in jj..jend {
                    dst[j * rows + i] = src[i * cols + j];
                }
            }
            jj = jend;
        }
        ii = iend;
    }
}

/// `dst = a·x + b·y` elementwise.
pub fn axpby_into(dst: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
    assert_eq!(dst.len(), x.len(), "axpby dst/x shape");
    assert_eq!(x.len(), y.len(), "axpby x/y shape");
    for i in 0..dst.len() {
        dst[i] = a * x[i] + b * y[i];
    }
}

/// `x = a·x + b·y` elementwise, in place.
pub fn axpby_inplace(x: &mut [f32], a: f32, y: &[f32], b: f32) {
    assert_eq!(x.len(), y.len(), "axpby_inplace shape");
    for i in 0..x.len() {
        x[i] = a * x[i] + b * y[i];
    }
}

/// `dst[i,:] = src[i,:] / max(‖src[i,:]‖₂, eps)` — the RMNP preconditioner
/// (Algorithm 2 line 5), single pass, threaded over row blocks.
pub fn row_normalize_into(
    dst: &mut [f32],
    src: &[f32],
    rows: usize,
    cols: usize,
    eps: f32,
) {
    assert_eq!(dst.len(), rows * cols, "rownorm dst shape");
    assert_eq!(src.len(), rows * cols, "rownorm src shape");
    let t = plan_threads(rows, rows * cols, PAR_MIN_ELEMS);
    if t <= 1 {
        row_normalize_rows(dst, src, cols, eps);
        return;
    }
    let rows_per = rows.div_ceil(t);
    std::thread::scope(|s| {
        let mut dst_rest = dst;
        let mut i0 = 0usize;
        while i0 < rows {
            let take = rows_per.min(rows - i0);
            let (chunk, rest) = std::mem::take(&mut dst_rest).split_at_mut(take * cols);
            dst_rest = rest;
            let src_chunk = &src[i0 * cols..(i0 + take) * cols];
            s.spawn(move || row_normalize_rows(chunk, src_chunk, cols, eps));
            i0 += take;
        }
    });
}

fn row_normalize_rows(dst: &mut [f32], src: &[f32], cols: usize, eps: f32) {
    if cols == 0 {
        return;
    }
    let rows = dst.len() / cols;
    for i in 0..rows {
        let o = i * cols;
        let srow = &src[o..o + cols];
        let inv = 1.0 / row_sumsq(srow).sqrt().max(eps);
        let drow = &mut dst[o..o + cols];
        for j in 0..cols {
            drow[j] = srow[j] * inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    out[i * n + j] += av * b[p * n + j];
                }
            }
        }
        out
    }

    fn randv(len: usize, rng: &mut Rng) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn matmul_matches_naive_across_shapes() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [
            (1, 1, 1),
            (4, 4, 4),
            (5, 7, 3),
            (33, 65, 17),
            (2, 128, 130),
            (130, 3, 2),
            (8, 1, 8),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let want = naive_matmul(&a, &b, m, k, n);
            let mut got = vec![0.0f32; m * n];
            matmul_into(&mut got, &a, &b, m, k, n);
            for (x, y) in got.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3, "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn matmul_threaded_matches_serial() {
        // force the parallel path by size, compare against the serial kernel
        let mut rng = Rng::new(2);
        let (m, k, n) = (67, 129, 131);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let mut serial = vec![0.0f32; m * n];
        matmul_rows(&mut serial, &a, &b, k, n);
        set_num_threads(3);
        let mut par = vec![0.0f32; m * n];
        matmul_into(&mut par, &a, &b, m, k, n);
        set_num_threads(0);
        // row partitioning does not change per-element accumulation order
        assert_eq!(serial, par);
    }

    #[test]
    fn gram_matches_matmul_transpose() {
        let mut rng = Rng::new(3);
        for (m, k) in [(1, 5), (6, 11), (13, 64), (40, 9), (4, 8)] {
            let a = randv(m * k, &mut rng);
            let mut at = vec![0.0f32; m * k];
            transpose_into(&mut at, &a, m, k);
            let want = naive_matmul(&a, &at, m, k, m);
            let mut got = vec![0.0f32; m * m];
            gram_into(&mut got, &a, m, k);
            for (idx, (x, y)) in got.iter().zip(&want).enumerate() {
                assert!((x - y).abs() < 1e-3, "({m},{k}) at {idx}: {x} vs {y}");
            }
            // exact symmetry by construction
            for i in 0..m {
                for j in 0..m {
                    assert_eq!(got[i * m + j], got[j * m + i]);
                }
            }
        }
    }

    #[test]
    fn gram_threaded_matches_serial() {
        let mut rng = Rng::new(4);
        // big enough to cross PAR_MIN_MULS so the threaded path runs
        let (m, k) = (160, 90);
        let a = randv(m * k, &mut rng);
        let mut serial = vec![0.0f32; m * m];
        gram_rows(&mut serial, &a, 0, m, m, k);
        mirror_lower(&mut serial, m);
        set_num_threads(4);
        let mut par = vec![0.0f32; m * m];
        gram_into(&mut par, &a, m, k);
        set_num_threads(0);
        for (x, y) in par.iter().zip(&serial) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn triangle_partition_covers_and_orders() {
        for m in [1usize, 2, 7, 100, 1023] {
            for t in [1usize, 2, 3, 8] {
                let b = triangle_partition(m, t);
                assert_eq!(*b.first().unwrap(), 0);
                assert_eq!(*b.last().unwrap(), m);
                assert!(b.windows(2).all(|w| w[0] <= w[1]), "{b:?}");
            }
        }
    }

    #[test]
    fn transpose_blocked_matches_simple() {
        let mut rng = Rng::new(5);
        for (r, c) in [(1, 1), (3, 5), (33, 70), (64, 64)] {
            let src = randv(r * c, &mut rng);
            let mut dst = vec![0.0f32; r * c];
            transpose_into(&mut dst, &src, r, c);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(dst[j * r + i], src[i * c + j]);
                }
            }
        }
    }

    #[test]
    fn axpby_variants() {
        let x = [1.0f32, 2.0, 3.0];
        let y = [10.0f32, 10.0, 10.0];
        let mut dst = [0.0f32; 3];
        axpby_into(&mut dst, 2.0, &x, 0.5, &y);
        assert_eq!(dst, [7.0, 9.0, 11.0]);
        let mut xm = x;
        axpby_inplace(&mut xm, 2.0, &y, 0.5);
        assert_eq!(xm, [7.0, 9.0, 11.0]);
    }

    #[test]
    fn rownorm_unit_rows_and_zero_rows() {
        let mut rng = Rng::new(6);
        let (rows, cols) = (9, 37);
        let mut src = randv(rows * cols, &mut rng);
        // make one row exactly zero
        for v in &mut src[3 * cols..4 * cols] {
            *v = 0.0;
        }
        let mut dst = vec![0.0f32; rows * cols];
        row_normalize_into(&mut dst, &src, rows, cols, 1e-7);
        for i in 0..rows {
            let n = row_sumsq(&dst[i * cols..(i + 1) * cols]).sqrt();
            if i == 3 {
                assert_eq!(n, 0.0, "zero row must stay zero");
            } else {
                assert!((n - 1.0).abs() < 1e-5, "row {i} norm {n}");
            }
        }
    }

    #[test]
    fn rownorm_threaded_matches_serial() {
        let mut rng = Rng::new(7);
        // large enough to cross PAR_MIN_ELEMS so the threaded path runs
        let (rows, cols) = (1024, 513);
        let src = randv(rows * cols, &mut rng);
        let mut serial = vec![0.0f32; rows * cols];
        row_normalize_rows(&mut serial, &src, cols, 1e-7);
        set_num_threads(5);
        let mut par = vec![0.0f32; rows * cols];
        row_normalize_into(&mut par, &src, rows, cols, 1e-7);
        set_num_threads(0);
        assert_eq!(serial, par);
    }

    #[test]
    fn dot_matches_sequential() {
        let mut rng = Rng::new(8);
        for len in [0usize, 1, 7, 8, 9, 64, 100] {
            let x = randv(len, &mut rng);
            let y = randv(len, &mut rng);
            let seq: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - seq).abs() < 1e-3 * (1.0 + seq.abs()));
        }
    }
}
