//! Performance-first tensor kernels: SIMD-dispatched, register-tiled,
//! multi-threaded, and allocation-free.
//!
//! Every kernel here writes into a caller-provided `dst` slice so hot
//! loops (NS5 iterations, fused optimizer steps) can run on preallocated
//! [`super::Workspace`] buffers. Design notes:
//!
//! * **Dispatch** — each public kernel resolves the
//!   [`super::simd`] ladder (config override → `RMNP_SIMD` env → runtime
//!   feature detection, cached once) and takes the AVX-512F f32x16 or
//!   AVX2/FMA f32x8 path (x86-64), the NEON f32x4 path (aarch64), or the
//!   portable scalar tiles below. All vector backends instantiate the
//!   same generic microkernel bodies (`tensor/simd/lane.rs`), so they
//!   share one loop structure and one set of invariants. All rungs agree
//!   within normal f32 rounding (1e-4 in the parity tests); within one
//!   rung results are bit-deterministic regardless of thread count. The
//!   bf16 storage kernels (`bf16_*` below) are stricter: their f32
//!   arithmetic carries no fused contraction and a pinned reduction
//!   order, so their results are bit-identical across *all* rungs.
//! * **Matmul** — the vector path repacks B into the [`super::PackedB`]
//!   strip-major panel layout and, for row counts past the
//!   [`pack_a_min_rows`] threshold, additionally repacks A into
//!   [`super::PackedA`] 4-row panels (both packed once per matmul in the
//!   calling thread into thread-local buffers, reused across calls), then
//!   runs a 4-row × 16-column register-tile microkernel whose
//!   accumulators live in registers across the whole k loop. Packed-A
//!   swaps the tile's four `k`-strided A row walks (repeated once per
//!   column strip) for one sequential panel stream; packing is an exact
//!   copy, so the fast path never changes output bits. The scalar
//!   fallback keeps PR 1's axpy-form 4-row tiles with a `KC`-wide
//!   k-panel; its accumulation order matches the seed kernel exactly, so
//!   the forced-scalar path is bit-identical to `matmul_naive`.
//! * **NS5 polynomial fusion** — [`ns_poly_into`] computes `bA + cA²`
//!   directly (init `b·A`, then accumulate `c·A·A` into the same buffer),
//!   so Newton–Schulz no longer materializes the m×m `A²` intermediate.
//! * **Reductions** — strict FP forbids LLVM from vectorizing `s += x*y`
//!   loops, so the scalar [`dot`] accumulates into 8 independent lanes;
//!   the vector dot uses four register FMA accumulators. Both
//!   reassociate the sum (covered by the parity tests).
//! * **Threading** — row-block parallelism over `std::thread::scope`,
//!   with chunk boundaries aligned to the 4-row tile height so packed-A
//!   panels split cleanly across workers; the symmetric [`gram_into`]
//!   balances its upper-triangle row blocks by area. The thread count
//!   comes from [`num_threads`]: the [`set_num_threads`] knob (wired to
//!   the `perf.threads` config key), else the `RMNP_THREADS` env var,
//!   else `available_parallelism`. Small problems stay single-threaded
//!   (spawn cost dominates), and a thread that called
//!   [`pin_thread_single`] (a `StepPlan` worker) never spawns nested
//!   kernel threads.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use crate::tensor::simd::{self, SimdPath};
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use crate::tensor::{PackedA, PackedB};
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use std::cell::RefCell;

/// Output rows per register tile in matmul/gram.
const MR: usize = 4;
/// k-panel width: `KC * 4B` per streamed B row chunk stays L1/L2-friendly.
const KC: usize = 256;
/// Reduction lanes (accumulator count) for scalar dot-style loops.
const LANES: usize = 8;
/// Minimum multiply-adds before a matmul/gram goes multi-threaded.
const PAR_MIN_MULS: usize = 1 << 20;
/// Minimum elements before an elementwise/row kernel goes multi-threaded.
const PAR_MIN_ELEMS: usize = 1 << 19;
/// Minimum slice length before `dot`/`axpby` take the vector call (below
/// this the cross-crate call outweighs the vector win).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
const SIMD_MIN_ELEMS: usize = 16;
/// Default minimum output rows before the vector matmul additionally
/// packs A into [`PackedA`] panels. Packing costs one O(m·k) pass; the
/// win is replacing `⌈n/16⌉` strided traversals of A with sequential
/// panel reads, so it needs enough rows (and more than one column strip
/// — see the `n > PackedB::NR` guard at the call sites) to pay for
/// itself. Tunable via [`set_pack_a_min_rows`] (the
/// `perf.pack_a_min_rows` config key) or the `RMNP_PACK_A_MIN_ROWS`
/// env var; the packed and unpacked paths are bit-identical, so the
/// threshold only moves speed, never results.
const PACK_A_MIN_ROWS_DEFAULT: usize = 64;

static PACK_A_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Set the packed-A row threshold (0 restores default/env resolution).
/// Wired to the `perf.pack_a_min_rows` config key. Safe to tune freely:
/// packing A is an exact copy with unchanged arithmetic order, so any
/// threshold produces bit-identical results (asserted by the
/// `pack_a_threshold_is_bit_invariant` test below).
pub fn set_pack_a_min_rows(n: usize) {
    PACK_A_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Effective packed-A row threshold: explicit override, else
/// `RMNP_PACK_A_MIN_ROWS`, else [`PACK_A_MIN_ROWS_DEFAULT`].
pub fn pack_a_min_rows() -> usize {
    let n = PACK_A_OVERRIDE.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("RMNP_PACK_A_MIN_ROWS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(PACK_A_MIN_ROWS_DEFAULT)
    })
}

// the scalar tile height must match the packed-A panel height, or the
// aligned row partition would split panels across workers
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
const _: () = assert!(MR == PackedA::MR);

static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// When set, kernels on this thread never spawn: `StepPlan` workers
    /// pin themselves single-threaded so sharding across params composes
    /// with (instead of multiplying) intra-kernel threading, and so the
    /// stepped bits are identical for any `perf.plan_threads`.
    static SINGLE_SCOPE: Cell<bool> = const { Cell::new(false) };
}

/// Pin (or unpin) the calling thread to single-threaded kernel execution.
pub fn pin_thread_single(single: bool) {
    SINGLE_SCOPE.with(|c| c.set(single));
}

/// Run `f` with intra-kernel threading disabled on the calling thread,
/// restoring the previous pin state afterwards — panics included (a drop
/// guard unpins during unwind, so a caught panic cannot leave the thread
/// permanently single-threaded).
pub fn run_single_threaded<R>(f: impl FnOnce() -> R) -> R {
    struct Restore(bool);
    impl Drop for Restore {
        fn drop(&mut self) {
            SINGLE_SCOPE.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(SINGLE_SCOPE.with(|c| c.replace(true)));
    f()
}

/// Set the kernel thread count (0 restores auto detection). Wired to the
/// `perf.threads` config key and the CLI. Capped at 256: `plan_threads`
/// clamps to the row count, so an absurd override would otherwise degrade
/// into one-thread-per-row spawn storms.
pub fn set_num_threads(n: usize) {
    THREAD_OVERRIDE.store(n.min(256), Ordering::Relaxed);
}

/// Effective kernel thread count: explicit override, else `RMNP_THREADS`,
/// else `available_parallelism` (capped at 16).
pub fn num_threads() -> usize {
    let n = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        std::env::var("RMNP_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
                    .min(16)
            })
    })
}

fn plan_threads(units: usize, work: usize, min_work: usize) -> usize {
    if SINGLE_SCOPE.with(|c| c.get()) || work < min_work || units < 2 {
        1
    } else {
        num_threads().clamp(1, units)
    }
}

/// Dot product of two equal-length slices (SIMD-dispatched).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if x.len() >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: active() returns Avx2 only when avx2+fma are detected
            SimdPath::Avx2 => return unsafe { simd::avx2::dot(x, y) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: active() returns Avx512 only when avx512f is detected
            SimdPath::Avx512 => return unsafe { simd::avx512::dot(x, y) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: active() returns Neon only when neon is detected
            SimdPath::Neon => return unsafe { simd::neon::dot(x, y) },
            _ => {}
        }
    }
    dot_scalar(x, y)
}

/// 8-lane scalar dot product (the portable rung, and the fold the scalar
/// Gram tiles share).
#[inline]
fn dot_scalar(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len();
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let o = c * LANES;
        let xb = &x[o..o + LANES];
        let yb = &y[o..o + LANES];
        for l in 0..LANES {
            acc[l] += xb[l] * yb[l];
        }
    }
    let mut s = 0.0f32;
    for a in acc {
        s += a;
    }
    for p in chunks * LANES..n {
        s += x[p] * y[p];
    }
    s
}

/// Sum of squares of a row.
#[inline]
pub fn row_sumsq(row: &[f32]) -> f32 {
    dot(row, row)
}

/// `dst (m×n) = a (m×k) · b (k×n)`. `dst` is fully overwritten.
pub fn matmul_into(dst: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(dst.len(), m * n, "matmul dst shape");
    assert_eq!(a.len(), m * k, "matmul lhs shape");
    assert_eq!(b.len(), k * n, "matmul rhs shape");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        dst.fill(0.0);
        return;
    }
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    {
        let path = simd::active();
        if path != SimdPath::Scalar {
            matmul_simd(path, dst, a, b, m, k, n);
            return;
        }
    }
    matmul_into_scalar(dst, a, b, m, k, n);
}

/// Split `dst` (`rows` rows of `row_len`) into contiguous row chunks and
/// run `f(chunk, first_row, row_count)` on each — on the calling thread
/// when `threads <= 1`, else one scoped thread per chunk. Chunk sizes are
/// rounded up to a multiple of `align` (every chunk start is then
/// `align`-aligned), so the packed-A panel lookup — which assumes chunks
/// begin on a 4-row panel boundary — holds on every worker. Every
/// threaded kernel in this module shares this partition, so the chunking
/// math lives in exactly one place.
fn par_row_chunks<F>(
    dst: &mut [f32],
    rows: usize,
    row_len: usize,
    threads: usize,
    align: usize,
    f: F,
) where
    F: Fn(&mut [f32], usize, usize) + Sync,
{
    if threads <= 1 {
        f(dst, 0, rows);
        return;
    }
    let rows_per = rows.div_ceil(threads).div_ceil(align) * align;
    std::thread::scope(|s| {
        let mut dst_rest = dst;
        let mut i0 = 0usize;
        while i0 < rows {
            let take = rows_per.min(rows - i0);
            let (chunk, rest) =
                std::mem::take(&mut dst_rest).split_at_mut(take * row_len);
            dst_rest = rest;
            let f = &f;
            s.spawn(move || f(chunk, i0, take));
            i0 += take;
        }
    });
}

/// The scalar-tile matmul path with row-block threading — the portable
/// fallback, kept callable on its own as the bitwise baseline for tests
/// (its accumulation order matches the seed kernel exactly).
pub(crate) fn matmul_into_scalar(
    dst: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let t = plan_threads(m, m * n * k, PAR_MIN_MULS);
    par_row_chunks(dst, m, n, t, 1, |chunk, i0, take| {
        matmul_rows(chunk, &a[i0 * k..(i0 + take) * k], b, k, n)
    });
}

/// Serial scalar register-tiled matmul over a contiguous block of output
/// rows.
fn matmul_rows(dst: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize) {
    dst.fill(0.0);
    if n == 0 || k == 0 {
        return;
    }
    matmul_rows_accum(dst, a, b, k, n, 1.0);
}

/// `dst += alpha · a · b` over a contiguous block of output rows, 4-row
/// register tiles, k-panels of [`KC`]. With `alpha = 1.0` the per-element
/// accumulation order (and bits) match the seed kernel; the fused NS5
/// polynomial calls it with `alpha = c` on a `b·A`-initialized dst.
fn matmul_rows_accum(dst: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize, alpha: f32) {
    if n == 0 || k == 0 {
        return;
    }
    let m = dst.len() / n;
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        let mut i = 0;
        // 4-row register tiles
        while i + MR <= m {
            let base = i * n;
            let block = &mut dst[base..base + MR * n];
            let (r0, rest) = block.split_at_mut(n);
            let (r1, rest) = rest.split_at_mut(n);
            let (r2, r3) = rest.split_at_mut(n);
            for p in kk..kend {
                let a0 = alpha * a[i * k + p];
                let a1 = alpha * a[(i + 1) * k + p];
                let a2 = alpha * a[(i + 2) * k + p];
                let a3 = alpha * a[(i + 3) * k + p];
                let brow = &b[p * n..p * n + n];
                for j in 0..n {
                    let x = brow[j];
                    r0[j] += a0 * x;
                    r1[j] += a1 * x;
                    r2[j] += a2 * x;
                    r3[j] += a3 * x;
                }
            }
            i += MR;
        }
        // remainder rows
        while i < m {
            let row = &mut dst[i * n..(i + 1) * n];
            for p in kk..kend {
                let av = alpha * a[i * k + p];
                let brow = &b[p * n..p * n + n];
                for j in 0..n {
                    row[j] += av * brow[j];
                }
            }
            i += 1;
        }
        kk = kend;
    }
}

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
thread_local! {
    /// Per-thread packed panel buffers (B strips + A panels) for the
    /// vector matmul paths. Packing happens in the calling thread
    /// *before* any row-chunk workers spawn (they share the panels
    /// read-only), and the buffers only grow, so steady-state calls are
    /// allocation-free.
    static PACK_TLS: RefCell<(PackedB, PackedA)> =
        RefCell::new((PackedB::new(), PackedA::new()));
}

/// Vector-rung matmul: repack B (and, past [`pack_a_min_rows`], A), then
/// run the packed microkernel over panel-aligned row-block threads.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn matmul_simd(
    path: SimdPath,
    dst: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    PACK_TLS.with(|cell| {
        let mut packs = cell.borrow_mut();
        let (pb, pa) = &mut *packs;
        pb.pack(b, k, n);
        let use_pa = m >= pack_a_min_rows() && n > PackedB::NR;
        if use_pa {
            pa.pack(a, m, k);
        }
        let packed_b = pb.data();
        let packed_a = if use_pa { pa.data() } else { &[][..] };
        let t = plan_threads(m, m * n * k, PAR_MIN_MULS);
        par_row_chunks(dst, m, n, t, PackedA::MR, |chunk, i0, take| {
            let a_rows = &a[i0 * k..(i0 + take) * k];
            let pa_rows = if use_pa {
                let mr = PackedA::MR;
                &packed_a[(i0 / mr) * mr * k..(i0 / mr + take / mr) * mr * k]
            } else {
                &[][..]
            };
            // SAFETY: `path` came from simd::active(), so the required
            // CPU features are present; the packed panels are shared
            // read-only across chunks
            unsafe {
                match path {
                    #[cfg(target_arch = "x86_64")]
                    SimdPath::Avx2 => simd::avx2::matmul_packed_rows(
                        chunk, a_rows, pa_rows, packed_b, k, n, 1.0, false,
                    ),
                    #[cfg(target_arch = "x86_64")]
                    SimdPath::Avx512 => simd::avx512::matmul_packed_rows(
                        chunk, a_rows, pa_rows, packed_b, k, n, 1.0, false,
                    ),
                    #[cfg(target_arch = "aarch64")]
                    SimdPath::Neon => simd::neon::matmul_packed_rows(
                        chunk, a_rows, pa_rows, packed_b, k, n, 1.0, false,
                    ),
                    // defensive: an unexpected path falls back to scalar
                    _ => matmul_rows(chunk, a_rows, b, k, n),
                }
            }
        });
    });
}

/// Fused NS5 polynomial: `dst (m×m) = b·A + c·A²` without materializing
/// `A²` — the init pass writes `b·A`, then `c·A·A` accumulates into the
/// same buffer (saving one m×m workspace buffer and a full memory pass
/// per Newton–Schulz iteration).
pub fn ns_poly_into(dst: &mut [f32], a: &[f32], m: usize, b: f32, c: f32) {
    assert_eq!(dst.len(), m * m, "ns_poly dst shape");
    assert_eq!(a.len(), m * m, "ns_poly src shape");
    if m == 0 {
        return;
    }
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    {
        let path = simd::active();
        if path != SimdPath::Scalar {
            ns_poly_simd(path, dst, a, m, b, c);
            return;
        }
    }
    let t = plan_threads(m, m * m * m, PAR_MIN_MULS);
    par_row_chunks(dst, m, m, t, 1, |chunk, i0, take| {
        ns_poly_rows(chunk, &a[i0 * m..(i0 + take) * m], a, m, b, c)
    });
}

/// Scalar rows of the fused polynomial: init `b·a_rows`, accumulate
/// `c · a_rows · a_full`.
fn ns_poly_rows(dst: &mut [f32], a_rows: &[f32], a_full: &[f32], m: usize, b: f32, c: f32) {
    for (d, s) in dst.iter_mut().zip(a_rows) {
        *d = b * *s;
    }
    matmul_rows_accum(dst, a_rows, a_full, m, m, c);
}

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn ns_poly_simd(path: SimdPath, dst: &mut [f32], a: &[f32], m: usize, b: f32, c: f32) {
    PACK_TLS.with(|cell| {
        let mut packs = cell.borrow_mut();
        let (pb, pa) = &mut *packs;
        pb.pack(a, m, m);
        let use_pa = m >= pack_a_min_rows() && m > PackedB::NR;
        if use_pa {
            pa.pack(a, m, m);
        }
        let packed_b = pb.data();
        let packed_a = if use_pa { pa.data() } else { &[][..] };
        let t = plan_threads(m, m * m * m, PAR_MIN_MULS);
        par_row_chunks(dst, m, m, t, PackedA::MR, |chunk, i0, take| {
            let a_rows = &a[i0 * m..(i0 + take) * m];
            let pa_rows = if use_pa {
                let mr = PackedA::MR;
                &packed_a[(i0 / mr) * mr * m..(i0 / mr + take / mr) * mr * m]
            } else {
                &[][..]
            };
            // SAFETY: `path` came from simd::active(), so the required
            // CPU features are present
            unsafe {
                match path {
                    #[cfg(target_arch = "x86_64")]
                    SimdPath::Avx2 => simd::avx2::ns_poly_rows(
                        chunk, a_rows, pa_rows, packed_b, m, b, c,
                    ),
                    #[cfg(target_arch = "x86_64")]
                    SimdPath::Avx512 => simd::avx512::ns_poly_rows(
                        chunk, a_rows, pa_rows, packed_b, m, b, c,
                    ),
                    #[cfg(target_arch = "aarch64")]
                    SimdPath::Neon => simd::neon::ns_poly_rows(
                        chunk, a_rows, pa_rows, packed_b, m, b, c,
                    ),
                    // defensive: an unexpected path falls back to scalar
                    _ => ns_poly_rows(chunk, a_rows, a, m, b, c),
                }
            }
        });
    });
}

/// `dst (m×m) = a (m×k) · aᵀ`. Computes the upper triangle with 4-row
/// register tiles (each streamed row `a_j` feeds four dot lanes), threads
/// over area-balanced row blocks, then mirrors to the lower triangle.
pub fn gram_into(dst: &mut [f32], a: &[f32], m: usize, k: usize) {
    assert_eq!(dst.len(), m * m, "gram dst shape");
    assert_eq!(a.len(), m * k, "gram src shape");
    // upper-triangle work ≈ m²k/2 multiply-adds
    let t = plan_threads(m, m * m * k / 2, PAR_MIN_MULS);
    if t <= 1 {
        gram_rows(dst, a, 0, m, m, k);
    } else {
        let bounds = triangle_partition(m, t);
        // reborrow (not move) so `dst` is usable again for the mirror pass
        // once every scoped borrow has ended
        let mut dst_rest: &mut [f32] = &mut *dst;
        std::thread::scope(|s| {
            for w in bounds.windows(2) {
                let (i0, i1) = (w[0], w[1]);
                if i1 <= i0 {
                    continue;
                }
                let (chunk, rest) =
                    std::mem::take(&mut dst_rest).split_at_mut((i1 - i0) * m);
                dst_rest = rest;
                s.spawn(move || gram_rows(chunk, a, i0, i1, m, k));
            }
        });
    }
    // mirror the strict lower triangle from the upper
    mirror_lower(dst, m);
}

fn mirror_lower(dst: &mut [f32], m: usize) {
    for i in 1..m {
        for j in 0..i {
            dst[i * m + j] = dst[j * m + i];
        }
    }
}

/// Row boundaries `0 = b0 < … < bt = m` splitting the upper-triangle area
/// roughly evenly: rows `0..x` cover area `x·m − x(x−1)/2`, so the b-th
/// boundary solves the quadratic for `b/t` of the total. Interior
/// boundaries are rounded to multiples of [`MR`]: the Gram remainder
/// rows reduce through a different fold than the 4-row tiles, so the
/// tile/remainder assignment must not depend on where the thread
/// boundaries land — with aligned boundaries the 4-row blocks are the
/// same for every thread count and Gram output bits never change with
/// `perf.threads`.
fn triangle_partition(m: usize, t: usize) -> Vec<usize> {
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0usize);
    let mf = m as f64;
    let total = mf * (mf + 1.0) / 2.0;
    for b in 1..t {
        let target = total * b as f64 / t as f64;
        let x = mf - (mf * mf - 2.0 * target).max(0.0).sqrt();
        let prev = *bounds.last().unwrap();
        let aligned = ((x / MR as f64).round() as usize) * MR;
        bounds.push(aligned.clamp(prev, m));
    }
    bounds.push(m);
    bounds
}

/// Upper-triangle rows `i0..i1` of the Gram matrix into `dst_chunk`
/// (which holds full rows `i0..i1`, each of length `m`), SIMD-dispatched.
/// Entries strictly left of the diagonal within a 4-row tile are computed
/// too (they are correct values); the mirror pass makes the lower
/// triangle consistent.
fn gram_rows(dst_chunk: &mut [f32], a: &[f32], i0: usize, i1: usize, m: usize, k: usize) {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    match simd::active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 dispatch rung implies avx2+fma support
        SimdPath::Avx2 => return unsafe { simd::avx2::gram_rows(dst_chunk, a, i0, i1, m, k) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx512 dispatch rung implies avx512f support
        SimdPath::Avx512 => {
            return unsafe { simd::avx512::gram_rows(dst_chunk, a, i0, i1, m, k) }
        }
        #[cfg(target_arch = "aarch64")]
        // SAFETY: the Neon dispatch rung implies neon support
        SimdPath::Neon => return unsafe { simd::neon::gram_rows(dst_chunk, a, i0, i1, m, k) },
        _ => {}
    }
    gram_rows_scalar(dst_chunk, a, i0, i1, m, k);
}

fn gram_rows_scalar(dst_chunk: &mut [f32], a: &[f32], i0: usize, i1: usize, m: usize, k: usize) {
    let mut i = i0;
    while i < i1 {
        if i + MR <= i1 {
            let ri0 = &a[i * k..(i + 1) * k];
            let ri1 = &a[(i + 1) * k..(i + 2) * k];
            let ri2 = &a[(i + 2) * k..(i + 3) * k];
            let ri3 = &a[(i + 3) * k..(i + 4) * k];
            let base = (i - i0) * m;
            let block = &mut dst_chunk[base..base + MR * m];
            let (o0, rest) = block.split_at_mut(m);
            let (o1, rest) = rest.split_at_mut(m);
            let (o2, o3) = rest.split_at_mut(m);
            let chunks = k / LANES;
            for j in i..m {
                let rj = &a[j * k..(j + 1) * k];
                let mut acc0 = [0.0f32; LANES];
                let mut acc1 = [0.0f32; LANES];
                let mut acc2 = [0.0f32; LANES];
                let mut acc3 = [0.0f32; LANES];
                for c in 0..chunks {
                    let o = c * LANES;
                    let rjb = &rj[o..o + LANES];
                    let r0b = &ri0[o..o + LANES];
                    let r1b = &ri1[o..o + LANES];
                    let r2b = &ri2[o..o + LANES];
                    let r3b = &ri3[o..o + LANES];
                    for l in 0..LANES {
                        let x = rjb[l];
                        acc0[l] += r0b[l] * x;
                        acc1[l] += r1b[l] * x;
                        acc2[l] += r2b[l] * x;
                        acc3[l] += r3b[l] * x;
                    }
                }
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
                for l in 0..LANES {
                    s0 += acc0[l];
                    s1 += acc1[l];
                    s2 += acc2[l];
                    s3 += acc3[l];
                }
                for p in chunks * LANES..k {
                    let x = rj[p];
                    s0 += ri0[p] * x;
                    s1 += ri1[p] * x;
                    s2 += ri2[p] * x;
                    s3 += ri3[p] * x;
                }
                o0[j] = s0;
                o1[j] = s1;
                o2[j] = s2;
                o3[j] = s3;
            }
            i += MR;
        } else {
            let ri = &a[i * k..(i + 1) * k];
            let base = (i - i0) * m;
            let orow = &mut dst_chunk[base..base + m];
            for j in i..m {
                orow[j] = dot_scalar(ri, &a[j * k..(j + 1) * k]);
            }
            i += 1;
        }
    }
}

/// `dst (cols×rows) = src (rows×cols)ᵀ`, 32×32 cache tiles.
pub fn transpose_into(dst: &mut [f32], src: &[f32], rows: usize, cols: usize) {
    assert_eq!(dst.len(), rows * cols, "transpose dst shape");
    assert_eq!(src.len(), rows * cols, "transpose src shape");
    const TB: usize = 32;
    let mut ii = 0;
    while ii < rows {
        let iend = (ii + TB).min(rows);
        let mut jj = 0;
        while jj < cols {
            let jend = (jj + TB).min(cols);
            for i in ii..iend {
                for j in jj..jend {
                    dst[j * rows + i] = src[i * cols + j];
                }
            }
            jj = jend;
        }
        ii = iend;
    }
}

/// `dst = a·x + b·y` elementwise (SIMD-dispatched).
pub fn axpby_into(dst: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
    assert_eq!(dst.len(), x.len(), "axpby dst/x shape");
    assert_eq!(x.len(), y.len(), "axpby x/y shape");
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if dst.len() >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 dispatch rung implies avx2+fma support
            SimdPath::Avx2 => return unsafe { simd::avx2::axpby(dst, a, x, b, y) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx512 dispatch rung implies avx512f support
            SimdPath::Avx512 => return unsafe { simd::avx512::axpby(dst, a, x, b, y) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: the Neon dispatch rung implies neon support
            SimdPath::Neon => return unsafe { simd::neon::axpby(dst, a, x, b, y) },
            _ => {}
        }
    }
    for i in 0..dst.len() {
        dst[i] = a * x[i] + b * y[i];
    }
}

/// `x = a·x + b·y` elementwise, in place (SIMD-dispatched).
pub fn axpby_inplace(x: &mut [f32], a: f32, y: &[f32], b: f32) {
    assert_eq!(x.len(), y.len(), "axpby_inplace shape");
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if x.len() >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 dispatch rung implies avx2+fma support
            SimdPath::Avx2 => return unsafe { simd::avx2::axpby_inplace(x, a, y, b) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx512 dispatch rung implies avx512f support
            SimdPath::Avx512 => return unsafe { simd::avx512::axpby_inplace(x, a, y, b) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: the Neon dispatch rung implies neon support
            SimdPath::Neon => return unsafe { simd::neon::axpby_inplace(x, a, y, b) },
            _ => {}
        }
    }
    for i in 0..x.len() {
        x[i] = a * x[i] + b * y[i];
    }
}

/// Fused bf16 EMA sweep: `x[i] = rne(a·widen(x[i]) + b·y[i])`, reading
/// and writing bf16 bits with all accumulation in f32 — the momentum
/// update of the bf16 storage mode (and the weight update against an
/// f32 direction). One load-widen, two rounded multiplies, one rounded
/// add, and one RNE round-store per element; no f32 copy of `x` is ever
/// materialized.
///
/// Unlike the f32 kernels, the result is **bit-identical on every SIMD
/// rung**: the arithmetic is elementwise with no fused contraction and
/// no reduction, so the rung only changes speed.
pub fn bf16_axpby_inplace(x: &mut [u16], a: f32, y: &[f32], b: f32) {
    assert_eq!(x.len(), y.len(), "bf16_axpby_inplace shape");
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if x.len() >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 dispatch rung implies avx2+fma support
            SimdPath::Avx2 => return unsafe { simd::avx2::bf16_axpby_inplace(x, a, y, b) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx512 dispatch rung implies avx512f support
            SimdPath::Avx512 => return unsafe { simd::avx512::bf16_axpby_inplace(x, a, y, b) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: the Neon dispatch rung implies neon support
            SimdPath::Neon => return unsafe { simd::neon::bf16_axpby_inplace(x, a, y, b) },
            _ => {}
        }
    }
    for (xi, &yi) in x.iter_mut().zip(y) {
        let xv = crate::tensor::simd::bf16_to_f32(*xi);
        *xi = crate::tensor::simd::bf16_from_f32(a * xv + b * yi);
    }
}

/// Fused bf16/bf16 sweep: `x[i] = rne(a·widen(x[i]) + b·widen(y[i]))` —
/// the weight update of the bf16 storage mode, where both the weights
/// and the momentum live as bf16 bits. Bit-identical on every rung,
/// like [`bf16_axpby_inplace`].
pub fn bf16_axpby_from_bf16(x: &mut [u16], a: f32, y: &[u16], b: f32) {
    assert_eq!(x.len(), y.len(), "bf16_axpby_from_bf16 shape");
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if x.len() >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 dispatch rung implies avx2+fma support
            SimdPath::Avx2 => return unsafe { simd::avx2::bf16_axpby_from_bf16(x, a, y, b) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx512 dispatch rung implies avx512f support
            SimdPath::Avx512 => {
                return unsafe { simd::avx512::bf16_axpby_from_bf16(x, a, y, b) }
            }
            #[cfg(target_arch = "aarch64")]
            // SAFETY: the Neon dispatch rung implies neon support
            SimdPath::Neon => return unsafe { simd::neon::bf16_axpby_from_bf16(x, a, y, b) },
            _ => {}
        }
    }
    for (xi, &yi) in x.iter_mut().zip(y) {
        let xv = crate::tensor::simd::bf16_to_f32(*xi);
        let yv = crate::tensor::simd::bf16_to_f32(yi);
        *xi = crate::tensor::simd::bf16_from_f32(a * xv + b * yv);
    }
}

/// Sum of squares of a bf16 row, widened and accumulated in f32 across
/// a fixed bank of 8 independent accumulators — the row-norm reduction
/// of the bf16 RMNP step. The reduction order is pinned independent of
/// lane width (stride-8 banks, pairwise fold), so — again unlike the
/// f32 [`row_sumsq`] — the result is bit-identical on every rung.
pub fn bf16_row_sumsq(x: &[u16]) -> f32 {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if x.len() >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 dispatch rung implies avx2+fma support
            SimdPath::Avx2 => return unsafe { simd::avx2::bf16_row_sumsq(x) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx512 dispatch rung implies avx512f support
            SimdPath::Avx512 => return unsafe { simd::avx512::bf16_row_sumsq(x) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: the Neon dispatch rung implies neon support
            SimdPath::Neon => return unsafe { simd::neon::bf16_row_sumsq(x) },
            _ => {}
        }
    }
    bf16_row_sumsq_scalar(x)
}

/// The portable core of [`bf16_row_sumsq`] — the identical stride-8
/// bank structure the generic body pins, so scalar and vector rungs
/// agree bit for bit.
fn bf16_row_sumsq_scalar(x: &[u16]) -> f32 {
    let n = x.len();
    let mut acc = [0.0f32; 8];
    let mut i = 0usize;
    while i + 8 <= n {
        for (j, a) in acc.iter_mut().enumerate() {
            let v = crate::tensor::simd::bf16_to_f32(x[i + j]);
            *a += v * v;
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    while i < n {
        let v = crate::tensor::simd::bf16_to_f32(x[i]);
        s += v * v;
        i += 1;
    }
    s
}

/// `dst[i,:] = src[i,:] / max(‖src[i,:]‖₂, eps)` — the RMNP preconditioner
/// (Algorithm 2 line 5), single pass, threaded over row blocks.
pub fn row_normalize_into(
    dst: &mut [f32],
    src: &[f32],
    rows: usize,
    cols: usize,
    eps: f32,
) {
    assert_eq!(dst.len(), rows * cols, "rownorm dst shape");
    assert_eq!(src.len(), rows * cols, "rownorm src shape");
    let t = plan_threads(rows, rows * cols, PAR_MIN_ELEMS);
    par_row_chunks(dst, rows, cols, t, 1, |chunk, i0, take| {
        row_normalize_rows(chunk, &src[i0 * cols..(i0 + take) * cols], cols, eps)
    });
}

/// One contiguous block of normalized rows (SIMD-dispatched).
fn row_normalize_rows(dst: &mut [f32], src: &[f32], cols: usize, eps: f32) {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if cols >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 dispatch rung implies avx2+fma support
            SimdPath::Avx2 => {
                return unsafe { simd::avx2::row_normalize_rows(dst, src, cols, eps) }
            }
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx512 dispatch rung implies avx512f support
            SimdPath::Avx512 => {
                return unsafe { simd::avx512::row_normalize_rows(dst, src, cols, eps) }
            }
            #[cfg(target_arch = "aarch64")]
            // SAFETY: the Neon dispatch rung implies neon support
            SimdPath::Neon => {
                return unsafe { simd::neon::row_normalize_rows(dst, src, cols, eps) }
            }
            _ => {}
        }
    }
    row_normalize_rows_scalar(dst, src, cols, eps);
}

fn row_normalize_rows_scalar(dst: &mut [f32], src: &[f32], cols: usize, eps: f32) {
    if cols == 0 {
        return;
    }
    let rows = dst.len() / cols;
    for i in 0..rows {
        let o = i * cols;
        let srow = &src[o..o + cols];
        let inv = 1.0 / dot_scalar(srow, srow).sqrt().max(eps);
        let drow = &mut dst[o..o + cols];
        for j in 0..cols {
            drow[j] = srow[j] * inv;
        }
    }
}

/// Row-wise softmax: `dst[i,:] = softmax(src[i,:])` (SIMD-dispatched).
///
/// `-inf` entries (the model layer's causal attention mask) exponentiate
/// to exactly 0; every row must contain at least one finite entry. The
/// exp/sum sweep is scalar in row order on every rung (only the max scan
/// and the normalize pass vectorize), so results are deterministic per
/// rung. Deliberately unthreaded: the model-layer callers hand over a few
/// hundred short rows, far below any threading payoff.
pub fn row_softmax_into(dst: &mut [f32], src: &[f32], rows: usize, cols: usize) {
    assert_eq!(dst.len(), rows * cols, "row_softmax dst shape");
    assert_eq!(src.len(), rows * cols, "row_softmax src shape");
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if cols >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 dispatch rung implies avx2+fma support
            SimdPath::Avx2 => return unsafe { simd::avx2::row_softmax_rows(dst, src, cols) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx512 dispatch rung implies avx512f support
            SimdPath::Avx512 => {
                return unsafe { simd::avx512::row_softmax_rows(dst, src, cols) }
            }
            #[cfg(target_arch = "aarch64")]
            // SAFETY: the Neon dispatch rung implies neon support
            SimdPath::Neon => return unsafe { simd::neon::row_softmax_rows(dst, src, cols) },
            _ => {}
        }
    }
    row_softmax_rows_scalar(dst, src, cols);
}

fn row_softmax_rows_scalar(dst: &mut [f32], src: &[f32], cols: usize) {
    if cols == 0 {
        return;
    }
    let rows = dst.len() / cols;
    for i in 0..rows {
        let o = i * cols;
        let srow = &src[o..o + cols];
        let mut max = f32::NEG_INFINITY;
        for &v in srow {
            if v > max {
                max = v;
            }
        }
        let drow = &mut dst[o..o + cols];
        let mut sum = 0.0f32;
        for (d, &s) in drow.iter_mut().zip(srow) {
            let e = (s - max).exp();
            *d = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for d in drow.iter_mut() {
            *d *= inv;
        }
    }
}

/// Row-wise softmax backward: given forward probabilities `probs` and the
/// upstream gradient `dprobs`, writes
/// `dst[i,:] = probs ⊙ (dprobs − Σ_k probs_k·dprobs_k)` per row
/// (SIMD-dispatched, unthreaded). Masked entries (`probs = 0`) get
/// gradient exactly 0, so the causal mask needs no special backward
/// handling.
pub fn row_softmax_grad_into(
    dst: &mut [f32],
    probs: &[f32],
    dprobs: &[f32],
    rows: usize,
    cols: usize,
) {
    assert_eq!(dst.len(), rows * cols, "row_softmax_grad dst shape");
    assert_eq!(probs.len(), rows * cols, "row_softmax_grad probs shape");
    assert_eq!(dprobs.len(), rows * cols, "row_softmax_grad dprobs shape");
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if cols >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 dispatch rung implies avx2+fma support
            SimdPath::Avx2 => {
                return unsafe { simd::avx2::row_softmax_grad_rows(dst, probs, dprobs, cols) }
            }
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx512 dispatch rung implies avx512f support
            SimdPath::Avx512 => {
                return unsafe { simd::avx512::row_softmax_grad_rows(dst, probs, dprobs, cols) }
            }
            #[cfg(target_arch = "aarch64")]
            // SAFETY: the Neon dispatch rung implies neon support
            SimdPath::Neon => {
                return unsafe { simd::neon::row_softmax_grad_rows(dst, probs, dprobs, cols) }
            }
            _ => {}
        }
    }
    if cols == 0 {
        return;
    }
    for i in 0..rows {
        let o = i * cols;
        let p = &probs[o..o + cols];
        let dp = &dprobs[o..o + cols];
        let c = dot_scalar(p, dp);
        let out = &mut dst[o..o + cols];
        for j in 0..cols {
            out[j] = p[j] * (dp[j] - c);
        }
    }
}

/// Fused RMSNorm: `dst[i,:] = gain ⊙ src[i,:] / sqrt(mean(src[i,:]²) + eps)`
/// (SIMD-dispatched, unthreaded). The model layer's pre-attention and
/// pre-gate normalization; `gain` has `cols` elements.
pub fn rmsnorm_into(
    dst: &mut [f32],
    src: &[f32],
    gain: &[f32],
    rows: usize,
    cols: usize,
    eps: f32,
) {
    assert_eq!(dst.len(), rows * cols, "rmsnorm dst shape");
    assert_eq!(src.len(), rows * cols, "rmsnorm src shape");
    assert_eq!(gain.len(), cols, "rmsnorm gain shape");
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if cols >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 dispatch rung implies avx2+fma support
            SimdPath::Avx2 => return unsafe { simd::avx2::rmsnorm_rows(dst, src, gain, cols, eps) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx512 dispatch rung implies avx512f support
            SimdPath::Avx512 => {
                return unsafe { simd::avx512::rmsnorm_rows(dst, src, gain, cols, eps) }
            }
            #[cfg(target_arch = "aarch64")]
            // SAFETY: the Neon dispatch rung implies neon support
            SimdPath::Neon => return unsafe { simd::neon::rmsnorm_rows(dst, src, gain, cols, eps) },
            _ => {}
        }
    }
    if cols == 0 {
        return;
    }
    for i in 0..rows {
        let o = i * cols;
        let srow = &src[o..o + cols];
        let r = 1.0 / (dot_scalar(srow, srow) / cols as f32 + eps).sqrt();
        let drow = &mut dst[o..o + cols];
        for j in 0..cols {
            drow[j] = gain[j] * srow[j] * r;
        }
    }
}

/// RMSNorm backward (SIMD-dispatched, unthreaded). With
/// `r_i = 1/sqrt(mean(src[i,:]²) + eps)` and upstream gradient `dy`:
///
/// * `dx[i,:]  = r_i·(gain ⊙ dy) − src[i,:]·(r_i³/cols)·Σ_j gain_j·dy_ij·src_ij`
/// * `dgain    = Σ_i dy[i,:] ⊙ src[i,:] · r_i` (fully overwritten; rows
///   accumulate sequentially so the sum order never depends on threads)
#[allow(clippy::too_many_arguments)]
pub fn rmsnorm_grad_into(
    dx: &mut [f32],
    dgain: &mut [f32],
    dy: &[f32],
    src: &[f32],
    gain: &[f32],
    rows: usize,
    cols: usize,
    eps: f32,
) {
    assert_eq!(dx.len(), rows * cols, "rmsnorm_grad dx shape");
    assert_eq!(dy.len(), rows * cols, "rmsnorm_grad dy shape");
    assert_eq!(src.len(), rows * cols, "rmsnorm_grad src shape");
    assert_eq!(gain.len(), cols, "rmsnorm_grad gain shape");
    assert_eq!(dgain.len(), cols, "rmsnorm_grad dgain shape");
    dgain.fill(0.0);
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if cols >= SIMD_MIN_ELEMS {
        match simd::active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 dispatch rung implies avx2+fma support
            SimdPath::Avx2 => {
                return unsafe {
                    simd::avx2::rmsnorm_grad_rows(dx, dgain, dy, src, gain, cols, eps)
                }
            }
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx512 dispatch rung implies avx512f support
            SimdPath::Avx512 => {
                return unsafe {
                    simd::avx512::rmsnorm_grad_rows(dx, dgain, dy, src, gain, cols, eps)
                }
            }
            #[cfg(target_arch = "aarch64")]
            // SAFETY: the Neon dispatch rung implies neon support
            SimdPath::Neon => {
                return unsafe {
                    simd::neon::rmsnorm_grad_rows(dx, dgain, dy, src, gain, cols, eps)
                }
            }
            _ => {}
        }
    }
    if cols == 0 {
        return;
    }
    for i in 0..rows {
        let o = i * cols;
        let srow = &src[o..o + cols];
        let dyrow = &dy[o..o + cols];
        let r = 1.0 / (dot_scalar(srow, srow) / cols as f32 + eps).sqrt();
        let mut c = 0.0f32;
        for j in 0..cols {
            c += gain[j] * dyrow[j] * srow[j];
        }
        let b = r * r * r * c / cols as f32;
        let dxrow = &mut dx[o..o + cols];
        for j in 0..cols {
            dxrow[j] = r * gain[j] * dyrow[j] - b * srow[j];
            dgain[j] += dyrow[j] * srow[j] * r;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    out[i * n + j] += av * b[p * n + j];
                }
            }
        }
        out
    }

    fn randv(len: usize, rng: &mut Rng) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn matmul_matches_naive_across_shapes() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [
            (1, 1, 1),
            (4, 4, 4),
            (5, 7, 3),
            (33, 65, 17),
            (2, 128, 130),
            (130, 3, 2),
            (8, 1, 8),
            // rows past PACK_A_MIN_ROWS with several column strips, both
            // m % 4 == 0 and a remainder-row tail: the packed-A path
            (64, 24, 40),
            (130, 40, 66),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let want = naive_matmul(&a, &b, m, k, n);
            let mut got = vec![0.0f32; m * n];
            matmul_into(&mut got, &a, &b, m, k, n);
            for (x, y) in got.iter().zip(&want) {
                assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn matmul_scalar_path_matches_naive_bitwise() {
        // the portable rung preserves the seed kernel's per-element
        // accumulation order exactly, independent of the SIMD dispatch
        let mut rng = Rng::new(2);
        let (m, k, n) = (19, 70, 23);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let want = naive_matmul(&a, &b, m, k, n);
        let mut got = vec![0.0f32; m * n];
        matmul_into_scalar(&mut got, &a, &b, m, k, n);
        assert_eq!(got, want);
    }

    #[test]
    fn matmul_threaded_matches_serial() {
        // the row partition must not change bits on the active path: the
        // tile and remainder kernels do identical per-row work, and the
        // packed-A panel lookup holds on 4-aligned chunk starts
        let mut rng = Rng::new(2);
        let (m, k, n) = (67, 129, 131);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        set_num_threads(1);
        let mut serial = vec![0.0f32; m * n];
        matmul_into(&mut serial, &a, &b, m, k, n);
        set_num_threads(3);
        let mut par = vec![0.0f32; m * n];
        matmul_into(&mut par, &a, &b, m, k, n);
        set_num_threads(0);
        assert_eq!(serial, par);
    }

    #[test]
    fn matmul_dispatched_tracks_scalar_within_tolerance() {
        // whatever rung is active, it stays within f32-rounding distance
        // of the portable path (exact when the scalar rung is active);
        // (65, 33, 17) and (80, 20, 33) straddle the packed-A threshold
        let mut rng = Rng::new(12);
        for (m, k, n) in [(7, 13, 9), (32, 64, 48), (65, 33, 17), (80, 20, 33)] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let mut fast = vec![0.0f32; m * n];
            matmul_into(&mut fast, &a, &b, m, k, n);
            let mut scalar = vec![0.0f32; m * n];
            matmul_into_scalar(&mut scalar, &a, &b, m, k, n);
            for (x, y) in fast.iter().zip(&scalar) {
                assert!((x - y).abs() < 1e-4, "({m},{k},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn ns_poly_fusion_matches_unfused() {
        // dst = b·A + c·A² against the two-buffer reference; m = 65/96
        // cross PACK_A_MIN_ROWS so the packed-A polynomial path runs too
        let mut rng = Rng::new(13);
        for m in [1usize, 3, 8, 17, 33, 65, 96] {
            let a = randv(m * m, &mut rng);
            let a2 = naive_matmul(&a, &a, m, m, m);
            let mut want = vec![0.0f32; m * m];
            for i in 0..m * m {
                want[i] = -4.775 * a[i] + 2.0315 * a2[i];
            }
            let mut got = vec![0.0f32; m * m];
            ns_poly_into(&mut got, &a, m, -4.775, 2.0315);
            for (idx, (x, y)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (x - y).abs() < 1e-3 * (1.0 + y.abs()),
                    "m={m} at {idx}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn gram_matches_matmul_transpose() {
        let mut rng = Rng::new(3);
        for (m, k) in [(1, 5), (6, 11), (13, 64), (40, 9), (4, 8)] {
            let a = randv(m * k, &mut rng);
            let mut at = vec![0.0f32; m * k];
            transpose_into(&mut at, &a, m, k);
            let want = naive_matmul(&a, &at, m, k, m);
            let mut got = vec![0.0f32; m * m];
            gram_into(&mut got, &a, m, k);
            for (idx, (x, y)) in got.iter().zip(&want).enumerate() {
                assert!((x - y).abs() < 1e-3, "({m},{k}) at {idx}: {x} vs {y}");
            }
            // exact symmetry by construction
            for i in 0..m {
                for j in 0..m {
                    assert_eq!(got[i * m + j], got[j * m + i]);
                }
            }
        }
    }

    #[test]
    fn gram_threaded_matches_serial_bitwise() {
        // the triangle boundaries are MR-aligned, so the tile/remainder
        // row assignment — and therefore every output bit — is identical
        // for any thread count, on every rung. (157 rows: the global
        // m % 4 tail rows take the remainder fold in both runs.)
        let mut rng = Rng::new(4);
        // big enough to cross PAR_MIN_MULS so the threaded path runs
        for (m, k) in [(160usize, 90usize), (157, 90)] {
            let a = randv(m * k, &mut rng);
            let mut serial = vec![0.0f32; m * m];
            gram_rows(&mut serial, &a, 0, m, m, k);
            mirror_lower(&mut serial, m);
            set_num_threads(4);
            let mut par = vec![0.0f32; m * m];
            gram_into(&mut par, &a, m, k);
            set_num_threads(0);
            assert_eq!(serial, par, "gram bits changed with threads (m={m})");
        }
    }

    #[test]
    fn triangle_partition_covers_orders_and_aligns() {
        for m in [1usize, 2, 7, 100, 1023] {
            for t in [1usize, 2, 3, 8] {
                let b = triangle_partition(m, t);
                assert_eq!(*b.first().unwrap(), 0);
                assert_eq!(*b.last().unwrap(), m);
                assert!(b.windows(2).all(|w| w[0] <= w[1]), "{b:?}");
                // interior boundaries sit on tile-height multiples so the
                // tile/remainder split is thread-count-invariant
                for &x in &b[1..b.len() - 1] {
                    assert!(x % MR == 0 || x == m, "unaligned boundary in {b:?}");
                }
            }
        }
    }

    #[test]
    fn aligned_row_chunks_cover_exactly_once() {
        // chunk starts must be align-multiples and the union must be a
        // disjoint cover of 0..rows, whatever the thread/align combo
        use std::sync::Mutex;
        for rows in [1usize, 4, 7, 17, 64, 67] {
            for threads in [1usize, 2, 3, 5] {
                for align in [1usize, 4] {
                    let mut dst = vec![0.0f32; rows * 3];
                    let seen = Mutex::new(Vec::new());
                    par_row_chunks(&mut dst, rows, 3, threads, align, |chunk, i0, take| {
                        assert_eq!(chunk.len(), take * 3);
                        assert_eq!(i0 % align, 0, "chunk start must be aligned");
                        seen.lock().unwrap().push((i0, take));
                    });
                    let mut seen = seen.into_inner().unwrap();
                    seen.sort();
                    let mut next = 0usize;
                    for (i0, take) in seen {
                        assert_eq!(i0, next, "gap or overlap at {i0}");
                        next = i0 + take;
                    }
                    assert_eq!(next, rows, "rows={rows} t={threads} a={align}");
                }
            }
        }
    }

    #[test]
    fn transpose_blocked_matches_simple() {
        let mut rng = Rng::new(5);
        for (r, c) in [(1, 1), (3, 5), (33, 70), (64, 64)] {
            let src = randv(r * c, &mut rng);
            let mut dst = vec![0.0f32; r * c];
            transpose_into(&mut dst, &src, r, c);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(dst[j * r + i], src[i * c + j]);
                }
            }
        }
    }

    #[test]
    fn axpby_variants() {
        let x = [1.0f32, 2.0, 3.0];
        let y = [10.0f32, 10.0, 10.0];
        let mut dst = [0.0f32; 3];
        axpby_into(&mut dst, 2.0, &x, 0.5, &y);
        assert_eq!(dst, [7.0, 9.0, 11.0]);
        let mut xm = x;
        axpby_inplace(&mut xm, 2.0, &y, 0.5);
        assert_eq!(xm, [7.0, 9.0, 11.0]);
    }

    #[test]
    fn axpby_long_dispatch_matches_scalar() {
        // lengths past SIMD_MIN_ELEMS take the vector path when active
        let mut rng = Rng::new(14);
        for len in [16usize, 23, 64, 100] {
            let x = randv(len, &mut rng);
            let y = randv(len, &mut rng);
            let mut dst = vec![0.0f32; len];
            axpby_into(&mut dst, 1.25, &x, -2.0, &y);
            for i in 0..len {
                let want = 1.25 * x[i] - 2.0 * y[i];
                assert!((dst[i] - want).abs() < 1e-5, "len {len} at {i}");
            }
        }
    }

    #[test]
    fn rownorm_unit_rows_and_zero_rows() {
        let mut rng = Rng::new(6);
        let (rows, cols) = (9, 37);
        let mut src = randv(rows * cols, &mut rng);
        // make one row exactly zero
        for v in &mut src[3 * cols..4 * cols] {
            *v = 0.0;
        }
        let mut dst = vec![0.0f32; rows * cols];
        row_normalize_into(&mut dst, &src, rows, cols, 1e-7);
        for i in 0..rows {
            let n = row_sumsq(&dst[i * cols..(i + 1) * cols]).sqrt();
            if i == 3 {
                assert_eq!(n, 0.0, "zero row must stay zero");
            } else {
                assert!((n - 1.0).abs() < 1e-5, "row {i} norm {n}");
            }
        }
    }

    #[test]
    fn rownorm_threaded_matches_serial() {
        let mut rng = Rng::new(7);
        // large enough to cross PAR_MIN_ELEMS so the threaded path runs
        let (rows, cols) = (1024, 513);
        let src = randv(rows * cols, &mut rng);
        let mut serial = vec![0.0f32; rows * cols];
        row_normalize_rows(&mut serial, &src, cols, 1e-7);
        set_num_threads(5);
        let mut par = vec![0.0f32; rows * cols];
        row_normalize_into(&mut par, &src, rows, cols, 1e-7);
        set_num_threads(0);
        assert_eq!(serial, par);
    }

    #[test]
    fn dot_matches_sequential() {
        let mut rng = Rng::new(8);
        for len in [0usize, 1, 7, 8, 9, 64, 100] {
            let x = randv(len, &mut rng);
            let y = randv(len, &mut rng);
            let seq: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - seq).abs() < 1e-3 * (1.0 + seq.abs()));
        }
    }

    #[test]
    fn row_softmax_rows_sum_to_one_and_respect_mask() {
        let mut rng = Rng::new(20);
        for cols in [3usize, 16, 33, 64] {
            let rows = 5;
            let mut src = randv(rows * cols, &mut rng);
            // causal-style mask on the last row: only entry 0 survives
            for v in src[(rows - 1) * cols + 1..rows * cols].iter_mut() {
                *v = f32::NEG_INFINITY;
            }
            let mut dst = vec![0.0f32; rows * cols];
            row_softmax_into(&mut dst, &src, rows, cols);
            for i in 0..rows {
                let row = &dst[i * cols..(i + 1) * cols];
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
                assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
            }
            // masked entries are exactly zero, the unmasked one exactly 1
            assert_eq!(dst[(rows - 1) * cols], 1.0);
            for &p in &dst[(rows - 1) * cols + 1..rows * cols] {
                assert_eq!(p, 0.0, "masked prob must be exactly 0");
            }
        }
    }

    #[test]
    fn row_softmax_grad_matches_reference_and_kills_masked_entries() {
        let mut rng = Rng::new(21);
        for cols in [5usize, 16, 48] {
            let rows = 4;
            let mut src = randv(rows * cols, &mut rng);
            for v in src[cols + cols / 2..2 * cols].iter_mut() {
                *v = f32::NEG_INFINITY; // partial mask on row 1
            }
            let mut p = vec![0.0f32; rows * cols];
            row_softmax_into(&mut p, &src, rows, cols);
            let dp = randv(rows * cols, &mut rng);
            let mut got = vec![0.0f32; rows * cols];
            row_softmax_grad_into(&mut got, &p, &dp, rows, cols);
            for i in 0..rows {
                let c: f32 = (0..cols).map(|j| p[i * cols + j] * dp[i * cols + j]).sum();
                for j in 0..cols {
                    let want = p[i * cols + j] * (dp[i * cols + j] - c);
                    assert!(
                        (got[i * cols + j] - want).abs() < 1e-5,
                        "({i},{j}): {} vs {want}",
                        got[i * cols + j]
                    );
                }
            }
            // masked probabilities are 0, so their gradient is exactly 0
            for j in cols / 2..cols {
                assert_eq!(got[cols + j], 0.0);
            }
        }
    }

    #[test]
    fn rmsnorm_rows_have_unit_rms_with_unit_gain() {
        let mut rng = Rng::new(22);
        for cols in [4usize, 16, 37, 96] {
            let rows = 6;
            let src = randv(rows * cols, &mut rng);
            let gain = vec![1.0f32; cols];
            let mut dst = vec![0.0f32; rows * cols];
            rmsnorm_into(&mut dst, &src, &gain, rows, cols, 1e-6);
            for i in 0..rows {
                let rms: f32 = (dst[i * cols..(i + 1) * cols]
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    / cols as f32)
                    .sqrt();
                assert!((rms - 1.0).abs() < 1e-2, "row {i} rms {rms}");
            }
            // zero rows stay finite (eps floor) and map to zero
            let zeros = vec![0.0f32; cols];
            let mut out = vec![1.0f32; cols];
            rmsnorm_into(&mut out, &zeros, &gain, 1, cols, 1e-6);
            assert!(out.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn rmsnorm_grad_matches_scalar_reference() {
        // reference reimplementation (f64) of the documented formulas
        let mut rng = Rng::new(23);
        for cols in [5usize, 16, 48] {
            let rows = 7;
            let src = randv(rows * cols, &mut rng);
            let dy = randv(rows * cols, &mut rng);
            let mut gain = randv(cols, &mut rng);
            for g in gain.iter_mut() {
                *g = 1.0 + 0.3 * *g;
            }
            let mut dx = vec![0.0f32; rows * cols];
            let mut dgain = vec![7.0f32; cols]; // must be overwritten, not accumulated onto
            rmsnorm_grad_into(&mut dx, &mut dgain, &dy, &src, &gain, rows, cols, 1e-6);
            let mut want_dg = vec![0.0f64; cols];
            for i in 0..rows {
                let o = i * cols;
                let ss: f64 = src[o..o + cols].iter().map(|&x| (x as f64) * (x as f64)).sum();
                let r = 1.0 / (ss / cols as f64 + 1e-6).sqrt();
                let c: f64 = (0..cols)
                    .map(|j| gain[j] as f64 * dy[o + j] as f64 * src[o + j] as f64)
                    .sum();
                let b = r * r * r * c / cols as f64;
                for j in 0..cols {
                    let want = r * gain[j] as f64 * dy[o + j] as f64 - b * src[o + j] as f64;
                    assert!(
                        (dx[o + j] as f64 - want).abs() < 1e-4,
                        "dx ({i},{j}): {} vs {want}",
                        dx[o + j]
                    );
                    want_dg[j] += dy[o + j] as f64 * src[o + j] as f64 * r;
                }
            }
            for j in 0..cols {
                assert!(
                    (dgain[j] as f64 - want_dg[j]).abs() < 1e-4,
                    "dgain {j}: {} vs {}",
                    dgain[j],
                    want_dg[j]
                );
            }
        }
    }

    #[test]
    fn pack_a_threshold_is_bit_invariant() {
        // packing A is an exact copy with unchanged arithmetic order, so
        // forcing the packed path on (threshold 1) and off (usize::MAX)
        // must produce bitwise-equal results for matmul and the fused NS
        // polynomial — the contract that makes `perf.pack_a_min_rows` a
        // pure speed knob
        let mut rng = Rng::new(31);
        let (m, k, n) = (80usize, 20usize, 33usize);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let s = randv(96 * 96, &mut rng);
        set_pack_a_min_rows(1);
        assert_eq!(pack_a_min_rows(), 1);
        let mut mm_packed = vec![0.0f32; m * n];
        matmul_into(&mut mm_packed, &a, &b, m, k, n);
        let mut ns_packed = vec![0.0f32; 96 * 96];
        ns_poly_into(&mut ns_packed, &s, 96, -4.775, 2.0315);
        set_pack_a_min_rows(usize::MAX);
        let mut mm_plain = vec![0.0f32; m * n];
        matmul_into(&mut mm_plain, &a, &b, m, k, n);
        let mut ns_plain = vec![0.0f32; 96 * 96];
        ns_poly_into(&mut ns_plain, &s, 96, -4.775, 2.0315);
        set_pack_a_min_rows(0);
        assert!(pack_a_min_rows() >= 1, "0 restores default/env resolution");
        assert_eq!(mm_packed, mm_plain, "matmul bits moved with the threshold");
        assert_eq!(ns_packed, ns_plain, "ns_poly bits moved with the threshold");
    }

    #[test]
    fn bf16_axpby_matches_rounding_reference() {
        // x = rne(a·widen(x) + b·y), verified element by element against
        // the conversion helpers; lengths straddle SIMD_MIN_ELEMS so both
        // the scalar core and the dispatched rung are exercised, and the
        // bit-identical-across-rungs contract makes assert_eq valid
        use crate::tensor::simd::{bf16_from_f32, bf16_to_f32};
        let mut rng = Rng::new(32);
        for len in [3usize, 15, 16, 33, 100] {
            let xf = randv(len, &mut rng);
            let y = randv(len, &mut rng);
            let x0: Vec<u16> = xf.iter().map(|&v| bf16_from_f32(v)).collect();
            let mut x = x0.clone();
            bf16_axpby_inplace(&mut x, 0.95, &y, 0.05);
            for i in 0..len {
                let want = bf16_from_f32(0.95 * bf16_to_f32(x0[i]) + 0.05 * y[i]);
                assert_eq!(x[i], want, "len {len} at {i}");
            }
            let yb: Vec<u16> = y.iter().map(|&v| bf16_from_f32(v)).collect();
            let mut x = x0.clone();
            bf16_axpby_from_bf16(&mut x, 0.9, &yb, -0.2);
            for i in 0..len {
                let want =
                    bf16_from_f32(0.9 * bf16_to_f32(x0[i]) - 0.2 * bf16_to_f32(yb[i]));
                assert_eq!(x[i], want, "len {len} at {i}");
            }
        }
    }

    #[test]
    fn bf16_row_sumsq_is_rung_invariant_and_correct() {
        // the dispatched reduction must reproduce the pinned 8-bank
        // scalar core bit for bit on whatever rung is active, and track
        // an f64 reference within bf16 rounding distance
        use crate::tensor::simd::bf16_from_f32;
        let mut rng = Rng::new(33);
        for len in [0usize, 5, 8, 16, 31, 64, 257] {
            let xf = randv(len, &mut rng);
            let x: Vec<u16> = xf.iter().map(|&v| bf16_from_f32(v)).collect();
            let got = bf16_row_sumsq(&x);
            let pinned = bf16_row_sumsq_scalar(&x);
            assert_eq!(got.to_bits(), pinned.to_bits(), "len {len}");
            let want: f64 = x
                .iter()
                .map(|&b| {
                    let v = crate::tensor::simd::bf16_to_f32(b) as f64;
                    v * v
                })
                .sum();
            assert!((got as f64 - want).abs() < 1e-3 * (1.0 + want), "len {len}");
        }
    }

    #[test]
    fn single_thread_pin_forces_serial_and_restores() {
        assert!(!SINGLE_SCOPE.with(|c| c.get()));
        let got = run_single_threaded(|| {
            assert_eq!(plan_threads(1024, usize::MAX, 0), 1, "pinned");
            7
        });
        assert_eq!(got, 7);
        assert!(!SINGLE_SCOPE.with(|c| c.get()), "pin must restore");
        pin_thread_single(true);
        assert_eq!(plan_threads(1024, usize::MAX, 0), 1);
        pin_thread_single(false);
    }
}
