//! The x86-64 AVX-512F backend: [`SimdLane`] implemented on 16-lane
//! `__m512` registers, plus thin `#[target_feature(enable = "avx512f")]`
//! wrappers around the generic bodies in [`super::lane`] — the widest
//! rung of the dispatch ladder.
//!
//! The generic layer fixes the loop structure, so this backend covers
//! one 16-wide packed-B strip with a **single** f32x16 register per tile
//! row (where AVX2 uses two f32x8 and NEON four f32x4), the dot/Gram
//! reductions run four accumulators of 16 lanes (64 elements per
//! unrolled step), and `_mm512_fmadd_ps` provides the fused
//! multiply-add. Horizontal folds use the `_mm512_reduce_*` intrinsics,
//! which are part of the AVX-512F foundation subset — nothing here
//! needs DQ/BW/VL extensions, so [`super::avx512_available`] checks
//! `avx512f` alone.
//!
//! Every function is `unsafe` because it must only run when AVX-512F is
//! present, which the dispatch sites in [`crate::tensor::kernels`]
//! guarantee via [`super::active`].

use core::arch::x86_64::*;

use super::lane::{self, SimdLane};

/// Packed-B strip width: 16 columns = one f32x16 accumulator per row.
pub const NR: usize = lane::NR;

/// Accumulator registers per strip row (`NR / 16`).
const NV: usize = NR / 16;

/// One AVX-512 register of 16 f32 lanes.
#[derive(Clone, Copy)]
pub(crate) struct F32x16(__m512);

impl SimdLane for F32x16 {
    const LANES: usize = 16;

    #[inline(always)]
    unsafe fn zero() -> Self {
        F32x16(_mm512_setzero_ps())
    }

    #[inline(always)]
    unsafe fn splat(x: f32) -> Self {
        F32x16(_mm512_set1_ps(x))
    }

    #[inline(always)]
    unsafe fn load(p: *const f32) -> Self {
        F32x16(_mm512_loadu_ps(p))
    }

    #[inline(always)]
    unsafe fn store(self, p: *mut f32) {
        _mm512_storeu_ps(p, self.0)
    }

    #[inline(always)]
    unsafe fn add(self, other: Self) -> Self {
        F32x16(_mm512_add_ps(self.0, other.0))
    }

    #[inline(always)]
    unsafe fn mul(self, other: Self) -> Self {
        F32x16(_mm512_mul_ps(self.0, other.0))
    }

    #[inline(always)]
    unsafe fn fma(self, a: Self, b: Self) -> Self {
        F32x16(_mm512_fmadd_ps(a.0, b.0, self.0))
    }

    #[inline(always)]
    unsafe fn hsum(self) -> f32 {
        _mm512_reduce_add_ps(self.0)
    }

    #[inline(always)]
    unsafe fn max(self, other: Self) -> Self {
        F32x16(_mm512_max_ps(self.0, other.0))
    }

    #[inline(always)]
    unsafe fn hmax(self) -> f32 {
        _mm512_reduce_max_ps(self.0)
    }
}

/// 4×f32x16 dot product (64 elements per unrolled step).
#[target_feature(enable = "avx512f")]
pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    lane::dot::<F32x16>(x, y)
}

/// `dst = a·x + b·y` elementwise.
#[target_feature(enable = "avx512f")]
pub unsafe fn axpby(dst: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
    lane::axpby::<F32x16>(dst, a, x, b, y)
}

/// `x = a·x + b·y` elementwise, in place.
#[target_feature(enable = "avx512f")]
pub unsafe fn axpby_inplace(x: &mut [f32], a: f32, y: &[f32], b: f32) {
    lane::axpby_inplace::<F32x16>(x, a, y, b)
}

/// `dst = b · a` elementwise (the init pass of the fused NS5 poly).
#[target_feature(enable = "avx512f")]
pub unsafe fn scale_into(dst: &mut [f32], a: &[f32], b: f32) {
    lane::scale_into::<F32x16>(dst, a, b)
}

/// Fused row normalization: `dst[i,:] = src[i,:] / max(‖src[i,:]‖₂, eps)`.
#[target_feature(enable = "avx512f")]
pub unsafe fn row_normalize_rows(dst: &mut [f32], src: &[f32], cols: usize, eps: f32) {
    lane::row_normalize_rows::<F32x16>(dst, src, cols, eps)
}

/// Row-wise softmax (vector max scan + normalize; scalar exp/sum).
#[target_feature(enable = "avx512f")]
pub unsafe fn row_softmax_rows(dst: &mut [f32], src: &[f32], cols: usize) {
    lane::row_softmax_rows::<F32x16>(dst, src, cols)
}

/// Row-wise softmax backward sweep.
#[target_feature(enable = "avx512f")]
pub unsafe fn row_softmax_grad_rows(dst: &mut [f32], p: &[f32], dp: &[f32], cols: usize) {
    lane::row_softmax_grad_rows::<F32x16>(dst, p, dp, cols)
}

/// Fused RMSNorm rows: `dst[i,:] = gain ⊙ src[i,:] · rms(src[i,:])⁻¹`.
#[target_feature(enable = "avx512f")]
pub unsafe fn rmsnorm_rows(dst: &mut [f32], src: &[f32], gain: &[f32], cols: usize, eps: f32) {
    lane::rmsnorm_rows::<F32x16>(dst, src, gain, cols, eps)
}

/// RMSNorm backward sweep (`dx` per row, `dgain` accumulated).
#[target_feature(enable = "avx512f")]
pub unsafe fn rmsnorm_grad_rows(
    dx: &mut [f32],
    dgain: &mut [f32],
    dy: &[f32],
    src: &[f32],
    gain: &[f32],
    cols: usize,
    eps: f32,
) {
    lane::rmsnorm_grad_rows::<F32x16>(dx, dgain, dy, src, gain, cols, eps)
}

/// `dst (mc×n) {=, +=} alpha · a (mc×k) · B` over the packed panels; see
/// [`lane::matmul_packed_rows`]. `pa` is the chunk's
/// [`crate::tensor::PackedA`] panels, or empty for the packed-B-only
/// path (bit-identical).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx512f")]
pub unsafe fn matmul_packed_rows(
    dst: &mut [f32],
    a: &[f32],
    pa: &[f32],
    pb: &[f32],
    k: usize,
    n: usize,
    alpha: f32,
    accumulate: bool,
) {
    lane::matmul_packed_rows::<F32x16, NV>(dst, a, pa, pb, k, n, alpha, accumulate)
}

/// Fused NS5 polynomial rows: `dst = b·a_rows + c·(a_rows · A)` with `A`
/// (m×m) pre-packed — no m×m `A²` intermediate is materialized.
#[target_feature(enable = "avx512f")]
pub unsafe fn ns_poly_rows(
    dst: &mut [f32],
    a_rows: &[f32],
    pa: &[f32],
    pb: &[f32],
    m: usize,
    b: f32,
    c: f32,
) {
    lane::ns_poly_rows::<F32x16, NV>(dst, a_rows, pa, pb, m, b, c)
}

/// Gram rows `i0..i1` of `a·aᵀ` into `dst_chunk` (full rows, length `m`
/// each): 4-row tiles share each streamed `a_j` row across four fma
/// accumulators; remainder rows fall back to [`dot`].
#[target_feature(enable = "avx512f")]
pub unsafe fn gram_rows(
    dst_chunk: &mut [f32],
    a: &[f32],
    i0: usize,
    i1: usize,
    m: usize,
    k: usize,
) {
    lane::gram_rows::<F32x16>(dst_chunk, a, i0, i1, m, k)
}

/// Pack f32 into bf16 bits (RNE); see [`lane::bf16_pack`].
#[target_feature(enable = "avx512f")]
pub unsafe fn bf16_pack(src: &[f32], dst: &mut [u16]) {
    lane::bf16_pack::<F32x16>(src, dst)
}

/// Unpack bf16 bits to f32 (exact); see [`lane::bf16_unpack`].
#[target_feature(enable = "avx512f")]
pub unsafe fn bf16_unpack(src: &[u16], dst: &mut [f32]) {
    lane::bf16_unpack::<F32x16>(src, dst)
}

/// bf16 EMA sweep `x = rne(a·widen(x) + b·y)`; see
/// [`lane::bf16_axpby_inplace`].
#[target_feature(enable = "avx512f")]
pub unsafe fn bf16_axpby_inplace(x: &mut [u16], a: f32, y: &[f32], b: f32) {
    lane::bf16_axpby_inplace::<F32x16>(x, a, y, b)
}

/// bf16/bf16 sweep `x = rne(a·widen(x) + b·widen(y))`; see
/// [`lane::bf16_axpby_from_bf16`].
#[target_feature(enable = "avx512f")]
pub unsafe fn bf16_axpby_from_bf16(x: &mut [u16], a: f32, y: &[u16], b: f32) {
    lane::bf16_axpby_from_bf16::<F32x16>(x, a, y, b)
}

/// Widened sum of squares of a bf16 row; see [`lane::bf16_row_sumsq`].
#[target_feature(enable = "avx512f")]
pub unsafe fn bf16_row_sumsq(x: &[u16]) -> f32 {
    lane::bf16_row_sumsq::<F32x16>(x)
}
