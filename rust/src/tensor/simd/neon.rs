//! The aarch64 NEON backend: [`SimdLane`] implemented on 4-lane
//! `float32x4_t` registers, plus thin `#[target_feature(enable = "neon")]`
//! wrappers around the generic bodies in [`super::lane`] — the rung that
//! lets ARM hosts leave the scalar tiles.
//!
//! The generic layer fixes the loop structure, so this backend covers one
//! 16-wide packed-B strip with **four** f32x4 registers per tile row
//! (where AVX2 uses two f32x8), the dot/Gram reductions run four
//! accumulators of 4 lanes (16 elements per unrolled step), and `vfmaq`
//! provides the fused multiply-add. aarch64 guarantees NEON in its
//! baseline, so [`super::neon_available`] is effectively always true
//! there — the feature check is kept for symmetry with the AVX2 rung and
//! for any future aarch64 profile without it.
//!
//! Every function is `unsafe` because it must only run when NEON is
//! present, which the dispatch sites in [`crate::tensor::kernels`]
//! guarantee via [`super::active`].

use core::arch::aarch64::*;

use super::lane::{self, SimdLane};

/// Packed-B strip width: 16 columns = four f32x4 accumulators per row.
pub const NR: usize = lane::NR;

/// Accumulator registers per strip row (`NR / 4`).
const NV: usize = NR / 4;

/// One NEON register of 4 f32 lanes.
#[derive(Clone, Copy)]
pub(crate) struct F32x4(float32x4_t);

impl SimdLane for F32x4 {
    const LANES: usize = 4;

    #[inline(always)]
    unsafe fn zero() -> Self {
        F32x4(vdupq_n_f32(0.0))
    }

    #[inline(always)]
    unsafe fn splat(x: f32) -> Self {
        F32x4(vdupq_n_f32(x))
    }

    #[inline(always)]
    unsafe fn load(p: *const f32) -> Self {
        F32x4(vld1q_f32(p))
    }

    #[inline(always)]
    unsafe fn store(self, p: *mut f32) {
        vst1q_f32(p, self.0)
    }

    #[inline(always)]
    unsafe fn add(self, other: Self) -> Self {
        F32x4(vaddq_f32(self.0, other.0))
    }

    #[inline(always)]
    unsafe fn mul(self, other: Self) -> Self {
        F32x4(vmulq_f32(self.0, other.0))
    }

    #[inline(always)]
    unsafe fn fma(self, a: Self, b: Self) -> Self {
        F32x4(vfmaq_f32(self.0, a.0, b.0))
    }

    #[inline(always)]
    unsafe fn hsum(self) -> f32 {
        vaddvq_f32(self.0)
    }

    #[inline(always)]
    unsafe fn max(self, other: Self) -> Self {
        F32x4(vmaxq_f32(self.0, other.0))
    }

    #[inline(always)]
    unsafe fn hmax(self) -> f32 {
        vmaxvq_f32(self.0)
    }
}

/// 4×f32x4 dot product (16 elements per unrolled step).
#[target_feature(enable = "neon")]
pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    lane::dot::<F32x4>(x, y)
}

/// `dst = a·x + b·y` elementwise.
#[target_feature(enable = "neon")]
pub unsafe fn axpby(dst: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
    lane::axpby::<F32x4>(dst, a, x, b, y)
}

/// `x = a·x + b·y` elementwise, in place.
#[target_feature(enable = "neon")]
pub unsafe fn axpby_inplace(x: &mut [f32], a: f32, y: &[f32], b: f32) {
    lane::axpby_inplace::<F32x4>(x, a, y, b)
}

/// `dst = b · a` elementwise (the init pass of the fused NS5 poly).
#[target_feature(enable = "neon")]
pub unsafe fn scale_into(dst: &mut [f32], a: &[f32], b: f32) {
    lane::scale_into::<F32x4>(dst, a, b)
}

/// Fused row normalization: `dst[i,:] = src[i,:] / max(‖src[i,:]‖₂, eps)`.
#[target_feature(enable = "neon")]
pub unsafe fn row_normalize_rows(dst: &mut [f32], src: &[f32], cols: usize, eps: f32) {
    lane::row_normalize_rows::<F32x4>(dst, src, cols, eps)
}

/// Row-wise softmax (vector max scan + normalize; scalar exp/sum).
#[target_feature(enable = "neon")]
pub unsafe fn row_softmax_rows(dst: &mut [f32], src: &[f32], cols: usize) {
    lane::row_softmax_rows::<F32x4>(dst, src, cols)
}

/// Row-wise softmax backward sweep.
#[target_feature(enable = "neon")]
pub unsafe fn row_softmax_grad_rows(dst: &mut [f32], p: &[f32], dp: &[f32], cols: usize) {
    lane::row_softmax_grad_rows::<F32x4>(dst, p, dp, cols)
}

/// Fused RMSNorm rows: `dst[i,:] = gain ⊙ src[i,:] · rms(src[i,:])⁻¹`.
#[target_feature(enable = "neon")]
pub unsafe fn rmsnorm_rows(dst: &mut [f32], src: &[f32], gain: &[f32], cols: usize, eps: f32) {
    lane::rmsnorm_rows::<F32x4>(dst, src, gain, cols, eps)
}

/// RMSNorm backward sweep (`dx` per row, `dgain` accumulated).
#[target_feature(enable = "neon")]
pub unsafe fn rmsnorm_grad_rows(
    dx: &mut [f32],
    dgain: &mut [f32],
    dy: &[f32],
    src: &[f32],
    gain: &[f32],
    cols: usize,
    eps: f32,
) {
    lane::rmsnorm_grad_rows::<F32x4>(dx, dgain, dy, src, gain, cols, eps)
}

/// `dst (mc×n) {=, +=} alpha · a (mc×k) · B` over the packed panels; see
/// [`lane::matmul_packed_rows`]. `pa` is the chunk's
/// [`crate::tensor::PackedA`] panels, or empty for the packed-B-only
/// path (bit-identical).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub unsafe fn matmul_packed_rows(
    dst: &mut [f32],
    a: &[f32],
    pa: &[f32],
    pb: &[f32],
    k: usize,
    n: usize,
    alpha: f32,
    accumulate: bool,
) {
    lane::matmul_packed_rows::<F32x4, NV>(dst, a, pa, pb, k, n, alpha, accumulate)
}

/// Fused NS5 polynomial rows: `dst = b·a_rows + c·(a_rows · A)` with `A`
/// (m×m) pre-packed — no m×m `A²` intermediate is materialized.
#[target_feature(enable = "neon")]
pub unsafe fn ns_poly_rows(
    dst: &mut [f32],
    a_rows: &[f32],
    pa: &[f32],
    pb: &[f32],
    m: usize,
    b: f32,
    c: f32,
) {
    lane::ns_poly_rows::<F32x4, NV>(dst, a_rows, pa, pb, m, b, c)
}

/// Gram rows `i0..i1` of `a·aᵀ` into `dst_chunk` (full rows, length `m`
/// each): 4-row tiles share each streamed `a_j` row across four fma
/// accumulators; remainder rows fall back to [`dot`].
#[target_feature(enable = "neon")]
pub unsafe fn gram_rows(
    dst_chunk: &mut [f32],
    a: &[f32],
    i0: usize,
    i1: usize,
    m: usize,
    k: usize,
) {
    lane::gram_rows::<F32x4>(dst_chunk, a, i0, i1, m, k)
}

/// Pack f32 into bf16 bits (RNE); see [`lane::bf16_pack`].
#[target_feature(enable = "neon")]
pub unsafe fn bf16_pack(src: &[f32], dst: &mut [u16]) {
    lane::bf16_pack::<F32x4>(src, dst)
}

/// Unpack bf16 bits to f32 (exact); see [`lane::bf16_unpack`].
#[target_feature(enable = "neon")]
pub unsafe fn bf16_unpack(src: &[u16], dst: &mut [f32]) {
    lane::bf16_unpack::<F32x4>(src, dst)
}
