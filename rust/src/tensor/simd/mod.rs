//! Backend-generic SIMD microkernels + runtime dispatch for the tensor
//! layer.
//!
//! PR 2 introduced hand-written AVX2/FMA kernels; this module now splits
//! them into three pieces so new ISAs are one small file, not a rewrite:
//!
//! * `lane` — the shared tiling/loop structure (dot, axpby, fused
//!   rownorm sweep, Gram tiles, packed matmul microkernel, fused NS5
//!   polynomial) written once over the `SimdLane` register abstraction;
//! * `avx2` — the x86-64 backend: 8-lane `__m256` + FMA (bit-identical
//!   to the pre-refactor hand-written kernels: same intrinsics, same
//!   order);
//! * `avx512` — the wider x86-64 backend: 16-lane `__m512` + FMA over
//!   the same generic bodies, one full `NR`-wide strip per register;
//! * `neon` — the aarch64 backend: 4-lane `float32x4_t` + `vfmaq`, the
//!   rung that lets ARM hosts leave the scalar tiles.
//!
//! The dispatch ladder resolves once per call site, cached where it
//! matters:
//!
//! 1. `perf.simd` config key / [`set_mode`] — explicit `"avx2"`,
//!    `"avx512"`, `"neon"`, or `"scalar"` override (the CLI prints the
//!    chosen rung at startup);
//! 2. the `RMNP_SIMD` environment variable (same values) — this is how
//!    CI's forced-scalar job keeps the portable path green;
//! 3. runtime detection ([`detected`]): `is_x86_feature_detected!` for
//!    AVX-512F, else AVX2+FMA, on x86-64;
//!    `is_aarch64_feature_detected!` for NEON on aarch64, evaluated once
//!    per process and cached.
//!
//! Forcing a rung the CPU cannot execute quietly lands on the scalar
//! tiles — [`active`] never returns a path the hardware cannot run, and
//! a forced rung never silently substitutes a *different* vector rung
//! (`RMNP_SIMD=neon` on x86 is scalar, not AVX2, and `RMNP_SIMD=avx512`
//! on an AVX2-only host is scalar, not AVX2; the `tests/neon_rung.rs`
//! and `tests/avx512_rung.rs` suites pin that contract).
//!
//! Numerics: the vector paths use fused multiply-add and lane-wide folds,
//! so results differ from the scalar tiles by normal f32 rounding
//! (reassociation + fused rounding), and the two vector backends differ
//! from each other the same way (different lane widths fold reductions
//! differently). The parity tests in `tests/kernels_parity.rs` hold every
//! rung within 1e-4 of the others. Within one rung, results are
//! bit-deterministic: the matmul tile and remainder kernels perform the
//! identical per-row operation sequence, the packed-A fast path reads
//! the same values in the same order (see `tensor/simd/lane.rs`), and
//! every threaded row partition — matmul chunks and the Gram triangle
//! boundaries alike — is aligned to the 4-row tile height so the Gram
//! tile/remainder fold assignment cannot move with the thread count.
//! Neither threads nor packing ever change output bits.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx512;
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
pub(crate) mod lane;
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

/// Requested dispatch mode (`perf.simd` / `RMNP_SIMD`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Detect at startup (the default).
    Auto,
    /// Force the AVX2/FMA path (falls back to scalar if unsupported).
    Avx2,
    /// Force the AVX-512F path (falls back to scalar if unsupported).
    Avx512,
    /// Force the NEON path (falls back to scalar if unsupported).
    Neon,
    /// Force the portable scalar tiles.
    Scalar,
}

impl SimdMode {
    /// Parse a `perf.simd` / `RMNP_SIMD` value.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "auto" => SimdMode::Auto,
            "avx2" => SimdMode::Avx2,
            "avx512" => SimdMode::Avx512,
            "neon" => SimdMode::Neon,
            "scalar" => SimdMode::Scalar,
            other => anyhow::bail!(
                "unknown simd mode `{other}` (expected auto|avx2|avx512|neon|scalar)"
            ),
        })
    }

    /// The config-file spelling of this mode.
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Avx2 => "avx2",
            SimdMode::Avx512 => "avx512",
            SimdMode::Neon => "neon",
            SimdMode::Scalar => "scalar",
        }
    }
}

/// The resolved execution path — what the kernels actually run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdPath {
    /// The x86-64 AVX2/FMA backend (8-lane f32 registers).
    Avx2,
    /// The x86-64 AVX-512F backend (16-lane f32 registers).
    Avx512,
    /// The aarch64 NEON backend (4-lane f32 registers).
    Neon,
    /// The portable scalar tiles.
    Scalar,
}

impl SimdPath {
    /// The mode that forces exactly this path (used by benches to pin a
    /// rung while measuring rung deltas).
    pub fn to_mode(self) -> SimdMode {
        match self {
            SimdPath::Avx2 => SimdMode::Avx2,
            SimdPath::Avx512 => SimdMode::Avx512,
            SimdPath::Neon => SimdMode::Neon,
            SimdPath::Scalar => SimdMode::Scalar,
        }
    }

    /// Short rung name recorded in the bench JSON envelopes.
    pub fn name(self) -> &'static str {
        match self {
            SimdPath::Avx2 => "avx2",
            SimdPath::Avx512 => "avx512",
            SimdPath::Neon => "neon",
            SimdPath::Scalar => "scalar",
        }
    }
}

static MODE: AtomicU8 = AtomicU8::new(0); // 0 = auto, 1 = avx2, 2 = scalar, 3 = neon, 4 = avx512

/// Set the dispatch mode (wired to the `perf.simd` config key and the
/// CLI). `Auto` restores env-var/detection resolution.
pub fn set_mode(mode: SimdMode) {
    let v = match mode {
        SimdMode::Auto => 0,
        SimdMode::Avx2 => 1,
        SimdMode::Scalar => 2,
        SimdMode::Neon => 3,
        SimdMode::Avx512 => 4,
    };
    MODE.store(v, Ordering::Relaxed);
}

/// The currently requested mode (not the resolved path; see [`active`]).
pub fn mode() -> SimdMode {
    match MODE.load(Ordering::Relaxed) {
        1 => SimdMode::Avx2,
        2 => SimdMode::Scalar,
        3 => SimdMode::Neon,
        4 => SimdMode::Avx512,
        _ => SimdMode::Auto,
    }
}

/// `RMNP_SIMD` env override, parsed once (invalid values mean `Auto`).
fn env_mode() -> SimdMode {
    static ENV: OnceLock<SimdMode> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("RMNP_SIMD")
            .ok()
            .and_then(|s| SimdMode::parse(&s).ok())
            .unwrap_or(SimdMode::Auto)
    })
}

/// Whether this CPU can run the AVX2/FMA kernels (detected once).
pub fn avx2_available() -> bool {
    static DET: OnceLock<bool> = OnceLock::new();
    *DET.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// Whether this CPU can run the AVX-512F kernels (detected once). The
/// f32x16 backend uses only `avx512f` intrinsics (loads, stores, FMA,
/// and the `_mm512_reduce_*` folds), so the foundation subset is the
/// whole requirement.
pub fn avx512_available() -> bool {
    static DET: OnceLock<bool> = OnceLock::new();
    *DET.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx512f")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// Whether this CPU can run the NEON kernels (detected once). aarch64
/// guarantees NEON in its baseline, so on ARM hosts this is effectively
/// always true; the check exists for ladder symmetry.
pub fn neon_available() -> bool {
    static DET: OnceLock<bool> = OnceLock::new();
    *DET.get_or_init(|| {
        #[cfg(target_arch = "aarch64")]
        {
            std::arch::is_aarch64_feature_detected!("neon")
        }
        #[cfg(not(target_arch = "aarch64"))]
        {
            false
        }
    })
}

/// The rung `Auto` resolves to on this host before any override — the
/// best available backend, widest rung first (AVX-512F implies AVX2 on
/// every real CPU, so the order only matters on x86-64).
pub fn detected() -> SimdPath {
    if avx512_available() {
        SimdPath::Avx512
    } else if avx2_available() {
        SimdPath::Avx2
    } else if neon_available() {
        SimdPath::Neon
    } else {
        SimdPath::Scalar
    }
}

/// Resolve the dispatch ladder to the path the kernels will take.
pub fn active() -> SimdPath {
    let requested = match mode() {
        SimdMode::Auto => env_mode(),
        explicit => explicit,
    };
    match requested {
        SimdMode::Scalar => SimdPath::Scalar,
        SimdMode::Avx2 => {
            if avx2_available() {
                SimdPath::Avx2
            } else {
                SimdPath::Scalar
            }
        }
        SimdMode::Avx512 => {
            if avx512_available() {
                SimdPath::Avx512
            } else {
                SimdPath::Scalar
            }
        }
        SimdMode::Neon => {
            if neon_available() {
                SimdPath::Neon
            } else {
                SimdPath::Scalar
            }
        }
        SimdMode::Auto => detected(),
    }
}

/// Human-readable label of the active path (printed at CLI startup and
/// recorded in the bench JSON envelopes).
pub fn label() -> &'static str {
    match active() {
        SimdPath::Avx2 => "avx2+fma (f32x8)",
        SimdPath::Avx512 => "avx512f (f32x16)",
        SimdPath::Neon => "neon (f32x4)",
        SimdPath::Scalar => "scalar (autovec tiles)",
    }
}

/// Convert one f32 to bf16 bits with round-to-nearest-even.
///
/// The rounding is the classic add-trick on the raw bit pattern:
/// `bits + 0x7FFF + (bit 16)` carries into the kept half exactly when
/// RNE rounds up (the extra LSB-of-kept term breaks exact ties toward
/// even). NaNs take a separate path — the carry would otherwise walk a
/// small payload up into the exponent and turn NaN into infinity — and
/// are quieted with their top payload bits preserved. Overflow rounds
/// to the correctly-signed infinity, matching IEEE-754 narrowing.
#[inline(always)]
pub fn bf16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) | 0x0040) as u16;
    }
    (bits.wrapping_add(0x7FFF + ((bits >> 16) & 1)) >> 16) as u16
}

/// Widen bf16 bits back to f32 — exact (bf16 is a prefix of f32).
#[inline(always)]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

fn bf16_pack_scalar(src: &[f32], dst: &mut [u16]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = bf16_from_f32(s);
    }
}

fn bf16_unpack_scalar(src: &[u16], dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = bf16_to_f32(s);
    }
}

/// Pack `src` into bf16 bit patterns with round-to-nearest-even,
/// dispatched down the same ladder as the float kernels. Every rung
/// performs the identical per-element bit arithmetic, so — unlike the
/// float kernels, where lane width changes reduction trees — the packed
/// bytes are bit-identical across rungs; the rung only changes speed.
pub fn bf16_pack(src: &[f32], dst: &mut [u16]) {
    assert_eq!(src.len(), dst.len(), "bf16_pack length mismatch");
    match active() {
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx2 => unsafe { avx2::bf16_pack(src, dst) },
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx512 => unsafe { avx512::bf16_pack(src, dst) },
        #[cfg(target_arch = "aarch64")]
        SimdPath::Neon => unsafe { neon::bf16_pack(src, dst) },
        _ => bf16_pack_scalar(src, dst),
    }
}

/// Unpack bf16 bit patterns to f32 (exact widening), dispatched like
/// [`bf16_pack`]. Bit-identical across rungs.
pub fn bf16_unpack(src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len(), "bf16_unpack length mismatch");
    match active() {
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx2 => unsafe { avx2::bf16_unpack(src, dst) },
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx512 => unsafe { avx512::bf16_unpack(src, dst) },
        #[cfg(target_arch = "aarch64")]
        SimdPath::Neon => unsafe { neon::bf16_unpack(src, dst) },
        _ => bf16_unpack_scalar(src, dst),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_and_names() {
        assert_eq!(SimdMode::parse("auto").unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse("avx2").unwrap(), SimdMode::Avx2);
        assert_eq!(SimdMode::parse("avx512").unwrap(), SimdMode::Avx512);
        assert_eq!(SimdMode::parse("neon").unwrap(), SimdMode::Neon);
        assert_eq!(SimdMode::parse("scalar").unwrap(), SimdMode::Scalar);
        assert!(SimdMode::parse("sse9").is_err());
        assert_eq!(SimdMode::Avx2.name(), "avx2");
        assert_eq!(SimdMode::Avx512.name(), "avx512");
        assert_eq!(SimdMode::Neon.name(), "neon");
        for path in [
            SimdPath::Avx2,
            SimdPath::Avx512,
            SimdPath::Neon,
            SimdPath::Scalar,
        ] {
            assert_eq!(SimdMode::parse(path.name()).unwrap(), path.to_mode());
        }
    }

    #[test]
    fn active_is_consistent_with_availability() {
        // whatever the mode, the resolved path must be runnable
        match active() {
            SimdPath::Avx2 => assert!(avx2_available()),
            SimdPath::Avx512 => assert!(avx512_available()),
            SimdPath::Neon => assert!(neon_available()),
            SimdPath::Scalar => {}
        }
        assert!(!label().is_empty());
        // the x86 and ARM rungs are mutually exclusive (avx512 is NOT
        // exclusive with avx2 — every AVX-512F CPU also has AVX2)
        assert!(!(avx2_available() && neon_available()));
        assert!(!(avx512_available() && neon_available()));
        if avx512_available() {
            assert_eq!(detected(), SimdPath::Avx512);
        }
        if !avx2_available() && !avx512_available() && !neon_available() {
            assert_eq!(detected(), SimdPath::Scalar);
        }
    }

    #[test]
    fn bf16_known_values_and_round_trip() {
        // hand-pinned conversions (the full python-oracle sweep lives in
        // tests/bf16_codec.rs; these are the spot checks)
        assert_eq!(bf16_from_f32(0.0), 0x0000);
        assert_eq!(bf16_from_f32(-0.0), 0x8000);
        assert_eq!(bf16_from_f32(1.0), 0x3F80);
        assert_eq!(bf16_from_f32(1.5), 0x3FC0);
        assert_eq!(bf16_from_f32(-0.5), 0xBF00);
        assert_eq!(bf16_from_f32(f32::INFINITY), 0x7F80);
        assert_eq!(bf16_from_f32(f32::NEG_INFINITY), 0xFF80);
        // overflow rounds to infinity, never wraps
        assert_eq!(bf16_from_f32(f32::MAX), 0x7F80);
        // exact ties go to even: 1.0 + 2^-8 sits halfway between
        // 0x3F80 and 0x3F81 and must land on the even 0x3F80
        assert_eq!(bf16_from_f32(f32::from_bits(0x3F80_8000)), 0x3F80);
        assert_eq!(bf16_from_f32(f32::from_bits(0x3F81_8000)), 0x3F82);
        // NaN stays NaN (quieted), payload top bits preserved
        assert_eq!(bf16_from_f32(f32::from_bits(0x7F80_0001)), 0x7FC0);
        assert!(bf16_to_f32(bf16_from_f32(f32::NAN)).is_nan());
        // every bf16-representable value round-trips exactly
        for b in [0x0000u16, 0x3F80, 0xC2C8, 0x0001, 0x8080, 0x7F7F] {
            assert_eq!(bf16_from_f32(bf16_to_f32(b)), b, "bits {b:#06x}");
        }
    }

    #[test]
    fn bf16_pack_dispatch_matches_scalar() {
        // the active rung (whatever it is) must produce the same bytes
        // as the scalar core — conversion is pure bit arithmetic
        let mut rng = crate::util::Rng::new(11);
        for len in [0usize, 1, 3, 8, 9, 31, 257] {
            let mut src = vec![0.0f32; len];
            rng.fill_normal(&mut src, 10.0);
            if len > 2 {
                src[1] = f32::NAN;
                src[2] = f32::INFINITY;
            }
            let mut fast = vec![0u16; len];
            let mut slow = vec![0u16; len];
            bf16_pack(&src, &mut fast);
            bf16_pack_scalar(&src, &mut slow);
            assert_eq!(fast, slow, "len {len}");
            let mut back_fast = vec![0.0f32; len];
            let mut back_slow = vec![0.0f32; len];
            bf16_unpack(&fast, &mut back_fast);
            bf16_unpack_scalar(&slow, &mut back_slow);
            assert_eq!(
                back_fast.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                back_slow.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "len {len}"
            );
        }
    }

    /// Backend kernel tests, written once against whichever vector
    /// backend this architecture compiles (`avx2` on x86-64, `neon` on
    /// aarch64) — the generic layer makes the expectations identical.
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    mod native_kernels {
        #[cfg(target_arch = "x86_64")]
        use super::super::{avx2 as native, avx2_available as native_available};
        #[cfg(target_arch = "aarch64")]
        use super::super::{neon as native, neon_available as native_available};
        use crate::tensor::{PackedA, PackedB};
        use crate::util::Rng;

        fn randv(len: usize, rng: &mut Rng) -> Vec<f32> {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, 1.0);
            v
        }

        fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
            let mut out = vec![0.0f32; m * n];
            for i in 0..m {
                for p in 0..k {
                    let av = a[i * k + p];
                    for j in 0..n {
                        out[i * n + j] += av * b[p * n + j];
                    }
                }
            }
            out
        }

        /// Rect/tall/wide shapes straddling the 16-col strip and 4-row
        /// panel boundaries, including every `m % 4` residue.
        const SHAPES: &[(usize, usize, usize)] = &[
            (1, 1, 1),
            (4, 4, 16),
            (5, 7, 3),
            (4, 9, 17),
            (9, 16, 33),
            (33, 65, 19),
            (2, 128, 130),
            (64, 32, 48),
            (66, 20, 40),
            (7, 40, 96),
        ];

        #[test]
        fn bf16_pack_wrapper_matches_scalar_core() {
            if !native_available() {
                return;
            }
            let mut rng = Rng::new(21);
            for len in [1usize, 4, 7, 8, 9, 100, 255] {
                let src = randv(len, &mut rng);
                let mut got = vec![0u16; len];
                unsafe { native::bf16_pack(&src, &mut got) };
                let want: Vec<u16> =
                    src.iter().map(|&x| super::super::bf16_from_f32(x)).collect();
                assert_eq!(got, want, "pack len {len}");
                let mut back = vec![0.0f32; len];
                unsafe { native::bf16_unpack(&got, &mut back) };
                for (b, &w) in back.iter().zip(&got) {
                    assert_eq!(b.to_bits(), (w as u32) << 16);
                }
            }
        }

        #[test]
        fn dot_matches_sequential() {
            if !native_available() {
                return;
            }
            let mut rng = Rng::new(1);
            for len in [0usize, 1, 7, 8, 9, 31, 32, 33, 64, 100, 257] {
                let x = randv(len, &mut rng);
                let y = randv(len, &mut rng);
                let seq: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
                let got = unsafe { native::dot(&x, &y) };
                assert!(
                    (got - seq).abs() < 1e-3 * (1.0 + seq.abs()),
                    "len {len}: {got} vs {seq}"
                );
            }
        }

        #[test]
        fn axpby_matches_scalar() {
            if !native_available() {
                return;
            }
            let mut rng = Rng::new(2);
            for len in [1usize, 5, 8, 9, 40, 100] {
                let x = randv(len, &mut rng);
                let y = randv(len, &mut rng);
                let mut dst = vec![0.0f32; len];
                unsafe { native::axpby(&mut dst, 1.5, &x, -0.5, &y) };
                for i in 0..len {
                    let want = 1.5 * x[i] - 0.5 * y[i];
                    assert!((dst[i] - want).abs() < 1e-5, "{i}");
                }
                let mut ip = x.clone();
                unsafe { native::axpby_inplace(&mut ip, 1.5, &y, -0.5) };
                for i in 0..len {
                    let want = 1.5 * x[i] - 0.5 * y[i];
                    assert!((ip[i] - want).abs() < 1e-5, "{i}");
                }
            }
        }

        #[test]
        fn packed_matmul_matches_naive_including_tails() {
            if !native_available() {
                return;
            }
            let mut rng = Rng::new(3);
            for &(m, k, n) in SHAPES {
                let a = randv(m * k, &mut rng);
                let b = randv(k * n, &mut rng);
                let mut pb = PackedB::new();
                pb.pack(&b, k, n);
                let mut pa = PackedA::new();
                pa.pack(&a, m, k);
                let want = naive(&a, &b, m, k, n);
                // packed-B-only path (strided A reads)
                let mut b_only = vec![0.0f32; m * n];
                unsafe {
                    native::matmul_packed_rows(&mut b_only, &a, &[], pb.data(), k, n, 1.0, false)
                };
                // packed-A path (panel A reads)
                let mut with_pa = vec![0.0f32; m * n];
                unsafe {
                    native::matmul_packed_rows(
                        &mut with_pa,
                        &a,
                        pa.data(),
                        pb.data(),
                        k,
                        n,
                        1.0,
                        false,
                    )
                };
                for i in 0..m {
                    for j in 0..n {
                        let w = want[i * n + j];
                        let x = b_only[i * n + j];
                        assert!(
                            (x - w).abs() < 1e-3 * (1.0 + w.abs()),
                            "b-only ({m},{k},{n}) at ({i},{j}): {x} vs {w}"
                        );
                    }
                }
                // packing A is an exact copy with unchanged arithmetic
                // order, so the two paths must agree bit for bit
                assert_eq!(
                    b_only, with_pa,
                    "packed-A changed bits at ({m},{k},{n})"
                );
            }
        }

        #[test]
        fn packed_matmul_accumulate_adds_scaled_product() {
            if !native_available() {
                return;
            }
            let mut rng = Rng::new(4);
            for &(m, k, n) in &[(6usize, 10usize, 21usize), (13, 8, 40)] {
                let a = randv(m * k, &mut rng);
                let b = randv(k * n, &mut rng);
                let init = randv(m * n, &mut rng);
                let mut pb = PackedB::new();
                pb.pack(&b, k, n);
                let mut pa = PackedA::new();
                pa.pack(&a, m, k);
                let want = naive(&a, &b, m, k, n);
                for pa_data in [&[][..], pa.data()] {
                    let mut got = init.clone();
                    unsafe {
                        native::matmul_packed_rows(
                            &mut got, &a, pa_data, pb.data(), k, n, 0.5, true,
                        )
                    };
                    for i in 0..m * n {
                        let w = init[i] + 0.5 * want[i];
                        assert!(
                            (got[i] - w).abs() < 1e-3 * (1.0 + w.abs()),
                            "({m},{k},{n}) at {i}: {} vs {w}",
                            got[i]
                        );
                    }
                }
            }
        }

        #[test]
        fn tile_and_remainder_rows_agree_bitwise() {
            // the determinism contract: processing a row inside a 4-tile
            // or as a remainder row gives identical bits
            if !native_available() {
                return;
            }
            let mut rng = Rng::new(5);
            let (k, n) = (37usize, 29usize);
            let a = randv(5 * k, &mut rng); // 5 rows: one 4-tile + 1 remainder
            let b = randv(k * n, &mut rng);
            let mut packed = PackedB::new();
            packed.pack(&b, k, n);
            let mut whole = vec![0.0f32; 5 * n];
            unsafe {
                native::matmul_packed_rows(&mut whole, &a, &[], packed.data(), k, n, 1.0, false)
            };
            // row 4 alone (remainder path) must equal row 4 of the block
            let mut single = vec![0.0f32; n];
            unsafe {
                native::matmul_packed_rows(
                    &mut single,
                    &a[4 * k..5 * k],
                    &[],
                    packed.data(),
                    k,
                    n,
                    1.0,
                    false,
                )
            };
            assert_eq!(&whole[4 * n..5 * n], &single[..]);
            // and row 0 computed alone must equal row 0 of the 4-tile
            let mut first = vec![0.0f32; n];
            unsafe {
                native::matmul_packed_rows(
                    &mut first,
                    &a[0..k],
                    &[],
                    packed.data(),
                    k,
                    n,
                    1.0,
                    false,
                )
            };
            assert_eq!(&whole[0..n], &first[..]);
        }

        #[test]
        fn rownorm_unit_and_zero_rows() {
            if !native_available() {
                return;
            }
            let mut rng = Rng::new(6);
            let (rows, cols) = (5usize, 37usize);
            let mut src = randv(rows * cols, &mut rng);
            for v in &mut src[2 * cols..3 * cols] {
                *v = 0.0;
            }
            let mut dst = vec![0.0f32; rows * cols];
            unsafe { native::row_normalize_rows(&mut dst, &src, cols, 1e-7) };
            for i in 0..rows {
                let n: f32 = dst[i * cols..(i + 1) * cols]
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    .sqrt();
                if i == 2 {
                    assert_eq!(n, 0.0);
                } else {
                    assert!((n - 1.0).abs() < 1e-5, "row {i} norm {n}");
                }
            }
        }

        #[test]
        fn gram_rows_matches_naive() {
            if !native_available() {
                return;
            }
            let mut rng = Rng::new(7);
            for (m, k) in [(1usize, 5usize), (4, 8), (6, 11), (13, 64), (9, 7)] {
                let a = randv(m * k, &mut rng);
                let mut got = vec![0.0f32; m * m];
                unsafe { native::gram_rows(&mut got, &a, 0, m, m, k) };
                for i in 0..m {
                    for j in i..m {
                        let want: f32 = (0..k).map(|p| a[i * k + p] * a[j * k + p]).sum();
                        let x = got[i * m + j];
                        assert!(
                            (x - want).abs() < 1e-3 * (1.0 + want.abs()),
                            "({m},{k}) at ({i},{j})"
                        );
                    }
                }
            }
        }

        #[test]
        fn ns_poly_rows_matches_two_pass() {
            if !native_available() {
                return;
            }
            let mut rng = Rng::new(8);
            for m in [4usize, 9, 33] {
                let a = randv(m * m, &mut rng);
                let a2 = naive(&a, &a, m, m, m);
                let want: Vec<f32> = a
                    .iter()
                    .zip(&a2)
                    .map(|(x, y)| -4.775 * x + 2.0315 * y)
                    .collect();
                let mut pb = PackedB::new();
                pb.pack(&a, m, m);
                let mut pa = PackedA::new();
                pa.pack(&a, m, m);
                for pa_data in [&[][..], pa.data()] {
                    let mut got = vec![0.0f32; m * m];
                    unsafe {
                        native::ns_poly_rows(&mut got, &a, pa_data, pb.data(), m, -4.775, 2.0315)
                    };
                    for i in 0..m * m {
                        assert!(
                            (got[i] - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()),
                            "m={m} at {i}: {} vs {}",
                            got[i],
                            want[i]
                        );
                    }
                }
            }
        }
    }
}
