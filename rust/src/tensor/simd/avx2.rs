//! The x86-64 AVX2/FMA backend: [`SimdLane`] implemented on 8-lane
//! `__m256` registers, plus thin `#[target_feature(enable = "avx2,fma")]`
//! wrappers around the generic bodies in [`super::lane`].
//!
//! Every function is `unsafe` because it must only run on CPUs where
//! [`super::avx2_available`] is true — the dispatch sites in
//! [`crate::tensor::kernels`] guarantee that via [`super::active`]. The
//! arithmetic sequences are the generic layer's; this file only pins the
//! register type and the ISA, so results are bit-identical to the
//! pre-refactor hand-written AVX2 kernels (same intrinsics, same order).

use core::arch::x86_64::*;

use super::lane::{self, SimdLane};

/// Packed-B strip width: 16 columns = two f32x8 accumulators per row.
pub const NR: usize = lane::NR;

/// Accumulator registers per strip row (`NR / 8`).
const NV: usize = NR / 8;

/// One AVX2 register of 8 f32 lanes.
#[derive(Clone, Copy)]
pub(crate) struct F32x8(__m256);

impl SimdLane for F32x8 {
    const LANES: usize = 8;

    #[inline(always)]
    unsafe fn zero() -> Self {
        F32x8(_mm256_setzero_ps())
    }

    #[inline(always)]
    unsafe fn splat(x: f32) -> Self {
        F32x8(_mm256_set1_ps(x))
    }

    #[inline(always)]
    unsafe fn load(p: *const f32) -> Self {
        F32x8(_mm256_loadu_ps(p))
    }

    #[inline(always)]
    unsafe fn store(self, p: *mut f32) {
        _mm256_storeu_ps(p, self.0)
    }

    #[inline(always)]
    unsafe fn add(self, other: Self) -> Self {
        F32x8(_mm256_add_ps(self.0, other.0))
    }

    #[inline(always)]
    unsafe fn mul(self, other: Self) -> Self {
        F32x8(_mm256_mul_ps(self.0, other.0))
    }

    #[inline(always)]
    unsafe fn fma(self, a: Self, b: Self) -> Self {
        F32x8(_mm256_fmadd_ps(a.0, b.0, self.0))
    }

    #[inline(always)]
    unsafe fn hsum(self) -> f32 {
        let lo = _mm256_castps256_ps128(self.0);
        let hi = _mm256_extractf128_ps(self.0, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    #[inline(always)]
    unsafe fn max(self, other: Self) -> Self {
        F32x8(_mm256_max_ps(self.0, other.0))
    }

    #[inline(always)]
    unsafe fn hmax(self) -> f32 {
        let lo = _mm256_castps256_ps128(self.0);
        let hi = _mm256_extractf128_ps(self.0, 1);
        let m = _mm_max_ps(lo, hi);
        let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
        let m = _mm_max_ss(m, _mm_shuffle_ps(m, m, 1));
        _mm_cvtss_f32(m)
    }
}

/// 4×f32x8 dot product (32 elements per unrolled step).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    lane::dot::<F32x8>(x, y)
}

/// `dst = a·x + b·y` elementwise.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn axpby(dst: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
    lane::axpby::<F32x8>(dst, a, x, b, y)
}

/// `x = a·x + b·y` elementwise, in place.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn axpby_inplace(x: &mut [f32], a: f32, y: &[f32], b: f32) {
    lane::axpby_inplace::<F32x8>(x, a, y, b)
}

/// `dst = b · a` elementwise (the init pass of the fused NS5 poly).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn scale_into(dst: &mut [f32], a: &[f32], b: f32) {
    lane::scale_into::<F32x8>(dst, a, b)
}

/// Fused row normalization: `dst[i,:] = src[i,:] / max(‖src[i,:]‖₂, eps)`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn row_normalize_rows(dst: &mut [f32], src: &[f32], cols: usize, eps: f32) {
    lane::row_normalize_rows::<F32x8>(dst, src, cols, eps)
}

/// Row-wise softmax (vector max scan + normalize; scalar exp/sum).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn row_softmax_rows(dst: &mut [f32], src: &[f32], cols: usize) {
    lane::row_softmax_rows::<F32x8>(dst, src, cols)
}

/// Row-wise softmax backward sweep.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn row_softmax_grad_rows(dst: &mut [f32], p: &[f32], dp: &[f32], cols: usize) {
    lane::row_softmax_grad_rows::<F32x8>(dst, p, dp, cols)
}

/// Fused RMSNorm rows: `dst[i,:] = gain ⊙ src[i,:] · rms(src[i,:])⁻¹`.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn rmsnorm_rows(dst: &mut [f32], src: &[f32], gain: &[f32], cols: usize, eps: f32) {
    lane::rmsnorm_rows::<F32x8>(dst, src, gain, cols, eps)
}

/// RMSNorm backward sweep (`dx` per row, `dgain` accumulated).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn rmsnorm_grad_rows(
    dx: &mut [f32],
    dgain: &mut [f32],
    dy: &[f32],
    src: &[f32],
    gain: &[f32],
    cols: usize,
    eps: f32,
) {
    lane::rmsnorm_grad_rows::<F32x8>(dx, dgain, dy, src, gain, cols, eps)
}

/// `dst (mc×n) {=, +=} alpha · a (mc×k) · B` over the packed panels; see
/// [`lane::matmul_packed_rows`]. `pa` is the chunk's
/// [`crate::tensor::PackedA`] panels, or empty for the packed-B-only
/// path (bit-identical).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub unsafe fn matmul_packed_rows(
    dst: &mut [f32],
    a: &[f32],
    pa: &[f32],
    pb: &[f32],
    k: usize,
    n: usize,
    alpha: f32,
    accumulate: bool,
) {
    lane::matmul_packed_rows::<F32x8, NV>(dst, a, pa, pb, k, n, alpha, accumulate)
}

/// Fused NS5 polynomial rows: `dst = b·a_rows + c·(a_rows · A)` with `A`
/// (m×m) pre-packed — no m×m `A²` intermediate is materialized.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn ns_poly_rows(
    dst: &mut [f32],
    a_rows: &[f32],
    pa: &[f32],
    pb: &[f32],
    m: usize,
    b: f32,
    c: f32,
) {
    lane::ns_poly_rows::<F32x8, NV>(dst, a_rows, pa, pb, m, b, c)
}

/// Gram rows `i0..i1` of `a·aᵀ` into `dst_chunk` (full rows, length `m`
/// each): 4-row tiles share each streamed `a_j` row across four FMA
/// accumulators; remainder rows fall back to [`dot`].
#[target_feature(enable = "avx2,fma")]
pub unsafe fn gram_rows(
    dst_chunk: &mut [f32],
    a: &[f32],
    i0: usize,
    i1: usize,
    m: usize,
    k: usize,
) {
    lane::gram_rows::<F32x8>(dst_chunk, a, i0, i1, m, k)
}

/// Pack f32 into bf16 bits (RNE); see [`lane::bf16_pack`].
#[target_feature(enable = "avx2,fma")]
pub unsafe fn bf16_pack(src: &[f32], dst: &mut [u16]) {
    lane::bf16_pack::<F32x8>(src, dst)
}

/// Unpack bf16 bits to f32 (exact); see [`lane::bf16_unpack`].
#[target_feature(enable = "avx2,fma")]
pub unsafe fn bf16_unpack(src: &[u16], dst: &mut [f32]) {
    lane::bf16_unpack::<F32x8>(src, dst)
}

/// bf16 EMA sweep `x = rne(a·widen(x) + b·y)`; see
/// [`lane::bf16_axpby_inplace`].
#[target_feature(enable = "avx2,fma")]
pub unsafe fn bf16_axpby_inplace(x: &mut [u16], a: f32, y: &[f32], b: f32) {
    lane::bf16_axpby_inplace::<F32x8>(x, a, y, b)
}

/// bf16/bf16 sweep `x = rne(a·widen(x) + b·widen(y))`; see
/// [`lane::bf16_axpby_from_bf16`].
#[target_feature(enable = "avx2,fma")]
pub unsafe fn bf16_axpby_from_bf16(x: &mut [u16], a: f32, y: &[u16], b: f32) {
    lane::bf16_axpby_from_bf16::<F32x8>(x, a, y, b)
}

/// Widened sum of squares of a bf16 row; see [`lane::bf16_row_sumsq`].
#[target_feature(enable = "avx2,fma")]
pub unsafe fn bf16_row_sumsq(x: &[u16]) -> f32 {
    lane::bf16_row_sumsq::<F32x8>(x)
}
