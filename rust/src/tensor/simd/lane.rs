//! The backend-generic microkernel bodies, written once over a small
//! [`SimdLane`] register abstraction and instantiated per backend
//! ([`super::avx2`] with 8-lane `__m256`, [`super::avx512`] with 16-lane
//! `__m512`, [`super::neon`] with 4-lane `float32x4_t`).
//!
//! Everything here is `#[inline(always)]` and carries **no**
//! `#[target_feature]` of its own: each backend module wraps these bodies
//! in thin `#[target_feature(enable = ...)]`-annotated functions, the
//! bodies inline into those wrappers, and the intrinsics behind the
//! [`SimdLane`] methods then compile with the right ISA enabled (the
//! same pattern `memchr`/`aho-corasick` use for their vector layers).
//!
//! **Bit-determinism contract.** For a fixed backend, the *matmul*
//! per-row arithmetic sequence is identical whether a row is processed
//! inside a 4-row tile or as a remainder row, and identical whether A
//! values come from the raw matrix or from [`crate::tensor::PackedA`]
//! panels (packing is an exact copy; only the read addresses change) —
//! so matmul row partitioning and the packed-A fast path never change
//! output bits. The *Gram* remainder rows reduce through [`dot`]'s
//! 4-accumulator fold, which differs from the tile rows' one-register
//! fold; Gram determinism instead comes from the caller keeping the
//! tile/remainder assignment fixed — `kernels::triangle_partition`
//! aligns its thread boundaries to [`MR`] so the same rows take the same
//! fold at every thread count. Across backends results differ by normal
//! f32 rounding (lane width changes the reduction tree); the parity
//! suite holds all rungs within 1e-4 of the scalar tiles.

use crate::tensor::{PackedA, PackedB};

/// Packed-B strip width in columns — every backend covers one strip with
/// `NR / LANES` accumulator registers per tile row.
pub(crate) const NR: usize = PackedB::NR;

/// Tile height in rows, matching the [`PackedA`] panel height.
pub(crate) const MR: usize = PackedA::MR;

/// One SIMD register of `LANES` f32 values.
///
/// All methods are `unsafe`: implementations are backed by arch
/// intrinsics that must only execute on CPUs with the matching feature,
/// which the dispatch ladder in [`super`] guarantees before any generic
/// body runs.
pub(crate) trait SimdLane: Copy {
    /// f32 lanes per register (8 for AVX2, 16 for AVX-512, 4 for NEON).
    const LANES: usize;
    /// All-zero register.
    unsafe fn zero() -> Self;
    /// Broadcast one scalar to every lane.
    unsafe fn splat(x: f32) -> Self;
    /// Unaligned load of `LANES` consecutive f32.
    unsafe fn load(p: *const f32) -> Self;
    /// Unaligned store of `LANES` consecutive f32.
    unsafe fn store(self, p: *mut f32);
    /// Lanewise `self + other`.
    unsafe fn add(self, other: Self) -> Self;
    /// Lanewise `self * other`.
    unsafe fn mul(self, other: Self) -> Self;
    /// Lanewise fused `self + a * b`.
    unsafe fn fma(self, a: Self, b: Self) -> Self;
    /// Horizontal sum of all lanes.
    unsafe fn hsum(self) -> f32;
    /// Lanewise `max(self, other)`.
    unsafe fn max(self, other: Self) -> Self;
    /// Horizontal maximum of all lanes.
    unsafe fn hmax(self) -> f32;
}

/// Dot product with four register accumulators (`4 * LANES` elements per
/// unrolled step), folded as `(acc0 + acc1) + (acc2 + acc3)`.
#[inline(always)]
pub(crate) unsafe fn dot<V: SimdLane>(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let l = V::LANES;
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut acc0 = V::zero();
    let mut acc1 = V::zero();
    let mut acc2 = V::zero();
    let mut acc3 = V::zero();
    let mut i = 0usize;
    while i + 4 * l <= n {
        acc0 = acc0.fma(V::load(xp.add(i)), V::load(yp.add(i)));
        acc1 = acc1.fma(V::load(xp.add(i + l)), V::load(yp.add(i + l)));
        acc2 = acc2.fma(V::load(xp.add(i + 2 * l)), V::load(yp.add(i + 2 * l)));
        acc3 = acc3.fma(V::load(xp.add(i + 3 * l)), V::load(yp.add(i + 3 * l)));
        i += 4 * l;
    }
    while i + l <= n {
        acc0 = acc0.fma(V::load(xp.add(i)), V::load(yp.add(i)));
        i += l;
    }
    let mut s = acc0.add(acc1).add(acc2.add(acc3)).hsum();
    while i < n {
        s += x[i] * y[i];
        i += 1;
    }
    s
}

/// `dst = a·x + b·y` elementwise.
#[inline(always)]
pub(crate) unsafe fn axpby<V: SimdLane>(dst: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
    debug_assert_eq!(dst.len(), x.len());
    debug_assert_eq!(x.len(), y.len());
    let n = dst.len();
    let l = V::LANES;
    let va = V::splat(a);
    let vb = V::splat(b);
    let dp = dst.as_mut_ptr();
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut i = 0usize;
    while i + l <= n {
        let ax = va.mul(V::load(xp.add(i)));
        ax.fma(vb, V::load(yp.add(i))).store(dp.add(i));
        i += l;
    }
    while i < n {
        dst[i] = a * x[i] + b * y[i];
        i += 1;
    }
}

/// `x = a·x + b·y` elementwise, in place.
#[inline(always)]
pub(crate) unsafe fn axpby_inplace<V: SimdLane>(x: &mut [f32], a: f32, y: &[f32], b: f32) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let l = V::LANES;
    let va = V::splat(a);
    let vb = V::splat(b);
    let xp = x.as_mut_ptr();
    let yp = y.as_ptr();
    let mut i = 0usize;
    while i + l <= n {
        let ax = va.mul(V::load(xp.add(i)));
        ax.fma(vb, V::load(yp.add(i))).store(xp.add(i));
        i += l;
    }
    while i < n {
        x[i] = a * x[i] + b * y[i];
        i += 1;
    }
}

/// `dst = b · a` elementwise (the init pass of the fused NS5 poly).
#[inline(always)]
pub(crate) unsafe fn scale_into<V: SimdLane>(dst: &mut [f32], a: &[f32], b: f32) {
    debug_assert_eq!(dst.len(), a.len());
    let n = dst.len();
    let l = V::LANES;
    let vb = V::splat(b);
    let dp = dst.as_mut_ptr();
    let ap = a.as_ptr();
    let mut i = 0usize;
    while i + l <= n {
        vb.mul(V::load(ap.add(i))).store(dp.add(i));
        i += l;
    }
    while i < n {
        dst[i] = b * a[i];
        i += 1;
    }
}

/// Pack f32 values into bf16 bit patterns (round-to-nearest-even, via
/// [`super::bf16_from_f32`]), unrolled by the backend's lane width.
///
/// The conversion is integer bit arithmetic, which the f32-only
/// [`SimdLane`] surface cannot express — so unlike the float kernels the
/// body carries no explicit vector ops. It still instantiates per
/// backend: the fixed `LANES`-wide inner loop inlines into the backend's
/// `#[target_feature]` wrapper, where LLVM is free to vectorize the
/// shift/add/compare sequence with that ISA's integer registers. Every
/// backend computes the identical per-element bits, so the packed bytes
/// never depend on the rung.
#[inline(always)]
pub(crate) unsafe fn bf16_pack<V: SimdLane>(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    let n = src.len();
    let l = V::LANES;
    let mut i = 0usize;
    while i + l <= n {
        for j in 0..l {
            *dst.get_unchecked_mut(i + j) = super::bf16_from_f32(*src.get_unchecked(i + j));
        }
        i += l;
    }
    while i < n {
        *dst.get_unchecked_mut(i) = super::bf16_from_f32(*src.get_unchecked(i));
        i += 1;
    }
}

/// Unpack bf16 bit patterns to f32 (exact widening shift), unrolled by
/// the backend's lane width; same instantiation story as [`bf16_pack`].
#[inline(always)]
pub(crate) unsafe fn bf16_unpack<V: SimdLane>(src: &[u16], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    let n = src.len();
    let l = V::LANES;
    let mut i = 0usize;
    while i + l <= n {
        for j in 0..l {
            *dst.get_unchecked_mut(i + j) = super::bf16_to_f32(*src.get_unchecked(i + j));
        }
        i += l;
    }
    while i < n {
        *dst.get_unchecked_mut(i) = super::bf16_to_f32(*src.get_unchecked(i));
        i += 1;
    }
}

/// Fused bf16 EMA sweep: `x[i] = rne(a·widen(x[i]) + b·y[i])` with the
/// accumulation in f32 and one RNE round-store per element — the
/// momentum update of the bf16 storage mode, reading and writing bf16
/// bits without materializing an f32 copy of `x`.
///
/// Like [`bf16_pack`], the body carries no explicit vector ops (the
/// widen/round halves are integer bit arithmetic the f32-only
/// [`SimdLane`] surface cannot express); the `LANES`-unrolled loop
/// inlines into each backend's `#[target_feature]` wrapper for
/// auto-vectorization. The f32 arithmetic is written as two rounded
/// multiplies and one rounded add — no fused contraction — so **every
/// rung produces identical bits**, a stronger contract than the f32
/// kernels (where lane width changes reduction trees).
#[inline(always)]
pub(crate) unsafe fn bf16_axpby_inplace<V: SimdLane>(x: &mut [u16], a: f32, y: &[f32], b: f32) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let l = V::LANES;
    let mut i = 0usize;
    while i + l <= n {
        for j in 0..l {
            let xv = super::bf16_to_f32(*x.get_unchecked(i + j));
            let r = a * xv + b * *y.get_unchecked(i + j);
            *x.get_unchecked_mut(i + j) = super::bf16_from_f32(r);
        }
        i += l;
    }
    while i < n {
        let xv = super::bf16_to_f32(*x.get_unchecked(i));
        let r = a * xv + b * *y.get_unchecked(i);
        *x.get_unchecked_mut(i) = super::bf16_from_f32(r);
        i += 1;
    }
}

/// Fused bf16/bf16 sweep: `x[i] = rne(a·widen(x[i]) + b·widen(y[i]))` —
/// the weight update of the bf16 storage mode, where both the weights
/// and the momentum live as bf16 bits. Same instantiation and
/// rung-invariance story as [`bf16_axpby_inplace`].
#[inline(always)]
pub(crate) unsafe fn bf16_axpby_from_bf16<V: SimdLane>(x: &mut [u16], a: f32, y: &[u16], b: f32) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let l = V::LANES;
    let mut i = 0usize;
    while i + l <= n {
        for j in 0..l {
            let xv = super::bf16_to_f32(*x.get_unchecked(i + j));
            let yv = super::bf16_to_f32(*y.get_unchecked(i + j));
            *x.get_unchecked_mut(i + j) = super::bf16_from_f32(a * xv + b * yv);
        }
        i += l;
    }
    while i < n {
        let xv = super::bf16_to_f32(*x.get_unchecked(i));
        let yv = super::bf16_to_f32(*y.get_unchecked(i));
        *x.get_unchecked_mut(i) = super::bf16_from_f32(a * xv + b * yv);
        i += 1;
    }
}

/// Sum of squares of a bf16 row, widened to f32 and accumulated in f32
/// across a **fixed** bank of 8 independent accumulators (stride-8
/// assignment, folded pairwise at the end) — the row-norm reduction of
/// the bf16 RMNP step.
///
/// The accumulator structure is pinned independent of `V::LANES`, so the
/// reduction order — and therefore the result bits — are identical on
/// every rung; the generic parameter only instantiates the loop inside
/// each backend's `#[target_feature]` wrapper, where LLVM can lift the
/// stride-8 banks into vector registers. Eight banks also break the
/// add-latency chain a serial scalar reduction would serialize on.
#[inline(always)]
pub(crate) unsafe fn bf16_row_sumsq<V: SimdLane>(x: &[u16]) -> f32 {
    let n = x.len();
    let mut acc = [0.0f32; 8];
    let mut i = 0usize;
    while i + 8 <= n {
        for (j, a) in acc.iter_mut().enumerate() {
            let v = super::bf16_to_f32(*x.get_unchecked(i + j));
            *a += v * v;
        }
        i += 8;
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    while i < n {
        let v = super::bf16_to_f32(*x.get_unchecked(i));
        s += v * v;
        i += 1;
    }
    s
}

/// Fused row normalization: `dst[i,:] = src[i,:] / max(‖src[i,:]‖₂, eps)`.
#[inline(always)]
pub(crate) unsafe fn row_normalize_rows<V: SimdLane>(
    dst: &mut [f32],
    src: &[f32],
    cols: usize,
    eps: f32,
) {
    if cols == 0 {
        return;
    }
    let l = V::LANES;
    let rows = dst.len() / cols;
    for i in 0..rows {
        let o = i * cols;
        let srow = &src[o..o + cols];
        let inv = 1.0 / dot::<V>(srow, srow).sqrt().max(eps);
        let vi = V::splat(inv);
        let sp = srow.as_ptr();
        let dp = dst.as_mut_ptr().add(o);
        let mut j = 0usize;
        while j + l <= cols {
            vi.mul(V::load(sp.add(j))).store(dp.add(j));
            j += l;
        }
        while j < cols {
            *dp.add(j) = srow[j] * inv;
            j += 1;
        }
    }
}

/// Row-wise softmax: `dst[i,:] = softmax(src[i,:])`. The max scan and the
/// final normalize pass are vectorized; the exp/sum sweep stays scalar
/// (there is no vector `exp`), accumulating the partition sum in f32 in
/// row order — so the vector and scalar rungs run the identical exp/sum
/// sequence. `-inf` entries (the causal attention mask) exponentiate to
/// exactly 0; each row must contain at least one finite entry.
#[inline(always)]
pub(crate) unsafe fn row_softmax_rows<V: SimdLane>(dst: &mut [f32], src: &[f32], cols: usize) {
    if cols == 0 {
        return;
    }
    let l = V::LANES;
    let rows = dst.len() / cols;
    for i in 0..rows {
        let o = i * cols;
        let srow = &src[o..o + cols];
        let sp = srow.as_ptr();
        let mut j = 0usize;
        let mut max = f32::NEG_INFINITY;
        if cols >= l {
            let mut vm = V::load(sp);
            j = l;
            while j + l <= cols {
                vm = vm.max(V::load(sp.add(j)));
                j += l;
            }
            max = vm.hmax();
        }
        while j < cols {
            if srow[j] > max {
                max = srow[j];
            }
            j += 1;
        }
        let drow = &mut dst[o..o + cols];
        let mut sum = 0.0f32;
        for (d, &s) in drow.iter_mut().zip(srow) {
            let e = (s - max).exp();
            *d = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        let vi = V::splat(inv);
        let dp = drow.as_mut_ptr();
        let mut j = 0usize;
        while j + l <= cols {
            vi.mul(V::load(dp.add(j))).store(dp.add(j));
            j += l;
        }
        while j < cols {
            *dp.add(j) *= inv;
            j += 1;
        }
    }
}

/// Row-wise softmax backward: given the forward probabilities `p` and an
/// upstream gradient `dp`, `dst[i,:] = p ⊙ (dp − Σ_k p_k·dp_k)` per row.
/// Masked entries (`p = 0`) get gradient exactly 0.
#[inline(always)]
pub(crate) unsafe fn row_softmax_grad_rows<V: SimdLane>(
    dst: &mut [f32],
    p: &[f32],
    dp: &[f32],
    cols: usize,
) {
    if cols == 0 {
        return;
    }
    let l = V::LANES;
    let rows = dst.len() / cols;
    for i in 0..rows {
        let o = i * cols;
        let prow = &p[o..o + cols];
        let dprow = &dp[o..o + cols];
        let c = dot::<V>(prow, dprow);
        let vc = V::splat(-c);
        let pp = prow.as_ptr();
        let dpp = dprow.as_ptr();
        let out = dst.as_mut_ptr().add(o);
        let mut j = 0usize;
        while j + l <= cols {
            let shifted = vc.add(V::load(dpp.add(j)));
            V::load(pp.add(j)).mul(shifted).store(out.add(j));
            j += l;
        }
        while j < cols {
            *out.add(j) = prow[j] * (dprow[j] - c);
            j += 1;
        }
    }
}

/// Fused RMSNorm: `dst[i,:] = gain ⊙ src[i,:] / sqrt(mean(src[i,:]²) + eps)`.
#[inline(always)]
pub(crate) unsafe fn rmsnorm_rows<V: SimdLane>(
    dst: &mut [f32],
    src: &[f32],
    gain: &[f32],
    cols: usize,
    eps: f32,
) {
    if cols == 0 {
        return;
    }
    let l = V::LANES;
    let rows = dst.len() / cols;
    let gp = gain.as_ptr();
    for i in 0..rows {
        let o = i * cols;
        let srow = &src[o..o + cols];
        let r = 1.0 / (dot::<V>(srow, srow) / cols as f32 + eps).sqrt();
        let vr = V::splat(r);
        let sp = srow.as_ptr();
        let dp = dst.as_mut_ptr().add(o);
        let mut j = 0usize;
        while j + l <= cols {
            let gx = V::load(gp.add(j)).mul(V::load(sp.add(j)));
            vr.mul(gx).store(dp.add(j));
            j += l;
        }
        while j < cols {
            *dp.add(j) = gain[j] * srow[j] * r;
            j += 1;
        }
    }
}

/// RMSNorm backward. With `r_i = 1/sqrt(mean(src[i,:]²) + eps)`:
/// `dx[i,:] = r·(g⊙dy) − src·(r³/cols)·Σ_j g_j·dy_ij·src_ij` and
/// `dgain += Σ_i dy[i,:] ⊙ src[i,:] · r_i` (the caller zeroes `dgain`;
/// rows accumulate sequentially so the result is order-deterministic).
#[inline(always)]
pub(crate) unsafe fn rmsnorm_grad_rows<V: SimdLane>(
    dx: &mut [f32],
    dgain: &mut [f32],
    dy: &[f32],
    src: &[f32],
    gain: &[f32],
    cols: usize,
    eps: f32,
) {
    if cols == 0 {
        return;
    }
    let l = V::LANES;
    let rows = dx.len() / cols;
    let gp = gain.as_ptr();
    let dgp = dgain.as_mut_ptr();
    for i in 0..rows {
        let o = i * cols;
        let srow = &src[o..o + cols];
        let dyrow = &dy[o..o + cols];
        let r = 1.0 / (dot::<V>(srow, srow) / cols as f32 + eps).sqrt();
        // c = Σ_j g_j · dy_j · src_j
        let sp = srow.as_ptr();
        let dyp = dyrow.as_ptr();
        let mut acc = V::zero();
        let mut j = 0usize;
        while j + l <= cols {
            let gy = V::load(gp.add(j)).mul(V::load(dyp.add(j)));
            acc = acc.fma(gy, V::load(sp.add(j)));
            j += l;
        }
        let mut c = acc.hsum();
        while j < cols {
            c += gain[j] * dyrow[j] * srow[j];
            j += 1;
        }
        let b = r * r * r * c / cols as f32;
        let vr = V::splat(r);
        let vnb = V::splat(-b);
        let dxp = dx.as_mut_ptr().add(o);
        let mut j = 0usize;
        while j + l <= cols {
            let gy = V::load(gp.add(j)).mul(V::load(dyp.add(j)));
            let t = vr.mul(gy);
            t.fma(vnb, V::load(sp.add(j))).store(dxp.add(j));
            let dg = V::load(dgp.add(j)).fma(V::load(dyp.add(j)).mul(V::load(sp.add(j))), vr);
            dg.store(dgp.add(j));
            j += l;
        }
        while j < cols {
            *dxp.add(j) = r * gain[j] * dyrow[j] - b * srow[j];
            *dgp.add(j) += dyrow[j] * srow[j] * r;
            j += 1;
        }
    }
}

/// One `R × NR` register tile of the packed matmul: `R` output rows
/// (`row0..row0+R` of the dst/a chunks) across the full column range,
/// with `NV = NR / LANES` accumulator registers per row.
///
/// A values come from the raw chunk (`ap`, strided `(row0+r)·k + p`
/// reads) when `USE_PA` is false, or sequentially from one packed
/// [`PackedA`] panel (`pa`, `p·MR + r` reads) when it is true — same
/// values, same arithmetic order, so the two modes produce identical
/// bits. The per-row operation sequence is also identical for every `R`,
/// so tile (`R = 4`) and remainder (`R = 1`) rows agree bitwise — row
/// partitioning across threads never changes results.
#[allow(clippy::too_many_arguments)] // a microkernel is its registers
#[inline(always)]
unsafe fn packed_tile<V: SimdLane, const R: usize, const NV: usize, const USE_PA: bool>(
    dp: *mut f32,
    row0: usize,
    ap: *const f32,
    pa: *const f32,
    pp: *const f32,
    k: usize,
    n: usize,
    alpha: f32,
    accumulate: bool,
) {
    let l = V::LANES;
    let full = n / NR;
    let tail = n - full * NR;
    for s in 0..full {
        let j0 = s * NR;
        let sp = pp.add(s * k * NR);
        let mut acc = [[V::zero(); NV]; R];
        if accumulate {
            for (r, row) in acc.iter_mut().enumerate() {
                for (v, reg) in row.iter_mut().enumerate() {
                    *reg = V::load(dp.add((row0 + r) * n + j0 + v * l));
                }
            }
        }
        for p in 0..k {
            let mut bv = [V::zero(); NV];
            for (v, reg) in bv.iter_mut().enumerate() {
                *reg = V::load(sp.add(p * NR + v * l));
            }
            for (r, row) in acc.iter_mut().enumerate() {
                let a = alpha
                    * if USE_PA {
                        *pa.add(p * MR + r)
                    } else {
                        *ap.add((row0 + r) * k + p)
                    };
                let av = V::splat(a);
                for (reg, b) in row.iter_mut().zip(bv) {
                    *reg = reg.fma(av, b);
                }
            }
        }
        for (r, row) in acc.iter().enumerate() {
            for (v, reg) in row.iter().enumerate() {
                reg.store(dp.add((row0 + r) * n + j0 + v * l));
            }
        }
    }
    if tail > 0 {
        // partial strip: stage through an NR-wide stack buffer so loads
        // and stores never touch memory past each row's end
        let j0 = full * NR;
        let sp = pp.add(full * k * NR);
        let mut tmp = [[0.0f32; NR]; R];
        if accumulate {
            for (r, row) in tmp.iter_mut().enumerate() {
                std::ptr::copy_nonoverlapping(
                    dp.add((row0 + r) * n + j0),
                    row.as_mut_ptr(),
                    tail,
                );
            }
        }
        let mut acc = [[V::zero(); NV]; R];
        for (r, row) in acc.iter_mut().enumerate() {
            for (v, reg) in row.iter_mut().enumerate() {
                *reg = V::load(tmp[r].as_ptr().add(v * l));
            }
        }
        for p in 0..k {
            let mut bv = [V::zero(); NV];
            for (v, reg) in bv.iter_mut().enumerate() {
                *reg = V::load(sp.add(p * NR + v * l));
            }
            for (r, row) in acc.iter_mut().enumerate() {
                let a = alpha
                    * if USE_PA {
                        *pa.add(p * MR + r)
                    } else {
                        *ap.add((row0 + r) * k + p)
                    };
                let av = V::splat(a);
                for (reg, b) in row.iter_mut().zip(bv) {
                    *reg = reg.fma(av, b);
                }
            }
        }
        for (r, row) in acc.iter().enumerate() {
            for (v, reg) in row.iter().enumerate() {
                reg.store(tmp[r].as_mut_ptr().add(v * l));
            }
            std::ptr::copy_nonoverlapping(tmp[r].as_ptr(), dp.add((row0 + r) * n + j0), tail);
        }
    }
}

/// `dst (mc×n) {=, +=} alpha · a (mc×k) · B` where `B` is packed in
/// [`PackedB`] layout and `pa` optionally holds the chunk's rows packed
/// in [`PackedA`] 4-row panels (`pa.is_empty()` selects the packed-B-only
/// path that reads `a` strided — bit-identical, see [`packed_tile`]).
/// `accumulate = false` overwrites `dst`; `true` adds onto the existing
/// contents (used by the fused NS5 polynomial). Accumulators live in
/// registers across the whole k loop, so dst traffic is one store per
/// element instead of one read-modify-write per (element, p) pair.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
pub(crate) unsafe fn matmul_packed_rows<V: SimdLane, const NV: usize>(
    dst: &mut [f32],
    a: &[f32],
    pa: &[f32],
    pb: &[f32],
    k: usize,
    n: usize,
    alpha: f32,
    accumulate: bool,
) {
    if n == 0 {
        return;
    }
    let mc = dst.len() / n;
    debug_assert_eq!(dst.len(), mc * n);
    debug_assert_eq!(a.len(), mc * k);
    debug_assert_eq!(NV * V::LANES, NR);
    debug_assert!(pb.len() >= PackedB::packed_len(k, n));
    let use_pa = !pa.is_empty();
    debug_assert!(!use_pa || pa.len() >= (mc / MR) * MR * k);
    let dp = dst.as_mut_ptr();
    let ap = a.as_ptr();
    let pp = pb.as_ptr();
    let mut i = 0usize;
    while i + MR <= mc {
        if use_pa {
            let panel = pa.as_ptr().add((i / MR) * MR * k);
            packed_tile::<V, MR, NV, true>(dp, i, ap, panel, pp, k, n, alpha, accumulate);
        } else {
            packed_tile::<V, MR, NV, false>(
                dp,
                i,
                ap,
                std::ptr::null(),
                pp,
                k,
                n,
                alpha,
                accumulate,
            );
        }
        i += MR;
    }
    while i < mc {
        packed_tile::<V, 1, NV, false>(
            dp,
            i,
            ap,
            std::ptr::null(),
            pp,
            k,
            n,
            alpha,
            accumulate,
        );
        i += 1;
    }
}

/// Fused NS5 polynomial rows: `dst = b·a_rows + c·(a_rows · A)` with `A`
/// (m×m) pre-packed as `pb` (and optionally as `pa` panels) — no m×m `A²`
/// intermediate is materialized.
#[inline(always)]
pub(crate) unsafe fn ns_poly_rows<V: SimdLane, const NV: usize>(
    dst: &mut [f32],
    a_rows: &[f32],
    pa: &[f32],
    pb: &[f32],
    m: usize,
    b: f32,
    c: f32,
) {
    scale_into::<V>(dst, a_rows, b);
    matmul_packed_rows::<V, NV>(dst, a_rows, pa, pb, m, m, c, true);
}

/// Gram rows `i0..i1` of `a·aᵀ` into `dst_chunk` (full rows, length `m`
/// each): 4-row tiles share each streamed `a_j` row across four fma
/// accumulators; remainder rows fall back to [`dot`].
#[inline(always)]
pub(crate) unsafe fn gram_rows<V: SimdLane>(
    dst_chunk: &mut [f32],
    a: &[f32],
    i0: usize,
    i1: usize,
    m: usize,
    k: usize,
) {
    let l = V::LANES;
    let mut i = i0;
    while i < i1 {
        if i + 4 <= i1 {
            let r0 = a.as_ptr().add(i * k);
            let r1 = a.as_ptr().add((i + 1) * k);
            let r2 = a.as_ptr().add((i + 2) * k);
            let r3 = a.as_ptr().add((i + 3) * k);
            let base = (i - i0) * m;
            for j in i..m {
                let rj = a.as_ptr().add(j * k);
                let mut acc0 = V::zero();
                let mut acc1 = V::zero();
                let mut acc2 = V::zero();
                let mut acc3 = V::zero();
                let mut p = 0usize;
                while p + l <= k {
                    let x = V::load(rj.add(p));
                    acc0 = acc0.fma(V::load(r0.add(p)), x);
                    acc1 = acc1.fma(V::load(r1.add(p)), x);
                    acc2 = acc2.fma(V::load(r2.add(p)), x);
                    acc3 = acc3.fma(V::load(r3.add(p)), x);
                    p += l;
                }
                let mut s0 = acc0.hsum();
                let mut s1 = acc1.hsum();
                let mut s2 = acc2.hsum();
                let mut s3 = acc3.hsum();
                while p < k {
                    let x = *rj.add(p);
                    s0 += *r0.add(p) * x;
                    s1 += *r1.add(p) * x;
                    s2 += *r2.add(p) * x;
                    s3 += *r3.add(p) * x;
                    p += 1;
                }
                dst_chunk[base + j] = s0;
                dst_chunk[base + m + j] = s1;
                dst_chunk[base + 2 * m + j] = s2;
                dst_chunk[base + 3 * m + j] = s3;
            }
            i += 4;
        } else {
            let ri = &a[i * k..(i + 1) * k];
            let base = (i - i0) * m;
            for j in i..m {
                dst_chunk[base + j] = dot::<V>(ri, &a[j * k..(j + 1) * k]);
            }
            i += 1;
        }
    }
}
