//! The matrix norms used throughout the paper's theory (Section 5.1):
//! Frobenius ‖·‖F, the mixed norm ‖·‖₁,₂ = Σᵢ‖row i‖₂, and
//! ‖·‖∞,₂ = maxᵢ‖row i‖₂, together with the trace inner product. These back
//! the property tests for Lemmas A.1/A.2 (`crate::optim::lemmas`).

use super::Matrix;

/// Frobenius norm ‖W‖F.
pub fn frobenius(w: &Matrix) -> f64 {
    w.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Mixed norm ‖W‖₁,₂ = Σᵢ ‖W_{i,:}‖₂.
pub fn one2_norm(w: &Matrix) -> f64 {
    (0..w.rows())
        .map(|i| {
            w.row(i)
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt()
        })
        .sum()
}

/// Norm ‖W‖∞,₂ = maxᵢ ‖W_{i,:}‖₂.
pub fn inf2_norm(w: &Matrix) -> f64 {
    (0..w.rows())
        .map(|i| {
            w.row(i)
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt()
        })
        .fold(0.0f64, f64::max)
}

/// Trace inner product ⟨Z, W⟩ = Tr(Zᵀ W) = Σᵢⱼ ZᵢⱼWᵢⱼ.
pub fn dual_pairing(z: &Matrix, w: &Matrix) -> f64 {
    assert_eq!((z.rows(), z.cols()), (w.rows(), w.cols()));
    z.data()
        .iter()
        .zip(w.data())
        .map(|(&a, &b)| (a as f64) * (b as f64))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn frobenius_known() {
        let w = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((frobenius(&w) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn norm_ordering() {
        // ‖W‖∞,₂ ≤ ‖W‖F ≤ ‖W‖₁,₂ ≤ √m ‖W‖F for any W.
        let mut rng = Rng::new(8);
        for _ in 0..20 {
            let m = 1 + rng.below(12) as usize;
            let n = 1 + rng.below(12) as usize;
            let w = Matrix::randn(m, n, 1.0, &mut rng);
            let f = frobenius(&w);
            let o = one2_norm(&w);
            let i = inf2_norm(&w);
            assert!(i <= f + 1e-6);
            assert!(f <= o + 1e-6);
            assert!(o <= (m as f64).sqrt() * f + 1e-6);
        }
    }

    #[test]
    fn pairing_duality_bound() {
        // |⟨A,B⟩| ≤ ‖A‖₁,₂ ‖B‖∞,₂ (Section 5.1).
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let m = 1 + rng.below(10) as usize;
            let n = 1 + rng.below(10) as usize;
            let a = Matrix::randn(m, n, 1.5, &mut rng);
            let b = Matrix::randn(m, n, 0.7, &mut rng);
            let lhs = dual_pairing(&a, &b).abs();
            let rhs = one2_norm(&a) * inf2_norm(&b);
            assert!(lhs <= rhs + 1e-6, "lhs {lhs} rhs {rhs}");
        }
    }
}
