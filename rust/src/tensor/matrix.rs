//! Dense row-major f32 matrix with exactly the operations the optimizer
//! references and analysis passes need.
//!
//! The hot operations (`matmul`, `gram`, `transpose`, `row_normalize`,
//! `axpby`) delegate to the register-tiled, multi-threaded kernels in
//! [`super::kernels`], and each has an allocation-free `_into(dst)` variant
//! for use with a [`super::Workspace`]. The seed's single-threaded scalar
//! implementations are kept as `*_naive` — they are the parity baseline
//! for the kernel tests and the "before" side of `benches/precond.rs`.

use crate::tensor::kernels;
use crate::util::Rng;

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Gaussian-initialized matrix with the given std.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// The row-major backing slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    /// The row-major backing slice, mutably.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the matrix, returning its backing buffer (used by
    /// [`super::Workspace`] to recycle storage).
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }
    /// Element setter.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy another matrix's contents into this one (shapes must match).
    pub fn copy_from(&mut self, src: &Matrix) {
        assert_eq!((self.rows, self.cols), (src.rows, src.cols), "copy_from shape");
        self.data.copy_from_slice(&src.data);
    }

    /// Transpose into a new matrix.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Transpose into a preallocated `cols × rows` matrix.
    pub fn transpose_into(&self, dst: &mut Matrix) {
        assert_eq!((dst.rows, dst.cols), (self.cols, self.rows), "transpose dst shape");
        kernels::transpose_into(&mut dst.data, &self.data, self.rows, self.cols);
    }

    /// Matmul `self (m×k) · other (k×n)` into a new matrix.
    ///
    /// Runs on the SIMD-dispatched, multi-threaded kernel layer; use
    /// [`Matrix::matmul_into`] with a [`super::Workspace`] buffer on hot
    /// paths to avoid the allocation.
    ///
    /// ```
    /// use rmnp::tensor::Matrix;
    /// let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
    /// assert_eq!(a.matmul(&Matrix::eye(2)), a);
    /// ```
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// Matmul into a preallocated `m × n` matrix (fully overwritten).
    pub fn matmul_into(&self, other: &Matrix, dst: &mut Matrix) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!((dst.rows, dst.cols), (self.rows, other.cols), "matmul dst shape");
        kernels::matmul_into(
            &mut dst.data,
            &self.data,
            &other.data,
            self.rows,
            self.cols,
            other.cols,
        );
    }

    /// The seed's cache-blocked scalar matmul, kept as the parity baseline
    /// and the "before" side of the kernel benchmarks.
    pub fn matmul_naive(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        const BK: usize = 64;
        for kk in (0..k).step_by(BK) {
            let kend = (kk + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for p in kk..kend {
                    let a = arow[p];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[p * n..(p + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        out
    }

    /// Gram matrix `self · selfᵀ` (m×m), the object whose diagonal
    /// dominance Section 3.2 of the paper measures.
    pub fn gram(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.rows);
        self.gram_into(&mut out);
        out
    }

    /// Gram matrix into a preallocated `m × m` matrix.
    pub fn gram_into(&self, dst: &mut Matrix) {
        assert_eq!((dst.rows, dst.cols), (self.rows, self.rows), "gram dst shape");
        kernels::gram_into(&mut dst.data, &self.data, self.rows, self.cols);
    }

    /// The seed's scalar Gram loop (parity baseline).
    pub fn gram_naive(&self) -> Matrix {
        let m = self.rows;
        let mut out = Matrix::zeros(m, m);
        for i in 0..m {
            let ri = self.row(i);
            for j in i..m {
                let rj = self.row(j);
                let dot: f32 = ri.iter().zip(rj).map(|(a, b)| a * b).sum();
                out.data[i * m + j] = dot;
                out.data[j * m + i] = dot;
            }
        }
        out
    }

    /// Elementwise: out = a*self + b*other.
    pub fn axpby(&self, a: f32, other: &Matrix, b: f32) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        self.axpby_into(a, other, b, &mut out);
        out
    }

    /// Elementwise `dst = a*self + b*other` into a preallocated matrix.
    pub fn axpby_into(&self, a: f32, other: &Matrix, b: f32, dst: &mut Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        assert_eq!((dst.rows, dst.cols), (self.rows, self.cols), "axpby dst shape");
        kernels::axpby_into(&mut dst.data, a, &self.data, b, &other.data);
    }

    /// Elementwise `self = a*self + b*other`, in place.
    pub fn axpby_inplace(&mut self, a: f32, other: &Matrix, b: f32) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        kernels::axpby_inplace(&mut self.data, a, &other.data, b);
    }

    /// In-place scale.
    pub fn scale_inplace(&mut self, a: f32) {
        for v in &mut self.data {
            *v *= a;
        }
    }

    /// Row-wise ℓ2 norms, `‖V_{i,:}‖₂` for each i.
    pub fn row_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| kernels::row_sumsq(self.row(i)).sqrt())
            .collect()
    }

    /// The RMNP preconditioned direction: row-wise ℓ2 normalization
    /// `RN(V)_{i,:} = V_{i,:} / max(‖V_{i,:}‖₂, eps)` (Algorithm 2, line 5).
    /// The `max(‖row‖, eps)` floor matches
    /// `python/compile/kernels/rownorm.py` — zero rows normalize to zero.
    pub fn row_normalize(&self, eps: f32) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        self.row_normalize_into(&mut out, eps);
        out
    }

    /// Row normalization into a preallocated same-shape matrix.
    pub fn row_normalize_into(&self, dst: &mut Matrix, eps: f32) {
        assert_eq!((dst.rows, dst.cols), (self.rows, self.cols), "rownorm dst shape");
        kernels::row_normalize_into(&mut dst.data, &self.data, self.rows, self.cols, eps);
    }

    /// The seed's clone-then-scale row normalization (parity baseline).
    pub fn row_normalize_naive(&self, eps: f32) -> Matrix {
        let mut out = self.clone();
        for i in 0..self.rows {
            let norm = self.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            let inv = 1.0 / norm.max(eps);
            for v in &mut out.data[i * self.cols..(i + 1) * self.cols] {
                *v *= inv;
            }
        }
        out
    }

    /// Maximum absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }
}

/// Storage precision for parameter and momentum matrices.
///
/// This is a *storage* contract only: every optimizer keeps its
/// accumulation discipline (f32 kernels, f64 scalar reductions where the
/// f32 mode already used them) in both modes — see the "Precision modes"
/// section of `docs/ARCHITECTURE.md`. Selected by the `perf.precision`
/// config key; threaded to [`crate::optim::OptState::new_with`] and the
/// native runtime at construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full f32 storage — the default, bit-compatible with every
    /// checkpoint and golden file that predates the bf16 mode.
    F32,
    /// bf16 (bfloat16) storage with f32 accumulation: parameters and
    /// momentum hold 2 bytes per element; every arithmetic step widens
    /// to f32, accumulates, and rounds once (RNE) on store.
    Bf16,
}

impl Precision {
    /// Parse a config/CLI value (`"f32"` or `"bf16"`).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" => Some(Precision::F32),
            "bf16" => Some(Precision::Bf16),
            _ => None,
        }
    }

    /// Canonical lowercase name (`"f32"` / `"bf16"`), the form the
    /// checkpoint precision stamp and config round-trip through.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        }
    }
}

/// Dense row-major matrix of bf16 bits (`u16` storage, f32 semantics).
///
/// The bf16 storage mode's owner type: parameters and momentum live as
/// raw bfloat16 bit patterns, and the fused kernels in
/// [`super::kernels`] (`bf16_axpby_inplace`, `bf16_row_sumsq`, …) read
/// and write these buffers directly — widening each element to f32 in
/// registers — so no f32 copy of the matrix is materialized on the hot
/// path. Conversions round to nearest-even via [`super::simd::bf16_pack`]
/// and widen exactly via [`super::simd::bf16_unpack`]; a round trip
/// `pack(unpack(bits))` is the identity, which is what makes same-mode
/// checkpoint resume byte-exact.
#[derive(Clone, Debug, PartialEq)]
pub struct Bf16Matrix {
    rows: usize,
    cols: usize,
    data: Vec<u16>,
}

impl Bf16Matrix {
    /// Zero-filled matrix (bf16 zero is the all-zero bit pattern).
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Bf16Matrix { rows, cols, data: vec![0u16; rows * cols] }
    }

    /// Round an f32 matrix to bf16 storage (RNE per element).
    pub fn from_matrix(src: &Matrix) -> Self {
        let mut out = Bf16Matrix::zeros(src.rows(), src.cols());
        crate::tensor::simd::bf16_pack(src.data(), &mut out.data);
        out
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// The row-major bf16 bit buffer.
    pub fn bits(&self) -> &[u16] {
        &self.data
    }
    /// The row-major bf16 bit buffer, mutably.
    pub fn bits_mut(&mut self) -> &mut [u16] {
        &mut self.data
    }

    /// Borrow row `i`'s bits as a slice.
    pub fn row(&self, i: usize) -> &[u16] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    /// Borrow row `i`'s bits mutably.
    pub fn row_mut(&mut self, i: usize) -> &mut [u16] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Widen into a preallocated same-shape f32 matrix (exact — every
    /// bf16 value is representable in f32).
    pub fn widen_into(&self, dst: &mut Matrix) {
        assert_eq!((dst.rows(), dst.cols()), (self.rows, self.cols), "widen dst shape");
        crate::tensor::simd::bf16_unpack(&self.data, dst.data_mut());
    }

    /// Widen into a new f32 matrix.
    pub fn to_matrix(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        self.widen_into(&mut out);
        out
    }

    /// Round an f32 matrix's contents into this one (shapes must match).
    pub fn pack_from(&mut self, src: &Matrix) {
        assert_eq!((self.rows, self.cols), (src.rows(), src.cols()), "pack_from shape");
        crate::tensor::simd::bf16_pack(src.data(), &mut self.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(7, 5, 1.0, &mut rng);
        let c = a.matmul(&Matrix::eye(5));
        for (x, y) in a.data().iter().zip(c.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(33, 65, 1.0, &mut rng);
        let b = Matrix::randn(65, 17, 1.0, &mut rng);
        let c = a.matmul(&b);
        // naive triple loop
        for i in 0..33 {
            for j in 0..17 {
                let mut s = 0.0f32;
                for k in 0..65 {
                    s += a.get(i, k) * b.get(k, j);
                }
                assert!((s - c.get(i, j)).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn matmul_scalar_rung_bitwise_matches_seed_path() {
        // the portable scalar rung keeps the seed kernel's per-element
        // accumulation order => identical bits; the dispatched path (which
        // may take AVX2/FMA) stays within f32-rounding distance
        let mut rng = Rng::new(21);
        let a = Matrix::randn(19, 70, 1.0, &mut rng);
        let b = Matrix::randn(70, 23, 1.0, &mut rng);
        let naive = a.matmul_naive(&b);
        let mut scalar = Matrix::zeros(19, 23);
        kernels::matmul_into_scalar(
            scalar.data_mut(),
            a.data(),
            b.data(),
            19,
            70,
            23,
        );
        assert_eq!(scalar, naive);
        let fast = a.matmul(&b);
        for (x, y) in fast.data().iter().zip(naive.data()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(4, 9, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(6, 11, 1.0, &mut rng);
        let g1 = a.gram();
        let g2 = a.matmul(&a.transpose());
        for (x, y) in g1.data().iter().zip(g2.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gram_matches_naive_baseline() {
        let mut rng = Rng::new(14);
        for (m, k) in [(1, 4), (6, 11), (17, 33), (32, 8)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let fast = a.gram();
            let naive = a.gram_naive();
            for (x, y) in fast.data().iter().zip(naive.data()) {
                assert!((x - y).abs() < 1e-4, "({m},{k}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn row_normalize_unit_rows() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(8, 16, 2.0, &mut rng);
        let d = a.row_normalize(1e-12);
        for n in d.row_norms() {
            assert!((n - 1.0).abs() < 1e-5, "row norm {n}");
        }
    }

    #[test]
    fn row_normalize_zero_row_safe() {
        let a = Matrix::zeros(3, 4);
        let d = a.row_normalize(1e-8);
        assert!(d.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn row_normalize_matches_python_oracle() {
        // hard-coded values from python/compile/kernels/ref.py::rownorm_ref
        // (numpy f32, eps = 1e-7, max(norm, eps) floor)
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let want = [0.267261, 0.534522, 0.801784, 0.455842, 0.569803, 0.683763];
        for (got, want) in a.row_normalize(1e-7).data().iter().zip(want) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
        // zero rows stay zero under the max(norm, eps) semantics
        let b = Matrix::from_vec(
            3,
            4,
            vec![0.5, -1.5, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0],
        );
        let want = [
            0.196116, -0.588348, 0.784465, 0.0, 0.0, 0.0, 0.0, 0.0, 0.6, 0.8, 0.0,
            0.0,
        ];
        for (got, want) in b.row_normalize(1e-7).data().iter().zip(want) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn row_normalize_matches_naive_baseline() {
        let mut rng = Rng::new(15);
        for (m, n) in [(1, 1), (8, 16), (16, 8), (5, 33)] {
            let a = Matrix::randn(m, n, 2.0, &mut rng);
            let fast = a.row_normalize(1e-7);
            let naive = a.row_normalize_naive(1e-7);
            for (x, y) in fast.data().iter().zip(naive.data()) {
                assert!((x - y).abs() < 1e-6, "({m},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn axpby_linear() {
        let a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![10.0, 10.0, 10.0]);
        let c = a.axpby(2.0, &b, 0.5);
        assert_eq!(c.data(), &[7.0, 9.0, 11.0]);
    }

    #[test]
    fn precision_parse_round_trips() {
        for p in [Precision::F32, Precision::Bf16] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("f16"), None);
        assert_eq!(Precision::parse("BF16"), None, "names are lowercase");
    }

    #[test]
    fn bf16_matrix_round_trip_is_identity_on_bf16_values() {
        // pack → widen → pack must be the identity: widening is exact, so
        // re-rounding an already-bf16 value changes nothing. This is the
        // property behind byte-exact same-mode checkpoint resume.
        let mut rng = Rng::new(40);
        let a = Matrix::randn(9, 21, 1.5, &mut rng);
        let b = Bf16Matrix::from_matrix(&a);
        let widened = b.to_matrix();
        let repacked = Bf16Matrix::from_matrix(&widened);
        assert_eq!(b, repacked);
        // and the rounding error of the single pack is within bf16 eps
        for (x, y) in a.data().iter().zip(widened.data()) {
            assert!((x - y).abs() <= 0.00393 * x.abs() + 1e-30, "{x} vs {y}");
        }
    }

    #[test]
    fn bf16_matrix_rows_and_pack_from() {
        let mut rng = Rng::new(41);
        let a = Matrix::randn(4, 7, 1.0, &mut rng);
        let mut b = Bf16Matrix::zeros(4, 7);
        b.pack_from(&a);
        assert_eq!(b, Bf16Matrix::from_matrix(&a));
        for i in 0..4 {
            assert_eq!(b.row(i), &b.bits()[i * 7..(i + 1) * 7]);
        }
        b.row_mut(2).fill(0);
        assert!(b.to_matrix().row(2).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn into_variants_match_allocating_ones() {
        let mut rng = Rng::new(16);
        let a = Matrix::randn(9, 13, 1.0, &mut rng);
        let b = Matrix::randn(13, 7, 1.0, &mut rng);
        let mut dst = Matrix::zeros(9, 7);
        a.matmul_into(&b, &mut dst);
        assert_eq!(dst, a.matmul(&b));
        let mut g = Matrix::zeros(9, 9);
        a.gram_into(&mut g);
        assert_eq!(g, a.gram());
        let mut t = Matrix::zeros(13, 9);
        a.transpose_into(&mut t);
        assert_eq!(t, a.transpose());
        let a2 = Matrix::randn(9, 13, 1.0, &mut rng);
        let mut s = Matrix::zeros(9, 13);
        a.axpby_into(1.5, &a2, -0.5, &mut s);
        assert_eq!(s, a.axpby(1.5, &a2, -0.5));
        let mut ip = a.clone();
        ip.axpby_inplace(1.5, &a2, -0.5);
        assert_eq!(ip, s);
        let mut rn = Matrix::zeros(9, 13);
        a.row_normalize_into(&mut rn, 1e-7);
        assert_eq!(rn, a.row_normalize(1e-7));
    }
}
