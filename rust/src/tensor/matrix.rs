//! Dense row-major f32 matrix with exactly the operations the optimizer
//! references and analysis passes need. Matmul is cache-blocked; everything
//! else is straightforward slice arithmetic.

use crate::util::Rng;

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Gaussian-initialized matrix with the given std.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transpose into a new matrix.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Cache-blocked matmul: `self (m×k) · other (k×n)`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        const BK: usize = 64;
        for kk in (0..k).step_by(BK) {
            let kend = (kk + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for p in kk..kend {
                    let a = arow[p];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[p * n..(p + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        out
    }

    /// Gram matrix `self · selfᵀ` (m×m), the object whose diagonal
    /// dominance Section 3.2 of the paper measures.
    pub fn gram(&self) -> Matrix {
        let m = self.rows;
        let mut out = Matrix::zeros(m, m);
        for i in 0..m {
            let ri = self.row(i);
            for j in i..m {
                let rj = self.row(j);
                let dot: f32 = ri.iter().zip(rj).map(|(a, b)| a * b).sum();
                out.data[i * m + j] = dot;
                out.data[j * m + i] = dot;
            }
        }
        out
    }

    /// Elementwise: out = a*self + b*other.
    pub fn axpby(&self, a: f32, other: &Matrix, b: f32) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(x, y)| a * x + b * y)
            .collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// In-place scale.
    pub fn scale_inplace(&mut self, a: f32) {
        for v in &mut self.data {
            *v *= a;
        }
    }

    /// Row-wise ℓ2 norms, `‖V_{i,:}‖₂` for each i.
    pub fn row_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|x| x * x).sum::<f32>().sqrt())
            .collect()
    }

    /// The RMNP preconditioned direction: row-wise ℓ2 normalization
    /// `RN(V)_{i,:} = V_{i,:} / max(‖V_{i,:}‖₂, eps)` (Algorithm 2, line 5).
    pub fn row_normalize(&self, eps: f32) -> Matrix {
        let mut out = self.clone();
        for i in 0..self.rows {
            let norm = self.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            let inv = 1.0 / norm.max(eps);
            for v in &mut out.data[i * self.cols..(i + 1) * self.cols] {
                *v *= inv;
            }
        }
        out
    }

    /// Maximum absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(7, 5, 1.0, &mut rng);
        let c = a.matmul(&Matrix::eye(5));
        for (x, y) in a.data().iter().zip(c.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(33, 65, 1.0, &mut rng);
        let b = Matrix::randn(65, 17, 1.0, &mut rng);
        let c = a.matmul(&b);
        // naive triple loop
        for i in 0..33 {
            for j in 0..17 {
                let mut s = 0.0f32;
                for k in 0..65 {
                    s += a.get(i, k) * b.get(k, j);
                }
                assert!((s - c.get(i, j)).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(4, 9, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(6, 11, 1.0, &mut rng);
        let g1 = a.gram();
        let g2 = a.matmul(&a.transpose());
        for (x, y) in g1.data().iter().zip(g2.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn row_normalize_unit_rows() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(8, 16, 2.0, &mut rng);
        let d = a.row_normalize(1e-12);
        for n in d.row_norms() {
            assert!((n - 1.0).abs() < 1e-5, "row norm {n}");
        }
    }

    #[test]
    fn row_normalize_zero_row_safe() {
        let a = Matrix::zeros(3, 4);
        let d = a.row_normalize(1e-8);
        assert!(d.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn axpby_linear() {
        let a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![10.0, 10.0, 10.0]);
        let c = a.axpby(2.0, &b, 0.5);
        assert_eq!(c.data(), &[7.0, 9.0, 11.0]);
    }
}
