//! Explicit SIMD microkernels + runtime dispatch for the tensor layer.
//!
//! PR 1's kernels leaned on LLVM autovectorizing 4×row scalar tiles; this
//! module adds hand-written AVX2/FMA f32x8 paths for the hot operations
//! (`dot`, packed-B matmul, Gram, `axpby`, the fused row-normalize sweep,
//! and the NS5 polynomial accumulate) and a one-time dispatch ladder:
//!
//! 1. `perf.simd` config key / [`set_mode`] — explicit `"avx2"` or
//!    `"scalar"` override (the CLI prints the chosen rung at startup);
//! 2. the `RMNP_SIMD` environment variable (same values) — this is how
//!    CI's forced-scalar job keeps the portable path green;
//! 3. `is_x86_feature_detected!("avx2") && ("fma")`, evaluated once per
//!    process and cached.
//!
//! Forcing `"avx2"` on hardware without it quietly lands on the scalar
//! rung — [`active`] never returns a path the CPU cannot execute. On
//! non-x86 targets the ladder collapses to scalar at compile time; a NEON
//! rung is a ROADMAP follow-on.
//!
//! Numerics: the AVX2 paths use FMA and 8-lane folds, so results differ
//! from the scalar tiles by normal f32 rounding (reassociation + fused
//! rounding). The parity tests in `tests/kernels_parity.rs` hold the
//! SIMD, scalar, and naive paths within 1e-4 of each other. Within one
//! path, results are bit-deterministic: the 4-row tile and the remainder
//! row kernels perform the identical per-row operation sequence, so row
//! partitioning (thread count) never changes output bits.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Requested dispatch mode (`perf.simd` / `RMNP_SIMD`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Detect at startup (the default).
    Auto,
    /// Force the AVX2/FMA path (falls back to scalar if unsupported).
    Avx2,
    /// Force the portable scalar tiles.
    Scalar,
}

impl SimdMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "auto" => SimdMode::Auto,
            "avx2" => SimdMode::Avx2,
            "scalar" => SimdMode::Scalar,
            other => anyhow::bail!(
                "unknown simd mode `{other}` (expected auto|avx2|scalar)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Avx2 => "avx2",
            SimdMode::Scalar => "scalar",
        }
    }
}

/// The resolved execution path — what the kernels actually run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdPath {
    Avx2,
    Scalar,
}

static MODE: AtomicU8 = AtomicU8::new(0); // 0 = auto, 1 = avx2, 2 = scalar

/// Set the dispatch mode (wired to the `perf.simd` config key and the
/// CLI). `Auto` restores env-var/detection resolution.
pub fn set_mode(mode: SimdMode) {
    let v = match mode {
        SimdMode::Auto => 0,
        SimdMode::Avx2 => 1,
        SimdMode::Scalar => 2,
    };
    MODE.store(v, Ordering::Relaxed);
}

/// The currently requested mode (not the resolved path; see [`active`]).
pub fn mode() -> SimdMode {
    match MODE.load(Ordering::Relaxed) {
        1 => SimdMode::Avx2,
        2 => SimdMode::Scalar,
        _ => SimdMode::Auto,
    }
}

/// `RMNP_SIMD` env override, parsed once (invalid values mean `Auto`).
fn env_mode() -> SimdMode {
    static ENV: OnceLock<SimdMode> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("RMNP_SIMD")
            .ok()
            .and_then(|s| SimdMode::parse(&s).ok())
            .unwrap_or(SimdMode::Auto)
    })
}

/// Whether this CPU can run the AVX2/FMA kernels (detected once).
pub fn avx2_available() -> bool {
    static DET: OnceLock<bool> = OnceLock::new();
    *DET.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// Resolve the dispatch ladder to the path the kernels will take.
pub fn active() -> SimdPath {
    let requested = match mode() {
        SimdMode::Auto => env_mode(),
        explicit => explicit,
    };
    match requested {
        SimdMode::Scalar => SimdPath::Scalar,
        SimdMode::Avx2 | SimdMode::Auto => {
            if avx2_available() {
                SimdPath::Avx2
            } else {
                SimdPath::Scalar
            }
        }
    }
}

/// Human-readable label of the active path (printed at CLI startup and
/// recorded in the bench JSON envelopes).
pub fn label() -> &'static str {
    match active() {
        SimdPath::Avx2 => "avx2+fma (f32x8)",
        SimdPath::Scalar => "scalar (autovec tiles)",
    }
}

/// The AVX2/FMA kernel bodies. Every function is `unsafe` because it must
/// only run on CPUs where [`avx2_available`] is true — the dispatch sites
/// in [`super::kernels`] guarantee that via [`active`].
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    use core::arch::x86_64::*;

    /// Packed-B strip width: 16 columns = two f32x8 accumulators per row.
    pub const NR: usize = 16;

    /// Horizontal sum of one f32x8.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// 4×f32x8 dot product (32 elements per unrolled step).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(xp.add(i)),
                _mm256_loadu_ps(yp.add(i)),
                acc0,
            );
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(xp.add(i + 8)),
                _mm256_loadu_ps(yp.add(i + 8)),
                acc1,
            );
            acc2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(xp.add(i + 16)),
                _mm256_loadu_ps(yp.add(i + 16)),
                acc2,
            );
            acc3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(xp.add(i + 24)),
                _mm256_loadu_ps(yp.add(i + 24)),
                acc3,
            );
            i += 32;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(xp.add(i)),
                _mm256_loadu_ps(yp.add(i)),
                acc0,
            );
            i += 8;
        }
        let acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
        let mut s = hsum(acc);
        while i < n {
            s += x[i] * y[i];
            i += 1;
        }
        s
    }

    /// `dst = a·x + b·y` elementwise.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpby(dst: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
        debug_assert_eq!(dst.len(), x.len());
        debug_assert_eq!(x.len(), y.len());
        let n = dst.len();
        let va = _mm256_set1_ps(a);
        let vb = _mm256_set1_ps(b);
        let dp = dst.as_mut_ptr();
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let ax = _mm256_mul_ps(va, _mm256_loadu_ps(xp.add(i)));
            let v = _mm256_fmadd_ps(vb, _mm256_loadu_ps(yp.add(i)), ax);
            _mm256_storeu_ps(dp.add(i), v);
            i += 8;
        }
        while i < n {
            dst[i] = a * x[i] + b * y[i];
            i += 1;
        }
    }

    /// `x = a·x + b·y` elementwise, in place.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpby_inplace(x: &mut [f32], a: f32, y: &[f32], b: f32) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let va = _mm256_set1_ps(a);
        let vb = _mm256_set1_ps(b);
        let xp = x.as_mut_ptr();
        let yp = y.as_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let ax = _mm256_mul_ps(va, _mm256_loadu_ps(xp.add(i)));
            let v = _mm256_fmadd_ps(vb, _mm256_loadu_ps(yp.add(i)), ax);
            _mm256_storeu_ps(xp.add(i), v);
            i += 8;
        }
        while i < n {
            x[i] = a * x[i] + b * y[i];
            i += 1;
        }
    }

    /// `dst = b · a` elementwise (the init pass of the fused NS5 poly).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale_into(dst: &mut [f32], a: &[f32], b: f32) {
        debug_assert_eq!(dst.len(), a.len());
        let n = dst.len();
        let vb = _mm256_set1_ps(b);
        let dp = dst.as_mut_ptr();
        let ap = a.as_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(dp.add(i), _mm256_mul_ps(vb, _mm256_loadu_ps(ap.add(i))));
            i += 8;
        }
        while i < n {
            dst[i] = b * a[i];
            i += 1;
        }
    }

    /// Fused row normalization: `dst[i,:] = src[i,:] / max(‖src[i,:]‖₂, eps)`.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn row_normalize_rows(dst: &mut [f32], src: &[f32], cols: usize, eps: f32) {
        if cols == 0 {
            return;
        }
        let rows = dst.len() / cols;
        for i in 0..rows {
            let o = i * cols;
            let srow = &src[o..o + cols];
            let inv = 1.0 / dot(srow, srow).sqrt().max(eps);
            let vi = _mm256_set1_ps(inv);
            let sp = srow.as_ptr();
            let dp = dst.as_mut_ptr().add(o);
            let mut j = 0usize;
            while j + 8 <= cols {
                _mm256_storeu_ps(dp.add(j), _mm256_mul_ps(vi, _mm256_loadu_ps(sp.add(j))));
                j += 8;
            }
            while j < cols {
                *dp.add(j) = srow[j] * inv;
                j += 1;
            }
        }
    }

    /// One MR×NR register tile of the packed-B matmul: `R` output rows
    /// (`row0..row0+R` of the dst/a chunks) across the full column range.
    ///
    /// The per-row operation sequence is identical for every `R`, so tile
    /// (`R = 4`) and remainder (`R = 1`) rows produce the same bits — row
    /// partitioning across threads never changes results.
    #[allow(clippy::too_many_arguments)] // a microkernel is its registers
    #[target_feature(enable = "avx2,fma")]
    unsafe fn packed_tile<const R: usize>(
        dp: *mut f32,
        row0: usize,
        ap: *const f32,
        pp: *const f32,
        k: usize,
        n: usize,
        alpha: f32,
        accumulate: bool,
    ) {
        let full = n / NR;
        let tail = n - full * NR;
        for s in 0..full {
            let j0 = s * NR;
            let sp = pp.add(s * k * NR);
            let mut acc = [[_mm256_setzero_ps(); 2]; R];
            if accumulate {
                for r in 0..R {
                    acc[r][0] = _mm256_loadu_ps(dp.add((row0 + r) * n + j0));
                    acc[r][1] = _mm256_loadu_ps(dp.add((row0 + r) * n + j0 + 8));
                }
            }
            for p in 0..k {
                let b0 = _mm256_loadu_ps(sp.add(p * NR));
                let b1 = _mm256_loadu_ps(sp.add(p * NR + 8));
                for r in 0..R {
                    let av = _mm256_set1_ps(alpha * *ap.add((row0 + r) * k + p));
                    acc[r][0] = _mm256_fmadd_ps(av, b0, acc[r][0]);
                    acc[r][1] = _mm256_fmadd_ps(av, b1, acc[r][1]);
                }
            }
            for r in 0..R {
                _mm256_storeu_ps(dp.add((row0 + r) * n + j0), acc[r][0]);
                _mm256_storeu_ps(dp.add((row0 + r) * n + j0 + 8), acc[r][1]);
            }
        }
        if tail > 0 {
            // partial strip: stage through a 16-wide stack buffer so loads
            // and stores never touch memory past each row's end
            let j0 = full * NR;
            let sp = pp.add(full * k * NR);
            let mut tmp = [[0.0f32; NR]; R];
            if accumulate {
                for r in 0..R {
                    std::ptr::copy_nonoverlapping(
                        dp.add((row0 + r) * n + j0),
                        tmp[r].as_mut_ptr(),
                        tail,
                    );
                }
            }
            let mut acc = [[_mm256_setzero_ps(); 2]; R];
            for r in 0..R {
                acc[r][0] = _mm256_loadu_ps(tmp[r].as_ptr());
                acc[r][1] = _mm256_loadu_ps(tmp[r].as_ptr().add(8));
            }
            for p in 0..k {
                let b0 = _mm256_loadu_ps(sp.add(p * NR));
                let b1 = _mm256_loadu_ps(sp.add(p * NR + 8));
                for r in 0..R {
                    let av = _mm256_set1_ps(alpha * *ap.add((row0 + r) * k + p));
                    acc[r][0] = _mm256_fmadd_ps(av, b0, acc[r][0]);
                    acc[r][1] = _mm256_fmadd_ps(av, b1, acc[r][1]);
                }
            }
            for r in 0..R {
                _mm256_storeu_ps(tmp[r].as_mut_ptr(), acc[r][0]);
                _mm256_storeu_ps(tmp[r].as_mut_ptr().add(8), acc[r][1]);
                std::ptr::copy_nonoverlapping(
                    tmp[r].as_ptr(),
                    dp.add((row0 + r) * n + j0),
                    tail,
                );
            }
        }
    }

    /// `dst (mc×n) {=, +=} alpha · a (mc×k) · B` where `B` is packed in
    /// [`crate::tensor::PackedB`] layout. `accumulate = false` overwrites
    /// `dst`; `true` adds onto the existing contents (used by the fused
    /// NS5 polynomial). The accumulators live in registers across the
    /// whole k loop, so dst traffic is one store per element instead of
    /// one read-modify-write per (element, p) pair.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn matmul_packed_rows(
        dst: &mut [f32],
        a: &[f32],
        packed: &[f32],
        k: usize,
        n: usize,
        alpha: f32,
        accumulate: bool,
    ) {
        if n == 0 {
            return;
        }
        let mc = dst.len() / n;
        debug_assert_eq!(dst.len(), mc * n);
        debug_assert_eq!(a.len(), mc * k);
        debug_assert!(packed.len() >= k * n.div_ceil(NR) * NR);
        let dp = dst.as_mut_ptr();
        let ap = a.as_ptr();
        let pp = packed.as_ptr();
        let mut i = 0usize;
        while i + 4 <= mc {
            packed_tile::<4>(dp, i, ap, pp, k, n, alpha, accumulate);
            i += 4;
        }
        while i < mc {
            packed_tile::<1>(dp, i, ap, pp, k, n, alpha, accumulate);
            i += 1;
        }
    }

    /// Fused NS5 polynomial rows: `dst = b·a_rows + c·(a_rows · A)` with
    /// `A` (m×m) pre-packed — no m×m `A²` intermediate is materialized.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn ns_poly_rows(
        dst: &mut [f32],
        a_rows: &[f32],
        packed: &[f32],
        m: usize,
        b: f32,
        c: f32,
    ) {
        scale_into(dst, a_rows, b);
        matmul_packed_rows(dst, a_rows, packed, m, m, c, true);
    }

    /// Gram rows `i0..i1` of `a·aᵀ` into `dst_chunk` (full rows, length
    /// `m` each): 4-row tiles share each streamed `a_j` row across four
    /// FMA accumulators; remainder rows fall back to [`dot`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gram_rows(
        dst_chunk: &mut [f32],
        a: &[f32],
        i0: usize,
        i1: usize,
        m: usize,
        k: usize,
    ) {
        let mut i = i0;
        while i < i1 {
            if i + 4 <= i1 {
                let r0 = a.as_ptr().add(i * k);
                let r1 = a.as_ptr().add((i + 1) * k);
                let r2 = a.as_ptr().add((i + 2) * k);
                let r3 = a.as_ptr().add((i + 3) * k);
                let base = (i - i0) * m;
                for j in i..m {
                    let rj = a.as_ptr().add(j * k);
                    let mut acc0 = _mm256_setzero_ps();
                    let mut acc1 = _mm256_setzero_ps();
                    let mut acc2 = _mm256_setzero_ps();
                    let mut acc3 = _mm256_setzero_ps();
                    let mut p = 0usize;
                    while p + 8 <= k {
                        let x = _mm256_loadu_ps(rj.add(p));
                        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(r0.add(p)), x, acc0);
                        acc1 = _mm256_fmadd_ps(_mm256_loadu_ps(r1.add(p)), x, acc1);
                        acc2 = _mm256_fmadd_ps(_mm256_loadu_ps(r2.add(p)), x, acc2);
                        acc3 = _mm256_fmadd_ps(_mm256_loadu_ps(r3.add(p)), x, acc3);
                        p += 8;
                    }
                    let mut s0 = hsum(acc0);
                    let mut s1 = hsum(acc1);
                    let mut s2 = hsum(acc2);
                    let mut s3 = hsum(acc3);
                    while p < k {
                        let x = *rj.add(p);
                        s0 += *r0.add(p) * x;
                        s1 += *r1.add(p) * x;
                        s2 += *r2.add(p) * x;
                        s3 += *r3.add(p) * x;
                        p += 1;
                    }
                    dst_chunk[base + j] = s0;
                    dst_chunk[base + m + j] = s1;
                    dst_chunk[base + 2 * m + j] = s2;
                    dst_chunk[base + 3 * m + j] = s3;
                }
                i += 4;
            } else {
                let ri = &a[i * k..(i + 1) * k];
                let base = (i - i0) * m;
                for j in i..m {
                    dst_chunk[base + j] = dot(ri, &a[j * k..(j + 1) * k]);
                }
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_and_names() {
        assert_eq!(SimdMode::parse("auto").unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse("avx2").unwrap(), SimdMode::Avx2);
        assert_eq!(SimdMode::parse("scalar").unwrap(), SimdMode::Scalar);
        assert!(SimdMode::parse("sse9").is_err());
        assert_eq!(SimdMode::Avx2.name(), "avx2");
    }

    #[test]
    fn active_is_consistent_with_availability() {
        // whatever the mode, the resolved path must be runnable
        if !avx2_available() {
            assert_eq!(active(), SimdPath::Scalar);
        }
        assert!(!label().is_empty());
    }

    #[cfg(target_arch = "x86_64")]
    mod avx2_kernels {
        use super::super::{avx2, avx2_available};
        use crate::util::Rng;

        fn randv(len: usize, rng: &mut Rng) -> Vec<f32> {
            let mut v = vec![0.0f32; len];
            rng.fill_normal(&mut v, 1.0);
            v
        }

        #[test]
        fn dot_matches_sequential() {
            if !avx2_available() {
                return;
            }
            let mut rng = Rng::new(1);
            for len in [0usize, 1, 7, 8, 9, 31, 32, 33, 64, 100, 257] {
                let x = randv(len, &mut rng);
                let y = randv(len, &mut rng);
                let seq: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
                let got = unsafe { avx2::dot(&x, &y) };
                assert!(
                    (got - seq).abs() < 1e-3 * (1.0 + seq.abs()),
                    "len {len}: {got} vs {seq}"
                );
            }
        }

        #[test]
        fn axpby_matches_scalar() {
            if !avx2_available() {
                return;
            }
            let mut rng = Rng::new(2);
            for len in [1usize, 5, 8, 9, 40, 100] {
                let x = randv(len, &mut rng);
                let y = randv(len, &mut rng);
                let mut dst = vec![0.0f32; len];
                unsafe { avx2::axpby(&mut dst, 1.5, &x, -0.5, &y) };
                for i in 0..len {
                    let want = 1.5 * x[i] - 0.5 * y[i];
                    assert!((dst[i] - want).abs() < 1e-5, "{i}");
                }
                let mut ip = x.clone();
                unsafe { avx2::axpby_inplace(&mut ip, 1.5, &y, -0.5) };
                for i in 0..len {
                    let want = 1.5 * x[i] - 0.5 * y[i];
                    assert!((ip[i] - want).abs() < 1e-5, "{i}");
                }
            }
        }

        #[test]
        fn packed_matmul_matches_naive_including_tails() {
            if !avx2_available() {
                return;
            }
            let mut rng = Rng::new(3);
            // shapes straddling the 16-col strip and 4-row tile boundaries
            for (m, k, n) in [
                (1usize, 1usize, 1usize),
                (4, 4, 16),
                (5, 7, 3),
                (4, 9, 17),
                (9, 16, 33),
                (33, 65, 19),
                (2, 128, 130),
            ] {
                let a = randv(m * k, &mut rng);
                let b = randv(k * n, &mut rng);
                let mut packed = crate::tensor::PackedB::new();
                packed.pack(&b, k, n);
                let mut got = vec![0.0f32; m * n];
                unsafe {
                    avx2::matmul_packed_rows(&mut got, &a, packed.data(), k, n, 1.0, false)
                };
                for i in 0..m {
                    for j in 0..n {
                        let mut want = 0.0f32;
                        for p in 0..k {
                            want += a[i * k + p] * b[p * n + j];
                        }
                        let x = got[i * n + j];
                        assert!(
                            (x - want).abs() < 1e-3 * (1.0 + want.abs()),
                            "({m},{k},{n}) at ({i},{j}): {x} vs {want}"
                        );
                    }
                }
            }
        }

        #[test]
        fn packed_matmul_accumulate_adds_scaled_product() {
            if !avx2_available() {
                return;
            }
            let mut rng = Rng::new(4);
            let (m, k, n) = (6usize, 10usize, 21usize);
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let init = randv(m * n, &mut rng);
            let mut packed = crate::tensor::PackedB::new();
            packed.pack(&b, k, n);
            let mut got = init.clone();
            unsafe {
                avx2::matmul_packed_rows(&mut got, &a, packed.data(), k, n, 0.5, true)
            };
            for i in 0..m {
                for j in 0..n {
                    let mut prod = 0.0f32;
                    for p in 0..k {
                        prod += a[i * k + p] * b[p * n + j];
                    }
                    let want = init[i * n + j] + 0.5 * prod;
                    let x = got[i * n + j];
                    assert!(
                        (x - want).abs() < 1e-3 * (1.0 + want.abs()),
                        "({i},{j}): {x} vs {want}"
                    );
                }
            }
        }

        #[test]
        fn tile_and_remainder_rows_agree_bitwise() {
            // the determinism contract: processing a row inside a 4-tile
            // or as a remainder row gives identical bits
            if !avx2_available() {
                return;
            }
            let mut rng = Rng::new(5);
            let (k, n) = (37usize, 29usize);
            let a = randv(5 * k, &mut rng); // 5 rows: one 4-tile + 1 remainder
            let b = randv(k * n, &mut rng);
            let mut packed = crate::tensor::PackedB::new();
            packed.pack(&b, k, n);
            let mut whole = vec![0.0f32; 5 * n];
            unsafe {
                avx2::matmul_packed_rows(&mut whole, &a, packed.data(), k, n, 1.0, false)
            };
            // row 4 alone (remainder path) must equal row 4 of the block
            let mut single = vec![0.0f32; n];
            unsafe {
                avx2::matmul_packed_rows(
                    &mut single,
                    &a[4 * k..5 * k],
                    packed.data(),
                    k,
                    n,
                    1.0,
                    false,
                )
            };
            assert_eq!(&whole[4 * n..5 * n], &single[..]);
            // and row 0 computed alone must equal row 0 of the 4-tile
            let mut first = vec![0.0f32; n];
            unsafe {
                avx2::matmul_packed_rows(
                    &mut first,
                    &a[0..k],
                    packed.data(),
                    k,
                    n,
                    1.0,
                    false,
                )
            };
            assert_eq!(&whole[0..n], &first[..]);
        }

        #[test]
        fn rownorm_unit_and_zero_rows() {
            if !avx2_available() {
                return;
            }
            let mut rng = Rng::new(6);
            let (rows, cols) = (5usize, 37usize);
            let mut src = randv(rows * cols, &mut rng);
            for v in &mut src[2 * cols..3 * cols] {
                *v = 0.0;
            }
            let mut dst = vec![0.0f32; rows * cols];
            unsafe { avx2::row_normalize_rows(&mut dst, &src, cols, 1e-7) };
            for i in 0..rows {
                let n: f32 = dst[i * cols..(i + 1) * cols]
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    .sqrt();
                if i == 2 {
                    assert_eq!(n, 0.0);
                } else {
                    assert!((n - 1.0).abs() < 1e-5, "row {i} norm {n}");
                }
            }
        }

        #[test]
        fn gram_rows_matches_naive() {
            if !avx2_available() {
                return;
            }
            let mut rng = Rng::new(7);
            for (m, k) in [(1usize, 5usize), (4, 8), (6, 11), (13, 64), (9, 7)] {
                let a = randv(m * k, &mut rng);
                let mut got = vec![0.0f32; m * m];
                unsafe { avx2::gram_rows(&mut got, &a, 0, m, m, k) };
                for i in 0..m {
                    for j in i..m {
                        let want: f32 = (0..k).map(|p| a[i * k + p] * a[j * k + p]).sum();
                        let x = got[i * m + j];
                        assert!(
                            (x - want).abs() < 1e-3 * (1.0 + want.abs()),
                            "({m},{k}) at ({i},{j})"
                        );
                    }
                }
            }
        }
    }
}
