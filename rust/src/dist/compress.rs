//! Gradient wire compression for the distributed path (`dist.compress`).
//!
//! Two modes, selected by the coordinator's config and announced to
//! workers in `RegisterAck` so both ends of every socket agree without
//! per-frame negotiation:
//!
//! * `none` — raw little-endian f32, byte-exact with what the backend
//!   produced (4 bytes/element);
//! * `bf16` — round-to-nearest-even truncation to bfloat16 (2
//!   bytes/element, the ≥2× payload cut), via the SIMD-layer
//!   [`crate::tensor::simd::bf16_pack`] ladder.
//!
//! **Determinism.** The codec is pure elementwise bit arithmetic — no
//! reductions — so encoded bytes are identical on every SIMD rung and
//! every worker count. Under `bf16` the *values* differ from the `none`
//! mode by one rounding step per element, but within a mode nothing is
//! host- or topology-dependent: the coordinator decodes each worker's
//! chunk to the same f32s those workers would re-send on a resend, and
//! the f64 reduction downstream consumes them in shard-index order. The
//! bit-exact-across-worker-counts contract therefore holds *per mode*
//! (the two modes produce different — both deterministic — runs).

use anyhow::{bail, Result};

use crate::tensor::simd;

/// Wire compression mode (`dist.compress` config key).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compression {
    /// Raw little-endian f32 (4 bytes/element) — the default.
    None,
    /// Round-to-nearest-even bfloat16 (2 bytes/element).
    Bf16,
}

impl Compression {
    /// Parse a `dist.compress` config value.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => Compression::None,
            "bf16" => Compression::Bf16,
            other => bail!("unknown dist.compress `{other}` (expected none|bf16)"),
        })
    }

    /// The config-file spelling of this mode.
    pub fn name(self) -> &'static str {
        match self {
            Compression::None => "none",
            Compression::Bf16 => "bf16",
        }
    }

    /// The stable one-byte codec id carried in every chunk frame.
    pub fn id(self) -> u8 {
        match self {
            Compression::None => 0,
            Compression::Bf16 => 1,
        }
    }

    /// Inverse of [`Compression::id`]; unknown ids are a protocol error.
    pub fn from_id(id: u8) -> Result<Self> {
        Ok(match id {
            0 => Compression::None,
            1 => Compression::Bf16,
            other => bail!("unknown gradient codec id {other}"),
        })
    }

    /// Encoded size of one f32 element in this mode.
    pub fn bytes_per_elem(self) -> usize {
        match self {
            Compression::None => 4,
            Compression::Bf16 => 2,
        }
    }
}

/// Reusable encoder/decoder for one gradient stream. Owns a staging
/// buffer for the bf16 half-words so the warm path never allocates
/// (chunk sizes repeat every step: one chunk per parameter).
pub struct GradCodec {
    mode: Compression,
    /// bf16 staging: packed halves on encode, aligned halves on decode.
    packed: Vec<u16>,
}

impl GradCodec {
    /// A codec for `mode` with empty (lazily grown) staging buffers.
    pub fn new(mode: Compression) -> Self {
        GradCodec { mode, packed: Vec::new() }
    }

    /// The mode this codec was built for.
    pub fn mode(&self) -> Compression {
        self.mode
    }

    /// Pre-grow the staging buffer for chunks up to `elems` elements, so
    /// even the first encode/decode of a run stays allocation-free.
    pub fn reserve(&mut self, elems: usize) {
        if self.mode == Compression::Bf16 && self.packed.len() < elems {
            self.packed.resize(elems, 0);
        }
    }

    /// Encode `src` into `out` (cleared first, capacity reused).
    pub fn encode_into(&mut self, src: &[f32], out: &mut Vec<u8>) {
        out.clear();
        match self.mode {
            Compression::None => {
                out.reserve(src.len() * 4);
                for &v in src {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Compression::Bf16 => {
                self.reserve(src.len());
                let halves = &mut self.packed[..src.len()];
                simd::bf16_pack(src, halves);
                out.reserve(src.len() * 2);
                for &h in halves.iter() {
                    out.extend_from_slice(&h.to_le_bytes());
                }
            }
        }
    }

    /// Decode exactly `elems` elements from `data`, appending the f32s
    /// to `out` (existing contents untouched — callers assemble a flat
    /// gradient chunk by chunk). Warm calls do not allocate once `out`
    /// has capacity and [`GradCodec::reserve`] has run.
    pub fn decode_append(&mut self, data: &[u8], elems: usize, out: &mut Vec<f32>) -> Result<()> {
        let want = elems * self.mode.bytes_per_elem();
        if data.len() != want {
            bail!(
                "gradient chunk payload is {} bytes, expected {want} ({elems} x {} elems)",
                data.len(),
                self.mode.name()
            );
        }
        let start = out.len();
        out.resize(start + elems, 0.0);
        match self.mode {
            Compression::None => {
                for (d, c) in out[start..].iter_mut().zip(data.chunks_exact(4)) {
                    *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
            }
            Compression::Bf16 => {
                // stage through the u16 buffer: `data` has no alignment
                // guarantee, and the SIMD unpack wants a typed slice
                self.reserve(elems);
                let halves = &mut self.packed[..elems];
                for (h, c) in halves.iter_mut().zip(data.chunks_exact(2)) {
                    *h = u16::from_le_bytes([c[0], c[1]]);
                }
                simd::bf16_unpack(halves, &mut out[start..]);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn mode_names_ids_and_sizes() {
        for mode in [Compression::None, Compression::Bf16] {
            assert_eq!(Compression::parse(mode.name()).unwrap(), mode);
            assert_eq!(Compression::from_id(mode.id()).unwrap(), mode);
        }
        assert!(Compression::parse("zstd").is_err());
        assert!(Compression::from_id(9).is_err());
        assert_eq!(Compression::None.bytes_per_elem(), 4);
        assert_eq!(Compression::Bf16.bytes_per_elem(), 2);
    }

    #[test]
    fn none_mode_round_trips_bit_exact() {
        let mut rng = Rng::new(3);
        let mut src = vec![0.0f32; 129];
        rng.fill_normal(&mut src, 5.0);
        src[0] = -0.0;
        src[7] = f32::MIN_POSITIVE / 2.0; // subnormal
        let mut codec = GradCodec::new(Compression::None);
        let mut wire = Vec::new();
        codec.encode_into(&src, &mut wire);
        assert_eq!(wire.len(), src.len() * 4);
        let mut back = vec![1.0f32; 3]; // decode must append, not clobber
        codec.decode_append(&wire, src.len(), &mut back).unwrap();
        assert_eq!(back.len(), 3 + src.len());
        for (a, b) in back[3..].iter().zip(&src) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bf16_mode_round_trips_representable_values() {
        // values whose mantissa fits in 7 bits survive exactly
        let src = [0.0f32, 1.0, -1.5, 0.15625, -100.0, 3.0e38];
        let mut codec = GradCodec::new(Compression::Bf16);
        let mut wire = Vec::new();
        codec.encode_into(&src, &mut wire);
        assert_eq!(wire.len(), src.len() * 2, ">=2x payload cut");
        let mut back = Vec::new();
        codec.decode_append(&wire, src.len(), &mut back).unwrap();
        for (a, b) in back.iter().zip(&src) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bf16_mode_rounds_to_nearest_even() {
        let mut codec = GradCodec::new(Compression::Bf16);
        let mut wire = Vec::new();
        // exact tie: 1.0 + 2^-8 → even neighbor 1.0
        codec.encode_into(&[f32::from_bits(0x3F80_8000)], &mut wire);
        assert_eq!(wire, [0x80, 0x3F]);
        // tie + sticky: must round up
        codec.encode_into(&[f32::from_bits(0x3F80_8001)], &mut wire);
        assert_eq!(wire, [0x81, 0x3F]);
    }

    #[test]
    fn decode_rejects_wrong_payload_size() {
        let mut codec = GradCodec::new(Compression::Bf16);
        let mut out = Vec::new();
        assert!(codec.decode_append(&[0u8; 5], 2, &mut out).is_err());
        assert!(out.is_empty(), "failed decode must not emit elements");
        let mut codec = GradCodec::new(Compression::None);
        assert!(codec.decode_append(&[0u8; 6], 2, &mut out).is_err());
    }

    #[test]
    fn warm_encode_reuses_buffers() {
        let mut rng = Rng::new(9);
        let mut src = vec![0.0f32; 64];
        rng.fill_normal(&mut src, 1.0);
        for mode in [Compression::None, Compression::Bf16] {
            let mut codec = GradCodec::new(mode);
            let mut wire = Vec::new();
            codec.encode_into(&src, &mut wire); // warmup sizes everything
            let cap = wire.capacity();
            for _ in 0..4 {
                codec.encode_into(&src, &mut wire);
                assert_eq!(wire.capacity(), cap, "{}: encode grew the buffer", mode.name());
                let mut back = Vec::with_capacity(src.len());
                codec.decode_append(&wire, src.len(), &mut back).unwrap();
                assert_eq!(back.len(), src.len());
            }
        }
    }
}
