//! The distributed worker: dial the coordinator, register, compute shard
//! gradients, apply broadcast updates.
//!
//! A worker owns a full [`NativeBackend`] replica. Everything that
//! defines the run — model tag, optimizer, seed, step range, and (on
//! resume) the checkpoint state — arrives in the `RegisterAck`, so every
//! rank is bit-identical by construction before the first step. The main
//! loop is strictly request/response on one read stream; heartbeats go
//! out on a side thread through a cloned write half so they never
//! interleave with a response the loop is waiting on.
//!
//! Gradients stream: each parameter leaves as a `ShardGradChunk` the
//! moment backward produces it (optionally bf16-compressed, per the
//! `compress` mode announced in the `RegisterAck`), and the broadcast
//! update arrives back as an `Apply` header plus an `ApplyChunk` stream
//! reassembled into one pre-sized flat buffer. All streaming buffers are
//! sized from the parameter layout at startup, so the warm step path
//! does not allocate.
//!
//! Failure behavior: any local error (guard-style protocol violation,
//! backend failure, send failure) is reported to the coordinator as a
//! best-effort `WorkerAbort{reason}` before the process exits nonzero —
//! a dying worker explains itself instead of silently becoming a missed
//! heartbeat. A closed or silent coordinator socket is a *clean* error
//! exit: the worker names the coordinator as the cause and does not
//! panic, so supervisors can restart the pair.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::DataSpec;
use crate::data::corpus::{token_source, TokenSource};
use crate::dist::compress::{Compression, GradCodec};
use crate::dist::wire::{self, Msg, RecvError};
use crate::dist::SHARD_SPLIT_BASE;
use crate::runtime::{Batch, BatchShape, NativeBackend, TrainBackend};
use crate::util::retry::with_retry;
use crate::{info, warnln};

/// Everything a worker needs to dial in; the run definition itself comes
/// back in the `RegisterAck`.
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// Coordinator address (`host:port`).
    pub connect: String,
    /// Unique worker identity; duplicates are refused by the coordinator.
    pub worker_id: String,
    /// `StepPlan` worker threads for the local backend (0 = kernel count).
    pub plan_threads: usize,
    /// Heartbeat period in ms.
    pub heartbeat_ms: u64,
    /// Exit after this many ms without a coordinator frame.
    pub worker_timeout_ms: u64,
    /// Bounded-backoff connect attempts before giving up.
    pub connect_attempts: usize,
    /// Run nonce read from the coordinator's addr file, when launched
    /// through one. The `RegisterAck` must echo it — a mismatch means the
    /// addr file is a stale leftover pointing at a different (re)run, and
    /// joining would silently train against the wrong trajectory.
    pub expect_nonce: Option<u64>,
}

/// What a worker did before the run ended.
#[derive(Clone, Copy, Debug)]
pub struct WorkerResult {
    /// The rank the coordinator assigned.
    pub rank: u32,
    /// Optimizer updates applied (skipped steps excluded).
    pub steps_applied: usize,
    /// Shard gradients computed and shipped.
    pub shards_done: usize,
}

/// One shard's deterministic token stream plus a one-batch cache.
///
/// `consumed` counts how many steps' batches this stream has produced;
/// a freshly adopted shard (after a redistribution or a resume) fast
/// forwards from 0, so the batch it yields for step `s` is identical to
/// what the shard's previous owner — or a never-interrupted run — would
/// have drawn. The cache makes a re-issued `StepBegin` for the same step
/// idempotent: the stream does not advance twice.
struct ShardFeed {
    src: Box<dyn TokenSource>,
    consumed: u64,
    cached_step: Option<u64>,
    buf: Vec<i32>,
}

impl ShardFeed {
    fn new(data: DataSpec, seed: u64, shard: u32, count: usize) -> ShardFeed {
        ShardFeed {
            src: token_source(data, seed, SHARD_SPLIT_BASE + u64::from(shard)),
            consumed: 0,
            cached_step: None,
            buf: vec![0; count],
        }
    }

    fn batch(&mut self, step: u64) -> anyhow::Result<&[i32]> {
        if self.cached_step != Some(step) {
            anyhow::ensure!(
                step >= self.consumed,
                "shard stream cannot rewind: step {step} but {} batches consumed",
                self.consumed
            );
            while self.consumed <= step {
                self.src.fill(&mut self.buf);
                self.consumed += 1;
            }
            self.cached_step = Some(step);
        }
        Ok(&self.buf)
    }
}

/// Dial the coordinator, register, and serve the step loop until a
/// `Shutdown` (clean) or an error (reported via `WorkerAbort` when the
/// socket still works).
pub fn run(opts: &WorkerOpts) -> anyhow::Result<WorkerResult> {
    anyhow::ensure!(!opts.connect.is_empty(), "worker needs a coordinator address");
    let stream = with_retry(
        &format!("connect to coordinator at {}", opts.connect),
        opts.connect_attempts.max(1),
        Duration::from_millis(50),
        || TcpStream::connect(&opts.connect).map_err(anyhow::Error::from),
    )?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_millis(opts.worker_timeout_ms.max(100))))?;
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let mut reader = stream;

    send(&writer, &Msg::Register { worker_id: opts.worker_id.clone() })?;
    let ack = loop {
        match wire::read_msg(&mut reader) {
            Ok(Msg::RegisterAck {
                rank,
                nonce,
                nshards,
                start_step,
                steps,
                seed,
                model,
                optimizer,
                data,
                compress,
                precision,
                state,
            }) => break (
                rank, nonce, nshards, start_step, steps, seed, model, optimizer, data,
                compress, precision, state,
            ),
            Ok(Msg::RegisterNack { reason }) => {
                anyhow::bail!("coordinator refused registration: {reason}")
            }
            Ok(other) => anyhow::bail!("wanted RegisterAck, got {}", other.name()),
            Err(RecvError::Corrupt { .. }) => {
                // the ack itself got mangled; the raced registration is
                // unrecoverable at this layer — bail and let the caller
                // (or supervisor) re-run the worker
                anyhow::bail!("registration ack failed its CRC — restart the worker")
            }
            Err(e) => anyhow::bail!("waiting for registration ack: {e}"),
        }
    };
    let (
        rank,
        nonce,
        nshards,
        start_step,
        steps,
        seed,
        model,
        optimizer,
        data,
        compress,
        precision,
        state,
    ) = ack;
    if let Some(want) = opts.expect_nonce {
        anyhow::ensure!(
            nonce == want,
            "coordinator answered with run nonce {nonce:#018x} but the addr file \
             promised {want:#018x} — the file is a stale leftover from another run; \
             re-read it (or delete it and restart the coordinator)"
        );
    }
    let mode = Compression::parse(&compress)?;
    let prec = crate::tensor::Precision::parse(&precision).ok_or_else(|| {
        anyhow::anyhow!("coordinator announced unknown precision `{precision}` (f32|bf16)")
    })?;
    let data = DataSpec::parse(&data)?;
    anyhow::ensure!(
        data != DataSpec::Images,
        "distributed training shards token corpora only (got images)"
    );
    info!(
        "worker `{}` registered: rank {rank}, {nshards} shards, steps \
         {start_step}..{steps}, model {model}, optimizer {optimizer}, \
         compress {}, precision {}",
        opts.worker_id,
        mode.name(),
        prec.name()
    );

    let mut backend =
        NativeBackend::new_with_precision(&model, &optimizer, seed, opts.plan_threads, prec)?;
    if let Some(st) = &state {
        backend.import_state(st)?;
    }
    let BatchShape::Tokens { rows, cols } = backend.batch_shape() else {
        anyhow::bail!("model `{model}` does not consume tokens");
    };
    let count = rows * cols;

    // one-way heartbeats on a side thread; the stop flag (not the socket)
    // ends it so a clean shutdown never races a half-written frame
    let stop = Arc::new(AtomicBool::new(false));
    let hb = {
        let writer = Arc::clone(&writer);
        let stop = Arc::clone(&stop);
        let period = Duration::from_millis(opts.heartbeat_ms.max(10));
        std::thread::spawn(move || {
            let mut last = Instant::now();
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(10));
                if last.elapsed() >= period {
                    let mut s = writer.lock().unwrap_or_else(|e| e.into_inner());
                    if wire::write_msg(&mut *s, &Msg::Heartbeat { rank }).is_err() {
                        return; // socket is gone; the main loop will notice
                    }
                    drop(s);
                    last = Instant::now();
                }
            }
        })
    };

    let result = step_loop(&mut reader, &writer, &mut backend, rank, data, seed, count, mode);
    if let Err(e) = &result {
        // a dying worker explains itself — the coordinator logs the reason
        // instead of waiting out a heartbeat deadline
        let _ = send(&writer, &Msg::WorkerAbort { rank, reason: e.to_string() });
    }
    stop.store(true, Ordering::Relaxed);
    let _ = hb.join();
    result
}

#[allow(clippy::too_many_arguments)]
fn step_loop(
    reader: &mut TcpStream,
    writer: &Mutex<TcpStream>,
    backend: &mut NativeBackend,
    rank: u32,
    data: DataSpec,
    seed: u64,
    count: usize,
    mode: Compression,
) -> anyhow::Result<WorkerResult> {
    let mut feeds: HashMap<u32, ShardFeed> = HashMap::new();
    let mut pending: Option<u64> = None;
    let mut last_applied: Option<u64> = None;
    let mut steps_applied = 0usize;
    let mut shards_done = 0usize;
    // pre-size every streaming buffer from the parameter layout so the
    // warm step path never allocates: one encode buffer the size of the
    // widest chunk, codec staging to match, and the reassembled downlink
    let layout = backend.chunk_elems();
    let total_chunks = layout.len() as u32;
    let max_elems = layout.iter().copied().max().unwrap_or(0);
    let flat_len: usize = layout.iter().sum();
    let mut codec = GradCodec::new(mode);
    codec.reserve(max_elems);
    let mut chunk_buf: Vec<u8> = Vec::with_capacity(max_elems * mode.bytes_per_elem());
    let mut flat: Vec<f32> = Vec::with_capacity(flat_len);
    loop {
        let msg = match wire::read_msg(reader) {
            Ok(m) => m,
            Err(RecvError::Corrupt { want, got }) => {
                // drop the frame, never deserialize it; the coordinator's
                // step timeout re-issues whatever this was
                warnln!(
                    "rank {rank}: dropping corrupt frame (crc {got:#010x}, wanted {want:#010x})"
                );
                continue;
            }
            Err(RecvError::Closed) => anyhow::bail!(
                "coordinator closed the connection — it crashed or was killed; \
                 restart it with --resume and re-launch workers"
            ),
            Err(RecvError::TimedOut) => anyhow::bail!(
                "coordinator silent past the worker timeout — exiting cleanly; \
                 restart the coordinator with --resume and re-launch workers"
            ),
            Err(RecvError::Other(e)) => anyhow::bail!("reading from coordinator: {e}"),
        };
        match msg {
            Msg::StepBegin { step, shards } => {
                if let Some(p) = pending {
                    anyhow::ensure!(
                        p == step,
                        "protocol violation: step {step} began while step {p} \
                         still awaits its Apply"
                    );
                    // same step re-issued (a peer died mid-gather or a frame
                    // was dropped): recompute from the shard caches — the
                    // streams do not advance, so this is idempotent
                }
                crate::util::fault::begin_step(step);
                for &shard in &shards {
                    let feed = feeds
                        .entry(shard)
                        .or_insert_with(|| ShardFeed::new(data, seed, shard, count));
                    // streamed uplink: each parameter's gradient ships as a
                    // ShardGradChunk the moment backward hands it over, so
                    // the coordinator folds chunk N while this rank (and
                    // its peers) still produce N+1
                    let toks = feed.batch(step)?;
                    backend.grad_batch_streamed(
                        &Batch::Tokens(toks),
                        &mut |i, loss, g| {
                            let mut data = std::mem::take(&mut chunk_buf);
                            codec.encode_into(g, &mut data);
                            let msg = Msg::ShardGradChunk {
                                step,
                                shard,
                                seq: i as u32,
                                total: total_chunks,
                                codec: mode.id(),
                                elems: g.len() as u32,
                                loss,
                                data,
                            };
                            let sent = send(writer, &msg);
                            if let Msg::ShardGradChunk { data, .. } = msg {
                                chunk_buf = data; // keep the warm buffer
                            }
                            sent
                        },
                    )?;
                    shards_done += 1;
                }
                pending = Some(step);
            }
            Msg::Apply { step, lr, apply, grads } => {
                match pending {
                    Some(p) => anyhow::ensure!(
                        p == step,
                        "protocol violation: Apply for step {step} while step {p} is pending"
                    ),
                    // no pending step: this rank had no shards and its
                    // (empty) StepBegin was lost — applying is still
                    // correct and keeps the replica in sync
                    None => {}
                }
                if let Some(a) = last_applied {
                    // a missed Apply (e.g. CRC-dropped) would silently fork
                    // this replica from the fleet; a gap is fatal, and the
                    // abort report lets the coordinator redistribute
                    anyhow::ensure!(
                        step == a + 1,
                        "protocol violation: Apply for step {step} after step {a} — \
                         a broadcast was lost, replica would diverge"
                    );
                }
                if apply {
                    if grads.is_empty() {
                        // streamed downlink: the header is followed by one
                        // ApplyChunk per parameter on this same ordered
                        // stream; reassemble into the reused flat buffer.
                        // Past this point the step is committed, so any
                        // loss here (corrupt or missing chunk) is fatal —
                        // a partial apply cannot be retried or abandoned
                        flat.clear();
                        recv_apply_chunks(reader, &mut codec, mode, step, &mut flat)?;
                        backend.apply_flat_grads(&flat, lr)?;
                    } else {
                        backend.apply_flat_grads(&grads, lr)?;
                    }
                    steps_applied += 1;
                }
                // on a guard skip (apply = false) the coordinator sends no
                // chunks and momentum stays untouched on every rank,
                // mirroring the single-process step_gated
                pending = None;
                last_applied = Some(step);
            }
            Msg::CheckpointRequest { step } => {
                let mut st = backend.export_state()?;
                st.step = step;
                send(writer, &Msg::CheckpointState { state: st })?;
            }
            Msg::Shutdown { reason } => {
                info!("rank {rank}: coordinator ended the run: {reason}");
                return Ok(WorkerResult { rank, steps_applied, shards_done });
            }
            other => warnln!("rank {rank}: ignoring unexpected {}", other.name()),
        }
    }
}

/// Read the `ApplyChunk` stream that follows an `Apply` header and decode
/// it into `flat`. The coordinator's per-connection writes are ordered,
/// so the chunks arrive back to back in sequence; the real chunk count
/// comes from the first chunk's `total`. Every failure mode is fatal by
/// design: the Apply broadcast is the commit point, so a chunk this rank
/// cannot decode means a replica that can never catch up.
fn recv_apply_chunks(
    reader: &mut TcpStream,
    codec: &mut GradCodec,
    mode: Compression,
    step: u64,
    flat: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let mut next = 0u32;
    let mut total = 1u32; // learned from the first chunk
    while next < total {
        let chunk = match wire::read_msg(reader) {
            Ok(m) => m,
            Err(RecvError::Corrupt { want, got }) => anyhow::bail!(
                "ApplyChunk {next} of step {step} failed its CRC \
                 (got {got:#010x}, wanted {want:#010x}) — the update is \
                 committed on peers, this replica cannot continue"
            ),
            Err(e) => anyhow::bail!("reading ApplyChunk {next} of step {step}: {e}"),
        };
        match chunk {
            Msg::ApplyChunk { step: s, seq, total: t, codec: c, elems, data } => {
                anyhow::ensure!(
                    s == step && seq == next,
                    "protocol violation: ApplyChunk step {s} seq {seq}, \
                     wanted step {step} seq {next}"
                );
                anyhow::ensure!(
                    Compression::from_id(c)? == mode,
                    "ApplyChunk codec id {c} does not match the run's {}",
                    mode.name()
                );
                if next == 0 {
                    anyhow::ensure!(t > 0, "Apply stream claims zero chunks");
                    total = t;
                } else {
                    anyhow::ensure!(
                        t == total,
                        "ApplyChunk claims {t} total chunks, stream established {total}"
                    );
                }
                codec.decode_append(&data, elems as usize, flat)?;
                next += 1;
            }
            other => anyhow::bail!(
                "protocol violation: {} interleaved an Apply chunk stream",
                other.name()
            ),
        }
    }
    Ok(())
}

/// Serialize a frame onto the shared write half. No retry here on
/// purpose: `write_all` may have committed part of a frame before
/// failing, and re-sending would corrupt the framing — recovery from a
/// failed send is connection-level (abort; the coordinator
/// redistributes), not frame-level.
fn send(writer: &Mutex<TcpStream>, msg: &Msg) -> anyhow::Result<()> {
    let mut s = writer.lock().unwrap_or_else(|e| e.into_inner());
    wire::write_msg(&mut *s, msg)
}
