//! Distributed data-parallel training over a fault-tolerant TCP coordinator.
//!
//! A run is one [`coordinator`] process plus `dist.workers` [`worker`]
//! processes (or threads — the tests drive both in-process) connected over
//! the [`wire`] protocol: length-prefixed binary frames on `std::net` TCP,
//! every frame CRC-32 guarded, no external RPC stack.
//!
//! # Determinism contract
//!
//! The global batch of step `s` is a fixed set of `dist.shards` shards;
//! shard `k` always draws from `token_source(data, seed, SHARD_SPLIT_BASE
//! + k)` regardless of which worker computes it. The coordinator reduces
//! per-*shard* gradients in shard-index order with f64 accumulation
//! ([`reduce_shards`]), clips the average, runs the anomaly guard, and
//! broadcasts one `Apply` frame that every worker executes identically.
//! Because nothing in the math depends on the shard→worker mapping, the
//! final weights are bit-exact for any worker count at equal global batch
//! — including after mid-run deaths and redistributions. The 1-worker run
//! is the degenerate case of the same code path, which is what the fault
//! scenarios compare killed runs against.
//!
//! # Failure model
//!
//! Workers heartbeat every `dist.heartbeat_ms`; a worker silent past
//! `dist.deadline_ms` (or whose socket closes, or who sends
//! `WorkerAbort`) is declared dead. Death *before* the step's barrier
//! completes discards the partial gather, reassigns the dead worker's
//! shards over the survivors, and re-issues `StepBegin` — workers serve
//! the repeat from their shard-batch cache, so no data is skipped and no
//! momentum is touched. The broadcast of `Apply` is the commit point:
//! once any worker may have applied a step, that step is never replayed
//! (replaying it would double-apply momentum on survivors). Checkpoints
//! are written by the coordinator through the validated v3 machinery, so
//! a killed-and-restarted coordinator resumes from `latest_valid()` and
//! freshly-registered workers import the shipped state.

pub mod coordinator;
pub mod wire;
pub mod worker;

use crate::runtime::StepMetrics;

/// Token-source split offset for shard streams. Splits 0 and 1 are the
/// single-process train/eval streams; shard `k` reads split `2 + k`, so
/// distributed shards never alias the sequential streams.
pub const SHARD_SPLIT_BASE: u64 = 2;

/// Global-norm clip threshold applied to the shard-averaged gradient —
/// the same constant the single-process backend uses per batch.
pub const CLIP_NORM: f64 = 1.0;

/// Deterministic shard assignment: shard `k` goes to `live[k % live.len()]`.
///
/// `live` must be the sorted list of live ranks; the result pairs each
/// live rank with its (possibly empty) shard list in `live` order. Only
/// the *set* of live ranks affects who computes what — never arrival
/// order — so any two coordinators with the same view assign identically.
pub fn assign_shards(nshards: u32, live: &[u32]) -> Vec<(u32, Vec<u32>)> {
    debug_assert!(live.windows(2).all(|w| w[0] < w[1]), "live ranks must be sorted + unique");
    let mut out: Vec<(u32, Vec<u32>)> = live.iter().map(|&r| (r, Vec::new())).collect();
    if out.is_empty() {
        return out;
    }
    for shard in 0..nshards {
        let slot = (shard as usize) % out.len();
        out[slot].1.push(shard);
    }
    out
}

/// Deterministic all-reduce over per-shard gradients.
///
/// `shards` must hold one `(loss, flat_grad)` entry per shard, **in
/// shard-index order** — the caller guarantees the order, this function
/// guarantees that equal inputs give bit-equal outputs. Each gradient
/// element is summed in f64 across shards, divided by the shard count,
/// and rounded once to f32; the mean loss and the global norm of the
/// averaged gradient are likewise f64 until the final rounding. The
/// average is clipped to `clip_norm` exactly like the single-process
/// step. Returns the step metrics plus the clipped averaged gradient.
pub fn reduce_shards(
    shards: &[(f32, Vec<f32>)],
    clip_norm: f64,
) -> anyhow::Result<(StepMetrics, Vec<f32>)> {
    anyhow::ensure!(!shards.is_empty(), "reduce over zero shards");
    let n = shards[0].1.len();
    for (i, (_, g)) in shards.iter().enumerate() {
        anyhow::ensure!(
            g.len() == n,
            "shard {i} gradient has {} elements, shard 0 has {n}",
            g.len()
        );
    }
    let inv = 1.0f64 / shards.len() as f64;
    let mut acc = vec![0f64; n];
    for (_, g) in shards {
        for (a, &x) in acc.iter_mut().zip(g.iter()) {
            *a += x as f64;
        }
    }
    let mut avg: Vec<f32> = acc.iter().map(|a| (a * inv) as f32).collect();
    let loss = shards.iter().map(|(l, _)| *l as f64).sum::<f64>() * inv;
    let norm = avg.iter().map(|&g| g as f64 * g as f64).sum::<f64>().sqrt();
    let clipped = norm > clip_norm;
    if clipped {
        let s = (clip_norm / norm) as f32;
        for g in &mut avg {
            *g *= s;
        }
    }
    let metrics = StepMetrics {
        loss: loss as f32,
        grad_norm: norm as f32,
        clipped: if clipped { 1.0 } else { 0.0 },
    };
    Ok((metrics, avg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_a_pure_function_of_the_live_set() {
        let a = assign_shards(5, &[0, 1, 2]);
        assert_eq!(
            a,
            vec![(0, vec![0, 3]), (1, vec![1, 4]), (2, vec![2])],
            "round-robin over sorted live ranks"
        );
        // dropping rank 1 redistributes its shards without consulting
        // any history — same answer no matter when the death happened
        let b = assign_shards(5, &[0, 2]);
        assert_eq!(b, vec![(0, vec![0, 2, 4]), (2, vec![1, 3])]);
        // more workers than shards: the surplus worker idles but still
        // receives a (empty) StepBegin so it stays barrier-synchronized
        let c = assign_shards(2, &[0, 1, 2]);
        assert_eq!(c, vec![(0, vec![0]), (1, vec![1]), (2, vec![])]);
        assert!(assign_shards(4, &[]).is_empty());
    }

    #[test]
    fn reduce_matches_a_naive_f64_oracle() {
        let shards = vec![
            (2.0f32, vec![0.5f32, -1.0, 3.0]),
            (4.0f32, vec![1.5f32, 2.0, -3.0]),
        ];
        let (m, avg) = reduce_shards(&shards, 1e9).unwrap();
        assert_eq!(avg, vec![1.0, 0.5, 0.0]);
        assert_eq!(m.loss, 3.0);
        let want_norm = ((1.0f64 + 0.25).sqrt()) as f32;
        assert_eq!(m.grad_norm, want_norm);
        assert_eq!(m.clipped, 0.0);
    }

    #[test]
    fn reduce_clips_like_the_single_process_step() {
        let shards = vec![(1.0f32, vec![3.0f32, 4.0])];
        let (m, avg) = reduce_shards(&shards, 1.0).unwrap();
        assert_eq!(m.clipped, 1.0);
        assert_eq!(m.grad_norm, 5.0);
        let s = (1.0f64 / 5.0) as f32;
        assert_eq!(avg, vec![3.0 * s, 4.0 * s]);
    }

    #[test]
    fn reduce_is_bitwise_stable_for_equal_shard_order() {
        // The determinism contract: the reduction depends only on the
        // (shard-ordered) inputs, so two coordinators — or one coordinator
        // before and after a redistribution — agree bit for bit.
        let mk = |seed: u64| {
            let mut r = crate::util::rng::Rng::new(seed);
            (0..4)
                .map(|_| {
                    (r.next_f32(), (0..257).map(|_| r.next_f32() * 2.0 - 1.0).collect::<Vec<_>>())
                })
                .collect::<Vec<_>>()
        };
        let (m1, g1) = reduce_shards(&mk(9), CLIP_NORM).unwrap();
        let (m2, g2) = reduce_shards(&mk(9), CLIP_NORM).unwrap();
        assert_eq!(m1.loss.to_bits(), m2.loss.to_bits());
        assert_eq!(m1.grad_norm.to_bits(), m2.grad_norm.to_bits());
        let b1: Vec<u32> = g1.iter().map(|x| x.to_bits()).collect();
        let b2: Vec<u32> = g2.iter().map(|x| x.to_bits()).collect();
        assert_eq!(b1, b2);
    }

    #[test]
    fn reduce_rejects_mismatched_lengths_and_empty_input() {
        assert!(reduce_shards(&[], 1.0).is_err());
        let bad = vec![(0.0f32, vec![1.0f32]), (0.0f32, vec![1.0f32, 2.0])];
        let err = reduce_shards(&bad, 1.0).unwrap_err().to_string();
        assert!(err.contains("shard 1"), "{err}");
    }
}
