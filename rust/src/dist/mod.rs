//! Distributed data-parallel training over a fault-tolerant TCP coordinator.
//!
//! A run is one [`coordinator`] process plus `dist.workers` [`worker`]
//! processes (or threads — the tests drive both in-process) connected over
//! the [`wire`] protocol: length-prefixed binary frames on `std::net` TCP,
//! every frame CRC-32 guarded, no external RPC stack.
//!
//! # Determinism contract
//!
//! The global batch of step `s` is a fixed set of `dist.shards` shards;
//! shard `k` always draws from `token_source(data, seed, SHARD_SPLIT_BASE
//! + k)` regardless of which worker computes it. Gradients cross the wire
//! as one chunk per parameter (`ShardGradChunk`, optionally
//! bf16-compressed — see [`compress`]), and the coordinator folds each
//! chunk incrementally with f64 accumulation **in shard-index order**
//! ([`ChunkReducer`], the streamed form of [`reduce_shards`] — same
//! reduction tree, bit-identical result), clips the average, runs the
//! anomaly guard, and broadcasts the same reduced gradient to every
//! worker as an `Apply` header plus `ApplyChunk` stream. Because nothing
//! in the math depends on the shard→worker mapping or on chunk *arrival*
//! order, the final weights are bit-exact for any worker count at equal
//! global batch — including after mid-run deaths and redistributions,
//! and in both compression modes (each mode is its own deterministic
//! trajectory; `bf16` rounds each element once on each wire crossing,
//! identically everywhere). The 1-worker run is the degenerate case of
//! the same code path, which is what the fault scenarios compare killed
//! runs against.
//!
//! # Failure model
//!
//! Workers heartbeat every `dist.heartbeat_ms`; a worker silent past
//! `dist.deadline_ms` (or whose socket closes, or who sends
//! `WorkerAbort`) is declared dead. Death *before* the step's barrier
//! completes — including mid-chunk-stream — discards the partial gather,
//! reassigns the dead worker's shards over the survivors, and re-issues
//! `StepBegin`; workers serve the repeat from their shard-batch cache
//! and replay the full chunk sequence bit-identically, so per-chunk
//! sequence numbers make the resend idempotent (stale duplicates lose
//! first-one-wins). The broadcast of `Apply` is the commit point: once
//! any worker may have applied a step, that step is never replayed
//! (replaying it would double-apply momentum on survivors). Checkpoints
//! are written by the coordinator through the validated v3 machinery, so
//! a killed-and-restarted coordinator resumes from `latest_valid()` and
//! freshly-registered workers import the shipped state. A fresh run
//! unlinks any leftover addr file before binding and stamps a random
//! nonce into both the addr file and `RegisterAck`, so a replica
//! pointed at a stale address can never join the wrong run.

pub mod compress;
pub mod coordinator;
pub mod wire;
pub mod worker;

use std::path::Path;

use crate::dist::compress::{Compression, GradCodec};
use crate::runtime::StepMetrics;

/// Token-source split offset for shard streams. Splits 0 and 1 are the
/// single-process train/eval streams; shard `k` reads split `2 + k`, so
/// distributed shards never alias the sequential streams.
pub const SHARD_SPLIT_BASE: u64 = 2;

/// Global-norm clip threshold applied to the shard-averaged gradient —
/// the same constant the single-process backend uses per batch.
pub const CLIP_NORM: f64 = 1.0;

/// Deterministic shard assignment: shard `k` goes to `live[k % live.len()]`.
///
/// `live` must be the sorted list of live ranks; the result pairs each
/// live rank with its (possibly empty) shard list in `live` order. Only
/// the *set* of live ranks affects who computes what — never arrival
/// order — so any two coordinators with the same view assign identically.
pub fn assign_shards(nshards: u32, live: &[u32]) -> Vec<(u32, Vec<u32>)> {
    debug_assert!(live.windows(2).all(|w| w[0] < w[1]), "live ranks must be sorted + unique");
    let mut out: Vec<(u32, Vec<u32>)> = live.iter().map(|&r| (r, Vec::new())).collect();
    if out.is_empty() {
        return out;
    }
    for shard in 0..nshards {
        let slot = (shard as usize) % out.len();
        out[slot].1.push(shard);
    }
    out
}

/// Deterministic all-reduce over per-shard gradients.
///
/// `shards` must hold one `(loss, flat_grad)` entry per shard, **in
/// shard-index order** — the caller guarantees the order, this function
/// guarantees that equal inputs give bit-equal outputs. Each gradient
/// element is summed in f64 across shards, divided by the shard count,
/// and rounded once to f32; the mean loss and the global norm of the
/// averaged gradient are likewise f64 until the final rounding. The
/// average is clipped to `clip_norm` exactly like the single-process
/// step. Returns the step metrics plus the clipped averaged gradient.
pub fn reduce_shards(
    shards: &[(f32, Vec<f32>)],
    clip_norm: f64,
) -> anyhow::Result<(StepMetrics, Vec<f32>)> {
    anyhow::ensure!(!shards.is_empty(), "reduce over zero shards");
    let n = shards[0].1.len();
    for (i, (_, g)) in shards.iter().enumerate() {
        anyhow::ensure!(
            g.len() == n,
            "shard {i} gradient has {} elements, shard 0 has {n}",
            g.len()
        );
    }
    let inv = 1.0f64 / shards.len() as f64;
    let mut acc = vec![0f64; n];
    for (_, g) in shards {
        for (a, &x) in acc.iter_mut().zip(g.iter()) {
            *a += x as f64;
        }
    }
    let mut avg: Vec<f32> = acc.iter().map(|a| (a * inv) as f32).collect();
    let loss = shards.iter().map(|(l, _)| *l as f64).sum::<f64>() * inv;
    let norm = avg.iter().map(|&g| g as f64 * g as f64).sum::<f64>().sqrt();
    let clipped = norm > clip_norm;
    if clipped {
        let s = (clip_norm / norm) as f32;
        for g in &mut avg {
            *g *= s;
        }
    }
    let metrics = StepMetrics {
        loss: loss as f32,
        grad_norm: norm as f32,
        clipped: if clipped { 1.0 } else { 0.0 },
    };
    Ok((metrics, avg))
}

/// One staged-but-not-yet-folded uplink chunk: element count plus the
/// still-encoded wire payload (decoded only at fold time, in shard order).
struct StagedChunk {
    elems: u32,
    data: Vec<u8>,
}

/// Incremental, order-insensitive form of [`reduce_shards`] for the
/// streamed gradient path.
///
/// The coordinator feeds every `ShardGradChunk` it receives into
/// [`accept`](ChunkReducer::accept) as it arrives; the reducer stages the
/// still-encoded payloads per `(shard, seq)` and folds sequence `k` the
/// moment **all** shards have delivered it — decoding and accumulating in
/// shard-index order with f64 arithmetic, exactly the reduction tree of
/// [`reduce_shards`]. Chunk *arrival* order therefore never affects the
/// result, and peak memory is one staged chunk set plus the flat output
/// instead of `workers × flat_len` floats. Duplicate `(shard, seq)`
/// deliveries (resends after a re-issued step — bit-identical by the
/// shard-batch-cache contract) lose first-one-wins.
pub struct ChunkReducer {
    nshards: usize,
    mode: Compression,
    clip_norm: f64,
    codec: GradCodec,
    /// Chunks per parameter, learned from the first accepted chunk.
    total: Option<usize>,
    /// `staged[shard][seq]` holds a chunk awaiting its barrier.
    staged: Vec<Vec<Option<StagedChunk>>>,
    /// Per-shard loss, recorded from the first chunk each shard delivers.
    loss: Vec<Option<f32>>,
    /// Next sequence number to fold (all below are already in `out`).
    next_fold: usize,
    /// Element count of each folded sequence — the parameter layout the
    /// coordinator reuses to chunk the Apply downlink.
    layout: Vec<u32>,
    /// f64 accumulator scratch, sized to the widest chunk seen.
    acc: Vec<f64>,
    /// Decode scratch for one shard's chunk.
    scratch: Vec<f32>,
    /// The averaged (not yet clipped) flat gradient, grown chunk by chunk.
    out: Vec<f32>,
}

impl ChunkReducer {
    /// A reducer for one gather attempt: `nshards` shard streams, all
    /// encoded with `mode`, clipped to `clip_norm` at the end.
    pub fn new(nshards: usize, mode: Compression, clip_norm: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(nshards > 0, "reduce over zero shards");
        Ok(ChunkReducer {
            nshards,
            mode,
            clip_norm,
            codec: GradCodec::new(mode),
            total: None,
            staged: (0..nshards).map(|_| Vec::new()).collect(),
            loss: vec![None; nshards],
            next_fold: 0,
            layout: Vec::new(),
            acc: Vec::new(),
            scratch: Vec::new(),
            out: Vec::new(),
        })
    }

    /// Accept one uplink chunk (the fields of a `ShardGradChunk` frame).
    ///
    /// Geometry is validated against what earlier chunks established:
    /// every chunk must agree on `total` and the codec, `shard`/`seq`
    /// must be in range, and `data` must be exactly `elems` encoded
    /// elements. Duplicates of an already-staged or already-folded
    /// `(shard, seq)` are silently dropped.
    pub fn accept(
        &mut self,
        shard: u32,
        seq: u32,
        total: u32,
        codec: u8,
        elems: u32,
        loss: f32,
        data: &[u8],
    ) -> anyhow::Result<()> {
        let got = Compression::from_id(codec)?;
        anyhow::ensure!(
            got == self.mode,
            "chunk codec {} does not match the run's {}",
            got.name(),
            self.mode.name()
        );
        anyhow::ensure!(
            (shard as usize) < self.nshards,
            "chunk for shard {shard} but the step has {} shards",
            self.nshards
        );
        anyhow::ensure!(total > 0, "chunk stream claims zero total chunks");
        match self.total {
            None => {
                let t = total as usize;
                self.total = Some(t);
                for s in &mut self.staged {
                    s.resize_with(t, || None);
                }
            }
            Some(t) => anyhow::ensure!(
                t == total as usize,
                "chunk claims {total} total chunks, stream established {t}"
            ),
        }
        anyhow::ensure!(seq < total, "chunk seq {seq} out of range 0..{total}");
        anyhow::ensure!(
            data.len() == elems as usize * self.mode.bytes_per_elem(),
            "chunk payload is {} bytes for {elems} {} elements",
            data.len(),
            self.mode.name()
        );
        let slot = &mut self.staged[shard as usize][seq as usize];
        if seq as usize >= self.next_fold && slot.is_none() {
            *slot = Some(StagedChunk { elems, data: data.to_vec() });
            self.loss[shard as usize].get_or_insert(loss);
            self.fold_ready()?;
        }
        Ok(())
    }

    /// Fold every sequence number whose barrier is complete, in order.
    fn fold_ready(&mut self) -> anyhow::Result<()> {
        let total = self.total.unwrap_or(0);
        while self.next_fold < total
            && self.staged.iter().all(|s| s[self.next_fold].is_some())
        {
            let seq = self.next_fold;
            let elems = self.staged[0][seq].as_ref().map(|c| c.elems).unwrap_or(0);
            self.acc.clear();
            self.acc.resize(elems as usize, 0.0);
            for shard in 0..self.nshards {
                let chunk = self.staged[shard][seq].take().expect("barrier checked");
                anyhow::ensure!(
                    chunk.elems == elems,
                    "seq {seq}: shard {shard} sent {} elements, shard 0 sent {elems}",
                    chunk.elems
                );
                self.scratch.clear();
                self.codec.decode_append(&chunk.data, elems as usize, &mut self.scratch)?;
                for (a, &x) in self.acc.iter_mut().zip(self.scratch.iter()) {
                    *a += x as f64;
                }
            }
            let inv = 1.0f64 / self.nshards as f64;
            self.out.extend(self.acc.iter().map(|a| (a * inv) as f32));
            self.layout.push(elems);
            self.next_fold += 1;
        }
        Ok(())
    }

    /// True once every shard has delivered every chunk (and all are folded).
    pub fn complete(&self) -> bool {
        matches!(self.total, Some(t) if self.next_fold == t)
    }

    /// Element count per folded sequence, in order — the parameter layout
    /// of the flat gradient [`finish`](ChunkReducer::finish) returns.
    pub fn layout(&self) -> &[u32] {
        &self.layout
    }

    /// Finalize: mean loss, norm, clip — bit-identical to running
    /// [`reduce_shards`] over the fully-decoded per-shard gradients.
    pub fn finish(mut self) -> anyhow::Result<(StepMetrics, Vec<f32>)> {
        anyhow::ensure!(self.complete(), "finish before every chunk arrived");
        let inv = 1.0f64 / self.nshards as f64;
        let loss = self
            .loss
            .iter()
            .map(|l| l.expect("complete implies a chunk per shard") as f64)
            .sum::<f64>()
            * inv;
        let norm = self.out.iter().map(|&g| g as f64 * g as f64).sum::<f64>().sqrt();
        let clipped = norm > self.clip_norm;
        if clipped {
            let s = (self.clip_norm / norm) as f32;
            for g in &mut self.out {
                *g *= s;
            }
        }
        let metrics = StepMetrics {
            loss: loss as f32,
            grad_norm: norm as f32,
            clipped: if clipped { 1.0 } else { 0.0 },
        };
        Ok((metrics, self.out))
    }
}

/// Parse a coordinator addr file: line one is the socket address; line
/// two — written by runs with stale-run protection — is the run nonce in
/// hex. Older single-line files parse with `nonce = None`.
pub fn read_addr_file(path: &Path) -> anyhow::Result<(String, Option<u64>)> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read addr file {}: {e}", path.display()))?;
    let mut lines = text.lines();
    let addr = lines.next().unwrap_or("").trim().to_string();
    anyhow::ensure!(!addr.is_empty(), "addr file {} is empty", path.display());
    let nonce = match lines.next().map(str::trim) {
        Some(s) if !s.is_empty() => Some(
            u64::from_str_radix(s.trim_start_matches("0x"), 16)
                .map_err(|e| anyhow::anyhow!("bad nonce in {}: {e}", path.display()))?,
        ),
        _ => None,
    };
    Ok((addr, nonce))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_a_pure_function_of_the_live_set() {
        let a = assign_shards(5, &[0, 1, 2]);
        assert_eq!(
            a,
            vec![(0, vec![0, 3]), (1, vec![1, 4]), (2, vec![2])],
            "round-robin over sorted live ranks"
        );
        // dropping rank 1 redistributes its shards without consulting
        // any history — same answer no matter when the death happened
        let b = assign_shards(5, &[0, 2]);
        assert_eq!(b, vec![(0, vec![0, 2, 4]), (2, vec![1, 3])]);
        // more workers than shards: the surplus worker idles but still
        // receives a (empty) StepBegin so it stays barrier-synchronized
        let c = assign_shards(2, &[0, 1, 2]);
        assert_eq!(c, vec![(0, vec![0]), (1, vec![1]), (2, vec![])]);
        assert!(assign_shards(4, &[]).is_empty());
    }

    #[test]
    fn reduce_matches_a_naive_f64_oracle() {
        let shards = vec![
            (2.0f32, vec![0.5f32, -1.0, 3.0]),
            (4.0f32, vec![1.5f32, 2.0, -3.0]),
        ];
        let (m, avg) = reduce_shards(&shards, 1e9).unwrap();
        assert_eq!(avg, vec![1.0, 0.5, 0.0]);
        assert_eq!(m.loss, 3.0);
        let want_norm = ((1.0f64 + 0.25).sqrt()) as f32;
        assert_eq!(m.grad_norm, want_norm);
        assert_eq!(m.clipped, 0.0);
    }

    #[test]
    fn reduce_clips_like_the_single_process_step() {
        let shards = vec![(1.0f32, vec![3.0f32, 4.0])];
        let (m, avg) = reduce_shards(&shards, 1.0).unwrap();
        assert_eq!(m.clipped, 1.0);
        assert_eq!(m.grad_norm, 5.0);
        let s = (1.0f64 / 5.0) as f32;
        assert_eq!(avg, vec![3.0 * s, 4.0 * s]);
    }

    #[test]
    fn reduce_is_bitwise_stable_for_equal_shard_order() {
        // The determinism contract: the reduction depends only on the
        // (shard-ordered) inputs, so two coordinators — or one coordinator
        // before and after a redistribution — agree bit for bit.
        let mk = |seed: u64| {
            let mut r = crate::util::rng::Rng::new(seed);
            (0..4)
                .map(|_| {
                    (r.next_f32(), (0..257).map(|_| r.next_f32() * 2.0 - 1.0).collect::<Vec<_>>())
                })
                .collect::<Vec<_>>()
        };
        let (m1, g1) = reduce_shards(&mk(9), CLIP_NORM).unwrap();
        let (m2, g2) = reduce_shards(&mk(9), CLIP_NORM).unwrap();
        assert_eq!(m1.loss.to_bits(), m2.loss.to_bits());
        assert_eq!(m1.grad_norm.to_bits(), m2.grad_norm.to_bits());
        let b1: Vec<u32> = g1.iter().map(|x| x.to_bits()).collect();
        let b2: Vec<u32> = g2.iter().map(|x| x.to_bits()).collect();
        assert_eq!(b1, b2);
    }

    #[test]
    fn reduce_rejects_mismatched_lengths_and_empty_input() {
        assert!(reduce_shards(&[], 1.0).is_err());
        let bad = vec![(0.0f32, vec![1.0f32]), (0.0f32, vec![1.0f32, 2.0])];
        let err = reduce_shards(&bad, 1.0).unwrap_err().to_string();
        assert!(err.contains("shard 1"), "{err}");
    }

    /// Random per-shard gradients plus their chunked wire encodings:
    /// `(shards, chunks)` where `chunks[shard][seq] = (elems, bytes)`.
    #[allow(clippy::type_complexity)]
    fn chunked_fixture(
        seed: u64,
        nshards: usize,
        sizes: &[usize],
        mode: Compression,
    ) -> (Vec<(f32, Vec<f32>)>, Vec<Vec<(u32, Vec<u8>)>>) {
        let mut r = crate::util::rng::Rng::new(seed);
        let n: usize = sizes.iter().sum();
        let shards: Vec<(f32, Vec<f32>)> = (0..nshards)
            .map(|_| {
                (r.next_f32(), (0..n).map(|_| r.next_f32() * 2.0 - 1.0).collect())
            })
            .collect();
        let mut codec = GradCodec::new(mode);
        let chunks = shards
            .iter()
            .map(|(_, g)| {
                let mut off = 0;
                sizes
                    .iter()
                    .map(|&sz| {
                        let mut buf = Vec::new();
                        codec.encode_into(&g[off..off + sz], &mut buf);
                        off += sz;
                        (sz as u32, buf)
                    })
                    .collect()
            })
            .collect();
        (shards, chunks)
    }

    /// Decode a chunk stream back to per-shard flat gradients — what the
    /// worker-side math sees after the wire crossing.
    fn decoded(
        shards: &[(f32, Vec<f32>)],
        chunks: &[Vec<(u32, Vec<u8>)>],
        mode: Compression,
    ) -> Vec<(f32, Vec<f32>)> {
        let mut codec = GradCodec::new(mode);
        shards
            .iter()
            .zip(chunks)
            .map(|((loss, _), cs)| {
                let mut flat = Vec::new();
                for (elems, data) in cs {
                    codec.decode_append(data, *elems as usize, &mut flat).unwrap();
                }
                (*loss, flat)
            })
            .collect()
    }

    fn assert_bit_equal(
        (ma, ga): &(StepMetrics, Vec<f32>),
        (mb, gb): &(StepMetrics, Vec<f32>),
    ) {
        assert_eq!(ma.loss.to_bits(), mb.loss.to_bits());
        assert_eq!(ma.grad_norm.to_bits(), mb.grad_norm.to_bits());
        assert_eq!(ma.clipped, mb.clipped);
        let ba: Vec<u32> = ga.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = gb.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ba, bb);
    }

    #[test]
    fn chunk_reducer_matches_reduce_shards_bitwise() {
        // uneven chunk sizes, shards delivered wildly out of order across
        // each other — the streamed reduction must still reproduce the
        // buffered one bit for bit because folding is in shard order.
        let sizes = [7usize, 64, 1, 130];
        for &mode in &[Compression::None, Compression::Bf16] {
            let (shards, chunks) = chunked_fixture(11, 3, &sizes, mode);
            let mut red = ChunkReducer::new(3, mode, CLIP_NORM).unwrap();
            // shard 2 streams everything first, then shard 0, then shard 1
            for shard in [2u32, 0, 1] {
                for (seq, (elems, data)) in chunks[shard as usize].iter().enumerate() {
                    let loss = shards[shard as usize].0;
                    red.accept(
                        shard,
                        seq as u32,
                        sizes.len() as u32,
                        mode.id(),
                        *elems,
                        loss,
                        data,
                    )
                    .unwrap();
                }
            }
            assert!(red.complete());
            let got = red.finish().unwrap();
            // the oracle reduces what the chunks decode to — for `none`
            // that is the raw gradients, for `bf16` the once-rounded ones
            let want = reduce_shards(&decoded(&shards, &chunks, mode), CLIP_NORM).unwrap();
            assert_bit_equal(&got, &want);
        }
    }

    #[test]
    fn chunk_reducer_ignores_duplicate_chunks() {
        let sizes = [5usize, 9];
        let mode = Compression::Bf16;
        let (shards, chunks) = chunked_fixture(23, 2, &sizes, mode);
        let feed = |dup: bool| {
            let mut red = ChunkReducer::new(2, mode, CLIP_NORM).unwrap();
            for shard in 0..2u32 {
                for (seq, (elems, data)) in chunks[shard as usize].iter().enumerate() {
                    let loss = shards[shard as usize].0;
                    let times = if dup { 2 } else { 1 };
                    for _ in 0..times {
                        red.accept(shard, seq as u32, 2, mode.id(), *elems, loss, data)
                            .unwrap();
                    }
                }
            }
            // a straggler duplicate of an already-folded chunk is dropped too
            if dup {
                let (elems, data) = &chunks[0][0];
                red.accept(0, 0, 2, mode.id(), *elems, shards[0].0, data).unwrap();
            }
            red.finish().unwrap()
        };
        assert_bit_equal(&feed(false), &feed(true));
    }

    #[test]
    fn chunk_reducer_rejects_bad_geometry() {
        let mode = Compression::None;
        let mut red = ChunkReducer::new(2, mode, 1.0).unwrap();
        let four = [0u8; 4];
        // wrong codec for the run
        let err = red
            .accept(0, 0, 2, Compression::Bf16.id(), 1, 0.0, &four)
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not match"), "{err}");
        // unknown codec id
        assert!(red.accept(0, 0, 2, 9, 1, 0.0, &four).is_err());
        // shard / seq out of range, zero-total stream
        assert!(red.accept(5, 0, 2, mode.id(), 1, 0.0, &four).is_err());
        red.accept(0, 0, 2, mode.id(), 1, 0.0, &four).unwrap();
        assert!(red.accept(0, 2, 2, mode.id(), 1, 0.0, &four).is_err());
        assert!(red.accept(1, 0, 0, mode.id(), 1, 0.0, &four).is_err());
        // total disagreeing with what the stream established
        let err = red.accept(1, 0, 3, mode.id(), 1, 0.0, &four).unwrap_err().to_string();
        assert!(err.contains("established 2"), "{err}");
        // payload length not matching the element count
        assert!(red.accept(1, 0, 2, mode.id(), 2, 0.0, &four).is_err());
        // cross-shard element-count mismatch surfaces at the fold barrier
        let err =
            red.accept(1, 0, 2, mode.id(), 2, 0.0, &[0u8; 8]).unwrap_err().to_string();
        assert!(err.contains("shard 1 sent 2"), "{err}");
        // finishing before the barrier is an error, not a partial result
        let red2 = ChunkReducer::new(1, mode, 1.0).unwrap();
        assert!(red2.finish().is_err());
    }

    #[test]
    fn addr_file_parses_with_and_without_nonce() {
        let dir = std::env::temp_dir()
            .join(format!("rmnp-addr-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("coordinator.addr");
        // modern two-line format: addr + hex nonce
        std::fs::write(&p, "127.0.0.1:4512\n0x00ab54a98ceb1f0a\n").unwrap();
        let (addr, nonce) = read_addr_file(&p).unwrap();
        assert_eq!(addr, "127.0.0.1:4512");
        assert_eq!(nonce, Some(0x00ab_54a9_8ceb_1f0a));
        // legacy single-line format still parses, just without a nonce
        std::fs::write(&p, "127.0.0.1:4512").unwrap();
        assert_eq!(read_addr_file(&p).unwrap(), ("127.0.0.1:4512".into(), None));
        // garbage nonce and empty file are loud errors
        std::fs::write(&p, "127.0.0.1:4512\nnot-hex\n").unwrap();
        assert!(read_addr_file(&p).is_err());
        std::fs::write(&p, "\n").unwrap();
        assert!(read_addr_file(&p).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
