//! The distributed coordinator: roster, heartbeats, barriers, checkpoints.
//!
//! One coordinator process owns the run. It listens on `dist.bind`
//! (publishing the bound address — plus a fresh run nonce workers verify
//! against their `RegisterAck`, so a stale addr file can never route a
//! replica into the wrong run — to `<out_dir>/coordinator.addr`), waits
//! for `dist.workers` registrations, and then drives the step loop:
//! assign shards over the live ranks ([`crate::dist::assign_shards`]),
//! fold each arriving `ShardGradChunk` incrementally at the barrier
//! ([`crate::dist::ChunkReducer`] — bit-identical to the buffered
//! [`crate::dist::reduce_shards`], at a fraction of the memory, and
//! overlapped with the workers' backward passes), run the anomaly guard
//! centrally, and broadcast the update as one `Apply` header plus an
//! `ApplyChunk` stream (encoded once, written per peer). Checkpoints are
//! requested from the lowest live rank after the `Apply` (TCP ordering
//! guarantees the worker has applied the step) and written through the
//! validated checkpoint machinery, with the guard's backoff state
//! stamped in — so a killed coordinator restarted with `--resume` picks
//! up from `latest_valid()` and ships the state to a fresh worker fleet.
//!
//! Threading: the main thread is the only writer of frames. An accept
//! thread hands each connection a dedicated reader thread; readers stamp
//! liveness on every frame and funnel everything except heartbeats into
//! one event queue the main thread drains between deadline checks.
//!
//! Failure handling is step-scoped. A worker death *before* the gather
//! completes discards all of the step's partial gradients, recomputes
//! the assignment over the survivors, and re-issues `StepBegin` (workers
//! serve repeats from their shard-batch cache). The `Apply` broadcast is
//! the commit point: after it, the step is never replayed — a peer that
//! dies during the broadcast is simply marked dead. Metrics and
//! summaries land in the same `metrics.csv` / `summary.jsonl` shapes the
//! single-process loop writes, with `backend = "dist"`.

use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{DataSpec, RunConfig};
use crate::coordinator::checkpoint;
use crate::coordinator::guard::{self, GuardConfig, StepGuard, Verdict};
use crate::coordinator::metrics::{append_jsonl, json_str, CsvWriter};
use crate::coordinator::schedule::lr_at;
use crate::coordinator::train::prepare_resumed_csv;
use crate::dist::compress::{Compression, GradCodec};
use crate::dist::wire::{self, Msg, RecvError};
use crate::dist::{assign_shards, ChunkReducer, CLIP_NORM};
use crate::runtime::{StepMetrics, TrainState};
use crate::{info, warnln};

/// Outcome of a distributed run (the coordinator's view).
#[derive(Clone, Debug)]
pub struct DistResult {
    /// Steps executed by this invocation (excludes restored steps).
    pub steps_run: usize,
    /// Workers declared dead mid-run (abort, disconnect, or deadline).
    pub deaths: usize,
    /// Steps whose optimizer update the anomaly guard skipped.
    pub skipped_steps: usize,
    /// Training loss of the last step with a finite loss.
    pub final_train_loss: f64,
    /// Wall-clock seconds of this invocation.
    pub seconds: f64,
    /// Workers the run started with.
    pub workers: usize,
    /// Data shards per global step.
    pub shards: usize,
}

enum Event {
    /// A decoded frame from connection `conn` (heartbeats excluded).
    Frame(u64, Msg),
    /// Connection `conn`'s reader exited (EOF, reset, or error).
    Closed(u64),
}

#[derive(Default)]
struct HubState {
    events: VecDeque<Event>,
    last_seen: HashMap<u64, Instant>,
    done: bool,
}

/// The readers' funnel into the main thread: one queue, one condvar.
struct Hub {
    state: Mutex<HubState>,
    cv: Condvar,
}

fn lock_hub(hub: &Hub) -> MutexGuard<'_, HubState> {
    hub.state.lock().unwrap_or_else(|e| e.into_inner())
}

/// Pop the next event, waiting up to `wait` for one to arrive. `None`
/// means the wait elapsed — the caller's chance to check deadlines.
fn next_event(hub: &Hub, wait: Duration) -> Option<Event> {
    let mut st = lock_hub(hub);
    if let Some(e) = st.events.pop_front() {
        return Some(e);
    }
    let (mut st, _) = hub.cv.wait_timeout(st, wait).unwrap_or_else(|e| e.into_inner());
    st.events.pop_front()
}

fn reader_loop(hub: Arc<Hub>, conn: u64, mut stream: TcpStream) {
    loop {
        match wire::read_msg(&mut stream) {
            Ok(msg) => {
                let mut st = lock_hub(&hub);
                if st.done {
                    return;
                }
                // ANY intact frame proves liveness; pure heartbeats stop
                // here so the event queue carries only actionable traffic
                st.last_seen.insert(conn, Instant::now());
                if matches!(msg, Msg::Heartbeat { .. }) {
                    continue;
                }
                st.events.push_back(Event::Frame(conn, msg));
                drop(st);
                hub.cv.notify_one();
            }
            Err(RecvError::Corrupt { want, got }) => {
                // dropped whole before deserialization; the stream stays
                // framed and step-level recovery (resend) fills the gap
                warnln!(
                    "conn {conn}: dropping corrupt frame (crc {got:#010x}, wanted {want:#010x})"
                );
            }
            Err(_) => {
                let mut st = lock_hub(&hub);
                st.events.push_back(Event::Closed(conn));
                drop(st);
                hub.cv.notify_one();
                return;
            }
        }
    }
}

/// The listening socket plus its accept/reader threads. The main thread
/// is the sole frame *writer*; the `conns` map holds the write halves.
struct Net {
    hub: Arc<Hub>,
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl Net {
    fn listen(bind: &str) -> anyhow::Result<Net> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| anyhow::anyhow!("binding coordinator to {bind}: {e}"))?;
        let addr = listener.local_addr()?;
        let hub = Arc::new(Hub { state: Mutex::new(HubState::default()), cv: Condvar::new() });
        let conns: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let accept = {
            let hub = Arc::clone(&hub);
            let conns = Arc::clone(&conns);
            std::thread::spawn(move || {
                let mut next_id = 0u64;
                for stream in listener.incoming() {
                    if lock_hub(&hub).done {
                        return;
                    }
                    let Ok(stream) = stream else { continue };
                    let _ = stream.set_nodelay(true);
                    let conn = next_id;
                    next_id += 1;
                    match stream.try_clone() {
                        Ok(write_half) => {
                            conns
                                .lock()
                                .unwrap_or_else(|e| e.into_inner())
                                .insert(conn, write_half);
                            let hub = Arc::clone(&hub);
                            std::thread::spawn(move || reader_loop(hub, conn, stream));
                        }
                        Err(e) => warnln!("conn {conn}: clone failed, dropping: {e}"),
                    }
                }
            })
        };
        Ok(Net { hub, conns, addr, accept: Some(accept) })
    }

    fn send(&self, conn: u64, msg: &Msg) -> anyhow::Result<()> {
        let mut conns = self.conns.lock().unwrap_or_else(|e| e.into_inner());
        let stream = conns
            .get_mut(&conn)
            .ok_or_else(|| anyhow::anyhow!("connection {conn} is gone"))?;
        wire::write_msg(stream, msg)
    }

    fn drop_conn(&self, conn: u64) {
        let removed = self.conns.lock().unwrap_or_else(|e| e.into_inner()).remove(&conn);
        if let Some(s) = removed {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }

    fn last_seen(&self, conn: u64) -> Option<Instant> {
        lock_hub(&self.hub).last_seen.get(&conn).copied()
    }

    fn shutdown(&mut self) {
        lock_hub(&self.hub).done = true;
        for (_, s) in self.conns.lock().unwrap_or_else(|e| e.into_inner()).drain() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        // a throwaway self-connection unblocks `accept` so the thread
        // observes `done` and exits instead of leaking
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Net {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown();
        }
    }
}

/// A registered worker. Rank = index into the coordinator's peer vec;
/// ranks are never reused, dead peers just stop being assigned shards.
struct Peer {
    conn: u64,
    id: String,
    alive: bool,
}

/// Run the coordinator side of a distributed job to completion.
///
/// Blocks until the run finishes, the guard aborts it, or every worker
/// is dead. Always broadcasts a `Shutdown` (with the completion or error
/// reason) before tearing the sockets down, so workers exit cleanly.
pub fn run(cfg: &RunConfig) -> anyhow::Result<DistResult> {
    let t_start = Instant::now();
    anyhow::ensure!(
        cfg.data != DataSpec::Images,
        "distributed training shards token corpora only (got images)"
    );
    anyhow::ensure!(cfg.dist_workers >= 1, "dist.workers must be at least 1");
    std::fs::create_dir_all(&cfg.out_dir)?;

    // resume: same contract as the single-process loop — newest *valid*
    // checkpoint or a clean refusal, never a silent restart from scratch
    let mut start_step = 0usize;
    let mut resume_guard: Option<(f64, usize)> = None;
    let mut resume_state: Option<TrainState> = None;
    if cfg.resume {
        match checkpoint::latest_valid(&cfg.out_dir)? {
            Some((step, path, mut state)) => {
                resume_guard = guard::extract_guard(&mut state);
                start_step = step;
                info!("coordinator resuming from {} (step {step})", path.display());
                resume_state = Some(state);
            }
            None => {
                if let Some((step, path)) = checkpoint::latest(&cfg.out_dir)? {
                    anyhow::bail!(
                        "resume requested but no checkpoint in {} validates \
                         (newest candidate is step-{step}: {}); refusing to \
                         restart from scratch",
                        cfg.out_dir.display(),
                        path.display()
                    );
                }
            }
        }
    }
    anyhow::ensure!(
        start_step <= cfg.steps,
        "checkpoint is at step {start_step} but the run only has {} steps",
        cfg.steps
    );

    let mode = Compression::parse(&cfg.dist_compress)?;
    let nshards = if cfg.dist_shards == 0 { cfg.dist_workers } else { cfg.dist_shards } as u32;
    // a leftover addr file from a dead run must never be readable while
    // the new listener comes up — a launcher polling it would dial a
    // socket nobody owns (or, worse, a different run on a reused port)
    let addr_path = cfg.out_dir.join("coordinator.addr");
    if addr_path.exists() {
        std::fs::remove_file(&addr_path)
            .map_err(|e| anyhow::anyhow!("unlinking stale {}: {e}", addr_path.display()))?;
    }
    let net = Net::listen(&cfg.dist_bind)?;
    let nonce = run_nonce(net.addr.port());
    // publish the bound address (and the run nonce workers must see
    // echoed in their RegisterAck) via write + rename so a polling worker
    // launcher never reads a torn file
    let tmp = cfg.out_dir.join("coordinator.addr.tmp");
    std::fs::write(&tmp, format!("{}\n{nonce:#018x}\n", net.addr))?;
    std::fs::rename(&tmp, &addr_path)?;
    info!(
        "coordinator listening on {} ({} workers, {nshards} shards, steps {start_step}..{}, \
         compress {}, nonce {nonce:#018x})",
        net.addr,
        cfg.dist_workers,
        cfg.steps,
        mode.name()
    );

    let peers = gather_workers(cfg, &net, start_step, nshards, &resume_state, nonce, mode)?;
    let mut co = Coord {
        cfg,
        net,
        peers,
        deaths: 0,
        last_abort: None,
        nshards,
        mode,
        layout: Vec::new(),
    };
    let run = co.train(start_step, resume_guard, t_start);
    match &run {
        Ok(_) => co.broadcast(&Msg::Shutdown { reason: "run complete".into() }),
        Err(e) => co.broadcast(&Msg::Shutdown { reason: e.to_string() }),
    }
    co.net.shutdown();
    run
}

/// A fresh run nonce: wall-clock nanos, pid, and the bound port scrambled
/// through a splitmix64 round, so even coordinators started within the
/// same tick differ. Stamped into the addr file and echoed in every
/// `RegisterAck` — a worker launched off a stale addr file fails the echo
/// check instead of silently joining the wrong run.
fn run_nonce(port: u16) -> u64 {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let mut z = nanos ^ (u64::from(std::process::id()) << 32) ^ u64::from(port);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Wait for `dist.workers` live registrations, acking each with the full
/// run definition (and the resume state, if any). Duplicate worker ids
/// are refused; a worker that dies before the roster completes frees its
/// slot for a later arrival.
#[allow(clippy::too_many_arguments)]
fn gather_workers(
    cfg: &RunConfig,
    net: &Net,
    start_step: usize,
    nshards: u32,
    resume_state: &Option<TrainState>,
    nonce: u64,
    mode: Compression,
) -> anyhow::Result<Vec<Peer>> {
    let deadline = Instant::now() + Duration::from_millis(cfg.dist_join_timeout_ms.max(1000));
    let mut peers: Vec<Peer> = Vec::new();
    while peers.iter().filter(|p| p.alive).count() < cfg.dist_workers {
        anyhow::ensure!(
            Instant::now() < deadline,
            "only {}/{} workers registered within {} ms",
            peers.iter().filter(|p| p.alive).count(),
            cfg.dist_workers,
            cfg.dist_join_timeout_ms
        );
        let Some(ev) = next_event(&net.hub, Duration::from_millis(50)) else { continue };
        match ev {
            Event::Frame(conn, Msg::Register { worker_id }) => {
                if peers.iter().any(|p| p.alive && p.id == worker_id) {
                    warnln!("refusing duplicate registration of `{worker_id}`");
                    let _ = net.send(
                        conn,
                        &Msg::RegisterNack {
                            reason: format!("worker id `{worker_id}` is already registered"),
                        },
                    );
                    continue;
                }
                let rank = peers.len() as u32;
                let ack = Msg::RegisterAck {
                    rank,
                    nonce,
                    nshards,
                    start_step: start_step as u64,
                    steps: cfg.steps as u64,
                    seed: cfg.seed,
                    model: cfg.model.clone(),
                    optimizer: cfg.optimizer.clone(),
                    data: cfg.data.name().to_string(),
                    compress: mode.name().to_string(),
                    precision: cfg.precision.clone(),
                    state: resume_state.clone(),
                };
                if let Err(e) = net.send(conn, &ack) {
                    warnln!("registration ack to `{worker_id}` failed, dropping: {e}");
                    net.drop_conn(conn);
                    continue;
                }
                info!("worker `{worker_id}` registered as rank {rank}");
                peers.push(Peer { conn, id: worker_id, alive: true });
            }
            Event::Frame(conn, Msg::WorkerAbort { reason, .. }) => {
                if let Some(p) = peers.iter_mut().find(|p| p.conn == conn && p.alive) {
                    warnln!("worker `{}` aborted during registration: {reason}", p.id);
                    p.alive = false;
                }
                net.drop_conn(conn);
            }
            Event::Frame(conn, other) => {
                warnln!("conn {conn}: ignoring {} before the roster is complete", other.name());
            }
            Event::Closed(conn) => {
                if let Some(p) = peers.iter_mut().find(|p| p.conn == conn && p.alive) {
                    warnln!("worker `{}` disconnected before the run started", p.id);
                    p.alive = false;
                }
                net.drop_conn(conn);
            }
        }
    }
    Ok(peers)
}

struct Coord<'a> {
    cfg: &'a RunConfig,
    net: Net,
    peers: Vec<Peer>,
    deaths: usize,
    last_abort: Option<String>,
    nshards: u32,
    /// Wire codec of the run (every uplink and downlink chunk uses it).
    mode: Compression,
    /// Per-parameter element counts, learned from the first gather; the
    /// Apply downlink chunks the averaged gradient along this layout.
    layout: Vec<u32>,
}

impl Coord<'_> {
    fn live_ranks(&self) -> Vec<u32> {
        self.peers
            .iter()
            .enumerate()
            .filter(|(_, p)| p.alive)
            .map(|(r, _)| r as u32)
            .collect()
    }

    fn rank_of(&self, conn: u64) -> Option<u32> {
        self.peers.iter().position(|p| p.conn == conn).map(|r| r as u32)
    }

    fn mark_dead(&mut self, rank: u32, why: &str) {
        let p = &mut self.peers[rank as usize];
        if !p.alive {
            return;
        }
        p.alive = false;
        self.deaths += 1;
        warnln!("worker `{}` (rank {rank}) is dead: {why}", p.id);
        self.net.drop_conn(p.conn);
    }

    /// Best-effort send to every live rank; a failed send marks the peer
    /// dead (its shards redistribute at the next gather).
    fn broadcast(&mut self, msg: &Msg) {
        for r in self.live_ranks() {
            if let Err(e) = self.net.send(self.peers[r as usize].conn, msg) {
                self.mark_dead(r, &format!("send failed: {e}"));
            }
        }
    }

    /// Declare dead every live peer silent past `dist.deadline_ms`.
    fn check_deadlines(&mut self) {
        let deadline = Duration::from_millis(self.cfg.dist_deadline_ms.max(100));
        for r in self.live_ranks() {
            let conn = self.peers[r as usize].conn;
            if self.net.last_seen(conn).is_some_and(|t| t.elapsed() > deadline) {
                self.mark_dead(r, "missed heartbeat deadline");
            }
        }
    }

    fn abort_suffix(&self) -> String {
        match &self.last_abort {
            Some(r) => format!(" (last worker abort: {r})"),
            None => String::new(),
        }
    }

    /// Handle an event any phase can receive: late registrations, worker
    /// aborts, closed connections, strays. Returns `true` if the event
    /// killed a live peer — the caller's gather must restart.
    fn handle_background(&mut self, ev: Event) -> bool {
        match ev {
            Event::Frame(conn, Msg::Register { worker_id }) => {
                warnln!("refusing `{worker_id}`: training already in progress");
                let _ = self.net.send(
                    conn,
                    &Msg::RegisterNack {
                        reason: "training already in progress — workers must join \
                                 before the first step"
                            .into(),
                    },
                );
                false
            }
            Event::Frame(conn, Msg::WorkerAbort { reason, .. }) => match self.rank_of(conn) {
                Some(r) if self.peers[r as usize].alive => {
                    self.last_abort = Some(reason.clone());
                    self.mark_dead(r, &format!("aborted: {reason}"));
                    true
                }
                _ => false,
            },
            Event::Frame(conn, other) => {
                warnln!("conn {conn}: ignoring stray {}", other.name());
                false
            }
            Event::Closed(conn) => match self.rank_of(conn) {
                Some(r) if self.peers[r as usize].alive => {
                    self.mark_dead(r, "connection closed");
                    true
                }
                _ => {
                    self.net.drop_conn(conn);
                    false
                }
            },
        }
    }

    /// Run step `step`'s barrier: assign, fold arriving gradient chunks
    /// incrementally, restart on death or timeout. Each `ShardGradChunk`
    /// folds the moment its cross-shard barrier completes, so the
    /// reduction overlaps the workers' remaining backward work instead of
    /// waiting for `workers × flat_len` floats to buffer up. Returns the
    /// reduced metrics and the clipped averaged gradient.
    fn gather_step(&mut self, step: usize) -> anyhow::Result<(StepMetrics, Vec<f32>)> {
        let step64 = step as u64;
        let step_timeout = Duration::from_millis(self.cfg.dist_step_timeout_ms.max(1000));
        let mut resends = 0usize;
        'attempt: loop {
            let live = self.live_ranks();
            anyhow::ensure!(
                !live.is_empty(),
                "all workers dead at step {step}{}",
                self.abort_suffix()
            );
            let assignment = assign_shards(self.nshards, &live);
            for (rank, shards) in &assignment {
                // idle ranks get an empty StepBegin so every replica sees
                // the same step sequence and the Apply protocol check holds
                let msg = Msg::StepBegin { step: step64, shards: shards.clone() };
                if let Err(e) = self.net.send(self.peers[*rank as usize].conn, &msg) {
                    self.mark_dead(*rank, &format!("send failed: {e}"));
                    continue 'attempt;
                }
            }
            // a fresh reducer per attempt: chunks from an earlier attempt
            // of the same step are bit-identical by the determinism
            // contract, so letting them land in the new reducer first is
            // harmless (first one wins per (shard, seq))
            let mut red = ChunkReducer::new(self.nshards as usize, self.mode, CLIP_NORM)?;
            let started = Instant::now();
            loop {
                if let Some(ev) = next_event(&self.net.hub, Duration::from_millis(50)) {
                    match ev {
                        Event::Frame(
                            _,
                            Msg::ShardGradChunk { step: s, shard, seq, total, codec, elems, loss, data },
                        ) => {
                            if s == step64 {
                                red.accept(shard, seq, total, codec, elems, loss, &data)?;
                            } else {
                                warnln!(
                                    "dropping shard gradient chunk for step {s} during step {step64}"
                                );
                            }
                        }
                        ev => {
                            if self.handle_background(ev) {
                                continue 'attempt;
                            }
                        }
                    }
                }
                if red.complete() {
                    self.layout = red.layout().to_vec();
                    return red.finish();
                }
                let deaths = self.deaths;
                self.check_deadlines();
                if self.deaths != deaths {
                    continue 'attempt;
                }
                if started.elapsed() > step_timeout {
                    resends += 1;
                    anyhow::ensure!(
                        resends <= 10,
                        "step {step} stalled: gather incomplete after {resends} \
                         timeouts{}",
                        self.abort_suffix()
                    );
                    warnln!(
                        "step {step}: gather incomplete after {step_timeout:?}, \
                         re-issuing assignments (workers replay from cache)"
                    );
                    continue 'attempt;
                }
            }
        }
    }

    /// Stream the reduced gradient to every live rank as `ApplyChunk`s,
    /// re-chunked along the uplink's parameter layout. Each chunk is
    /// encoded once and written per peer; a failed write marks that peer
    /// dead, the same policy as [`broadcast`](Coord::broadcast). Under
    /// bf16 every rank decodes the identical once-rounded bytes, so the
    /// replicas stay bit-identical.
    fn broadcast_apply_chunks(&mut self, step: u64, avg: &[f32]) {
        let layout = self.layout.clone();
        debug_assert_eq!(layout.iter().map(|&e| e as usize).sum::<usize>(), avg.len());
        let total = layout.len() as u32;
        let mut codec = GradCodec::new(self.mode);
        let mut buf: Vec<u8> = Vec::new();
        let mut off = 0usize;
        for (seq, &elems) in layout.iter().enumerate() {
            let n = elems as usize;
            let mut data = std::mem::take(&mut buf);
            codec.encode_into(&avg[off..off + n], &mut data);
            off += n;
            let msg = Msg::ApplyChunk {
                step,
                seq: seq as u32,
                total,
                codec: self.mode.id(),
                elems,
                data,
            };
            for r in self.live_ranks() {
                if let Err(e) = self.net.send(self.peers[r as usize].conn, &msg) {
                    self.mark_dead(r, &format!("send failed: {e}"));
                }
            }
            if let Msg::ApplyChunk { data, .. } = msg {
                buf = data; // keep the warm buffer for the next chunk
            }
        }
    }

    /// Fetch a full state export from the lowest live rank. Sent after
    /// the step's `Apply` on the same stream, so the worker has applied
    /// the update by the time it serves this. Falls over to the next
    /// live rank if the target dies mid-export.
    fn request_checkpoint(&mut self, label_step: usize) -> anyhow::Result<TrainState> {
        let timeout = Duration::from_millis(self.cfg.dist_step_timeout_ms.max(1000));
        'target: loop {
            let live = self.live_ranks();
            anyhow::ensure!(
                !live.is_empty(),
                "all workers dead before checkpoint step-{label_step}{}",
                self.abort_suffix()
            );
            let target = live[0];
            let conn = self.peers[target as usize].conn;
            if let Err(e) = self.net.send(conn, &Msg::CheckpointRequest { step: label_step as u64 })
            {
                self.mark_dead(target, &format!("send failed: {e}"));
                continue 'target;
            }
            let started = Instant::now();
            loop {
                if let Some(ev) = next_event(&self.net.hub, Duration::from_millis(50)) {
                    match ev {
                        Event::Frame(c, Msg::CheckpointState { state }) if c == conn => {
                            return Ok(state)
                        }
                        Event::Frame(_, Msg::ShardGrads { .. } | Msg::ShardGradChunk { .. }) => {
                            // stale duplicate from the step just committed
                        }
                        ev => {
                            if self.handle_background(ev) && !self.peers[target as usize].alive {
                                continue 'target;
                            }
                        }
                    }
                }
                let deaths = self.deaths;
                self.check_deadlines();
                if self.deaths != deaths && !self.peers[target as usize].alive {
                    continue 'target;
                }
                anyhow::ensure!(
                    started.elapsed() <= timeout,
                    "checkpoint step-{label_step} stalled: rank {target} never \
                     answered the export request"
                );
            }
        }
    }

    fn train(
        &mut self,
        start_step: usize,
        resume_guard: Option<(f64, usize)>,
        t_start: Instant,
    ) -> anyhow::Result<DistResult> {
        let cfg = self.cfg;
        const METRIC_COLUMNS: [&str; 8] = [
            "step", "lr", "loss", "grad_norm", "clipped", "eval_loss", "lr_scale", "skipped",
        ];
        let metrics_path = cfg.out_dir.join("metrics.csv");
        let mut csv = if start_step > 0 && metrics_path.exists() {
            prepare_resumed_csv(&metrics_path, start_step, &METRIC_COLUMNS)?;
            CsvWriter::append(&metrics_path)?
        } else {
            CsvWriter::create(&metrics_path, &METRIC_COLUMNS)?
        };

        let mut guard = StepGuard::new(GuardConfig {
            enabled: cfg.guard,
            backoff: cfg.guard_backoff,
            min_scale: cfg.guard_min_scale,
            recover: cfg.guard_recover,
            max_consecutive: cfg.guard_max_bad.max(1),
            max_grad_norm: cfg.guard_max_grad_norm,
        })?;
        if let Some((scale, bad)) = resume_guard {
            guard.restore(scale, bad);
            if guard.lr_scale() < 1.0 || guard.consecutive_bad() > 0 {
                info!(
                    "guard state restored: lr scale {:.6}, {} consecutive anomalous",
                    guard.lr_scale(),
                    guard.consecutive_bad()
                );
            }
        }

        let mut last_train = f64::NAN;
        let mut clip_sum = 0.0f64;
        for step in start_step..cfg.steps {
            let (metrics, avg) = self.gather_step(step)?;
            // the scale set by step N's anomaly applies from step N+1 —
            // same capture-before-observe order as the single-process loop
            let lr_scale = guard.lr_scale();
            let lr = (lr_at(cfg.schedule, cfg.lr, step, cfg.steps) * lr_scale) as f32;
            let verdict = guard.observe(step, &metrics);
            let apply = verdict == Verdict::Apply;
            // commit point: once this broadcast starts, the step is never
            // replayed (a replay would double-apply momentum on survivors).
            // The header's grads are always empty — the gradient follows
            // as an ApplyChunk stream, and a guard skip sends no chunks
            self.broadcast(&Msg::Apply { step: step as u64, lr, apply, grads: Vec::new() });
            if apply {
                self.broadcast_apply_chunks(step as u64, &avg);
            }
            anyhow::ensure!(
                !self.live_ranks().is_empty(),
                "all workers dead at step {step}{}",
                self.abort_suffix()
            );
            if apply {
                clip_sum += metrics.clipped as f64;
            }
            if metrics.loss.is_finite() {
                last_train = metrics.loss as f64;
            }
            csv.row(&[
                step as f64,
                lr as f64,
                metrics.loss as f64,
                metrics.grad_norm as f64,
                metrics.clipped as f64,
                f64::NAN, // the coordinator holds no model; no eval column
                lr_scale,
                if apply { 0.0 } else { 1.0 },
            ])?;

            if let Err(abort) = guard.check_abort() {
                csv.flush()?;
                append_jsonl(
                    &cfg.out_dir.join("summary.jsonl"),
                    &[
                        ("model", json_str(&cfg.model)),
                        ("optimizer", json_str(&cfg.optimizer)),
                        ("backend", json_str("dist")),
                        ("aborted", "true".into()),
                        ("abort_step", format!("{step}")),
                        ("skipped_steps", format!("{}", guard.skipped())),
                        ("reason", json_str(&abort.to_string())),
                    ],
                )?;
                return Err(abort);
            }

            if cfg.checkpoint_every > 0 && (step + 1) % cfg.checkpoint_every == 0 {
                let mut state = self.request_checkpoint(step + 1)?;
                state.step = (step + 1) as u64;
                guard::stamp_guard(&mut state, &guard);
                checkpoint::save_state(
                    &cfg.out_dir.join(format!("step-{}.ckpt", step + 1)),
                    &state,
                )?;
                if cfg.keep_checkpoints > 0 {
                    if let Err(e) = checkpoint::prune(&cfg.out_dir, cfg.keep_checkpoints) {
                        warnln!("checkpoint prune failed: {e}");
                    }
                }
            }

            if step % 25 == 0 || step + 1 == cfg.steps {
                csv.flush()?;
            }
            if step % 50 == 0 || step + 1 == cfg.steps {
                info!(
                    "[dist/{}/{}] {} step {step}/{} loss {:.4} gnorm {:.3} lr {:.2e} \
                     ({} live)",
                    cfg.model,
                    cfg.optimizer,
                    cfg.data.name(),
                    cfg.steps,
                    metrics.loss,
                    metrics.grad_norm,
                    lr,
                    self.live_ranks().len()
                );
            }
        }
        csv.flush()?;

        let steps_run = cfg.steps - start_step;
        let result = DistResult {
            steps_run,
            deaths: self.deaths,
            skipped_steps: guard.skipped(),
            final_train_loss: last_train,
            seconds: t_start.elapsed().as_secs_f64(),
            workers: cfg.dist_workers,
            shards: self.nshards as usize,
        };
        append_jsonl(
            &cfg.out_dir.join("summary.jsonl"),
            &[
                ("model", json_str(&cfg.model)),
                ("optimizer", json_str(&cfg.optimizer)),
                ("backend", json_str("dist")),
                ("data", json_str(cfg.data.name())),
                ("workers", format!("{}", result.workers)),
                ("shards", format!("{}", result.shards)),
                ("lr", format!("{}", cfg.lr)),
                ("steps", format!("{}", cfg.steps)),
                ("steps_run", format!("{steps_run}")),
                ("deaths", format!("{}", result.deaths)),
                ("skipped_steps", format!("{}", result.skipped_steps)),
                ("guard_min_lr_scale", format!("{}", guard.min_scale_seen())),
                ("clip_rate", format!("{:.4}", clip_sum / steps_run.max(1) as f64)),
                ("final_train_loss", format!("{:.6}", result.final_train_loss)),
                ("seconds", format!("{:.2}", result.seconds)),
            ],
        )?;
        Ok(result)
    }
}
