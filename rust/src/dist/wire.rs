//! Length-prefixed, CRC-guarded binary wire protocol for distributed runs.
//!
//! Every frame on the coordinator/worker TCP link looks like:
//!
//! ```text
//! | len: u32 LE | crc: u32 LE | payload: len bytes |
//! ```
//!
//! where `crc` is the CRC-32 (from [`crate::util::crc32`], zlib-compatible)
//! of the payload alone. The receiver reads the 8-byte header, bounds-checks
//! `len` against [`MAX_FRAME`], reads the payload, and verifies the CRC
//! *before* deserializing anything: a corrupted frame is reported as
//! [`RecvError::Corrupt`] and dropped whole — because the length prefix was
//! already consumed, the stream stays framed and the next frame parses
//! cleanly. Recovery from a dropped frame is step-level (the coordinator
//! re-requests the step), never byte-level.
//!
//! The payload is a [`Msg`], encoded as a one-byte tag followed by its
//! fields in declaration order. Scalars are little-endian; strings are
//! `u32` length + UTF-8 bytes; `Vec<f32>` is `u32` count + LE IEEE-754
//! words, so f32 payloads (gradients, checkpoint buffers) round-trip
//! bit-exactly. No external serialization crate is involved — the crate
//! must keep building offline with vendored deps only.

use std::cell::RefCell;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::runtime::{NamedBuffer, TrainState};
use crate::util::crc32::crc32;

/// Hard cap on a frame's payload length (256 MiB). A header whose length
/// field exceeds this is treated as a protocol error rather than an
/// allocation request — it can only come from a desynced or hostile peer.
pub const MAX_FRAME: u32 = 1 << 28;

/// Why a [`read_msg`] call failed.
#[derive(Debug)]
pub enum RecvError {
    /// The payload failed its CRC-32 check. The frame was dropped before
    /// any deserialization; the stream remains framed and the next
    /// [`read_msg`] call picks up at the next frame boundary.
    Corrupt {
        /// CRC the frame header promised.
        want: u32,
        /// CRC the payload actually hashed to.
        got: u32,
    },
    /// The peer closed the connection (EOF mid-header or mid-payload).
    Closed,
    /// The socket's read timeout elapsed before a complete frame arrived.
    TimedOut,
    /// Any other I/O or decode failure.
    Other(anyhow::Error),
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Corrupt { want, got } => {
                write!(f, "frame CRC mismatch (header {want:#010x}, payload {got:#010x})")
            }
            RecvError::Closed => write!(f, "connection closed by peer"),
            RecvError::TimedOut => write!(f, "read timed out"),
            RecvError::Other(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RecvError {}

/// Every message that crosses the coordinator/worker link.
///
/// The RPC set mirrors a conventional coordinator surface — register,
/// heartbeat, shard assignment, barrier (gather + apply), checkpoint
/// state — flattened onto a symmetric frame stream. Tags are stable wire
/// contract: new messages append, existing tags never change meaning.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Worker → coordinator: request to join the run under a unique id.
    Register {
        /// Caller-chosen worker identity; duplicates are refused.
        worker_id: String,
    },
    /// Coordinator → worker: registration accepted; everything the worker
    /// needs to build its backend and join the step loop.
    RegisterAck {
        /// The worker's rank (index into the coordinator's peer table).
        rank: u32,
        /// Random per-run nonce, also published in the second line of the
        /// coordinator's addr file. A worker launched from an addr file
        /// refuses an ack whose nonce disagrees — so a replica can never
        /// join a *different* run that happens to reuse a stale address.
        nonce: u64,
        /// Total number of data shards in the global batch.
        nshards: u32,
        /// First step the run will execute (0, or the resume point).
        start_step: u64,
        /// Total steps the run will execute.
        steps: u64,
        /// Run seed; shard streams derive from it deterministically.
        seed: u64,
        /// Model tag (e.g. `gpt2_tiny`) the worker must instantiate.
        model: String,
        /// Optimizer registry name.
        optimizer: String,
        /// Data spec name understood by [`crate::config::DataSpec::parse`].
        data: String,
        /// Wire compression mode for gradient chunks, a
        /// [`crate::dist::compress::Compression`] name. Announced once at
        /// registration so both ends agree without per-frame negotiation.
        compress: String,
        /// Parameter/momentum storage precision every rank must use, a
        /// [`crate::tensor::Precision`] name (`f32`/`bf16`). Announced so
        /// replicas stay bit-identical to the coordinator's backend, and
        /// so bf16-stored params compose with `compress = "bf16"` without
        /// a second rounding on the wire.
        precision: String,
        /// On resume: the checkpoint state every worker imports so all
        /// ranks start bit-identical. `None` on a fresh run.
        state: Option<TrainState>,
    },
    /// Coordinator → worker: registration refused (duplicate id, run
    /// already in progress, ...). The worker should exit cleanly.
    RegisterNack {
        /// Human-readable refusal reason.
        reason: String,
    },
    /// Worker → coordinator: one-way liveness beacon, sent on a side
    /// thread every `dist.heartbeat_ms`. Never acknowledged, so the
    /// worker's main read loop stays strictly request/response.
    Heartbeat {
        /// The sender's rank.
        rank: u32,
    },
    /// Coordinator → worker: compute gradients for these shards of this
    /// step. Re-sent verbatim after a peer death or a gather timeout;
    /// workers serve repeats from their shard-batch cache, so the resend
    /// is idempotent.
    StepBegin {
        /// Global step index.
        step: u64,
        /// Shard indices assigned to this worker for this step.
        shards: Vec<u32>,
    },
    /// Worker → coordinator: loss + flat gradient for one shard.
    ShardGrads {
        /// Global step index this gradient belongs to.
        step: u64,
        /// Which shard was computed.
        shard: u32,
        /// Mean loss over the shard's batch.
        loss: f32,
        /// Flattened gradient in the backend's scheduling order.
        grads: Vec<f32>,
    },
    /// Coordinator → worker: the barrier result. Broadcasting this frame
    /// is the step's commit point — after it, the step is never replayed.
    Apply {
        /// Global step index being committed.
        step: u64,
        /// Effective learning rate (schedule × guard scale).
        lr: f32,
        /// `false` when the anomaly guard skipped the step; `grads` is
        /// empty and momentum must not be touched.
        apply: bool,
        /// Clipped, shard-averaged flat gradient (empty on a skip).
        grads: Vec<f32>,
    },
    /// Coordinator → worker: export your state so the coordinator can
    /// write a validated checkpoint. Sent after the step's `Apply` on the
    /// same stream, so TCP ordering guarantees the worker has applied it.
    CheckpointRequest {
        /// Step count the checkpoint will be labeled with.
        step: u64,
    },
    /// Worker → coordinator: the exported state for a
    /// [`Msg::CheckpointRequest`].
    CheckpointState {
        /// Full parameter + optimizer state of the worker's backend.
        state: TrainState,
    },
    /// Worker → coordinator: the worker is aborting (guard trip, protocol
    /// violation, local I/O failure) and wants the coordinator to know
    /// why instead of just vanishing into a heartbeat timeout.
    WorkerAbort {
        /// The sender's rank.
        rank: u32,
        /// Human-readable abort reason, logged by the coordinator.
        reason: String,
    },
    /// Coordinator → worker: the run is over (complete or aborted);
    /// workers exit their loop cleanly.
    Shutdown {
        /// Why the run ended.
        reason: String,
    },
    /// Worker → coordinator: one parameter's gradient for one shard of
    /// one step, sent as soon as backward produces it — the streamed
    /// replacement for [`Msg::ShardGrads`]. Chunks arrive in `seq` order
    /// on each connection (TCP) and the coordinator reduces them
    /// incrementally; on a resend after a death or timeout the worker
    /// replays the full chunk sequence from its shard-batch cache, and
    /// the sequence numbers make the replay idempotent.
    ShardGradChunk {
        /// Global step index this gradient belongs to.
        step: u64,
        /// Which shard was computed.
        shard: u32,
        /// Chunk index within the stream, `0..total` (one per parameter,
        /// in the backend's scheduling order).
        seq: u32,
        /// Total chunks in this shard's stream.
        total: u32,
        /// Codec id ([`crate::dist::compress::Compression::id`]) the
        /// payload is encoded with; must match the run's announced mode.
        codec: u8,
        /// Number of f32 elements encoded in `data`.
        elems: u32,
        /// Mean loss over the shard's batch (same value on every chunk).
        loss: f32,
        /// Codec-encoded gradient elements.
        data: Vec<u8>,
    },
    /// Coordinator → worker: one parameter's slice of the reduced
    /// gradient — the streamed replacement for the [`Msg::Apply`]
    /// payload. The commit-point `Apply` header frame still leads the
    /// stream (carrying `step`/`lr`/`apply` with an empty `grads`);
    /// `total` chunks follow on the same ordered stream.
    ApplyChunk {
        /// Global step index being committed.
        step: u64,
        /// Chunk index within the stream, `0..total`.
        seq: u32,
        /// Total chunks in this step's apply stream.
        total: u32,
        /// Codec id the payload is encoded with.
        codec: u8,
        /// Number of f32 elements encoded in `data`.
        elems: u32,
        /// Codec-encoded reduced-gradient elements.
        data: Vec<u8>,
    },
}

impl Msg {
    /// Short stable name for logs.
    pub fn name(&self) -> &'static str {
        match self {
            Msg::Register { .. } => "Register",
            Msg::RegisterAck { .. } => "RegisterAck",
            Msg::RegisterNack { .. } => "RegisterNack",
            Msg::Heartbeat { .. } => "Heartbeat",
            Msg::StepBegin { .. } => "StepBegin",
            Msg::ShardGrads { .. } => "ShardGrads",
            Msg::Apply { .. } => "Apply",
            Msg::CheckpointRequest { .. } => "CheckpointRequest",
            Msg::CheckpointState { .. } => "CheckpointState",
            Msg::WorkerAbort { .. } => "WorkerAbort",
            Msg::Shutdown { .. } => "Shutdown",
            Msg::ShardGradChunk { .. } => "ShardGradChunk",
            Msg::ApplyChunk { .. } => "ApplyChunk",
        }
    }

    /// Serialize to a fresh payload buffer (no frame header). The send
    /// path uses [`Msg::encode_into`] to reuse a scratch buffer; this
    /// wrapper exists for tests and one-shot callers.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        self.encode_into(&mut out);
        out
    }

    /// Serialize to a payload (no frame header), appending to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let mut e = Enc(out);
        match self {
            Msg::Register { worker_id } => {
                e.u8(1);
                e.str(worker_id);
            }
            Msg::RegisterAck {
                rank,
                nonce,
                nshards,
                start_step,
                steps,
                seed,
                model,
                optimizer,
                data,
                compress,
                precision,
                state,
            } => {
                e.u8(2);
                e.u32(*rank);
                e.u64(*nonce);
                e.u32(*nshards);
                e.u64(*start_step);
                e.u64(*steps);
                e.u64(*seed);
                e.str(model);
                e.str(optimizer);
                e.str(data);
                e.str(compress);
                e.str(precision);
                match state {
                    None => e.u8(0),
                    Some(st) => {
                        e.u8(1);
                        e.state(st);
                    }
                }
            }
            Msg::RegisterNack { reason } => {
                e.u8(3);
                e.str(reason);
            }
            Msg::Heartbeat { rank } => {
                e.u8(4);
                e.u32(*rank);
            }
            Msg::StepBegin { step, shards } => {
                e.u8(5);
                e.u64(*step);
                e.u32(shards.len() as u32);
                for &s in shards {
                    e.u32(s);
                }
            }
            Msg::ShardGrads { step, shard, loss, grads } => {
                e.u8(6);
                e.u64(*step);
                e.u32(*shard);
                e.f32(*loss);
                e.f32s(grads);
            }
            Msg::Apply { step, lr, apply, grads } => {
                e.u8(7);
                e.u64(*step);
                e.f32(*lr);
                e.u8(u8::from(*apply));
                e.f32s(grads);
            }
            Msg::CheckpointRequest { step } => {
                e.u8(8);
                e.u64(*step);
            }
            Msg::CheckpointState { state } => {
                e.u8(9);
                e.state(state);
            }
            Msg::WorkerAbort { rank, reason } => {
                e.u8(10);
                e.u32(*rank);
                e.str(reason);
            }
            Msg::Shutdown { reason } => {
                e.u8(11);
                e.str(reason);
            }
            Msg::ShardGradChunk { step, shard, seq, total, codec, elems, loss, data } => {
                e.u8(12);
                e.u64(*step);
                e.u32(*shard);
                e.u32(*seq);
                e.u32(*total);
                e.u8(*codec);
                e.u32(*elems);
                e.f32(*loss);
                e.bytes(data);
            }
            Msg::ApplyChunk { step, seq, total, codec, elems, data } => {
                e.u8(13);
                e.u64(*step);
                e.u32(*seq);
                e.u32(*total);
                e.u8(*codec);
                e.u32(*elems);
                e.bytes(data);
            }
        }
    }

    /// Deserialize a payload produced by [`Msg::encode`]. Fails on unknown
    /// tags, truncated fields, or trailing bytes — a CRC-valid frame that
    /// still fails here indicates a protocol-version mismatch, not line
    /// noise.
    pub fn decode(payload: &[u8]) -> anyhow::Result<Msg> {
        let mut d = Dec { buf: payload, pos: 0 };
        let tag = d.u8()?;
        let msg = match tag {
            1 => Msg::Register { worker_id: d.str()? },
            2 => {
                let rank = d.u32()?;
                let nonce = d.u64()?;
                let nshards = d.u32()?;
                let start_step = d.u64()?;
                let steps = d.u64()?;
                let seed = d.u64()?;
                let model = d.str()?;
                let optimizer = d.str()?;
                let data = d.str()?;
                let compress = d.str()?;
                let precision = d.str()?;
                let state = match d.u8()? {
                    0 => None,
                    1 => Some(d.state()?),
                    other => anyhow::bail!("bad Option tag {other} in RegisterAck"),
                };
                Msg::RegisterAck {
                    rank,
                    nonce,
                    nshards,
                    start_step,
                    steps,
                    seed,
                    model,
                    optimizer,
                    data,
                    compress,
                    precision,
                    state,
                }
            }
            3 => Msg::RegisterNack { reason: d.str()? },
            4 => Msg::Heartbeat { rank: d.u32()? },
            5 => {
                let step = d.u64()?;
                let n = d.u32()? as usize;
                let mut shards = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    shards.push(d.u32()?);
                }
                Msg::StepBegin { step, shards }
            }
            6 => Msg::ShardGrads {
                step: d.u64()?,
                shard: d.u32()?,
                loss: d.f32()?,
                grads: d.f32s()?,
            },
            7 => Msg::Apply {
                step: d.u64()?,
                lr: d.f32()?,
                apply: d.u8()? != 0,
                grads: d.f32s()?,
            },
            8 => Msg::CheckpointRequest { step: d.u64()? },
            9 => Msg::CheckpointState { state: d.state()? },
            10 => Msg::WorkerAbort { rank: d.u32()?, reason: d.str()? },
            11 => Msg::Shutdown { reason: d.str()? },
            12 => Msg::ShardGradChunk {
                step: d.u64()?,
                shard: d.u32()?,
                seq: d.u32()?,
                total: d.u32()?,
                codec: d.u8()?,
                elems: d.u32()?,
                loss: d.f32()?,
                data: d.bytes()?,
            },
            13 => Msg::ApplyChunk {
                step: d.u64()?,
                seq: d.u32()?,
                total: d.u32()?,
                codec: d.u8()?,
                elems: d.u32()?,
                data: d.bytes()?,
            },
            other => anyhow::bail!("unknown message tag {other}"),
        };
        d.finish()?;
        Ok(msg)
    }
}

static WIRE_BYTES: AtomicU64 = AtomicU64::new(0);

/// Total framed bytes (headers included) written by this process since
/// start, across every connection and both protocol roles — an
/// in-process coordinator+worker run counts both directions. Benches
/// read before/after deltas of this to report wire bytes per step.
pub fn bytes_written() -> u64 {
    WIRE_BYTES.load(Ordering::Relaxed)
}

/// Write one framed message and flush it.
///
/// The frame is staged in a per-thread scratch buffer (header
/// placeholder, payload, then the length/CRC backfilled) so the warm
/// send path performs zero heap allocations once the buffer has grown to
/// the connection's largest frame.
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> anyhow::Result<()> {
    thread_local! {
        static FRAME: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
    }
    FRAME.with(|cell| -> anyhow::Result<()> {
        let mut frame = cell.borrow_mut();
        frame.clear();
        frame.extend_from_slice(&[0u8; 8]);
        msg.encode_into(&mut frame);
        let plen = frame.len() - 8;
        anyhow::ensure!(
            plen <= MAX_FRAME as usize,
            "{} payload of {plen} bytes exceeds the {MAX_FRAME} byte frame cap",
            msg.name(),
        );
        let crc = crc32(&frame[8..]);
        frame[0..4].copy_from_slice(&(plen as u32).to_le_bytes());
        frame[4..8].copy_from_slice(&crc.to_le_bytes());
        w.write_all(&frame)?;
        w.flush()?;
        WIRE_BYTES.fetch_add(frame.len() as u64, Ordering::Relaxed);
        Ok(())
    })
}

/// Read one framed message, verifying length bounds and the CRC before
/// deserialization. See [`RecvError`] for the failure taxonomy.
pub fn read_msg(r: &mut impl Read) -> Result<Msg, RecvError> {
    let mut head = [0u8; 8];
    read_exact_or(r, &mut head)?;
    let len = u32::from_le_bytes(head[0..4].try_into().expect("4-byte slice"));
    let want = u32::from_le_bytes(head[4..8].try_into().expect("4-byte slice"));
    if len > MAX_FRAME {
        return Err(RecvError::Other(anyhow::anyhow!(
            "frame length {len} exceeds the {MAX_FRAME} byte cap — peer desynced?"
        )));
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_or(r, &mut payload)?;
    let got = crc32(&payload);
    if got != want {
        return Err(RecvError::Corrupt { want, got });
    }
    Msg::decode(&payload).map_err(RecvError::Other)
}

fn read_exact_or(r: &mut impl Read, buf: &mut [u8]) -> Result<(), RecvError> {
    r.read_exact(buf).map_err(|e| match e.kind() {
        std::io::ErrorKind::UnexpectedEof => RecvError::Closed,
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => RecvError::TimedOut,
        _ => RecvError::Other(e.into()),
    })
}

/// Little-endian field writer; all multi-byte scalars go through here so
/// the wire layout is defined in exactly one place.
struct Enc<'a>(&'a mut Vec<u8>);

impl Enc<'_> {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.0.extend_from_slice(b);
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        self.0.reserve(xs.len() * 4);
        for &x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn state(&mut self, st: &TrainState) {
        self.u64(st.step);
        self.buffers(&st.params);
        self.buffers(&st.opt);
    }
    fn buffers(&mut self, bufs: &[NamedBuffer]) {
        self.u32(bufs.len() as u32);
        for b in bufs {
            self.str(&b.name);
            self.f32s(&b.data);
        }
    }
}

/// Bounds-checked little-endian field reader over a payload slice.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Dec<'_> {
    fn take(&mut self, n: usize) -> anyhow::Result<&[u8]> {
        anyhow::ensure!(
            n <= self.buf.len() - self.pos,
            "truncated payload: wanted {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }
    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }
    fn str(&mut self) -> anyhow::Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("invalid UTF-8 in string field: {e}"))?
            .to_string())
    }
    fn bytes(&mut self) -> anyhow::Result<Vec<u8>> {
        let n = self.u32()? as usize;
        // bounds-checked before allocation, like `f32s`
        Ok(self.take(n)?.to_vec())
    }
    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        // bounds-check the count against the remaining bytes *before*
        // allocating, so a corrupt count can't request a huge Vec
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")))
            .collect())
    }
    fn state(&mut self) -> anyhow::Result<TrainState> {
        let step = self.u64()?;
        let params = self.buffers()?;
        let opt = self.buffers()?;
        Ok(TrainState { step, params, opt })
    }
    fn buffers(&mut self) -> anyhow::Result<Vec<NamedBuffer>> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n <= 1 << 20, "implausible buffer count {n}");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let name = self.str()?;
            let data = self.f32s()?;
            out.push(NamedBuffer { name, data });
        }
        Ok(out)
    }
    fn finish(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "{} trailing bytes after message payload",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> TrainState {
        TrainState {
            step: 42,
            params: vec![
                NamedBuffer { name: "embed".into(), data: vec![1.0, -2.5, f32::MIN_POSITIVE] },
                NamedBuffer { name: "head".into(), data: vec![] },
            ],
            opt: vec![NamedBuffer {
                name: "embed.momentum".into(),
                data: vec![0.5, f32::from_bits(7)],
            }],
        }
    }

    fn all_variants() -> Vec<Msg> {
        vec![
            Msg::Register { worker_id: "w-1".into() },
            Msg::RegisterAck {
                rank: 3,
                nonce: 0x1234_5678_9ABC_DEF0,
                nshards: 8,
                start_step: 12,
                steps: 100,
                seed: 0xDEAD_BEEF,
                model: "gpt2_tiny".into(),
                optimizer: "rmnp".into(),
                data: "synthetic".into(),
                compress: "bf16".into(),
                precision: "bf16".into(),
                state: Some(sample_state()),
            },
            Msg::RegisterAck {
                rank: 0,
                nonce: 0,
                nshards: 1,
                start_step: 0,
                steps: 10,
                seed: 1,
                model: "m".into(),
                optimizer: "o".into(),
                data: "d".into(),
                compress: "none".into(),
                precision: "f32".into(),
                state: None,
            },
            Msg::RegisterNack { reason: "training already in progress".into() },
            Msg::Heartbeat { rank: 7 },
            Msg::StepBegin { step: 5, shards: vec![0, 2, 4] },
            Msg::ShardGrads { step: 5, shard: 2, loss: 3.25, grads: vec![0.0, -1.0, f32::NAN] },
            Msg::Apply { step: 5, lr: 1e-3, apply: true, grads: vec![0.125; 9] },
            Msg::Apply { step: 6, lr: 5e-4, apply: false, grads: vec![] },
            Msg::CheckpointRequest { step: 6 },
            Msg::CheckpointState { state: sample_state() },
            Msg::WorkerAbort { rank: 1, reason: "guard abort".into() },
            Msg::Shutdown { reason: "run complete".into() },
            Msg::ShardGradChunk {
                step: 7,
                shard: 1,
                seq: 2,
                total: 3,
                codec: 1,
                elems: 2,
                loss: 1.5,
                data: vec![0xC0, 0x3F, 0x00, 0xBF],
            },
            Msg::ShardGradChunk {
                step: 0,
                shard: 0,
                seq: 0,
                total: 1,
                codec: 0,
                elems: 0,
                loss: f32::NAN,
                data: vec![],
            },
            Msg::ApplyChunk {
                step: 7,
                seq: 0,
                total: 2,
                codec: 0,
                elems: 1,
                data: 1.0f32.to_le_bytes().to_vec(),
            },
        ]
    }

    /// NaN != NaN, so compare through bits for the gradient-bearing arms.
    fn bits(m: &Msg) -> Vec<u8> {
        m.encode()
    }

    #[test]
    fn every_variant_roundtrips_through_a_frame() {
        for msg in all_variants() {
            let mut buf = Vec::new();
            write_msg(&mut buf, &msg).unwrap();
            let mut cursor = &buf[..];
            let back = read_msg(&mut cursor).unwrap();
            assert_eq!(bits(&back), bits(&msg), "roundtrip mismatch for {}", msg.name());
            assert!(cursor.is_empty(), "frame for {} left trailing bytes", msg.name());
        }
    }

    #[test]
    fn golden_heartbeat_frame_bytes() {
        // Locks the layout: len=5 LE, crc32(payload) LE, then payload =
        // tag 4 + rank 7 LE. The expected bytes (CRC 0xAE756964) were
        // computed with an independent zlib implementation, so this test
        // pins the wire format itself, not just self-consistency.
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Heartbeat { rank: 7 }).unwrap();
        assert_eq!(buf, [5, 0, 0, 0, 0x64, 0x69, 0x75, 0xAE, 4, 7, 0, 0, 0]);
    }

    #[test]
    fn golden_chunk_frame_bytes() {
        // Locks the chunk layouts against python/gen_wire_golden.py
        // (struct-packed fields + an independent zlib CRC-32) — pins the
        // wire format itself, not just self-consistency. The data bytes
        // are bf16(1.5), bf16(-0.5) for the uplink and f32 1.0 for the
        // downlink.
        let mut buf = Vec::new();
        write_msg(
            &mut buf,
            &Msg::ShardGradChunk {
                step: 7,
                shard: 1,
                seq: 2,
                total: 3,
                codec: 1,
                elems: 2,
                loss: 1.5,
                data: vec![0xC0, 0x3F, 0x00, 0xBF],
            },
        )
        .unwrap();
        assert_eq!(
            buf,
            [
                0x26, 0x00, 0x00, 0x00, 0xE5, 0x8B, 0xBA, 0xC7, 0x0C, 0x07, 0x00, 0x00, 0x00,
                0x00, 0x00, 0x00, 0x00, 0x01, 0x00, 0x00, 0x00, 0x02, 0x00, 0x00, 0x00, 0x03,
                0x00, 0x00, 0x00, 0x01, 0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0xC0, 0x3F, 0x04,
                0x00, 0x00, 0x00, 0xC0, 0x3F, 0x00, 0xBF
            ]
        );

        let mut buf = Vec::new();
        write_msg(
            &mut buf,
            &Msg::ApplyChunk {
                step: 7,
                seq: 0,
                total: 2,
                codec: 0,
                elems: 1,
                data: 1.0f32.to_le_bytes().to_vec(),
            },
        )
        .unwrap();
        assert_eq!(
            buf,
            [
                0x1E, 0x00, 0x00, 0x00, 0x05, 0x21, 0xC1, 0x41, 0x0D, 0x07, 0x00, 0x00, 0x00,
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x02, 0x00, 0x00, 0x00, 0x00,
                0x01, 0x00, 0x00, 0x00, 0x04, 0x00, 0x00, 0x00, 0x00, 0x00, 0x80, 0x3F
            ]
        );
    }

    #[test]
    fn wire_byte_counter_advances_by_whole_frames() {
        let before = bytes_written();
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Heartbeat { rank: 0 }).unwrap();
        write_msg(&mut buf, &Msg::CheckpointRequest { step: 1 }).unwrap();
        // other tests run concurrently, so the counter may advance by
        // more than our own frames — but never by less
        assert!(bytes_written() >= before + buf.len() as u64);
    }

    #[test]
    fn corrupt_chunk_mid_stream_drops_only_that_chunk() {
        // a chunk stream with a corrupted middle frame: the reader
        // reports Corrupt for it and the following chunks still parse —
        // recovery is the coordinator's step-level resend, not byte-level
        let chunk = |seq: u32| Msg::ShardGradChunk {
            step: 3,
            shard: 0,
            seq,
            total: 3,
            codec: 0,
            elems: 1,
            data: 2.0f32.to_le_bytes().to_vec(),
            loss: 0.25,
        };
        let mut buf = Vec::new();
        let mut ends = Vec::new();
        for seq in 0..3 {
            write_msg(&mut buf, &chunk(seq)).unwrap();
            ends.push(buf.len());
        }
        buf[ends[1] - 2] ^= 0x01; // flip a data byte inside chunk 1

        let mut cursor = &buf[..];
        assert!(matches!(read_msg(&mut cursor), Ok(Msg::ShardGradChunk { seq: 0, .. })));
        assert!(matches!(read_msg(&mut cursor), Err(RecvError::Corrupt { .. })));
        match read_msg(&mut cursor).unwrap() {
            Msg::ShardGradChunk { seq, data, .. } => {
                assert_eq!(seq, 2);
                assert_eq!(data, 2.0f32.to_le_bytes());
            }
            other => panic!("wanted chunk 2, got {}", other.name()),
        }
    }

    #[test]
    fn truncated_chunk_stream_reports_closed_at_every_cut() {
        // a peer dying mid-chunk-stream must surface as Closed on the
        // partial frame, after the intact prefix parsed normally
        let mut buf = Vec::new();
        for seq in 0..2 {
            write_msg(
                &mut buf,
                &Msg::ShardGradChunk {
                    step: 1,
                    shard: 0,
                    seq,
                    total: 2,
                    codec: 1,
                    elems: 2,
                    loss: 1.0,
                    data: vec![0x80, 0x3F, 0x00, 0xC0],
                },
            )
            .unwrap();
        }
        let first = buf.len() / 2;
        for cut in [first + 1, first + 8, buf.len() - 1] {
            let mut cursor = &buf[..cut];
            assert!(
                matches!(read_msg(&mut cursor), Ok(Msg::ShardGradChunk { seq: 0, .. })),
                "cut {cut}: intact first chunk must parse"
            );
            match read_msg(&mut cursor) {
                Err(RecvError::Closed) => {}
                other => panic!("cut {cut}: wanted Closed, got {other:?}"),
            }
        }
    }

    #[test]
    fn corrupt_frame_is_dropped_and_the_next_frame_parses() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Heartbeat { rank: 1 }).unwrap();
        let first_len = buf.len();
        write_msg(&mut buf, &Msg::Shutdown { reason: "after the bad frame".into() }).unwrap();
        buf[first_len - 1] ^= 0x40; // flip a payload bit of frame 1

        let mut cursor = &buf[..];
        match read_msg(&mut cursor) {
            Err(RecvError::Corrupt { want, got }) => assert_ne!(want, got),
            other => panic!("wanted Corrupt, got {other:?}"),
        }
        // the stream stayed framed: the very next read yields frame 2
        match read_msg(&mut cursor).unwrap() {
            Msg::Shutdown { reason } => assert_eq!(reason, "after the bad frame"),
            other => panic!("wanted Shutdown, got {}", other.name()),
        }
    }

    #[test]
    fn truncated_stream_reports_closed() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::CheckpointRequest { step: 9 }).unwrap();
        for cut in [0, 3, 8, buf.len() - 1] {
            let mut cursor = &buf[..cut];
            match read_msg(&mut cursor) {
                Err(RecvError::Closed) => {}
                other => panic!("cut at {cut}: wanted Closed, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_length_field_is_refused_without_allocating() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        let mut cursor = &buf[..];
        match read_msg(&mut cursor) {
            Err(RecvError::Other(e)) => assert!(e.to_string().contains("frame length")),
            other => panic!("wanted Other, got {other:?}"),
        }
    }

    #[test]
    fn unknown_tag_and_trailing_bytes_are_decode_errors() {
        assert!(Msg::decode(&[200]).is_err());
        let mut payload = Msg::Heartbeat { rank: 0 }.encode();
        payload.push(0);
        assert!(Msg::decode(&payload).is_err());
    }

    #[test]
    fn truncated_f32_count_cannot_trigger_a_huge_allocation() {
        // ShardGrads claiming u32::MAX floats in a 30-byte payload must
        // fail the bounds check, not attempt a 16 GiB Vec.
        let mut e = Vec::new();
        e.push(6u8);
        e.extend_from_slice(&1u64.to_le_bytes());
        e.extend_from_slice(&0u32.to_le_bytes());
        e.extend_from_slice(&1.0f32.to_le_bytes());
        e.extend_from_slice(&u32::MAX.to_le_bytes()); // grad count
        assert!(Msg::decode(&e).is_err());
    }
}
