//! Length-prefixed, CRC-guarded binary wire protocol for distributed runs.
//!
//! Every frame on the coordinator/worker TCP link looks like:
//!
//! ```text
//! | len: u32 LE | crc: u32 LE | payload: len bytes |
//! ```
//!
//! where `crc` is the CRC-32 (from [`crate::util::crc32`], zlib-compatible)
//! of the payload alone. The receiver reads the 8-byte header, bounds-checks
//! `len` against [`MAX_FRAME`], reads the payload, and verifies the CRC
//! *before* deserializing anything: a corrupted frame is reported as
//! [`RecvError::Corrupt`] and dropped whole — because the length prefix was
//! already consumed, the stream stays framed and the next frame parses
//! cleanly. Recovery from a dropped frame is step-level (the coordinator
//! re-requests the step), never byte-level.
//!
//! The payload is a [`Msg`], encoded as a one-byte tag followed by its
//! fields in declaration order. Scalars are little-endian; strings are
//! `u32` length + UTF-8 bytes; `Vec<f32>` is `u32` count + LE IEEE-754
//! words, so f32 payloads (gradients, checkpoint buffers) round-trip
//! bit-exactly. No external serialization crate is involved — the crate
//! must keep building offline with vendored deps only.

use std::io::{Read, Write};

use crate::runtime::{NamedBuffer, TrainState};
use crate::util::crc32::crc32;

/// Hard cap on a frame's payload length (256 MiB). A header whose length
/// field exceeds this is treated as a protocol error rather than an
/// allocation request — it can only come from a desynced or hostile peer.
pub const MAX_FRAME: u32 = 1 << 28;

/// Why a [`read_msg`] call failed.
#[derive(Debug)]
pub enum RecvError {
    /// The payload failed its CRC-32 check. The frame was dropped before
    /// any deserialization; the stream remains framed and the next
    /// [`read_msg`] call picks up at the next frame boundary.
    Corrupt {
        /// CRC the frame header promised.
        want: u32,
        /// CRC the payload actually hashed to.
        got: u32,
    },
    /// The peer closed the connection (EOF mid-header or mid-payload).
    Closed,
    /// The socket's read timeout elapsed before a complete frame arrived.
    TimedOut,
    /// Any other I/O or decode failure.
    Other(anyhow::Error),
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Corrupt { want, got } => {
                write!(f, "frame CRC mismatch (header {want:#010x}, payload {got:#010x})")
            }
            RecvError::Closed => write!(f, "connection closed by peer"),
            RecvError::TimedOut => write!(f, "read timed out"),
            RecvError::Other(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RecvError {}

/// Every message that crosses the coordinator/worker link.
///
/// The RPC set mirrors a conventional coordinator surface — register,
/// heartbeat, shard assignment, barrier (gather + apply), checkpoint
/// state — flattened onto a symmetric frame stream. Tags are stable wire
/// contract: new messages append, existing tags never change meaning.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Worker → coordinator: request to join the run under a unique id.
    Register {
        /// Caller-chosen worker identity; duplicates are refused.
        worker_id: String,
    },
    /// Coordinator → worker: registration accepted; everything the worker
    /// needs to build its backend and join the step loop.
    RegisterAck {
        /// The worker's rank (index into the coordinator's peer table).
        rank: u32,
        /// Total number of data shards in the global batch.
        nshards: u32,
        /// First step the run will execute (0, or the resume point).
        start_step: u64,
        /// Total steps the run will execute.
        steps: u64,
        /// Run seed; shard streams derive from it deterministically.
        seed: u64,
        /// Model tag (e.g. `gpt2_tiny`) the worker must instantiate.
        model: String,
        /// Optimizer registry name.
        optimizer: String,
        /// Data spec name understood by [`crate::config::DataSpec::parse`].
        data: String,
        /// On resume: the checkpoint state every worker imports so all
        /// ranks start bit-identical. `None` on a fresh run.
        state: Option<TrainState>,
    },
    /// Coordinator → worker: registration refused (duplicate id, run
    /// already in progress, ...). The worker should exit cleanly.
    RegisterNack {
        /// Human-readable refusal reason.
        reason: String,
    },
    /// Worker → coordinator: one-way liveness beacon, sent on a side
    /// thread every `dist.heartbeat_ms`. Never acknowledged, so the
    /// worker's main read loop stays strictly request/response.
    Heartbeat {
        /// The sender's rank.
        rank: u32,
    },
    /// Coordinator → worker: compute gradients for these shards of this
    /// step. Re-sent verbatim after a peer death or a gather timeout;
    /// workers serve repeats from their shard-batch cache, so the resend
    /// is idempotent.
    StepBegin {
        /// Global step index.
        step: u64,
        /// Shard indices assigned to this worker for this step.
        shards: Vec<u32>,
    },
    /// Worker → coordinator: loss + flat gradient for one shard.
    ShardGrads {
        /// Global step index this gradient belongs to.
        step: u64,
        /// Which shard was computed.
        shard: u32,
        /// Mean loss over the shard's batch.
        loss: f32,
        /// Flattened gradient in the backend's scheduling order.
        grads: Vec<f32>,
    },
    /// Coordinator → worker: the barrier result. Broadcasting this frame
    /// is the step's commit point — after it, the step is never replayed.
    Apply {
        /// Global step index being committed.
        step: u64,
        /// Effective learning rate (schedule × guard scale).
        lr: f32,
        /// `false` when the anomaly guard skipped the step; `grads` is
        /// empty and momentum must not be touched.
        apply: bool,
        /// Clipped, shard-averaged flat gradient (empty on a skip).
        grads: Vec<f32>,
    },
    /// Coordinator → worker: export your state so the coordinator can
    /// write a validated checkpoint. Sent after the step's `Apply` on the
    /// same stream, so TCP ordering guarantees the worker has applied it.
    CheckpointRequest {
        /// Step count the checkpoint will be labeled with.
        step: u64,
    },
    /// Worker → coordinator: the exported state for a
    /// [`Msg::CheckpointRequest`].
    CheckpointState {
        /// Full parameter + optimizer state of the worker's backend.
        state: TrainState,
    },
    /// Worker → coordinator: the worker is aborting (guard trip, protocol
    /// violation, local I/O failure) and wants the coordinator to know
    /// why instead of just vanishing into a heartbeat timeout.
    WorkerAbort {
        /// The sender's rank.
        rank: u32,
        /// Human-readable abort reason, logged by the coordinator.
        reason: String,
    },
    /// Coordinator → worker: the run is over (complete or aborted);
    /// workers exit their loop cleanly.
    Shutdown {
        /// Why the run ended.
        reason: String,
    },
}

impl Msg {
    /// Short stable name for logs.
    pub fn name(&self) -> &'static str {
        match self {
            Msg::Register { .. } => "Register",
            Msg::RegisterAck { .. } => "RegisterAck",
            Msg::RegisterNack { .. } => "RegisterNack",
            Msg::Heartbeat { .. } => "Heartbeat",
            Msg::StepBegin { .. } => "StepBegin",
            Msg::ShardGrads { .. } => "ShardGrads",
            Msg::Apply { .. } => "Apply",
            Msg::CheckpointRequest { .. } => "CheckpointRequest",
            Msg::CheckpointState { .. } => "CheckpointState",
            Msg::WorkerAbort { .. } => "WorkerAbort",
            Msg::Shutdown { .. } => "Shutdown",
        }
    }

    /// Serialize to a payload (no frame header).
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc(Vec::with_capacity(64));
        match self {
            Msg::Register { worker_id } => {
                e.u8(1);
                e.str(worker_id);
            }
            Msg::RegisterAck {
                rank,
                nshards,
                start_step,
                steps,
                seed,
                model,
                optimizer,
                data,
                state,
            } => {
                e.u8(2);
                e.u32(*rank);
                e.u32(*nshards);
                e.u64(*start_step);
                e.u64(*steps);
                e.u64(*seed);
                e.str(model);
                e.str(optimizer);
                e.str(data);
                match state {
                    None => e.u8(0),
                    Some(st) => {
                        e.u8(1);
                        e.state(st);
                    }
                }
            }
            Msg::RegisterNack { reason } => {
                e.u8(3);
                e.str(reason);
            }
            Msg::Heartbeat { rank } => {
                e.u8(4);
                e.u32(*rank);
            }
            Msg::StepBegin { step, shards } => {
                e.u8(5);
                e.u64(*step);
                e.u32(shards.len() as u32);
                for &s in shards {
                    e.u32(s);
                }
            }
            Msg::ShardGrads { step, shard, loss, grads } => {
                e.u8(6);
                e.u64(*step);
                e.u32(*shard);
                e.f32(*loss);
                e.f32s(grads);
            }
            Msg::Apply { step, lr, apply, grads } => {
                e.u8(7);
                e.u64(*step);
                e.f32(*lr);
                e.u8(u8::from(*apply));
                e.f32s(grads);
            }
            Msg::CheckpointRequest { step } => {
                e.u8(8);
                e.u64(*step);
            }
            Msg::CheckpointState { state } => {
                e.u8(9);
                e.state(state);
            }
            Msg::WorkerAbort { rank, reason } => {
                e.u8(10);
                e.u32(*rank);
                e.str(reason);
            }
            Msg::Shutdown { reason } => {
                e.u8(11);
                e.str(reason);
            }
        }
        e.0
    }

    /// Deserialize a payload produced by [`Msg::encode`]. Fails on unknown
    /// tags, truncated fields, or trailing bytes — a CRC-valid frame that
    /// still fails here indicates a protocol-version mismatch, not line
    /// noise.
    pub fn decode(payload: &[u8]) -> anyhow::Result<Msg> {
        let mut d = Dec { buf: payload, pos: 0 };
        let tag = d.u8()?;
        let msg = match tag {
            1 => Msg::Register { worker_id: d.str()? },
            2 => {
                let rank = d.u32()?;
                let nshards = d.u32()?;
                let start_step = d.u64()?;
                let steps = d.u64()?;
                let seed = d.u64()?;
                let model = d.str()?;
                let optimizer = d.str()?;
                let data = d.str()?;
                let state = match d.u8()? {
                    0 => None,
                    1 => Some(d.state()?),
                    other => anyhow::bail!("bad Option tag {other} in RegisterAck"),
                };
                Msg::RegisterAck {
                    rank,
                    nshards,
                    start_step,
                    steps,
                    seed,
                    model,
                    optimizer,
                    data,
                    state,
                }
            }
            3 => Msg::RegisterNack { reason: d.str()? },
            4 => Msg::Heartbeat { rank: d.u32()? },
            5 => {
                let step = d.u64()?;
                let n = d.u32()? as usize;
                let mut shards = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    shards.push(d.u32()?);
                }
                Msg::StepBegin { step, shards }
            }
            6 => Msg::ShardGrads {
                step: d.u64()?,
                shard: d.u32()?,
                loss: d.f32()?,
                grads: d.f32s()?,
            },
            7 => Msg::Apply {
                step: d.u64()?,
                lr: d.f32()?,
                apply: d.u8()? != 0,
                grads: d.f32s()?,
            },
            8 => Msg::CheckpointRequest { step: d.u64()? },
            9 => Msg::CheckpointState { state: d.state()? },
            10 => Msg::WorkerAbort { rank: d.u32()?, reason: d.str()? },
            11 => Msg::Shutdown { reason: d.str()? },
            other => anyhow::bail!("unknown message tag {other}"),
        };
        d.finish()?;
        Ok(msg)
    }
}

/// Write one framed message and flush it.
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> anyhow::Result<()> {
    let payload = msg.encode();
    anyhow::ensure!(
        payload.len() <= MAX_FRAME as usize,
        "{} payload of {} bytes exceeds the {} byte frame cap",
        msg.name(),
        payload.len(),
        MAX_FRAME
    );
    let mut frame = Vec::with_capacity(payload.len() + 8);
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&crc32(&payload).to_le_bytes());
    frame.extend_from_slice(&payload);
    w.write_all(&frame)?;
    w.flush()?;
    Ok(())
}

/// Read one framed message, verifying length bounds and the CRC before
/// deserialization. See [`RecvError`] for the failure taxonomy.
pub fn read_msg(r: &mut impl Read) -> Result<Msg, RecvError> {
    let mut head = [0u8; 8];
    read_exact_or(r, &mut head)?;
    let len = u32::from_le_bytes(head[0..4].try_into().expect("4-byte slice"));
    let want = u32::from_le_bytes(head[4..8].try_into().expect("4-byte slice"));
    if len > MAX_FRAME {
        return Err(RecvError::Other(anyhow::anyhow!(
            "frame length {len} exceeds the {MAX_FRAME} byte cap — peer desynced?"
        )));
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_or(r, &mut payload)?;
    let got = crc32(&payload);
    if got != want {
        return Err(RecvError::Corrupt { want, got });
    }
    Msg::decode(&payload).map_err(RecvError::Other)
}

fn read_exact_or(r: &mut impl Read, buf: &mut [u8]) -> Result<(), RecvError> {
    r.read_exact(buf).map_err(|e| match e.kind() {
        std::io::ErrorKind::UnexpectedEof => RecvError::Closed,
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => RecvError::TimedOut,
        _ => RecvError::Other(e.into()),
    })
}

/// Little-endian field writer; all multi-byte scalars go through here so
/// the wire layout is defined in exactly one place.
struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        self.0.reserve(xs.len() * 4);
        for &x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn state(&mut self, st: &TrainState) {
        self.u64(st.step);
        self.buffers(&st.params);
        self.buffers(&st.opt);
    }
    fn buffers(&mut self, bufs: &[NamedBuffer]) {
        self.u32(bufs.len() as u32);
        for b in bufs {
            self.str(&b.name);
            self.f32s(&b.data);
        }
    }
}

/// Bounds-checked little-endian field reader over a payload slice.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Dec<'_> {
    fn take(&mut self, n: usize) -> anyhow::Result<&[u8]> {
        anyhow::ensure!(
            n <= self.buf.len() - self.pos,
            "truncated payload: wanted {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }
    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }
    fn str(&mut self) -> anyhow::Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("invalid UTF-8 in string field: {e}"))?
            .to_string())
    }
    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        // bounds-check the count against the remaining bytes *before*
        // allocating, so a corrupt count can't request a huge Vec
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")))
            .collect())
    }
    fn state(&mut self) -> anyhow::Result<TrainState> {
        let step = self.u64()?;
        let params = self.buffers()?;
        let opt = self.buffers()?;
        Ok(TrainState { step, params, opt })
    }
    fn buffers(&mut self) -> anyhow::Result<Vec<NamedBuffer>> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n <= 1 << 20, "implausible buffer count {n}");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let name = self.str()?;
            let data = self.f32s()?;
            out.push(NamedBuffer { name, data });
        }
        Ok(out)
    }
    fn finish(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "{} trailing bytes after message payload",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> TrainState {
        TrainState {
            step: 42,
            params: vec![
                NamedBuffer { name: "embed".into(), data: vec![1.0, -2.5, f32::MIN_POSITIVE] },
                NamedBuffer { name: "head".into(), data: vec![] },
            ],
            opt: vec![NamedBuffer {
                name: "embed.momentum".into(),
                data: vec![0.5, f32::from_bits(7)],
            }],
        }
    }

    fn all_variants() -> Vec<Msg> {
        vec![
            Msg::Register { worker_id: "w-1".into() },
            Msg::RegisterAck {
                rank: 3,
                nshards: 8,
                start_step: 12,
                steps: 100,
                seed: 0xDEAD_BEEF,
                model: "gpt2_tiny".into(),
                optimizer: "rmnp".into(),
                data: "synthetic".into(),
                state: Some(sample_state()),
            },
            Msg::RegisterAck {
                rank: 0,
                nshards: 1,
                start_step: 0,
                steps: 10,
                seed: 1,
                model: "m".into(),
                optimizer: "o".into(),
                data: "d".into(),
                state: None,
            },
            Msg::RegisterNack { reason: "training already in progress".into() },
            Msg::Heartbeat { rank: 7 },
            Msg::StepBegin { step: 5, shards: vec![0, 2, 4] },
            Msg::ShardGrads { step: 5, shard: 2, loss: 3.25, grads: vec![0.0, -1.0, f32::NAN] },
            Msg::Apply { step: 5, lr: 1e-3, apply: true, grads: vec![0.125; 9] },
            Msg::Apply { step: 6, lr: 5e-4, apply: false, grads: vec![] },
            Msg::CheckpointRequest { step: 6 },
            Msg::CheckpointState { state: sample_state() },
            Msg::WorkerAbort { rank: 1, reason: "guard abort".into() },
            Msg::Shutdown { reason: "run complete".into() },
        ]
    }

    /// NaN != NaN, so compare through bits for the gradient-bearing arms.
    fn bits(m: &Msg) -> Vec<u8> {
        m.encode()
    }

    #[test]
    fn every_variant_roundtrips_through_a_frame() {
        for msg in all_variants() {
            let mut buf = Vec::new();
            write_msg(&mut buf, &msg).unwrap();
            let mut cursor = &buf[..];
            let back = read_msg(&mut cursor).unwrap();
            assert_eq!(bits(&back), bits(&msg), "roundtrip mismatch for {}", msg.name());
            assert!(cursor.is_empty(), "frame for {} left trailing bytes", msg.name());
        }
    }

    #[test]
    fn golden_heartbeat_frame_bytes() {
        // Locks the layout: len=5 LE, crc32(payload) LE, then payload =
        // tag 4 + rank 7 LE. The expected bytes (CRC 0xAE756964) were
        // computed with an independent zlib implementation, so this test
        // pins the wire format itself, not just self-consistency.
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Heartbeat { rank: 7 }).unwrap();
        assert_eq!(buf, [5, 0, 0, 0, 0x64, 0x69, 0x75, 0xAE, 4, 7, 0, 0, 0]);
    }

    #[test]
    fn corrupt_frame_is_dropped_and_the_next_frame_parses() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Heartbeat { rank: 1 }).unwrap();
        let first_len = buf.len();
        write_msg(&mut buf, &Msg::Shutdown { reason: "after the bad frame".into() }).unwrap();
        buf[first_len - 1] ^= 0x40; // flip a payload bit of frame 1

        let mut cursor = &buf[..];
        match read_msg(&mut cursor) {
            Err(RecvError::Corrupt { want, got }) => assert_ne!(want, got),
            other => panic!("wanted Corrupt, got {other:?}"),
        }
        // the stream stayed framed: the very next read yields frame 2
        match read_msg(&mut cursor).unwrap() {
            Msg::Shutdown { reason } => assert_eq!(reason, "after the bad frame"),
            other => panic!("wanted Shutdown, got {}", other.name()),
        }
    }

    #[test]
    fn truncated_stream_reports_closed() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::CheckpointRequest { step: 9 }).unwrap();
        for cut in [0, 3, 8, buf.len() - 1] {
            let mut cursor = &buf[..cut];
            match read_msg(&mut cursor) {
                Err(RecvError::Closed) => {}
                other => panic!("cut at {cut}: wanted Closed, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_length_field_is_refused_without_allocating() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        let mut cursor = &buf[..];
        match read_msg(&mut cursor) {
            Err(RecvError::Other(e)) => assert!(e.to_string().contains("frame length")),
            other => panic!("wanted Other, got {other:?}"),
        }
    }

    #[test]
    fn unknown_tag_and_trailing_bytes_are_decode_errors() {
        assert!(Msg::decode(&[200]).is_err());
        let mut payload = Msg::Heartbeat { rank: 0 }.encode();
        payload.push(0);
        assert!(Msg::decode(&payload).is_err());
    }

    #[test]
    fn truncated_f32_count_cannot_trigger_a_huge_allocation() {
        // ShardGrads claiming u32::MAX floats in a 30-byte payload must
        // fail the bounds check, not attempt a 16 GiB Vec.
        let mut e = Vec::new();
        e.push(6u8);
        e.extend_from_slice(&1u64.to_le_bytes());
        e.extend_from_slice(&0u32.to_le_bytes());
        e.extend_from_slice(&1.0f32.to_le_bytes());
        e.extend_from_slice(&u32::MAX.to_le_bytes()); // grad count
        assert!(Msg::decode(&e).is_err());
    }
}
