//! Machine-readable benchmark reports.
//!
//! Each bench binary assembles a [`crate::util::Json`] document and writes
//! it next to the package root (`BENCH_precond.json`,
//! `BENCH_train_step.json`, …) so the perf trajectory stays comparable
//! across PRs: every run records the kernel thread count, the measured
//! medians, and the derived speedups/improvements. `scripts/bench_check.sh`
//! parses these files to gate regressions.

use std::collections::BTreeMap;
use std::path::Path;

use crate::bench::BenchResult;
use crate::util::Json;

/// Build a JSON object from key/value pairs (keys are sorted by BTreeMap,
/// which keeps the files diff-stable).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut map = BTreeMap::new();
    for (k, v) in pairs {
        map.insert(k.to_string(), v);
    }
    Json::Obj(map)
}

/// Shorthand [`Json::Num`] constructor.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
/// Shorthand [`Json::Num`] constructor for counts.
pub fn int(x: usize) -> Json {
    Json::Num(x as f64)
}
/// Shorthand [`Json::Str`] constructor.
pub fn text(s: &str) -> Json {
    Json::Str(s.to_string())
}

/// One measured result as a JSON object (seconds per iteration).
pub fn bench_json(r: &BenchResult) -> Json {
    obj(vec![
        ("name", text(&r.name)),
        ("median_s", num(r.median())),
        ("mean_s", num(r.mean())),
        ("p10_s", num(r.p10())),
        ("p90_s", num(r.p90())),
        ("iters_per_sample", int(r.iters_per_sample)),
        ("samples", int(r.samples.len())),
    ])
}

/// Standard envelope: bench name + thread count + SIMD rung + payload
/// fields.
pub fn envelope(bench: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("bench", text(bench)),
        ("threads", int(crate::tensor::kernels::num_threads())),
        ("simd", text(crate::tensor::simd::label())),
    ];
    pairs.extend(fields);
    obj(pairs)
}

/// Write a document as one JSON line + trailing newline.
pub fn write(path: &Path, doc: &Json) -> anyhow::Result<()> {
    std::fs::write(path, doc.render() + "\n")
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_json_has_all_stats() {
        let r = BenchResult {
            name: "x".into(),
            iters_per_sample: 3,
            samples: vec![1.0, 2.0, 3.0],
        };
        let j = bench_json(&r);
        assert_eq!(j.get("median_s").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("iters_per_sample").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn write_and_reparse() {
        let doc = envelope(
            "smoke",
            vec![("results", Json::Arr(vec![num(0.5)]))],
        );
        let dir = std::env::temp_dir().join(format!("rmnp-report-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_smoke.json");
        write(&path, &doc).unwrap();
        let back = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.req_str("bench").unwrap(), "smoke");
        assert!(back.get("threads").unwrap().as_usize().unwrap() >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
