//! Hand-rolled micro-benchmark harness (criterion-style; criterion is not
//! in the offline vendor set).
//!
//! Adaptive: measures a calibration run, picks an iteration count to hit a
//! target measurement window, then reports mean/median/p10/p90 over
//! multiple samples. Heavy benchmarks (NS5 at d=1600 takes seconds per
//! call on CPU) automatically degrade to fewer iterations instead of
//! blowing the time budget.

pub mod report;

use std::time::Instant;

use crate::util::{mean, percentile};

/// One benchmark's summary statistics, all in seconds per iteration.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label (also the JSON report key).
    pub name: String,
    /// Iterations averaged into each sample.
    pub iters_per_sample: usize,
    /// Per-sample seconds-per-iteration measurements.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Mean seconds per iteration over all samples.
    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }
    /// Median seconds per iteration (the headline statistic).
    pub fn median(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }
    /// 10th-percentile sample (fast tail).
    pub fn p10(&self) -> f64 {
        percentile(&self.samples, 10.0)
    }
    /// 90th-percentile sample (slow tail).
    pub fn p90(&self) -> f64 {
        percentile(&self.samples, 90.0)
    }

    /// `name  median  [p10 .. p90]  (n samples x m iters)` line.
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} [{} .. {}] ({}x{})",
            self.name,
            fmt_secs(self.median()),
            fmt_secs(self.p10()),
            fmt_secs(self.p90()),
            self.samples.len(),
            self.iters_per_sample,
        )
    }
}

/// Pretty seconds: ns/µs/ms/s.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Target seconds per sample window.
    pub sample_target: f64,
    /// Number of samples.
    pub samples: usize,
    /// Hard cap on total seconds for one benchmark.
    pub budget: f64,
    /// Warmup iterations.
    pub warmup: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { sample_target: 0.2, samples: 10, budget: 10.0, warmup: 1 }
    }
}

/// Run `f` under the harness and return per-iteration statistics.
pub fn bench(name: &str, opts: BenchOpts, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..opts.warmup {
        f();
    }
    // calibrate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((opts.sample_target / once).round() as usize).clamp(1, 1_000_000);
    let mut samples = Vec::with_capacity(opts.samples);
    let deadline = Instant::now() + std::time::Duration::from_secs_f64(opts.budget);
    for _ in 0..opts.samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t.elapsed().as_secs_f64() / iters as f64);
        if Instant::now() > deadline {
            break;
        }
    }
    BenchResult { name: name.to_string(), iters_per_sample: iters, samples }
}

/// Fixed-iteration-count variant (for exact paper protocols like
/// "time per 100 steps").
pub fn bench_n(name: &str, iters: usize, repeats: usize, mut f: impl FnMut()) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::with_capacity(repeats);
    for _ in 0..repeats.max(1) {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t.elapsed().as_secs_f64() / iters as f64);
    }
    BenchResult { name: name.to_string(), iters_per_sample: iters, samples }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_duration() {
        let r = bench(
            "sleep",
            BenchOpts { sample_target: 0.01, samples: 3, budget: 2.0, warmup: 0 },
            || std::thread::sleep(std::time::Duration::from_millis(2)),
        );
        assert!(r.median() >= 0.0018, "median {}", r.median());
        assert!(r.median() < 0.05);
        assert!(!r.report_line().is_empty());
    }

    #[test]
    fn bench_n_respects_iters() {
        let mut count = 0usize;
        let r = bench_n("count", 7, 2, || count += 1);
        // 1 warmup + 7*2
        assert_eq!(count, 15);
        assert_eq!(r.iters_per_sample, 7);
        assert_eq!(r.samples.len(), 2);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            iters_per_sample: 1,
            samples: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        };
        assert!(r.p10() <= r.median() && r.median() <= r.p90());
        assert_eq!(r.mean(), 3.0);
    }
}
