//! Checkpoint store: raw little-endian binary format with versioning.
//!
//! Layout of `<dir>/step-N.ckpt` (format **v2**):
//!
//! ```text
//! magic "RMNPCKPT"            8 bytes
//! version u32                 4   (= 2)
//! step u64                    8   (training steps taken)
//! n_params u32                4   (parameter section length)
//! n_opt u32                   4   (optimizer-state section length)
//! for each buffer (params first, then optimizer state):
//!   name_len u32, name bytes
//!   elem_count u32
//!   f32 data (little endian)
//! ```
//!
//! Format **v1** (no step, no section split — everything is one flat
//! buffer list) is still readable: [`load_state`] maps a v1 file to a
//! [`TrainState`] with `step = 0` and every buffer in the parameter
//! section, and [`load`] returns the flat list for either version.
//!
//! Integer counters (the device-side `t`, AdamW's step count) are stored
//! through their f32 bits — the restore path reinterprets them, so
//! round-trips are bit-exact.
//!
//! The reader **validates before trusting**: counts and lengths from the
//! file are checked against the actual file size, so a truncated or
//! corrupted checkpoint is a clean error instead of a huge allocation or
//! a short read deep inside a buffer. The writer refuses (rather than
//! silently truncates) anything whose count doesn't fit the u32 fields.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::runtime::backend::TrainState;

// Defined at the backend layer (the trait's checkpoint currency);
// re-exported here so `coordinator::checkpoint::NamedBuffer` keeps
// working.
pub use crate::runtime::backend::NamedBuffer;

const MAGIC: &[u8; 8] = b"RMNPCKPT";
const VERSION: u32 = 2;

fn u32_of(n: usize, what: &str) -> anyhow::Result<u32> {
    u32::try_from(n).map_err(|_| {
        anyhow::anyhow!("checkpoint {what} {n} does not fit the u32 format field")
    })
}

fn write_buffers(out: &mut impl Write, buffers: &[NamedBuffer]) -> anyhow::Result<()> {
    for b in buffers {
        let name = b.name.as_bytes();
        out.write_all(&u32_of(name.len(), "name length")?.to_le_bytes())?;
        out.write_all(name)?;
        out.write_all(&u32_of(b.data.len(), "buffer length")?.to_le_bytes())?;
        for v in &b.data {
            out.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Open a temp file next to `path` for an atomic write: the caller
/// writes the full payload, then [`commit`] renames it into place, so a
/// crash mid-write never leaves a truncated `step-N.ckpt` for a later
/// resume to trip over (the `.tmp` suffix is invisible to [`latest`]).
fn tmp_writer(path: &Path) -> anyhow::Result<(std::io::BufWriter<std::fs::File>, PathBuf)> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("ckpt.tmp");
    Ok((std::io::BufWriter::new(std::fs::File::create(&tmp)?), tmp))
}

/// Flush and atomically rename a [`tmp_writer`] file into place.
fn commit(out: std::io::BufWriter<std::fs::File>, tmp: &Path, path: &Path) -> anyhow::Result<()> {
    out.into_inner()
        .map_err(|e| anyhow::anyhow!("flushing checkpoint: {e}"))?;
    std::fs::rename(tmp, path)?;
    Ok(())
}

/// Write a v2 checkpoint: step counter + parameter and optimizer-state
/// sections. The write is atomic (temp file + rename).
pub fn save_state(path: &Path, state: &TrainState) -> anyhow::Result<()> {
    let (mut out, tmp) = tmp_writer(path)?;
    out.write_all(MAGIC)?;
    out.write_all(&VERSION.to_le_bytes())?;
    out.write_all(&state.step.to_le_bytes())?;
    out.write_all(&u32_of(state.params.len(), "parameter count")?.to_le_bytes())?;
    out.write_all(&u32_of(state.opt.len(), "optimizer-buffer count")?.to_le_bytes())?;
    write_buffers(&mut out, &state.params)?;
    write_buffers(&mut out, &state.opt)?;
    commit(out, &tmp, path)
}

/// Write a legacy v1 checkpoint (flat buffer list, no step counter).
/// Kept so the v1-read compatibility path stays covered; new code should
/// use [`save_state`].
pub fn save(path: &Path, buffers: &[NamedBuffer]) -> anyhow::Result<()> {
    let (mut out, tmp) = tmp_writer(path)?;
    out.write_all(MAGIC)?;
    out.write_all(&1u32.to_le_bytes())?;
    out.write_all(&u32_of(buffers.len(), "buffer count")?.to_le_bytes())?;
    write_buffers(&mut out, buffers)?;
    commit(out, &tmp, path)
}

/// Bounded reader state: tracks how many bytes may legally remain so
/// counts read from the file can be validated before allocation.
struct BoundedReader<R> {
    inner: R,
    remaining: u64,
    path: PathBuf,
}

impl<R: Read> BoundedReader<R> {
    fn take(&mut self, n: u64, what: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            n <= self.remaining,
            "corrupt checkpoint {}: {what} needs {n} bytes but only {} remain \
             (truncated file?)",
            self.path.display(),
            self.remaining
        );
        self.remaining -= n;
        Ok(())
    }

    fn read_exact(&mut self, buf: &mut [u8], what: &str) -> anyhow::Result<()> {
        self.take(buf.len() as u64, what)?;
        self.inner
            .read_exact(buf)
            .map_err(|e| anyhow::anyhow!("reading {what}: {e}"))?;
        Ok(())
    }

    fn read_u32(&mut self, what: &str) -> anyhow::Result<u32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b, what)?;
        Ok(u32::from_le_bytes(b))
    }

    fn read_u64(&mut self, what: &str) -> anyhow::Result<u64> {
        let mut b = [0u8; 8];
        self.read_exact(&mut b, what)?;
        Ok(u64::from_le_bytes(b))
    }

    /// Read `n` bytes into a fresh buffer, validating against the file
    /// size BEFORE allocating — a corrupt length field must error, not
    /// attempt a giant allocation.
    fn read_vec(&mut self, n: u64, what: &str) -> anyhow::Result<Vec<u8>> {
        self.take(n, what)?;
        let mut bytes = vec![0u8; n as usize];
        self.inner
            .read_exact(&mut bytes)
            .map_err(|e| anyhow::anyhow!("reading {what}: {e}"))?;
        Ok(bytes)
    }

    fn read_buffers(&mut self, n: usize) -> anyhow::Result<Vec<NamedBuffer>> {
        // each buffer needs ≥ 8 header bytes, so n is bounded by the file
        anyhow::ensure!(
            (n as u64) <= self.remaining / 8,
            "corrupt checkpoint {}: buffer count {n} exceeds what {} bytes \
             can hold",
            self.path.display(),
            self.remaining
        );
        let mut buffers = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = self.read_u32("name length")? as u64;
            let name = self.read_vec(name_len, "buffer name")?;
            let count = self.read_u32("element count")? as u64;
            let bytes = self.read_vec(count * 4, "buffer data")?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            buffers.push(NamedBuffer { name: String::from_utf8(name)?, data });
        }
        Ok(buffers)
    }
}

fn open(path: &Path) -> anyhow::Result<(BoundedReader<std::io::BufReader<std::fs::File>>, u32)> {
    let file = std::fs::File::open(path)?;
    let len = file.metadata()?.len();
    let mut r = BoundedReader {
        inner: std::io::BufReader::new(file),
        remaining: len,
        path: path.to_path_buf(),
    };
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic, "magic")?;
    anyhow::ensure!(&magic == MAGIC, "not a checkpoint: {}", path.display());
    let version = r.read_u32("version")?;
    anyhow::ensure!(
        version == 1 || version == VERSION,
        "unsupported checkpoint v{version} (this build reads v1/v2)"
    );
    Ok((r, version))
}

/// Read a checkpoint into a [`TrainState`]. v2 files restore the step
/// counter and the parameter/optimizer split; v1 files come back with
/// `step = 0` and every buffer in `params`.
pub fn load_state(path: &Path) -> anyhow::Result<TrainState> {
    let (mut r, version) = open(path)?;
    if version == 1 {
        let n = r.read_u32("buffer count")? as usize;
        let params = r.read_buffers(n)?;
        return Ok(TrainState { step: 0, params, opt: Vec::new() });
    }
    let step = r.read_u64("step counter")?;
    let n_params = r.read_u32("parameter count")? as usize;
    let n_opt = r.read_u32("optimizer-buffer count")? as usize;
    let params = r.read_buffers(n_params)?;
    let opt = r.read_buffers(n_opt)?;
    Ok(TrainState { step, params, opt })
}

/// Read a checkpoint as one flat buffer list (v1 order; v2 parameters
/// followed by optimizer state).
pub fn load(path: &Path) -> anyhow::Result<Vec<NamedBuffer>> {
    let state = load_state(path)?;
    let mut all = state.params;
    all.extend(state.opt);
    Ok(all)
}

/// Latest checkpoint in a directory (by step number in the filename).
/// Unreadable or non-UTF-8 entries are skipped, not treated as "no
/// checkpoints" — a resume must never silently restart from scratch
/// because one stray file broke the scan.
pub fn latest(dir: &Path) -> Option<(usize, PathBuf)> {
    let mut best: Option<(usize, PathBuf)> = None;
    for entry in std::fs::read_dir(dir).ok()? {
        let Ok(entry) = entry else { continue };
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if let Some(step) = name
            .strip_prefix("step-")
            .and_then(|s| s.strip_suffix(".ckpt"))
            .and_then(|s| s.parse::<usize>().ok())
        {
            if best.as_ref().map_or(true, |(b, _)| step > *b) {
                best = Some((step, path));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rmnp-ckpt-{}-{name}", std::process::id()))
    }

    fn sample_state() -> TrainState {
        TrainState {
            step: 42,
            params: vec![
                NamedBuffer { name: "w".into(), data: vec![1.5, -2.25, 0.0] },
                NamedBuffer { name: "embed".into(), data: vec![0.5; 8] },
            ],
            opt: vec![
                NamedBuffer { name: "w.momentum".into(), data: vec![0.25, 0.0, -1.0] },
                NamedBuffer { name: "w.t".into(), data: vec![f32::from_bits(7)] },
                NamedBuffer { name: "empty".into(), data: vec![] },
            ],
        }
    }

    #[test]
    fn v2_roundtrip_exact() {
        let dir = tmp("rt2");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-42.ckpt");
        let state = sample_state();
        save_state(&path, &state).unwrap();
        let back = load_state(&path).unwrap();
        assert_eq!(back, state);
        // bit-exact integer reinterpretation survives
        assert_eq!(back.opt[1].data[0].to_bits(), 7);
        // flat view concatenates params then opt
        let flat = load(&path).unwrap();
        assert_eq!(flat.len(), 5);
        assert_eq!(flat[0].name, "w");
        assert_eq!(flat[2].name, "w.momentum");
    }

    #[test]
    fn v1_files_still_load() {
        let dir = tmp("v1");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-5.ckpt");
        let buffers = vec![
            NamedBuffer { name: "w".into(), data: vec![1.5, -2.25, 0.0] },
            NamedBuffer { name: "t".into(), data: vec![f32::from_bits(42)] },
            NamedBuffer { name: "empty".into(), data: vec![] },
        ];
        save(&path, &buffers).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, buffers);
        assert_eq!(back[1].data[0].to_bits(), 42);
        // v1 through the state API: step 0, everything in params
        let state = load_state(&path).unwrap();
        assert_eq!(state.step, 0);
        assert_eq!(state.params, buffers);
        assert!(state.opt.is_empty());
    }

    #[test]
    fn latest_picks_max_step() {
        let dir = tmp("latest");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for s in [3usize, 10, 7] {
            save(&dir.join(format!("step-{s}.ckpt")), &[]).unwrap();
        }
        let (step, path) = latest(&dir).unwrap();
        assert_eq!(step, 10);
        assert!(path.ends_with("step-10.ckpt"));
    }

    #[test]
    fn saves_are_atomic_and_leave_no_tmp_behind() {
        let dir = tmp("atomic");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-9.ckpt");
        save_state(&path, &sample_state()).unwrap();
        // a crashed write would have left only the .tmp; a completed one
        // leaves only the final file, and latest() never selects a .tmp
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["step-9.ckpt".to_string()], "{names:?}");
        // simulate the crash: a stale tmp alongside real checkpoints is
        // ignored by the scan
        std::fs::write(dir.join("step-12.ckpt.tmp"), b"partial").unwrap();
        let (step, _) = latest(&dir).unwrap();
        assert_eq!(step, 9, "a .tmp from a crashed save must not win");
    }

    #[test]
    fn rejects_garbage() {
        let dir = tmp("bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.ckpt");
        std::fs::write(&path, b"garbage!").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_truncated_files() {
        let dir = tmp("trunc");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-1.ckpt");
        save_state(&path, &sample_state()).unwrap();
        let full = std::fs::read(&path).unwrap();
        // cut the file at every prefix length that can break a section:
        // mid-header, mid-name, mid-data
        for cut in [4usize, 12, 20, 27, 30, full.len() - 3] {
            let short = dir.join("short.ckpt");
            std::fs::write(&short, &full[..cut]).unwrap();
            let err = load_state(&short);
            assert!(err.is_err(), "cut at {cut} must fail");
        }
        // the untouched file still loads (the loop above didn't clobber it)
        assert!(load_state(&path).is_ok());
    }

    #[test]
    fn rejects_oversized_counts() {
        let dir = tmp("counts");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-1.ckpt");
        save_state(&path, &sample_state()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // corrupt n_params (offset 20: magic 8 + version 4 + step 8) to a
        // count the file cannot possibly hold — must error, not allocate
        bytes[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        let bad = dir.join("huge-count.ckpt");
        std::fs::write(&bad, &bytes).unwrap();
        let err = load_state(&bad).unwrap_err().to_string();
        assert!(err.contains("buffer count"), "{err}");

        // corrupt the first buffer's elem_count instead: header is 28
        // bytes (magic 8 + version 4 + step 8 + counts 8), then
        // name_len(4) + "w"(1) puts elem_count at offset 33
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[33..37].copy_from_slice(&u32::MAX.to_le_bytes());
        let bad = dir.join("huge-elems.ckpt");
        std::fs::write(&bad, &bytes).unwrap();
        let err = load_state(&bad).unwrap_err().to_string();
        assert!(err.contains("buffer data"), "{err}");

        // corrupt the first buffer's name length (offset 28)
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[28..32].copy_from_slice(&u32::MAX.to_le_bytes());
        let bad = dir.join("huge-name.ckpt");
        std::fs::write(&bad, &bytes).unwrap();
        assert!(load_state(&bad).is_err());
    }

    #[test]
    fn save_refuses_counts_beyond_u32() {
        // a buffer whose length cannot be represented must be a clean
        // error, not a silent truncation. (Allocating > u32::MAX floats is
        // not feasible in a test, so exercise the guard directly.)
        assert!(u32_of(usize::MAX, "test").is_err());
        assert!(u32_of(42, "test").is_ok());
    }
}
