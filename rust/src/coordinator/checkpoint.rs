//! Checkpoint store: raw little-endian binary format with a text index.
//!
//! Layout of `<dir>/step-N.ckpt`:
//!
//! ```text
//! magic "RMNPCKPT"            8 bytes
//! version u32                 4
//! n_buffers u32               4
//! for each buffer:
//!   name_len u32, name bytes
//!   elem_count u32
//!   f32 data (little endian)
//! ```
//!
//! The scalar step counter "t" (an i32 on device) is stored through its
//! f32 bits like everything else — the restore path reinterprets it, so
//! round-trips are exact.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"RMNPCKPT";
const VERSION: u32 = 1;

/// One named state buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct NamedBuffer {
    pub name: String,
    pub data: Vec<f32>,
}

/// Write a checkpoint file.
pub fn save(path: &Path, buffers: &[NamedBuffer]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    out.write_all(MAGIC)?;
    out.write_all(&VERSION.to_le_bytes())?;
    out.write_all(&(buffers.len() as u32).to_le_bytes())?;
    for b in buffers {
        let name = b.name.as_bytes();
        out.write_all(&(name.len() as u32).to_le_bytes())?;
        out.write_all(name)?;
        out.write_all(&(b.data.len() as u32).to_le_bytes())?;
        for v in &b.data {
            out.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read a checkpoint file.
pub fn load(path: &Path) -> anyhow::Result<Vec<NamedBuffer>> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a checkpoint: {}", path.display());
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    let version = u32::from_le_bytes(u32buf);
    anyhow::ensure!(version == VERSION, "unsupported checkpoint v{version}");
    f.read_exact(&mut u32buf)?;
    let n = u32::from_le_bytes(u32buf) as usize;
    let mut buffers = Vec::with_capacity(n);
    for _ in 0..n {
        f.read_exact(&mut u32buf)?;
        let name_len = u32::from_le_bytes(u32buf) as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        f.read_exact(&mut u32buf)?;
        let count = u32::from_le_bytes(u32buf) as usize;
        let mut bytes = vec![0u8; count * 4];
        f.read_exact(&mut bytes)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        buffers.push(NamedBuffer { name: String::from_utf8(name)?, data });
    }
    Ok(buffers)
}

/// Latest checkpoint in a directory (by step number in the filename).
pub fn latest(dir: &Path) -> Option<(usize, PathBuf)> {
    let mut best: Option<(usize, PathBuf)> = None;
    for entry in std::fs::read_dir(dir).ok()? {
        let path = entry.ok()?.path();
        let name = path.file_name()?.to_str()?;
        if let Some(step) = name
            .strip_prefix("step-")
            .and_then(|s| s.strip_suffix(".ckpt"))
            .and_then(|s| s.parse::<usize>().ok())
        {
            if best.as_ref().map_or(true, |(b, _)| step > *b) {
                best = Some((step, path));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rmnp-ckpt-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_exact() {
        let dir = tmp("rt");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-5.ckpt");
        let buffers = vec![
            NamedBuffer { name: "w".into(), data: vec![1.5, -2.25, 0.0] },
            NamedBuffer { name: "t".into(), data: vec![f32::from_bits(42)] },
            NamedBuffer { name: "empty".into(), data: vec![] },
        ];
        save(&path, &buffers).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, buffers);
        // bit-exact i32 reinterpretation survives
        assert_eq!(back[1].data[0].to_bits(), 42);
    }

    #[test]
    fn latest_picks_max_step() {
        let dir = tmp("latest");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for s in [3usize, 10, 7] {
            save(&dir.join(format!("step-{s}.ckpt")), &[]).unwrap();
        }
        let (step, path) = latest(&dir).unwrap();
        assert_eq!(step, 10);
        assert!(path.ends_with("step-10.ckpt"));
    }

    #[test]
    fn rejects_garbage() {
        let dir = tmp("bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.ckpt");
        std::fs::write(&path, b"garbage!").unwrap();
        assert!(load(&path).is_err());
    }
}
