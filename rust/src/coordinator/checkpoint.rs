//! Checkpoint store: raw little-endian binary format with versioning,
//! per-section CRC32 integrity, and crash-consistent writes.
//!
//! Layout of `<dir>/step-N.ckpt` (format **v3**):
//!
//! ```text
//! magic "RMNPCKPT"            8 bytes
//! version u32                 4   (= 3)
//! step u64                    8   (training steps taken)
//! n_params u32                4   (parameter section length)
//! n_opt u32                   4   (optimizer-state section length)
//! for each parameter buffer:
//!   name_len u32, name bytes
//!   elem_count u32
//!   f32 data (little endian)
//! params_crc u32              4   (CRC-32 of the parameter buffers)
//! for each optimizer buffer:  (same encoding)
//! opt_crc u32                 4   (CRC-32 of the optimizer buffers)
//! footer_crc u32              4   (CRC-32 of every preceding byte)
//! ```
//!
//! Format **v2** (no CRCs) and **v1** (no step, no section split —
//! everything is one flat buffer list) are still readable: [`load_state`]
//! maps a v1 file to a [`TrainState`] with `step = 0` and every buffer in
//! the parameter section, and [`load`] returns the flat list for any
//! version.
//!
//! Integer counters (the device-side `t`, AdamW's step count) are stored
//! through their f32 bits — the restore path reinterprets them, so
//! round-trips are bit-exact.
//!
//! **Crash consistency.** Saves write to a `.ckpt.tmp` sibling, fsync the
//! file, rename it into place, then fsync the parent directory — so a
//! kill at any instruction leaves either the old checkpoint set intact or
//! the new file fully durable, never a torn `step-N.ckpt`. Tests and
//! benches that don't need durability can set `RMNP_NO_FSYNC=1` to skip
//! both syncs.
//!
//! **Validation before trust.** Counts and lengths from the file are
//! checked against the actual file size before any allocation, every v3
//! section must match its CRC, the whole file must match the footer CRC,
//! and no version may carry trailing bytes (which also catches the one
//! corruption CRCs can't: a bit-flip of the version field itself, which
//! would otherwise downgrade a v3 file to an unchecksummed v2 parse).
//! [`latest_valid`] builds on this to walk back to the newest checkpoint
//! that fully validates instead of dying on a torn newest one.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::runtime::backend::TrainState;
use crate::util::crc32::Crc32;

// Defined at the backend layer (the trait's checkpoint currency);
// re-exported here so `coordinator::checkpoint::NamedBuffer` keeps
// working.
pub use crate::runtime::backend::NamedBuffer;

const MAGIC: &[u8; 8] = b"RMNPCKPT";
const VERSION: u32 = 3;

fn u32_of(n: usize, what: &str) -> anyhow::Result<u32> {
    u32::try_from(n).map_err(|_| {
        anyhow::anyhow!("checkpoint {what} {n} does not fit the u32 format field")
    })
}

/// Should saves fsync the checkpoint file and its directory? On by
/// default; `RMNP_NO_FSYNC=1` turns it off for tests/benches where
/// durability is irrelevant and the sync dominates the save time.
fn fsync_enabled() -> bool {
    std::env::var_os("RMNP_NO_FSYNC").map_or(true, |v| v != "1")
}

/// A [`Write`] adapter that feeds everything written through two CRC-32
/// digests: `footer` (never reset — covers the whole file) and `section`
/// (reset at each section boundary by the v3 writer).
struct CrcWriter<W> {
    inner: W,
    footer: Crc32,
    section: Crc32,
}

impl<W: Write> Write for CrcWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.footer.update(&buf[..n]);
        self.section.update(&buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

fn write_buffers(out: &mut impl Write, buffers: &[NamedBuffer]) -> anyhow::Result<()> {
    for b in buffers {
        let name = b.name.as_bytes();
        out.write_all(&u32_of(name.len(), "name length")?.to_le_bytes())?;
        out.write_all(name)?;
        out.write_all(&u32_of(b.data.len(), "buffer length")?.to_le_bytes())?;
        for v in &b.data {
            out.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Open a temp file next to `path` for an atomic write: the caller
/// writes the full payload, then [`commit`] renames it into place, so a
/// crash mid-write never leaves a truncated `step-N.ckpt` for a later
/// resume to trip over (the `.tmp` suffix is invisible to [`latest`]).
fn tmp_writer(path: &Path) -> anyhow::Result<(std::io::BufWriter<std::fs::File>, PathBuf)> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("ckpt.tmp");
    Ok((std::io::BufWriter::new(std::fs::File::create(&tmp)?), tmp))
}

/// Flush, fsync, and atomically rename a [`tmp_writer`] file into place,
/// then fsync the parent directory so the rename itself is durable. A
/// rename alone can survive a crash the data didn't — the file contents
/// must reach disk before the name does.
fn commit(out: std::io::BufWriter<std::fs::File>, tmp: &Path, path: &Path) -> anyhow::Result<()> {
    let file = out
        .into_inner()
        .map_err(|e| anyhow::anyhow!("flushing checkpoint: {e}"))?;
    if fsync_enabled() {
        file.sync_all()
            .map_err(|e| anyhow::anyhow!("fsync {}: {e}", tmp.display()))?;
    }
    drop(file);
    std::fs::rename(tmp, path)?;
    #[cfg(unix)]
    if fsync_enabled() {
        if let Some(dir) = path.parent() {
            std::fs::File::open(dir)
                .and_then(|d| d.sync_all())
                .map_err(|e| anyhow::anyhow!("fsync dir {}: {e}", dir.display()))?;
        }
    }
    Ok(())
}

/// Write a v3 checkpoint: step counter, parameter and optimizer-state
/// sections, per-section CRC-32s, and a whole-file footer CRC-32. The
/// write is atomic and durable (temp file + fsync + rename + dir fsync).
pub fn save_state(path: &Path, state: &TrainState) -> anyhow::Result<()> {
    let (out, tmp) = tmp_writer(path)?;
    let mut out = CrcWriter { inner: out, footer: Crc32::new(), section: Crc32::new() };
    out.write_all(MAGIC)?;
    out.write_all(&VERSION.to_le_bytes())?;
    out.write_all(&state.step.to_le_bytes())?;
    out.write_all(&u32_of(state.params.len(), "parameter count")?.to_le_bytes())?;
    out.write_all(&u32_of(state.opt.len(), "optimizer-buffer count")?.to_le_bytes())?;
    out.section = Crc32::new();
    write_buffers(&mut out, &state.params)?;
    let params_crc = out.section.value();
    out.write_all(&params_crc.to_le_bytes())?;
    out.section = Crc32::new();
    write_buffers(&mut out, &state.opt)?;
    let opt_crc = out.section.value();
    out.write_all(&opt_crc.to_le_bytes())?;
    let footer_crc = out.footer.value();
    out.write_all(&footer_crc.to_le_bytes())?;
    commit(out.inner, &tmp, path)
}

/// Write a legacy v2 checkpoint (sections but no CRCs). Kept so the
/// v2-read compatibility path stays honestly covered — tests use this to
/// produce genuine v2 bytes; new code saves v3 via [`save_state`].
pub fn save_state_v2(path: &Path, state: &TrainState) -> anyhow::Result<()> {
    let (mut out, tmp) = tmp_writer(path)?;
    out.write_all(MAGIC)?;
    out.write_all(&2u32.to_le_bytes())?;
    out.write_all(&state.step.to_le_bytes())?;
    out.write_all(&u32_of(state.params.len(), "parameter count")?.to_le_bytes())?;
    out.write_all(&u32_of(state.opt.len(), "optimizer-buffer count")?.to_le_bytes())?;
    write_buffers(&mut out, &state.params)?;
    write_buffers(&mut out, &state.opt)?;
    commit(out, &tmp, path)
}

/// Write a legacy v1 checkpoint (flat buffer list, no step counter).
/// Kept so the v1-read compatibility path stays covered; new code should
/// use [`save_state`].
pub fn save(path: &Path, buffers: &[NamedBuffer]) -> anyhow::Result<()> {
    let (mut out, tmp) = tmp_writer(path)?;
    out.write_all(MAGIC)?;
    out.write_all(&1u32.to_le_bytes())?;
    out.write_all(&u32_of(buffers.len(), "buffer count")?.to_le_bytes())?;
    write_buffers(&mut out, buffers)?;
    commit(out, &tmp, path)
}

/// Bounded reader state: tracks how many bytes may legally remain so
/// counts read from the file can be validated before allocation, and
/// mirrors the writer's two CRC digests so v3 sections verify as they
/// stream past.
struct BoundedReader<R> {
    inner: R,
    remaining: u64,
    path: PathBuf,
    footer: Crc32,
    section: Crc32,
}

impl<R: Read> BoundedReader<R> {
    fn take(&mut self, n: u64, what: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            n <= self.remaining,
            "corrupt checkpoint {}: {what} needs {n} bytes but only {} remain \
             (truncated file?)",
            self.path.display(),
            self.remaining
        );
        self.remaining -= n;
        Ok(())
    }

    fn read_exact(&mut self, buf: &mut [u8], what: &str) -> anyhow::Result<()> {
        self.take(buf.len() as u64, what)?;
        self.inner
            .read_exact(buf)
            .map_err(|e| anyhow::anyhow!("reading {what}: {e}"))?;
        self.footer.update(buf);
        self.section.update(buf);
        Ok(())
    }

    fn read_u32(&mut self, what: &str) -> anyhow::Result<u32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b, what)?;
        Ok(u32::from_le_bytes(b))
    }

    fn read_u64(&mut self, what: &str) -> anyhow::Result<u64> {
        let mut b = [0u8; 8];
        self.read_exact(&mut b, what)?;
        Ok(u64::from_le_bytes(b))
    }

    /// Read `n` bytes into a fresh buffer, validating against the file
    /// size BEFORE allocating — a corrupt length field must error, not
    /// attempt a giant allocation.
    fn read_vec(&mut self, n: u64, what: &str) -> anyhow::Result<Vec<u8>> {
        self.take(n, what)?;
        let mut bytes = vec![0u8; n as usize];
        self.inner
            .read_exact(&mut bytes)
            .map_err(|e| anyhow::anyhow!("reading {what}: {e}"))?;
        self.footer.update(&bytes);
        self.section.update(&bytes);
        Ok(bytes)
    }

    fn read_buffers(&mut self, n: usize) -> anyhow::Result<Vec<NamedBuffer>> {
        // each buffer needs ≥ 8 header bytes, so n is bounded by the file
        anyhow::ensure!(
            (n as u64) <= self.remaining / 8,
            "corrupt checkpoint {}: buffer count {n} exceeds what {} bytes \
             can hold",
            self.path.display(),
            self.remaining
        );
        let mut buffers = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = self.read_u32("name length")? as u64;
            let name = self.read_vec(name_len, "buffer name")?;
            let count = self.read_u32("element count")? as u64;
            let bytes = self.read_vec(count * 4, "buffer data")?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            buffers.push(NamedBuffer { name: String::from_utf8(name)?, data });
        }
        Ok(buffers)
    }

    /// Reset the section digest at a section boundary.
    fn begin_section(&mut self) {
        self.section = Crc32::new();
    }

    /// Compare the streamed section digest against the stored CRC that
    /// follows the section. Must be called before any further section
    /// bytes are read (the stored CRC itself feeds only the footer's
    /// view of the file, which matches the writer).
    fn check_section_crc(&mut self, what: &str) -> anyhow::Result<()> {
        let computed = self.section.value();
        let stored = self.read_u32(what)?;
        anyhow::ensure!(
            stored == computed,
            "corrupt checkpoint {}: {what} mismatch \
             (stored {stored:#010x}, computed {computed:#010x})",
            self.path.display()
        );
        Ok(())
    }

    /// Compare the whole-file digest against the stored footer CRC. The
    /// computed value is captured before the stored bytes are read —
    /// the footer covers every byte that precedes it.
    fn check_footer_crc(&mut self) -> anyhow::Result<()> {
        let computed = self.footer.value();
        let stored = self.read_u32("footer CRC")?;
        anyhow::ensure!(
            stored == computed,
            "corrupt checkpoint {}: footer CRC mismatch \
             (stored {stored:#010x}, computed {computed:#010x})",
            self.path.display()
        );
        Ok(())
    }
}

fn open(path: &Path) -> anyhow::Result<(BoundedReader<std::io::BufReader<std::fs::File>>, u32)> {
    let file = std::fs::File::open(path)?;
    let len = file.metadata()?.len();
    let mut r = BoundedReader {
        inner: std::io::BufReader::new(file),
        remaining: len,
        path: path.to_path_buf(),
        footer: Crc32::new(),
        section: Crc32::new(),
    };
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic, "magic")?;
    anyhow::ensure!(&magic == MAGIC, "not a checkpoint: {}", path.display());
    let version = r.read_u32("version")?;
    anyhow::ensure!(
        (1..=VERSION).contains(&version),
        "unsupported checkpoint v{version} (this build reads v1/v2/v3)"
    );
    Ok((r, version))
}

/// Read a checkpoint into a [`TrainState`]. v2/v3 files restore the step
/// counter and the parameter/optimizer split (v3 additionally verifies
/// section + footer CRCs); v1 files come back with `step = 0` and every
/// buffer in `params`. Any version rejects trailing bytes.
pub fn load_state(path: &Path) -> anyhow::Result<TrainState> {
    let (mut r, version) = open(path)?;
    let state = if version == 1 {
        let n = r.read_u32("buffer count")? as usize;
        let params = r.read_buffers(n)?;
        TrainState { step: 0, params, opt: Vec::new() }
    } else {
        let step = r.read_u64("step counter")?;
        let n_params = r.read_u32("parameter count")? as usize;
        let n_opt = r.read_u32("optimizer-buffer count")? as usize;
        r.begin_section();
        let params = r.read_buffers(n_params)?;
        if version >= 3 {
            r.check_section_crc("parameter-section CRC")?;
        }
        r.begin_section();
        let opt = r.read_buffers(n_opt)?;
        if version >= 3 {
            r.check_section_crc("optimizer-section CRC")?;
            r.check_footer_crc()?;
        }
        TrainState { step, params, opt }
    };
    // A genuine file of any version ends exactly here. Trailing bytes
    // mean corruption — most importantly a version field flipped 3 -> 2,
    // which would otherwise let a v3 file parse as v2 with its three CRC
    // words silently ignored.
    anyhow::ensure!(
        r.remaining == 0,
        "corrupt checkpoint {}: {} trailing bytes after the final section",
        r.path.display(),
        r.remaining
    );
    Ok(state)
}

/// Read a checkpoint as one flat buffer list (v1 order; v2/v3 parameters
/// followed by optimizer state).
pub fn load(path: &Path) -> anyhow::Result<Vec<NamedBuffer>> {
    let state = load_state(path)?;
    let mut all = state.params;
    all.extend(state.opt);
    Ok(all)
}

/// All `step-N.ckpt` files in `dir`, sorted newest-first. A missing
/// directory is an empty list; any other scan error propagates — an
/// unreadable checkpoint dir must not be mistaken for "no checkpoints"
/// (that is how a resume silently restarts from scratch). Non-UTF-8 or
/// non-matching names are skipped.
fn candidates(dir: &Path) -> anyhow::Result<Vec<(usize, PathBuf)>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => anyhow::bail!("scanning checkpoint dir {}: {e}", dir.display()),
    };
    let mut found = Vec::new();
    for entry in entries {
        let entry =
            entry.map_err(|e| anyhow::anyhow!("scanning checkpoint dir {}: {e}", dir.display()))?;
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if let Some(step) = name
            .strip_prefix("step-")
            .and_then(|s| s.strip_suffix(".ckpt"))
            .and_then(|s| s.parse::<usize>().ok())
        {
            found.push((step, path));
        }
    }
    found.sort_by(|a, b| b.0.cmp(&a.0));
    Ok(found)
}

/// Latest checkpoint in a directory (by step number in the filename),
/// without validating its contents. `Ok(None)` means the directory has
/// no checkpoints (or doesn't exist); IO errors scanning it propagate.
pub fn latest(dir: &Path) -> anyhow::Result<Option<(usize, PathBuf)>> {
    Ok(candidates(dir)?.into_iter().next())
}

/// Newest checkpoint that fully validates: header parses, every CRC
/// matches, and the payload step agrees with the filename. Corrupt or
/// mismatched candidates are logged and skipped, walking back to the
/// next-newest — a torn newest checkpoint costs `checkpoint_every` steps
/// of progress, not the whole run. Returns the loaded state so resume
/// doesn't read the file twice.
pub fn latest_valid(dir: &Path) -> anyhow::Result<Option<(usize, PathBuf, TrainState)>> {
    for (step, path) in candidates(dir)? {
        match load_state(&path) {
            Ok(state) if state.step == step as u64 => return Ok(Some((step, path, state))),
            Ok(state) => crate::warnln!(
                "skipping checkpoint {}: filename says step {step} but payload \
                 says step {} — walking back",
                path.display(),
                state.step
            ),
            Err(e) => crate::warnln!("skipping corrupt checkpoint: {e} — walking back"),
        }
    }
    Ok(None)
}

/// Keep-last-K retention: delete all but the newest `keep` checkpoints
/// in `dir`, plus any stale `.ckpt.tmp` leftovers from crashed saves.
/// `keep == 0` disables pruning entirely. Returns how many files were
/// removed.
pub fn prune(dir: &Path, keep: usize) -> anyhow::Result<usize> {
    if keep == 0 {
        return Ok(0);
    }
    let mut removed = 0;
    for (_, path) in candidates(dir)?.into_iter().skip(keep) {
        std::fs::remove_file(&path)
            .map_err(|e| anyhow::anyhow!("pruning {}: {e}", path.display()))?;
        removed += 1;
    }
    // stale tmp files are never in-flight here: prune runs right after a
    // completed commit, and saves are single-threaded per run dir
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let path = entry.path();
            let is_tmp = path
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.ends_with(".ckpt.tmp"));
            if is_tmp && std::fs::remove_file(&path).is_ok() {
                removed += 1;
            }
        }
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rmnp-ckpt-{}-{name}", std::process::id()))
    }

    fn sample_state() -> TrainState {
        TrainState {
            step: 42,
            params: vec![
                NamedBuffer { name: "w".into(), data: vec![1.5, -2.25, 0.0] },
                NamedBuffer { name: "embed".into(), data: vec![0.5; 8] },
            ],
            opt: vec![
                NamedBuffer { name: "w.momentum".into(), data: vec![0.25, 0.0, -1.0] },
                NamedBuffer { name: "w.t".into(), data: vec![f32::from_bits(7)] },
                NamedBuffer { name: "empty".into(), data: vec![] },
            ],
        }
    }

    #[test]
    fn v3_roundtrip_exact() {
        let dir = tmp("rt3");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-42.ckpt");
        let state = sample_state();
        save_state(&path, &state).unwrap();
        let back = load_state(&path).unwrap();
        assert_eq!(back, state);
        // bit-exact integer reinterpretation survives
        assert_eq!(back.opt[1].data[0].to_bits(), 7);
        // flat view concatenates params then opt
        let flat = load(&path).unwrap();
        assert_eq!(flat.len(), 5);
        assert_eq!(flat[0].name, "w");
        assert_eq!(flat[2].name, "w.momentum");
    }

    #[test]
    fn v2_files_still_load() {
        let dir = tmp("v2");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-42.ckpt");
        let state = sample_state();
        save_state_v2(&path, &state).unwrap();
        // genuinely v2 on disk: 12 bytes shorter (no CRC words), version 2
        let v2_bytes = std::fs::read(&path).unwrap();
        assert_eq!(u32::from_le_bytes(v2_bytes[8..12].try_into().unwrap()), 2);
        let v3 = dir.join("step-43.ckpt");
        save_state(&v3, &state).unwrap();
        assert_eq!(std::fs::read(&v3).unwrap().len(), v2_bytes.len() + 12);
        // and it loads identically through the current reader
        assert_eq!(load_state(&path).unwrap(), state);
    }

    #[test]
    fn v1_files_still_load() {
        let dir = tmp("v1");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-5.ckpt");
        let buffers = vec![
            NamedBuffer { name: "w".into(), data: vec![1.5, -2.25, 0.0] },
            NamedBuffer { name: "t".into(), data: vec![f32::from_bits(42)] },
            NamedBuffer { name: "empty".into(), data: vec![] },
        ];
        save(&path, &buffers).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, buffers);
        assert_eq!(back[1].data[0].to_bits(), 42);
        // v1 through the state API: step 0, everything in params
        let state = load_state(&path).unwrap();
        assert_eq!(state.step, 0);
        assert_eq!(state.params, buffers);
        assert!(state.opt.is_empty());
    }

    #[test]
    fn section_crc_catches_payload_flips() {
        let dir = tmp("crc");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-42.ckpt");
        save_state(&path, &sample_state()).unwrap();
        let good = std::fs::read(&path).unwrap();
        // flip one bit in the first parameter's data (header is 28 bytes,
        // then name_len(4) + "w"(1) + elem_count(4) puts data at 37)
        let mut bad = good.clone();
        bad[37] ^= 0x10;
        let p = dir.join("flipped.ckpt");
        std::fs::write(&p, &bad).unwrap();
        let err = load_state(&p).unwrap_err().to_string();
        assert!(err.contains("parameter-section CRC"), "{err}");

        // flip a stored section-CRC byte: the footer CRC catches it
        let mut bad = good.clone();
        let opt_crc_at = good.len() - 8; // [opt_crc u32][footer_crc u32]
        bad[opt_crc_at] ^= 0x01;
        let p = dir.join("crcflip.ckpt");
        std::fs::write(&p, &bad).unwrap();
        let err = load_state(&p).unwrap_err().to_string();
        assert!(
            err.contains("optimizer-section CRC") || err.contains("footer CRC"),
            "{err}"
        );
    }

    #[test]
    fn version_flip_to_v2_is_rejected_not_misparsed() {
        // the one corruption a CRC can't see: the version byte itself
        // flips 3 -> 2 and the reader takes the unchecksummed v2 path.
        // The bounded reader + trailing-bytes check must still refuse the
        // file (the v2 parse trips over the embedded CRC words — here as
        // a bogus buffer-name length; in the aligned worst case as 12
        // trailing bytes).
        let dir = tmp("verflip");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-42.ckpt");
        save_state(&path, &sample_state()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8] = 2; // version u32 LE at offset 8
        let p = dir.join("downgraded.ckpt");
        std::fs::write(&p, &bytes).unwrap();
        assert!(load_state(&p).is_err(), "downgraded v3 must not parse as v2");
    }

    #[test]
    fn trailing_bytes_are_rejected_for_legacy_versions_too() {
        let dir = tmp("trail");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-42.ckpt");
        save_state_v2(&path, &sample_state()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.push(0);
        let p = dir.join("padded.ckpt");
        std::fs::write(&p, &bytes).unwrap();
        let err = load_state(&p).unwrap_err().to_string();
        assert!(err.contains("trailing bytes"), "{err}");
    }

    #[test]
    fn latest_picks_max_step() {
        let dir = tmp("latest");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for s in [3usize, 10, 7] {
            save(&dir.join(format!("step-{s}.ckpt")), &[]).unwrap();
        }
        let (step, path) = latest(&dir).unwrap().unwrap();
        assert_eq!(step, 10);
        assert!(path.ends_with("step-10.ckpt"));
    }

    #[test]
    fn latest_reports_missing_dir_as_none_not_error() {
        let dir = tmp("latest-none");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(latest(&dir).unwrap().is_none());
        assert!(latest_valid(&dir).unwrap().is_none());
    }

    #[test]
    fn latest_valid_walks_back_over_a_torn_newest() {
        let dir = tmp("walkback");
        let _ = std::fs::remove_dir_all(&dir);
        let mut state = sample_state();
        state.step = 5;
        save_state(&dir.join("step-5.ckpt"), &state).unwrap();
        state.step = 10;
        save_state(&dir.join("step-10.ckpt"), &state).unwrap();
        // tear the newest: truncate it mid-payload
        let bytes = std::fs::read(dir.join("step-10.ckpt")).unwrap();
        std::fs::write(dir.join("step-10.ckpt"), &bytes[..bytes.len() / 2]).unwrap();
        let (step, path, loaded) = latest_valid(&dir).unwrap().unwrap();
        assert_eq!(step, 5);
        assert!(path.ends_with("step-5.ckpt"));
        assert_eq!(loaded.step, 5);
        // plain latest() still reports the (torn) newest by filename
        assert_eq!(latest(&dir).unwrap().unwrap().0, 10);
    }

    #[test]
    fn latest_valid_rejects_step_mismatched_payloads() {
        let dir = tmp("stepmatch");
        let _ = std::fs::remove_dir_all(&dir);
        let mut state = sample_state();
        state.step = 3;
        save_state(&dir.join("step-3.ckpt"), &state).unwrap();
        // a step-9 file whose payload says step 3 (e.g. a bad copy)
        save_state(&dir.join("step-9.ckpt"), &state).unwrap();
        let (step, _, _) = latest_valid(&dir).unwrap().unwrap();
        assert_eq!(step, 3, "mismatched payload must be skipped");
    }

    #[test]
    fn prune_keeps_the_newest_k() {
        let dir = tmp("prune");
        let _ = std::fs::remove_dir_all(&dir);
        let mut state = sample_state();
        for s in [2u64, 4, 6, 8, 10] {
            state.step = s;
            save_state(&dir.join(format!("step-{s}.ckpt")), &state).unwrap();
        }
        std::fs::write(dir.join("step-99.ckpt.tmp"), b"stale").unwrap();
        // keep == 0 disables pruning
        assert_eq!(prune(&dir, 0).unwrap(), 0);
        assert_eq!(candidates(&dir).unwrap().len(), 5);
        let removed = prune(&dir, 2).unwrap();
        assert_eq!(removed, 4, "3 old checkpoints + 1 stale tmp");
        let left: Vec<usize> = candidates(&dir).unwrap().into_iter().map(|c| c.0).collect();
        assert_eq!(left, vec![10, 8]);
        assert!(!dir.join("step-99.ckpt.tmp").exists());
    }

    #[test]
    fn saves_are_atomic_and_leave_no_tmp_behind() {
        let dir = tmp("atomic");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-9.ckpt");
        save_state(&path, &sample_state()).unwrap();
        // a crashed write would have left only the .tmp; a completed one
        // leaves only the final file, and latest() never selects a .tmp
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["step-9.ckpt".to_string()], "{names:?}");
        // simulate the crash: a stale tmp alongside real checkpoints is
        // ignored by the scan
        std::fs::write(dir.join("step-12.ckpt.tmp"), b"partial").unwrap();
        let (step, _) = latest(&dir).unwrap().unwrap();
        assert_eq!(step, 9, "a .tmp from a crashed save must not win");
    }

    #[test]
    fn rejects_garbage() {
        let dir = tmp("bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.ckpt");
        std::fs::write(&path, b"garbage!").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_truncated_files() {
        let dir = tmp("trunc");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-1.ckpt");
        save_state(&path, &sample_state()).unwrap();
        let full = std::fs::read(&path).unwrap();
        // cut the file at every prefix length that can break a section:
        // mid-header, mid-name, mid-data, mid-CRC-words
        for cut in [4usize, 12, 20, 27, 30, full.len() - 3, full.len() - 11] {
            let short = dir.join("short.ckpt");
            std::fs::write(&short, &full[..cut]).unwrap();
            let err = load_state(&short);
            assert!(err.is_err(), "cut at {cut} must fail");
        }
        // the untouched file still loads (the loop above didn't clobber it)
        assert!(load_state(&path).is_ok());
    }

    #[test]
    fn rejects_oversized_counts() {
        let dir = tmp("counts");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step-1.ckpt");
        save_state(&path, &sample_state()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // corrupt n_params (offset 20: magic 8 + version 4 + step 8) to a
        // count the file cannot possibly hold — must error, not allocate
        bytes[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        let bad = dir.join("huge-count.ckpt");
        std::fs::write(&bad, &bytes).unwrap();
        let err = load_state(&bad).unwrap_err().to_string();
        assert!(err.contains("buffer count"), "{err}");

        // corrupt the first buffer's elem_count instead: header is 28
        // bytes (magic 8 + version 4 + step 8 + counts 8), then
        // name_len(4) + "w"(1) puts elem_count at offset 33
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[33..37].copy_from_slice(&u32::MAX.to_le_bytes());
        let bad = dir.join("huge-elems.ckpt");
        std::fs::write(&bad, &bytes).unwrap();
        let err = load_state(&bad).unwrap_err().to_string();
        assert!(err.contains("buffer data"), "{err}");

        // corrupt the first buffer's name length (offset 28)
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[28..32].copy_from_slice(&u32::MAX.to_le_bytes());
        let bad = dir.join("huge-name.ckpt");
        std::fs::write(&bad, &bytes).unwrap();
        assert!(load_state(&bad).is_err());
    }

    #[test]
    fn save_refuses_counts_beyond_u32() {
        // a buffer whose length cannot be represented must be a clean
        // error, not a silent truncation. (Allocating > u32::MAX floats is
        // not feasible in a test, so exercise the guard directly.)
        assert!(u32_of(usize::MAX, "test").is_err());
        assert!(u32_of(42, "test").is_ok());
    }
}
