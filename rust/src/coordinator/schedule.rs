//! Learning-rate schedules (paper protocol: cosine annealing with 10%
//! linear warmup; constant for microbenchmarks).

use crate::config::Schedule;

/// LR at 0-based step `t` of `total` steps with peak `lr`.
pub fn lr_at(schedule: Schedule, lr: f64, t: usize, total: usize) -> f64 {
    match schedule {
        Schedule::Constant => lr,
        Schedule::CosineWarmup { warmup_frac, min_ratio } => {
            let total = total.max(1);
            let warmup = ((total as f64 * warmup_frac).round() as usize).max(1);
            if t < warmup {
                // linear ramp ending at lr on step `warmup`
                lr * (t + 1) as f64 / warmup as f64
            } else {
                let prog = (t - warmup) as f64
                    / ((total.saturating_sub(warmup)).max(1)) as f64;
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * prog.min(1.0)).cos());
                let floor = lr * min_ratio;
                floor + (lr - floor) * cos
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const COS: Schedule = Schedule::CosineWarmup { warmup_frac: 0.1, min_ratio: 0.1 };

    #[test]
    fn constant_is_constant() {
        for t in [0, 5, 99] {
            assert_eq!(lr_at(Schedule::Constant, 3e-3, t, 100), 3e-3);
        }
    }

    #[test]
    fn warmup_ramps_to_peak() {
        let total = 100;
        let lrs: Vec<f64> = (0..10).map(|t| lr_at(COS, 1.0, t, total)).collect();
        for w in lrs.windows(2) {
            assert!(w[1] > w[0], "warmup must increase");
        }
        assert!((lrs[9] - 1.0).abs() < 1e-12, "peak at end of warmup: {}", lrs[9]);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let total = 100;
        let end = lr_at(COS, 1.0, total - 1, total);
        assert!((end - 0.1).abs() < 0.02, "end lr {end}");
        let mid = lr_at(COS, 1.0, 55, total);
        assert!(mid < 1.0 && mid > 0.1);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let total = 200;
        let mut prev = f64::INFINITY;
        for t in 20..total {
            let lr = lr_at(COS, 1.0, t, total);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }

    #[test]
    fn degenerate_totals() {
        assert!(lr_at(COS, 1.0, 0, 1) > 0.0);
        assert!(lr_at(COS, 1.0, 0, 0) > 0.0);
    }
}
