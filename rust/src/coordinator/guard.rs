//! Anomaly-guarded stepping: the training loop's defense against loss
//! spikes and numeric blow-ups.
//!
//! A single NaN loss poisons momentum silently — the optimizer update
//! writes NaN into every moment buffer and the run is dead long before
//! the metrics show it. [`StepGuard`] sits between the gradient
//! computation and the optimizer update (via
//! [`TrainBackend::step_gated`](crate::runtime::backend::TrainBackend::step_gated)):
//!
//! ```text
//!            ┌────────────────────────────────────────────────┐
//!            │                  HEALTHY                       │
//!            │  scale ← min(scale × recover, 1.0) per step    │
//!            └───────┬────────────────────────────▲───────────┘
//!         anomalous  │                            │ finite
//!         metrics    ▼                            │ metrics
//!            ┌────────────────────────────────────┴───────────┐
//!            │                  BACKOFF                       │
//!            │  skip update (momentum untouched)              │
//!            │  scale ← max(scale × backoff, min_scale)       │
//!            └───────┬────────────────────────────────────────┘
//!                    │ max_consecutive anomalous steps in a row
//!                    ▼
//!              ABORT (clean error, checkpoint set intact)
//! ```
//!
//! An *anomalous* step has a non-finite loss or gradient norm, or — when
//! `max_grad_norm` is set — a gradient norm above that threshold. The
//! guard's verdict controls the backend: [`Verdict::Skip`] means the
//! optimizer update (and therefore momentum) is never applied, so a bad
//! batch costs one skipped step, not the run. Everything the guard does
//! is surfaced: per-step `lr_scale`/`skipped` columns in metrics.csv and
//! run totals in summary.jsonl.

use crate::runtime::backend::{NamedBuffer, StepMetrics, TrainState};

/// Tuning for the [`StepGuard`] state machine. The defaults halve the
/// LR on each anomaly, floor at 1/64 of the base LR, double back to
/// full LR over good steps, and abort after 8 consecutive anomalies.
#[derive(Clone, Copy, Debug)]
pub struct GuardConfig {
    /// Master switch; `false` makes [`StepGuard::observe`] always apply.
    pub enabled: bool,
    /// LR-scale multiplier per anomalous step; must be in (0, 1).
    pub backoff: f64,
    /// Floor for the LR scale; must be in (0, 1].
    pub min_scale: f64,
    /// LR-scale multiplier per healthy step (capped at 1.0); must be ≥ 1.
    pub recover: f64,
    /// Abort after this many *consecutive* anomalous steps; must be ≥ 1.
    pub max_consecutive: usize,
    /// Treat a finite grad norm above this as anomalous too (loss-spike
    /// guard); 0.0 disables the threshold.
    pub max_grad_norm: f64,
}

impl Default for GuardConfig {
    fn default() -> Self {
        GuardConfig {
            enabled: true,
            backoff: 0.5,
            min_scale: 1.0 / 64.0,
            recover: 2.0,
            max_consecutive: 8,
            max_grad_norm: 0.0,
        }
    }
}

/// The guard's decision for one step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Healthy metrics: apply the optimizer update.
    Apply,
    /// Anomalous metrics: skip the update, leave momentum untouched.
    Skip,
}

/// Per-run anomaly guard state. One instance lives for the whole
/// training loop, and its live state (LR scale + consecutive-bad streak)
/// is stamped into every checkpoint as the synthetic [`GUARD_BUFFER`]
/// optimizer buffer: a `--resume` mid-backoff continues at the backed-off
/// LR and keeps counting the streak toward the abort threshold, instead
/// of silently restoring full LR right where the run was blowing up.
/// Checkpoints without the stamp (older builds) resume healthy.
#[derive(Clone, Debug)]
pub struct StepGuard {
    cfg: GuardConfig,
    scale: f64,
    consecutive_bad: usize,
    skipped: usize,
    min_scale_seen: f64,
}

impl StepGuard {
    /// Validate the config and build a guard in the healthy state.
    pub fn new(cfg: GuardConfig) -> anyhow::Result<Self> {
        anyhow::ensure!(
            cfg.backoff > 0.0 && cfg.backoff < 1.0,
            "guard backoff must be in (0, 1), got {}",
            cfg.backoff
        );
        anyhow::ensure!(
            cfg.min_scale > 0.0 && cfg.min_scale <= 1.0,
            "guard min_scale must be in (0, 1], got {}",
            cfg.min_scale
        );
        anyhow::ensure!(
            cfg.recover >= 1.0,
            "guard recover must be >= 1, got {}",
            cfg.recover
        );
        anyhow::ensure!(
            cfg.max_consecutive >= 1,
            "guard max_consecutive must be >= 1"
        );
        Ok(StepGuard {
            cfg,
            scale: 1.0,
            consecutive_bad: 0,
            skipped: 0,
            min_scale_seen: 1.0,
        })
    }

    fn anomalous(&self, m: &StepMetrics) -> bool {
        !m.loss.is_finite()
            || !m.grad_norm.is_finite()
            || (self.cfg.max_grad_norm > 0.0 && f64::from(m.grad_norm) > self.cfg.max_grad_norm)
    }

    /// Judge one step's metrics and update the state machine. Called by
    /// the training loop from inside the backend's gate, *after* the
    /// gradients exist but *before* the optimizer update.
    pub fn observe(&mut self, step: usize, m: &StepMetrics) -> Verdict {
        if !self.cfg.enabled {
            return Verdict::Apply;
        }
        if self.anomalous(m) {
            self.skipped += 1;
            self.consecutive_bad += 1;
            self.scale = (self.scale * self.cfg.backoff).max(self.cfg.min_scale);
            self.min_scale_seen = self.min_scale_seen.min(self.scale);
            crate::warnln!(
                "step {step}: anomalous metrics (loss {}, grad_norm {}) — \
                 skipping optimizer update, lr scale -> {:.6} \
                 ({}/{} consecutive)",
                m.loss,
                m.grad_norm,
                self.scale,
                self.consecutive_bad,
                self.cfg.max_consecutive
            );
            Verdict::Skip
        } else {
            self.consecutive_bad = 0;
            self.scale = (self.scale * self.cfg.recover).min(1.0);
            Verdict::Apply
        }
    }

    /// Error out if the run has hit `max_consecutive` anomalous steps in
    /// a row — the loop calls this after each step so the abort is a
    /// clean error with the checkpoint set intact, never a panic.
    pub fn check_abort(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.consecutive_bad < self.cfg.max_consecutive,
            "aborting run: {} consecutive anomalous steps (non-finite or \
             exploding loss/grad-norm) — LR backoff reached scale {:.6} \
             without recovery; the newest valid checkpoint is intact",
            self.consecutive_bad,
            self.scale
        );
        Ok(())
    }

    /// The multiplier the loop applies to the scheduled LR this step.
    pub fn lr_scale(&self) -> f64 {
        self.scale
    }

    /// Total steps skipped so far.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// The lowest LR scale the backoff reached over the run.
    pub fn min_scale_seen(&self) -> f64 {
        self.min_scale_seen
    }

    /// Anomalous steps in the current consecutive streak.
    pub fn consecutive_bad(&self) -> usize {
        self.consecutive_bad
    }

    /// The persistable backoff state: `(lr_scale, consecutive_bad)`.
    pub fn snapshot(&self) -> (f64, usize) {
        (self.scale, self.consecutive_bad)
    }

    /// Restore a [`StepGuard::snapshot`] taken by the run that wrote the
    /// checkpoint. The scale is clamped to `[min_scale, 1.0]` under the
    /// *current* config (the resume may tighten or loosen the floor), and
    /// non-finite values — only reachable through a hand-edited
    /// checkpoint — are ignored, leaving the guard healthy.
    pub fn restore(&mut self, scale: f64, consecutive_bad: usize) {
        if !scale.is_finite() {
            return;
        }
        self.scale = scale.clamp(self.cfg.min_scale, 1.0);
        self.consecutive_bad = consecutive_bad;
        self.min_scale_seen = self.min_scale_seen.min(self.scale);
    }
}

/// Name of the synthetic optimizer buffer that carries guard state in a
/// checkpoint. The double-underscore namespace can never collide with a
/// real `{task}.{key}` optimizer buffer, so the stamp is v3-compatible:
/// old readers ignore it, old checkpoints simply lack it.
pub const GUARD_BUFFER: &str = "__guard__";

/// Append the guard's [`StepGuard::snapshot`] to a checkpoint state as
/// the [`GUARD_BUFFER`] optimizer buffer.
///
/// Layout: 3 f32 slots. The f64 LR scale travels bit-exactly as its high
/// and low 32-bit halves (checkpoint f32 I/O is bit-preserving, and
/// integer-through-f32-bits is the format's idiom for counters), and the
/// streak count rides the third slot's bits. A rounded-to-f32 scale
/// would break bit-exact resume for non-power-of-two backoff factors.
pub fn stamp_guard(state: &mut TrainState, guard: &StepGuard) {
    let (scale, bad) = guard.snapshot();
    let bits = scale.to_bits();
    state.opt.push(NamedBuffer {
        name: GUARD_BUFFER.to_string(),
        data: vec![
            f32::from_bits((bits >> 32) as u32),
            f32::from_bits(bits as u32),
            f32::from_bits(bad as u32),
        ],
    });
}

/// Remove the [`GUARD_BUFFER`] stamp from a loaded checkpoint state and
/// decode it to `(lr_scale, consecutive_bad)`.
///
/// Must run *before* the state reaches a backend's `import_state` — the
/// backends insist on consuming every optimizer buffer, and this one is
/// the coordinator's, not theirs. Returns `None` (leaving the state
/// untouched) when the stamp is absent or malformed, so pre-stamp
/// checkpoints keep loading and resume with a healthy guard.
pub fn extract_guard(state: &mut TrainState) -> Option<(f64, usize)> {
    let pos = state.opt.iter().position(|b| b.name == GUARD_BUFFER)?;
    let buf = state.opt.remove(pos);
    if buf.data.len() != 3 {
        return None;
    }
    let hi = buf.data[0].to_bits() as u64;
    let lo = buf.data[1].to_bits() as u64;
    let scale = f64::from_bits((hi << 32) | lo);
    let bad = buf.data[2].to_bits() as usize;
    Some((scale, bad))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn good() -> StepMetrics {
        StepMetrics { loss: 2.5, grad_norm: 0.8, clipped: 0.0 }
    }

    fn nan() -> StepMetrics {
        StepMetrics { loss: f32::NAN, grad_norm: f32::NAN, clipped: 0.0 }
    }

    #[test]
    fn healthy_steps_stay_at_full_scale() {
        let mut g = StepGuard::new(GuardConfig::default()).unwrap();
        for step in 0..10 {
            assert_eq!(g.observe(step, &good()), Verdict::Apply);
        }
        assert_eq!(g.lr_scale(), 1.0);
        assert_eq!(g.skipped(), 0);
        assert_eq!(g.min_scale_seen(), 1.0);
        g.check_abort().unwrap();
    }

    #[test]
    fn nan_skips_and_backs_off_then_recovers() {
        let mut g = StepGuard::new(GuardConfig::default()).unwrap();
        assert_eq!(g.observe(0, &nan()), Verdict::Skip);
        assert_eq!(g.lr_scale(), 0.5);
        assert_eq!(g.observe(1, &nan()), Verdict::Skip);
        assert_eq!(g.lr_scale(), 0.25);
        assert_eq!(g.skipped(), 2);
        assert_eq!(g.consecutive_bad(), 2);
        // one good step halves the distance back (recover = 2.0)
        assert_eq!(g.observe(2, &good()), Verdict::Apply);
        assert_eq!(g.lr_scale(), 0.5);
        assert_eq!(g.consecutive_bad(), 0);
        // full recovery caps at 1.0
        assert_eq!(g.observe(3, &good()), Verdict::Apply);
        assert_eq!(g.observe(4, &good()), Verdict::Apply);
        assert_eq!(g.lr_scale(), 1.0);
        assert_eq!(g.min_scale_seen(), 0.25);
        assert_eq!(g.skipped(), 2, "recovery doesn't un-count skips");
    }

    #[test]
    fn backoff_floors_at_min_scale() {
        let mut g = StepGuard::new(GuardConfig {
            max_consecutive: 100,
            ..GuardConfig::default()
        })
        .unwrap();
        for step in 0..20 {
            g.observe(step, &nan());
        }
        assert_eq!(g.lr_scale(), 1.0 / 64.0);
        assert_eq!(g.min_scale_seen(), 1.0 / 64.0);
    }

    #[test]
    fn aborts_after_max_consecutive_only() {
        let mut g = StepGuard::new(GuardConfig {
            max_consecutive: 3,
            ..GuardConfig::default()
        })
        .unwrap();
        g.observe(0, &nan());
        g.observe(1, &nan());
        g.check_abort().unwrap(); // 2 < 3: still trying
        // a good step resets the streak entirely
        g.observe(2, &good());
        g.observe(3, &nan());
        g.observe(4, &nan());
        g.check_abort().unwrap();
        g.observe(5, &nan());
        let err = g.check_abort().unwrap_err().to_string();
        assert!(err.contains("anomalous"), "{err}");
        assert!(err.contains("checkpoint"), "{err}");
    }

    #[test]
    fn infinite_loss_and_grad_spikes_are_anomalous() {
        let mut g = StepGuard::new(GuardConfig {
            max_grad_norm: 100.0,
            ..GuardConfig::default()
        })
        .unwrap();
        let inf = StepMetrics { loss: f32::INFINITY, grad_norm: 1.0, clipped: 0.0 };
        assert_eq!(g.observe(0, &inf), Verdict::Skip);
        let spike = StepMetrics { loss: 3.0, grad_norm: 5000.0, clipped: 1.0 };
        assert_eq!(g.observe(1, &spike), Verdict::Skip);
        let fine = StepMetrics { loss: 3.0, grad_norm: 99.0, clipped: 0.0 };
        assert_eq!(g.observe(2, &fine), Verdict::Apply);
    }

    #[test]
    fn disabled_guard_never_intervenes() {
        let mut g = StepGuard::new(GuardConfig {
            enabled: false,
            ..GuardConfig::default()
        })
        .unwrap();
        for step in 0..20 {
            assert_eq!(g.observe(step, &nan()), Verdict::Apply);
        }
        assert_eq!(g.lr_scale(), 1.0);
        assert_eq!(g.skipped(), 0);
        g.check_abort().unwrap();
    }

    #[test]
    fn stamp_and_extract_roundtrip_bit_exactly() {
        let mut g = StepGuard::new(GuardConfig { backoff: 0.3, ..GuardConfig::default() })
            .unwrap();
        g.observe(0, &nan());
        g.observe(1, &nan());
        let (scale, bad) = g.snapshot();
        assert_eq!(bad, 2);
        assert!(scale < 0.1, "0.3^2 = {scale}");
        let mut state = TrainState { step: 7, params: vec![], opt: vec![] };
        stamp_guard(&mut state, &g);
        assert_eq!(state.opt.len(), 1);
        assert_eq!(state.opt[0].name, GUARD_BUFFER);
        let (rs, rb) = extract_guard(&mut state).unwrap();
        // 0.3 is not a power of two: only a bit-exact f64 round-trip
        // reproduces the backed-off scale exactly
        assert_eq!(rs.to_bits(), scale.to_bits());
        assert_eq!(rb, bad);
        assert!(state.opt.is_empty(), "extract must remove the stamp");
        assert_eq!(extract_guard(&mut state), None, "second extract finds nothing");
    }

    #[test]
    fn restore_continues_the_backoff_and_streak() {
        let mut a = StepGuard::new(GuardConfig { max_consecutive: 4, ..GuardConfig::default() })
            .unwrap();
        a.observe(0, &nan());
        a.observe(1, &nan());
        let (scale, bad) = a.snapshot();
        // "resume": a fresh guard picks up where the old one stopped
        let mut b = StepGuard::new(GuardConfig { max_consecutive: 4, ..GuardConfig::default() })
            .unwrap();
        b.restore(scale, bad);
        assert_eq!(b.lr_scale(), 0.25);
        assert_eq!(b.consecutive_bad(), 2);
        assert_eq!(b.min_scale_seen(), 0.25);
        b.observe(2, &nan());
        b.observe(3, &nan());
        let err = b.check_abort().unwrap_err().to_string();
        assert!(err.contains("4 consecutive"), "streak must span the resume: {err}");
    }

    #[test]
    fn restore_clamps_to_the_current_floor_and_ignores_garbage() {
        let mut g = StepGuard::new(GuardConfig { min_scale: 0.25, ..GuardConfig::default() })
            .unwrap();
        g.restore(1e-9, 3);
        assert_eq!(g.lr_scale(), 0.25, "clamped up to the new floor");
        assert_eq!(g.consecutive_bad(), 3);
        g.restore(7.0, 0);
        assert_eq!(g.lr_scale(), 1.0, "clamped down to 1.0");
        let before = g.snapshot();
        g.restore(f64::NAN, 9);
        assert_eq!(g.snapshot(), before, "non-finite scale is ignored");
    }

    #[test]
    fn extract_tolerates_malformed_stamps() {
        let mut state = TrainState {
            step: 0,
            params: vec![],
            opt: vec![NamedBuffer { name: GUARD_BUFFER.into(), data: vec![1.0] }],
        };
        assert_eq!(extract_guard(&mut state), None);
        assert!(state.opt.is_empty(), "malformed stamp is still consumed");
        // and a state with only real buffers is untouched
        let mut state = TrainState {
            step: 0,
            params: vec![],
            opt: vec![NamedBuffer { name: "embed.momentum".into(), data: vec![0.0] }],
        };
        assert_eq!(extract_guard(&mut state), None);
        assert_eq!(state.opt.len(), 1);
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        let bad = |f: fn(&mut GuardConfig)| {
            let mut c = GuardConfig::default();
            f(&mut c);
            StepGuard::new(c).is_err()
        };
        assert!(bad(|c| c.backoff = 0.0));
        assert!(bad(|c| c.backoff = 1.0));
        assert!(bad(|c| c.min_scale = 0.0));
        assert!(bad(|c| c.min_scale = 1.5));
        assert!(bad(|c| c.recover = 0.5));
        assert!(bad(|c| c.max_consecutive = 0));
        assert!(StepGuard::new(GuardConfig::default()).is_ok());
    }
}
