//! Sweep executor: run a grid of (optimizer, lr) training jobs and collect
//! final validation perplexities (paper Tables 9–13, 20, 21).
//!
//! Jobs fan out across worker threads. Each job builds its own backend
//! through [`train::run_auto`] — native jobs need nothing but the
//! config, and PJRT jobs each own a private engine (client handles are
//! not `Send`; per-job compile caches are fine at sweep model scales).

use std::path::{Path, PathBuf};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use crate::config::RunConfig;
use crate::coordinator::train;
use crate::info;

/// One grid cell request.
#[derive(Clone, Debug)]
pub struct SweepJob {
    /// Optimizer name (validated against the registry by the run).
    pub optimizer: String,
    /// Peak matrix learning rate for this cell.
    pub lr: f64,
}

/// One grid cell outcome.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Optimizer name of the cell.
    pub optimizer: String,
    /// Peak matrix learning rate of the cell.
    pub lr: f64,
    /// Final validation perplexity.
    pub final_ppl: f64,
    /// Final held-out loss.
    pub final_eval_loss: f64,
    /// Wall-clock seconds of the run.
    pub seconds: f64,
}

/// Run `jobs` over `base` (model/steps/data fixed, optimizer+lr varied),
/// with up to `workers` threads. Results keep job order.
pub fn run_grid(
    base: &RunConfig,
    jobs: &[SweepJob],
    workers: usize,
) -> anyhow::Result<Vec<SweepCell>> {
    let workers = workers.clamp(1, jobs.len().max(1));
    let queue: Arc<Mutex<Vec<(usize, SweepJob)>>> = Arc::new(Mutex::new(
        jobs.iter().cloned().enumerate().rev().collect(),
    ));
    let (tx, rx) = channel::<(usize, anyhow::Result<SweepCell>)>();

    std::thread::scope(|scope| {
        for wid in 0..workers {
            let queue = queue.clone();
            let tx = tx.clone();
            let base = base.clone();
            scope.spawn(move || loop {
                let job = { queue.lock().unwrap().pop() };
                let Some((idx, job)) = job else { break };
                let mut cfg = base.clone();
                cfg.optimizer = job.optimizer.clone();
                cfg.lr = job.lr;
                cfg.out_dir = sweep_dir(&base.out_dir, &job);
                // divide the stepping-thread budget across concurrent
                // jobs: each native job would otherwise spawn a
                // full-width StepPlan pool and oversubscribe the cores
                // (bits are plan_threads-invariant, so this is safe)
                if workers > 1 && cfg.plan_threads == 0 {
                    cfg.plan_threads =
                        (crate::tensor::kernels::num_threads() / workers).max(1);
                }
                info!(
                    "sweep[{idx}] {} {} lr={:.2e} ({} backend, worker {wid})",
                    cfg.model,
                    cfg.optimizer,
                    cfg.lr,
                    cfg.backend.name()
                );
                let result = train::run_auto(&cfg).map(|r| SweepCell {
                    optimizer: job.optimizer,
                    lr: job.lr,
                    final_ppl: r.final_ppl,
                    final_eval_loss: r.final_eval_loss,
                    seconds: r.seconds,
                });
                let _ = tx.send((idx, result));
            });
        }
        drop(tx);
        let mut cells: Vec<Option<SweepCell>> = vec![None; jobs.len()];
        for (idx, result) in rx {
            cells[idx] = Some(result?);
        }
        cells
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                c.ok_or_else(|| anyhow::anyhow!("sweep job {i} never finished"))
            })
            .collect()
    })
}

fn sweep_dir(base: &Path, job: &SweepJob) -> PathBuf {
    base.join(format!("{}_lr{:.0e}", job.optimizer, job.lr).replace(['+', '.'], ""))
}

/// Render cells as a paper-style block: one row per optimizer with its LR
/// grid and perplexities (Tables 9–13 layout).
pub fn format_table(model: &str, cells: &[SweepCell]) -> String {
    use std::fmt::Write;
    let mut by_opt: Vec<(String, Vec<&SweepCell>)> = Vec::new();
    for c in cells {
        match by_opt.iter_mut().find(|(o, _)| *o == c.optimizer) {
            Some((_, v)) => v.push(c),
            None => by_opt.push((c.optimizer.clone(), vec![c])),
        }
    }
    let mut out = String::new();
    let _ = writeln!(out, "LR sweep on {model} (validation perplexity, lower is better)");
    for (opt, mut row) in by_opt {
        row.sort_by(|a, b| a.lr.partial_cmp(&b.lr).unwrap());
        let _ = write!(out, "  Matrix LR |");
        for c in &row {
            let _ = write!(out, " {:>9.2e} |", c.lr);
        }
        let _ = writeln!(out);
        let _ = write!(out, "  {opt:<9} |");
        let best = row
            .iter()
            .map(|c| c.final_ppl)
            .fold(f64::INFINITY, f64::min);
        for c in &row {
            let mark = if (c.final_ppl - best).abs() < 1e-9 { "*" } else { " " };
            let _ = write!(out, " {:>8.3}{mark}|", c.final_ppl);
        }
        let _ = writeln!(out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_dir_is_unique_per_job() {
        let base = PathBuf::from("runs/x");
        let a = sweep_dir(&base, &SweepJob { optimizer: "rmnp".into(), lr: 1e-3 });
        let b = sweep_dir(&base, &SweepJob { optimizer: "rmnp".into(), lr: 2e-3 });
        let c = sweep_dir(&base, &SweepJob { optimizer: "muon".into(), lr: 1e-3 });
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn format_table_marks_best() {
        let cells = vec![
            SweepCell {
                optimizer: "rmnp".into(),
                lr: 1e-3,
                final_ppl: 12.0,
                final_eval_loss: 2.48,
                seconds: 1.0,
            },
            SweepCell {
                optimizer: "rmnp".into(),
                lr: 2e-3,
                final_ppl: 11.0,
                final_eval_loss: 2.40,
                seconds: 1.0,
            },
        ];
        let t = format_table("gpt2_tiny", &cells);
        assert!(t.contains("11.000*"), "{t}");
        assert!(t.contains("12.000 "), "{t}");
    }
}
