//! Metric logging: per-step CSV series + JSONL run summaries.
//!
//! Every training run writes `metrics.csv` (step, lr, loss, grad_norm,
//! clipped, eval_loss?, lr_scale, skipped) and optionally `dominance.csv`
//! (per-matrix r statistics). The report harnesses read these back to
//! print the paper's tables/series, so the writer/reader pair round-trips
//! exactly.
//!
//! Disk-touching operations (flush, JSONL append) go through the bounded
//! retry policy in [`crate::util::retry`], so a transient `EAGAIN` or
//! momentary full-disk blip doesn't kill a long run mid-epoch; and
//! [`CsvWriter`] flushes on drop (with the same retry, loudly on
//! failure) so buffered rows survive early returns.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create (or truncate) `path` and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len() })
    }

    /// Reopen an existing CSV for appending: the header row already on
    /// disk determines the arity (how resumed training runs continue
    /// their `metrics.csv` in place).
    pub fn append(path: &Path) -> anyhow::Result<Self> {
        let first = BufReader::new(File::open(path)?)
            .lines()
            .next()
            .ok_or_else(|| anyhow::anyhow!("cannot append to headerless {}", path.display()))??;
        let columns = first.split(',').count();
        let out = BufWriter::new(
            std::fs::OpenOptions::new().append(true).open(path)?,
        );
        Ok(CsvWriter { out, columns })
    }

    /// Write one row; NaN renders as empty cell.
    pub fn row(&mut self, values: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(values.len() == self.columns, "csv row arity");
        let mut line = String::new();
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            if v.is_nan() {
                // empty cell
            } else {
                write!(line, "{v}")?;
            }
        }
        writeln!(self.out, "{line}")?;
        Ok(())
    }

    /// Flush buffered rows to disk (retried on transient IO errors).
    pub fn flush(&mut self) -> anyhow::Result<()> {
        let out = &mut self.out;
        crate::util::retry::io_retry("csv flush", || {
            out.flush()?;
            Ok(())
        })
    }
}

impl Drop for CsvWriter {
    fn drop(&mut self) {
        // best-effort: rows buffered when the loop errors out (e.g. a
        // guard abort) must still reach disk, and a flush failure here
        // should be loud, not the BufWriter's silent drop
        if let Err(e) = self.flush() {
            crate::warnln!("csv flush on drop failed: {e}");
        }
    }
}

/// Parsed CSV: header + rows (empty cells come back as NaN).
pub struct CsvData {
    /// Column names from the header row.
    pub header: Vec<String>,
    /// Data rows in file order.
    pub rows: Vec<Vec<f64>>,
}

impl CsvData {
    /// Read and parse a whole CSV file.
    pub fn read(path: &Path) -> anyhow::Result<Self> {
        let f = BufReader::new(File::open(path)?);
        let mut lines = f.lines();
        let header: Vec<String> = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("empty csv"))??
            .split(',')
            .map(String::from)
            .collect();
        let mut rows = Vec::new();
        for line in lines {
            let line = line?;
            if line.is_empty() {
                continue;
            }
            rows.push(
                line.split(',')
                    .map(|c| c.parse::<f64>().unwrap_or(f64::NAN))
                    .collect(),
            );
        }
        Ok(CsvData { header, rows })
    }

    /// Column by name.
    pub fn column(&self, name: &str) -> anyhow::Result<Vec<f64>> {
        let idx = self
            .header
            .iter()
            .position(|h| h == name)
            .ok_or_else(|| anyhow::anyhow!("csv: no column `{name}`"))?;
        Ok(self.rows.iter().map(|r| r[idx]).collect())
    }

    /// Column with NaN entries removed.
    pub fn column_dense(&self, name: &str) -> anyhow::Result<Vec<f64>> {
        Ok(self.column(name)?.into_iter().filter(|v| !v.is_nan()).collect())
    }
}

/// Append one JSON object per line to a run-summary file. The open +
/// write is retried on transient IO errors; the whole line is re-written
/// per attempt, so readers that take the *last* line (summary consumers
/// do) always see a complete record once any attempt lands.
pub fn append_jsonl(path: &Path, fields: &[(&str, String)]) -> anyhow::Result<()> {
    let mut line = String::from("{");
    for (i, (k, v)) in fields.iter().enumerate() {
        if i > 0 {
            line.push(',');
        }
        write!(line, "{k:?}:{v}")?;
    }
    line.push_str("}\n");
    crate::util::retry::io_retry("jsonl append", || {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(line.as_bytes())?;
        Ok(())
    })
}

/// Quote a string for JSONL values.
pub fn json_str(s: &str) -> String {
    format!("{s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "rmnp-metrics-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn csv_roundtrip_with_gaps() {
        let path = tmpdir().join("m.csv");
        {
            let mut w = CsvWriter::create(&path, &["step", "loss", "eval"]).unwrap();
            w.row(&[0.0, 3.5, f64::NAN]).unwrap();
            w.row(&[1.0, 3.2, 3.4]).unwrap();
            w.flush().unwrap();
        }
        let data = CsvData::read(&path).unwrap();
        assert_eq!(data.header, vec!["step", "loss", "eval"]);
        assert_eq!(data.column("loss").unwrap(), vec![3.5, 3.2]);
        let eval = data.column("eval").unwrap();
        assert!(eval[0].is_nan() && eval[1] == 3.4);
        assert_eq!(data.column_dense("eval").unwrap(), vec![3.4]);
        assert!(data.column("nope").is_err());
    }

    #[test]
    fn csv_append_continues_in_place() {
        let path = tmpdir().join("resume.csv");
        {
            let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row(&[0.0, 3.5]).unwrap();
            w.flush().unwrap();
        }
        {
            let mut w = CsvWriter::append(&path).unwrap();
            w.row(&[1.0, 3.1]).unwrap();
            assert!(w.row(&[1.0]).is_err(), "arity comes from the header");
            w.flush().unwrap();
        }
        let data = CsvData::read(&path).unwrap();
        assert_eq!(data.rows.len(), 2);
        assert_eq!(data.column("loss").unwrap(), vec![3.5, 3.1]);
        assert!(CsvWriter::append(&tmpdir().join("missing.csv")).is_err());
    }

    #[test]
    fn csv_arity_enforced() {
        let path = tmpdir().join("a.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        assert!(w.row(&[1.0]).is_err());
    }

    #[test]
    fn jsonl_appends() {
        let path = tmpdir().join("runs.jsonl");
        let _ = std::fs::remove_file(&path);
        append_jsonl(&path, &[("name", json_str("x")), ("ppl", "12.5".into())]).unwrap();
        append_jsonl(&path, &[("name", json_str("y")), ("ppl", "11.0".into())]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let j = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(j.req_str("name").unwrap(), "x");
        assert_eq!(j.get("ppl").unwrap().as_f64(), Some(12.5));
    }
}
