//! L3 coordinator: training loop, LR schedules, metric logging,
//! checkpointing, and the multi-threaded sweep executor.
//!
//! The device-facing pieces (`train`, `sweep`) drive PJRT and are
//! gated behind the `pjrt` feature; schedules, metrics, and checkpoint
//! I/O are pure host code and always available.

// The crate-level `missing_docs` warning is enforced for tensor/ and
// optim/; this module's full docs pass is still pending (ROADMAP.md).
#![allow(missing_docs)]

pub mod checkpoint;
pub mod metrics;
pub mod schedule;
#[cfg(feature = "pjrt")]
pub mod sweep;
#[cfg(feature = "pjrt")]
pub mod train;

pub use schedule::lr_at;
#[cfg(feature = "pjrt")]
pub use sweep::{run_grid, SweepCell, SweepJob};
#[cfg(feature = "pjrt")]
pub use train::{run, RunResult};
