//! L3 coordinator: training loop, LR schedules, metric logging,
//! checkpointing, and the multi-threaded sweep executor.

pub mod checkpoint;
pub mod metrics;
pub mod schedule;
pub mod sweep;
pub mod train;

pub use schedule::lr_at;
pub use sweep::{run_grid, SweepCell, SweepJob};
pub use train::{run, RunResult};
