//! L3 coordinator: training loop, LR schedules, metric logging,
//! checkpointing, and the multi-threaded sweep executor.
//!
//! Everything here is host code and always available: [`train::run`]
//! drives any [`TrainBackend`](crate::runtime::TrainBackend) — the
//! native backend by default, the PJRT session when built with the
//! `pjrt` feature — and [`sweep::run_grid`] fans training jobs out
//! across worker threads through the same abstraction.

pub mod checkpoint;
pub mod guard;
pub mod metrics;
pub mod schedule;
pub mod sweep;
pub mod train;

pub use guard::{GuardConfig, StepGuard, Verdict};
pub use schedule::lr_at;
pub use sweep::{run_grid, SweepCell, SweepJob};
pub use train::{run, run_auto, RunResult};
