//! The training loop: config → data pipeline → backend stepping →
//! metrics/eval/dominance/checkpoints.
//!
//! The loop is generic over [`TrainBackend`]: [`run`] drives any
//! backend, and [`run_auto`] builds the one `cfg.backend` selects — the
//! always-available [`NativeBackend`](crate::runtime::NativeBackend)
//! (the default), or the PJRT session when the crate is built with the
//! `pjrt` feature.
//!
//! ## Resume
//!
//! With `cfg.resume = true` and a checkpoint in `cfg.out_dir`, the run
//! restores the newest checkpoint that *validates* (header, CRCs, step
//! stamp — [`checkpoint::latest_valid`] walks back over torn ones)
//! through the backend's named-buffer state (parameters **and**
//! optimizer state, bit-exactly), fast-forwards the train/eval data
//! streams to the saved step, and continues — the continued trajectory
//! is bit-identical to an uninterrupted run for any `perf.plan_threads`
//! (asserted by `tests/native_train.rs` and `tests/fault_injection.rs`).
//! The anomaly guard's backoff state rides along in the checkpoint
//! ([`guard::stamp_guard`]), so resuming mid-backoff continues at the
//! backed-off LR with the abort streak intact. If checkpoints exist but
//! none validates, resume is a clean error, never a silent restart from
//! scratch.
//!
//! ## Anomaly guard
//!
//! Each step runs through [`TrainBackend::step_gated`] with a
//! [`StepGuard`] deciding between the gradient computation and the
//! optimizer update: non-finite loss/grad-norm skips the update (momentum
//! untouched), backs off the LR scale, and recovers over healthy steps;
//! `cfg.guard_max_bad` consecutive anomalies abort the run cleanly with
//! the checkpoint set intact. Per-step `lr_scale`/`skipped` land in
//! metrics.csv; run totals land in summary.jsonl.

use std::path::Path;

use crate::config::{BackendKind, DataSpec, RunConfig};
use crate::coordinator::checkpoint;
use crate::coordinator::guard::{self, GuardConfig, StepGuard, Verdict};
use crate::coordinator::metrics::{append_jsonl, json_str, CsvWriter};
use crate::coordinator::schedule::lr_at;
use crate::data::corpus::token_source;
use crate::data::images::ImageSource;
use crate::data::loader::BatchLoader;
use crate::runtime::backend::StepMetrics;
use crate::runtime::{Batch, BatchShape, NativeBackend, TrainBackend};
use crate::util::Timer;
use crate::{debugln, info, warnln};

/// Outcome of a full training run.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Training loss on the last batch.
    pub final_train_loss: f64,
    /// Held-out loss of the final evaluation.
    pub final_eval_loss: f64,
    /// exp(final_eval_loss) — the paper reports validation perplexity.
    pub final_ppl: f64,
    /// Fraction of steps where gradient clipping engaged.
    pub mean_clip_rate: f64,
    /// Steps executed by this invocation (excludes restored steps).
    pub steps: usize,
    /// Wall-clock seconds of this invocation.
    pub seconds: f64,
    /// mean train loss over the last 10% of steps (smoother than the last
    /// point for small-scale runs)
    pub tail_train_loss: f64,
    /// Steps whose optimizer update the anomaly guard skipped.
    pub skipped_steps: usize,
}

enum Feed {
    Tokens(BatchLoader<Vec<i32>>),
    Images(BatchLoader<(Vec<f32>, Vec<i32>)>),
}

impl Feed {
    /// Draw and discard `n` batches — how a resumed run fast-forwards the
    /// deterministic stream to the position an uninterrupted run would be
    /// at.
    fn skip(&self, n: usize) {
        for _ in 0..n {
            match self {
                Feed::Tokens(l) => {
                    let _ = l.next();
                }
                Feed::Images(l) => {
                    let _ = l.next();
                }
            }
        }
    }
}

fn make_feed(backend: &dyn TrainBackend, cfg: &RunConfig, split: u64) -> anyhow::Result<Feed> {
    match backend.batch_shape() {
        BatchShape::Images { batch, hw, pixels } => {
            anyhow::ensure!(
                cfg.data == DataSpec::Images,
                "vision models need data.corpus = \"images\""
            );
            let mut src = ImageSource::new(10, hw, cfg.seed, split);
            Ok(Feed::Images(BatchLoader::spawn(4, move || {
                let mut images = vec![0.0f32; pixels];
                let mut labels = vec![0i32; batch];
                src.fill(batch, &mut images, &mut labels);
                (images, labels)
            })))
        }
        BatchShape::Tokens { rows, cols } => {
            anyhow::ensure!(
                cfg.data != DataSpec::Images,
                "LM models need a token corpus, got images"
            );
            let count = rows * cols;
            let mut src = token_source(cfg.data, cfg.seed, split);
            Ok(Feed::Tokens(BatchLoader::spawn(4, move || {
                let mut tokens = vec![0i32; count];
                src.fill(&mut tokens);
                tokens
            })))
        }
    }
}

/// Build the backend `cfg` selects and run the job to completion.
pub fn run_auto(cfg: &RunConfig) -> anyhow::Result<RunResult> {
    // apply perf knobs BEFORE the backend exists: NativeBackend sizes its
    // StepPlan pool from the kernel thread count when plan_threads = 0,
    // so `perf.threads` must already be in effect (run() re-applies,
    // which is idempotent, for callers that build backends themselves)
    cfg.apply_perf()?;
    match cfg.backend {
        BackendKind::Native => {
            let mut backend = NativeBackend::new_with_precision(
                &cfg.model,
                &cfg.optimizer,
                cfg.seed,
                cfg.plan_threads,
                cfg.precision_mode()?,
            )?;
            run(&mut backend, cfg)
        }
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => {
            let engine = crate::runtime::Engine::new(&cfg.artifacts)?;
            let mut backend = crate::runtime::TrainSession::new(
                &engine,
                &cfg.model,
                &cfg.optimizer,
                cfg.seed as i32,
            )?;
            run(&mut backend, cfg)
        }
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => anyhow::bail!(
            "runtime.backend = \"pjrt\" needs a build with `--features pjrt` \
             (and real XLA bindings); the native backend runs offline"
        ),
    }
}

/// Run one training job on `backend` to completion, writing metrics
/// under `cfg.out_dir`. Returns the summary.
pub fn run(backend: &mut dyn TrainBackend, cfg: &RunConfig) -> anyhow::Result<RunResult> {
    let t_start = std::time::Instant::now();
    cfg.apply_perf()?;
    std::fs::create_dir_all(&cfg.out_dir)?;

    // resume: restore the newest *valid* checkpoint before touching the
    // feeds — latest_valid verifies header/CRCs/step and walks back over
    // torn candidates, logging what it skipped
    let mut start_step = 0usize;
    let mut resume_guard: Option<(f64, usize)> = None;
    if cfg.resume {
        match checkpoint::latest_valid(&cfg.out_dir)? {
            Some((step, path, mut state)) => {
                // the guard stamp is the coordinator's synthetic opt
                // buffer — strip it before the backend import, which
                // insists on consuming every buffer itself
                resume_guard = guard::extract_guard(&mut state);
                backend.import_state(&state)?;
                start_step = step;
                info!(
                    "resumed {} from {} (step {start_step})",
                    cfg.tag(),
                    path.display()
                );
            }
            None => {
                // checkpoints on disk but none validates: refusing is the
                // only safe move — silently restarting from scratch would
                // overwrite the evidence and lie about the trajectory
                if let Some((step, path)) = checkpoint::latest(&cfg.out_dir)? {
                    anyhow::bail!(
                        "resume requested but no checkpoint in {} validates \
                         (newest candidate is step-{step}: {}); refusing to \
                         restart from scratch",
                        cfg.out_dir.display(),
                        path.display()
                    );
                }
                // empty dir: a fresh run is what the caller asked for
            }
        }
    }
    anyhow::ensure!(
        start_step <= cfg.steps,
        "checkpoint is at step {start_step} but the run only has {} steps",
        cfg.steps
    );

    let train_feed = make_feed(backend, cfg, 0)?;
    let eval_feed = make_feed(backend, cfg, 1)?;
    if start_step > 0 {
        // replay the deterministic streams to where the saved run was
        train_feed.skip(start_step);
        if cfg.eval_every > 0 {
            // eval_now draws n.max(1) batches per eval event — mirror it
            eval_feed.skip((start_step / cfg.eval_every) * cfg.eval_batches.max(1));
        }
    }

    const METRIC_COLUMNS: [&str; 8] = [
        "step", "lr", "loss", "grad_norm", "clipped", "eval_loss", "lr_scale", "skipped",
    ];
    let metrics_path = cfg.out_dir.join("metrics.csv");
    let mut csv = if start_step > 0 && metrics_path.exists() {
        // drop rows the interrupted run wrote past the restored step (so
        // the continued file has no duplicate/out-of-order step entries)
        // and migrate pre-guard headers to the current arity
        prepare_resumed_csv(&metrics_path, start_step, &METRIC_COLUMNS)?;
        CsvWriter::append(&metrics_path)?
    } else {
        CsvWriter::create(&metrics_path, &METRIC_COLUMNS)?
    };
    let mut dom_csv: Option<CsvWriter> = None;

    let mut guard = StepGuard::new(GuardConfig {
        enabled: cfg.guard,
        backoff: cfg.guard_backoff,
        min_scale: cfg.guard_min_scale,
        recover: cfg.guard_recover,
        max_consecutive: cfg.guard_max_bad.max(1),
        max_grad_norm: cfg.guard_max_grad_norm,
    })?;
    if let Some((scale, bad)) = resume_guard {
        // resume mid-backoff at the backed-off LR with the streak intact
        // — restoring full LR right where the run was blowing up is how
        // a NaN burst used to survive a --resume
        guard.restore(scale, bad);
        if guard.lr_scale() < 1.0 || guard.consecutive_bad() > 0 {
            info!(
                "guard state restored: lr scale {:.6}, {} consecutive anomalous",
                guard.lr_scale(),
                guard.consecutive_bad()
            );
        }
    }

    let mut timer = Timer::new();
    let mut clip_sum = 0.0f64;
    let mut tail_losses = Vec::new();
    let tail_from = cfg.steps - (cfg.steps / 10).max(1);
    let mut last_train = f64::NAN;
    let mut last_eval = f64::NAN;

    fn eval_now(
        backend: &mut dyn TrainBackend,
        feed: &Feed,
        n: usize,
    ) -> anyhow::Result<f64> {
        let mut acc = 0.0;
        for _ in 0..n.max(1) {
            let loss = match feed {
                Feed::Tokens(l) => {
                    let toks = l.next();
                    backend.eval(&Batch::Tokens(&toks))?
                }
                Feed::Images(l) => {
                    let (images, labels) = l.next();
                    backend.eval(&Batch::Images { images: &images, labels: &labels })?
                }
            };
            acc += loss as f64;
        }
        Ok(acc / n.max(1) as f64)
    }

    for step in start_step..cfg.steps {
        crate::util::fault::begin_step(step as u64);
        // capture the guard's scale BEFORE the step: a backed-off scale
        // set by step N's anomaly applies from step N+1
        let lr_scale = guard.lr_scale();
        let lr = (lr_at(cfg.schedule, cfg.lr, step, cfg.steps) * lr_scale) as f32;
        let mut verdict = Verdict::Apply;
        let (metrics, applied) = {
            let guard = &mut guard;
            let verdict = &mut verdict;
            let decide = &mut |m: &StepMetrics| {
                *verdict = guard.observe(step, m);
                *verdict == Verdict::Apply
            };
            match &train_feed {
                Feed::Tokens(l) => {
                    let toks = timer.time("data", || l.next());
                    timer
                        .time("step", || backend.step_gated(&Batch::Tokens(&toks), lr, decide))?
                }
                Feed::Images(l) => {
                    let (images, labels) = timer.time("data", || l.next());
                    timer.time("step", || {
                        backend.step_gated(
                            &Batch::Images { images: &images, labels: &labels },
                            lr,
                            decide,
                        )
                    })?
                }
            }
        };
        if verdict == Verdict::Skip && applied {
            warnln!(
                "backend `{}` cannot skip a fused step — anomaly at step \
                 {step} was observed (LR backed off) but the update applied",
                backend.label()
            );
        }
        if applied {
            clip_sum += metrics.clipped as f64;
        }
        if metrics.loss.is_finite() {
            last_train = metrics.loss as f64;
            if step >= tail_from {
                tail_losses.push(metrics.loss as f64);
            }
        }

        let mut eval_loss = f64::NAN;
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            eval_loss = timer
                .time("eval", || eval_now(&mut *backend, &eval_feed, cfg.eval_batches))?;
            last_eval = eval_loss;
        }
        csv.row(&[
            step as f64,
            lr as f64,
            metrics.loss as f64,
            metrics.grad_norm as f64,
            metrics.clipped as f64,
            eval_loss,
            lr_scale,
            if verdict == Verdict::Skip { 1.0 } else { 0.0 },
        ])?;

        if let Err(abort) = guard.check_abort() {
            // clean abort: flush what we have, record the outcome, leave
            // the checkpoint set intact for a later resume
            csv.flush()?;
            append_jsonl(
                &cfg.out_dir.join("summary.jsonl"),
                &[
                    ("model", json_str(&cfg.model)),
                    ("optimizer", json_str(&cfg.optimizer)),
                    ("aborted", "true".into()),
                    ("abort_step", format!("{step}")),
                    ("skipped_steps", format!("{}", guard.skipped())),
                    ("reason", json_str(&abort.to_string())),
                ],
            )?;
            return Err(abort);
        }

        if cfg.dominance_every > 0 && (step + 1) % cfg.dominance_every == 0 {
            // best-effort diagnostics: a failed probe must never kill a
            // training run that is otherwise making progress
            let doms = backend.dominance().unwrap_or_else(|e| {
                crate::warnln!("dominance probe failed at step {step}: {e}");
                Vec::new()
            });
            if !doms.is_empty() {
                let w = match &mut dom_csv {
                    Some(w) => w,
                    None => {
                        let path = cfg.out_dir.join("dominance.csv");
                        let writer = if start_step > 0 && path.exists() {
                            drop_rows_from(&path, start_step)?;
                            CsvWriter::append(&path)?
                        } else {
                            let mut header = vec!["step".to_string()];
                            for i in 0..doms.len() {
                                header.push(format!("r_avg_{i}"));
                                header.push(format!("r_min_{i}"));
                                header.push(format!("r_max_{i}"));
                            }
                            let refs: Vec<&str> =
                                header.iter().map(String::as_str).collect();
                            CsvWriter::create(&path, &refs)?
                        };
                        dom_csv = Some(writer);
                        dom_csv.as_mut().unwrap()
                    }
                };
                let mut row = vec![step as f64];
                for (a, mi, ma) in doms {
                    row.extend([a as f64, mi as f64, ma as f64]);
                }
                w.row(&row)?;
            }
        }

        if cfg.checkpoint_every > 0 && (step + 1) % cfg.checkpoint_every == 0 {
            timer.time("ckpt", || save_checkpoint(&mut *backend, cfg, step + 1, &guard))?;
            if cfg.keep_checkpoints > 0 {
                // retention is best-effort: a failed prune must never kill
                // a run whose checkpoint just landed safely
                if let Err(e) = checkpoint::prune(&cfg.out_dir, cfg.keep_checkpoints) {
                    warnln!("checkpoint prune failed: {e}");
                }
            }
        }

        if step % 25 == 0 || step + 1 == cfg.steps {
            // keep long-run metrics observable from outside the process
            csv.flush()?;
        }
        if step % 50 == 0 || step + 1 == cfg.steps {
            info!(
                "[{}/{}/{}] {} step {step}/{} loss {:.4} gnorm {:.3} lr {:.2e}",
                cfg.model, cfg.optimizer, backend.label(), cfg.data.name(), cfg.steps,
                metrics.loss, metrics.grad_norm, lr
            );
        }
    }

    // final held-out evaluation (always)
    let final_eval = eval_now(backend, &eval_feed, cfg.eval_batches.max(4))?;
    last_eval = final_eval;
    csv.flush()?;
    if let Some(w) = &mut dom_csv {
        w.flush()?;
    }

    let seconds = t_start.elapsed().as_secs_f64();
    debugln!("timer: {}", timer.report());
    let tail = if tail_losses.is_empty() {
        last_train
    } else {
        tail_losses.iter().sum::<f64>() / tail_losses.len() as f64
    };
    let steps_run = cfg.steps - start_step;
    let result = RunResult {
        final_train_loss: last_train,
        final_eval_loss: last_eval,
        final_ppl: last_eval.exp(),
        mean_clip_rate: clip_sum / steps_run.max(1) as f64,
        steps: steps_run,
        seconds,
        tail_train_loss: tail,
        skipped_steps: guard.skipped(),
    };
    append_jsonl(
        &cfg.out_dir.join("summary.jsonl"),
        &[
            ("model", json_str(&cfg.model)),
            ("arch", json_str(backend.arch())),
            ("optimizer", json_str(&cfg.optimizer)),
            ("backend", json_str(backend.label())),
            ("data", json_str(cfg.data.name())),
            ("lr", format!("{}", cfg.lr)),
            ("steps", format!("{}", cfg.steps)),
            // steps_run distinguishes a resumed continuation from a full
            // rerun — the fault harness uses it to prove no silent
            // restart-from-scratch happened (a scratch rerun of a
            // deterministic stream ends byte-identical, so checkpoint
            // bytes alone can't tell)
            ("steps_run", format!("{steps_run}")),
            ("skipped_steps", format!("{}", result.skipped_steps)),
            ("guard_min_lr_scale", format!("{}", guard.min_scale_seen())),
            ("final_train_loss", format!("{:.6}", result.final_train_loss)),
            ("final_eval_loss", format!("{:.6}", result.final_eval_loss)),
            ("final_ppl", format!("{:.4}", result.final_ppl)),
            ("clip_rate", format!("{:.4}", result.mean_clip_rate)),
            ("seconds", format!("{:.2}", result.seconds)),
        ],
    )?;
    Ok(result)
}

/// Rewrite a step-keyed CSV keeping the header and only the *complete*
/// rows whose leading `step` column is below `start_step` — an
/// interrupted run may have flushed rows past the checkpoint a resume
/// restores from, and its final row may have died mid-flush.
pub(crate) fn drop_rows_from(path: &Path, start_step: usize) -> anyhow::Result<()> {
    let text = std::fs::read_to_string(path)?;
    let columns = text.lines().next().map_or(0, |h| h.split(',').count());
    let mut kept = String::new();
    for (i, line) in text.lines().enumerate() {
        let keep = i == 0
            || (line.split(',').count() == columns
                && line
                    .split(',')
                    .next()
                    .and_then(|s| s.parse::<f64>().ok())
                    .is_some_and(|step| step < start_step as f64));
        if keep {
            kept.push_str(line);
            kept.push('\n');
        }
    }
    std::fs::write(path, kept)?;
    Ok(())
}

/// Prepare an interrupted `metrics.csv` for in-place continuation:
/// [`drop_rows_from`] semantics (keep only complete rows below
/// `start_step`), plus header migration — a file written before the
/// guard columns existed is rewritten to the current header with old
/// rows padded by empty cells (or truncated, should columns ever be
/// removed), so [`CsvWriter::append`] derives the right arity.
pub(crate) fn prepare_resumed_csv(
    path: &Path,
    start_step: usize,
    header: &[&str],
) -> anyhow::Result<()> {
    let text = std::fs::read_to_string(path)?;
    let old_columns = text.lines().next().map_or(0, |h| h.split(',').count());
    let mut kept = String::new();
    kept.push_str(&header.join(","));
    kept.push('\n');
    for line in text.lines().skip(1) {
        let complete = line.split(',').count() == old_columns;
        let below = line
            .split(',')
            .next()
            .and_then(|s| s.parse::<f64>().ok())
            .is_some_and(|step| step < start_step as f64);
        if complete && below {
            let mut cells: Vec<&str> = line.split(',').collect();
            cells.truncate(header.len());
            kept.push_str(&cells.join(","));
            for _ in cells.len()..header.len() {
                kept.push(',');
            }
            kept.push('\n');
        }
    }
    std::fs::write(path, kept)?;
    Ok(())
}

fn save_checkpoint(
    backend: &mut dyn TrainBackend,
    cfg: &RunConfig,
    step: usize,
    guard: &StepGuard,
) -> anyhow::Result<()> {
    let mut state = backend.export_state()?;
    // a backend reports steps across restores; the file is named by the
    // absolute step
    state.step = step as u64;
    // ride the guard's backoff state along so a resume continues it
    guard::stamp_guard(&mut state, guard);
    checkpoint::save_state(&cfg.out_dir.join(format!("step-{step}.ckpt")), &state)
}

/// Evaluate perplexity of a run result against a directory path (helper
/// for tests and reports).
pub fn read_final_ppl(out_dir: &Path) -> anyhow::Result<f64> {
    let text = std::fs::read_to_string(out_dir.join("summary.jsonl"))?;
    let last = text
        .lines()
        .last()
        .ok_or_else(|| anyhow::anyhow!("empty summary"))?;
    let j = crate::util::json::parse(last)?;
    j.get("final_ppl")
        .and_then(crate::util::json::Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("no final_ppl"))
}
