//! The training loop: config → data pipeline → device-resident stepping →
//! metrics/eval/dominance/checkpoints.

use std::path::Path;

use crate::config::{DataSpec, RunConfig};
use crate::coordinator::checkpoint::{self, NamedBuffer};
use crate::coordinator::metrics::{append_jsonl, json_str, CsvWriter};
use crate::coordinator::schedule::lr_at;
use crate::data::corpus::token_source;
use crate::data::images::ImageSource;
use crate::data::loader::BatchLoader;
use crate::runtime::session::{Batch, TrainSession};
use crate::runtime::Engine;
use crate::util::Timer;
use crate::{debugln, info};

/// Outcome of a full training run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub final_train_loss: f64,
    pub final_eval_loss: f64,
    /// exp(final_eval_loss) — the paper reports validation perplexity.
    pub final_ppl: f64,
    pub mean_clip_rate: f64,
    pub steps: usize,
    pub seconds: f64,
    /// mean train loss over the last 10% of steps (smoother than the last
    /// point for small-scale runs)
    pub tail_train_loss: f64,
}

enum Feed {
    Tokens(BatchLoader<Vec<i32>>),
    Images(BatchLoader<(Vec<f32>, Vec<i32>)>),
}

fn make_feed(engine: &Engine, cfg: &RunConfig, split: u64) -> anyhow::Result<Feed> {
    let model = engine.manifest.model(&cfg.model)?;
    if model.family == "vision" {
        anyhow::ensure!(
            cfg.data == DataSpec::Images,
            "vision models need data.corpus = \"images\""
        );
        let ispec = &model.batch_specs[0];
        let b = ispec.shape[0];
        let hw = *ispec.shape.last().unwrap();
        let n_img = ispec.elements();
        let mut src = ImageSource::new(10, hw, cfg.seed, split);
        Ok(Feed::Images(BatchLoader::spawn(4, move || {
            let mut images = vec![0.0f32; n_img];
            let mut labels = vec![0i32; b];
            src.fill(b, &mut images, &mut labels);
            (images, labels)
        })))
    } else {
        anyhow::ensure!(
            cfg.data != DataSpec::Images,
            "LM models need a token corpus, got images"
        );
        let spec = &model.batch_specs[0];
        let count = spec.elements();
        let mut src = token_source(cfg.data, cfg.seed, split);
        Ok(Feed::Tokens(BatchLoader::spawn(4, move || {
            let mut tokens = vec![0i32; count];
            src.fill(&mut tokens);
            tokens
        })))
    }
}

/// Run one training job to completion, writing metrics under
/// `cfg.out_dir`. Returns the summary.
pub fn run(engine: &Engine, cfg: &RunConfig) -> anyhow::Result<RunResult> {
    let t_start = std::time::Instant::now();
    cfg.apply_perf()?;
    std::fs::create_dir_all(&cfg.out_dir)?;
    let mut sess =
        TrainSession::new(engine, &cfg.model, &cfg.optimizer, cfg.seed as i32)?;
    let train_feed = make_feed(engine, cfg, 0)?;
    let eval_feed = make_feed(engine, cfg, 1)?;

    let mut csv = CsvWriter::create(
        &cfg.out_dir.join("metrics.csv"),
        &["step", "lr", "loss", "grad_norm", "clipped", "eval_loss"],
    )?;
    let mut dom_csv: Option<CsvWriter> = None;

    let mut timer = Timer::new();
    let mut clip_sum = 0.0f64;
    let mut tail_losses = Vec::new();
    let tail_from = cfg.steps - (cfg.steps / 10).max(1);
    let mut last_train = f64::NAN;
    let mut last_eval = f64::NAN;

    let eval_now = |sess: &TrainSession, feed: &Feed, n: usize| -> anyhow::Result<f64> {
        let mut acc = 0.0;
        for _ in 0..n.max(1) {
            let loss = match feed {
                Feed::Tokens(l) => {
                    let toks = l.next();
                    sess.eval(&Batch::Tokens(&toks))?
                }
                Feed::Images(l) => {
                    let (images, labels) = l.next();
                    sess.eval(&Batch::Images { images: &images, labels: &labels })?
                }
            };
            acc += loss as f64;
        }
        Ok(acc / n.max(1) as f64)
    };

    for step in 0..cfg.steps {
        let lr = lr_at(cfg.schedule, cfg.lr, step, cfg.steps) as f32;
        let metrics = match &train_feed {
            Feed::Tokens(l) => {
                let toks = timer.time("data", || l.next());
                timer.time("step", || sess.step(&Batch::Tokens(&toks), lr))?
            }
            Feed::Images(l) => {
                let (images, labels) = timer.time("data", || l.next());
                timer.time("step", || {
                    sess.step(&Batch::Images { images: &images, labels: &labels }, lr)
                })?
            }
        };
        clip_sum += metrics.clipped as f64;
        last_train = metrics.loss as f64;
        if step >= tail_from {
            tail_losses.push(metrics.loss as f64);
        }

        let mut eval_loss = f64::NAN;
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            eval_loss = timer.time("eval", || {
                eval_now(&sess, &eval_feed, cfg.eval_batches)
            })?;
            last_eval = eval_loss;
        }
        csv.row(&[
            step as f64,
            lr as f64,
            metrics.loss as f64,
            metrics.grad_norm as f64,
            metrics.clipped as f64,
            eval_loss,
        ])?;

        if cfg.dominance_every > 0 && (step + 1) % cfg.dominance_every == 0 {
            if let Ok(doms) = sess.dominance() {
                let w = match &mut dom_csv {
                    Some(w) => w,
                    None => {
                        let mut header = vec!["step".to_string()];
                        for i in 0..doms.len() {
                            header.push(format!("r_avg_{i}"));
                            header.push(format!("r_min_{i}"));
                            header.push(format!("r_max_{i}"));
                        }
                        let refs: Vec<&str> =
                            header.iter().map(String::as_str).collect();
                        dom_csv = Some(CsvWriter::create(
                            &cfg.out_dir.join("dominance.csv"),
                            &refs,
                        )?);
                        dom_csv.as_mut().unwrap()
                    }
                };
                let mut row = vec![step as f64];
                for (a, mi, ma) in doms {
                    row.extend([a as f64, mi as f64, ma as f64]);
                }
                w.row(&row)?;
            }
        }

        if cfg.checkpoint_every > 0 && (step + 1) % cfg.checkpoint_every == 0 {
            timer.time("ckpt", || save_checkpoint(engine, &sess, cfg, step + 1))?;
        }

        if step % 25 == 0 || step + 1 == cfg.steps {
            // keep long-run metrics observable from outside the process
            csv.flush()?;
        }
        if step % 50 == 0 || step + 1 == cfg.steps {
            info!(
                "[{}/{}] {} step {step}/{} loss {:.4} gnorm {:.3} lr {:.2e}",
                cfg.model, cfg.optimizer, cfg.data.name(), cfg.steps,
                metrics.loss, metrics.grad_norm, lr
            );
        }
    }

    // final held-out evaluation (always)
    let final_eval = eval_now(&sess, &eval_feed, cfg.eval_batches.max(4))?;
    last_eval = final_eval;
    csv.flush()?;
    if let Some(w) = &mut dom_csv {
        w.flush()?;
    }

    let seconds = t_start.elapsed().as_secs_f64();
    debugln!("timer: {}", timer.report());
    let tail = if tail_losses.is_empty() {
        last_train
    } else {
        tail_losses.iter().sum::<f64>() / tail_losses.len() as f64
    };
    let result = RunResult {
        final_train_loss: last_train,
        final_eval_loss: last_eval,
        final_ppl: last_eval.exp(),
        mean_clip_rate: clip_sum / cfg.steps.max(1) as f64,
        steps: cfg.steps,
        seconds,
        tail_train_loss: tail,
    };
    append_jsonl(
        &cfg.out_dir.join("summary.jsonl"),
        &[
            ("model", json_str(&cfg.model)),
            ("optimizer", json_str(&cfg.optimizer)),
            ("data", json_str(cfg.data.name())),
            ("lr", format!("{}", cfg.lr)),
            ("steps", format!("{}", cfg.steps)),
            ("final_train_loss", format!("{:.6}", result.final_train_loss)),
            ("final_eval_loss", format!("{:.6}", result.final_eval_loss)),
            ("final_ppl", format!("{:.4}", result.final_ppl)),
            ("clip_rate", format!("{:.4}", result.mean_clip_rate)),
            ("seconds", format!("{:.2}", result.seconds)),
        ],
    )?;
    Ok(result)
}

fn save_checkpoint(
    engine: &Engine,
    sess: &TrainSession,
    cfg: &RunConfig,
    step: usize,
) -> anyhow::Result<()> {
    let entry = engine.manifest.opt_entry(&cfg.model, &cfg.optimizer)?;
    let state = sess.download_state()?;
    let buffers: Vec<NamedBuffer> = entry
        .state_names
        .iter()
        .zip(state)
        .map(|(name, data)| NamedBuffer { name: name.clone(), data })
        .collect();
    checkpoint::save(
        &cfg.out_dir.join(format!("step-{step}.ckpt")),
        &buffers,
    )
}

/// Evaluate perplexity of a run result against a directory path (helper
/// for tests and reports).
pub fn read_final_ppl(out_dir: &Path) -> anyhow::Result<f64> {
    let text = std::fs::read_to_string(out_dir.join("summary.jsonl"))?;
    let last = text
        .lines()
        .last()
        .ok_or_else(|| anyhow::anyhow!("empty summary"))?;
    let j = crate::util::json::parse(last)?;
    j.get("final_ppl")
        .and_then(crate::util::json::Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("no final_ppl"))
}
