//! Analysis passes over run outputs: dominance-ratio aggregation
//! (paper Section 3.2 / Appendix B) and paper-style report formatting.

pub mod dominance;
pub mod report;

pub use dominance::{global_series, DominanceSeries};
pub use report::markdown_table;
