//! Analysis passes over run outputs: dominance-ratio aggregation
//! (paper Section 3.2 / Appendix B) and paper-style report formatting.

// The crate-level `missing_docs` warning is enforced for tensor/ and
// optim/; this module's full docs pass is still pending (ROADMAP.md).
#![allow(missing_docs)]

pub mod dominance;
pub mod report;

pub use dominance::{global_series, DominanceSeries};
pub use report::markdown_table;
