//! Dominance-ratio aggregation (paper Eqs. 5–6, Appendix B).
//!
//! Training runs log per-matrix (r_avg, r_min, r_max) triples into
//! `dominance.csv`; this module reconstructs the paper's two views:
//!
//! * **per-parameter** (Figures 4/7/8/10): raw + window-50-smoothed series
//!   for selected matrices;
//! * **global** (Figures 5/9): r̄ statistics averaged across all matrix
//!   parameters per step.

use std::path::Path;

use crate::coordinator::metrics::CsvData;
use crate::util::moving_average;

/// One aggregated dominance series over training.
#[derive(Clone, Debug)]
pub struct DominanceSeries {
    /// Logged step index per row.
    pub steps: Vec<f64>,
    /// Global r̄_avg per logged step.
    pub r_avg: Vec<f64>,
    /// Global r̄_min per logged step.
    pub r_min: Vec<f64>,
    /// Global r̄_max per logged step.
    pub r_max: Vec<f64>,
    /// Number of matrix parameters aggregated.
    pub n_params: usize,
}

impl DominanceSeries {
    /// Window-50 smoothed copies (the paper's solid curves).
    pub fn smoothed(&self, window: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        (
            moving_average(&self.r_avg, window),
            moving_average(&self.r_min, window),
            moving_average(&self.r_max, window),
        )
    }

    /// Fraction of logged steps where every global statistic exceeds the
    /// paper's y = 1 threshold.
    pub fn frac_above_one(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        let n = self
            .steps
            .iter()
            .enumerate()
            .filter(|(i, _)| self.r_min[*i] > 1.0)
            .count();
        n as f64 / self.steps.len() as f64
    }

    /// Tail (last 25% of steps) means of the three statistics.
    pub fn tail_means(&self) -> (f64, f64, f64) {
        let from = self.steps.len() - (self.steps.len() / 4).max(1);
        let mean_from = |xs: &[f64]| {
            let t = &xs[from.min(xs.len().saturating_sub(1))..];
            t.iter().sum::<f64>() / t.len().max(1) as f64
        };
        (
            mean_from(&self.r_avg),
            mean_from(&self.r_min),
            mean_from(&self.r_max),
        )
    }
}

/// Build the global series from a run's `dominance.csv`: per step, average
/// each statistic across all K matrix parameters (Appendix B Eqs. 14–16).
pub fn global_series(csv_path: &Path) -> anyhow::Result<DominanceSeries> {
    let data = CsvData::read(csv_path)?;
    let steps = data.column("step")?;
    let k = (data.header.len() - 1) / 3;
    anyhow::ensure!(k > 0, "no dominance columns in {}", csv_path.display());
    let mut r_avg = vec![0.0; steps.len()];
    let mut r_min = vec![0.0; steps.len()];
    let mut r_max = vec![0.0; steps.len()];
    for i in 0..k {
        let a = data.column(&format!("r_avg_{i}"))?;
        let mi = data.column(&format!("r_min_{i}"))?;
        let ma = data.column(&format!("r_max_{i}"))?;
        for row in 0..steps.len() {
            r_avg[row] += a[row] / k as f64;
            r_min[row] += mi[row] / k as f64;
            r_max[row] += ma[row] / k as f64;
        }
    }
    Ok(DominanceSeries { steps, r_avg, r_min, r_max, n_params: k })
}

/// Per-parameter series (one matrix) from the same CSV.
pub fn param_series(csv_path: &Path, index: usize) -> anyhow::Result<DominanceSeries> {
    let data = CsvData::read(csv_path)?;
    let steps = data.column("step")?;
    Ok(DominanceSeries {
        r_avg: data.column(&format!("r_avg_{index}"))?,
        r_min: data.column(&format!("r_min_{index}"))?,
        r_max: data.column(&format!("r_max_{index}"))?,
        steps,
        n_params: 1,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::CsvWriter;

    fn write_fixture() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("rmnp-dom-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dominance.csv");
        let mut w = CsvWriter::create(
            &path,
            &["step", "r_avg_0", "r_min_0", "r_max_0", "r_avg_1", "r_min_1", "r_max_1"],
        )
        .unwrap();
        // param 0 climbs from 0.5 to 4.5; param 1 fixed at 3/2/5
        for s in 0..8 {
            let x = 0.5 + s as f64 * 4.0 / 7.0;
            w.row(&[s as f64, x, x * 0.5, x * 2.0, 3.0, 2.0, 5.0]).unwrap();
        }
        w.flush().unwrap();
        path
    }

    #[test]
    fn global_series_averages_params() {
        let path = write_fixture();
        let s = global_series(&path).unwrap();
        assert_eq!(s.n_params, 2);
        assert_eq!(s.steps.len(), 8);
        // step 0: avg of 0.5 and 3.0
        assert!((s.r_avg[0] - 1.75).abs() < 1e-9);
        // min statistic at step 7: (0.5*4.5 + 2)/2
        assert!((s.r_min[7] - (2.25 + 2.0) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn frac_above_one_and_tail() {
        let path = write_fixture();
        let s = global_series(&path).unwrap();
        let f = s.frac_above_one();
        assert!(f > 0.5 && f <= 1.0, "{f}");
        let (a, mi, ma) = s.tail_means();
        assert!(mi <= a && a <= ma);
    }

    #[test]
    fn param_series_reads_one_matrix() {
        let path = write_fixture();
        let s = param_series(&path, 1).unwrap();
        assert!(s.r_avg.iter().all(|&x| (x - 3.0).abs() < 1e-9));
        let (sm, _, _) = s.smoothed(4);
        assert_eq!(sm.len(), 8);
    }
}
