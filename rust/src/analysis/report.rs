//! Paper-style report rendering: aligned text/markdown tables shared by
//! every experiment harness.

use std::fmt::Write;

/// Render a markdown-style table with right-aligned numeric columns.
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let cols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(cols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        let _ = write!(out, "|");
        for (i, c) in cells.iter().enumerate().take(cols) {
            let _ = write!(out, " {:>width$} |", c, width = widths[i]);
        }
        let _ = writeln!(out);
    };
    line(&mut out, &header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let _ = write!(out, "|");
    for w in &widths {
        let _ = write!(out, "{:-<width$}|", "", width = w + 2);
    }
    let _ = writeln!(out);
    for row in rows {
        line(&mut out, row);
    }
    out
}

/// Format seconds with 3 decimals (paper Table 2 convention).
pub fn secs3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a perplexity with 2 decimals, flagging the per-column winner
/// elsewhere (callers mark with `*`).
pub fn ppl2(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.2}")
    } else {
        "—".to_string()
    }
}

/// Mark the minimum entry of each column (row-major `values[row][col]`)
/// with a trailing `*` — the paper bolds the winner per column.
pub fn mark_column_winners(values: &[Vec<f64>]) -> Vec<Vec<String>> {
    if values.is_empty() {
        return vec![];
    }
    let cols = values[0].len();
    let mut best = vec![f64::INFINITY; cols];
    for row in values {
        for (c, v) in row.iter().enumerate() {
            if v.is_finite() && *v < best[c] {
                best[c] = *v;
            }
        }
    }
    values
        .iter()
        .map(|row| {
            row.iter()
                .enumerate()
                .map(|(c, v)| {
                    if v.is_finite() && (*v - best[c]).abs() < 1e-9 {
                        format!("{}*", ppl2(*v))
                    } else {
                        ppl2(*v)
                    }
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_aligned() {
        let t = markdown_table(
            &["opt", "ppl"],
            &[
                vec!["rmnp".into(), "22.82".into()],
                vec!["adamw".into(), "24.19".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(t.contains("rmnp"));
    }

    #[test]
    fn winners_marked_per_column() {
        let rows = mark_column_winners(&[
            vec![24.19, 18.80],
            vec![22.86, 17.38],
            vec![22.82, 17.31],
        ]);
        assert_eq!(rows[2][0], "22.82*");
        assert_eq!(rows[2][1], "17.31*");
        assert_eq!(rows[0][0], "24.19");
    }

    #[test]
    fn handles_nan() {
        assert_eq!(ppl2(f64::NAN), "—");
        let rows = mark_column_winners(&[vec![f64::NAN], vec![3.0]]);
        assert_eq!(rows[1][0], "3.00*");
    }
}
