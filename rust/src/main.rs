fn main() -> anyhow::Result<()> { rmnp::cli::run() }
