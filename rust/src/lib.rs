//! # RMNP — Row-Momentum Normalized Preconditioning
//!
//! A three-layer reproduction of *"RMNP: Row-Momentum Normalized
//! Preconditioning for Scalable Matrix-Based Optimization"* (CS.LG 2026):
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the RMNP row-ℓ2
//!   normalization and Muon's Newton–Schulz-5 orthogonalization.
//! * **L2** — JAX compute graphs (`python/compile/`): transformer / SSM /
//!   CNN models and fused train-step graphs per optimizer, AOT-lowered to
//!   HLO text artifacts at build time.
//! * **L3** — this crate: a training framework that loads the artifacts via
//!   PJRT and runs every experiment in the paper — data pipeline, training
//!   loop, LR schedules, metric logging, checkpointing, sweeps, and the
//!   benchmark harnesses that regenerate each table and figure.
//!
//! Python never runs on the training path: `make artifacts` is the only
//! step that invokes it.
//!
//! Module map:
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | f32 matrix substrate: tiled/threaded kernels, workspace arena |
//! | [`util`] | RNG, logging, timers, JSON, small helpers |
//! | [`config`] | TOML-subset parser + typed experiment configuration |
//! | [`cli`] | hand-rolled argument parser and subcommand dispatch |
//! | [`data`] | synthetic corpora, tokenizers, batch loader, image data |
//! | [`optim`] | fused pure-rust optimizers behind the `MatrixOptimizer` trait |
//! | [`model`] | architecture blocks (attention/gated-MLP/SSM/conv) behind `ModelArch` |
//! | [`runtime`] | training backends: native (model layer + StepPlan) and PJRT |
//! | [`coordinator`] | training loop, schedules, metrics, checkpoints, sweeps |
//! | [`dist`] | data-parallel training over a fault-tolerant TCP coordinator |
//! | [`analysis`] | dominance ratios, smoothing, paper-style reports |
//! | [`exp`] | one harness per paper table/figure |
//! | [`bench`] | micro-benchmark harness + JSON perf reports |
//!
//! The XLA/PJRT-backed runtime is behind the `pjrt` cargo feature so the
//! default build is green offline; training itself no longer needs it —
//! the [`runtime::NativeBackend`] (default `runtime.backend = native`)
//! runs the [`model`] layer's architecture blocks (attention, gated MLP,
//! SSM scan, conv stem) host-side and steps through
//! [`optim::StepPlan`], so `rmnp train` and the pretrain/sweep
//! experiment grids run end to end in every build.

// Every public item needs a doc comment. Fully enforced for [`tensor`],
// [`optim`], [`model`], [`runtime`], [`config`], [`coordinator`], and
// [`exp`]; the remaining modules carry a module-level allow until their
// docs pass lands (tracked in ROADMAP.md).
#![warn(missing_docs)]

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod exp;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod util;
