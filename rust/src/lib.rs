//! # RMNP — Row-Momentum Normalized Preconditioning
//!
//! A three-layer reproduction of *"RMNP: Row-Momentum Normalized
//! Preconditioning for Scalable Matrix-Based Optimization"* (CS.LG 2026):
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the RMNP row-ℓ2
//!   normalization and Muon's Newton–Schulz-5 orthogonalization.
//! * **L2** — JAX compute graphs (`python/compile/`): transformer / SSM /
//!   CNN models and fused train-step graphs per optimizer, AOT-lowered to
//!   HLO text artifacts at build time.
//! * **L3** — this crate: a training framework that loads the artifacts via
//!   PJRT and runs every experiment in the paper — data pipeline, training
//!   loop, LR schedules, metric logging, checkpointing, sweeps, and the
//!   benchmark harnesses that regenerate each table and figure.
//!
//! Python never runs on the training path: `make artifacts` is the only
//! step that invokes it.
//!
//! Module map:
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | minimal f32 matrix/tensor substrate (host-side math) |
//! | [`util`] | RNG, logging, timers, small helpers |
//! | [`config`] | TOML-subset parser + typed experiment configuration |
//! | [`cli`] | hand-rolled argument parser and subcommand dispatch |
//! | [`data`] | synthetic corpora, tokenizers, batch loader, image data |
//! | [`optim`] | pure-rust reference optimizers (AdamW/Muon/RMNP/...) |
//! | [`runtime`] | PJRT client, artifact registry, device-resident state |
//! | [`coordinator`] | training loop, schedules, metrics, checkpoints, sweeps |
//! | [`analysis`] | dominance ratios, smoothing, paper-style reports |
//! | [`exp`] | one harness per paper table/figure |
//! | [`bench`] | micro-benchmark harness (criterion-style, hand-rolled) |

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod util;
