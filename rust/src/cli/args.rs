//! Tiny argument parser: positional subcommands + `--flag value` /
//! `--flag` switches (no external crates offline).

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// positional arguments in order (subcommands first)
    pub positional: Vec<String>,
    /// `--key value` pairs; bare `--key` stores "true"
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key=value or --key value or bare --key
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else {
                    let takes_value = iter
                        .peek()
                        .map(|next| !next.starts_with("--"))
                        .unwrap_or(false);
                    let v = if takes_value {
                        iter.next().unwrap()
                    } else {
                        "true".to_string()
                    };
                    args.flags.entry(name.to_string()).or_default().push(v);
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self, depth: usize) -> Option<&str> {
        self.positional.get(depth).map(String::as_str)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    /// All occurrences of a repeatable flag (e.g. --set a=1 --set b=2).
    pub fn flag_all(&self, name: &str) -> Vec<&str> {
        self.flags
            .get(name)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.flag(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    /// Comma-separated list flag.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.flag(name)
            .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommands_and_flags() {
        let a = parse("exp pretrain --family gpt2 --steps 300 --quiet");
        assert_eq!(a.subcommand(0), Some("exp"));
        assert_eq!(a.subcommand(1), Some("pretrain"));
        assert_eq!(a.flag("family"), Some("gpt2"));
        assert_eq!(a.usize_or("steps", 0), 300);
        assert!(a.has("quiet"));
        assert_eq!(a.flag("quiet"), Some("true"));
    }

    #[test]
    fn eq_form_and_repeats() {
        let a = parse("train --set a=1 --set b=2 --lr=0.5");
        assert_eq!(a.flag_all("set"), vec!["a=1", "b=2"]);
        assert_eq!(a.f64_or("lr", 0.0), 0.5);
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("exp --scales tiny,small");
        assert_eq!(a.list("scales"), vec!["tiny", "small"]);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.str_or("missing", "x"), "x");
    }

    #[test]
    fn negative_numbers_are_values() {
        let a = parse("bench --offset -3");
        // "-3" doesn't start with --, so it's the value
        assert_eq!(a.flag("offset"), Some("-3"));
    }
}
