//! Subcommand implementations.

use std::path::{Path, PathBuf};

use crate::cli::args::Args;
use crate::config::{BackendKind, DataSpec, RunConfig};
use crate::coordinator::train;
use crate::data::corpus::token_source;
use crate::data::tokenizer::BpeTokenizer;
use crate::exp::{self, ExpOpts};
use crate::util::human_bytes;
#[cfg(feature = "pjrt")]
use crate::info;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;

#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str = "this experiment drives the PJRT engine directly: \
rebuild with `--features pjrt` (and real XLA bindings) to run it. Every \
training experiment (train, pretrain, sweep, …) runs offline on the \
native backend.";

fn exp_opts(args: &Args) -> anyhow::Result<ExpOpts> {
    Ok(ExpOpts {
        artifacts: PathBuf::from(args.str_or("artifacts", "artifacts")),
        out: PathBuf::from(args.str_or("out", "runs")),
        steps: args.usize_or("steps", 200),
        seed: args.usize_or("seed", 1234) as u64,
        workers: args.usize_or("workers", 2),
        scales: args.list("scales"),
        backend: BackendKind::parse(args.str_or("backend", "native"))?,
    })
}

/// `rmnp train` — one training run on the configured backend (native by
/// default; no artifacts or `pjrt` feature needed).
pub fn train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.flag("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    for kv in args.flag_all("set") {
        cfg.apply_override(kv)?;
    }
    if let Some(a) = args.flag("artifacts") {
        cfg.artifacts = PathBuf::from(a);
    }
    if let Some(b) = args.flag("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    if args.has("resume") {
        cfg.resume = true;
    }
    // perf knobs are applied inside train::run (covers exp/sweep callers too)
    let result = train::run_auto(&cfg)?;
    println!(
        "done: final train loss {:.4}, eval loss {:.4}, ppl {:.2}, clip rate {:.1}%, {:.1}s",
        result.final_train_loss,
        result.final_eval_loss,
        result.final_ppl,
        100.0 * result.mean_clip_rate,
        result.seconds
    );
    Ok(())
}

/// `rmnp exp <name>`
pub fn exp(args: &Args) -> anyhow::Result<()> {
    let opts = exp_opts(args)?;
    match args.subcommand(1) {
        #[cfg(feature = "pjrt")]
        Some("precond") => {
            let rows = exp::precond::run(
                &opts,
                args.usize_or("max-d", 0),
                args.usize_or("repeats", 3),
            )?;
            println!("{}", exp::precond::format_table(&rows));
            println!("{}", exp::precond::format_figure1(&rows));
            Ok(())
        }
        #[cfg(not(feature = "pjrt"))]
        Some("precond") => {
            // native kernel-layer path: no artifacts required
            let _ = &opts;
            let rows = exp::precond::run_native(
                args.usize_or("max-d", 640),
                args.usize_or("repeats", 2),
            );
            println!("{}", exp::precond::format_table(&rows));
            println!("{}", exp::precond::format_figure1(&rows));
            Ok(())
        }
        Some("pretrain") => {
            let family = args.str_or("family", "gpt2");
            let (default_scales, default_data, title): (&[&str], _, _) = match family {
                "gpt2" => (&["tiny", "small", "medium", "large"], "markov", "Table 17"),
                "llama" => (&["s60", "s130", "s350", "s1b"], "zipf", "Table 19"),
                "ssm" => (&["base"], "ngram", "Table 20"),
                "vision" => (&["base"], "images", "Table 21"),
                other => anyhow::bail!("unknown family `{other}`"),
            };
            let dataset = DataSpec::parse(args.str_or("dataset", default_data))?;
            let scales: Vec<String> = if opts.scales.is_empty() {
                default_scales.iter().map(|s| s.to_string()).collect()
            } else {
                opts.scales.clone()
            };
            let scale_refs: Vec<&str> = scales.iter().map(String::as_str).collect();
            let optimizers = args.list("optimizers");
            let opt_refs: Vec<&str> = if optimizers.is_empty() {
                vec!["adamw", "muon", "rmnp"]
            } else {
                optimizers.iter().map(String::as_str).collect()
            };
            let grid = exp::pretrain::compare(
                &opts, family, &scale_refs, &opt_refs, dataset, 1,
            )?;
            println!("{}", exp::pretrain::format_grid(&grid, title));
            Ok(())
        }
        Some("sweep") => {
            let model = args.str_or("model", "gpt2_tiny").to_string();
            let dataset = DataSpec::parse(args.str_or(
                "dataset",
                if model.starts_with("llama") { "zipf" } else { "markov" },
            ))?;
            let optimizers = args.list("optimizers");
            let opt_refs: Vec<&str> = if optimizers.is_empty() {
                // the Shampoo/SOAP baselines only exist as PJRT artifacts
                if model.starts_with("llama") && opts.backend == BackendKind::Pjrt {
                    vec!["muon", "rmnp", "shampoo", "soap"]
                } else {
                    vec!["muon", "rmnp"]
                }
            } else {
                optimizers.iter().map(String::as_str).collect()
            };
            let cells = exp::sweeps::run(&opts, &model, &opt_refs, dataset)?;
            println!("{}", exp::sweeps::format(&model, &cells));
            for (opt, lr, ppl) in exp::sweeps::winners(&cells) {
                println!("  best {opt}: lr {lr:.2e} -> ppl {ppl:.2}");
            }
            Ok(())
        }
        #[cfg(feature = "pjrt")]
        Some("dominance") => {
            let engine = Engine::new(&opts.artifacts)?;
            let models = {
                let m = args.list("models");
                if m.is_empty() {
                    vec!["gpt2_tiny".to_string(), "gpt2_small".to_string(),
                         "gpt2_medium".to_string()]
                } else {
                    m
                }
            };
            let optimizer = args.str_or("optimizer", "muon");
            let mut runs = Vec::new();
            for model in &models {
                // per-family default corpus (vision needs image batches)
                let dataset = if model.starts_with("llama") {
                    DataSpec::Zipf
                } else if model.starts_with("ssm") {
                    DataSpec::Ngram
                } else if model.starts_with("vision") {
                    DataSpec::Images
                } else {
                    DataSpec::Markov
                };
                runs.push(exp::dominance_exp::run_one(
                    &opts, &engine, model, optimizer, dataset,
                )?);
            }
            for r in &runs {
                println!("{}", exp::dominance_exp::format_per_param(r));
            }
            println!("{}", exp::dominance_exp::format_global(&runs));
            for r in &runs {
                println!(
                    "  dominance reproduced on {}: {}",
                    r.model,
                    exp::dominance_exp::reproduces_dominance(r)
                );
            }
            Ok(())
        }
        Some("extended") => {
            for (title, grid) in exp::pretrain::extended(&opts)? {
                println!("{}", exp::pretrain::format_grid(&grid, &format!("Table 14 — {title}")));
            }
            Ok(())
        }
        Some("ablation-embed") => {
            let rows = exp::pretrain::embed_ablation(&opts)?;
            println!("{}", exp::pretrain::format_embed_ablation(&rows));
            Ok(())
        }
        Some("ssm") => {
            let grid = exp::pretrain::ssm(&opts)?;
            println!("{}", exp::pretrain::format_grid(&grid, "Table 20 — Mamba-like SSM"));
            Ok(())
        }
        Some("vision") => {
            let grid = exp::pretrain::vision(&opts)?;
            println!("{}", exp::pretrain::format_grid(&grid, "Table 21 — MLP (exp CE)"));
            Ok(())
        }
        Some("cliprate") => {
            let runs_dir = PathBuf::from(args.str_or("runs", "runs"));
            let summaries = exp::cliprate::scan(&runs_dir)?;
            println!("{}", exp::cliprate::format(&summaries));
            Ok(())
        }
        // native sharded multi-param stepping demo/bench (no artifacts)
        Some("stepplan") => {
            use crate::bench::bench_n;
            use crate::optim::plan::{self, OptKind};
            use crate::tensor::simd;
            use crate::util::Rng;

            let d = args.usize_or("d", 512);
            let layers = args.usize_or("layers", 6);
            let steps = args.usize_or("steps", 5);
            let threads = args.usize_or("threads", 0);
            let kind = OptKind::parse(args.str_or("optimizer", "rmnp"))?;
            if let Some(s) = args.flag("simd") {
                simd::set_mode(simd::SimdMode::parse(s)?);
            }
            let shapes = exp::precond::shape_counts(d, layers);
            let mut rng = Rng::new(opts.seed);
            let tasks = plan::tasks_from_shapes(&shapes, kind, 0.02, &mut rng);
            let mut plan = plan::StepPlan::new(tasks, threads);
            for i in 0..plan.len() {
                let grad_seed = opts.seed ^ (i as u64 + 1);
                plan.with_task(i, |t| {
                    let mut grng = Rng::new(grad_seed);
                    grng.fill_normal(t.grad.data_mut(), 1.0);
                });
            }
            println!(
                "step plan: {} params ({} elems) at d={d}, optimizer {}, \
                 pool {} workers, simd {}",
                plan.len(),
                plan.total_elems(),
                kind.name(),
                plan.threads(),
                simd::label()
            );
            let elems = plan.total_elems();
            let r = bench_n("step_all", steps.max(1), 2, || plan.step_all(1e-3));
            println!("  {}", r.report_line());
            println!("  {:.1}M params/s", elems as f64 / r.median() / 1e6);
            Ok(())
        }
        // optimizer-zoo race: every registry optimizer, matched budget,
        // native backend — no artifacts, runs in every build
        Some("shootout") => {
            use crate::tensor::simd;

            if let Some(s) = args.flag("simd") {
                simd::set_mode(simd::SimdMode::parse(s)?);
            }
            let mut sopts = exp::shootout::ShootoutOpts {
                steps: args.usize_or("steps", 20),
                seed: opts.seed,
                repeats: args.usize_or("repeats", 2),
                d: args.usize_or("d", 512),
                json: args.str_or("json", "BENCH_shootout.json").into(),
                ..exp::shootout::ShootoutOpts::default()
            };
            let models = args.list("models");
            if !models.is_empty() {
                sopts.models = models;
            }
            sopts.optimizers = args.list("optimizers");
            let (shots, skips, costs) = exp::shootout::run(&sopts)?;
            println!("{}", exp::shootout::format_table(&sopts, &shots, &skips, &costs));
            exp::shootout::write_report(&sopts, &shots, &skips, &costs)?;
            println!("wrote {}", sopts.json.display());
            Ok(())
        }
        // crash/fault-injection suite: spawns this same binary as the
        // victim child, so it needs no artifacts and runs in every build
        Some("faults") => {
            let bin = std::env::current_exe()?;
            let fopts = exp::faults::FaultOpts {
                out: opts.out.join("faults"),
                steps: args.usize_or("steps", 12),
                checkpoint_every: args.usize_or("checkpoint-every", 3),
                kills: args.usize_or("kills", 2),
                seed: opts.seed,
                compress: args.str_or("compress", "none").to_string(),
            };
            let rows =
                exp::faults::run_filtered(&bin, &fopts, args.str_or("scenarios", ""))?;
            println!("{}", exp::faults::format(&rows));
            let failed = rows.iter().filter(|s| !s.passed).count();
            anyhow::ensure!(failed == 0, "{failed} fault scenario(s) failed");
            Ok(())
        }
        Some("all") => run_all(args, &opts),
        #[cfg(not(feature = "pjrt"))]
        Some("dominance") => anyhow::bail!(NO_PJRT),
        other => anyhow::bail!("unknown exp `{other:?}` (see `rmnp help`)"),
    }
}

/// `rmnp coordinator` — the coordinator side of a distributed run: bind,
/// wait for `dist.workers` registrations, drive the barrier-synchronized
/// step loop, own the checkpoints.
pub fn coordinator(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.flag("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    for kv in args.flag_all("set") {
        cfg.apply_override(kv)?;
    }
    if let Some(w) = args.flag("workers") {
        cfg.apply_override(&format!("dist.workers={w}"))?;
    }
    if let Some(b) = args.flag("bind") {
        cfg.dist_bind = b.to_string();
    }
    if args.has("resume") {
        cfg.resume = true;
    }
    let result = crate::dist::coordinator::run(&cfg)?;
    println!(
        "done: {} steps over {} workers ({} shards), {} death(s), \
         final train loss {:.4}, {:.1}s",
        result.steps_run,
        result.workers,
        result.shards,
        result.deaths,
        result.final_train_loss,
        result.seconds
    );
    Ok(())
}

/// `rmnp worker` — one distributed worker: dial the coordinator given by
/// `--connect`, `--addr-file` (the coordinator's published addr + run
/// nonce), or `dist.connect`; compute shard gradients, apply the
/// broadcast updates. The run definition (model, optimizer, seed, resume
/// state) comes from the coordinator, not from local flags.
pub fn worker(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.flag("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    for kv in args.flag_all("set") {
        cfg.apply_override(kv)?;
    }
    cfg.apply_perf()?;
    // --addr-file also yields the run nonce, so a worker launched off a
    // stale file fails the registration echo check instead of joining a
    // different run; an explicit --connect takes precedence
    let (connect, expect_nonce) = match (args.flag("connect"), args.flag("addr-file")) {
        (Some(c), _) => (c.to_string(), None),
        (None, Some(f)) => crate::dist::read_addr_file(Path::new(f))?,
        (None, None) => (cfg.dist_connect.clone(), None),
    };
    let worker_id = args
        .flag("id")
        .map(str::to_string)
        .unwrap_or_else(|| format!("worker-{}", std::process::id()));
    let opts = crate::dist::worker::WorkerOpts {
        connect,
        worker_id,
        plan_threads: cfg.plan_threads,
        heartbeat_ms: cfg.dist_heartbeat_ms,
        worker_timeout_ms: cfg.dist_worker_timeout_ms,
        connect_attempts: 8,
        expect_nonce,
    };
    let result = crate::dist::worker::run(&opts)?;
    println!(
        "worker done: rank {}, {} step(s) applied, {} shard gradient(s)",
        result.rank, result.steps_applied, result.shards_done
    );
    Ok(())
}

/// `rmnp exp all` — a scaled-down pass over every experiment.
fn run_all(args: &Args, opts: &ExpOpts) -> anyhow::Result<()> {
    crate::info!("=== exp all: precond (capped, native kernels) ===");
    let rows =
        exp::precond::run_native(args.usize_or("max-d", 640), args.usize_or("repeats", 2));
    println!("{}", exp::precond::format_table(&rows));

    crate::info!("=== exp all: gpt2 pretrain ===");
    let grid = exp::pretrain::compare(
        opts, "gpt2", &["tiny", "small"], &["adamw", "muon", "rmnp"],
        DataSpec::Markov, 1,
    )?;
    println!("{}", exp::pretrain::format_grid(&grid, "Table 17 (scaled)"));

    crate::info!("=== exp all: llama pretrain ===");
    let grid = exp::pretrain::compare(
        opts, "llama", &["s60", "s130"], &["adamw", "muon", "rmnp"],
        DataSpec::Zipf, 1,
    )?;
    println!("{}", exp::pretrain::format_grid(&grid, "Table 19 (scaled)"));

    #[cfg(feature = "pjrt")]
    if opts.backend == BackendKind::Pjrt {
        info!("=== exp all: dominance (pjrt) ===");
        let engine = Engine::new(&opts.artifacts)?;
        let r = exp::dominance_exp::run_one(
            opts, &engine, "gpt2_tiny", "muon", DataSpec::Markov,
        )?;
        println!("{}", exp::dominance_exp::format_global(&[r]));
    }

    crate::info!("=== exp all: ssm + vision ===");
    let grid = exp::pretrain::ssm(opts)?;
    println!("{}", exp::pretrain::format_grid(&grid, "Table 20"));
    let grid = exp::pretrain::vision(opts)?;
    println!("{}", exp::pretrain::format_grid(&grid, "Table 21"));

    crate::info!("=== exp all: clip rates ===");
    let summaries = exp::cliprate::scan(&opts.out)?;
    println!("{}", exp::cliprate::format(&summaries));
    Ok(())
}

/// `rmnp report <what>`
pub fn report(args: &Args) -> anyhow::Result<()> {
    match args.subcommand(1) {
        Some("cliprate") => {
            let runs_dir = PathBuf::from(args.str_or("runs", "runs"));
            let summaries = exp::cliprate::scan(&runs_dir)?;
            println!("{}", exp::cliprate::format(&summaries));
            Ok(())
        }
        Some("curves") => {
            let runs_dir = PathBuf::from(args.str_or("runs", "runs"));
            let mut found = 0;
            for entry in std::fs::read_dir(&runs_dir)? {
                let dir = entry?.path();
                let csv = dir.join("metrics.csv");
                if csv.exists() {
                    let data = crate::coordinator::metrics::CsvData::read(&csv)?;
                    let loss = data.column("loss")?;
                    let n = loss.len();
                    if n == 0 {
                        continue;
                    }
                    found += 1;
                    let pick = |f: f64| loss[((n - 1) as f64 * f) as usize];
                    println!(
                        "{:<48} steps {:>5}  loss {:.3} -> {:.3} -> {:.3}",
                        dir.file_name().unwrap().to_string_lossy(),
                        n,
                        pick(0.0),
                        pick(0.5),
                        pick(1.0)
                    );
                }
            }
            anyhow::ensure!(found > 0, "no metrics.csv under {}", runs_dir.display());
            Ok(())
        }
        other => anyhow::bail!("unknown report `{other:?}`"),
    }
}

/// `rmnp data <sample|encode>`
pub fn data(args: &Args) -> anyhow::Result<()> {
    match args.subcommand(1) {
        Some("sample") => {
            let spec = DataSpec::parse(args.str_or("corpus", "markov"))?;
            let n = args.usize_or("n", 64);
            let mut src = token_source(spec, args.usize_or("seed", 1) as u64, 0);
            let mut tokens = vec![0i32; n];
            src.fill(&mut tokens);
            println!("{tokens:?}");
            Ok(())
        }
        Some("encode") => {
            let text = args
                .flag("text")
                .ok_or_else(|| anyhow::anyhow!("--text required"))?;
            let tok = BpeTokenizer::train(text, args.usize_or("vocab", 300));
            let ids = tok.encode(text);
            println!(
                "vocab {} | {} bytes -> {} tokens | {ids:?}",
                tok.vocab_size(),
                text.len(),
                ids.len()
            );
            let back = tok.decode(&ids);
            anyhow::ensure!(back == text.as_bytes(), "roundtrip failed");
            Ok(())
        }
        other => anyhow::bail!("unknown data command `{other:?}`"),
    }
}

/// `rmnp info`
pub fn info(args: &Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let man = crate::runtime::Manifest::load(&dir)?;
    println!("artifacts: {} ({} graphs)", dir.display(), man.graphs.len());
    println!("vocab: {}", man.vocab);
    println!("models:");
    for (tag, m) in &man.models {
        let opts: Vec<&str> = m.optimizers.keys().map(String::as_str).collect();
        println!(
            "  {tag:<16} {} params {:<12} opts [{}]",
            m.family,
            m.param_count.to_string(),
            opts.join(", ")
        );
    }
    println!("precond shapes: {}", man.precond_ops.len());
    let total: u64 = man
        .graphs
        .values()
        .filter_map(|g| std::fs::metadata(man.dir.join(&g.file)).ok())
        .map(|m| m.len())
        .sum();
    println!("artifact bytes: {}", human_bytes(total));
    Ok(())
}
