//! Command-line interface: `rmnp <command> ...`.
//!
//! ```text
//! rmnp train   [--config F] [--set k=v]... [--resume]   one training run
//! rmnp coordinator [--workers N] [--bind ADDR] [--resume]  distributed run
//! rmnp worker  --connect ADDR | --addr-file F [--id NAME]  one data-parallel worker
//! rmnp exp     <precond|pretrain|sweep|dominance|extended|ablation-embed|
//!               ssm|vision|cliprate|stepplan|shootout|faults|all>
//!                                        [opts]         paper experiments
//! rmnp report  <cliprate|curves> --runs DIR      re-render from saved CSVs
//! rmnp data    <sample|encode> [opts]            data-pipeline utilities
//! rmnp info                                      manifest summary
//! ```
//!
//! Training commands default to the host-native backend and run offline
//! in every build; `--backend pjrt` selects the artifact path in
//! `--features pjrt` builds (`rmnp train` also accepts
//! `--set runtime.backend=pjrt` / the config-file key).

// The crate-level `missing_docs` warning is enforced everywhere except
// cli/ and data/; these two modules' full docs pass is still pending
// (ROADMAP.md).
#![allow(missing_docs)]

pub mod args;
pub mod commands;

use args::Args;

const USAGE: &str = "\
rmnp — RMNP optimizer reproduction (rust + JAX + Pallas, AOT via PJRT)

USAGE:
  rmnp train   [--config FILE] [--set section.key=value]... [--resume]
  rmnp coordinator [--config FILE] [--set k=v]... [--resume]
                          [--workers N] [--bind HOST:PORT]
                          (bound address lands in <out.dir>/coordinator.addr)
  rmnp worker  --connect HOST:PORT | --addr-file FILE [--id NAME] [--set k=v]...
                          (--addr-file reads the coordinator's addr + run nonce)
  rmnp exp precond        [--max-d N] [--repeats N]
  rmnp exp pretrain       --family gpt2|llama|ssm|vision [--dataset markov|zipf|ngram|images]
                          [--scales a,b,...] [--steps N] [--workers N]
  rmnp exp sweep          --model TAG [--dataset NAME] [--optimizers a,b] [--steps N]
  rmnp exp dominance      [--models TAG,TAG] [--optimizer muon] [--steps N]  (pjrt builds)
  rmnp exp extended       [--steps N]
  rmnp exp ablation-embed [--steps N]
  rmnp exp ssm|vision     [--steps N]
  rmnp exp cliprate       [--runs DIR]
  rmnp exp stepplan       [--d 512] [--layers 6] [--optimizer rmnp|muon|adamw]
                          [--steps N] [--threads N] [--simd auto|avx2|neon|scalar]
  rmnp exp shootout       [--models TAG,TAG] [--optimizers a,b] [--steps 20]
                          [--d 512] [--repeats N] [--json FILE] [--simd MODE]
                          (every registry optimizer head-to-head, native backend)
  rmnp exp faults         [--kills N] [--steps N] [--checkpoint-every N]
                          [--scenarios SUBSTR] (filter: e.g. --scenarios dist)
                          [--compress none|bf16] (dist scenarios' wire codec)
  rmnp exp all            [--steps N] (scaled-down full suite)
  rmnp report cliprate    [--runs DIR]
  rmnp data sample        [--corpus markov] [--n 64] [--seed 1]
  rmnp data encode        --text STRING [--vocab 300]
  rmnp info               [--artifacts DIR]

Backends: training runs on the host-native backend by default (offline, no
          artifacts); --backend pjrt selects the PJRT artifact path in
          `--features pjrt` builds (rmnp train also reads
          --set runtime.backend=... and the config-file key).
Resume:   --resume / --set train.resume=true restores the newest
          step-N.ckpt in out.dir that passes CRC validation (torn files
          are skipped) and continues bit-exactly.
Common flags: --artifacts DIR (default artifacts), --out DIR (default runs),
              --seed N, --verbose
Perf knobs:   --set perf.threads=N  --set perf.simd=auto|avx2|neon|scalar
              --set perf.plan_threads=N  (env: RMNP_THREADS, RMNP_SIMD)
";

/// CLI entry point (called from main).
pub fn run() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.has("verbose") {
        crate::util::logging::set_level(crate::util::Level::Debug);
    }
    // announce the dispatch ladder's detection result once at startup
    // (stderr). `perf.*` config overrides apply later, per command — the
    // paths that apply them (`RunConfig::apply_perf`, `exp stepplan
    // --simd`) announce the final active rung themselves.
    crate::info!(
        "kernels: detected simd={} threads={}",
        crate::tensor::simd::label(),
        crate::tensor::kernels::num_threads()
    );
    match args.subcommand(0) {
        Some("train") => commands::train(&args),
        Some("coordinator") => commands::coordinator(&args),
        Some("worker") => commands::worker(&args),
        Some("exp") => commands::exp(&args),
        Some("report") => commands::report(&args),
        Some("data") => commands::data(&args),
        Some("info") => commands::info(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            anyhow::bail!("unknown command `{other}`");
        }
    }
}
