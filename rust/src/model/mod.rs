//! The model layer: real architecture blocks behind the [`ModelArch`]
//! trait.
//!
//! Until PR 5 the native backend baked an order-2 scaled MLP directly
//! into `runtime/native.rs` for every registry tag. This module replaces
//! that monolith with four architecture-faithful implementations, all
//! running forward **and** backward on the kernel/workspace layer and
//! allocation-free after warmup:
//!
//! | arch | tags | block structure |
//! |---|---|---|
//! | [`attention`] | `gpt2_*` | RMSNorm → QKV → causal row-softmax → out proj → residual |
//! | [`gated_mlp`] | `llama_*` | RMSNorm → silu(x·G) ⊙ (x·U) gated blocks over order-2 context |
//! | [`ssm`] | `ssm_*` | in-proj → sigmoid-decay linear scan → out proj → residual |
//! | [`conv`] | `vision_*` | 3×3 conv stem → ReLU → FC → ReLU → classifier head |
//!
//! The split mirrors the paper's experimental axes: NorMuon/Muon-family
//! results show row/neuron-norm behavior is architecture-sensitive —
//! attention and MLP blocks respond differently to normalization — so
//! the attention sublayer (gpt2 tags) and the gated-FFN sublayer (llama
//! tags) get separate offline trajectories instead of one shared MLP.
//!
//! ## Contract
//!
//! A [`ModelArch`] owns its activation/gradient buffers and describes
//! its parameters as a [`ParamDef`] layout; the training backend
//! materializes those as [`ParamTask`]s inside a
//! [`StepPlan`](crate::optim::StepPlan) and hands them back to
//! [`ModelArch::forward`]/[`ModelArch::backward`] as plan-task guards
//! plus an index map (layout order → plan scheduling order). The model
//! layer never steps parameters — it only reads weights and fills
//! gradient buffers; clipping and optimizer updates stay in the backend.
//!
//! Determinism: forward/backward are sequential host code over the
//! bit-deterministic kernels — the only threading is *inside* kernel
//! calls, which never changes output bits (see `docs/ARCHITECTURE.md`),
//! so a step is bit-identical for any `perf.threads`/`perf.plan_threads`
//! and reproducible under forced `RMNP_SIMD=scalar`
//! (`tests/model_grad.rs` pins both, and checks every backward against a
//! finite-difference oracle).

pub mod attention;
pub mod common;
pub mod conv;
pub mod gated_mlp;
pub mod registry;
pub mod ssm;

use std::sync::MutexGuard;

use crate::optim::plan::ParamTask;

pub use registry::{build_arch, model_spec, ArchKind, ModelSpec};

/// A locked plan task, the form in which the backend exposes parameters
/// to the model layer (the whole-model lock of
/// [`StepPlan::with_all_tasks`](crate::optim::StepPlan::with_all_tasks)).
pub type TaskGuard<'a> = MutexGuard<'a, ParamTask>;

/// RMSNorm variance floor (LLaMA-style `1e-6`), shared by the attention
/// and gated-MLP blocks.
pub const RMS_EPS: f32 = 1e-6;

/// Batch input: either tokens (LM) or images+labels (vision).
pub enum Batch<'a> {
    /// Row-major `rows × cols` token ids.
    Tokens(&'a [i32]),
    /// Flattened image pixels plus one label per image.
    Images {
        /// `batch × hw × hw` pixels, row-major.
        images: &'a [f32],
        /// One class label per image.
        labels: &'a [i32],
    },
}

/// The batch geometry a model consumes — what the data feed needs to
/// know to assemble inputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchShape {
    /// LM token batches: `rows` sequences of `cols` tokens each.
    Tokens {
        /// Sequences per batch.
        rows: usize,
        /// Tokens per sequence (context + 1 target).
        cols: usize,
    },
    /// Vision batches: `batch` square images plus labels.
    Images {
        /// Images per batch.
        batch: usize,
        /// Image side length (images are `hw × hw`).
        hw: usize,
        /// Total pixels per batch (`batch × hw × hw`).
        pixels: usize,
    },
}

/// How a parameter is initialized.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ParamInit {
    /// Gaussian with the given standard deviation.
    Randn(f32),
    /// Every element set to the given constant (norm gains, scan decays).
    Const(f32),
}

/// What role a parameter plays — this drives the optimizer assignment in
/// the training backend (the paper's protocol: matrix params on the
/// matrix optimizer; embeddings/head on AdamW unless the `*emb` ablation
/// variant flips them; vectors always element-wise).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamClass {
    /// A 2-D weight matrix — rides the configured matrix optimizer.
    Matrix,
    /// The token embedding table (AdamW by default; matrix optimizer
    /// under the `*emb` registry variants, Tables 15/16).
    Embed,
    /// The output head (same assignment rule as [`ParamClass::Embed`]).
    Head,
    /// A 1-D vector (RMSNorm gains, scan decays) — always AdamW: row
    /// normalization or NS5 over a single row is degenerate.
    Vector,
}

/// One named parameter in an architecture's layout.
#[derive(Clone, Debug)]
pub struct ParamDef {
    /// Stable parameter name — the checkpoint section name and the
    /// plan-task name.
    pub name: String,
    /// Rows of the parameter matrix (1 for vectors).
    pub rows: usize,
    /// Columns of the parameter matrix.
    pub cols: usize,
    /// Initialization recipe.
    pub init: ParamInit,
    /// Role (drives the backend's optimizer assignment).
    pub class: ParamClass,
}

impl ParamDef {
    /// Shorthand constructor.
    pub fn new(
        name: impl Into<String>,
        rows: usize,
        cols: usize,
        init: ParamInit,
        class: ParamClass,
    ) -> Self {
        ParamDef { name: name.into(), rows, cols, init, class }
    }
}

/// One architecture: parameter layout plus forward/backward on the
/// kernel layer.
///
/// Calling convention shared by all methods: `tasks` is the full task
/// list in **plan scheduling order** (from
/// [`StepPlan::with_all_tasks`](crate::optim::StepPlan::with_all_tasks)),
/// and `idx` maps **layout order** (the order [`ModelArch::params`]
/// returns) to positions in `tasks` — `&tasks[idx[0]]` is always the
/// first parameter the layout declared. A full step is
/// `load_batch → forward → backward`; `eval` is `load_batch → forward`.
/// All three are allocation-free once the internal buffers and the
/// workspace are warm (held by `tests/alloc.rs`).
pub trait ModelArch: Send {
    /// Which architecture this is (registry kind; names the checkpoint
    /// stamp and the bench envelopes).
    fn arch(&self) -> ArchKind;

    /// The resolved model spec (dims, batch geometry, family).
    fn spec(&self) -> &ModelSpec;

    /// The batch geometry this model consumes.
    fn batch_shape(&self) -> BatchShape;

    /// The named-parameter layout, in a stable order. The backend
    /// materializes exactly these tasks (same names, same shapes).
    fn params(&self) -> Vec<ParamDef>;

    /// Stage one batch into the model's input buffers (embedding lookup
    /// for LM archs, pixel copy for vision). Validates shape and ranges.
    fn load_batch(
        &mut self,
        tasks: &[TaskGuard<'_>],
        idx: &[usize],
        batch: &Batch,
    ) -> anyhow::Result<()>;

    /// Forward pass over the staged batch; returns the mean loss
    /// (cross-entropy, accumulated in f64) and leaves every activation
    /// the backward needs in place.
    fn forward(&mut self, tasks: &[TaskGuard<'_>], idx: &[usize]) -> f64;

    /// Backward pass: fills **every** task's gradient buffer (each is
    /// fully overwritten). Requires a preceding [`ModelArch::forward`]
    /// on the same staged batch.
    fn backward(&mut self, tasks: &mut [TaskGuard<'_>], idx: &[usize]);
}
