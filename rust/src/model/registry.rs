//! The model-tag table: one place that maps registry tags
//! (`gpt2_tiny`, `llama_s130emb`, …) to an architecture and its dims —
//! the model-side twin of `optim::registry`. Unknown tags are an
//! **error**, never a silent default model.

use crate::data::VOCAB;
use crate::model::{attention, conv, gated_mlp, ssm, ModelArch};

/// Which architecture implementation a tag resolves to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArchKind {
    /// Causal single-head attention blocks (`gpt2_*` tags).
    Attention,
    /// RMSNorm + silu-gated MLP blocks over order-2 context (`llama_*`).
    GatedMlp,
    /// Linear state-space scan with learned sigmoid decay (`ssm_*`).
    Ssm,
    /// 3×3 conv stem + FC classifier (`vision_*`).
    Conv,
}

impl ArchKind {
    /// Short arch label — used in the checkpoint stamp, the `summary.jsonl`
    /// `arch` field, and the per-arch bench envelopes.
    pub fn name(self) -> &'static str {
        match self {
            ArchKind::Attention => "attention",
            ArchKind::GatedMlp => "gated_mlp",
            ArchKind::Ssm => "ssm",
            ArchKind::Conv => "conv",
        }
    }
}

/// One scaled model configuration, resolved from a registry tag.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Registry tag this spec was resolved from.
    pub tag: String,
    /// Model family: `gpt2` | `llama` | `ssm` | `vision`.
    pub family: &'static str,
    /// Which architecture implements this tag.
    pub arch: ArchKind,
    /// Embedding width (token families; conv channels side for vision is
    /// [`ModelSpec::channels`]).
    pub d_model: usize,
    /// Hidden width (gated-MLP width, SSM state width, vision FC width;
    /// the attention arch works at `d_model` throughout).
    pub d_hidden: usize,
    /// Number of stacked blocks (attention/gated archs; the SSM and conv
    /// archs are single-block and ignore it).
    pub layers: usize,
    /// Sequences (or images) per batch.
    pub batch: usize,
    /// Tokens per sequence, context + target (0 for vision).
    pub seq: usize,
    /// Image side length (0 for token families).
    pub hw: usize,
    /// Conv stem channels (0 for token families).
    pub channels: usize,
    /// Output classes: the vocabulary for LMs, 10 for vision.
    pub classes: usize,
    /// Whether embeddings/head ride the matrix optimizer (the `*emb`
    /// registry variants; Tables 15/16 ablation).
    pub matrix_embeds: bool,
}

impl ModelSpec {
    /// Positions per batch the loss averages over: next-token targets
    /// for the sequence archs, context-pair targets for order-2 gated
    /// MLP, one label per image for vision.
    pub fn positions(&self) -> usize {
        match self.arch {
            ArchKind::Attention | ArchKind::Ssm => self.batch * (self.seq - 1),
            ArchKind::GatedMlp => self.batch * (self.seq - 2),
            ArchKind::Conv => self.batch,
        }
    }
}

/// tag → (family, arch, d_model, d_hidden, layers)
const MODELS: &[(&str, &str, ArchKind, usize, usize, usize)] = &[
    ("gpt2_tiny", "gpt2", ArchKind::Attention, 32, 64, 2),
    ("gpt2_small", "gpt2", ArchKind::Attention, 48, 96, 2),
    ("gpt2_medium", "gpt2", ArchKind::Attention, 64, 128, 3),
    ("gpt2_large", "gpt2", ArchKind::Attention, 80, 160, 3),
    ("llama_s60", "llama", ArchKind::GatedMlp, 32, 64, 2),
    ("llama_s130", "llama", ArchKind::GatedMlp, 48, 96, 2),
    ("llama_s350", "llama", ArchKind::GatedMlp, 64, 128, 3),
    ("llama_s1b", "llama", ArchKind::GatedMlp, 96, 192, 4),
    ("ssm_base", "ssm", ArchKind::Ssm, 48, 96, 2),
    ("vision_base", "vision", ArchKind::Conv, 0, 96, 2),
];

/// Resolve a registry tag to its model spec. The `*emb` llama variants
/// share dims with their base scale but put embeddings/head on the
/// matrix optimizer. Unknown tags are an error (no silent default).
pub fn model_spec(tag: &str) -> anyhow::Result<ModelSpec> {
    let (base, matrix_embeds) = match tag.strip_suffix("emb") {
        Some(b) if b.starts_with("llama_") => (b, true),
        _ => (tag, false),
    };
    let &(_, family, arch, d_model, d_hidden, layers) = MODELS
        .iter()
        .find(|m| m.0 == base)
        .ok_or_else(|| {
            let known: Vec<&str> = MODELS.iter().map(|m| m.0).collect();
            anyhow::anyhow!(
                "unknown native model `{tag}` (known: {} — llama tags also \
                 accept an `emb` suffix)",
                known.join("|")
            )
        })?;
    let vision = arch == ArchKind::Conv;
    Ok(ModelSpec {
        tag: tag.to_string(),
        family,
        arch,
        d_model,
        d_hidden,
        layers,
        batch: if vision { 16 } else { 8 },
        seq: if vision { 0 } else { 33 },
        hw: if vision { 8 } else { 0 },
        channels: if vision { 8 } else { 0 },
        classes: if vision { 10 } else { VOCAB },
        matrix_embeds,
    })
}

/// Build the architecture a tag selects, ready for a training backend.
pub fn build_arch(tag: &str) -> anyhow::Result<Box<dyn ModelArch>> {
    let spec = model_spec(tag)?;
    Ok(match spec.arch {
        ArchKind::Attention => Box::new(attention::AttentionArch::new(spec)),
        ArchKind::GatedMlp => Box::new(gated_mlp::GatedMlpArch::new(spec)),
        ArchKind::Ssm => Box::new(ssm::SsmArch::new(spec)),
        ArchKind::Conv => Box::new(conv::ConvArch::new(spec)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_resolve_to_their_arch() {
        assert_eq!(model_spec("gpt2_tiny").unwrap().arch, ArchKind::Attention);
        assert_eq!(model_spec("llama_s1b").unwrap().arch, ArchKind::GatedMlp);
        assert_eq!(model_spec("ssm_base").unwrap().arch, ArchKind::Ssm);
        assert_eq!(model_spec("vision_base").unwrap().arch, ArchKind::Conv);
        assert!(model_spec("gpt9_huge").is_err());
        assert!(model_spec("ssm_baseemb").is_err(), "emb suffix is llama-only");
    }

    #[test]
    fn emb_variants_share_dims_and_flip_the_flag() {
        let base = model_spec("llama_s130").unwrap();
        let emb = model_spec("llama_s130emb").unwrap();
        assert_eq!(base.d_model, emb.d_model);
        assert_eq!(base.layers, emb.layers);
        assert!(!base.matrix_embeds && emb.matrix_embeds);
        assert_eq!(emb.tag, "llama_s130emb");
    }

    #[test]
    fn positions_follow_the_arch() {
        assert_eq!(model_spec("gpt2_tiny").unwrap().positions(), 8 * 32);
        assert_eq!(model_spec("llama_s60").unwrap().positions(), 8 * 31);
        assert_eq!(model_spec("ssm_base").unwrap().positions(), 8 * 32);
        assert_eq!(model_spec("vision_base").unwrap().positions(), 16);
    }

    #[test]
    fn every_tag_builds_its_arch() {
        for (tag, ..) in MODELS {
            let arch = build_arch(tag).unwrap();
            assert_eq!(arch.spec().tag, *tag);
            assert_eq!(arch.arch(), model_spec(tag).unwrap().arch);
            let defs = arch.params();
            assert!(!defs.is_empty());
            // names are unique (they become checkpoint section names)
            let mut names: Vec<&str> = defs.iter().map(|d| d.name.as_str()).collect();
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), defs.len(), "{tag}: duplicate param name");
        }
    }
}
