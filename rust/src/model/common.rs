//! Shared pieces of the architecture implementations: the softmax
//! cross-entropy head (forward + backward), token validation, and the
//! embedding gather/scatter helpers.

use crate::data::VOCAB;

/// Row-wise softmax + mean cross-entropy in one sweep. Writes the
/// softmax probabilities into `probs` and returns the mean CE over the
/// `n` rows, accumulated in f64 (the same numerics the pre-model-layer
/// backend used, so losses stay comparable across PRs).
pub(crate) fn softmax_xent_fwd(
    logits: &[f32],
    probs: &mut [f32],
    targets: &[usize],
    n: usize,
    c: usize,
) -> f64 {
    debug_assert_eq!(logits.len(), n * c);
    debug_assert_eq!(probs.len(), n * c);
    debug_assert_eq!(targets.len(), n);
    let mut loss = 0.0f64;
    for r in 0..n {
        let row = &logits[r * c..(r + 1) * c];
        let out = &mut probs[r * c..(r + 1) * c];
        let mut max = f32::NEG_INFINITY;
        for &v in row {
            if v > max {
                max = v;
            }
        }
        let mut sum = 0.0f64;
        for (o, &v) in out.iter_mut().zip(row) {
            let e = (v - max).exp();
            *o = e;
            sum += e as f64;
        }
        let inv = (1.0 / sum) as f32;
        for o in out.iter_mut() {
            *o *= inv;
        }
        let p = out[targets[r]].max(1e-30) as f64;
        loss -= p.ln();
    }
    loss / n as f64
}

/// Cross-entropy backward in place over the forward's probabilities:
/// `probs ← (softmax − onehot(target)) / n`, the gradient of the mean CE
/// with respect to the logits.
pub(crate) fn xent_grad_inplace(probs: &mut [f32], targets: &[usize], n: usize, c: usize) {
    debug_assert_eq!(probs.len(), n * c);
    let invn = 1.0 / n as f32;
    for r in 0..n {
        let row = &mut probs[r * c..(r + 1) * c];
        row[targets[r]] -= 1.0;
        for v in row.iter_mut() {
            *v *= invn;
        }
    }
}

/// Validate that a token id is inside the shared vocabulary.
pub(crate) fn check_token(t: i32) -> anyhow::Result<usize> {
    anyhow::ensure!(
        (0..VOCAB as i32).contains(&t),
        "token id {t} out of vocab range (0..{VOCAB})"
    );
    Ok(t as usize)
}

/// Copy embedding rows for a context list: `dst` row `r` receives
/// `table[ctx[r]]` (both row-major with width `d`).
pub(crate) fn gather_rows(dst: &mut [f32], table: &[f32], ctx: &[usize], d: usize) {
    debug_assert_eq!(dst.len(), ctx.len() * d);
    for (r, &t) in ctx.iter().enumerate() {
        dst[r * d..(r + 1) * d].copy_from_slice(&table[t * d..(t + 1) * d]);
    }
}

/// Scatter-add position gradients back into an embedding-table gradient:
/// `egrad[ctx[r]] += src[r]` for every position. The caller zeroes
/// `egrad` first (each backward fully overwrites every gradient buffer).
pub(crate) fn scatter_add_rows(egrad: &mut [f32], src: &[f32], ctx: &[usize], d: usize) {
    debug_assert_eq!(src.len(), ctx.len() * d);
    for (r, &t) in ctx.iter().enumerate() {
        let dst = &mut egrad[t * d..(t + 1) * d];
        for (a, &b) in dst.iter_mut().zip(&src[r * d..(r + 1) * d]) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xent_of_uniform_logits_is_ln_c() {
        let (n, c) = (4usize, 8usize);
        let logits = vec![0.0f32; n * c];
        let mut probs = vec![0.0f32; n * c];
        let targets = vec![3usize; n];
        let loss = softmax_xent_fwd(&logits, &mut probs, &targets, n, c);
        assert!((loss - (c as f64).ln()).abs() < 1e-6, "{loss}");
        for &p in &probs {
            assert!((p - 1.0 / c as f32).abs() < 1e-6);
        }
        xent_grad_inplace(&mut probs, &targets, n, c);
        // rows of dZ sum to zero and the target entry is negative
        for r in 0..n {
            let row = &probs[r * c..(r + 1) * c];
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-6);
            assert!(row[3] < 0.0);
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let d = 3;
        let table: Vec<f32> = (0..12).map(|x| x as f32).collect(); // 4 rows
        let ctx = vec![2usize, 0, 2];
        let mut x = vec![0.0f32; 9];
        gather_rows(&mut x, &table, &ctx, d);
        assert_eq!(&x[0..3], &[6.0, 7.0, 8.0]);
        assert_eq!(&x[3..6], &[0.0, 1.0, 2.0]);
        let mut eg = vec![0.0f32; 12];
        let src = vec![1.0f32; 9];
        scatter_add_rows(&mut eg, &src, &ctx, d);
        assert_eq!(&eg[6..9], &[2.0, 2.0, 2.0], "row 2 hit twice");
        assert_eq!(&eg[0..3], &[1.0, 1.0, 1.0]);
        assert_eq!(&eg[3..6], &[0.0, 0.0, 0.0]);
        assert!(check_token(5).is_ok());
        assert!(check_token(-1).is_err());
        assert!(check_token(512).is_err());
    }
}
