//! RMSNorm + silu-gated MLP blocks over order-2 context — the arch
//! behind the `llama_*` tags (the transformer's SwiGLU-style FFN
//! sublayer, isolated so the row-norm ablations see MLP behavior
//! separately from attention behavior).
//!
//! Input follows the corpora's order-2 structure: each position embeds
//! its two predecessor tokens, `x = [E[t−1], E[t−2]]`. Per block `i`:
//!
//! ```text
//! N = rmsnorm(a) ⊙ gain_i          (a = x for the first block)
//! a' = silu(N·G_i) ⊙ (N·U_i)       (silu(u) = u·σ(u))
//! ```
//!
//! then `logits = a_last·W_head`. The per-block RMSNorm is what keeps
//! the gated stack depth-stable: silu gating grows activations
//! multiplicatively, so He-initialized unnormalized stacks blow up by
//! `layers = 4` (llama_s1b) — normalizing each block input pins the
//! activation scale at any depth (verified against the numpy oracle
//! during development; `tests/model_grad.rs` holds the gradients).

use crate::data::VOCAB;
use crate::model::common::{check_token, softmax_xent_fwd, xent_grad_inplace};
use crate::model::{
    ArchKind, Batch, BatchShape, ModelArch, ModelSpec, ParamClass, ParamDef, ParamInit,
    TaskGuard, RMS_EPS,
};
use crate::optim::plan::ParamTask;
use crate::tensor::{kernels, Workspace};

/// Layout position of the embedding table.
const E: usize = 0;
/// Parameters per gated block (gain, gate, up).
const PER_BLOCK: usize = 3;

fn gain_i(i: usize) -> usize {
    1 + PER_BLOCK * i
}
fn gate_i(i: usize) -> usize {
    2 + PER_BLOCK * i
}
fn up_i(i: usize) -> usize {
    3 + PER_BLOCK * i
}

#[inline]
fn sigmoid(u: f32) -> f32 {
    1.0 / (1.0 + (-u).exp())
}

/// Stacked silu-gated MLP blocks over order-2 embedded context.
pub struct GatedMlpArch {
    spec: ModelSpec,
    /// Positions per sequence (`seq − 2`: two context tokens each).
    t: usize,
    /// Total positions per batch.
    n: usize,
    /// Previous / previous-previous token per position.
    t1: Vec<usize>,
    t2: Vec<usize>,
    targets: Vec<usize>,
    /// Network input, `n × 2d`.
    x: Vec<f32>,
    /// Per-block normalized inputs (`n × k_i`, `k_0 = 2d`, else `h`).
    norms: Vec<Vec<f32>>,
    /// Per-block gate/up pre-activations and outputs, `n × h` each.
    us: Vec<Vec<f32>>,
    vs: Vec<Vec<f32>>,
    acts: Vec<Vec<f32>>,
    logits: Vec<f32>,
    probs: Vec<f32>,
    // backward scratch
    da: Vec<f32>,
    du: Vec<f32>,
    dv: Vec<f32>,
    dnorm: Vec<f32>,
    dtmp: Vec<f32>,
    ws: Workspace,
}

impl GatedMlpArch {
    fn kdim(&self, i: usize) -> usize {
        if i == 0 {
            2 * self.spec.d_model
        } else {
            self.spec.d_hidden
        }
    }

    /// Preallocate every activation/gradient buffer for `spec`.
    pub fn new(spec: ModelSpec) -> Self {
        // positions() is the single source of the per-arch windowing
        let n = spec.positions();
        let t = n / spec.batch;
        let (d, h, c, l) = (spec.d_model, spec.d_hidden, spec.classes, spec.layers);
        let kmax = (2 * d).max(h);
        GatedMlpArch {
            t,
            n,
            t1: vec![0; n],
            t2: vec![0; n],
            targets: vec![0; n],
            x: vec![0.0f32; n * 2 * d],
            norms: (0..l)
                .map(|i| vec![0.0f32; n * if i == 0 { 2 * d } else { h }])
                .collect(),
            us: (0..l).map(|_| vec![0.0f32; n * h]).collect(),
            vs: (0..l).map(|_| vec![0.0f32; n * h]).collect(),
            acts: (0..l).map(|_| vec![0.0f32; n * h]).collect(),
            logits: vec![0.0f32; n * c],
            probs: vec![0.0f32; n * c],
            da: vec![0.0f32; n * h],
            du: vec![0.0f32; n * h],
            dv: vec![0.0f32; n * h],
            dnorm: vec![0.0f32; n * kmax],
            dtmp: vec![0.0f32; n * kmax],
            ws: Workspace::new(),
            spec,
        }
    }
}

impl ModelArch for GatedMlpArch {
    fn arch(&self) -> ArchKind {
        ArchKind::GatedMlp
    }

    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn batch_shape(&self) -> BatchShape {
        BatchShape::Tokens { rows: self.spec.batch, cols: self.spec.seq }
    }

    fn params(&self) -> Vec<ParamDef> {
        let (d, h) = (self.spec.d_model, self.spec.d_hidden);
        let mut defs = vec![ParamDef::new(
            "embed",
            VOCAB,
            d,
            ParamInit::Randn(1.0),
            ParamClass::Embed,
        )];
        for i in 0..self.spec.layers {
            let k = self.kdim(i);
            let std = (2.0 / k as f32).sqrt();
            defs.push(ParamDef::new(
                format!("h{i}.gain"),
                1,
                k,
                ParamInit::Const(1.0),
                ParamClass::Vector,
            ));
            defs.push(ParamDef::new(
                format!("h{i}.gate"),
                k,
                h,
                ParamInit::Randn(std),
                ParamClass::Matrix,
            ));
            defs.push(ParamDef::new(
                format!("h{i}.up"),
                k,
                h,
                ParamInit::Randn(std),
                ParamClass::Matrix,
            ));
        }
        defs.push(ParamDef::new(
            "head",
            h,
            self.spec.classes,
            ParamInit::Randn(1.0 / (h as f32).sqrt()),
            ParamClass::Head,
        ));
        defs
    }

    fn load_batch(
        &mut self,
        tasks: &[TaskGuard<'_>],
        idx: &[usize],
        batch: &Batch,
    ) -> anyhow::Result<()> {
        let spec = &self.spec;
        let Batch::Tokens(tokens) = batch else {
            anyhow::bail!("gated-MLP arch consumes tokens, got images");
        };
        anyhow::ensure!(
            tokens.len() == spec.batch * spec.seq,
            "token batch has {} ids, model wants {}×{}",
            tokens.len(),
            spec.batch,
            spec.seq
        );
        let mut r = 0usize;
        for b in 0..spec.batch {
            let row = &tokens[b * spec.seq..(b + 1) * spec.seq];
            for j in 2..spec.seq {
                self.t1[r] = check_token(row[j - 1])?;
                self.t2[r] = check_token(row[j - 2])?;
                self.targets[r] = check_token(row[j])?;
                r += 1;
            }
        }
        debug_assert_eq!(r, self.n);
        let d = spec.d_model;
        let embed = tasks[idx[E]].w.data();
        for r in 0..self.n {
            let dst = &mut self.x[r * 2 * d..(r + 1) * 2 * d];
            let (t1, t2) = (self.t1[r], self.t2[r]);
            dst[..d].copy_from_slice(&embed[t1 * d..(t1 + 1) * d]);
            dst[d..].copy_from_slice(&embed[t2 * d..(t2 + 1) * d]);
        }
        Ok(())
    }

    fn forward(&mut self, tasks: &[TaskGuard<'_>], idx: &[usize]) -> f64 {
        let (n, h) = (self.n, self.spec.d_hidden);
        for i in 0..self.spec.layers {
            let k = self.kdim(i);
            {
                let input = if i == 0 { &self.x } else { &self.acts[i - 1] };
                kernels::rmsnorm_into(
                    &mut self.norms[i],
                    input,
                    tasks[idx[gain_i(i)]].w.data(),
                    n,
                    k,
                    RMS_EPS,
                );
            }
            let (gate, up) = (tasks[idx[gate_i(i)]].w.data(), tasks[idx[up_i(i)]].w.data());
            kernels::matmul_into(&mut self.us[i], &self.norms[i], gate, n, k, h);
            kernels::matmul_into(&mut self.vs[i], &self.norms[i], up, n, k, h);
            let (u_i, v_i) = (&self.us[i], &self.vs[i]);
            let a_i = &mut self.acts[i];
            // a = silu(u) ⊙ v, one fused elementwise sweep
            for ((a, &u), &v) in a_i.iter_mut().zip(u_i).zip(v_i) {
                *a = u * sigmoid(u) * v;
            }
        }
        let c = self.spec.classes;
        kernels::matmul_into(
            &mut self.logits,
            &self.acts[self.spec.layers - 1],
            tasks[idx[1 + PER_BLOCK * self.spec.layers]].w.data(),
            n,
            h,
            c,
        );
        softmax_xent_fwd(&self.logits, &mut self.probs, &self.targets, n, c)
    }

    fn backward(&mut self, tasks: &mut [TaskGuard<'_>], idx: &[usize]) {
        let (n, h, c) = (self.n, self.spec.d_hidden, self.spec.classes);
        let layers = self.spec.layers;
        let head = 1 + PER_BLOCK * layers;
        let d = self.spec.d_model;
        xent_grad_inplace(&mut self.probs, &self.targets, n, c);
        {
            let mut at = self.ws.take(h * n);
            kernels::transpose_into(&mut at, &self.acts[layers - 1], n, h);
            kernels::matmul_into(tasks[idx[head]].grad.data_mut(), &at, &self.probs, h, n, c);
            self.ws.give(at);
            let mut ht = self.ws.take(c * h);
            kernels::transpose_into(&mut ht, tasks[idx[head]].w.data(), h, c);
            kernels::matmul_into(&mut self.da, &self.probs, &ht, n, c, h);
            self.ws.give(ht);
        }
        for i in (0..layers).rev() {
            let k = self.kdim(i);
            // du = da ⊙ v ⊙ silu'(u) ; dv = da ⊙ silu(u)
            {
                let (da, u_i, v_i) = (&self.da, &self.us[i], &self.vs[i]);
                let (du, dv) = (&mut self.du, &mut self.dv);
                for j in 0..n * h {
                    let u = u_i[j];
                    let sig = sigmoid(u);
                    du[j] = da[j] * v_i[j] * (sig * (1.0 + u * (1.0 - sig)));
                    dv[j] = da[j] * u * sig;
                }
            }
            // dG = Nᵀ·du ; dU = Nᵀ·dv
            {
                let mut nt = self.ws.take(k * n);
                kernels::transpose_into(&mut nt, &self.norms[i], n, k);
                kernels::matmul_into(tasks[idx[gate_i(i)]].grad.data_mut(), &nt, &self.du, k, n, h);
                kernels::matmul_into(tasks[idx[up_i(i)]].grad.data_mut(), &nt, &self.dv, k, n, h);
                self.ws.give(nt);
            }
            // dN = du·Gᵀ + dv·Uᵀ
            {
                let mut wt = self.ws.take(h * k);
                kernels::transpose_into(&mut wt, tasks[idx[gate_i(i)]].w.data(), k, h);
                kernels::matmul_into(&mut self.dnorm[..n * k], &self.du, &wt, n, h, k);
                kernels::transpose_into(&mut wt, tasks[idx[up_i(i)]].w.data(), k, h);
                kernels::matmul_into(&mut self.dtmp[..n * k], &self.dv, &wt, n, h, k);
                kernels::axpby_inplace(&mut self.dnorm[..n * k], 1.0, &self.dtmp[..n * k], 1.0);
                self.ws.give(wt);
            }
            // through the RMSNorm; the gain grad lands in its task
            {
                let input = if i == 0 { &self.x } else { &self.acts[i - 1] };
                let gt = &mut *tasks[idx[gain_i(i)]];
                let ParamTask { w, grad, .. } = gt;
                kernels::rmsnorm_grad_into(
                    &mut self.dtmp[..n * k],
                    grad.data_mut(),
                    &self.dnorm[..n * k],
                    input,
                    w.data(),
                    n,
                    k,
                    RMS_EPS,
                );
            }
            if i > 0 {
                // k == h here: the block input was the previous activation
                self.da.copy_from_slice(&self.dtmp[..n * h]);
            }
        }
        // embedding scatter: dtmp[..n*2d] holds dX after the i = 0 pass
        let egrad = tasks[idx[E]].grad.data_mut();
        egrad.fill(0.0);
        for r in 0..self.n {
            let src = &self.dtmp[r * 2 * d..(r + 1) * 2 * d];
            let (t1, t2) = (self.t1[r], self.t2[r]);
            for (a, &b) in egrad[t1 * d..(t1 + 1) * d].iter_mut().zip(&src[..d]) {
                *a += b;
            }
            for (a, &b) in egrad[t2 * d..(t2 + 1) * d].iter_mut().zip(&src[d..]) {
                *a += b;
            }
        }
    }
}
