//! Pixel/conv stem — the arch behind the `vision_*` tags.
//!
//! Per image (`hw × hw`, single input channel):
//!
//! ```text
//! F   = relu(conv3x3(x, K))        (C channels, zero padding, stride 1)
//! a   = relu(flatten(F)·W1)        (hw²·C → h)
//! logits = a·W_head                (h → 10 classes)
//! ```
//!
//! The 3×3 kernel bank is stored as a `C × 9` matrix parameter — one row
//! per output channel — so RMNP's row normalization acts per-channel
//! (exactly the per-neuron-norm view the paper's vision ablation needs).
//! The conv is the first layer, so its backward only accumulates the
//! kernel gradient (no input gradient is required), which keeps the
//! stem's loops small enough to stay scalar.

use crate::model::common::{softmax_xent_fwd, xent_grad_inplace};
use crate::model::{
    ArchKind, Batch, BatchShape, ModelArch, ModelSpec, ParamClass, ParamDef, ParamInit, TaskGuard,
};
use crate::tensor::{kernels, Workspace};

/// Layout positions.
const CONV: usize = 0;
const FC: usize = 1;
const HEAD: usize = 2;

/// 3×3 conv stem + FC classifier.
pub struct ConvArch {
    spec: ModelSpec,
    /// Images per batch (one loss position each).
    n: usize,
    targets: Vec<usize>,
    /// Input pixels, `n × hw²`.
    x: Vec<f32>,
    /// Post-ReLU conv features, `n × hw²·C` (channel-major per image).
    feat: Vec<f32>,
    /// Post-ReLU FC activations, `n × h`.
    a1: Vec<f32>,
    logits: Vec<f32>,
    probs: Vec<f32>,
    // backward scratch
    da1: Vec<f32>,
    dfeat: Vec<f32>,
    ws: Workspace,
}

impl ConvArch {
    /// Preallocate every activation/gradient buffer for `spec`.
    pub fn new(spec: ModelSpec) -> Self {
        // positions() is the single source of the per-arch windowing
        let n = spec.positions();
        let px = spec.hw * spec.hw;
        let fdim = px * spec.channels;
        let (h, c) = (spec.d_hidden, spec.classes);
        ConvArch {
            n,
            targets: vec![0; n],
            x: vec![0.0f32; n * px],
            feat: vec![0.0f32; n * fdim],
            a1: vec![0.0f32; n * h],
            logits: vec![0.0f32; n * c],
            probs: vec![0.0f32; n * c],
            da1: vec![0.0f32; n * h],
            dfeat: vec![0.0f32; n * fdim],
            ws: Workspace::new(),
            spec,
        }
    }
}

impl ModelArch for ConvArch {
    fn arch(&self) -> ArchKind {
        ArchKind::Conv
    }

    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn batch_shape(&self) -> BatchShape {
        // `ImageSource` emits `(batch, 3, hw, hw)` RGB planes; the stem is
        // single-channel, so `load_batch` collapses the three planes to one.
        BatchShape::Images {
            batch: self.spec.batch,
            hw: self.spec.hw,
            pixels: self.spec.batch * 3 * self.spec.hw * self.spec.hw,
        }
    }

    fn params(&self) -> Vec<ParamDef> {
        let (hw, ch, h) = (self.spec.hw, self.spec.channels, self.spec.d_hidden);
        let fdim = hw * hw * ch;
        vec![
            ParamDef::new(
                "stem.conv",
                ch,
                9,
                ParamInit::Randn((2.0f32 / 9.0).sqrt()),
                ParamClass::Matrix,
            ),
            ParamDef::new(
                "h0.in",
                fdim,
                h,
                ParamInit::Randn((2.0 / fdim as f32).sqrt()),
                ParamClass::Matrix,
            ),
            ParamDef::new(
                "head",
                h,
                self.spec.classes,
                ParamInit::Randn(1.0 / (h as f32).sqrt()),
                ParamClass::Head,
            ),
        ]
    }

    fn load_batch(
        &mut self,
        _tasks: &[TaskGuard<'_>],
        _idx: &[usize],
        batch: &Batch,
    ) -> anyhow::Result<()> {
        let spec = &self.spec;
        let Batch::Images { images, labels } = batch else {
            anyhow::bail!("conv arch consumes images, got tokens");
        };
        let px = spec.hw * spec.hw;
        anyhow::ensure!(
            images.len() == spec.batch * 3 * px && labels.len() == spec.batch,
            "image batch shape mismatch ({} pixels / {} labels, model wants {}×3×{px} / {})",
            images.len(),
            labels.len(),
            spec.batch,
            spec.batch
        );
        // collapse the RGB planes to the stem's single input channel
        for (b, dst) in self.x.chunks_exact_mut(px).enumerate() {
            let src = &images[b * 3 * px..(b + 1) * 3 * px];
            for (i, d) in dst.iter_mut().enumerate() {
                *d = (src[i] + src[px + i] + src[2 * px + i]) * (1.0 / 3.0);
            }
        }
        for (r, &l) in labels.iter().enumerate() {
            anyhow::ensure!((l as usize) < spec.classes, "label {l} out of range");
            self.targets[r] = l as usize;
        }
        Ok(())
    }

    fn forward(&mut self, tasks: &[TaskGuard<'_>], idx: &[usize]) -> f64 {
        let (hw, ch, h, n) = (self.spec.hw, self.spec.channels, self.spec.d_hidden, self.n);
        let px = hw * hw;
        let fdim = px * ch;
        let kernel = tasks[idx[CONV]].w.data();
        for im in 0..n {
            let x = &self.x[im * px..(im + 1) * px];
            let fimg = &mut self.feat[im * fdim..(im + 1) * fdim];
            for c in 0..ch {
                let krow = &kernel[c * 9..(c + 1) * 9];
                for i in 0..hw {
                    for j in 0..hw {
                        let mut acc = 0.0f32;
                        for u in 0..3usize {
                            let xi = i + u;
                            if !(1..=hw).contains(&xi) {
                                continue; // zero padding (xi-1 out of range)
                            }
                            for v in 0..3usize {
                                let xj = j + v;
                                if !(1..=hw).contains(&xj) {
                                    continue;
                                }
                                acc += krow[u * 3 + v] * x[(xi - 1) * hw + (xj - 1)];
                            }
                        }
                        fimg[c * px + i * hw + j] = acc.max(0.0);
                    }
                }
            }
        }
        kernels::matmul_into(&mut self.a1, &self.feat, tasks[idx[FC]].w.data(), n, fdim, h);
        for a in self.a1.iter_mut() {
            if *a < 0.0 {
                *a = 0.0;
            }
        }
        let c = self.spec.classes;
        kernels::matmul_into(&mut self.logits, &self.a1, tasks[idx[HEAD]].w.data(), n, h, c);
        softmax_xent_fwd(&self.logits, &mut self.probs, &self.targets, n, c)
    }

    fn backward(&mut self, tasks: &mut [TaskGuard<'_>], idx: &[usize]) {
        let (hw, ch, h, n, c) = (
            self.spec.hw,
            self.spec.channels,
            self.spec.d_hidden,
            self.n,
            self.spec.classes,
        );
        let px = hw * hw;
        let fdim = px * ch;
        xent_grad_inplace(&mut self.probs, &self.targets, n, c);
        // head grad + da1 (ReLU-masked)
        {
            let mut at = self.ws.take(h * n);
            kernels::transpose_into(&mut at, &self.a1, n, h);
            kernels::matmul_into(tasks[idx[HEAD]].grad.data_mut(), &at, &self.probs, h, n, c);
            self.ws.give(at);
            let mut wt = self.ws.take(c * h);
            kernels::transpose_into(&mut wt, tasks[idx[HEAD]].w.data(), h, c);
            kernels::matmul_into(&mut self.da1, &self.probs, &wt, n, c, h);
            self.ws.give(wt);
            for (g, &a) in self.da1.iter_mut().zip(&self.a1) {
                if a <= 0.0 {
                    *g = 0.0;
                }
            }
        }
        // FC grad + dfeat (ReLU-masked)
        {
            let mut ft = self.ws.take(fdim * n);
            kernels::transpose_into(&mut ft, &self.feat, n, fdim);
            kernels::matmul_into(tasks[idx[FC]].grad.data_mut(), &ft, &self.da1, fdim, n, h);
            self.ws.give(ft);
            let mut wt = self.ws.take(h * fdim);
            kernels::transpose_into(&mut wt, tasks[idx[FC]].w.data(), fdim, h);
            kernels::matmul_into(&mut self.dfeat, &self.da1, &wt, n, h, fdim);
            self.ws.give(wt);
            for (g, &f) in self.dfeat.iter_mut().zip(&self.feat) {
                if f <= 0.0 {
                    *g = 0.0;
                }
            }
        }
        // conv kernel grad (first layer: no input gradient needed)
        let kgrad = tasks[idx[CONV]].grad.data_mut();
        kgrad.fill(0.0);
        for im in 0..n {
            let x = &self.x[im * px..(im + 1) * px];
            let dimg = &self.dfeat[im * fdim..(im + 1) * fdim];
            for c in 0..ch {
                let krow = &mut kgrad[c * 9..(c + 1) * 9];
                for i in 0..hw {
                    for j in 0..hw {
                        let g = dimg[c * px + i * hw + j];
                        if g == 0.0 {
                            continue;
                        }
                        for u in 0..3usize {
                            let xi = i + u;
                            if !(1..=hw).contains(&xi) {
                                continue;
                            }
                            for v in 0..3usize {
                                let xj = j + v;
                                if !(1..=hw).contains(&xj) {
                                    continue;
                                }
                                krow[u * 3 + v] += g * x[(xi - 1) * hw + (xj - 1)];
                            }
                        }
                    }
                }
            }
        }
    }
}
