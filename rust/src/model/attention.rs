//! Causal single-head attention blocks — the arch behind the `gpt2_*`
//! tags.
//!
//! Per block `b` (stacked `spec.layers` deep, all at width `d_model`):
//!
//! ```text
//! Xn   = rmsnorm(X) ⊙ gain_b
//! Q    = Xn·Wq   K = Xn·Wk   V = Xn·Wv
//! A    = row_softmax(causal_mask(Q·Kᵀ / √d))      (per sequence, T×T)
//! X'   = X + (A·V)·Wo                             (residual)
//! ```
//!
//! then `logits = X_last·W_head`, softmax cross-entropy against the next
//! token: position `j` of each sequence predicts token `j+1`, so a
//! `seq`-token batch row yields `T = seq−1` training positions with full
//! causal context — real attention structure instead of the fixed
//! order-2 window the pre-model-layer MLP used.
//!
//! The projections and their gradients run as full-batch matmuls on the
//! kernel layer; only the `T×T` score/softmax pieces loop per sequence.
//! The causal mask writes `−inf` into the score buffer, which
//! [`kernels::row_softmax_into`] turns into exactly-zero probabilities —
//! and exactly-zero gradients in the backward sweep, so masking needs no
//! special handling anywhere else.

use crate::data::VOCAB;
use crate::model::common::{
    check_token, gather_rows, scatter_add_rows, softmax_xent_fwd, xent_grad_inplace,
};
use crate::model::{
    ArchKind, Batch, BatchShape, ModelArch, ModelSpec, ParamClass, ParamDef, ParamInit,
    TaskGuard, RMS_EPS,
};
use crate::optim::plan::ParamTask;
use crate::tensor::{kernels, Workspace};

/// Layout position of the embedding table.
const E: usize = 0;
/// Parameters per attention block (gain, wq, wk, wv, wo).
const PER_BLOCK: usize = 5;

fn gain_i(b: usize) -> usize {
    1 + PER_BLOCK * b
}
fn wq_i(b: usize) -> usize {
    2 + PER_BLOCK * b
}
fn wk_i(b: usize) -> usize {
    3 + PER_BLOCK * b
}
fn wv_i(b: usize) -> usize {
    4 + PER_BLOCK * b
}
fn wo_i(b: usize) -> usize {
    5 + PER_BLOCK * b
}

/// Stacked causal attention blocks with a tied softmax-CE head.
pub struct AttentionArch {
    spec: ModelSpec,
    /// Input positions per sequence (`seq − 1`).
    t: usize,
    /// Total positions per batch (`batch · t`).
    n: usize,
    /// Context token per position (for the embedding scatter).
    ctx: Vec<usize>,
    /// Target class per position.
    targets: Vec<usize>,
    /// Block inputs: `xs[0]` is the embedding output, `xs[b+1]` the
    /// residual output of block `b`. Each `n × d`.
    xs: Vec<Vec<f32>>,
    /// Saved per-block activations (`n × d` each).
    xn: Vec<Vec<f32>>,
    q: Vec<Vec<f32>>,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    ctxv: Vec<Vec<f32>>,
    /// Saved attention probabilities per block, `batch · T × T`.
    att: Vec<Vec<f32>>,
    logits: Vec<f32>,
    probs: Vec<f32>,
    /// Per-sequence score scratch (`T × T`).
    sc: Vec<f32>,
    // backward scratch, `n × d` each
    dx: Vec<f32>,
    dxn: Vec<f32>,
    dq: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
    dctx: Vec<f32>,
    dtmp: Vec<f32>,
    // per-sequence backward scratch, `T × T` each
    datt: Vec<f32>,
    dsc: Vec<f32>,
    ws: Workspace,
}

impl AttentionArch {
    /// Preallocate every activation/gradient buffer for `spec`.
    pub fn new(spec: ModelSpec) -> Self {
        // positions() is the single source of the per-arch windowing
        let n = spec.positions();
        let t = n / spec.batch;
        let d = spec.d_model;
        let c = spec.classes;
        let l = spec.layers;
        let nd = || vec![0.0f32; n * d];
        AttentionArch {
            t,
            n,
            ctx: vec![0; n],
            targets: vec![0; n],
            xs: (0..=l).map(|_| nd()).collect(),
            xn: (0..l).map(|_| nd()).collect(),
            q: (0..l).map(|_| nd()).collect(),
            k: (0..l).map(|_| nd()).collect(),
            v: (0..l).map(|_| nd()).collect(),
            ctxv: (0..l).map(|_| nd()).collect(),
            att: (0..l).map(|_| vec![0.0f32; spec.batch * t * t]).collect(),
            logits: vec![0.0f32; n * c],
            probs: vec![0.0f32; n * c],
            sc: vec![0.0f32; t * t],
            dx: nd(),
            dxn: nd(),
            dq: nd(),
            dk: nd(),
            dv: nd(),
            dctx: nd(),
            dtmp: nd(),
            datt: vec![0.0f32; t * t],
            dsc: vec![0.0f32; t * t],
            ws: Workspace::new(),
            spec,
        }
    }
}

impl ModelArch for AttentionArch {
    fn arch(&self) -> ArchKind {
        ArchKind::Attention
    }

    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn batch_shape(&self) -> BatchShape {
        BatchShape::Tokens { rows: self.spec.batch, cols: self.spec.seq }
    }

    fn params(&self) -> Vec<ParamDef> {
        let d = self.spec.d_model;
        let sd = 1.0 / (d as f32).sqrt();
        let mut defs = vec![ParamDef::new(
            "embed",
            VOCAB,
            d,
            ParamInit::Randn(1.0),
            ParamClass::Embed,
        )];
        for b in 0..self.spec.layers {
            defs.push(ParamDef::new(
                format!("blk{b}.gain"),
                1,
                d,
                ParamInit::Const(1.0),
                ParamClass::Vector,
            ));
            for (suffix, std) in [("wq", sd), ("wk", sd), ("wv", sd), ("wo", 0.5 * sd)] {
                defs.push(ParamDef::new(
                    format!("blk{b}.{suffix}"),
                    d,
                    d,
                    ParamInit::Randn(std),
                    ParamClass::Matrix,
                ));
            }
        }
        defs.push(ParamDef::new(
            "head",
            d,
            self.spec.classes,
            ParamInit::Randn(sd),
            ParamClass::Head,
        ));
        defs
    }

    fn load_batch(
        &mut self,
        tasks: &[TaskGuard<'_>],
        idx: &[usize],
        batch: &Batch,
    ) -> anyhow::Result<()> {
        let spec = &self.spec;
        let Batch::Tokens(tokens) = batch else {
            anyhow::bail!("attention arch consumes tokens, got images");
        };
        anyhow::ensure!(
            tokens.len() == spec.batch * spec.seq,
            "token batch has {} ids, model wants {}×{}",
            tokens.len(),
            spec.batch,
            spec.seq
        );
        let t = self.t;
        let mut r = 0usize;
        for b in 0..spec.batch {
            let row = &tokens[b * spec.seq..(b + 1) * spec.seq];
            for j in 0..t {
                self.ctx[r] = check_token(row[j])?;
                self.targets[r] = check_token(row[j + 1])?;
                r += 1;
            }
        }
        debug_assert_eq!(r, self.n);
        let embed = tasks[idx[E]].w.data();
        gather_rows(&mut self.xs[0], embed, &self.ctx, spec.d_model);
        Ok(())
    }

    fn forward(&mut self, tasks: &[TaskGuard<'_>], idx: &[usize]) -> f64 {
        let (d, t, n) = (self.spec.d_model, self.t, self.n);
        let alpha = 1.0 / (d as f32).sqrt();
        for b in 0..self.spec.layers {
            kernels::rmsnorm_into(
                &mut self.xn[b],
                &self.xs[b],
                tasks[idx[gain_i(b)]].w.data(),
                n,
                d,
                RMS_EPS,
            );
            let (wq, wk, wv) = (
                tasks[idx[wq_i(b)]].w.data(),
                tasks[idx[wk_i(b)]].w.data(),
                tasks[idx[wv_i(b)]].w.data(),
            );
            kernels::matmul_into(&mut self.q[b], &self.xn[b], wq, n, d, d);
            kernels::matmul_into(&mut self.k[b], &self.xn[b], wk, n, d, d);
            kernels::matmul_into(&mut self.v[b], &self.xn[b], wv, n, d, d);
            for s in 0..self.spec.batch {
                let off = s * t * d;
                let aoff = s * t * t;
                // scores = (Q·Kᵀ)·α with the causal mask, per sequence
                let mut kt = self.ws.take(d * t);
                kernels::transpose_into(&mut kt, &self.k[b][off..off + t * d], t, d);
                kernels::matmul_into(&mut self.sc, &self.q[b][off..off + t * d], &kt, t, d, t);
                self.ws.give(kt);
                for x in self.sc.iter_mut() {
                    *x *= alpha;
                }
                for i in 0..t {
                    for j in i + 1..t {
                        self.sc[i * t + j] = f32::NEG_INFINITY;
                    }
                }
                kernels::row_softmax_into(&mut self.att[b][aoff..aoff + t * t], &self.sc, t, t);
                kernels::matmul_into(
                    &mut self.ctxv[b][off..off + t * d],
                    &self.att[b][aoff..aoff + t * t],
                    &self.v[b][off..off + t * d],
                    t,
                    t,
                    d,
                );
            }
            // residual: xs[b+1] = xs[b] + ctxv·Wo
            let wo = tasks[idx[wo_i(b)]].w.data();
            kernels::matmul_into(&mut self.dtmp, &self.ctxv[b], wo, n, d, d);
            let (lower, upper) = self.xs.split_at_mut(b + 1);
            kernels::axpby_into(&mut upper[0], 1.0, &lower[b], 1.0, &self.dtmp);
        }
        let c = self.spec.classes;
        kernels::matmul_into(
            &mut self.logits,
            &self.xs[self.spec.layers],
            tasks[idx[1 + PER_BLOCK * self.spec.layers]].w.data(),
            n,
            d,
            c,
        );
        softmax_xent_fwd(&self.logits, &mut self.probs, &self.targets, n, c)
    }

    fn backward(&mut self, tasks: &mut [TaskGuard<'_>], idx: &[usize]) {
        let (d, t, n, c) = (self.spec.d_model, self.t, self.n, self.spec.classes);
        let layers = self.spec.layers;
        let head = 1 + PER_BLOCK * layers;
        let alpha = 1.0 / (d as f32).sqrt();
        xent_grad_inplace(&mut self.probs, &self.targets, n, c);
        // dW_head = X_lastᵀ · dZ ; dX = dZ · W_headᵀ
        {
            let mut xt = self.ws.take(d * n);
            kernels::transpose_into(&mut xt, &self.xs[layers], n, d);
            kernels::matmul_into(tasks[idx[head]].grad.data_mut(), &xt, &self.probs, d, n, c);
            self.ws.give(xt);
            let mut ht = self.ws.take(c * d);
            kernels::transpose_into(&mut ht, tasks[idx[head]].w.data(), d, c);
            kernels::matmul_into(&mut self.dx, &self.probs, &ht, n, c, d);
            self.ws.give(ht);
        }
        for b in (0..layers).rev() {
            // attention branch: dO = dx (the residual keeps dx intact
            // until the norm contribution is added at the end)
            {
                let mut ct = self.ws.take(d * n);
                kernels::transpose_into(&mut ct, &self.ctxv[b], n, d);
                kernels::matmul_into(tasks[idx[wo_i(b)]].grad.data_mut(), &ct, &self.dx, d, n, d);
                self.ws.give(ct);
                let mut wt = self.ws.take(d * d);
                kernels::transpose_into(&mut wt, tasks[idx[wo_i(b)]].w.data(), d, d);
                kernels::matmul_into(&mut self.dctx, &self.dx, &wt, n, d, d);
                self.ws.give(wt);
            }
            for s in 0..self.spec.batch {
                let off = s * t * d;
                let aoff = s * t * t;
                // dA = dCtx·Vᵀ ; dV = Aᵀ·dCtx
                let mut vt = self.ws.take(d * t);
                kernels::transpose_into(&mut vt, &self.v[b][off..off + t * d], t, d);
                kernels::matmul_into(&mut self.datt, &self.dctx[off..off + t * d], &vt, t, d, t);
                self.ws.give(vt);
                let mut at = self.ws.take(t * t);
                kernels::transpose_into(&mut at, &self.att[b][aoff..aoff + t * t], t, t);
                kernels::matmul_into(
                    &mut self.dv[off..off + t * d],
                    &at,
                    &self.dctx[off..off + t * d],
                    t,
                    t,
                    d,
                );
                self.ws.give(at);
                // through the softmax, then the 1/√d scale
                kernels::row_softmax_grad_into(
                    &mut self.dsc,
                    &self.att[b][aoff..aoff + t * t],
                    &self.datt,
                    t,
                    t,
                );
                for x in self.dsc.iter_mut() {
                    *x *= alpha;
                }
                // dQ = dS·K ; dK = dSᵀ·Q
                kernels::matmul_into(
                    &mut self.dq[off..off + t * d],
                    &self.dsc,
                    &self.k[b][off..off + t * d],
                    t,
                    t,
                    d,
                );
                let mut st = self.ws.take(t * t);
                kernels::transpose_into(&mut st, &self.dsc, t, t);
                kernels::matmul_into(
                    &mut self.dk[off..off + t * d],
                    &st,
                    &self.q[b][off..off + t * d],
                    t,
                    t,
                    d,
                );
                self.ws.give(st);
            }
            // projection weight grads: dW• = Xnᵀ · d•  (full batch)
            {
                let mut xnt = self.ws.take(d * n);
                kernels::transpose_into(&mut xnt, &self.xn[b], n, d);
                kernels::matmul_into(tasks[idx[wq_i(b)]].grad.data_mut(), &xnt, &self.dq, d, n, d);
                kernels::matmul_into(tasks[idx[wk_i(b)]].grad.data_mut(), &xnt, &self.dk, d, n, d);
                kernels::matmul_into(tasks[idx[wv_i(b)]].grad.data_mut(), &xnt, &self.dv, d, n, d);
                self.ws.give(xnt);
            }
            // dXn = dQ·Wqᵀ + dK·Wkᵀ + dV·Wvᵀ
            {
                let mut wt = self.ws.take(d * d);
                kernels::transpose_into(&mut wt, tasks[idx[wq_i(b)]].w.data(), d, d);
                kernels::matmul_into(&mut self.dxn, &self.dq, &wt, n, d, d);
                kernels::transpose_into(&mut wt, tasks[idx[wk_i(b)]].w.data(), d, d);
                kernels::matmul_into(&mut self.dtmp, &self.dk, &wt, n, d, d);
                kernels::axpby_inplace(&mut self.dxn, 1.0, &self.dtmp, 1.0);
                kernels::transpose_into(&mut wt, tasks[idx[wv_i(b)]].w.data(), d, d);
                kernels::matmul_into(&mut self.dtmp, &self.dv, &wt, n, d, d);
                kernels::axpby_inplace(&mut self.dxn, 1.0, &self.dtmp, 1.0);
                self.ws.give(wt);
            }
            // through the RMSNorm (gain grad lands in the task), then add
            // the residual passthrough: dX_b = dX_{b+1} + d(norm branch)
            {
                let gt = &mut *tasks[idx[gain_i(b)]];
                let ParamTask { w, grad, .. } = gt;
                kernels::rmsnorm_grad_into(
                    &mut self.dtmp,
                    grad.data_mut(),
                    &self.dxn,
                    &self.xs[b],
                    w.data(),
                    n,
                    d,
                    RMS_EPS,
                );
            }
            kernels::axpby_inplace(&mut self.dx, 1.0, &self.dtmp, 1.0);
        }
        // embedding scatter
        let egrad = tasks[idx[E]].grad.data_mut();
        egrad.fill(0.0);
        scatter_add_rows(egrad, &self.dx, &self.ctx, d);
    }
}
